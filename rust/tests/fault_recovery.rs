//! Integration tests: fault tolerance end to end — kill one worker
//! mid-job, detect it within the miss threshold, re-deal onto the
//! survivors, and land bit-identical to a clean run on those ranks.
//!
//! Exercises the full chaos choreography from `distarray::fault` for
//! every element type, plus the checkpoint/restore round-trip at the
//! shard-codec level.

use distarray::element::Element;
use distarray::fault::{read_shard, run_chaos, shard_path, write_shard, DetectorConfig};
use std::time::Duration;

/// A fast detector so the suite stays sub-second: 10 ms rounds,
/// 3 misses to a verdict.
fn fast() -> DetectorConfig {
    DetectorConfig { interval: Duration::from_millis(10), miss_threshold: 3 }
}

/// Kill rank `victim` of `np` and require: detection within the miss
/// threshold (plus the scenario's settle slack), the right survivor
/// list, and bit-identical recovery.
fn chaos_case<T: Element>(np: usize, victim: usize, n: usize) {
    let cfg = fast();
    let slack = cfg.miss_threshold as u64 + 8;
    let r = run_chaos::<T>(np, victim, n, cfg).unwrap();
    assert_eq!(r.killed, victim);
    let want: Vec<usize> = (0..np).filter(|&p| p != victim).collect();
    assert_eq!(r.survivors, want);
    assert_eq!(r.n_global, n);
    assert!(
        r.probe_rounds <= slack,
        "{}: detection took {} rounds, threshold {}",
        T::DTYPE,
        r.probe_rounds,
        slack
    );
    assert!(r.bit_identical, "{}: survivors diverged from the clean reference", T::DTYPE);
}

#[test]
fn kill_one_worker_recovers_f64() {
    chaos_case::<f64>(4, 2, 4096);
}

#[test]
fn kill_one_worker_recovers_f32() {
    chaos_case::<f32>(4, 1, 4096);
}

#[test]
fn kill_one_worker_recovers_i64() {
    chaos_case::<i64>(4, 3, 4096);
}

#[test]
fn kill_one_worker_recovers_u64() {
    chaos_case::<u64>(4, 2, 4096);
}

#[test]
fn kill_last_worker_of_two() {
    // The smallest world that can lose a worker: 2 ranks, leader
    // carries on alone.
    chaos_case::<f64>(2, 1, 1024);
}

#[test]
fn uneven_global_length_survives_the_redeal() {
    // A length that divides evenly into neither 4 nor 3 blocks — the
    // redeal crosses every block boundary.
    chaos_case::<f64>(4, 2, 1003);
}

#[test]
fn checkpoint_round_trip_is_bit_identical() {
    let dir = std::env::temp_dir().join(format!("distarray_faultrec_{}", std::process::id()));
    let sections = [vec![1.5f64; 1024], vec![-2.25f64; 1024]];
    write_shard::<f64>(&dir, 1, 4, 7, 4096, &[&sections[0], &sections[1]]).unwrap();
    let back = read_shard::<f64>(&dir, 1).unwrap();
    assert_eq!((back.pid, back.np, back.epoch, back.n_global), (1, 4, 7, 4096));
    assert_eq!(back.sections, sections);

    // Corruption is a one-line error, not a bad restore: flip a data
    // byte and the CRC must reject the shard.
    let path = shard_path(&dir, 1);
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    std::fs::write(&path, &bytes).unwrap();
    let err = read_shard::<f64>(&dir, 1).unwrap_err();
    assert!(err.to_string().contains("ckpt_v1"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}
