//! Property tests for the incremental JSON push parser: the event
//! stream is invariant to where the input is split, malformed and
//! truncated input fails with an error (never a panic, a hang, or an
//! unbounded buffer), and NDJSON folding stays bounded-memory at
//! multi-MB scale.

use distarray::json::{Json, JsonEvent, PushParser, StreamDocs, MAX_DEPTH};
use distarray::prop::{forall, Rng};

/// Owned rendering of one parse event, for comparing streams.
fn own(ev: &JsonEvent<'_>) -> String {
    match ev {
        JsonEvent::ObjBegin => "{".into(),
        JsonEvent::ObjEnd => "}".into(),
        JsonEvent::ArrBegin => "[".into(),
        JsonEvent::ArrEnd => "]".into(),
        JsonEvent::Key(k) => format!("K:{k}"),
        JsonEvent::Str(s) => format!("S:{s}"),
        JsonEvent::Num(n) => format!("N:{n}"),
        JsonEvent::Bool(b) => format!("B:{b}"),
        JsonEvent::Null => "null".into(),
    }
}

/// Parse `text` fed as the slices delimited by ascending `cuts`
/// (byte offsets; may split multi-byte UTF-8 sequences and tokens).
fn parse_split(text: &str, cuts: &[usize]) -> Result<Vec<String>, distarray::json::JsonError> {
    let bytes = text.as_bytes();
    let mut p = PushParser::new();
    let mut out = Vec::new();
    let mut start = 0;
    for &c in cuts.iter().chain(std::iter::once(&bytes.len())) {
        let c = c.min(bytes.len());
        p.feed(&bytes[start..c], |ev| out.push(own(&ev)))?;
        start = c;
    }
    p.finish(|ev| out.push(own(&ev)))?;
    Ok(out)
}

/// Documents covering every token kind, escapes, multi-byte UTF-8
/// (splitting mid-character must not change the result), nesting, and
/// NDJSON-style multiple top-level values.
const CORPUS: [&str; 8] = [
    r#"{"a":1,"b":[true,false,null],"c":{"d":"e"}}"#,
    r#"[1.5e-3,-7,0.25,1e9,[],{}]"#,
    "{\"esc\":\"a\\\"b\\\\c\\n\\u0041\\u00e9\",\"t\":\"tab\\there\"}",
    "{\"unicode\":\"héllo wörld — ∑π≈3\"}",
    "  [ { \"spaced\" : [ 1 , 2 ] } , \"x\" ]  ",
    "{\"line\":1}\n{\"line\":2}\n{\"line\":3}\n",
    r#"{"deep":[[[[{"k":[[[1]]]}]]]]}"#,
    "3.14159",
];

#[test]
fn every_byte_boundary_split_equals_whole_parse() {
    for doc in CORPUS {
        let whole = parse_split(doc, &[]).expect("corpus doc parses whole");
        assert!(!whole.is_empty());
        for k in 1..doc.len() {
            let split = parse_split(doc, &[k])
                .unwrap_or_else(|e| panic!("split at {k} of {doc:?} failed: {e}"));
            assert_eq!(split, whole, "split at byte {k} of {doc:?} diverged");
        }
    }
}

#[test]
fn seven_byte_slices_equal_whole_parse() {
    for doc in CORPUS {
        let whole = parse_split(doc, &[]).unwrap();
        let cuts: Vec<usize> = (1..doc.len()).filter(|k| k % 7 == 0).collect();
        assert_eq!(parse_split(doc, &cuts).unwrap(), whole, "7-byte slices of {doc:?}");
    }
}

/// Random documents, random cut points: the event stream never
/// depends on the chunking. The whole-parse reference is
/// [`Json::parse`] round-tripped through `Display`, so the push
/// parser is also checked against the whole-document grammar.
#[test]
fn random_docs_random_cuts_match_whole_document_parser() {
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth >= 3 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Num((rng.below(2000) as f64 - 1000.0) / 8.0),
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Null,
            3 => Json::Str(format!("s{}—π{}", rng.below(100), rng.below(10))),
            4 => Json::Arr((0..rng.below(4)).map(|_| gen(rng, depth + 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), gen(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    forall(40, 0xDA7A_57AE, |rng| {
        let doc = gen(rng, 0);
        let text = doc.to_string();
        let whole = parse_split(&text, &[]).expect("rendered doc parses");
        let mut cuts: Vec<usize> = (0..rng.below(6)).map(|_| 1 + rng.below(text.len().max(2) - 1)).collect();
        cuts.sort_unstable();
        cuts.dedup();
        assert_eq!(parse_split(&text, &cuts).unwrap(), whole, "doc {text:?} cuts {cuts:?}");
        // And the parse agrees with the whole-document API.
        assert!(Json::parse(&text).is_ok());
    });
}

#[test]
fn malformed_input_errors_and_poisons_never_panics() {
    let bad = [
        "{",
        "[1,",
        "\"abc",
        "{\"a\":}",
        "{\"a\" 1}",
        "{:1}",
        "[1 2]",
        "tru",
        "nul",
        "1e",
        "{\"a\":1}}",
        "]",
        "}",
        ",",
        "\"\\u12\"x",
        "\u{FFFD}",
    ];
    for doc in bad {
        let mut p = PushParser::new();
        let mut r = p.feed(doc.as_bytes(), |_| {});
        if r.is_ok() {
            r = p.finish(|_| {});
        }
        assert!(r.is_err(), "malformed {doc:?} must error");
        // Poisoned: later feeds keep failing instead of resynchronizing
        // into garbage.
        assert!(p.feed(b"1", |_| {}).is_err(), "{doc:?} must poison the parser");
    }
}

#[test]
fn truncation_at_every_boundary_errors_or_parses_a_prefix() {
    // Chopping a valid document anywhere must either finish with an
    // error (truncated token/container) or succeed because the prefix
    // happens to be a complete value run — never panic or hang.
    let doc = r#"{"a":[1,2,{"b":"c\u0041"}],"d":true}"#;
    for k in 0..doc.len() {
        let mut p = PushParser::new();
        let pre = &doc.as_bytes()[..k];
        if p.feed(pre, |_| {}).is_ok() {
            let _ = p.finish(|_| {});
        }
    }
}

#[test]
fn nesting_beyond_max_depth_is_an_error_not_a_crash() {
    let deep = "[".repeat(MAX_DEPTH + 8);
    let mut p = PushParser::new();
    let err = p.feed(deep.as_bytes(), |_| {}).expect_err("over-deep input must error");
    assert!(err.msg.contains("deep"), "unexpected error: {err}");
}

#[test]
fn unterminated_token_buffers_only_what_was_fed() {
    // An adversarial never-ending string may buffer the token itself,
    // but nothing more — no amplification, no resynthesis.
    let mut p = PushParser::new();
    p.feed(b"\"", |_| {}).unwrap();
    let chunk = vec![b'x'; 64 * 1024];
    for _ in 0..16 {
        p.feed(&chunk, |_| {}).unwrap();
    }
    let fed = 1 + 16 * chunk.len();
    assert!(p.buffered_bytes() <= fed, "buffered {} > fed {fed}", p.buffered_bytes());
    assert!(p.buffered_bytes() >= 16 * chunk.len(), "token must be retained until it closes");
    assert!(p.finish(|_| {}).is_err(), "unterminated string is truncated input");
}

#[test]
fn multi_mb_ndjson_in_seven_byte_slices_stays_bounded() {
    // A synthetic multi-MB report: thousands of ~200 B lines. Folding
    // through StreamDocs in 7-byte slices must keep peak resident
    // parse memory near the largest line, not the document total.
    let line = |i: usize| {
        format!(
            "{{\"schema\":\"trace_event_v1\",\"kind\":\"chunk_send\",\"rank\":{},\"t_ns\":{},\
             \"dur_ns\":12,\"peer\":{},\"bytes\":65536,\"chunk\":{},\"pad\":\"{}\"}}\n",
            i % 8,
            i * 1000,
            (i + 1) % 8,
            i,
            "p".repeat(100)
        )
    };
    let mut text = String::new();
    let mut n_lines = 0;
    while text.len() < 2 * 1024 * 1024 {
        text.push_str(&line(n_lines));
        n_lines += 1;
    }
    let max_line = text.lines().map(str::len).max().unwrap();
    let mut docs = StreamDocs::new();
    let mut seen = 0usize;
    for chunk in text.as_bytes().chunks(7) {
        docs.feed(chunk, |_| seen += 1).unwrap();
    }
    docs.finish(|_| seen += 1).unwrap();
    assert_eq!(seen, n_lines, "every NDJSON line folds to one document");
    assert_eq!(docs.docs(), n_lines);
    assert!(
        docs.peak_resident_bytes() <= 4 * max_line + 1024,
        "peak resident {} B not bounded by the largest line ({max_line} B) on a {} B stream",
        docs.peak_resident_bytes(),
        text.len()
    );
}
