//! Integration tests over the file-based messaging transport — the
//! paper's cross-process aggregation path [44] — including failure
//! injection.

use distarray::comm::{CommError, FileTransport, Transport};
use distarray::coordinator::{run_leader, run_worker, EngineKind, MapKind, RunConfig};
use distarray::darray::Darray;
use distarray::dmap::Dmap;
use distarray::stream::STREAM_Q;
use std::path::PathBuf;
use std::thread;
use std::time::Duration;

fn spool(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("distarray_it_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Full coordinator protocol over files (threads standing in for OS
/// processes; the on-disk protocol is identical).
#[test]
fn coordinator_over_file_transport() {
    let dir = spool("coord");
    let np = 3;
    let mut hs = Vec::new();
    for pid in 1..np {
        let dir = dir.clone();
        hs.push(thread::spawn(move || {
            let t = FileTransport::new(&dir, pid, np).unwrap();
            run_worker(&t).unwrap()
        }));
    }
    let leader = FileTransport::new(&dir, 0, np).unwrap();
    let cfg = RunConfig {
        n_global: 30_000,
        nt: 2,
        q: STREAM_Q,
        map: MapKind::Block,
        engine: EngineKind::Native,
        dtype: distarray::element::Dtype::F64,
        backend: distarray::backend::BackendKind::Host,
        threads: 1,
        coll: distarray::collective::CollKind::Star,
        nppn: 0,
        chunk_bytes: 0,
        artifacts: "artifacts".into(),
        trace: false,
        heartbeat: false,
        checkpoint: String::new(),
        restore: false,
        transport: distarray::comm::TransportKind::File,
        recv_timeout_ms: 0,
    };
    let (agg, _) = run_leader(&leader, &cfg).unwrap();
    for h in hs {
        assert!(h.join().unwrap().passed);
    }
    assert!(agg.all_valid);
    // Spool drained: every message consumed.
    assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 0);
    std::fs::remove_dir_all(&dir).ok();
}

/// Remap (data-heavy path) works across the file transport too.
#[test]
fn remap_over_files() {
    let dir = spool("remap");
    let np = 3;
    let n = 5_000;
    let mut hs = Vec::new();
    for pid in 0..np {
        let dir = dir.clone();
        hs.push(thread::spawn(move || {
            let t = FileTransport::new(&dir, pid, np).unwrap();
            let src = Darray::from_global_fn(Dmap::block_1d(np), &[n], pid, |g| g as f64);
            let mut dst = Darray::zeros(Dmap::cyclic_1d(np), &[n], pid);
            dst.assign_from(&src, &t, 0).unwrap();
            for g in 0..n {
                if let Some(v) = dst.global_get(g) {
                    assert_eq!(v, g as f64);
                }
            }
        }));
    }
    for h in hs {
        h.join().unwrap();
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// FAILURE INJECTION: a missing worker surfaces as a leader timeout,
/// not a hang or corruption.
#[test]
fn leader_times_out_on_dead_worker() {
    let dir = spool("dead");
    let leader = FileTransport::new(&dir, 0, 2).unwrap();
    // No worker process ever starts. The recv must time out.
    let err = leader.recv_timeout(1, distarray::comm::tags::RESULT, Duration::from_millis(50));
    assert!(matches!(err, Err(CommError::Timeout { from: 1, .. })));
    std::fs::remove_dir_all(&dir).ok();
}

/// FAILURE INJECTION: a corrupted payload decodes to an error, not a
/// panic or silent garbage.
#[test]
fn corrupt_payload_is_decode_error() {
    use distarray::comm::Decode;
    let dir = spool("corrupt");
    let a = FileTransport::new(&dir, 0, 2).unwrap();
    let b = FileTransport::new(&dir, 1, 2).unwrap();
    a.send(1, distarray::comm::tags::CONFIG, b"garbage!").unwrap();
    let payload = b.recv(0, distarray::comm::tags::CONFIG).unwrap();
    let decoded = RunConfig::from_bytes(&payload);
    assert!(decoded.is_err());
    std::fs::remove_dir_all(&dir).ok();
}

/// FAILURE INJECTION: truncated message file cannot happen (atomic
/// rename), but a *delayed* writer must not lose the message: a recv
/// that times out once still receives the late message on retry.
#[test]
fn late_message_recovered_after_timeout() {
    let dir = spool("late");
    let b = FileTransport::new(&dir, 1, 2).unwrap().with_poll(Duration::from_micros(100));
    assert!(b.recv_timeout(0, 42, Duration::from_millis(10)).is_err());
    let a = FileTransport::new(&dir, 0, 2).unwrap();
    a.send(1, 42, b"late but intact").unwrap();
    assert_eq!(b.recv(0, 42).unwrap(), b"late but intact");
    std::fs::remove_dir_all(&dir).ok();
}

/// Concurrent many-to-one aggregation (the paper's result collection)
/// under heavy interleaving.
#[test]
fn many_to_one_aggregation_stress() {
    let dir = spool("stress");
    let np = 8;
    let msgs_per_worker = 50;
    let mut hs = Vec::new();
    for pid in 1..np {
        let dir = dir.clone();
        hs.push(thread::spawn(move || {
            let t = FileTransport::new(&dir, pid, np).unwrap();
            for i in 0..msgs_per_worker {
                let payload = format!("{pid}:{i}");
                t.send(0, 7, payload.as_bytes()).unwrap();
            }
        }));
    }
    let leader = FileTransport::new(&dir, 0, np).unwrap().with_poll(Duration::from_micros(100));
    for pid in 1..np {
        for i in 0..msgs_per_worker {
            let got = leader.recv(pid, 7).unwrap();
            assert_eq!(String::from_utf8(got).unwrap(), format!("{pid}:{i}"), "ordering broken");
        }
    }
    for h in hs {
        h.join().unwrap();
    }
    std::fs::remove_dir_all(&dir).ok();
}
