//! Backend-equivalence properties: every registered (available)
//! execution backend must produce validation-identical STREAM results
//! and bit-identical remap outcomes vs the serial reference, for every
//! sealed dtype — and remap plans executed through
//! `Backend::execute_plan` must plan exactly once per key.

use distarray::backend::{
    run_stream_t, Backend, BackendKind, BackendRegistry, ChunkedThreadedBackend, HostBackend,
};
use distarray::comm::{ChannelHub, Transport};
use distarray::darray::{DarrayT, RemapEngine};
use distarray::dmap::{Dist, Dmap, Grid, Overlap};
use distarray::element::Element;
use distarray::prop::{forall, Rng};
use distarray::stream::{run_serial_t, STREAM_Q};
use std::sync::Arc;

fn registry() -> BackendRegistry {
    // 3 threads: uneven against most vector lengths, so chunk seams
    // are exercised.
    BackendRegistry::with_defaults(3, "artifacts")
}

/// STREAM on every available backend must match the serial reference's
/// validation *exactly* (same element-wise arithmetic ⇒ bit-identical
/// final vectors ⇒ identical max deviations from the closed forms).
fn stream_equivalence_case<T: Element>(n: usize, nt: usize, q: T) {
    let reference = run_serial_t::<T>(n, nt, q);
    let reg = registry();
    let map = Dmap::block_1d(1);
    let mut covered = 0;
    for be in reg.available() {
        // Capability gate: a backend that declares it cannot run this
        // dtype/length combination (e.g. pjrt with f32, or a length
        // off the artifact grid in a `pjrt`-feature build) is out of
        // scope for equivalence, not a failure.
        if be.prepare_alloc(T::DTYPE, n).is_err() {
            continue;
        }
        let r = run_stream_t::<T>(be.as_ref(), &map, n, nt, q, 0)
            .unwrap_or_else(|e| panic!("backend {}: {e}", be.kind()));
        assert_eq!(r.backend, be.kind(), "result must name its backend");
        assert_eq!(r.width, T::WIDTH);
        assert_eq!(r.n_local, n);
        assert_eq!(
            r.validation.passed, reference.validation.passed,
            "{} vs serial at dtype {}",
            be.kind(),
            T::DTYPE
        );
        assert_eq!(
            (r.validation.err_a, r.validation.err_b, r.validation.err_c),
            (
                reference.validation.err_a,
                reference.validation.err_b,
                reference.validation.err_c
            ),
            "{} must be bit-identical to the serial reference at dtype {}",
            be.kind(),
            T::DTYPE
        );
        covered += 1;
    }
    assert!(covered >= 2, "host and threaded must always be available");
}

#[test]
fn stream_validation_identical_across_backends_all_dtypes() {
    stream_equivalence_case::<f64>(4099, 7, STREAM_Q);
    stream_equivalence_case::<f32>(2048, 5, std::f32::consts::SQRT_2 - 1.0);
    stream_equivalence_case::<i64>(513, 4, 0i64);
    stream_equivalence_case::<u64>(1000, 3, 0u64);
}

fn random_map_1d(rng: &mut Rng, np: usize) -> Dmap {
    let dist = match rng.below(3) {
        0 => Dist::Block,
        1 => Dist::Cyclic,
        _ => Dist::BlockCyclic { block_size: rng.range(1, 16) },
    };
    Dmap::new(
        Grid::line(np),
        vec![dist],
        vec![Overlap::none()],
        (0..np).collect(),
    )
}

/// Remap through `Backend::execute_plan` (via `assign_from_engine_on`)
/// must be bit-identical to the scratch-planned serial reference
/// (`assign_from`), with the engine planning exactly once per key.
fn remap_equivalence_case<T: Element>(
    backend: Arc<dyn Backend>,
    src_map: Dmap,
    dst_map: Dmap,
    n: usize,
) {
    let np = src_map.np();
    let engine = Arc::new(RemapEngine::new());
    let world = ChannelHub::world(np);
    let hs: Vec<_> = world
        .into_iter()
        .map(|t| {
            let (src_map, dst_map) = (src_map.clone(), dst_map.clone());
            let (engine, backend) = (engine.clone(), backend.clone());
            std::thread::spawn(move || {
                let pid = t.pid();
                let a = DarrayT::<T>::from_global_fn(src_map, &[n], pid, |g| {
                    T::from_f64((g % 251) as f64)
                });
                // Serial reference: scratch-planned direct assignment.
                let mut reference = DarrayT::<T>::zeros(dst_map.clone(), &[n], pid);
                reference.assign_from(&a, &t, 0).unwrap();
                // Backend path, iterated: plans once, executes thrice.
                let mut via = DarrayT::<T>::zeros(dst_map, &[n], pid);
                for epoch in 1..4 {
                    via.fill(T::ZERO);
                    via.assign_from_engine_on(&a, &t, epoch, &engine, backend.as_ref())
                        .unwrap();
                }
                assert_eq!(
                    via.loc(),
                    reference.loc(),
                    "pid {pid}: backend remap must be bit-identical"
                );
            })
        })
        .collect();
    for h in hs {
        h.join().unwrap();
    }
    assert_eq!(
        engine.plans_built(),
        1,
        "iterated Backend::execute_plan must plan exactly once"
    );
}

#[test]
fn remap_bit_identical_across_backends_all_dtypes() {
    forall(10, 0xBE0D, |rng| {
        let np = rng.range(1, 6);
        let src_map = random_map_1d(rng, np);
        let dst_map = random_map_1d(rng, np);
        let n = rng.range(1, 300);
        let backends: [Arc<dyn Backend>; 2] = [
            Arc::new(HostBackend::new()),
            Arc::new(ChunkedThreadedBackend::new(2)),
        ];
        for backend in backends {
            match rng.below(4) {
                0 => remap_equivalence_case::<f64>(
                    backend,
                    src_map.clone(),
                    dst_map.clone(),
                    n,
                ),
                1 => remap_equivalence_case::<f32>(
                    backend,
                    src_map.clone(),
                    dst_map.clone(),
                    n,
                ),
                2 => remap_equivalence_case::<i64>(
                    backend,
                    src_map.clone(),
                    dst_map.clone(),
                    n,
                ),
                _ => remap_equivalence_case::<u64>(
                    backend,
                    src_map.clone(),
                    dst_map.clone(),
                    n,
                ),
            }
        }
    });
}

/// Acceptance pin: every sealed dtype goes through every available
/// backend's `execute_plan` at least once (no rng dispatch).
#[test]
fn remap_every_dtype_on_every_available_backend() {
    let reg = registry();
    for be in reg.available() {
        let src = Dmap::block_1d(3);
        let dst = Dmap::cyclic_1d(3);
        remap_equivalence_case::<f64>(be.clone(), src.clone(), dst.clone(), 97);
        remap_equivalence_case::<f32>(be.clone(), src.clone(), dst.clone(), 97);
        remap_equivalence_case::<i64>(be.clone(), src.clone(), dst.clone(), 97);
        remap_equivalence_case::<u64>(be.clone(), src.clone(), dst.clone(), 97);
    }
}

/// The pjrt backend is registered in every build but only available
/// with the feature; unavailable backends fail loudly and cleanly.
#[cfg(not(feature = "pjrt"))]
#[test]
fn pjrt_backend_is_registered_but_unavailable_by_default() {
    let reg = registry();
    let be = reg.get(BackendKind::Pjrt).expect("registered");
    assert!(!be.available());
    let err = run_stream_t::<f64>(be.as_ref(), &Dmap::block_1d(1), 64, 2, STREAM_Q, 0);
    assert!(err.is_err());
}
