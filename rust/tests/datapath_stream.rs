//! Properties of the shared chunked datapath that need a controlled
//! process: buffer-pool hit rates are asserted against the global
//! pool, so these tests serialize on one lock and this file stays the
//! binary's only pool user (integration test binaries run in their
//! own process, unlike `cargo test --lib` units).

use distarray::collective::{CollKind, Collective, TagSpace, Topology};
use distarray::comm::datapath::{self, ChunkStream, ChunkTag};
use distarray::comm::{tags, ChannelHub, FileTransport, Transport};
use distarray::element::Dtype;
use distarray::report::bench_json;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Serializes the pool- and ambient-sensitive tests within this
/// binary (they mutate process-global state).
static POOL_LOCK: Mutex<()> = Mutex::new(());

/// Check out and release enough pooled buffers that every later
/// checkout — at any realistic concurrency — is a hit.
fn prewarm_pool(count: usize, cap: usize) {
    let bufs: Vec<_> = (0..count).map(|_| datapath::checkout(cap)).collect();
    drop(bufs);
}

/// The satellite's acceptance assertion: once the pool is warm,
/// steady-state remap sends are 100% pool hits — zero allocations on
/// the send path, proven by the instrument rather than assumed.
#[test]
fn steady_state_remap_pool_hit_rate_is_total() {
    let _guard = POOL_LOCK.lock().unwrap();
    // Peak concurrency of a 2-PID remap is 3 live buffers per sender
    // (stream frame + group header + payload); 16 warm buffers leave
    // a wide margin.
    prewarm_pool(16, 1 << 16);
    let b = bench_json::run_remap(2, 1 << 13, 8, Dtype::F64);
    assert!(b.pool_checkouts > 0, "timed sends must go through the pool");
    assert_eq!(
        b.pool_hits, b.pool_checkouts,
        "100% hit rate after warm-up: steady-state sends allocate nothing"
    );
    assert_eq!(b.messages, 8 * 2, "one single-chunk stream per peer per epoch");
}

/// Tree and hierarchical gathers forward multi-chunk bundle streams
/// correctly: with the ambient chunk forced tiny, every hop's
/// `bundle::Acc` stream splits into many chunks, and the root still
/// reassembles rank-ordered parts with the exact wire-byte model
/// (each part's bytes plus its 24-byte entry/frame overhead cross
/// each tree edge once — no per-hop re-serialization).
#[test]
fn tree_gather_forwards_multi_chunk_bundles() {
    let _guard = POOL_LOCK.lock().unwrap();
    datapath::set_ambient_chunk_bytes(32);
    let np = 6;
    let part_len = 100usize;
    let coll = Arc::new(Collective::new(CollKind::Tree, Topology::flat(np)));
    let hs: Vec<_> = ChannelHub::world(np)
        .into_iter()
        .map(|t| {
            let coll = coll.clone();
            std::thread::spawn(move || {
                let part = vec![t.pid() as u8; part_len];
                let got = coll
                    .gather(&t, TagSpace::packed(tags::NS_COLL, 1), part)
                    .unwrap();
                if t.pid() == 0 {
                    let parts = got.expect("root holds the gather");
                    assert_eq!(parts.len(), np);
                    for (r, p) in parts.iter().enumerate() {
                        assert_eq!(*p, vec![r as u8; part_len]);
                    }
                } else {
                    assert!(got.is_none());
                }
                (t.stats().msgs_sent(), t.stats().bytes_sent())
            })
        })
        .collect();
    let mut msgs = 0u64;
    let mut bytes = 0u64;
    for h in hs {
        let (m, b) = h.join().unwrap();
        msgs += m;
        bytes += b;
    }
    datapath::set_ambient_chunk_bytes(0);
    // Every rank sends one stream; each stream is > 1 chunk at the
    // 32-byte ambient chunk, so the message count strictly exceeds
    // the single-message P−1 model.
    assert!(msgs > (np - 1) as u64, "streams must be multi-chunk ({msgs} msgs)");
    // Byte model: rank r's subtree bundle carries its subtree's
    // entries (16-byte prefix + part each) + 8-byte count + 16-byte
    // stream frame per edge; every part crosses one edge per tree
    // level above its origin — strictly less than the O(P²·part)
    // chain, and exactly Σ_edges (frame + 8 + Σ_subtree (16 + part)).
    let per_entry = (16 + part_len) as u64;
    // Binomial tree on 6 ranks: subtree sizes sent upward are
    // 1 (rank 1→0), 1 (3→2), 1 (5→4), 2 (2→0), 2 (4→0).
    let expected_entries: u64 = [1u64, 1, 1, 2, 2].iter().sum();
    let expected_bytes = expected_entries * per_entry + 5 * (16 + 8);
    assert_eq!(bytes, expected_bytes, "forwarded-segment byte model");
}

/// Multi-chunk streams over the file transport: the spool's
/// `send_parts` override writes frame + windowed payload per chunk,
/// and the receiver reassembles them in order.
#[test]
fn chunked_stream_roundtrips_over_file_transport() {
    let _guard = POOL_LOCK.lock().unwrap();
    let dir = std::env::temp_dir().join(format!("distarray_datapath_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let payload: Vec<u8> = (0..5000).map(|i| (i % 251) as u8).collect();
    let want = payload.clone();
    let tag = ChunkTag::new(tags::NS_COLL, 9);
    let d0 = dir.clone();
    let sender = std::thread::spawn(move || {
        let t = FileTransport::new(&d0, 0, 2)
            .unwrap()
            .with_poll(Duration::from_micros(200));
        // 5000 bytes at 512-byte chunks → 10 streamed messages.
        let sent = ChunkStream::send(&t, 1, tag, 512, &[&payload]).unwrap();
        assert_eq!(sent, 10);
    });
    let t1 = FileTransport::new(&dir, 1, 2)
        .unwrap()
        .with_poll(Duration::from_micros(200));
    let got = ChunkStream::recv(&t1, 0, tag).unwrap();
    sender.join().unwrap();
    assert_eq!(got, want);
    let _ = std::fs::remove_dir_all(&dir);
}
