//! Transport conformance suite — one behavioural contract, checked
//! against every production transport.
//!
//! The point-to-point semantics the rest of the stack assumes
//! (per-`(from, tag)` FIFO, `send_parts` ≡ `send`, tag isolation
//! under concurrent senders, diagnosable timeouts, bit-identical
//! chunk streams) are properties of the [`Transport`] *trait*, not of
//! any one implementation. This suite encodes them once as generic
//! checks and instantiates the whole battery over in-process worlds
//! of each transport: channel, file spool, shared-memory rings
//! (unix only), and TCP loopback. A new transport earns its place by
//! adding one `#[test]` that builds a world and calls `conformance`.

use distarray::comm::datapath::{ChunkStream, ChunkTag};
use distarray::comm::{tags, ChannelHub, CommError, FileTransport, Tag, Transport};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Epoch namespace for this suite — far from anything the runtime
/// packs, so a stray message from another subsystem can never alias.
const EPOCH: u64 = 0xC0F0;

fn tag(step: u64) -> Tag {
    tags::pack(tags::NS_COLL, EPOCH, step)
}

/// Unique scratch directory per (transport, process) for the spool
/// and ring transports.
fn scratch(label: &str) -> std::path::PathBuf {
    static N: AtomicU64 = AtomicU64::new(0);
    std::env::temp_dir().join(format!(
        "distarray_conformance_{label}_{}_{}",
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed)
    ))
}

/// Messages are delivered in send order per `(from, tag)` pair, and
/// two tags from the same sender are independent FIFOs: draining one
/// completely never disturbs the other.
fn check_ordering<Tr: Transport>(t0: &Tr, t1: &Tr) {
    const N: u64 = 64;
    let (tag_a, tag_b) = (tag(1), tag(2));
    std::thread::scope(|s| {
        s.spawn(|| {
            for i in 0..N {
                t1.send(0, tag_a, &i.to_le_bytes()).expect("send a");
                t1.send(0, tag_b, &(i + 1000).to_le_bytes()).expect("send b");
            }
        });
        // Drain B first even though A's messages arrived interleaved.
        for i in 0..N {
            let m = t0.recv(1, tag_b).expect("recv b");
            assert_eq!(m, (i + 1000).to_le_bytes(), "tag B out of order at {i}");
        }
        for i in 0..N {
            let m = t0.recv(1, tag_a).expect("recv a");
            assert_eq!(m, i.to_le_bytes(), "tag A out of order at {i}");
        }
    });
}

/// `send_parts` delivers the exact concatenation a plain `send` of
/// the pre-joined buffer would — receivers cannot tell them apart.
fn check_send_parts<Tr: Transport>(t0: &Tr, t1: &Tr) {
    let parts: [&[u8]; 4] = [b"dist", b"", b"arr", b"ay conformance"];
    let joined: Vec<u8> = parts.concat();
    std::thread::scope(|s| {
        s.spawn(|| {
            t1.send_parts(0, tag(3), &parts).expect("send_parts");
            t1.send(0, tag(4), &joined).expect("send joined");
        });
        let via_parts = t0.recv(1, tag(3)).expect("recv parts");
        let via_send = t0.recv(1, tag(4)).expect("recv joined");
        assert_eq!(via_parts, joined);
        assert_eq!(via_parts, via_send);
    });
}

/// Concurrent senders on one endpoint, each with its own tag: both
/// streams arrive complete and in per-tag order (the endpoint is
/// `Sync`, and tags isolate the FIFOs).
fn check_concurrent_tags<Tr: Transport>(t0: &Tr, t1: &Tr) {
    const N: u64 = 32;
    let (tag_a, tag_b) = (tag(5), tag(6));
    std::thread::scope(|s| {
        s.spawn(|| {
            for i in 0..N {
                t1.send(0, tag_a, &i.to_le_bytes()).expect("send a");
            }
        });
        s.spawn(|| {
            for i in 0..N {
                t1.send(0, tag_b, &(i * 7).to_le_bytes()).expect("send b");
            }
        });
        for i in 0..N {
            assert_eq!(t0.recv(1, tag_b).expect("recv b"), (i * 7).to_le_bytes());
        }
        for i in 0..N {
            assert_eq!(t0.recv(1, tag_a).expect("recv a"), i.to_le_bytes());
        }
    });
}

/// A receive that never completes fails with `Timeout` naming the
/// awaited peer and tag — hangs must be diagnosable from the error.
fn check_timeout_names_peer<Tr: Transport>(t0: &Tr) {
    let t = tag(7);
    let err = t0
        .recv_timeout(1, t, Duration::from_millis(50))
        .expect_err("nobody sent — must time out");
    match &err {
        CommError::Timeout { from, tag: got, .. } => {
            assert_eq!(*from, 1);
            assert_eq!(*got, t);
        }
        other => panic!("expected Timeout, got {other:?}"),
    }
    let msg = err.to_string();
    assert!(msg.contains("from 1"), "timeout must name the peer: {msg}");
    // try_recv maps the same condition to Ok(None), not an error.
    assert!(t0.try_recv(1, t).expect("try_recv").is_none());
}

/// A chunked stream reassembles bit-identically: irregular part
/// boundaries and chunk framing are invisible to the consumer.
fn check_chunk_stream<Tr: Transport>(t0: &Tr, t1: &Tr) {
    // Deterministic bytes, long enough for several chunks.
    let total = 3 * 64 * 1024 + 777;
    let mut payload = Vec::with_capacity(total);
    let mut x = 0x2545F4914F6CDD1Du64;
    for _ in 0..total {
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        payload.push(x as u8);
    }
    let ctag = ChunkTag::new(tags::NS_COLL, EPOCH + 1);
    let chunk_bytes = 64 * 1024;
    std::thread::scope(|s| {
        s.spawn(|| {
            // Split at boundaries that align with nothing.
            let parts: [&[u8]; 3] =
                [&payload[..1], &payload[1..70_001], &payload[70_001..]];
            ChunkStream::send(t1, 0, ctag, chunk_bytes, &parts).expect("chunked send");
        });
        let mut got = vec![0u8; total];
        let mut seen = 0usize;
        ChunkStream::drain_chunks(t0, &[1], ctag, |c| {
            assert_eq!(c.peer, 1);
            assert_eq!(c.total, total, "stream header disagrees on length");
            let p = c.payload();
            got[c.offset..c.offset + p.len()].copy_from_slice(p);
            seen += p.len();
            Ok(())
        })
        .expect("drain");
        assert_eq!(seen, total, "chunks lost or duplicated");
        assert_eq!(got, payload, "stream not bit-identical");
    });
}

/// The full battery over a fresh two-endpoint world.
fn conformance<Tr: Transport>(mut world: Vec<Tr>) {
    assert_eq!(world.len(), 2, "conformance worlds are pairs");
    let t1 = world.pop().unwrap();
    let t0 = world.pop().unwrap();
    check_ordering(&t0, &t1);
    check_send_parts(&t0, &t1);
    check_concurrent_tags(&t0, &t1);
    check_timeout_names_peer(&t0);
    check_chunk_stream(&t0, &t1);
}

#[test]
fn channel_transport_conforms() {
    conformance(ChannelHub::world(2));
}

#[test]
fn file_transport_conforms() {
    let dir = scratch("file");
    let world: Vec<FileTransport> = (0..2)
        .map(|p| {
            FileTransport::new(&dir, p, 2)
                .map(|t| t.with_poll(Duration::from_micros(100)))
        })
        .collect::<distarray::comm::Result<_>>()
        .expect("file world");
    conformance(world);
    let _ = std::fs::remove_dir_all(&dir);
}

#[cfg(unix)]
#[test]
fn shmem_transport_conforms() {
    use distarray::comm::ShmemTransport;
    let dir = scratch("shmem");
    let world = ShmemTransport::world(&dir, 2).expect("shmem world");
    conformance(world);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tcp_transport_conforms() {
    use distarray::comm::TcpRendezvous;
    conformance(TcpRendezvous::loopback_world(2).expect("tcp loopback world"));
}

/// The hybrid router satisfies the same contract end-to-end: with one
/// rank per node every message takes the TCP leg, but through the
/// hybrid dispatch surface.
#[test]
fn hybrid_transport_conforms() {
    use distarray::comm::HybridTransport;
    let dir = scratch("hybrid");
    match HybridTransport::world(&dir, 2, 1) {
        Ok(world) => conformance(world),
        // Non-unix hosts cannot build the shmem half; the router
        // itself is exercised on unix CI.
        Err(e) if cfg!(not(unix)) => eprintln!("hybrid world unsupported here: {e}"),
        Err(e) => panic!("hybrid world: {e}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}
