//! Property-based invariant tests (in-house `prop` substrate):
//! randomized sweeps over map algebra, partitions, remap plans and
//! their cached-engine execution, the wire codec, and the JSON codec.

use distarray::comm::{ChannelHub, Transport, WireReader, WireWriter};
use distarray::darray::{DarrayT, RemapEngine};
use distarray::dmap::{Dist, Dmap, Grid, Overlap, Partition};
use distarray::element::Element;
use distarray::json::Json;
use distarray::prop::{forall, Rng};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

fn random_dist(rng: &mut Rng) -> Dist {
    match rng.below(3) {
        0 => Dist::Block,
        1 => Dist::Cyclic,
        _ => Dist::BlockCyclic { block_size: rng.range(1, 16) },
    }
}

fn random_map_1d(rng: &mut Rng) -> Dmap {
    let np = rng.range(1, 12);
    Dmap::new(
        Grid::line(np),
        vec![random_dist(rng)],
        vec![Overlap::none()],
        (0..np).collect(),
    )
}

/// INVARIANT: for any (dist, n, g), ownership is a bijection
/// global ↔ (coord, local).
#[test]
fn prop_dist_bijection() {
    forall(300, 0xD157, |rng| {
        let d = random_dist(rng);
        let n = rng.range(1, 500);
        let g = rng.range(1, 16);
        let mut seen = vec![false; n];
        for c in 0..g {
            let len = d.local_len(c, n, g);
            for l in 0..len {
                let gidx = d.local_to_global(c, l, n, g);
                assert!(gidx < n, "{d:?} n={n} g={g} c={c} l={l} -> {gidx}");
                assert!(!seen[gidx], "double-owned {gidx}");
                seen[gidx] = true;
                assert_eq!(d.owner(gidx, n, g), c);
                assert_eq!(d.global_to_local(gidx, n, g), l);
            }
        }
        assert!(seen.iter().all(|&s| s), "uncovered index {d:?} n={n} g={g}");
    });
}

/// INVARIANT: a partition's ranges exactly tile [0, total).
#[test]
fn prop_partition_tiles_range() {
    forall(200, 0xBEEF, |rng| {
        let map = random_map_1d(rng);
        let n = rng.range(1, 2000);
        let p = Partition::of(&map, &[n]);
        let mut covered = 0usize;
        let mut last_hi = 0usize;
        for (pid, r) in p.ranges() {
            assert!(*pid < map.np());
            assert!(r.lo >= last_hi, "overlapping ranges");
            covered += r.len();
            last_hi = r.hi;
        }
        assert_eq!(covered, n);
        // owner_of agrees with the map's own owner computation.
        for _ in 0..20 {
            let i = rng.below(n);
            assert_eq!(p.owner_of(i), Some(map.owner(&[i], &[n])));
        }
    });
}

/// INVARIANT: a remap plan moves every element exactly once, and the
/// (src, dst) of every transfer agrees with both partitions.
#[test]
fn prop_remap_plan_exact() {
    forall(150, 0x0E0A, |rng| {
        let n = rng.range(1, 1500);
        let src_map = random_map_1d(rng);
        let np = src_map.np();
        // destination over the same np (remap requires same world)
        let dst_map = Dmap::new(
            Grid::line(np),
            vec![random_dist(rng)],
            vec![Overlap::none()],
            (0..np).collect(),
        );
        let src = Partition::of(&src_map, &[n]);
        let dst = Partition::of(&dst_map, &[n]);
        let plan = src.transfers_to(&dst);
        let total: usize = plan.iter().map(|(_, _, r)| r.len()).sum();
        assert_eq!(total, n, "plan must move each element once");
        for (s, d, r) in plan {
            for i in r.lo..r.hi {
                assert_eq!(src.owner_of(i), Some(s));
                assert_eq!(dst.owner_of(i), Some(d));
            }
        }
    });
}

/// SPMD remap round-trip at dtype `T`:
/// `A --assign_from--> B --assign_from--> A'` must reproduce `A`
/// exactly for ANY pair of 1-D maps over the same world, and an
/// aligned first hop must be silent. Runs through a shared
/// [`RemapEngine`] and returns the total messages sent on hop 1,
/// asserting the engine planned exactly once per hop direction.
fn remap_roundtrip_case<T: Element>(src_map: Dmap, dst_map: Dmap, n: usize) -> u64 {
    let np = src_map.np();
    let engine = Arc::new(RemapEngine::new());
    let hop1_msgs = Arc::new(AtomicU64::new(0));
    let world = ChannelHub::world(np);
    let hs: Vec<_> = world
        .into_iter()
        .map(|t| {
            let (src_map, dst_map) = (src_map.clone(), dst_map.clone());
            let engine = engine.clone();
            let hop1_msgs = hop1_msgs.clone();
            std::thread::spawn(move || {
                let pid = t.pid();
                let a = DarrayT::<T>::from_global_fn(src_map.clone(), &[n], pid, |g| {
                    T::from_f64((g % 251) as f64)
                });
                let mut b = DarrayT::<T>::zeros(dst_map, &[n], pid);
                b.assign_from_engine(&a, &t, 0, &engine).unwrap();
                hop1_msgs.fetch_add(t.stats().msgs_sent(), Ordering::Relaxed);
                let mut a2 = DarrayT::<T>::zeros(src_map, &[n], pid);
                a2.assign_from_engine(&b, &t, 1, &engine).unwrap();
                assert_eq!(a2.loc(), a.loc(), "pid {pid}: round trip corrupted data");
            })
        })
        .collect();
    for h in hs {
        h.join().unwrap();
    }
    // Two plan keys (src→dst, dst→src) — or one when the maps are the
    // same object, in which case the keys coincide.
    let expected_builds = if src_map == dst_map { 1 } else { 2 };
    assert_eq!(
        engine.plans_built(),
        expected_builds,
        "each plan key must be built exactly once"
    );
    hop1_msgs.load(Ordering::Relaxed)
}

/// INVARIANT: remap round-trips are exact at every dtype, and aligned
/// maps communicate nothing.
#[test]
fn prop_remap_roundtrip_all_dtypes() {
    forall(25, 0xD7F0, |rng| {
        let src_map = random_map_1d(rng);
        let np = src_map.np();
        let dst_map = Dmap::new(
            Grid::line(np),
            vec![random_dist(rng)],
            vec![Overlap::none()],
            (0..np).collect(),
        );
        let n = rng.range(1, 400);
        let aligned = src_map.aligned_with(&dst_map, &[n]);
        let msgs = match rng.below(3) {
            0 => remap_roundtrip_case::<f64>(src_map, dst_map, n),
            1 => remap_roundtrip_case::<f32>(src_map, dst_map, n),
            _ => remap_roundtrip_case::<i64>(src_map, dst_map, n),
        };
        if aligned {
            assert_eq!(msgs, 0, "aligned maps must remap with zero messages");
        }
    });
}

/// INVARIANT: the engine-cached plan drives execution identically to
/// scratch planning (same result, same traffic), for random map pairs.
#[test]
fn prop_engine_matches_scratch_plan() {
    forall(20, 0xCAC4E, |rng| {
        let src_map = random_map_1d(rng);
        let np = src_map.np();
        let dst_map = Dmap::new(
            Grid::line(np),
            vec![random_dist(rng)],
            vec![Overlap::none()],
            (0..np).collect(),
        );
        let n = rng.range(1, 300);
        let world = ChannelHub::world(np);
        let engine = Arc::new(RemapEngine::new());
        let hs: Vec<_> = world
            .into_iter()
            .map(|t| {
                let (src_map, dst_map) = (src_map.clone(), dst_map.clone());
                let engine = engine.clone();
                std::thread::spawn(move || {
                    let pid = t.pid();
                    let a = DarrayT::<u64>::from_global_fn(src_map, &[n], pid, |g| g as u64);
                    let mut via_scratch = DarrayT::<u64>::zeros(dst_map.clone(), &[n], pid);
                    via_scratch.assign_from(&a, &t, 0).unwrap();
                    let scratch_traffic = t.stats().bytes_sent();
                    let mut via_engine = DarrayT::<u64>::zeros(dst_map, &[n], pid);
                    via_engine.assign_from_engine(&a, &t, 1, &engine).unwrap();
                    assert_eq!(via_engine.loc(), via_scratch.loc());
                    let engine_traffic = t.stats().bytes_sent() - scratch_traffic;
                    assert_eq!(engine_traffic, scratch_traffic, "identical plans, identical bytes");
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
    });
}

/// INVARIANT: map alignment is reflexive and symmetric.
#[test]
fn prop_alignment_symmetric() {
    forall(150, 0xA116, |rng| {
        let n = rng.range(1, 300);
        let a = random_map_1d(rng);
        let b = random_map_1d(rng);
        assert!(a.aligned_with(&a, &[n]), "reflexive");
        if a.np() == b.np() {
            assert_eq!(a.aligned_with(&b, &[n]), b.aligned_with(&a, &[n]), "symmetric");
        }
    });
}

/// INVARIANT: the wire codec round-trips arbitrary payload sequences.
#[test]
fn prop_wire_roundtrip() {
    forall(200, 0x3142, |rng| {
        // Random schema of up to 8 fields.
        let nfields = rng.range(1, 8);
        let mut kinds = Vec::new();
        let mut w = WireWriter::new();
        for _ in 0..nfields {
            match rng.below(5) {
                0 => {
                    // 52 bits so the f64 side-channel stores it exactly.
                    let v = rng.next_u64() >> 12;
                    w.put_u64(v);
                    kinds.push((0u8, v as f64, String::new(), vec![]));
                }
                1 => {
                    let v = rng.f64_range(-1e12, 1e12);
                    w.put_f64(v);
                    kinds.push((1, v, String::new(), vec![]));
                }
                2 => {
                    let len = rng.below(40);
                    let s: String = (0..len).map(|_| (b'a' + rng.below(26) as u8) as char).collect();
                    w.put_str(&s);
                    kinds.push((2, 0.0, s, vec![]));
                }
                3 => {
                    let len = rng.below(100);
                    let v: Vec<f64> = (0..len).map(|_| rng.f64_range(-1e6, 1e6)).collect();
                    w.put_f64_slice(&v);
                    kinds.push((3, 0.0, String::new(), v));
                }
                _ => {
                    let v = rng.bool();
                    w.put_bool(v);
                    kinds.push((4, f64::from(v), String::new(), vec![]));
                }
            }
        }
        let buf = w.finish();
        let mut r = WireReader::new(&buf);
        for (k, num, s, v) in kinds {
            match k {
                0 => assert_eq!(r.get_u64().unwrap(), num as u64),
                1 => assert_eq!(r.get_f64().unwrap(), num),
                2 => assert_eq!(r.get_str().unwrap(), s),
                3 => assert_eq!(r.get_f64_vec().unwrap(), v),
                _ => assert_eq!(r.get_bool().unwrap(), num != 0.0),
            }
        }
        assert_eq!(r.remaining(), 0);
    });
}

/// INVARIANT: JSON display → parse is the identity on random values.
#[test]
fn prop_json_roundtrip() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool()),
            2 => Json::Num((rng.f64_range(-1e9, 1e9) * 100.0).round() / 100.0),
            3 => {
                let len = rng.below(12);
                Json::Str((0..len).map(|_| (b' ' + rng.below(94) as u8) as char).collect())
            }
            4 => {
                let len = rng.below(5);
                Json::Arr((0..len).map(|_| random_json(rng, depth - 1)).collect())
            }
            _ => {
                let len = rng.below(5);
                let mut m = std::collections::BTreeMap::new();
                for i in 0..len {
                    m.insert(format!("k{i}"), random_json(rng, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    forall(300, 0x7503, |rng| {
        let j = random_json(rng, 3);
        let parsed = Json::parse(&j.to_string()).expect("rendered json parses");
        assert_eq!(parsed, j);
    });
}

/// INVARIANT: overlap stored length = owned + halo, halo within array.
#[test]
fn prop_overlap_bounds() {
    forall(200, 0x4A10, |rng| {
        let n = rng.range(1, 400);
        let g = rng.range(1, 10);
        let amount = rng.below(20);
        let d = Dist::Block;
        let ov = Overlap::new(amount);
        for c in 0..g {
            let own = d.local_len(c, n, g);
            let stored = ov.stored_len(&d, c, n, g);
            assert!(stored >= own);
            assert!(stored - own <= amount);
            if let Some((lo, hi)) = ov.halo_range(&d, c, n, g) {
                assert!(lo < hi && hi <= n);
                assert_eq!(stored - own, hi - lo);
            }
        }
    });
}

/// INVARIANT: validation closed forms match brute-force iteration for
/// random q and nt.
#[test]
fn prop_validation_closed_form() {
    forall(200, 0x5555, |rng| {
        let q = rng.f64_range(-0.9, 0.9);
        let nt = rng.range(1, 30);
        let a0 = rng.f64_range(0.1, 3.0);
        let (mut a, mut b, mut c) = (a0, 0.0f64, 0.0f64);
        for _ in 0..nt {
            c = a;
            b = q * c;
            c = a + b;
            a = b + q * c;
        }
        let (ea, eb, ec) = distarray::stream::validate::expected(a0, q, nt);
        let scale = a.abs().max(1.0);
        assert!((a - ea).abs() < 1e-9 * scale, "A: {a} vs {ea} (q={q} nt={nt})");
        assert!((b - eb).abs() < 1e-9 * scale);
        assert!((c - ec).abs() < 1e-9 * scale);
    });
}

/// INVARIANT: Table II schedule never overcommits memory and never
/// produces zero-length vectors.
#[test]
fn prop_schedule_sound() {
    forall(200, 0x7AB2, |rng| {
        let base_log2 = rng.range(10, 31) as u32;
        let base_nt = rng.range(1, 100);
        let mem = (1u64 << rng.range(24, 40)) + rng.next_u64() % (1 << 24);
        let max_np = 1usize << rng.below(8);
        for (np, p) in distarray::stream::params::schedule(base_log2, base_nt, mem, max_np) {
            assert!(p.local_len() >= 1);
            assert!(p.nt >= base_nt);
            let footprint = (p.local_bytes() as u64).saturating_mul(np as u64);
            // Either under the cap, or the vector can't shrink further.
            assert!(
                footprint <= mem * 8 / 10 + 1 || p.log2_local == 0,
                "np={np} {p:?} mem={mem}"
            );
        }
    });
}
