//! Equivalence and cost-model properties of the collective subsystem.
//!
//! The acceptance bar for `rust/src/collective/`: every algorithm
//! (star, binomial tree, ring, hierarchical) × every sealed dtype ×
//! non-power-of-two world sizes × both transports (in-process
//! channels and the file spool) produces results **bit-identical** to
//! the star reference — reductions fold in PID order regardless of
//! schedule — and the message counts match each algorithm's cost
//! model (tree = P−1, ring broadcast = (P−1)·chunks, hierarchical =
//! (P−L) intra + (L−1) inter).

use distarray::collective::{AllreduceOrder, CollKind, Collective, ReduceOp, TagSpace, Topology};
use distarray::comm::{tags, ChannelHub, FileTransport, Transport};
use distarray::element::Element;
use std::path::PathBuf;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

const KINDS: [CollKind; 4] = [CollKind::Star, CollKind::Tree, CollKind::Ring, CollKind::Hier];
/// Includes non-powers-of-two (3, 5, 6) and an exact power (8).
const NPS: [usize; 4] = [2, 3, 6, 8];

/// The context under test: 3-wide node groups (so P = 5, 8 are
/// genuinely multi-node for `hier`) and a tiny ring chunk so even
/// short payloads exercise multi-chunk pipelining.
fn ctx(kind: CollKind, np: usize) -> Collective {
    Collective::new(kind, Topology::grouped(np, 3)).with_chunk_bytes(16)
}

fn spmd_channel<R: Send + 'static>(
    np: usize,
    f: impl Fn(&dyn Transport) -> R + Send + Sync + 'static,
) -> Vec<R> {
    let f = Arc::new(f);
    ChannelHub::world(np)
        .into_iter()
        .map(|t| {
            let f = f.clone();
            thread::spawn(move || f(&t))
        })
        .collect::<Vec<_>>()
        .into_iter()
        .map(|h| h.join().unwrap())
        .collect()
}

fn spool(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("distarray_coll_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn spmd_file<R: Send + 'static>(
    name: &str,
    np: usize,
    f: impl Fn(&dyn Transport) -> R + Send + Sync + 'static,
) -> Vec<R> {
    let dir = spool(name);
    let f = Arc::new(f);
    let out: Vec<R> = (0..np)
        .map(|pid| {
            let f = f.clone();
            let dir = dir.clone();
            thread::spawn(move || {
                let t = FileTransport::new(&dir, pid, np)
                    .unwrap()
                    .with_poll(Duration::from_micros(200));
                f(&t)
            })
        })
        .collect::<Vec<_>>()
        .into_iter()
        .map(|h| h.join().unwrap())
        .collect();
    std::fs::remove_dir_all(&dir).ok();
    out
}

/// Per-PID contribution: distinct in every dtype (integers see
/// `3·pid + 1`, floats additionally a fractional part, so float sums
/// are genuinely order-sensitive).
fn contribution<T: Element>(pid: usize) -> T {
    T::from_f64((3 * pid + 1) as f64 + pid as f64 * 0.265625)
}

/// The star reference result is, by construction, the PID-ordered
/// fold of the contributions.
fn reference<T: Element>(np: usize, op: ReduceOp) -> T {
    (1..np).fold(contribution::<T>(0), |acc, p| op.combine(acc, contribution::<T>(p)))
}

fn check_allreduce_channel<T: Element>(kind: CollKind, np: usize, op: ReduceOp, epoch: u64) {
    let got = spmd_channel(np, move |t| {
        let coll = ctx(kind, np);
        coll.allreduce_scalar::<T>(
            t,
            TagSpace::packed(tags::NS_COLL, epoch),
            contribution::<T>(t.pid()),
            op,
        )
        .unwrap()
    });
    let want = reference::<T>(np, op);
    for g in got {
        assert_eq!(g, want, "{kind} np={np} {op:?} {:?}", T::DTYPE);
    }
}

#[test]
fn allreduce_bit_identical_to_star_all_dtypes() {
    for kind in KINDS {
        for np in NPS {
            for (i, op) in [ReduceOp::Sum, ReduceOp::Min, ReduceOp::Max].into_iter().enumerate() {
                let epoch = (np * 10 + i) as u64;
                check_allreduce_channel::<f64>(kind, np, op, epoch);
                check_allreduce_channel::<f32>(kind, np, op, epoch + 1000);
                check_allreduce_channel::<i64>(kind, np, op, epoch + 2000);
                check_allreduce_channel::<u64>(kind, np, op, epoch + 3000);
            }
        }
    }
}

#[test]
fn bcast_and_gather_match_star_reference() {
    for kind in KINDS {
        for np in NPS {
            // Broadcast: a payload long enough to split into several
            // 16-byte ring chunks.
            let out = spmd_channel(np, move |t| {
                let coll = ctx(kind, np);
                let payload = if t.pid() == 0 {
                    (0..100u8).collect()
                } else {
                    Vec::new()
                };
                coll.bcast(t, TagSpace::packed(tags::NS_COLL, 1), payload).unwrap()
            });
            let want: Vec<u8> = (0..100u8).collect();
            for got in out {
                assert_eq!(got, want, "{kind} np={np} bcast");
            }
            // Gather: per-rank distinct parts of distinct lengths.
            let out = spmd_channel(np, move |t| {
                let coll = ctx(kind, np);
                let part = vec![t.pid() as u8; t.pid() + 1];
                coll.gather(t, TagSpace::packed(tags::NS_COLL, 2), part).unwrap()
            });
            for (pid, got) in out.into_iter().enumerate() {
                if pid == 0 {
                    let parts = got.expect("root holds the gather");
                    assert_eq!(parts.len(), np);
                    for (r, p) in parts.iter().enumerate() {
                        assert_eq!(*p, vec![r as u8; r + 1], "{kind} np={np} gather");
                    }
                } else {
                    assert!(got.is_none(), "{kind} np={np}: only the root gets parts");
                }
            }
            // Allgather: everyone ends with every part.
            let out = spmd_channel(np, move |t| {
                let coll = ctx(kind, np);
                let part = vec![0xA0 | t.pid() as u8];
                coll.allgather(t, TagSpace::packed(tags::NS_COLL, 3), part).unwrap()
            });
            for parts in out {
                assert_eq!(parts.len(), np, "{kind} np={np} allgather");
                for (r, p) in parts.iter().enumerate() {
                    assert_eq!(*p, vec![0xA0 | r as u8]);
                }
            }
        }
    }
}

/// Total messages (summed over PIDs) of one operation under a fresh
/// world.
fn count_msgs(
    kind: CollKind,
    np: usize,
    run: impl Fn(&Collective, &dyn Transport) + Send + Sync + 'static,
) -> u64 {
    spmd_channel(np, move |t| {
        let coll = ctx(kind, np);
        run(&coll, t);
        t.stats().msgs_sent()
    })
    .into_iter()
    .sum()
}

#[test]
fn message_counts_match_cost_models() {
    for np in NPS {
        let nodes = Topology::grouped(np, 3).node_count();
        let log2 = {
            let mut r = 0u32;
            while (1usize << r) < np {
                r += 1;
            }
            r as u64
        };
        // Star and tree broadcast both send P−1 messages (the tree
        // wins on depth, not count).
        for kind in [CollKind::Star, CollKind::Tree] {
            let msgs = count_msgs(kind, np, |c, t| {
                let p = if t.pid() == 0 { vec![1u8; 64] } else { Vec::new() };
                c.bcast(t, TagSpace::packed(tags::NS_COLL, 10), p).unwrap();
            });
            assert_eq!(msgs, (np - 1) as u64, "{kind} bcast np={np}");
        }
        // Tree gather: P−1 bundles.
        let msgs = count_msgs(CollKind::Tree, np, |c, t| {
            c.gather(t, TagSpace::packed(tags::NS_COLL, 11), vec![t.pid() as u8]).unwrap();
        });
        assert_eq!(msgs, (np - 1) as u64, "tree gather np={np}");
        // Ring broadcast: (P−1) × chunks (100 bytes at 16-byte chunks
        // → 7 chunks).
        let msgs = count_msgs(CollKind::Ring, np, |c, t| {
            let p = if t.pid() == 0 { vec![2u8; 100] } else { Vec::new() };
            c.bcast(t, TagSpace::packed(tags::NS_COLL, 12), p).unwrap();
        });
        assert_eq!(msgs, ((np - 1) * 7) as u64, "ring bcast np={np}");
        // Hierarchical gather: (P − L) intra + (L − 1) inter = P−1
        // total, with the cross-node share shrunk to L−1.
        let msgs = count_msgs(CollKind::Hier, np, |c, t| {
            c.gather(t, TagSpace::packed(tags::NS_COLL, 13), vec![t.pid() as u8]).unwrap();
        });
        assert_eq!(msgs, (np - 1) as u64, "hier gather np={np} (≤ intra + nodes−1)");
        // Hierarchical barrier: 2(P − L) intra + 2(L − 1) inter.
        let msgs = count_msgs(CollKind::Hier, np, |c, t| {
            c.barrier(t, TagSpace::packed(tags::NS_COLL, 14), Duration::from_secs(10)).unwrap();
        });
        assert_eq!(
            msgs,
            (2 * (np - nodes) + 2 * (nodes - 1)) as u64,
            "hier barrier np={np} nodes={nodes}"
        );
        // Dissemination barrier: P messages per round, ceil(log2 P)
        // rounds.
        let msgs = count_msgs(CollKind::Ring, np, |c, t| {
            c.barrier(t, TagSpace::packed(tags::NS_COLL, 15), Duration::from_secs(10)).unwrap();
        });
        assert_eq!(msgs, np as u64 * log2, "dissemination barrier np={np}");
    }
}

/// The same equivalence properties over the file-based transport —
/// the paper's cross-process messaging path. Smaller sweep (file
/// spool polling makes each op milliseconds, not microseconds).
#[test]
fn file_transport_matches_star_reference() {
    for kind in KINDS {
        let np = 3;
        let name = format!("eq_{kind}");
        let out = spmd_file(&name, np, move |t| {
            let coll = ctx(kind, np);
            let sum = coll
                .allreduce_scalar::<f64>(
                    t,
                    TagSpace::packed(tags::NS_COLL, 20),
                    contribution::<f64>(t.pid()),
                    ReduceOp::Sum,
                )
                .unwrap();
            let isum = coll
                .allreduce_scalar::<i64>(
                    t,
                    TagSpace::packed(tags::NS_COLL, 21),
                    contribution::<i64>(t.pid()),
                    ReduceOp::Sum,
                )
                .unwrap();
            let fmin = coll
                .allreduce_scalar::<f32>(
                    t,
                    TagSpace::packed(tags::NS_COLL, 25),
                    contribution::<f32>(t.pid()),
                    ReduceOp::Min,
                )
                .unwrap();
            let umax = coll
                .allreduce_scalar::<u64>(
                    t,
                    TagSpace::packed(tags::NS_COLL, 26),
                    contribution::<u64>(t.pid()),
                    ReduceOp::Max,
                )
                .unwrap();
            assert_eq!(fmin, reference::<f32>(t.np(), ReduceOp::Min));
            assert_eq!(umax, reference::<u64>(t.np(), ReduceOp::Max));
            let bc = coll
                .bcast(
                    t,
                    TagSpace::packed(tags::NS_COLL, 22),
                    if t.pid() == 0 { vec![9u8; 50] } else { Vec::new() },
                )
                .unwrap();
            let gathered = coll
                .gather(t, TagSpace::packed(tags::NS_COLL, 23), vec![t.pid() as u8; 4])
                .unwrap();
            coll.barrier(t, TagSpace::packed(tags::NS_COLL, 24), Duration::from_secs(30))
                .unwrap();
            (sum, isum, bc, gathered)
        });
        let want_sum = reference::<f64>(np, ReduceOp::Sum);
        let want_isum = reference::<i64>(np, ReduceOp::Sum);
        for (pid, (sum, isum, bc, gathered)) in out.into_iter().enumerate() {
            assert_eq!(sum.to_bits(), want_sum.to_bits(), "{kind} file f64 sum");
            assert_eq!(isum, want_isum, "{kind} file i64 sum");
            assert_eq!(bc, vec![9u8; 50], "{kind} file bcast");
            if pid == 0 {
                let parts = gathered.expect("root");
                for (r, p) in parts.iter().enumerate() {
                    assert_eq!(*p, vec![r as u8; 4], "{kind} file gather");
                }
            } else {
                assert!(gathered.is_none());
            }
        }
    }
}

/// The gather no longer re-serializes per hop: a ring gather is
/// chunk-pipelined and **direct**, so total traffic is O(P·chunks)
/// messages and O(P·part) wire bytes — each part crosses exactly one
/// link plus one 16-byte stream frame. (The old accumulating chain
/// cost O(P²·part) wire bytes, re-encoding the bundle at every hop.)
#[test]
fn ring_gather_is_direct_and_chunk_pipelined() {
    for np in NPS {
        let part_len = 100usize;
        let chunks = 7u64; // 100 bytes at the ctx's 16-byte chunks
        let out = spmd_channel(np, move |t| {
            let coll = ctx(CollKind::Ring, np);
            let got = coll
                .gather(t, TagSpace::packed(tags::NS_COLL, 70), vec![t.pid() as u8; part_len])
                .unwrap();
            if t.pid() == 0 {
                let parts = got.expect("root holds the gather");
                assert_eq!(parts.len(), np);
                for (r, p) in parts.iter().enumerate() {
                    assert_eq!(*p, vec![r as u8; part_len]);
                }
            } else {
                assert!(got.is_none());
            }
            (t.stats().msgs_sent(), t.stats().bytes_sent())
        });
        let msgs: u64 = out.iter().map(|(m, _)| m).sum();
        let bytes: u64 = out.iter().map(|(_, b)| b).sum();
        assert_eq!(msgs, (np as u64 - 1) * chunks, "O(P·chunks) messages, np={np}");
        assert_eq!(
            bytes,
            (np as u64 - 1) * (part_len as u64 + 16),
            "O(P·part) wire bytes, np={np}"
        );
    }
}

/// An `auto` context under the `Fast` order waiver with the threshold
/// forced low: the elimination (reduce-scatter + allgather) schedule
/// must be exactly equal to the star reference for wrapping integer
/// sums and every min/max, and tolerance-equal for f32/f64 sums
/// (fold order follows the ring, so floats reassociate).
fn elim_ctx(np: usize) -> Collective {
    Collective::new(CollKind::Auto, Topology::grouped(np, 3))
        .with_chunk_bytes(16)
        .with_elim_threshold(1)
}

/// Per-PID, per-element contribution (order-sensitive for floats).
fn vec_contribution<T: Element>(pid: usize, n: usize) -> Vec<T> {
    (0..n)
        .map(|j| T::from_f64((3 * pid + 1) as f64 + (j % 13) as f64 + pid as f64 * 0.265625))
        .collect()
}

/// Star reference: element-wise fold in PID order.
fn vec_reference<T: Element>(np: usize, n: usize, op: ReduceOp) -> Vec<T> {
    (1..np).fold(vec_contribution::<T>(0, n), |acc, p| {
        let other = vec_contribution::<T>(p, n);
        acc.into_iter().zip(other).map(|(a, b)| op.combine(a, b)).collect()
    })
}

fn check_elim_exact<T: Element>(np: usize, n: usize, op: ReduceOp, epoch: u64) {
    let got = spmd_channel(np, move |t| {
        let coll = elim_ctx(np);
        coll.allreduce_ordered::<T>(
            t,
            TagSpace::packed(tags::NS_COLL, epoch),
            &vec_contribution::<T>(t.pid(), n),
            op,
            AllreduceOrder::Fast,
        )
        .unwrap()
    });
    let want = vec_reference::<T>(np, n, op);
    for g in got {
        assert_eq!(g, want, "np={np} {op:?} {:?}", T::DTYPE);
    }
}

#[test]
fn elimination_allreduce_exact_for_integers_and_minmax() {
    for np in NPS {
        let n = 4 * np + 3; // uneven segments
        for (i, op) in [ReduceOp::Sum, ReduceOp::Min, ReduceOp::Max].into_iter().enumerate() {
            let epoch = (100 + np * 10 + i) as u64;
            check_elim_exact::<i64>(np, n, op, epoch);
            check_elim_exact::<u64>(np, n, op, epoch + 500);
            if op != ReduceOp::Sum {
                // Float min/max are order-free — exact under
                // elimination too.
                check_elim_exact::<f64>(np, n, op, epoch + 1500);
                check_elim_exact::<f32>(np, n, op, epoch + 2500);
            }
        }
    }
}

#[test]
fn elimination_allreduce_float_sums_within_tolerance() {
    for np in NPS {
        let n = 4 * np + 3;
        let got = spmd_channel(np, move |t| {
            let coll = elim_ctx(np);
            let f64s = coll
                .allreduce_ordered::<f64>(
                    t,
                    TagSpace::packed(tags::NS_COLL, 200 + np as u64),
                    &vec_contribution::<f64>(t.pid(), n),
                    ReduceOp::Sum,
                    AllreduceOrder::Fast,
                )
                .unwrap();
            let f32s = coll
                .allreduce_ordered::<f32>(
                    t,
                    TagSpace::packed(tags::NS_COLL, 300 + np as u64),
                    &vec_contribution::<f32>(t.pid(), n),
                    ReduceOp::Sum,
                    AllreduceOrder::Fast,
                )
                .unwrap();
            (f64s, f32s)
        });
        let want64 = vec_reference::<f64>(np, n, ReduceOp::Sum);
        let want32 = vec_reference::<f32>(np, n, ReduceOp::Sum);
        for (g64, g32) in got {
            for (g, w) in g64.iter().zip(&want64) {
                assert!((g - w).abs() <= 1e-12 * w.abs().max(1.0), "np={np} f64 {g} vs {w}");
            }
            for (g, w) in g32.iter().zip(&want32) {
                assert!((g - w).abs() <= 1e-4 * w.abs().max(1.0), "np={np} f32 {g} vs {w}");
            }
        }
    }
}

/// Without the `Fast` waiver the same context must stay bit-identical
/// to the star reference — the default path is untouched by the
/// elimination mode.
#[test]
fn deterministic_order_stays_bit_identical_under_auto() {
    let np = 5;
    let n = 23;
    let got = spmd_channel(np, move |t| {
        let coll = elim_ctx(np);
        coll.allreduce_ordered::<f64>(
            t,
            TagSpace::packed(tags::NS_COLL, 400),
            &vec_contribution::<f64>(t.pid(), n),
            ReduceOp::Sum,
            AllreduceOrder::Deterministic,
        )
        .unwrap()
    });
    let want = vec_reference::<f64>(np, n, ReduceOp::Sum);
    for g in got {
        for (a, b) in g.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits(), "deterministic path must match star bitwise");
        }
    }
}

/// The elimination cost model: each rank moves `(P−1)/P · 2n`
/// payload bytes (plus one 16-byte stream frame per step) in exactly
/// `2(P−1)` messages.
#[test]
fn elimination_cost_model_bytes_per_rank() {
    let np = 4usize;
    let n = 32usize; // divisible by np → equal 8-element segments
    let out = spmd_channel(np, move |t| {
        // Default chunk size: each 64-byte segment is a single-chunk
        // stream, so the byte model is exact.
        let coll = Collective::new(CollKind::Auto, Topology::grouped(np, 3))
            .with_elim_threshold(1);
        let got = coll
            .allreduce_ordered::<f64>(
                t,
                TagSpace::packed(tags::NS_COLL, 500),
                &vec_contribution::<f64>(t.pid(), n),
                ReduceOp::Sum,
                AllreduceOrder::Fast,
            )
            .unwrap();
        assert_eq!(got.len(), n);
        (t.stats().msgs_sent(), t.stats().bytes_sent())
    });
    let seg_bytes = (n / np) * 8;
    let steps = 2 * (np - 1);
    for (pid, (msgs, bytes)) in out.into_iter().enumerate() {
        assert_eq!(msgs, steps as u64, "pid {pid}: 2(P−1) segment messages");
        assert_eq!(
            bytes,
            (steps * (seg_bytes + 16)) as u64,
            "pid {pid}: (P−1)/P·2n payload bytes + stream frames"
        );
    }
}

/// The rewired legacy call sites agree across algorithms end to end:
/// `DarrayT` reductions and `agg` through explicit contexts equal the
/// ambient-star results bit-for-bit.
#[test]
fn darray_reductions_agree_across_algorithms() {
    use distarray::darray::{allreduce_with, DarrayT, ReduceOp as DOp};
    use distarray::dmap::Dmap;
    let np = 5;
    let mut per_kind: Vec<Vec<u64>> = Vec::new();
    for kind in KINDS {
        let out = spmd_channel(np, move |t| {
            let coll = ctx(kind, np);
            let a = DarrayT::<f64>::from_global_fn(Dmap::cyclic_1d(np), &[333], t.pid(), |g| {
                (g as f64).sin()
            });
            let local = a.loc().iter().sum::<f64>();
            allreduce_with(&coll, t, local, DOp::Sum, 30).unwrap().to_bits()
        });
        per_kind.push(out);
    }
    for k in 1..per_kind.len() {
        assert_eq!(per_kind[0], per_kind[k], "kind {} disagrees with star", KINDS[k]);
    }
}
