//! Integration tests for the PJRT path: the AOT artifacts (L1 Pallas
//! kernels lowered through L2 JAX) executed from Rust, cross-checked
//! against the native engine element by element.
//!
//! Requires `make artifacts`; tests skip (with a loud note) if the
//! artifacts directory is absent so `cargo test` alone stays green.

use distarray::runtime::PjrtRuntime;
use distarray::stream::{ops, validate, STREAM_Q};

fn runtime() -> Option<PjrtRuntime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return None;
    }
    Some(PjrtRuntime::load("artifacts").expect("artifacts load"))
}

#[test]
fn per_op_artifacts_match_native_ops() {
    let Some(rt) = runtime() else { return };
    let n = rt.n();
    let a: Vec<f64> = (0..n).map(|i| (i % 97) as f64 * 0.25 - 10.0).collect();
    let b: Vec<f64> = (0..n).map(|i| (i % 89) as f64 * -0.5 + 3.0).collect();
    let q = STREAM_Q;

    // copy
    let got = rt.copy(&a).unwrap();
    assert_eq!(got, a, "pjrt copy differs");
    // scale
    let got = rt.scale(&a, q).unwrap();
    let mut want = vec![0.0; n];
    ops::scale(&mut want, &a, q);
    assert_close(&got, &want, 1e-14);
    // add
    let got = rt.add(&a, &b).unwrap();
    ops::add(&mut want, &a, &b);
    assert_close(&got, &want, 1e-14);
    // triad
    let got = rt.triad(&a, &b, q).unwrap();
    ops::triad(&mut want, &a, &b, q);
    assert_close(&got, &want, 1e-12);
}

#[test]
fn fused_step_matches_four_ops() {
    let Some(rt) = runtime() else { return };
    let n = rt.n();
    let a: Vec<f64> = (0..n).map(|i| 1.0 + (i % 13) as f64 * 0.01).collect();
    let q = STREAM_Q;
    let (fa, fb, fc) = rt.step_fused(&a, q).unwrap();
    // Native four-op reference.
    let mut c = vec![0.0; n];
    let mut b = vec![0.0; n];
    let mut a2 = vec![0.0; n];
    ops::copy(&mut c, &a);
    ops::scale(&mut b, &c, q);
    let bc = b.clone();
    ops::add(&mut c, &a, &bc);
    ops::triad(&mut a2, &b, &c, q);
    assert_close(&fa, &a2, 1e-12);
    assert_close(&fb, &b, 1e-12);
    assert_close(&fc, &c, 1e-12);
}

#[test]
fn full_run_artifact_validates_against_closed_forms() {
    let Some(rt) = runtime() else { return };
    let n = rt.n();
    let nt = rt.nt();
    let a = vec![1.0f64; n];
    let (a2, b2, c2) = rt.run(&a, STREAM_Q).unwrap();
    // Closed-form check on the Rust side.
    let rep = validate(&a2, &b2, &c2, 1.0, STREAM_Q, nt);
    assert!(rep.passed, "{rep:?}");
    // And via the validate artifact itself (L2 graph).
    let errs = rt.validate(&a2, &b2, &c2, STREAM_Q).unwrap();
    assert!(errs.iter().all(|e| *e < 1e-10), "{errs:?}");
}

#[test]
fn shape_mismatch_rejected() {
    let Some(rt) = runtime() else { return };
    let wrong = vec![1.0f64; rt.n() + 1];
    assert!(rt.copy(&wrong).is_err());
}

#[test]
fn validate_artifact_detects_corruption() {
    let Some(rt) = runtime() else { return };
    let n = rt.n();
    let a = vec![1.0f64; n];
    let (mut a2, b2, c2) = rt.run(&a, STREAM_Q).unwrap();
    a2[n / 2] += 0.5; // corrupt one element
    let errs = rt.validate(&a2, &b2, &c2, STREAM_Q).unwrap();
    assert!(errs[0] > 0.4, "corruption not detected: {errs:?}");
}

#[test]
fn load_subset_only_compiles_requested() {
    let Some(_) = runtime() else { return };
    let rt = PjrtRuntime::load_subset("artifacts", &["copy"]).unwrap();
    assert!(rt.has("copy"));
    assert!(!rt.has("triad"));
    let a = vec![2.5f64; rt.n()];
    assert_eq!(rt.copy(&a).unwrap(), a);
    assert!(rt.triad(&a, &a, 1.0).is_err());
}

fn assert_close(got: &[f64], want: &[f64], tol: f64) {
    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= tol * (1.0 + w.abs()),
            "idx {i}: {g} vs {w}"
        );
    }
}
