//! Properties of the coalesced per-peer remap path.
//!
//! The per-peer rewrite must be invisible except in the message
//! counts: for random map pairs × every sealed dtype × host-class and
//! threaded backends, the remapped values are bit-identical to the
//! per-range reference (the destination's `from_global_fn` ground
//! truth), while each PID sends exactly one message per **distinct
//! destination peer** — not one per plan step — and receives one per
//! distinct source peer. The same holds over the file transport
//! (multi-part spool writes + polled arrival-order receives).

use distarray::backend::{Backend, ChunkedThreadedBackend, HostBackend};
use distarray::comm::{ChannelHub, FileTransport, Transport};
use distarray::darray::{DarrayT, RemapEngine};
use distarray::dmap::{Dmap, Pid};
use distarray::element::Element;
use distarray::prop::{forall, Rng};
use std::collections::HashSet;
use std::sync::Arc;
use std::thread;

/// Deterministic test values, exactly representable in every sealed
/// dtype (small non-negative integers) so equality is bitwise.
fn value<T: Element>(g: usize) -> T {
    T::from_f64(((g * 37 + 11) % 256) as f64)
}

fn random_map(rng: &mut Rng, np: usize) -> Dmap {
    match rng.below(3) {
        0 => Dmap::block_1d(np),
        1 => Dmap::cyclic_1d(np),
        _ => Dmap::block_cyclic_1d(np, rng.range(2, 6)),
    }
}

/// Distinct crossing peers of `pid` per the raw transfer list — the
/// reference the coalesced counts must match.
fn distinct_peers(
    transfers: &[(Pid, Pid, distarray::dmap::GlobalRange)],
    pid: Pid,
) -> (HashSet<Pid>, HashSet<Pid>) {
    let sends = transfers
        .iter()
        .filter(|(s, d, _)| s != d && *s == pid)
        .map(|&(_, d, _)| d)
        .collect();
    let recvs = transfers
        .iter()
        .filter(|(s, d, _)| s != d && *d == pid)
        .map(|&(s, _, _)| s)
        .collect();
    (sends, recvs)
}

/// Run one SPMD remap and assert value correctness + per-peer message
/// counts; `backend = None` exercises the direct engine path.
fn check_remap_t<T: Element>(
    np: usize,
    n: usize,
    src_map: &Dmap,
    dst_map: &Dmap,
    backend: Option<Arc<dyn Backend>>,
) {
    let engine = Arc::new(RemapEngine::new());
    let world = ChannelHub::world(np);
    let mut hs = Vec::new();
    for t in world {
        let engine = engine.clone();
        let (sm, dm) = (src_map.clone(), dst_map.clone());
        let backend = backend.clone();
        hs.push(thread::spawn(move || {
            let pid = t.pid();
            let src = DarrayT::<T>::from_global_fn(sm, &[n], pid, value::<T>);
            let mut dst = DarrayT::<T>::zeros(dm.clone(), &[n], pid);
            match &backend {
                Some(be) => dst
                    .assign_from_engine_on(&src, &t, 1, &engine, be.as_ref())
                    .unwrap(),
                None => dst.assign_from_engine(&src, &t, 1, &engine).unwrap(),
            }
            // Bit-identical to the per-range reference.
            let expect = DarrayT::<T>::from_global_fn(dm, &[n], pid, value::<T>);
            assert_eq!(dst.loc(), expect.loc(), "pid {pid} values");
            // Message counts: one per distinct peer, per direction.
            let plan = engine.plan(src.map(), dst.map(), &[n]);
            let (send_peers, recv_peers) = distinct_peers(plan.transfers(), pid);
            assert_eq!(plan.peer_sends(pid).len(), send_peers.len(), "pid {pid}");
            assert_eq!(plan.peer_recvs(pid).len(), recv_peers.len(), "pid {pid}");
            assert_eq!(
                t.stats().msgs_sent() as usize,
                send_peers.len(),
                "pid {pid}: one message per destination peer"
            );
            assert_eq!(
                t.stats().msgs_recv() as usize,
                recv_peers.len(),
                "pid {pid}: one message per source peer"
            );
        }));
    }
    for h in hs {
        h.join().unwrap();
    }
    assert_eq!(engine.plans_built(), 1, "exactly one plan per key");
}

#[test]
fn coalesced_remap_matches_reference_all_dtypes_and_backends() {
    // Shared across cases: a deliberately tiny tile (64 B) so even
    // small payloads exercise the pool-parallel pack/unpack.
    let chunked: Arc<dyn Backend> = Arc::new(ChunkedThreadedBackend::with_tile(3, 64));
    let host: Arc<dyn Backend> = Arc::new(HostBackend::new());
    forall(10, 0xC0A1E5CE, |rng| {
        let np = rng.range(2, 4);
        let n = rng.range(8, 160);
        let src = random_map(rng, np);
        let dst = random_map(rng, np);
        check_remap_t::<f64>(np, n, &src, &dst, None);
        check_remap_t::<f32>(np, n, &src, &dst, None);
        check_remap_t::<i64>(np, n, &src, &dst, None);
        check_remap_t::<u64>(np, n, &src, &dst, None);
        check_remap_t::<f64>(np, n, &src, &dst, Some(host.clone()));
        check_remap_t::<f64>(np, n, &src, &dst, Some(chunked.clone()));
        check_remap_t::<f32>(np, n, &src, &dst, Some(chunked.clone()));
    });
}

/// The acceptance criterion verbatim: block→cyclic on np=4 — each PID
/// sends exactly one message per destination peer (3 of them), far
/// fewer than the plan-step count the old path used.
#[test]
fn block_to_cyclic_np4_one_message_per_destination_peer() {
    let np = 4;
    let n = 256;
    let engine = Arc::new(RemapEngine::new());
    let world = ChannelHub::world(np);
    let mut hs = Vec::new();
    for t in world {
        let engine = engine.clone();
        hs.push(thread::spawn(move || {
            let pid = t.pid();
            let src = DarrayT::<f64>::from_global_fn(Dmap::block_1d(np), &[n], pid, value::<f64>);
            let mut dst = DarrayT::<f64>::zeros(Dmap::cyclic_1d(np), &[n], pid);
            dst.assign_from_engine(&src, &t, 7, &engine).unwrap();
            assert_eq!(t.stats().msgs_sent(), 3, "pid {pid}: one send per peer");
            assert_eq!(t.stats().msgs_recv(), 3, "pid {pid}: one recv per peer");
            let plan = engine.plan(src.map(), dst.map(), &[n]);
            let steps = plan
                .transfers()
                .iter()
                .filter(|(s, d, _)| s != d && *s == pid)
                .count();
            assert!(steps > 3, "coalescing must merge {steps} plan steps into 3 messages");
        }));
    }
    for h in hs {
        h.join().unwrap();
    }
}

/// The same coalesced path over the file transport: multi-part spool
/// writes, polled try_recv sweeps, exponential backoff.
#[test]
fn coalesced_remap_over_file_transport() {
    let np = 3;
    let n = 48;
    let dir = std::env::temp_dir().join(format!("distarray_coalesce_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut hs = Vec::new();
    for pid in 0..np {
        let dir = dir.clone();
        hs.push(thread::spawn(move || {
            let t = FileTransport::new(&dir, pid, np)
                .unwrap()
                .with_poll(std::time::Duration::from_micros(50));
            let src = DarrayT::<i64>::from_global_fn(Dmap::block_1d(np), &[n], pid, value::<i64>);
            let mut dst = DarrayT::<i64>::zeros(Dmap::cyclic_1d(np), &[n], pid);
            dst.assign_from(&src, &t, 3).unwrap();
            let expect =
                DarrayT::<i64>::from_global_fn(Dmap::cyclic_1d(np), &[n], pid, value::<i64>);
            assert_eq!(dst.loc(), expect.loc(), "pid {pid}");
            assert_eq!(t.stats().msgs_sent(), (np - 1) as u64, "pid {pid}");
        }));
    }
    for h in hs {
        h.join().unwrap();
    }
    let _ = std::fs::remove_dir_all(&dir);
}
