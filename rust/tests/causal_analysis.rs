//! Acceptance for the causal analysis plane: histogram properties
//! (merge of splits equals the whole, monotone quantiles, saturating
//! counters), cross-rank message matching on synthetic traces with
//! skewed anchors and ring-wrap losses, and an end-to-end in-process
//! four-rank traced run whose every `chunk_send` matches an arrive,
//! whose critical path covers the wall span, and whose per-rank
//! busy/idle times partition the wall exactly.

use distarray::collective::{Collective, ReduceOp, TagSpace};
use distarray::comm::{tags, ChannelHub, Transport};
use distarray::darray::Darray;
use distarray::dmap::Dmap;
use distarray::json::Json;
use distarray::obs::analyze::{analyze_files, AnalyzeOpts};
use distarray::obs::causal::{critical_path, match_edges, CEvent, Streams};
use distarray::obs::hist::{bucket_hi, bucket_of, HistSnapshot};
use distarray::obs::{self, EventKind};
use distarray::prop::{forall, Rng};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::thread;

/// obs state (gate, ring, sink, histograms) is process-global; the
/// test that touches it runs serialized with any future siblings.
fn obs_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

fn tmp(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("{name}_{}", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

// ---------------------------------------------------------------------------
// Histogram properties
// ---------------------------------------------------------------------------

fn random_value(rng: &mut Rng) -> u64 {
    // Shift by a random amount so samples spread over every bucket
    // scale instead of clustering at 64-bit magnitudes.
    rng.next_u64() >> rng.below(64)
}

#[test]
fn hist_merge_of_random_splits_equals_the_whole() {
    forall(50, 0x5EED_0001, |rng| {
        let n = rng.range(1, 200);
        let mut whole = HistSnapshot::new();
        let mut left = HistSnapshot::new();
        let mut right = HistSnapshot::new();
        for _ in 0..n {
            let v = random_value(rng);
            whole.record(v);
            if rng.bool() {
                left.record(v);
            } else {
                right.record(v);
            }
        }
        left.merge(&right);
        assert_eq!(left, whole, "merge of a random split must equal the whole");
    });
}

#[test]
fn hist_quantiles_are_monotone_and_bucket_bounded() {
    forall(50, 0x5EED_0002, |rng| {
        let mut h = HistSnapshot::new();
        let n = rng.range(1, 300);
        let mut max = 0u64;
        for _ in 0..n {
            let v = random_value(rng);
            max = max.max(v);
            h.record(v);
        }
        let mut prev = 0u64;
        for q in [0.0, 0.1, 0.5, 0.9, 0.99, 1.0] {
            let x = h.quantile(q);
            assert!(x >= prev, "quantiles must be monotone: q{q} gave {x} < {prev}");
            prev = x;
        }
        // Log2 buckets bound any quantile by the max sample's bucket.
        assert!(h.quantile(1.0) <= bucket_hi(bucket_of(max)));
    });
}

#[test]
fn hist_counters_saturate_instead_of_wrapping() {
    let mut a = HistSnapshot::new();
    a.count = u64::MAX - 2;
    a.sum = u64::MAX - 2;
    a.counts[bucket_of(7)] = u64::MAX - 2;
    let b = a.clone();
    a.merge(&b);
    assert_eq!(a.count, u64::MAX);
    assert_eq!(a.sum, u64::MAX);
    assert_eq!(a.counts[bucket_of(7)], u64::MAX);
    a.record(7);
    assert_eq!(a.count, u64::MAX, "record at the ceiling must stick, not wrap");
}

// ---------------------------------------------------------------------------
// Causal matching on synthetic traces
// ---------------------------------------------------------------------------

fn send(rank: i64, peer: i64, at_ns: u64, step: u64) -> CEvent {
    CEvent {
        t_ns: at_ns,
        dur_ns: 0,
        at_ns,
        kind: EventKind::ChunkSend,
        rank,
        peer,
        ns: 8,
        epoch: 1,
        step,
        bytes: 4096,
        transport: 0,
    }
}

fn arrive(rank: i64, peer: i64, at_ns: u64, step: u64) -> CEvent {
    CEvent { kind: EventKind::ChunkArrive, rank, peer, ..send(rank, peer, at_ns, step) }
}

#[test]
fn random_traffic_matching_accounts_for_every_send() {
    forall(30, 0x5EED_0003, |rng| {
        let mut s = Streams::default();
        let n = rng.range(1, 40);
        let mut expect_matched = 0u64;
        let mut expect_unmatched = 0u64;
        for i in 0..n {
            let from = rng.below(4) as i64;
            let to = (from + 1 + rng.below(3) as i64) % 4;
            let t = (i as u64) * 100 + rng.below(50) as u64;
            s.events.push(send(from, to, t, i as u64));
            if rng.below(10) < 8 {
                s.events.push(arrive(to, from, t + 30, i as u64));
                expect_matched += 1;
            } else {
                // The arrive was lost to ring wrap: a partial edge.
                expect_unmatched += 1;
            }
        }
        let g = match_edges(&s);
        assert_eq!(g.edges.len() as u64, expect_matched);
        assert_eq!(g.unmatched_sends, expect_unmatched);
        assert_eq!(g.unmatched_arrives, 0);
        // The walk never panics and stays within the global span.
        let cp = critical_path(&s, &g);
        let start = s.events.iter().map(|e| e.at_ns).min().unwrap();
        let end = s.events.iter().map(|e| e.at_ns + e.dur_ns).max().unwrap();
        assert_eq!((cp.start_ns, cp.end_ns), (start, end));
        for seg in &cp.segments {
            assert!(seg.t0_ns >= start && seg.t1_ns <= end && seg.t1_ns >= seg.t0_ns);
        }
    });
}

/// One rank's trace file: opening meta (wall anchor), events, closing
/// meta (drop count) — the exact shape `close_sink` writes.
fn write_rank_file(path: &str, rank: i64, anchor: u64, events: &[String], dropped: u64) {
    let mut s =
        format!("{{\"schema\":\"trace_meta_v1\",\"rank\":{rank},\"wall_anchor_ns\":{anchor}}}\n");
    for line in events {
        s.push_str(line);
        s.push('\n');
    }
    s.push_str(&format!(
        "{{\"schema\":\"trace_meta_v1\",\"rank\":{rank},\"dropped\":{dropped},\"recorded\":9}}\n"
    ));
    std::fs::write(path, s).unwrap();
}

fn event_line(kind: &str, rank: i64, t_ns: u64, dur_ns: u64, peer: i64, step: u64) -> String {
    format!(
        "{{\"schema\":\"trace_event_v1\",\"kind\":\"{kind}\",\"rank\":{rank},\"t_ns\":{t_ns},\
         \"dur_ns\":{dur_ns},\"peer\":{peer},\"ns\":8,\"epoch\":1,\"step\":{step},\
         \"bytes\":4096,\"chunk\":{step}}}"
    )
}

/// Four per-rank files forming a known pipeline chain
/// 0 → 1 → 2 → 3, with rank 2's wall anchor deliberately 6 µs low —
/// the edge into rank 2 gets a negative latency, which must surface
/// as a skew estimate and a warning, never as a crash.
#[test]
fn skewed_anchors_surface_as_a_skew_estimate_and_warning() {
    let mk = |r: usize| tmp(&format!("causal_skew_r{r}"));
    let base = 1_000_000u64;
    write_rank_file(
        &mk(0),
        0,
        base,
        &[
            event_line("remap_exec", 0, 0, 100, -1, 0),
            event_line("chunk_send", 0, 100, 0, 1, 0),
        ],
        0,
    );
    write_rank_file(
        &mk(1),
        1,
        base,
        &[
            event_line("chunk_arrive", 1, 130, 10, 0, 0),
            event_line("remap_exec", 1, 140, 60, -1, 0),
            event_line("chunk_send", 1, 200, 0, 2, 1),
        ],
        0,
    );
    write_rank_file(
        &mk(2),
        2,
        base - 6000, // the skewed clock
        &[
            event_line("chunk_arrive", 2, 230, 10, 1, 1),
            event_line("remap_exec", 2, 240, 60, -1, 0),
            event_line("chunk_send", 2, 300, 0, 3, 2),
        ],
        0,
    );
    write_rank_file(
        &mk(3),
        3,
        base,
        &[
            event_line("chunk_arrive", 3, 330, 10, 2, 2),
            event_line("remap_exec", 3, 340, 60, -1, 0),
        ],
        0,
    );
    let files: Vec<String> = (0..4).map(mk).collect();
    let a = analyze_files(&files, &AnalyzeOpts::default()).unwrap();
    assert_eq!(a.graph.edges.len(), 3, "all three hops match despite the skew");
    // Rank 2's arrive lands (aligned) before rank 1's send: the
    // magnitude is a lower bound on the anchor disagreement.
    assert_eq!(a.graph.skew_est_ns, 5960);
    assert_eq!(a.graph.min_latency_ns, 40);
    assert!(a.graph.skew_exceeds_min_latency());
    assert!(
        a.warnings.iter().any(|w| w.contains("clock skew")),
        "warnings: {:?}",
        a.warnings
    );
    // The path still tiles the whole (aligned) wall span.
    assert_eq!(a.path.total_ns(), a.wall_ns);
    let covered: u64 = a.path.segments.iter().map(|s| s.dur_ns()).sum();
    assert_eq!(covered, a.path.total_ns(), "{:#?}", a.path.segments);
    let doc = Json::parse(&a.to_json()).expect("analysis_v1 parses");
    assert_eq!(doc.get("clock_skew_ns_est").unwrap().as_usize(), Some(5960));
    for f in &files {
        std::fs::remove_file(f).ok();
    }
}

/// A ring-wrapped run: rank 1's arrive line was dropped before the
/// drain reached it. The matcher degrades to partial edges, counts
/// the loss, and the analysis warns — nothing panics.
#[test]
fn dropped_events_degrade_to_partial_edges_with_warnings() {
    let mk = |r: usize| tmp(&format!("causal_drop_r{r}"));
    let base = 2_000_000u64;
    write_rank_file(
        &mk(0),
        0,
        base,
        &[
            event_line("remap_exec", 0, 0, 100, -1, 0),
            event_line("chunk_send", 0, 100, 0, 1, 0),
        ],
        0,
    );
    // Rank 1 lost its arrive to ring wrap (dropped=1 in the closer).
    write_rank_file(
        &mk(1),
        1,
        base,
        &[
            event_line("remap_exec", 1, 140, 60, -1, 0),
            event_line("chunk_send", 1, 200, 0, 2, 1),
        ],
        1,
    );
    write_rank_file(
        &mk(2),
        2,
        base,
        &[
            event_line("chunk_arrive", 2, 230, 10, 1, 1),
            event_line("remap_exec", 2, 240, 60, -1, 0),
            event_line("chunk_send", 2, 300, 0, 3, 2),
        ],
        0,
    );
    write_rank_file(
        &mk(3),
        3,
        base,
        &[
            event_line("chunk_arrive", 3, 330, 10, 2, 2),
            event_line("remap_exec", 3, 340, 60, -1, 0),
        ],
        0,
    );
    let files: Vec<String> = (0..4).map(mk).collect();
    let a = analyze_files(&files, &AnalyzeOpts::default()).unwrap();
    assert_eq!(a.graph.edges.len(), 2);
    assert_eq!(a.graph.unmatched_sends, 1);
    assert_eq!(a.streams.total_dropped(), 1);
    assert!(a.warnings.iter().any(|w| w.contains("ring wrap")), "{:?}", a.warnings);
    assert!(a.warnings.iter().any(|w| w.contains("no counterpart")), "{:?}", a.warnings);
    // Render and JSON both survive partial graphs.
    let _ = a.render();
    Json::parse(&a.to_json()).expect("analysis_v1 parses");
    for f in &files {
        std::fs::remove_file(f).ok();
    }
}

// ---------------------------------------------------------------------------
// End to end: traced in-process 4-rank run → analyze
// ---------------------------------------------------------------------------

/// ISSUE acceptance: on a traced four-rank run, every recorded
/// `chunk_send` matches its `chunk_arrive` (the datapath instruments
/// both ends of every hop), the critical path covers at least the
/// wall span, per-rank busy + idle partition the wall exactly, and
/// achieved-vs-modeled bandwidth is reported.
#[test]
fn four_rank_traced_run_analyzes_end_to_end() {
    if !obs::COMPILED {
        return; // obs-off build: nothing to trace by design
    }
    let _g = obs_lock();
    let trace = tmp("causal_e2e_trace");
    obs::set_rank(0);
    obs::emit::install_sink(&trace).expect("open trace sink");
    obs::set_enabled(true);

    let np = 4;
    let n = 20_000;
    let hs: Vec<_> = ChannelHub::world(np)
        .into_iter()
        .map(|t| {
            thread::spawn(move || {
                let pid = t.pid();
                obs::set_thread_rank(pid);
                let src =
                    Darray::from_global_fn(Dmap::block_1d(np), &[n], pid, |g| g as f64);
                let mut dst = Darray::zeros(Dmap::cyclic_1d(np), &[n], pid);
                dst.assign_from(&src, &t, 1).unwrap();
                let coll = Collective::star(np);
                let local = vec![pid as f64; 64];
                let sum = coll
                    .allreduce(&t, TagSpace::packed(tags::NS_COLL, 41), &local, ReduceOp::Sum)
                    .unwrap();
                assert_eq!(sum[0], (0..np).map(|p| p as f64).sum::<f64>());
                obs::clear_thread_rank();
            })
        })
        .collect();
    for h in hs {
        h.join().unwrap();
    }

    obs::set_enabled(false);
    obs::emit::close_sink();

    let files = vec![trace.clone()];
    let a = analyze_files(&files, &AnalyzeOpts::default()).expect("trace analyzes");

    // Every recorded send has its matched arrive: chunk hops are
    // instrumented symmetrically and the ring did not wrap.
    let sends =
        a.streams.events.iter().filter(|e| e.kind == EventKind::ChunkSend).count();
    assert!(sends > 0, "a 4-rank remap must move chunks");
    assert_eq!(a.graph.edges.len(), sends, "matched edges == chunk_send count");
    assert_eq!(a.graph.unmatched_sends, 0);
    assert_eq!(a.graph.unmatched_arrives, 0);
    assert_eq!(a.streams.total_dropped(), 0);

    // The critical path covers the wall span.
    assert!(a.path.total_ns() >= a.wall_ns, "{} < {}", a.path.total_ns(), a.wall_ns);
    assert!(!a.path.segments.is_empty());

    // Busy + idle partition each rank's wall exactly.
    assert_eq!(a.ranks.len(), np);
    for r in &a.ranks {
        assert_eq!(r.busy_ns + r.idle_ns(), r.wall_ns(), "rank {}", r.rank);
    }

    // Bandwidth is reported on both sides of the comparison.
    assert!(a.achieved_bw > 0.0);
    assert!(a.modeled_bw > 0.0, "default era must resolve");

    // The runtime histograms rode the trace file and fold non-empty.
    let hists = a.merged_hists();
    assert!(
        hists.get("chunk_arrive_wait_ns").map(|h| h.count > 0).unwrap_or(false),
        "chunk-wait histogram missing from trace; got {:?}",
        hists.keys().collect::<Vec<_>>()
    );
    assert!(
        hists.get("coll_round_ns").map(|h| h.count > 0).unwrap_or(false),
        "collective-round histogram missing from trace"
    );

    // The machine document CI consumes round-trips.
    let doc = Json::parse(&a.to_json()).expect("analysis_v1 parses");
    assert_eq!(doc.get("schema").unwrap().as_str(), Some("analysis_v1"));
    assert_eq!(doc.get("matched_edges").unwrap().as_usize(), Some(sends));
    let per_rank = doc.get("per_rank").unwrap().items().unwrap();
    assert_eq!(per_rank.len(), np);

    std::fs::remove_file(&trace).ok();
}
