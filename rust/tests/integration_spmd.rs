//! Integration tests: full SPMD flows over the in-process transport —
//! the distributed-array programming model end to end.

use distarray::comm::{barrier::barrier, ChannelHub, Transport};
use distarray::coordinator::{run_leader, run_worker, EngineKind, MapKind, RunConfig};
use distarray::darray::Darray;
use distarray::dmap::Dmap;
use distarray::stream::{aggregate, run_parallel, STREAM_Q};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

fn spmd<R: Send + 'static>(
    np: usize,
    f: impl Fn(usize, &dyn Transport) -> R + Send + Sync + 'static,
) -> Vec<R> {
    let world = ChannelHub::world(np);
    let f = Arc::new(f);
    let hs: Vec<_> = world
        .into_iter()
        .map(|t| {
            let f = f.clone();
            thread::spawn(move || f(t.pid(), &t))
        })
        .collect();
    hs.into_iter().map(|h| h.join().unwrap()).collect()
}

/// Figure 2's central property: the same-map STREAM communicates
/// NOTHING — asserted over the real transport, not assumed.
#[test]
fn same_map_stream_is_communication_free() {
    let silent = spmd(6, |pid, t| {
        let r = run_parallel(&Dmap::block_1d(6), 6 * 4096, 4, STREAM_Q, pid);
        assert!(r.validation.passed);
        t.stats().is_silent()
    });
    assert!(silent.into_iter().all(|s| s), "Figure 2 violated: traffic observed");
}

/// Chained remaps through all three distributions preserve content.
#[test]
fn remap_chain_roundtrip() {
    spmd(4, |pid, t| {
        let n = 10_000;
        let block = Darray::from_global_fn(Dmap::block_1d(4), &[n], pid, |g| (g * 3 + 1) as f64);
        let mut cyc = Darray::zeros(Dmap::cyclic_1d(4), &[n], pid);
        cyc.assign_from(&block, t, 1).unwrap();
        let mut bc = Darray::zeros(Dmap::block_cyclic_1d(4, 7), &[n], pid);
        bc.assign_from(&cyc, t, 2).unwrap();
        let mut back = Darray::zeros(Dmap::block_1d(4), &[n], pid);
        back.assign_from(&bc, t, 3).unwrap();
        assert_eq!(back.loc(), block.loc(), "pid {pid}: chain corrupted data");
    });
}

/// agg() after a parallel STREAM returns the closed-form constants.
#[test]
fn stream_then_agg_full_array() {
    spmd(3, |pid, t| {
        let n = 999;
        let map = Dmap::block_1d(3);
        // Run one STREAM iteration on darrays, then aggregate A.
        let mut a = Darray::constant(map.clone(), &[n], pid, 1.0);
        let mut b = Darray::constant(map.clone(), &[n], pid, 2.0);
        let mut c = Darray::constant(map.clone(), &[n], pid, 0.0);
        for _ in 0..5 {
            c.copy_from(&a).unwrap();
            b.scale_from(&c, STREAM_Q).unwrap();
            let tmp = c.clone();
            c.add_from(&a, &b).unwrap();
            drop(tmp);
            let b2 = b.clone();
            a.triad_from(&b2, &c, STREAM_Q).unwrap();
        }
        let global = a.agg(t, 9).unwrap();
        if pid == 0 {
            let g = global.unwrap();
            assert_eq!(g.len(), n);
            for v in g {
                assert!((v - 1.0).abs() < 1e-12, "A must stay 1.0 with q=√2−1");
            }
        }
    });
}

/// Halo exchange composes with owner-computes stencils.
#[test]
fn halo_stencil_flow() {
    spmd(4, |pid, t| {
        let n = 40;
        let map = Dmap::block_1d_overlap(4, 1);
        let mut u = Darray::from_global_fn(map.clone(), &[n], pid, |g| g as f64);
        u.sync_halo(t, 0).unwrap();
        // forward difference using the halo: d[i] = u[i+1] - u[i] == 1
        let owned = u.local_len();
        let stored = u.stored().to_vec();
        let coord = map.coord_of(pid)[0];
        let last = if coord == 3 { owned - 1 } else { owned };
        for i in 0..last {
            let d = stored[i + 1] - stored[i];
            assert_eq!(d, 1.0, "pid {pid} i={i}");
        }
    });
}

/// Barriers interleave with data traffic without tag collisions.
#[test]
fn barrier_and_data_interleave() {
    spmd(5, |pid, t| {
        for epoch in 0..10u64 {
            let n = 500;
            let src = Darray::from_global_fn(Dmap::block_1d(5), &[n], pid, |g| (g + epoch as usize) as f64);
            let mut dst = Darray::zeros(Dmap::cyclic_1d(5), &[n], pid);
            dst.assign_from(&src, t, 100 + epoch).unwrap();
            barrier(t, epoch, Duration::from_secs(10)).unwrap();
            for g in (pid..n).step_by(97) {
                if let Some(v) = dst.global_get(g) {
                    assert_eq!(v, (g + epoch as usize) as f64);
                }
            }
        }
    });
}

/// Coordinator protocol across every map kind.
#[test]
fn coordinator_all_map_kinds() {
    for map in [MapKind::Block, MapKind::Cyclic, MapKind::BlockCyclic { block_size: 64 }] {
        let np = 4;
        let mut world = ChannelHub::world(np);
        let leader = world.remove(0);
        let hs: Vec<_> = world
            .into_iter()
            .map(|t| thread::spawn(move || run_worker(&t).unwrap()))
            .collect();
        let cfg = RunConfig {
            n_global: 40_000,
            nt: 2,
            q: STREAM_Q,
            map,
            engine: EngineKind::Native,
            dtype: distarray::element::Dtype::F64,
            backend: distarray::backend::BackendKind::Host,
            threads: 1,
            coll: distarray::collective::CollKind::Star,
            nppn: 0,
            chunk_bytes: 0,
            artifacts: "artifacts".into(),
            trace: false,
            heartbeat: false,
            checkpoint: String::new(),
            restore: false,
            transport: distarray::comm::TransportKind::Channel,
            recv_timeout_ms: 0,
        };
        let (agg, results) = run_leader(&leader, &cfg).unwrap();
        for h in hs {
            h.join().unwrap();
        }
        assert!(agg.all_valid, "{map:?}");
        assert_eq!(results.iter().map(|r| r.n_local).sum::<usize>(), 40_000);
    }
}

/// Aggregate bandwidth equals the sum of per-process bandwidths.
#[test]
fn aggregate_is_sum_of_locals() {
    let results = spmd(4, |pid, _| run_parallel(&Dmap::block_1d(4), 4 * 8192, 3, STREAM_Q, pid));
    let sum: f64 = results.iter().map(|r| r.bandwidths()[3]).sum();
    let agg = aggregate(&results).unwrap();
    assert!((agg.triad_bw() - sum).abs() / sum < 1e-12);
}

/// Mixed engines in one world must still validate (engine is a
/// per-config choice; numerics are engine-independent).
#[test]
fn native_matches_reference_constants() {
    let results = spmd(2, |pid, _| run_parallel(&Dmap::block_1d(2), 2048, 50, STREAM_Q, pid));
    for r in results {
        assert!(r.validation.passed, "50 iterations drifted: {:?}", r.validation);
    }
}
