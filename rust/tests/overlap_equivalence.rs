//! Bit-identity of the compute-on-arrival datapath.
//!
//! The acceptance bar for the overlapped receive paths: the
//! chunk-granular, double-buffered remap receive
//! ([`ChunkedThreadedBackend::with_overlap`]) and the fold-on-arrival
//! elimination allreduce ([`Collective::with_overlap`]) must produce
//! results **bit-identical** to their serial (whole-message
//! reassembly) counterparts for every sealed dtype — including chunk
//! sizes that split single elements across chunk boundaries (the
//! carry paths), multi-chunk group headers, and uneven segment sizes.

use distarray::backend::ChunkedThreadedBackend;
use distarray::collective::{AllreduceOrder, CollKind, Collective, ReduceOp, TagSpace, Topology};
use distarray::comm::{datapath, tags, ChannelHub, Transport};
use distarray::darray::{DarrayT, RemapEngine};
use distarray::dmap::Dmap;
use distarray::element::Element;
use std::sync::{Arc, Mutex};
use std::thread;

/// Serializes tests that set the process-wide ambient chunk size (the
/// remap datapath reads it internally); the guard restores the
/// default even when an assertion unwinds.
static AMBIENT: Mutex<()> = Mutex::new(());

struct ChunkGuard;

impl Drop for ChunkGuard {
    fn drop(&mut self) {
        datapath::set_ambient_chunk_bytes(0);
    }
}

fn spmd<R: Send + 'static>(
    np: usize,
    f: impl Fn(&dyn Transport) -> R + Send + Sync + 'static,
) -> Vec<R> {
    let f = Arc::new(f);
    ChannelHub::world(np)
        .into_iter()
        .map(|t| {
            let f = f.clone();
            thread::spawn(move || f(&t))
        })
        .collect::<Vec<_>>()
        .into_iter()
        .map(|h| h.join().unwrap())
        .collect()
}

/// Deterministic, dtype-exact source values (integers in f64 range).
fn src_val<T: Element>(g: usize) -> T {
    T::from_f64(((g * 7919) % 2039) as f64)
}

/// One block→cyclic remap per PID through the chunked backend,
/// returning every PID's destination slice.
fn remap_once<T: Element>(np: usize, n: usize, tile: usize, overlap: bool) -> Vec<Vec<T>> {
    let backend = Arc::new(ChunkedThreadedBackend::with_tile(2, tile).with_overlap(overlap));
    let engine = Arc::new(RemapEngine::new());
    spmd(np, move |t| {
        let pid = t.pid();
        let src = DarrayT::<T>::from_global_fn(Dmap::block_1d(np), &[n], pid, src_val);
        let mut dst = DarrayT::<T>::zeros(Dmap::cyclic_1d(np), &[n], pid);
        dst.assign_from_engine_on(&src, t, 1, &engine, &*backend).unwrap();
        dst.loc().to_vec()
    })
}

fn check_remap<T: Element>(np: usize, n: usize, tile: usize) {
    let on = remap_once::<T>(np, n, tile, true);
    let off = remap_once::<T>(np, n, tile, false);
    for pid in 0..np {
        let want = DarrayT::<T>::from_global_fn(Dmap::cyclic_1d(np), &[n], pid, src_val);
        assert_eq!(on[pid], off[pid], "overlap on vs off, pid={pid} {:?}", T::DTYPE);
        assert_eq!(on[pid], want.loc(), "overlap vs ground truth, pid={pid} {:?}", T::DTYPE);
    }
}

#[test]
fn overlapped_remap_bit_identical_across_dtypes() {
    let _serial = AMBIENT.lock().unwrap();
    let _restore = ChunkGuard;
    // 13-byte chunks split every element (and the group header)
    // across chunk boundaries — the GroupScatter carry paths.
    datapath::set_ambient_chunk_bytes(13);
    check_remap::<f64>(3, 101, 64);
    check_remap::<f32>(3, 101, 64);
    check_remap::<i64>(3, 101, 64);
    check_remap::<u64>(3, 101, 64);
}

#[test]
fn overlapped_remap_parallel_scatter_matches() {
    let _serial = AMBIENT.lock().unwrap();
    let _restore = ChunkGuard;
    // Chunk windows (4096 B) above the tile size (64 B): landed
    // windows fan out over the worker pool (`scatter_window_par`).
    datapath::set_ambient_chunk_bytes(4096);
    check_remap::<f64>(3, 12 * 1024, 64);
    check_remap::<f32>(2, 12 * 1024, 64);
}

/// Both allreduce modes in one world: overlap on and off at disjoint
/// epochs, per PID.
fn allreduce_both<T: Element>(np: usize, n: usize, op: ReduceOp) -> Vec<(Vec<T>, Vec<T>)> {
    spmd(np, move |t| {
        let base = Collective::new(CollKind::Auto, Topology::grouped(np, 3))
            .with_chunk_bytes(13)
            .with_elim_threshold(1);
        let local: Vec<T> = (0..n)
            .map(|j| T::from_f64((3 * t.pid() + 1) as f64 + (j % 17) as f64))
            .collect();
        let on = base
            .clone()
            .allreduce_ordered::<T>(
                t,
                TagSpace::packed(tags::NS_COLL, 1),
                &local,
                op,
                AllreduceOrder::Fast,
            )
            .unwrap();
        let off = base
            .with_overlap(false)
            .allreduce_ordered::<T>(
                t,
                TagSpace::packed(tags::NS_COLL, 2),
                &local,
                op,
                AllreduceOrder::Fast,
            )
            .unwrap();
        (on, off)
    })
}

fn check_allreduce<T: Element>(np: usize, op: ReduceOp) {
    let n = 4 * np + 3; // uneven segments
    for (pid, (on, off)) in allreduce_both::<T>(np, n, op).into_iter().enumerate() {
        assert_eq!(on, off, "overlap on vs off, np={np} pid={pid} {op:?} {:?}", T::DTYPE);
    }
}

#[test]
fn overlapped_allreduce_bit_identical_across_dtypes() {
    // 13-byte segment chunks split every element — the
    // fold-on-arrival carry buffer — at even and odd world sizes.
    for np in [2, 5] {
        check_allreduce::<f64>(np, ReduceOp::Sum);
        check_allreduce::<f32>(np, ReduceOp::Sum);
        check_allreduce::<i64>(np, ReduceOp::Sum);
        check_allreduce::<u64>(np, ReduceOp::Sum);
        check_allreduce::<f64>(np, ReduceOp::Min);
        check_allreduce::<i64>(np, ReduceOp::Max);
    }
}

/// The fold-on-arrival reduce-scatter must also agree with the star
/// reference exactly for integer sums (wrapping) and min/max — the
/// same bar the serial elimination schedule already meets.
#[test]
fn overlapped_allreduce_matches_star_reference_for_exact_ops() {
    let np = 5;
    let n = 4 * np + 3;
    let got = allreduce_both::<i64>(np, n, ReduceOp::Sum);
    let contribution =
        |pid: usize| -> Vec<i64> { (0..n).map(|j| (3 * pid + 1 + (j % 17)) as i64).collect() };
    let want = (1..np).fold(contribution(0), |acc, p| {
        acc.into_iter().zip(contribution(p)).map(|(a, b)| a.wrapping_add(b)).collect()
    });
    for (on, off) in got {
        assert_eq!(on, want, "fold-on-arrival vs star reference");
        assert_eq!(off, want, "serial elimination vs star reference");
    }
}
