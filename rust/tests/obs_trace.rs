//! Acceptance tests for the telemetry plane, end to end: the const
//! and runtime gates make tracing a no-op when off, and a four-rank
//! in-process traced run emits schema-valid NDJSON covering the
//! remap, collective, and datapath layers that folds, summarizes, and
//! exports to a loadable Chrome trace document.

use distarray::collective::{Collective, ReduceOp, TagSpace};
use distarray::comm::{tags, ChannelHub, Transport};
use distarray::darray::Darray;
use distarray::dmap::Dmap;
use distarray::json::Json;
use distarray::obs::{self, report};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::thread;

/// obs state (gate, ring, sink) is process-global; the tests that
/// touch it run serialized.
fn obs_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

fn tmp(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("{name}_{}", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

/// The zero-cost claim at the macro layer: with recording off (either
/// gate), `span_begin` hands out 0 and the recording macros store
/// nothing in the ring.
#[test]
fn disabled_tracing_records_nothing_and_spans_are_zero() {
    let _g = obs_lock();
    obs::set_enabled(false);
    let before = obs::recorder().recorded();
    assert_eq!(obs::span_begin(), 0, "span_begin must be 0 when recording is off");
    distarray::obs_event!(obs::EventKind::Mark, tag: 0, peer: obs::NO_PEER, a: 1, b: 2);
    let start = obs::span_begin();
    distarray::obs_span!(obs::EventKind::Mark, start, tag: 0, peer: obs::NO_PEER, a: 3, b: 4);
    assert_eq!(obs::recorder().recorded(), before, "disabled tracing must not record");
}

/// The const gate: `COMPILED` mirrors the `obs-off` feature, and in
/// an `obs-off` build the runtime switch can never stick.
#[test]
fn const_gate_wins_over_the_runtime_switch() {
    let _g = obs_lock();
    if obs::COMPILED {
        obs::set_enabled(true);
        assert!(obs::enabled());
        obs::set_enabled(false);
        assert!(!obs::enabled());
    } else {
        obs::set_enabled(true);
        assert!(!obs::enabled(), "obs-off build must never enable recording");
    }
}

/// ISSUE acceptance: a 4-rank traced run (threads standing in for
/// ranks) produces an NDJSON stream that validates line by line,
/// folds with all four ranks attributed, covers the remap,
/// collective, and datapath layers, and exports to a loadable Chrome
/// `trace_event` document.
#[test]
fn four_rank_traced_run_emits_valid_ndjson_and_chrome_export() {
    if !obs::COMPILED {
        return; // obs-off build: nothing to trace by design
    }
    let _g = obs_lock();
    let trace = tmp("obs_trace_accept");
    obs::set_rank(0);
    obs::emit::install_sink(&trace).expect("open trace sink");
    obs::set_enabled(true);

    let np = 4;
    let n = 20_000;
    let hs: Vec<_> = ChannelHub::world(np)
        .into_iter()
        .map(|t| {
            thread::spawn(move || {
                let pid = t.pid();
                obs::set_thread_rank(pid);
                // Remap through the chunked datapath: block -> cyclic
                // touches every peer pair.
                let src =
                    Darray::from_global_fn(Dmap::block_1d(np), &[n], pid, |g| g as f64);
                let mut dst = Darray::zeros(Dmap::cyclic_1d(np), &[n], pid);
                dst.assign_from(&src, &t, 1).unwrap();
                // Collective round on the same transport.
                let coll = Collective::star(np);
                let local = vec![pid as f64; 64];
                let sum = coll
                    .allreduce(&t, TagSpace::packed(tags::NS_COLL, 40), &local, ReduceOp::Sum)
                    .unwrap();
                assert_eq!(sum[0], (0..np).map(|p| p as f64).sum::<f64>());
                obs::clear_thread_rank();
            })
        })
        .collect();
    for h in hs {
        h.join().unwrap();
    }

    obs::set_enabled(false);
    obs::emit::close_sink();

    // Line-by-line schema validation (what CI runs as `trace-report
    // --check`).
    let files = vec![trace.clone()];
    let check = report::check_files(&files).expect("trace must be schema-valid NDJSON");
    let (lines, events) = (check.lines, check.events);
    assert!(events > 0, "traced run recorded no events");
    assert!(lines >= events + 2, "expected opening and closing meta lines");
    assert!(check.warnings.is_empty(), "clean run warned: {:?}", check.warnings);

    // Bounded fold: every rank attributed, every instrumented layer
    // present.
    let fold = report::fold_files(&files).expect("trace must fold");
    for rank in 0..np as i64 {
        assert!(fold.ranks.contains_key(&rank), "rank {rank} missing from fold");
    }
    assert_eq!(fold.total_events() as usize, events);
    let summary = report::render_summary(&fold);
    for kind in ["remap_exec", "chunk_send", "chunk_arrive", "coll_op"] {
        assert!(summary.contains(kind), "trace must cover '{kind}'; summary:\n{summary}");
    }

    // Chrome export loads as one JSON document with the same events.
    let chrome = tmp("obs_trace_chrome");
    report::write_chrome(&files, &chrome).expect("chrome export");
    let text = std::fs::read_to_string(&chrome).unwrap();
    let doc = Json::parse(text.trim()).expect("chrome document parses");
    let entries = doc.get("traceEvents").unwrap().items().unwrap();
    assert_eq!(entries.len(), events, "one chrome entry per trace event");
    assert!(entries.iter().all(|e| e.get("ph").is_some() && e.get("ts").is_some()));

    std::fs::remove_file(&trace).ok();
    std::fs::remove_file(&chrome).ok();
}
