//! # distarray — Easy Acceleration with Distributed Arrays
//!
//! A production-grade reproduction of Kepner et al., *"Easy
//! Acceleration with Distributed Arrays"* (HPEC 2025): a PGAS-style
//! distributed-array library with the STREAM memory-bandwidth
//! benchmark as its evaluation workload, structured as a three-layer
//! Rust + JAX + Pallas stack (see DESIGN.md).
//!
//! Layer map:
//! * **L3 (this crate)** — maps ([`dmap`]), distributed arrays
//!   ([`darray`]), transports ([`comm`]), triples launcher
//!   ([`launcher`]), leader/worker coordinator ([`coordinator`]),
//!   hardware-era models ([`hardware`]), STREAM drivers ([`stream`]),
//!   pluggable execution backends ([`backend`]), topology-aware
//!   collectives ([`collective`]), baseline programming models
//!   ([`baselines`]), report generators ([`report`]), and the
//!   runtime telemetry plane ([`obs`]).
//! * **L2/L1 (python/, build-time only)** — the STREAM step as a JAX
//!   graph over Pallas kernels, AOT-lowered to `artifacts/*.hlo.txt`
//!   and executed from Rust via [`runtime`].
//!
//! Quickstart:
//! ```no_run
//! use distarray::dmap::Dmap;
//! use distarray::stream::{run_parallel_spmd, STREAM_Q};
//!
//! // 4-process parallel STREAM over a block map, in-process SPMD.
//! let agg = run_parallel_spmd(&Dmap::block_1d(4), 1 << 20, 10, STREAM_Q);
//! println!("triad {:.2} GB/s (validated: {})",
//!          agg.triad_bw() / 1e9, agg.all_valid);
//! ```

pub mod backend;
pub mod baselines;
pub mod benchx;
pub mod cli;
pub mod collective;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod darray;
pub mod dmap;
pub mod element;
pub mod fault;
pub mod hardware;
pub mod json;
pub mod launcher;
pub mod obs;
pub mod prop;
pub mod report;
pub mod runtime;
pub mod stream;
