//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `repro <subcommand> [positional...] [--flag value|--flag]`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from raw args (excluding argv[0]).
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // --key=value or --key value or bare --key
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.flags.insert(name.to_string(), "true".into());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process's own args.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn flag_usize(&self, name: &str, default: usize) -> usize {
        self.flag(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn flag_f64(&self, name: &str, default: f64) -> f64 {
        self.flag(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn flag_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flag(name).unwrap_or(default)
    }

    pub fn flag_bool(&self, name: &str) -> bool {
        matches!(self.flag(name), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("run extra --np 8 --engine native --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.flag_usize("np", 1), 8);
        assert_eq!(a.flag_str("engine", "pjrt"), "native");
        assert!(a.flag_bool("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn bare_flag_before_positional_consumes_it() {
        // Documented ambiguity: `--verbose extra` binds "extra" as the
        // flag's value; use `--verbose=true` or trailing placement.
        let a = parse("run --verbose extra");
        assert_eq!(a.flag("verbose"), Some("extra"));
    }

    #[test]
    fn key_equals_value() {
        let a = parse("sweep --nodes=128 --out=fig3.csv");
        assert_eq!(a.flag_usize("nodes", 0), 128);
        assert_eq!(a.flag("out"), Some("fig3.csv"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("report");
        assert_eq!(a.flag_usize("np", 4), 4);
        assert_eq!(a.flag_f64("q", 0.5), 0.5);
        assert!(!a.flag_bool("verbose"));
    }

    #[test]
    fn empty_args() {
        let a = parse("");
        assert!(a.subcommand.is_none());
    }
}
