//! `artifacts/manifest.json` — shapes and filenames of the AOT
//! artifacts, written by `python/compile/aot.py`.

use super::{Result, RuntimeError};
use crate::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Metadata for one artifact.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    pub outputs: usize,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Vector length lowered into the artifacts.
    pub n: usize,
    /// Iteration count baked into the `run`/`validate` artifacts.
    pub nt: usize,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let j = Json::parse(&text)
            .map_err(|e| RuntimeError::Manifest(format!("parse: {e}")))?;
        let n = j
            .get("n")
            .and_then(Json::as_usize)
            .ok_or_else(|| RuntimeError::Manifest("missing 'n'".into()))?;
        let nt = j
            .get("nt")
            .and_then(Json::as_usize)
            .ok_or_else(|| RuntimeError::Manifest("missing 'nt'".into()))?;
        let arts = j
            .get("artifacts")
            .and_then(Json::obj)
            .ok_or_else(|| RuntimeError::Manifest("missing 'artifacts'".into()))?;
        let mut artifacts = BTreeMap::new();
        for (name, meta) in arts {
            let file = meta
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| RuntimeError::Manifest(format!("artifact {name}: no file")))?;
            let outputs = meta.get("outputs").and_then(Json::as_usize).unwrap_or(1);
            artifacts.insert(
                name.clone(),
                ArtifactMeta { name: name.clone(), file: dir.join(file), outputs },
            );
        }
        Ok(Manifest { n, nt, artifacts, dir })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| RuntimeError::MissingArtifact(name.into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("distarray_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"n": 1024, "nt": 5, "dtype": "f64",
                "artifacts": {"copy": {"file": "copy.hlo.txt", "outputs": 1},
                              "run": {"file": "run.hlo.txt", "outputs": 3, "nt": 5}}}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.n, 1024);
        assert_eq!(m.nt, 5);
        assert_eq!(m.get("run").unwrap().outputs, 3);
        assert!(m.get("copy").unwrap().file.ends_with("copy.hlo.txt"));
        assert!(m.get("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_is_io_error() {
        let r = Manifest::load("/nonexistent/dir");
        assert!(matches!(r, Err(RuntimeError::Io(_))));
    }
}
