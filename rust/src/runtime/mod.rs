//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt`,
//! produced once by `python/compile/aot.py`) and executes them from
//! Rust. Python never runs on this path.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! One compiled executable per artifact; executables are compiled at
//! load time and reused for every call (compilation never sits on the
//! hot path).

pub mod manifest;
pub mod pjrt;

pub use manifest::{ArtifactMeta, Manifest};
pub use pjrt::PjrtRuntime;

/// Runtime errors.
#[derive(Debug)]
pub enum RuntimeError {
    Xla(String),
    Io(std::io::Error),
    Manifest(String),
    MissingArtifact(String),
    ShapeMismatch { expected: usize, got: usize },
    /// The crate was built without the `pjrt` feature (the default in
    /// offline environments; the feature expects a vendored `xla`).
    Unavailable,
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::Xla(m) => write!(f, "xla error: {m}"),
            RuntimeError::Io(e) => write!(f, "io error: {e}"),
            RuntimeError::Manifest(m) => write!(f, "manifest error: {m}"),
            RuntimeError::MissingArtifact(n) => {
                write!(f, "artifact '{n}' not found (run `make artifacts`)")
            }
            RuntimeError::ShapeMismatch { expected, got } => {
                write!(f, "shape mismatch: artifact expects n={expected}, got {got}")
            }
            RuntimeError::Unavailable => write!(
                f,
                "pjrt execution unavailable: build with `--features pjrt` (requires a vendored xla crate)"
            ),
        }
    }
}

impl std::error::Error for RuntimeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RuntimeError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for RuntimeError {
    fn from(e: std::io::Error) -> Self {
        RuntimeError::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, RuntimeError>;
