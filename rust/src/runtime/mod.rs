//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt`,
//! produced once by `python/compile/aot.py`) and executes them from
//! Rust. Python never runs on this path.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! One compiled executable per artifact; executables are compiled at
//! load time and reused for every call (compilation never sits on the
//! hot path).

pub mod manifest;
pub mod pjrt;

pub use manifest::{ArtifactMeta, Manifest};
pub use pjrt::PjrtRuntime;

/// Runtime errors.
#[derive(Debug, thiserror::Error)]
pub enum RuntimeError {
    #[error("xla error: {0}")]
    Xla(String),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("manifest error: {0}")]
    Manifest(String),
    #[error("artifact '{0}' not found (run `make artifacts`)")]
    MissingArtifact(String),
    #[error("shape mismatch: artifact expects n={expected}, got {got}")]
    ShapeMismatch { expected: usize, got: usize },
}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, RuntimeError>;
