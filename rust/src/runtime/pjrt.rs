//! The PJRT executor: compile HLO-text artifacts once, execute many.
//!
//! The real executor wraps the external `xla` crate and is compiled
//! only under the `pjrt` feature (the crate is otherwise
//! dependency-free so it builds fully offline). The default build
//! ships the stub below, which keeps the whole API surface but
//! reports [`RuntimeError::Unavailable`] from `load`, so every
//! caller's graceful-skip path (`repro validate`, `stream_e2e`, the
//! integration tests, and the `--backend pjrt` execution backend in
//! [`crate::backend::PjrtBackend`]) exercises the same code shape
//! either way.

#[cfg(feature = "pjrt")]
mod imp {
    use super::super::manifest::Manifest;
    use super::super::{Result, RuntimeError};
    use std::collections::HashMap;
    use std::path::Path;

    /// Loaded PJRT runtime holding one compiled executable per artifact.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        manifest: Manifest,
        executables: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl PjrtRuntime {
        /// Load every artifact in `dir` and compile it on the CPU client.
        pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
            let manifest = Manifest::load(&dir)?;
            Self::compile(manifest)
        }

        /// Load only the named artifacts (faster startup for examples).
        pub fn load_subset(dir: impl AsRef<Path>, names: &[&str]) -> Result<Self> {
            let mut manifest = Manifest::load(&dir)?;
            manifest.artifacts.retain(|k, _| names.contains(&k.as_str()));
            Self::compile(manifest)
        }

        fn compile(manifest: Manifest) -> Result<Self> {
            let client = xla::PjRtClient::cpu()?;
            let mut executables = HashMap::new();
            for (name, meta) in &manifest.artifacts {
                let proto = xla::HloModuleProto::from_text_file(
                    meta.file
                        .to_str()
                        .ok_or_else(|| RuntimeError::Manifest("non-utf8 path".into()))?,
                )?;
                let comp = xla::XlaComputation::from_proto(&proto);
                executables.insert(name.clone(), client.compile(&comp)?);
            }
            Ok(PjrtRuntime { client, manifest, executables })
        }

        /// Vector length the artifacts were lowered with.
        pub fn n(&self) -> usize {
            self.manifest.n
        }

        /// Iterations baked into the `run` artifact.
        pub fn nt(&self) -> usize {
            self.manifest.nt
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub fn has(&self, name: &str) -> bool {
            self.executables.contains_key(name)
        }

        fn exe(&self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
            self.executables
                .get(name)
                .ok_or_else(|| RuntimeError::MissingArtifact(name.into()))
        }

        fn check_n(&self, got: usize) -> Result<()> {
            if got != self.manifest.n {
                return Err(RuntimeError::ShapeMismatch { expected: self.manifest.n, got });
            }
            Ok(())
        }

        /// Execute an artifact on f64 inputs (vectors and scalars),
        /// return all tuple outputs as vectors.
        pub fn execute(&self, name: &str, inputs: &[In<'_>]) -> Result<Vec<Vec<f64>>> {
            let exe = self.exe(name)?;
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|i| match i {
                    In::Vec(v) => xla::Literal::vec1(v),
                    In::Scalar(s) => xla::Literal::from(*s),
                })
                .collect();
            let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
            // aot.py lowers with return_tuple=True → always a tuple.
            let parts = result.to_tuple()?;
            let mut out = Vec::with_capacity(parts.len());
            for p in parts {
                out.push(p.to_vec::<f64>()?);
            }
            Ok(out)
        }

        // ---- typed wrappers over the STREAM artifacts ----

        /// `copy`: C = A.
        pub fn copy(&self, a: &[f64]) -> Result<Vec<f64>> {
            self.check_n(a.len())?;
            Ok(self.execute("copy", &[In::Vec(a)])?.remove(0))
        }

        /// `scale`: B = q·C.
        pub fn scale(&self, c: &[f64], q: f64) -> Result<Vec<f64>> {
            self.check_n(c.len())?;
            Ok(self.execute("scale", &[In::Vec(c), In::Scalar(q)])?.remove(0))
        }

        /// `add`: C = A + B.
        pub fn add(&self, a: &[f64], b: &[f64]) -> Result<Vec<f64>> {
            self.check_n(a.len())?;
            Ok(self.execute("add", &[In::Vec(a), In::Vec(b)])?.remove(0))
        }

        /// `triad`: A = B + q·C.
        pub fn triad(&self, b: &[f64], c: &[f64], q: f64) -> Result<Vec<f64>> {
            self.check_n(b.len())?;
            Ok(self
                .execute("triad", &[In::Vec(b), In::Vec(c), In::Scalar(q)])?
                .remove(0))
        }

        /// `step_fused`: one full STREAM iteration, returns (A', B', C').
        pub fn step_fused(&self, a: &[f64], q: f64) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>)> {
            self.check_n(a.len())?;
            let mut out = self.execute("step_fused", &[In::Vec(a), In::Scalar(q)])?;
            let c = out.pop().unwrap();
            let b = out.pop().unwrap();
            let a = out.pop().unwrap();
            Ok((a, b, c))
        }

        /// `run`: the full Nt-iteration STREAM (Nt from the manifest).
        /// Takes only the initial A — B and C are determined by A within
        /// the recurrence (they are overwritten in iteration 1).
        pub fn run(&self, a: &[f64], q: f64) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>)> {
            self.check_n(a.len())?;
            let mut out = self.execute("run", &[In::Vec(a), In::Scalar(q)])?;
            let c = out.pop().unwrap();
            let b = out.pop().unwrap();
            let a = out.pop().unwrap();
            Ok((a, b, c))
        }

        /// `validate`: [errA, errB, errC] against the closed forms.
        pub fn validate(&self, a: &[f64], b: &[f64], c: &[f64], q: f64) -> Result<Vec<f64>> {
            self.check_n(a.len())?;
            Ok(self
                .execute(
                    "validate",
                    &[In::Vec(a), In::Vec(b), In::Vec(c), In::Scalar(q)],
                )?
                .remove(0))
        }
    }

    /// An input to [`PjrtRuntime::execute`].
    pub enum In<'a> {
        Vec(&'a [f64]),
        Scalar(f64),
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use super::super::manifest::Manifest;
    use super::super::{Result, RuntimeError};
    use std::path::Path;

    /// Stub runtime: same API, always unavailable. `load` fails before
    /// a value is ever constructed, so the accessor bodies below are
    /// unreachable in practice but keep the surface type-checked.
    pub struct PjrtRuntime {
        manifest: Manifest,
    }

    impl PjrtRuntime {
        pub fn load(_dir: impl AsRef<Path>) -> Result<Self> {
            Err(RuntimeError::Unavailable)
        }

        pub fn load_subset(_dir: impl AsRef<Path>, _names: &[&str]) -> Result<Self> {
            Err(RuntimeError::Unavailable)
        }

        pub fn n(&self) -> usize {
            self.manifest.n
        }

        pub fn nt(&self) -> usize {
            self.manifest.nt
        }

        pub fn platform(&self) -> String {
            "unavailable".into()
        }

        pub fn has(&self, _name: &str) -> bool {
            false
        }

        pub fn execute(&self, _name: &str, _inputs: &[In<'_>]) -> Result<Vec<Vec<f64>>> {
            Err(RuntimeError::Unavailable)
        }

        pub fn copy(&self, _a: &[f64]) -> Result<Vec<f64>> {
            Err(RuntimeError::Unavailable)
        }

        pub fn scale(&self, _c: &[f64], _q: f64) -> Result<Vec<f64>> {
            Err(RuntimeError::Unavailable)
        }

        pub fn add(&self, _a: &[f64], _b: &[f64]) -> Result<Vec<f64>> {
            Err(RuntimeError::Unavailable)
        }

        pub fn triad(&self, _b: &[f64], _c: &[f64], _q: f64) -> Result<Vec<f64>> {
            Err(RuntimeError::Unavailable)
        }

        pub fn step_fused(&self, _a: &[f64], _q: f64) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>)> {
            Err(RuntimeError::Unavailable)
        }

        pub fn run(&self, _a: &[f64], _q: f64) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>)> {
            Err(RuntimeError::Unavailable)
        }

        pub fn validate(&self, _a: &[f64], _b: &[f64], _c: &[f64], _q: f64) -> Result<Vec<f64>> {
            Err(RuntimeError::Unavailable)
        }
    }

    /// An input to [`PjrtRuntime::execute`] (stub mirror).
    pub enum In<'a> {
        Vec(&'a [f64]),
        Scalar(f64),
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn stub_load_reports_unavailable() {
            let err = PjrtRuntime::load("artifacts");
            assert!(matches!(err, Err(RuntimeError::Unavailable)));
            let err = PjrtRuntime::load_subset("artifacts", &["copy"]);
            assert!(matches!(err, Err(RuntimeError::Unavailable)));
        }
    }
}

pub use imp::{In, PjrtRuntime};
