//! The [`Element`] trait — the scalar axis of the distributed-array
//! stack.
//!
//! The paper's distributed-array model is *dtype-independent*: the map
//! algebra, the owner-computes rule, and the remap planner all operate
//! on index sets, never on values. What the element type does control
//! is **bytes per element**, and bytes are the whole story for a
//! bandwidth benchmark: STREAM in `f32` moves half the bytes per
//! element of `f64`, so at equal bytes/second it streams ~2× the
//! elements/second (§III bytes-per-iteration formulas with width
//! `W = T::WIDTH`: Copy/Scale move `2·W·N` bytes, Add/Triad `3·W·N`).
//!
//! [`Element`] is a **sealed** trait implemented for exactly `f64`,
//! `f32`, `i64`, and `u64`. It supplies:
//!
//! * the algebra STREAM needs (`ZERO`/`ONE`, [`Element::add`],
//!   [`Element::mul`]) — wrapping for the integer types so debug
//!   builds cannot panic on overflow;
//! * the wire contract ([`Element::write_le`] / [`Element::read_le`]
//!   and `WIDTH`), plus the **bulk slice codec**
//!   ([`Element::copy_to_le`] / [`Element::copy_from_le`]) that
//!   compiles to a single memcpy on little-endian targets and backs
//!   the typed codec (`WireWriter::put_slice::<T>` /
//!   `WireReader::get_slice_into::<T>`) — the remap hot path never
//!   loops per element;
//! * f64 round-trips (`from_f64`/`to_f64`) for validation and
//!   reductions, plus a per-iteration validation tolerance
//!   (`TOL_BASE`) scaled to the type's roundoff;
//! * a runtime [`Dtype`] token for CLI flags, config files, and wire
//!   payload self-description.
//!
//! Sealing keeps the wire format and the remap engine's payload
//! assumptions closed: a foreign impl cannot introduce an unknown
//! width or encoding.

mod sealed {
    pub trait Sealed {}
    impl Sealed for f64 {}
    impl Sealed for f32 {}
    impl Sealed for i64 {}
    impl Sealed for u64 {}
}

/// Runtime identifier for an [`Element`] type — the `--dtype` axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dtype {
    F32,
    F64,
    I64,
    U64,
}

impl Dtype {
    /// Parse a CLI/config spelling (`f32`, `f64`, `i64`, `u64`).
    pub fn parse(s: &str) -> Option<Dtype> {
        match s {
            "f32" => Some(Dtype::F32),
            "f64" => Some(Dtype::F64),
            "i64" => Some(Dtype::I64),
            "u64" => Some(Dtype::U64),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::F64 => "f64",
            Dtype::I64 => "i64",
            Dtype::U64 => "u64",
        }
    }

    /// Bytes per element.
    pub fn width(&self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::F64 | Dtype::I64 | Dtype::U64 => 8,
        }
    }

    /// Stable wire code (payload self-description).
    pub fn code(&self) -> u8 {
        match self {
            Dtype::F32 => 0,
            Dtype::F64 => 1,
            Dtype::I64 => 2,
            Dtype::U64 => 3,
        }
    }

    pub fn from_code(c: u8) -> Option<Dtype> {
        match c {
            0 => Some(Dtype::F32),
            1 => Some(Dtype::F64),
            2 => Some(Dtype::I64),
            3 => Some(Dtype::U64),
            _ => None,
        }
    }

    /// Is STREAM meaningful for this dtype? The §III recurrence needs
    /// a real `q` with `2q + q² = 1`; integer dtypes are remap/storage
    /// dtypes only.
    pub fn is_float(&self) -> bool {
        matches!(self, Dtype::F32 | Dtype::F64)
    }
}

impl std::fmt::Display for Dtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Dtype-erased immutable view of a typed slice — the object-safe
/// currency of the pluggable execution backends ([`crate::backend`]).
/// A `&dyn Backend` method cannot be generic over [`Element`], so the
/// sealed dtype set is reified as one enum variant per dtype; a typed
/// call site erases with [`Element::erase`] and an implementation
/// recovers the concrete slice with [`Element::unerase`].
#[derive(Debug, Clone, Copy)]
pub enum ElemSlice<'a> {
    F32(&'a [f32]),
    F64(&'a [f64]),
    I64(&'a [i64]),
    U64(&'a [u64]),
}

impl<'a> ElemSlice<'a> {
    pub fn dtype(&self) -> Dtype {
        match self {
            ElemSlice::F32(_) => Dtype::F32,
            ElemSlice::F64(_) => Dtype::F64,
            ElemSlice::I64(_) => Dtype::I64,
            ElemSlice::U64(_) => Dtype::U64,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            ElemSlice::F32(s) => s.len(),
            ElemSlice::F64(s) => s.len(),
            ElemSlice::I64(s) => s.len(),
            ElemSlice::U64(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Dtype-erased mutable view of a typed slice (see [`ElemSlice`]).
#[derive(Debug)]
pub enum ElemSliceMut<'a> {
    F32(&'a mut [f32]),
    F64(&'a mut [f64]),
    I64(&'a mut [i64]),
    U64(&'a mut [u64]),
}

impl<'a> ElemSliceMut<'a> {
    pub fn dtype(&self) -> Dtype {
        match self {
            ElemSliceMut::F32(_) => Dtype::F32,
            ElemSliceMut::F64(_) => Dtype::F64,
            ElemSliceMut::I64(_) => Dtype::I64,
            ElemSliceMut::U64(_) => Dtype::U64,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            ElemSliceMut::F32(s) => s.len(),
            ElemSliceMut::F64(s) => s.len(),
            ElemSliceMut::I64(s) => s.len(),
            ElemSliceMut::U64(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A scalar that can live in a distributed array: fixed width,
/// little-endian wire encoding, and just enough algebra for the
/// owner-computes kernels. Sealed — see the module docs.
pub trait Element:
    sealed::Sealed + Copy + Default + PartialEq + Send + Sync + std::fmt::Debug + 'static
{
    /// Additive identity (STREAM `C0`).
    const ZERO: Self;
    /// Multiplicative identity.
    const ONE: Self;
    /// Bytes per element, in memory and on the wire.
    const WIDTH: usize;
    /// Runtime dtype token.
    const DTYPE: Dtype;
    /// Per-iteration closed-form validation tolerance (§III checks).
    /// Scaled by the iteration count; zero for exact (integer) types.
    const TOL_BASE: f64;

    /// Smallest representable value (`-∞` for floats) — the identity
    /// of a `max` reduction.
    const MIN_BOUND: Self;
    /// Largest representable value (`+∞` for floats) — the identity
    /// of a `min` reduction.
    const MAX_BOUND: Self;

    /// `a + b` (wrapping for integer types).
    fn add(a: Self, b: Self) -> Self;
    /// `a * b` (wrapping for integer types).
    fn mul(a: Self, b: Self) -> Self;
    /// The smaller of `a` and `b` (IEEE `min` for floats — matching
    /// the historical f64 reduction semantics).
    fn elem_min(a: Self, b: Self) -> Self;
    /// The larger of `a` and `b` (IEEE `max` for floats).
    fn elem_max(a: Self, b: Self) -> Self;

    /// Nearest representable value to `v` (used for constants like the
    /// STREAM `q` and for test data generation).
    fn from_f64(v: f64) -> Self;
    /// Widen to f64 (reductions, validation).
    fn to_f64(self) -> f64;

    /// Append this value's little-endian bytes to `buf`.
    fn write_le(self, buf: &mut Vec<u8>);
    /// Decode from exactly [`Element::WIDTH`] little-endian bytes.
    fn read_le(bytes: &[u8]) -> Self;

    /// Bulk encode: append the little-endian bytes of every element of
    /// `src` to `buf` — the codec behind `WireWriter::put_slice`.
    ///
    /// On little-endian targets the in-memory layout of a sealed
    /// element slice *is* its wire encoding, so this is a single
    /// byte-cast `extend_from_slice` (one memcpy, no per-element
    /// loop). Elsewhere it falls back to per-element
    /// [`Element::write_le`].
    fn copy_to_le(src: &[Self], buf: &mut Vec<u8>) {
        #[cfg(target_endian = "little")]
        {
            // SAFETY: the trait is sealed to f32/f64/i64/u64 — Copy
            // POD scalars of exactly WIDTH bytes with no padding and
            // no invalid bit patterns, so viewing the slice as raw
            // bytes is valid, and on a little-endian target those
            // bytes are exactly the LE wire encoding.
            let bytes = unsafe {
                std::slice::from_raw_parts(src.as_ptr().cast::<u8>(), std::mem::size_of_val(src))
            };
            buf.extend_from_slice(bytes);
        }
        #[cfg(not(target_endian = "little"))]
        for &x in src {
            x.write_le(buf);
        }
    }

    /// Zero-copy view of a slice as its little-endian wire bytes —
    /// `Some` on little-endian targets (where the in-memory layout
    /// *is* the encoding), `None` elsewhere (callers fall back to
    /// [`Element::copy_to_le`] staging). Lets bulk senders window a
    /// typed slice straight onto the wire with no staging buffer.
    fn as_le_bytes(src: &[Self]) -> Option<&[u8]> {
        if cfg!(target_endian = "little") {
            // SAFETY: the trait is sealed to f32/f64/i64/u64 — Copy
            // POD scalars of exactly WIDTH bytes with no padding and
            // no invalid bit patterns, so viewing the slice as raw
            // bytes is valid; on a little-endian target those bytes
            // are exactly the LE wire encoding (checked above).
            Some(unsafe {
                std::slice::from_raw_parts(src.as_ptr().cast::<u8>(), std::mem::size_of_val(src))
            })
        } else {
            None
        }
    }

    /// Bulk decode: fill `dst` from exactly `dst.len() × WIDTH`
    /// little-endian bytes — the codec behind
    /// `WireReader::get_slice_into`. Single memcpy on little-endian
    /// targets (see [`Element::copy_to_le`]); per-element elsewhere.
    ///
    /// Panics if `bytes.len() != dst.len() * WIDTH`; callers (the wire
    /// reader) validate lengths against the payload header first.
    fn copy_from_le(bytes: &[u8], dst: &mut [Self]) {
        assert_eq!(
            bytes.len(),
            std::mem::size_of_val(dst),
            "bulk decode length mismatch"
        );
        #[cfg(target_endian = "little")]
        // SAFETY: as in `copy_to_le` — sealed POD scalars whose LE
        // byte image is their in-memory representation; lengths match
        // per the assert above.
        unsafe {
            std::ptr::copy_nonoverlapping(
                bytes.as_ptr(),
                dst.as_mut_ptr().cast::<u8>(),
                bytes.len(),
            );
        }
        #[cfg(not(target_endian = "little"))]
        for (d, c) in dst.iter_mut().zip(bytes.chunks_exact(Self::WIDTH)) {
            *d = Self::read_le(c);
        }
    }

    /// STREAM Triad fused form `b + q·c` — one definition so every
    /// engine (serial, darray, threaded) computes identically.
    #[inline]
    fn triad(b: Self, q: Self, c: Self) -> Self {
        Self::add(b, Self::mul(q, c))
    }

    /// Erase the dtype of a slice into the backend currency.
    fn erase(s: &[Self]) -> ElemSlice<'_>;
    /// Erase the dtype of a mutable slice into the backend currency.
    fn erase_mut(s: &mut [Self]) -> ElemSliceMut<'_>;
    /// Recover the typed slice, `None` if the view holds another dtype.
    fn unerase(s: ElemSlice<'_>) -> Option<&[Self]>;
    /// Recover the typed mutable slice, `None` on a dtype mismatch.
    fn unerase_mut(s: ElemSliceMut<'_>) -> Option<&mut [Self]>;
}

/// The erased-view vocabulary every sealed dtype implements the same
/// way, differing only in the [`ElemSlice`] variant.
macro_rules! element_erased_views {
    ($var:ident) => {
        #[inline]
        fn erase(s: &[Self]) -> ElemSlice<'_> {
            ElemSlice::$var(s)
        }

        #[inline]
        fn erase_mut(s: &mut [Self]) -> ElemSliceMut<'_> {
            ElemSliceMut::$var(s)
        }

        #[inline]
        fn unerase(s: ElemSlice<'_>) -> Option<&[Self]> {
            match s {
                ElemSlice::$var(x) => Some(x),
                _ => None,
            }
        }

        #[inline]
        fn unerase_mut(s: ElemSliceMut<'_>) -> Option<&mut [Self]> {
            match s {
                ElemSliceMut::$var(x) => Some(x),
                _ => None,
            }
        }
    };
}

macro_rules! element_float {
    ($t:ty, $var:ident, $dtype:expr, $width:expr, $tol:expr) => {
        impl Element for $t {
            const ZERO: Self = 0.0;
            const ONE: Self = 1.0;
            const WIDTH: usize = $width;
            const DTYPE: Dtype = $dtype;
            const TOL_BASE: f64 = $tol;
            const MIN_BOUND: Self = <$t>::NEG_INFINITY;
            const MAX_BOUND: Self = <$t>::INFINITY;

            element_erased_views!($var);

            #[inline]
            fn add(a: Self, b: Self) -> Self {
                a + b
            }

            #[inline]
            fn mul(a: Self, b: Self) -> Self {
                a * b
            }

            #[inline]
            fn elem_min(a: Self, b: Self) -> Self {
                a.min(b)
            }

            #[inline]
            fn elem_max(a: Self, b: Self) -> Self {
                a.max(b)
            }

            #[inline]
            fn from_f64(v: f64) -> Self {
                v as $t
            }

            #[inline]
            fn to_f64(self) -> f64 {
                self as f64
            }

            #[inline]
            fn write_le(self, buf: &mut Vec<u8>) {
                buf.extend_from_slice(&self.to_le_bytes());
            }

            #[inline]
            fn read_le(bytes: &[u8]) -> Self {
                <$t>::from_le_bytes(bytes.try_into().expect("exactly WIDTH bytes"))
            }
        }
    };
}

macro_rules! element_int {
    ($t:ty, $var:ident, $dtype:expr) => {
        impl Element for $t {
            const ZERO: Self = 0;
            const ONE: Self = 1;
            const WIDTH: usize = 8;
            const DTYPE: Dtype = $dtype;
            const TOL_BASE: f64 = 0.0; // integer arithmetic is exact
            const MIN_BOUND: Self = <$t>::MIN;
            const MAX_BOUND: Self = <$t>::MAX;

            element_erased_views!($var);

            #[inline]
            fn add(a: Self, b: Self) -> Self {
                a.wrapping_add(b)
            }

            #[inline]
            fn mul(a: Self, b: Self) -> Self {
                a.wrapping_mul(b)
            }

            #[inline]
            fn elem_min(a: Self, b: Self) -> Self {
                Ord::min(a, b)
            }

            #[inline]
            fn elem_max(a: Self, b: Self) -> Self {
                Ord::max(a, b)
            }

            #[inline]
            fn from_f64(v: f64) -> Self {
                v as $t
            }

            #[inline]
            fn to_f64(self) -> f64 {
                self as f64
            }

            #[inline]
            fn write_le(self, buf: &mut Vec<u8>) {
                buf.extend_from_slice(&self.to_le_bytes());
            }

            #[inline]
            fn read_le(bytes: &[u8]) -> Self {
                <$t>::from_le_bytes(bytes.try_into().expect("exactly WIDTH bytes"))
            }
        }
    };
}

// f64: the classic STREAM dtype; 1e-13/iter matches the historical
// tolerance of the §III checks. f32: ~eps·ulp-growth per iteration,
// 1e-5/iter gives ample slack while still catching real corruption
// (a single flipped mantissa bit at magnitude 1 is ~1e-7 · 2^k).
element_float!(f64, F64, Dtype::F64, 8, 1e-13);
element_float!(f32, F32, Dtype::F32, 4, 1e-5);
element_int!(i64, I64, Dtype::I64);
element_int!(u64, U64, Dtype::U64);

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<T: Element>(vals: &[T]) {
        let mut buf = Vec::new();
        for &v in vals {
            v.write_le(&mut buf);
        }
        assert_eq!(buf.len(), vals.len() * T::WIDTH);
        for (i, &v) in vals.iter().enumerate() {
            let got = T::read_le(&buf[i * T::WIDTH..(i + 1) * T::WIDTH]);
            assert_eq!(got, v);
        }
    }

    #[test]
    fn wire_roundtrips_all_dtypes() {
        roundtrip(&[0.0f64, -1.5, std::f64::consts::PI, f64::MAX]);
        roundtrip(&[0.0f32, -1.5, std::f32::consts::E, f32::MIN_POSITIVE]);
        roundtrip(&[0i64, -42, i64::MAX, i64::MIN]);
        roundtrip(&[0u64, 42, u64::MAX]);
    }

    #[test]
    fn widths_match_dtype() {
        assert_eq!(<f32 as Element>::WIDTH, Dtype::F32.width());
        assert_eq!(<f64 as Element>::WIDTH, Dtype::F64.width());
        assert_eq!(<i64 as Element>::WIDTH, Dtype::I64.width());
        assert_eq!(<u64 as Element>::WIDTH, Dtype::U64.width());
    }

    #[test]
    fn dtype_parse_name_code_roundtrip() {
        for d in [Dtype::F32, Dtype::F64, Dtype::I64, Dtype::U64] {
            assert_eq!(Dtype::parse(d.name()), Some(d));
            assert_eq!(Dtype::from_code(d.code()), Some(d));
        }
        assert_eq!(Dtype::parse("f16"), None);
        assert_eq!(Dtype::from_code(9), None);
    }

    #[test]
    fn integer_ops_wrap_instead_of_panicking() {
        assert_eq!(i64::add(i64::MAX, 1), i64::MIN);
        assert_eq!(u64::mul(u64::MAX, 2), u64::MAX - 1);
    }

    #[test]
    fn triad_matches_definition() {
        let q = 0.5f64;
        assert_eq!(f64::triad(2.0, q, 4.0), 4.0);
        assert_eq!(i64::triad(2, 3, 4), 14);
    }

    #[test]
    fn float_dtypes_only_for_stream() {
        assert!(Dtype::F32.is_float() && Dtype::F64.is_float());
        assert!(!Dtype::I64.is_float() && !Dtype::U64.is_float());
    }

    /// The bulk codec must agree byte-for-byte with the per-element
    /// encoder for every sealed dtype.
    fn bulk_matches_per_element<T: Element>(vals: &[T]) {
        let mut per_elem = Vec::new();
        for &v in vals {
            v.write_le(&mut per_elem);
        }
        let mut bulk = Vec::new();
        T::copy_to_le(vals, &mut bulk);
        assert_eq!(bulk, per_elem);
        let mut back = vec![T::ZERO; vals.len()];
        T::copy_from_le(&bulk, &mut back);
        assert_eq!(back, vals);
    }

    #[test]
    fn bulk_codec_matches_per_element_all_dtypes() {
        bulk_matches_per_element(&[0.0f64, -1.5, std::f64::consts::PI, f64::MAX, f64::MIN]);
        bulk_matches_per_element(&[0.0f32, -1.5, std::f32::consts::E, f32::MIN_POSITIVE]);
        bulk_matches_per_element(&[0i64, -42, i64::MAX, i64::MIN]);
        bulk_matches_per_element(&[0u64, 42, u64::MAX]);
        bulk_matches_per_element::<f64>(&[]);
    }

    /// Acceptance criterion: a 1M-element f64 slice goes through the
    /// bulk path (one byte-cast memcpy on LE targets) and round-trips
    /// exactly.
    #[test]
    fn bulk_codec_one_million_f64() {
        let n = 1 << 20;
        let vals: Vec<f64> = (0..n).map(|i| i as f64 * 0.5 - 1000.0).collect();
        let mut buf = Vec::new();
        f64::copy_to_le(&vals, &mut buf);
        assert_eq!(buf.len(), n * 8);
        // Spot-check the encoding really is LE per element.
        assert_eq!(&buf[..8], &vals[0].to_le_bytes());
        assert_eq!(&buf[8 * (n - 1)..], &vals[n - 1].to_le_bytes());
        let mut back = vec![0.0f64; n];
        f64::copy_from_le(&buf, &mut back);
        assert_eq!(back, vals);
    }

    #[test]
    #[should_panic(expected = "bulk decode length mismatch")]
    fn bulk_decode_checks_length() {
        let mut dst = [0.0f64; 2];
        f64::copy_from_le(&[0u8; 8], &mut dst);
    }

    #[test]
    fn erase_unerase_roundtrips() {
        let v = [1.5f32, -2.0, 3.25];
        let e = f32::erase(&v);
        assert_eq!(e.dtype(), Dtype::F32);
        assert_eq!(e.len(), 3);
        assert!(!e.is_empty());
        assert_eq!(f32::unerase(e), Some(&v[..]));
        // Cross-dtype recovery refuses.
        assert_eq!(f64::unerase(e), None);
        assert_eq!(i64::unerase(e), None);

        let mut m = [7i64, 8];
        let em = i64::erase_mut(&mut m);
        assert_eq!(em.dtype(), Dtype::I64);
        assert_eq!(em.len(), 2);
        let back = i64::unerase_mut(em).unwrap();
        back[0] = 9;
        assert_eq!(m, [9, 8]);
        assert!(u64::unerase_mut(u64::erase_mut(&mut [1u64])).is_some());
    }
}
