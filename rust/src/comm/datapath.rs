//! The shared bulk-transfer datapath: one chunked, pooled streaming
//! layer beneath every mover in the codebase.
//!
//! Before this module existed the chunking + pooling + coalescing
//! machinery lived in three places — the remap engine's pooled
//! per-peer sends, the ring broadcast's ad-hoc chunk pipeline, and the
//! threaded backend's pack/unpack loops — each with its own framing
//! and its own idea of how many chunks fit a tag. [`ChunkStream`] is
//! the single implementation all of them now ride:
//!
//! * **Framing** — a stream frames `[total][n_chunks]` exactly once,
//!   at the head of chunk 0; every later chunk is raw bytes. A
//!   receiver can size its reassembly buffer from the first message
//!   without a separate round.
//! * **Chunking** — the 16-bit tag-round cap ([`MAX_CHUNKS`]) is
//!   enforced here, once, by [`plan_chunks`]: the chunk size is raised
//!   when a payload would otherwise need more than `2^16` chunks, so
//!   no algorithm has to carry its own copy of that rule.
//! * **Pooling** — stream headers (and any caller-built message body)
//!   come out of the global [`BufferPool`] via [`checkout`]; senders
//!   never copy payload bytes into a staging buffer — each chunk is a
//!   window over the caller's `parts`, handed to
//!   [`Transport::send_parts`] as slices.
//! * **Tags** — a [`ChunkTag`] packs `(namespace, epoch, lane)` and
//!   reserves the low 16 bits of the step field for the chunk index,
//!   so every namespace (`NS_REMAP`, `NS_COLL`, `NS_STAGE`) rides the
//!   same layer without aliasing.
//! * **Draining** — [`ChunkStream::drain`] completes streams from many
//!   peers in **arrival order** (non-blocking [`Transport::try_recv`]
//!   sweeps, spin-then-backoff), so one slow peer never serializes the
//!   rest — the receive loop previously private to the remap engine.
//!
//! The process default chunk size is [`DEFAULT_CHUNK_BYTES`],
//! overridable per run with `--chunk-bytes` (installed here via
//! [`set_ambient_chunk_bytes`] and inherited by worker processes
//! through the environment, like `--coll`).

use super::pool::{BufferPool, PooledBuf};
use super::{tags, CommError, Result, Tag, Transport, WireReader, WireWriter};
use crate::dmap::Pid;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

/// Hard cap on stream chunks: the chunk index lives in the low 16
/// bits of the packed tag step field.
pub const MAX_CHUNKS: usize = 1 << 16;

/// Default stream chunk: 64 KiB — large enough that framing overhead
/// vanishes, small enough that a multi-hop pipeline fills quickly.
pub const DEFAULT_CHUNK_BYTES: usize = 64 << 10;

/// Process-wide chunk-size override (0 = unset, use the default).
static AMBIENT_CHUNK_BYTES: AtomicUsize = AtomicUsize::new(0);

/// Install the process-default stream chunk size (the `--chunk-bytes`
/// axis; `repro run` sets it from the CLI and worker processes inherit
/// it through `DISTARRAY_CHUNK_BYTES`). Values are floored to 1 byte.
pub fn set_ambient_chunk_bytes(bytes: usize) {
    AMBIENT_CHUNK_BYTES.store(bytes, Ordering::Relaxed);
}

/// The current process-default stream chunk size.
pub fn ambient_chunk_bytes() -> usize {
    match AMBIENT_CHUNK_BYTES.load(Ordering::Relaxed) {
        0 => DEFAULT_CHUNK_BYTES,
        b => b.max(1),
    }
}

/// The chunk size actually used for a `total`-byte stream: the
/// requested size, raised if needed so the chunk count fits the
/// 16-bit tag field. Returns `(chunk_bytes, n_chunks)`; empty streams
/// are one (header-only) chunk.
pub fn plan_chunks(total: usize, chunk_bytes: usize) -> (usize, usize) {
    let cb = chunk_bytes.max(1).max(total.div_ceil(MAX_CHUNKS));
    (cb, total.div_ceil(cb).max(1))
}

/// Check a cleared wire buffer with at least `cap` bytes out of the
/// process-global [`BufferPool`] — the only sanctioned way for a
/// mover to get a staging/header buffer (keeps every bulk path's
/// allocations observable through one instrument).
pub fn checkout(cap: usize) -> PooledBuf<'static> {
    BufferPool::global().checkout(cap)
}

/// `(checkouts, hits)` of the global pool — the steady-state
/// zero-allocation instrument surfaced in the bench documents.
pub fn pool_counters() -> (u64, u64) {
    let pool = BufferPool::global();
    (pool.checkouts(), pool.hits())
}

/// The tag coordinates of one chunk stream: `tag(chunk) =
/// pack(ns, epoch, lane | chunk)`. The lane is the caller's high step
/// bits (a collective's `level | phase`, zero for remap/stage
/// epochs); its low 16 bits must be clear — they carry the chunk
/// index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkTag {
    ns: u8,
    epoch: u64,
    lane: u64,
}

impl ChunkTag {
    /// A lane-0 stream tag — one stream per `(ns, epoch, peer pair)`,
    /// the remap/stage shape.
    pub fn new(ns: u8, epoch: u64) -> ChunkTag {
        ChunkTag { ns, epoch, lane: 0 }
    }

    /// A stream tag in an explicit lane (multiples of `2^16`; the
    /// collective subsystem packs `level | phase` here).
    pub fn with_lane(ns: u8, epoch: u64, lane: u64) -> ChunkTag {
        debug_assert!((lane & (MAX_CHUNKS as u64 - 1)) == 0, "lane overlaps the chunk field");
        debug_assert!(lane < 1 << 24, "lane exceeds the 24-bit step field");
        ChunkTag { ns, epoch, lane }
    }

    /// The wire tag of chunk `c`.
    #[inline]
    pub fn at(&self, chunk: u64) -> Tag {
        debug_assert!(chunk < MAX_CHUNKS as u64, "chunk index exceeds the 16-bit tag field");
        tags::pack(self.ns, self.epoch, self.lane | chunk)
    }
}

/// How long a drain waits in total before reporting a timeout
/// (matches [`Transport::recv`]'s default).
const RECV_WINDOW: Duration = Duration::from_secs(120);
/// Empty sweeps before the drain stops spinning (yield) and starts
/// sleeping.
const SPIN_SWEEPS: u32 = 64;
/// First sleep of the drain backoff.
const POLL_MIN: Duration = Duration::from_micros(20);
/// Backoff cap — bounds worst-case added latency per chunk.
const POLL_MAX: Duration = Duration::from_millis(1);

/// The chunked stream writer/reader — all methods are stateless
/// associated functions over a [`Transport`].
pub struct ChunkStream;

/// Reassembly state of one incoming stream.
struct Reassembly {
    peer: Pid,
    /// Caller-side index of this peer (stable across completions).
    idx: usize,
    next_chunk: usize,
    /// 0 until chunk 0's header has been parsed.
    n_chunks: usize,
    total: usize,
    buf: Vec<u8>,
}

impl Reassembly {
    /// Feed one received chunk; `Ok(true)` when the stream completed.
    fn feed(&mut self, chunk: Vec<u8>) -> Result<bool> {
        if self.next_chunk == 0 {
            let (total, n_chunks, buf) = parse_first(&chunk)?;
            self.total = total;
            self.n_chunks = n_chunks;
            self.buf = buf;
        } else {
            self.buf.extend_from_slice(&chunk);
        }
        self.next_chunk += 1;
        if self.next_chunk < self.n_chunks {
            return Ok(false);
        }
        check_total(self.buf.len(), self.total)?;
        Ok(true)
    }
}

/// Parse chunk 0: the `[total][n_chunks]` frame plus the first
/// payload bytes, returned in a buffer sized for the whole stream.
fn parse_first(first: &[u8]) -> Result<(usize, usize, Vec<u8>)> {
    let mut rd = WireReader::new(first);
    let total = rd.get_usize()?;
    let n_chunks = rd.get_usize()?;
    if n_chunks == 0 || n_chunks > MAX_CHUNKS {
        return Err(CommError::Malformed(format!(
            "chunk stream frames {n_chunks} chunks (valid: 1..={MAX_CHUNKS})"
        )));
    }
    let mut buf = Vec::with_capacity(total);
    buf.extend_from_slice(rd.take_raw(rd.remaining())?);
    Ok((total, n_chunks, buf))
}

fn check_total(got: usize, total: usize) -> Result<()> {
    if got != total {
        return Err(CommError::Malformed(format!(
            "chunk stream reassembled {got} of {total} bytes"
        )));
    }
    Ok(())
}

impl ChunkStream {
    /// Send the logical concatenation of `parts` to `to` as a chunked
    /// stream under `tag`. The `[total][n_chunks]` frame is written
    /// once into a pooled header buffer; every chunk is a window of
    /// slices over `parts` handed to [`Transport::send_parts`] — no
    /// payload byte is ever staged or copied by this layer. Returns
    /// the number of chunk messages sent.
    pub fn send(
        t: &dyn Transport,
        to: Pid,
        tag: ChunkTag,
        chunk_bytes: usize,
        parts: &[&[u8]],
    ) -> Result<usize> {
        let total: usize = parts.iter().map(|p| p.len()).sum();
        let (cb, n_chunks) = plan_chunks(total, chunk_bytes);
        let mut header = checkout(16);
        let mut w = WireWriter::from_vec(header.take());
        w.put_u64(total as u64);
        w.put_u64(n_chunks as u64);
        header.restore(w.finish());

        // Cursor over the logical byte space of `parts`; chunks are
        // consecutive, so it only ever advances.
        let mut pi = 0usize;
        let mut po = 0usize;
        let mut slices: Vec<&[u8]> = Vec::with_capacity(parts.len() + 1);
        for c in 0..n_chunks {
            let lo = c * cb;
            let hi = (lo + cb).min(total);
            slices.clear();
            if c == 0 {
                slices.push(header.as_slice());
            }
            let mut remaining = hi - lo;
            while remaining > 0 {
                let avail = parts[pi].len() - po;
                if avail == 0 {
                    pi += 1;
                    po = 0;
                    continue;
                }
                let take = avail.min(remaining);
                slices.push(&parts[pi][po..po + take]);
                po += take;
                remaining -= take;
            }
            t.send_parts(to, tag.at(c as u64), &slices)?;
        }
        Ok(n_chunks)
    }

    /// Blocking receive of one whole stream from `from`: reads the
    /// frame off chunk 0, then the remaining chunks in order.
    pub fn recv(t: &dyn Transport, from: Pid, tag: ChunkTag) -> Result<Vec<u8>> {
        Self::recv_forward(t, from, tag, None)
    }

    /// Blocking receive that forwards every chunk to `next` the
    /// moment it lands (before reassembly) — the ring-pipeline hop:
    /// all links stream concurrently once the pipe fills.
    pub fn recv_forward(
        t: &dyn Transport,
        from: Pid,
        tag: ChunkTag,
        next: Option<Pid>,
    ) -> Result<Vec<u8>> {
        let first = t.recv(from, tag.at(0))?;
        if let Some(nx) = next {
            t.send(nx, tag.at(0), &first)?;
        }
        let (total, n_chunks, mut out) = parse_first(&first)?;
        for c in 1..n_chunks {
            let chunk = t.recv(from, tag.at(c as u64))?;
            if let Some(nx) = next {
                t.send(nx, tag.at(c as u64), &chunk)?;
            }
            out.extend_from_slice(&chunk);
        }
        check_total(out.len(), total)?;
        Ok(out)
    }

    /// Receive one stream from **every** peer in `peers`, completing
    /// them in arrival order: sweep the pending streams with
    /// non-blocking receives, spinning briefly then backing off
    /// exponentially between empty sweeps. `on_payload(i, bytes)` is
    /// called once per peer with `i` indexing into `peers`.
    pub fn drain(
        t: &dyn Transport,
        peers: &[Pid],
        tag: ChunkTag,
        mut on_payload: impl FnMut(usize, Vec<u8>) -> Result<()>,
    ) -> Result<()> {
        match peers {
            [] => return Ok(()),
            // A single incoming stream has nothing to reorder —
            // block directly.
            &[only] => {
                let payload = Self::recv(t, only, tag)?;
                return on_payload(0, payload);
            }
            _ => {}
        }
        let mut pending: Vec<Reassembly> = peers
            .iter()
            .enumerate()
            .map(|(idx, &peer)| Reassembly {
                peer,
                idx,
                next_chunk: 0,
                n_chunks: 0,
                total: 0,
                buf: Vec::new(),
            })
            .collect();
        let deadline = Instant::now() + RECV_WINDOW;
        let mut delay = POLL_MIN;
        let mut empty_sweeps = 0u32;
        while !pending.is_empty() {
            let mut progressed = false;
            let mut i = 0;
            while i < pending.len() {
                // Drain whatever this peer has ready before moving on
                // (consecutive chunks of a hot stream complete back
                // to back).
                let mut done = false;
                while let Some(chunk) =
                    t.try_recv(pending[i].peer, tag.at(pending[i].next_chunk as u64))?
                {
                    progressed = true;
                    if pending[i].feed(chunk)? {
                        done = true;
                        break;
                    }
                }
                if done {
                    let r = pending.swap_remove(i);
                    on_payload(r.idx, r.buf)?;
                } else {
                    i += 1;
                }
            }
            if pending.is_empty() {
                break;
            }
            if progressed {
                delay = POLL_MIN;
                empty_sweeps = 0;
                continue;
            }
            if Instant::now() >= deadline {
                return Err(CommError::Timeout {
                    from: pending[0].peer,
                    tag: tag.at(pending[0].next_chunk as u64),
                });
            }
            if empty_sweeps < SPIN_SWEEPS {
                empty_sweeps += 1;
                std::thread::yield_now();
            } else {
                std::thread::sleep(delay);
                delay = (delay * 2).min(POLL_MAX);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ChannelHub;

    const NS: u8 = tags::NS_COLL;

    #[test]
    fn plan_chunks_enforces_the_tag_cap_once() {
        // Exactly 2^16 chunks fit (chunk indices 0..=65535).
        assert_eq!(plan_chunks(MAX_CHUNKS, 1), (1, MAX_CHUNKS));
        // One byte more: the chunk size is raised, never the count.
        assert_eq!(plan_chunks(MAX_CHUNKS + 1, 1), (2, MAX_CHUNKS / 2 + 1));
        // Requested sizes below the floor are raised too.
        let (cb, n) = plan_chunks(10 * MAX_CHUNKS, 4);
        assert_eq!(cb, 10);
        assert_eq!(n, MAX_CHUNKS);
        // Ordinary payloads honor the requested size.
        assert_eq!(plan_chunks(100, 16), (16, 7));
        assert_eq!(plan_chunks(0, 16), (16, 1), "empty streams are one header chunk");
        assert_eq!(plan_chunks(16, 16), (16, 1));
        assert_eq!(plan_chunks(17, 16), (16, 2));
    }

    #[test]
    fn chunk_tag_packs_lane_and_chunk_disjointly() {
        let a = ChunkTag::new(NS, 7);
        let b = ChunkTag::with_lane(NS, 7, 1 << 16);
        assert_eq!(a.at(0), tags::pack(NS, 7, 0));
        assert_eq!(a.at(5), tags::pack(NS, 7, 5));
        assert_eq!(b.at(5), tags::pack(NS, 7, (1 << 16) | 5));
        assert_ne!(a.at(5), b.at(5));
    }

    #[test]
    fn ambient_chunk_bytes_defaults_and_overrides() {
        // Process-global: keep this the only test that mutates it, and
        // use a large transient value so any concurrently constructed
        // context still sees single-chunk streams at test sizes.
        assert_eq!(ambient_chunk_bytes(), DEFAULT_CHUNK_BYTES);
        set_ambient_chunk_bytes(1 << 20);
        assert_eq!(ambient_chunk_bytes(), 1 << 20);
        set_ambient_chunk_bytes(0);
        assert_eq!(ambient_chunk_bytes(), DEFAULT_CHUNK_BYTES);
    }

    #[test]
    fn multipart_stream_roundtrips_and_counts_chunks() {
        let mut world = ChannelHub::world(2);
        let t1 = world.pop().unwrap();
        let t0 = world.pop().unwrap();
        let tag = ChunkTag::new(NS, 42);
        let a: Vec<u8> = (0..40).collect();
        let b: Vec<u8> = (100..140).collect();
        // 80 payload bytes at 16-byte chunks → 5 chunks.
        let sent = ChunkStream::send(&t0, 1, tag, 16, &[&a, &[], &b]).unwrap();
        assert_eq!(sent, 5);
        assert_eq!(t0.stats().msgs_sent(), 5);
        let got = ChunkStream::recv(&t1, 0, tag).unwrap();
        let want: Vec<u8> = a.iter().chain(b.iter()).copied().collect();
        assert_eq!(got, want);
    }

    #[test]
    fn empty_stream_is_one_message() {
        let mut world = ChannelHub::world(2);
        let t1 = world.pop().unwrap();
        let t0 = world.pop().unwrap();
        let tag = ChunkTag::new(NS, 43);
        assert_eq!(ChunkStream::send(&t0, 1, tag, 64, &[]).unwrap(), 1);
        assert_eq!(ChunkStream::recv(&t1, 0, tag).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn forwarding_relays_every_chunk_down_a_chain() {
        let world = ChannelHub::world(3);
        let payload: Vec<u8> = (0..100u8).collect();
        let tag = ChunkTag::new(NS, 44);
        let hs: Vec<_> = world
            .into_iter()
            .map(|t| {
                let payload = payload.clone();
                std::thread::spawn(move || match t.pid() {
                    0 => {
                        ChunkStream::send(&t, 1, tag, 16, &[&payload]).unwrap();
                        payload
                    }
                    1 => ChunkStream::recv_forward(&t, 0, tag, Some(2)).unwrap(),
                    _ => ChunkStream::recv(&t, 1, tag).unwrap(),
                })
            })
            .collect();
        for h in hs {
            assert_eq!(h.join().unwrap(), payload);
        }
    }

    #[test]
    fn drain_completes_multi_chunk_streams_from_many_peers() {
        let np = 4;
        let world = ChannelHub::world(np);
        let tag = ChunkTag::new(NS, 45);
        let hs: Vec<_> = world
            .into_iter()
            .map(|t| {
                std::thread::spawn(move || {
                    if t.pid() == 0 {
                        let peers: Vec<Pid> = (1..t.np()).collect();
                        let mut got: Vec<Option<Vec<u8>>> = vec![None; peers.len()];
                        ChunkStream::drain(&t, &peers, tag, |i, payload| {
                            got[i] = Some(payload);
                            Ok(())
                        })
                        .unwrap();
                        for (i, g) in got.iter().enumerate() {
                            let want = vec![(i + 1) as u8; 50 + (i + 1)];
                            assert_eq!(g.as_deref(), Some(&want[..]));
                        }
                    } else {
                        let part = vec![t.pid() as u8; 50 + t.pid()];
                        ChunkStream::send(&t, 0, tag, 16, &[&part]).unwrap();
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
    }

    #[test]
    fn malformed_chunk_count_is_loud() {
        let mut world = ChannelHub::world(2);
        let t1 = world.pop().unwrap();
        let t0 = world.pop().unwrap();
        let tag = ChunkTag::new(NS, 46);
        let mut w = WireWriter::new();
        w.put_u64(4);
        w.put_u64(0); // zero chunks: invalid
        t0.send(1, tag.at(0), &w.finish()).unwrap();
        assert!(matches!(
            ChunkStream::recv(&t1, 0, tag),
            Err(CommError::Malformed(_))
        ));
    }
}
