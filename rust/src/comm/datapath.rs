//! The shared bulk-transfer datapath: one chunked, pooled streaming
//! layer beneath every mover in the codebase.
//!
//! Before this module existed the chunking + pooling + coalescing
//! machinery lived in three places — the remap engine's pooled
//! per-peer sends, the ring broadcast's ad-hoc chunk pipeline, and the
//! threaded backend's pack/unpack loops — each with its own framing
//! and its own idea of how many chunks fit a tag. [`ChunkStream`] is
//! the single implementation all of them now ride:
//!
//! * **Framing** — a stream frames `[total][n_chunks]` exactly once,
//!   at the head of chunk 0; every later chunk is raw bytes. A
//!   receiver can size its reassembly buffer from the first message
//!   without a separate round.
//! * **Chunking** — the 16-bit tag-round cap ([`MAX_CHUNKS`]) is
//!   enforced here, once, by [`plan_chunks`]: the chunk size is raised
//!   when a payload would otherwise need more than `2^16` chunks, so
//!   no algorithm has to carry its own copy of that rule.
//! * **Pooling** — stream headers (and any caller-built message body)
//!   come out of the global [`BufferPool`] via [`checkout`]; senders
//!   never copy payload bytes into a staging buffer — each chunk is a
//!   window over the caller's `parts`, handed to
//!   [`Transport::send_parts`] as slices.
//! * **Tags** — a [`ChunkTag`] packs `(namespace, epoch, lane)` and
//!   reserves the low 16 bits of the step field for the chunk index,
//!   so every namespace (`NS_REMAP`, `NS_COLL`, `NS_STAGE`) rides the
//!   same layer without aliasing.
//! * **Draining** — [`ChunkStream::drain`] completes streams from many
//!   peers in **arrival order** (non-blocking [`Transport::try_recv`]
//!   sweeps, spin-then-backoff), so one slow peer never serializes the
//!   rest — the receive loop previously private to the remap engine.
//!
//! The process default chunk size is [`DEFAULT_CHUNK_BYTES`],
//! overridable per run with `--chunk-bytes` (installed here via
//! [`set_ambient_chunk_bytes`] and inherited by worker processes
//! through the environment, like `--coll`).

use super::pool::{BufferPool, PooledBuf};
use super::{tags, CommError, CommStats, Result, Tag, Transport, WireReader, WireWriter};
use crate::dmap::Pid;
use crate::obs::hist::{record_since, HistKind};
use crate::obs::{span_begin, EventKind};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

/// Hard cap on stream chunks: the chunk index lives in the low 16
/// bits of the packed tag step field.
pub const MAX_CHUNKS: usize = 1 << 16;

/// Default stream chunk: 64 KiB — large enough that framing overhead
/// vanishes, small enough that a multi-hop pipeline fills quickly.
pub const DEFAULT_CHUNK_BYTES: usize = 64 << 10;

/// Process-wide chunk-size override (0 = unset, use the default).
static AMBIENT_CHUNK_BYTES: AtomicUsize = AtomicUsize::new(0);

/// Install the process-default stream chunk size (the `--chunk-bytes`
/// axis; `repro run` sets it from the CLI and worker processes inherit
/// it through `DISTARRAY_CHUNK_BYTES`). Values are floored to 1 byte.
pub fn set_ambient_chunk_bytes(bytes: usize) {
    AMBIENT_CHUNK_BYTES.store(bytes, Ordering::Relaxed);
}

/// The current process-default stream chunk size.
pub fn ambient_chunk_bytes() -> usize {
    match AMBIENT_CHUNK_BYTES.load(Ordering::Relaxed) {
        0 => DEFAULT_CHUNK_BYTES,
        b => b.max(1),
    }
}

/// The chunk size actually used for a `total`-byte stream: the
/// requested size, raised if needed so the chunk count fits the
/// 16-bit tag field. Returns `(chunk_bytes, n_chunks)`; empty streams
/// are one (header-only) chunk.
pub fn plan_chunks(total: usize, chunk_bytes: usize) -> (usize, usize) {
    let cb = chunk_bytes.max(1).max(total.div_ceil(MAX_CHUNKS));
    (cb, total.div_ceil(cb).max(1))
}

/// Check a cleared wire buffer with at least `cap` bytes out of the
/// process-global [`BufferPool`] — the only sanctioned way for a
/// mover to get a staging/header buffer (keeps every bulk path's
/// allocations observable through one instrument).
pub fn checkout(cap: usize) -> PooledBuf<'static> {
    BufferPool::global().checkout(cap)
}

/// `(checkouts, hits)` of the global pool — the steady-state
/// zero-allocation instrument surfaced in the bench documents.
pub fn pool_counters() -> (u64, u64) {
    let pool = BufferPool::global();
    (pool.checkouts(), pool.hits())
}

/// Process-cumulative wire totals of every [`ChunkStream`] chunk sent
/// or received (frame bytes included). Like the pool counters this is
/// a process-wide monotone instrument: bench documents surface deltas
/// around their timed region; per-endpoint assertions (the "bounded
/// communication" zero-message property) stay on
/// [`Transport::stats`].
static STREAM_STATS: CommStats = CommStats::new();

/// The datapath's process-wide stream counters.
pub fn comm_stats() -> &'static CommStats {
    &STREAM_STATS
}

/// Snapshot of [`comm_stats`]: `(msgs_sent, bytes_sent, msgs_recv,
/// bytes_recv)`.
pub fn comm_snapshot() -> (u64, u64, u64, u64) {
    STREAM_STATS.snapshot()
}

/// The transport-kind stamp for a message exchanged with `peer`,
/// pre-shifted into the top byte of a chunk event's `b` field. Chunk
/// indices occupy at most 16 bits ([`MAX_CHUNKS`]), so the top byte is
/// free; 0 means "unknown transport" and the emitter omits the field.
#[inline]
fn transport_stamp(t: &dyn Transport, peer: Pid) -> u64 {
    (t.kind_to(peer).map(|k| k.code()).unwrap_or(0) as u64) << 56
}

/// Count one landed chunk and record its arrival as a **span** whose
/// duration is the receiver-side wait: `wait_start` is the
/// [`span_begin`] stamp taken when the receiver began waiting for
/// this chunk (0 when recording was off — the event degrades to an
/// instant). The wait also feeds the chunk-wait histogram, which
/// survives ring wrap. `stamp` is the [`transport_stamp`] of the
/// sending peer, carried in `b`'s top byte.
#[inline]
fn note_arrival(tag: &ChunkTag, chunk: &ArrivedChunk, wait_start: u64, stamp: u64) {
    let wire = chunk.payload().len() + if chunk.chunk_idx == 0 { FRAME_BYTES } else { 0 };
    STREAM_STATS.record_recv(wire);
    record_since(HistKind::ChunkWait, wait_start);
    crate::obs_span!(
        EventKind::ChunkArrive,
        wait_start,
        tag: tag.at(chunk.chunk_idx as u64),
        peer: chunk.peer as u32,
        a: wire as u64,
        b: chunk.chunk_idx as u64 | stamp
    );
}

/// Count one received wire message on the blocking path (where no
/// [`ArrivedChunk`] is built). Same wait-span semantics as
/// [`note_arrival`].
#[inline]
fn note_recv_wire(
    tag: &ChunkTag,
    from: Pid,
    chunk_idx: u64,
    wire: usize,
    wait_start: u64,
    stamp: u64,
) {
    STREAM_STATS.record_recv(wire);
    record_since(HistKind::ChunkWait, wait_start);
    crate::obs_span!(
        EventKind::ChunkArrive,
        wait_start,
        tag: tag.at(chunk_idx),
        peer: from as u32,
        a: wire as u64,
        b: chunk_idx | stamp
    );
}

/// Count one sent chunk and record its event.
#[inline]
fn note_send(tag: &ChunkTag, to: Pid, chunk_idx: u64, wire: usize, stamp: u64) {
    STREAM_STATS.record_send(wire);
    crate::obs_event!(
        EventKind::ChunkSend,
        tag: tag.at(chunk_idx),
        peer: to as u32,
        a: wire as u64,
        b: chunk_idx | stamp
    );
}

/// The tag coordinates of one chunk stream: `tag(chunk) =
/// pack(ns, epoch, lane | chunk)`. The lane is the caller's high step
/// bits (a collective's `level | phase`, zero for remap/stage
/// epochs); its low 16 bits must be clear — they carry the chunk
/// index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkTag {
    ns: u8,
    epoch: u64,
    lane: u64,
}

impl ChunkTag {
    /// A lane-0 stream tag — one stream per `(ns, epoch, peer pair)`,
    /// the remap/stage shape.
    pub fn new(ns: u8, epoch: u64) -> ChunkTag {
        ChunkTag { ns, epoch, lane: 0 }
    }

    /// A stream tag in an explicit lane (multiples of `2^16`; the
    /// collective subsystem packs `level | phase` here).
    pub fn with_lane(ns: u8, epoch: u64, lane: u64) -> ChunkTag {
        debug_assert!((lane & (MAX_CHUNKS as u64 - 1)) == 0, "lane overlaps the chunk field");
        debug_assert!(lane < 1 << 24, "lane exceeds the 24-bit step field");
        ChunkTag { ns, epoch, lane }
    }

    /// The wire tag of chunk `c`.
    #[inline]
    pub fn at(&self, chunk: u64) -> Tag {
        debug_assert!(chunk < MAX_CHUNKS as u64, "chunk index exceeds the 16-bit tag field");
        tags::pack(self.ns, self.epoch, self.lane | chunk)
    }
}

/// How long a drain waits in total before reporting a timeout
/// (matches [`Transport::recv`]'s default — the configurable
/// [`super::default_recv_timeout`]).
fn recv_window() -> Duration {
    super::default_recv_timeout()
}
/// Empty sweeps before the drain stops spinning (yield) and starts
/// sleeping.
const SPIN_SWEEPS: u32 = 64;
/// First sleep of the drain backoff.
const POLL_MIN: Duration = Duration::from_micros(20);
/// Backoff cap — bounds worst-case added latency per chunk.
const POLL_MAX: Duration = Duration::from_millis(1);

/// Wire size of the stream frame at the head of chunk 0:
/// `[total: u64][n_chunks: u64]`.
const FRAME_BYTES: usize = 16;

/// The chunked stream writer/reader — all methods are stateless
/// associated functions over a [`Transport`].
pub struct ChunkStream;

/// One landed chunk of an incoming stream, delivered by
/// [`ChunkStream::drain_chunks`] the moment it arrives. Owns its wire
/// message, so a consumer can hand the whole value to another thread
/// (a ready-queue) without copying a byte.
#[derive(Debug)]
pub struct ArrivedChunk {
    /// The sending peer.
    pub peer: Pid,
    /// Caller-side index of the peer in the `peers` slice.
    pub peer_idx: usize,
    /// This chunk's index within its stream.
    pub chunk_idx: usize,
    /// Chunks in the whole stream (parsed off chunk 0's frame).
    pub n_chunks: usize,
    /// Total payload bytes of the whole stream.
    pub total: usize,
    /// Byte offset of this chunk's first payload byte in the stream.
    pub offset: usize,
    /// Final chunk of its stream?
    pub is_last: bool,
    data: Vec<u8>,
    /// Payload start within `data` ([`FRAME_BYTES`] on chunk 0).
    start: usize,
}

impl ArrivedChunk {
    /// This chunk's payload bytes (the frame already stripped).
    pub fn payload(&self) -> &[u8] {
        &self.data[self.start..]
    }
}

/// Progress state of one incoming stream under a chunk-granular
/// drain: frame fields plus the byte cursor — no reassembly buffer.
struct Incoming {
    peer: Pid,
    /// Caller-side index of this peer (stable across completions).
    idx: usize,
    next_chunk: usize,
    /// 0 until chunk 0's frame has been parsed.
    n_chunks: usize,
    total: usize,
    offset: usize,
}

impl Incoming {
    fn new(peer: Pid, idx: usize) -> Incoming {
        Incoming { peer, idx, next_chunk: 0, n_chunks: 0, total: 0, offset: 0 }
    }

    /// Feed one received wire message; returns the landed chunk and
    /// whether its stream is now complete.
    fn feed(&mut self, data: Vec<u8>) -> Result<(ArrivedChunk, bool)> {
        let start = if self.next_chunk == 0 {
            let (total, n_chunks) = parse_frame(&data)?;
            self.total = total;
            self.n_chunks = n_chunks;
            FRAME_BYTES
        } else {
            0
        };
        let offset = self.offset;
        let len = data.len() - start;
        if offset + len > self.total {
            return Err(CommError::Malformed(format!(
                "chunk stream overflows: {} of {} framed bytes",
                offset + len,
                self.total
            )));
        }
        self.offset = offset + len;
        let chunk_idx = self.next_chunk;
        self.next_chunk += 1;
        let is_last = self.next_chunk == self.n_chunks;
        if is_last {
            check_total(self.offset, self.total)?;
        }
        let chunk = ArrivedChunk {
            peer: self.peer,
            peer_idx: self.idx,
            chunk_idx,
            n_chunks: self.n_chunks,
            total: self.total,
            offset,
            is_last,
            data,
            start,
        };
        Ok((chunk, is_last))
    }
}

/// Parse and validate chunk 0's `[total][n_chunks]` frame.
fn parse_frame(first: &[u8]) -> Result<(usize, usize)> {
    let mut rd = WireReader::new(first);
    let total = rd.get_usize()?;
    let n_chunks = rd.get_usize()?;
    if n_chunks == 0 || n_chunks > MAX_CHUNKS {
        return Err(CommError::Malformed(format!(
            "chunk stream frames {n_chunks} chunks (valid: 1..={MAX_CHUNKS})"
        )));
    }
    Ok((total, n_chunks))
}

fn check_total(got: usize, total: usize) -> Result<()> {
    if got != total {
        return Err(CommError::Malformed(format!(
            "chunk stream reassembled {got} of {total} bytes"
        )));
    }
    Ok(())
}

/// The drain's spin-then-sleep backoff: yield for the first
/// [`SPIN_SWEEPS`] empty sweeps, then sleep with exponential growth
/// capped at [`POLL_MAX`]. Any progress resets it to spinning from
/// [`POLL_MIN`], so a stream that keeps advancing is polled hot.
pub(crate) struct Backoff {
    delay: Duration,
    empty_sweeps: u32,
}

impl Backoff {
    pub(crate) fn new() -> Backoff {
        Backoff { delay: POLL_MIN, empty_sweeps: 0 }
    }

    /// Record progress: the next empty sweep spins again and the first
    /// sleep after that restarts at [`POLL_MIN`].
    pub(crate) fn progress(&mut self) {
        self.delay = POLL_MIN;
        self.empty_sweeps = 0;
    }

    /// One empty sweep: yield while still spinning, otherwise sleep
    /// and double the next delay (capped).
    pub(crate) fn wait(&mut self) {
        if self.empty_sweeps < SPIN_SWEEPS {
            self.empty_sweeps += 1;
            std::thread::yield_now();
        } else {
            std::thread::sleep(self.delay);
            self.delay = (self.delay * 2).min(POLL_MAX);
        }
    }

    /// The next sleep this backoff would take (the reset instrument).
    #[cfg(test)]
    pub(crate) fn delay(&self) -> Duration {
        self.delay
    }
}

impl ChunkStream {
    /// Send the logical concatenation of `parts` to `to` as a chunked
    /// stream under `tag`. The `[total][n_chunks]` frame is written
    /// once into a pooled header buffer; every chunk is a window of
    /// slices over `parts` handed to [`Transport::send_parts`] — no
    /// payload byte is ever staged or copied by this layer. Returns
    /// the number of chunk messages sent.
    pub fn send(
        t: &dyn Transport,
        to: Pid,
        tag: ChunkTag,
        chunk_bytes: usize,
        parts: &[&[u8]],
    ) -> Result<usize> {
        let total: usize = parts.iter().map(|p| p.len()).sum();
        let (cb, n_chunks) = plan_chunks(total, chunk_bytes);
        let mut header = checkout(16);
        let mut w = WireWriter::from_vec(header.take());
        w.put_u64(total as u64);
        w.put_u64(n_chunks as u64);
        header.restore(w.finish());

        let stamp = transport_stamp(t, to);
        // Cursor over the logical byte space of `parts`; chunks are
        // consecutive, so it only ever advances.
        let mut pi = 0usize;
        let mut po = 0usize;
        let mut slices: Vec<&[u8]> = Vec::with_capacity(parts.len() + 1);
        for c in 0..n_chunks {
            let lo = c * cb;
            let hi = (lo + cb).min(total);
            slices.clear();
            if c == 0 {
                slices.push(header.as_slice());
            }
            let mut remaining = hi - lo;
            while remaining > 0 {
                let avail = parts[pi].len() - po;
                if avail == 0 {
                    pi += 1;
                    po = 0;
                    continue;
                }
                let take = avail.min(remaining);
                slices.push(&parts[pi][po..po + take]);
                po += take;
                remaining -= take;
            }
            t.send_parts(to, tag.at(c as u64), &slices)?;
            let wire = (hi - lo) + if c == 0 { FRAME_BYTES } else { 0 };
            note_send(&tag, to, c as u64, wire, stamp);
        }
        Ok(n_chunks)
    }

    /// Blocking receive of one whole stream from `from`: reads the
    /// frame off chunk 0, then the remaining chunks in order.
    pub fn recv(t: &dyn Transport, from: Pid, tag: ChunkTag) -> Result<Vec<u8>> {
        Self::recv_forward(t, from, tag, None)
    }

    /// Blocking receive that forwards every chunk to `next` the
    /// moment it lands (before reassembly) — the ring-pipeline hop:
    /// all links stream concurrently once the pipe fills.
    pub fn recv_forward(
        t: &dyn Transport,
        from: Pid,
        tag: ChunkTag,
        next: Option<Pid>,
    ) -> Result<Vec<u8>> {
        let stamp = transport_stamp(t, from);
        let wait = span_begin();
        let first = t.recv(from, tag.at(0))?;
        note_recv_wire(&tag, from, 0, first.len(), wait, stamp);
        if let Some(nx) = next {
            t.send(nx, tag.at(0), &first)?;
            note_send(&tag, nx, 0, first.len(), transport_stamp(t, nx));
        }
        let (total, n_chunks) = parse_frame(&first)?;
        // Pre-reserve `total` off chunk 0's frame: a multi-chunk
        // receive allocates its output exactly once, never growing
        // through the doubling path mid-stream.
        let mut out = Vec::with_capacity(total);
        out.extend_from_slice(&first[FRAME_BYTES..]);
        for c in 1..n_chunks {
            let wait = span_begin();
            let chunk = t.recv(from, tag.at(c as u64))?;
            note_recv_wire(&tag, from, c as u64, chunk.len(), wait, stamp);
            if let Some(nx) = next {
                t.send(nx, tag.at(c as u64), &chunk)?;
                note_send(&tag, nx, c as u64, chunk.len(), transport_stamp(t, nx));
            }
            out.extend_from_slice(&chunk);
        }
        check_total(out.len(), total)?;
        Ok(out)
    }

    /// Receive one stream from **every** peer in `peers`, completing
    /// them in arrival order: sweep the pending streams with
    /// non-blocking receives, spinning briefly then backing off
    /// exponentially between empty sweeps. `on_payload(i, bytes)` is
    /// called once per peer with `i` indexing into `peers`.
    ///
    /// Built on [`ChunkStream::drain_chunks`]: the payload buffer is
    /// reserved once off the frame and filled as chunks land — kept
    /// for consumers that genuinely need the contiguous bytes;
    /// compute-on-arrival consumers should take `drain_chunks`
    /// directly and skip the reassembly copy entirely.
    pub fn drain(
        t: &dyn Transport,
        peers: &[Pid],
        tag: ChunkTag,
        mut on_payload: impl FnMut(usize, Vec<u8>) -> Result<()>,
    ) -> Result<()> {
        let mut bufs: Vec<Vec<u8>> = Vec::new();
        bufs.resize_with(peers.len(), Vec::new);
        Self::drain_chunks(t, peers, tag, |c| {
            let buf = &mut bufs[c.peer_idx];
            if c.chunk_idx == 0 {
                buf.reserve_exact(c.total);
            }
            buf.extend_from_slice(c.payload());
            if c.is_last {
                on_payload(c.peer_idx, std::mem::take(buf))
            } else {
                Ok(())
            }
        })
    }

    /// Chunk-granular drain: receive one stream from **every** peer in
    /// `peers`, firing `on_chunk` the moment each chunk lands — the
    /// compute-on-arrival primitive. Chunks of one stream arrive in
    /// order; streams from different peers interleave in arrival
    /// order (the same non-blocking sweep + spin-then-backoff loop as
    /// [`ChunkStream::drain`]). A single-peer drain blocks per chunk
    /// instead of sweeping, so the callback still overlaps the
    /// sender's next chunk.
    pub fn drain_chunks(
        t: &dyn Transport,
        peers: &[Pid],
        tag: ChunkTag,
        on_chunk: impl FnMut(ArrivedChunk) -> Result<()>,
    ) -> Result<()> {
        Self::drain_chunks_window(t, peers, tag, recv_window(), on_chunk)
    }

    /// [`ChunkStream::drain_chunks`] with an explicit stall window:
    /// the drain times out only after `window` elapses **without any
    /// progress** (every landed chunk resets the deadline, so a slow
    /// but advancing peer is never killed mid-stream). The timeout
    /// error names every stalled peer and its next-expected chunk.
    pub fn drain_chunks_window(
        t: &dyn Transport,
        peers: &[Pid],
        tag: ChunkTag,
        window: Duration,
        mut on_chunk: impl FnMut(ArrivedChunk) -> Result<()>,
    ) -> Result<()> {
        match peers {
            [] => return Ok(()),
            // A single incoming stream has nothing to reorder —
            // block per chunk.
            &[only] => {
                let stamp = transport_stamp(t, only);
                let mut inc = Incoming::new(only, 0);
                loop {
                    let wait = span_begin();
                    let msg = t.recv_timeout(only, tag.at(inc.next_chunk as u64), window)?;
                    let (chunk, done) = inc.feed(msg)?;
                    note_arrival(&tag, &chunk, wait, stamp);
                    on_chunk(chunk)?;
                    if done {
                        return Ok(());
                    }
                }
            }
            _ => {}
        }
        let mut pending: Vec<Incoming> = peers
            .iter()
            .enumerate()
            .map(|(idx, &peer)| Incoming::new(peer, idx))
            .collect();
        let mut deadline = Instant::now() + window;
        let mut backoff = Backoff::new();
        // One wait stamp for the whole sweep: the per-chunk "wait" in
        // a multi-peer drain is the time since the previous landing —
        // the receiver was free to take whichever peer was ready.
        let mut wait = span_begin();
        while !pending.is_empty() {
            let mut progressed = false;
            let mut i = 0;
            while i < pending.len() {
                // Drain whatever this peer has ready before moving on
                // (consecutive chunks of a hot stream complete back
                // to back).
                let mut done = false;
                while let Some(msg) =
                    t.try_recv(pending[i].peer, tag.at(pending[i].next_chunk as u64))?
                {
                    progressed = true;
                    let (chunk, fin) = pending[i].feed(msg)?;
                    note_arrival(&tag, &chunk, wait, transport_stamp(t, chunk.peer));
                    wait = span_begin();
                    on_chunk(chunk)?;
                    if fin {
                        done = true;
                        break;
                    }
                }
                if done {
                    pending.swap_remove(i);
                } else {
                    i += 1;
                }
            }
            if pending.is_empty() {
                break;
            }
            if progressed {
                backoff.progress();
                deadline = Instant::now() + window;
                continue;
            }
            if Instant::now() >= deadline {
                let stalled: Vec<(Pid, u64)> = pending
                    .iter()
                    .map(|p| (p.peer, p.next_chunk as u64))
                    .collect();
                return Err(CommError::Timeout {
                    from: pending[0].peer,
                    tag: tag.at(pending[0].next_chunk as u64),
                    stalled,
                });
            }
            backoff.wait();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ChannelHub;

    const NS: u8 = tags::NS_COLL;

    #[test]
    fn plan_chunks_enforces_the_tag_cap_once() {
        // Exactly 2^16 chunks fit (chunk indices 0..=65535).
        assert_eq!(plan_chunks(MAX_CHUNKS, 1), (1, MAX_CHUNKS));
        // One byte more: the chunk size is raised, never the count.
        assert_eq!(plan_chunks(MAX_CHUNKS + 1, 1), (2, MAX_CHUNKS / 2 + 1));
        // Requested sizes below the floor are raised too.
        let (cb, n) = plan_chunks(10 * MAX_CHUNKS, 4);
        assert_eq!(cb, 10);
        assert_eq!(n, MAX_CHUNKS);
        // Ordinary payloads honor the requested size.
        assert_eq!(plan_chunks(100, 16), (16, 7));
        assert_eq!(plan_chunks(0, 16), (16, 1), "empty streams are one header chunk");
        assert_eq!(plan_chunks(16, 16), (16, 1));
        assert_eq!(plan_chunks(17, 16), (16, 2));
    }

    #[test]
    fn chunk_tag_packs_lane_and_chunk_disjointly() {
        let a = ChunkTag::new(NS, 7);
        let b = ChunkTag::with_lane(NS, 7, 1 << 16);
        assert_eq!(a.at(0), tags::pack(NS, 7, 0));
        assert_eq!(a.at(5), tags::pack(NS, 7, 5));
        assert_eq!(b.at(5), tags::pack(NS, 7, (1 << 16) | 5));
        assert_ne!(a.at(5), b.at(5));
    }

    #[test]
    fn ambient_chunk_bytes_defaults_and_overrides() {
        // Process-global: keep this the only test that mutates it, and
        // use a large transient value so any concurrently constructed
        // context still sees single-chunk streams at test sizes.
        assert_eq!(ambient_chunk_bytes(), DEFAULT_CHUNK_BYTES);
        set_ambient_chunk_bytes(1 << 20);
        assert_eq!(ambient_chunk_bytes(), 1 << 20);
        set_ambient_chunk_bytes(0);
        assert_eq!(ambient_chunk_bytes(), DEFAULT_CHUNK_BYTES);
    }

    #[test]
    fn multipart_stream_roundtrips_and_counts_chunks() {
        let mut world = ChannelHub::world(2);
        let t1 = world.pop().unwrap();
        let t0 = world.pop().unwrap();
        let tag = ChunkTag::new(NS, 42);
        let a: Vec<u8> = (0..40).collect();
        let b: Vec<u8> = (100..140).collect();
        // 80 payload bytes at 16-byte chunks → 5 chunks.
        let sent = ChunkStream::send(&t0, 1, tag, 16, &[&a, &[], &b]).unwrap();
        assert_eq!(sent, 5);
        assert_eq!(t0.stats().msgs_sent(), 5);
        let got = ChunkStream::recv(&t1, 0, tag).unwrap();
        let want: Vec<u8> = a.iter().chain(b.iter()).copied().collect();
        assert_eq!(got, want);
    }

    #[test]
    fn empty_stream_is_one_message() {
        let mut world = ChannelHub::world(2);
        let t1 = world.pop().unwrap();
        let t0 = world.pop().unwrap();
        let tag = ChunkTag::new(NS, 43);
        assert_eq!(ChunkStream::send(&t0, 1, tag, 64, &[]).unwrap(), 1);
        assert_eq!(ChunkStream::recv(&t1, 0, tag).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn forwarding_relays_every_chunk_down_a_chain() {
        let world = ChannelHub::world(3);
        let payload: Vec<u8> = (0..100u8).collect();
        let tag = ChunkTag::new(NS, 44);
        let hs: Vec<_> = world
            .into_iter()
            .map(|t| {
                let payload = payload.clone();
                std::thread::spawn(move || match t.pid() {
                    0 => {
                        ChunkStream::send(&t, 1, tag, 16, &[&payload]).unwrap();
                        payload
                    }
                    1 => ChunkStream::recv_forward(&t, 0, tag, Some(2)).unwrap(),
                    _ => ChunkStream::recv(&t, 1, tag).unwrap(),
                })
            })
            .collect();
        for h in hs {
            assert_eq!(h.join().unwrap(), payload);
        }
    }

    #[test]
    fn drain_completes_multi_chunk_streams_from_many_peers() {
        let np = 4;
        let world = ChannelHub::world(np);
        let tag = ChunkTag::new(NS, 45);
        let hs: Vec<_> = world
            .into_iter()
            .map(|t| {
                std::thread::spawn(move || {
                    if t.pid() == 0 {
                        let peers: Vec<Pid> = (1..t.np()).collect();
                        let mut got: Vec<Option<Vec<u8>>> = vec![None; peers.len()];
                        ChunkStream::drain(&t, &peers, tag, |i, payload| {
                            got[i] = Some(payload);
                            Ok(())
                        })
                        .unwrap();
                        for (i, g) in got.iter().enumerate() {
                            let want = vec![(i + 1) as u8; 50 + (i + 1)];
                            assert_eq!(g.as_deref(), Some(&want[..]));
                        }
                    } else {
                        let part = vec![t.pid() as u8; 50 + t.pid()];
                        ChunkStream::send(&t, 0, tag, 16, &[&part]).unwrap();
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
    }

    /// The backoff resets to [`POLL_MIN`] on progress — a slow but
    /// advancing peer is polled hot again instead of inheriting the
    /// grown delay.
    #[test]
    fn backoff_resets_to_poll_min_on_progress() {
        let mut b = Backoff::new();
        assert_eq!(b.delay(), POLL_MIN);
        // Spin phase: the delay does not grow while yielding.
        for _ in 0..SPIN_SWEEPS {
            b.wait();
        }
        assert_eq!(b.delay(), POLL_MIN, "spinning must not inflate the delay");
        // Sleep phase: exponential growth, capped.
        for _ in 0..32 {
            b.wait();
        }
        assert!(b.delay() > POLL_MIN);
        assert!(b.delay() <= POLL_MAX);
        b.progress();
        assert_eq!(b.delay(), POLL_MIN, "progress must reset the backoff");
    }

    /// `drain_chunks` fires the callback once per landed chunk with
    /// in-order indices, correct payload offsets, and `is_last` on
    /// the final chunk — for both the multi-peer sweep and the
    /// single-peer blocking path.
    #[test]
    fn drain_chunks_delivers_every_chunk_in_order() {
        for senders in [1usize, 3] {
            let np = senders + 1;
            let world = ChannelHub::world(np);
            let tag = ChunkTag::new(NS, 47);
            let hs: Vec<_> = world
                .into_iter()
                .map(|t| {
                    std::thread::spawn(move || {
                        if t.pid() != 0 {
                            let part = vec![t.pid() as u8; 50];
                            ChunkStream::send(&t, 0, tag, 16, &[&part]).unwrap();
                            return;
                        }
                        let peers: Vec<Pid> = (1..t.np()).collect();
                        let mut next_idx = vec![0usize; peers.len()];
                        let mut got = vec![Vec::<u8>::new(); peers.len()];
                        let mut finished = vec![false; peers.len()];
                        ChunkStream::drain_chunks(&t, &peers, tag, |c| {
                            assert_eq!(c.peer, peers[c.peer_idx]);
                            assert_eq!(c.chunk_idx, next_idx[c.peer_idx], "in-order per peer");
                            assert_eq!(c.total, 50);
                            // 50 bytes at 16-byte chunks → 4 chunks.
                            assert_eq!(c.n_chunks, 4);
                            assert_eq!(c.offset, got[c.peer_idx].len());
                            assert_eq!(c.is_last, c.chunk_idx == 3);
                            next_idx[c.peer_idx] += 1;
                            got[c.peer_idx].extend_from_slice(c.payload());
                            if c.is_last {
                                finished[c.peer_idx] = true;
                            }
                            Ok(())
                        })
                        .unwrap();
                        for (i, g) in got.iter().enumerate() {
                            assert!(finished[i]);
                            assert_eq!(g, &vec![(i + 1) as u8; 50]);
                        }
                    })
                })
                .collect();
            for h in hs {
                h.join().unwrap();
            }
        }
    }

    /// A transport wrapper that silently swallows everything one peer
    /// sends — the receiver sees that peer as fully stalled.
    struct Withhold {
        inner: crate::comm::ChannelTransport,
        peer: Pid,
    }

    impl super::Transport for Withhold {
        fn pid(&self) -> Pid {
            self.inner.pid()
        }
        fn np(&self) -> usize {
            self.inner.np()
        }
        fn send(&self, to: Pid, tag: Tag, payload: &[u8]) -> Result<()> {
            self.inner.send(to, tag, payload)
        }
        fn recv_timeout(
            &self,
            from: Pid,
            tag: Tag,
            timeout: std::time::Duration,
        ) -> Result<Vec<u8>> {
            if from == self.peer {
                return Err(CommError::timeout(from, tag));
            }
            self.inner.recv_timeout(from, tag, timeout)
        }
        fn stats(&self) -> &crate::comm::CommStats {
            self.inner.stats()
        }
    }

    /// A peer that withholds its chunks past the stall window produces
    /// a timeout naming **every** stalled peer and its next-expected
    /// chunk — not just an arbitrary first one.
    #[test]
    fn drain_timeout_names_every_stalled_peer() {
        let np = 4;
        let mut world = ChannelHub::world(np);
        let t3 = world.pop().unwrap();
        let t2 = world.pop().unwrap();
        let t1 = world.pop().unwrap();
        let t0 = world.pop().unwrap();
        let tag = ChunkTag::new(NS, 48);
        // Peer 1 completes; peers 2 and 3 are withheld (their sends
        // land in the mailbox but the wrapper hides one of them; the
        // other never sends at all).
        ChunkStream::send(&t1, 0, tag, 16, &[&[7u8; 40][..]]).unwrap();
        ChunkStream::send(&t2, 0, tag, 16, &[&[8u8; 40][..]]).unwrap();
        drop(t3); // peer 3 never sends
        let t = Withhold { inner: t0, peer: 2 };
        let err = ChunkStream::drain_chunks_window(
            &t,
            &[1, 2, 3],
            tag,
            Duration::from_millis(50),
            |_c| Ok(()),
        )
        .unwrap_err();
        match err {
            CommError::Timeout { mut stalled, .. } => {
                stalled.sort_unstable();
                assert_eq!(stalled, vec![(2, 0), (3, 0)], "both stalled peers, next chunk 0");
            }
            other => panic!("expected Timeout, got {other:?}"),
        }
        // The Display form carries the full stall list.
        let msg = err_display(&t, tag);
        assert!(msg.contains("pid 2 (next chunk 0)") && msg.contains("pid 3 (next chunk 0)"));
    }

    /// Re-run the stalled drain and render its error (the first drain
    /// consumed peer 1's stream; peer 2's withheld chunks are still
    /// in the mailbox, peer 3 stays silent).
    fn err_display(t: &Withhold, tag: ChunkTag) -> String {
        ChunkStream::drain_chunks_window(t, &[2, 3], tag, Duration::from_millis(30), |_| Ok(()))
            .unwrap_err()
            .to_string()
    }

    /// A slow but progressing peer never trips the stall window: the
    /// deadline resets on every landed chunk, so a stream whose total
    /// duration exceeds the window still completes as long as each
    /// gap stays under it.
    #[test]
    fn slow_but_progressing_peer_resets_the_window() {
        let np = 3;
        let mut world = ChannelHub::world(np);
        let t2 = world.pop().unwrap();
        let t1 = world.pop().unwrap();
        let t0 = world.pop().unwrap();
        let tag = ChunkTag::new(NS, 49);
        let window = Duration::from_millis(250);
        // Peer 2 is fast; peer 1 dribbles 3 chunks with 100 ms gaps —
        // 300 ms total, over the 250 ms window, but each gap under it.
        let slow = std::thread::spawn(move || {
            let payload = vec![5u8; 48];
            let (cb, n_chunks) = plan_chunks(payload.len(), 16);
            assert_eq!(n_chunks, 3);
            let mut w = WireWriter::new();
            w.put_u64(payload.len() as u64);
            w.put_u64(n_chunks as u64);
            let frame = w.finish();
            for c in 0..n_chunks {
                std::thread::sleep(Duration::from_millis(100));
                let lo = c * cb;
                let window_bytes = &payload[lo..(lo + cb).min(payload.len())];
                if c == 0 {
                    t1.send_parts(0, tag.at(0), &[&frame, window_bytes]).unwrap();
                } else {
                    t1.send(0, tag.at(c as u64), window_bytes).unwrap();
                }
            }
        });
        ChunkStream::send(&t2, 0, tag, 16, &[&[6u8; 32][..]]).unwrap();
        let mut done = 0;
        ChunkStream::drain_chunks_window(&t0, &[1, 2], tag, window, |c| {
            if c.is_last {
                done += 1;
            }
            Ok(())
        })
        .unwrap();
        assert_eq!(done, 2, "both streams complete despite the slow dribble");
        slow.join().unwrap();
    }

    /// The receive side allocates its output exactly once, sized off
    /// chunk 0's frame: no growth reallocation ever runs, so the
    /// final capacity equals the payload length.
    #[test]
    fn multi_chunk_recv_allocates_once() {
        let mut world = ChannelHub::world(2);
        let t1 = world.pop().unwrap();
        let t0 = world.pop().unwrap();
        let tag = ChunkTag::new(NS, 50);
        // A non-power-of-two total: growth-doubling from empty could
        // never land on exactly this capacity.
        let payload: Vec<u8> = (0..5000u32).map(|i| (i % 251) as u8).collect();
        ChunkStream::send(&t0, 1, tag, 512, &[&payload]).unwrap();
        let got = ChunkStream::recv(&t1, 0, tag).unwrap();
        assert_eq!(got, payload);
        assert_eq!(got.capacity(), got.len(), "single reserve off the frame, no regrowth");
        // The drain path shares the same guarantee via `reserve_exact`.
        ChunkStream::send(&t0, 1, tag, 512, &[&payload]).unwrap();
        ChunkStream::drain(&t1, &[0], tag, |_, bytes| {
            assert_eq!(bytes.capacity(), bytes.len());
            assert_eq!(bytes, payload);
            Ok(())
        })
        .unwrap();
    }

    /// The datapath's process-wide stream counters see every chunk's
    /// wire bytes (frame included) on both sides. The instrument is
    /// global and monotone — other tests may add traffic concurrently
    /// — so the assertions are at-least deltas.
    #[test]
    fn stream_stats_count_wire_traffic() {
        let (ms0, bs0, mr0, br0) = comm_snapshot();
        let mut world = ChannelHub::world(2);
        let t1 = world.pop().unwrap();
        let t0 = world.pop().unwrap();
        let tag = ChunkTag::new(NS, 51);
        let payload = vec![1u8; 80];
        // 80 bytes at 16-byte chunks → 5 chunks, 96 wire bytes.
        assert_eq!(ChunkStream::send(&t0, 1, tag, 16, &[&payload]).unwrap(), 5);
        assert_eq!(ChunkStream::recv(&t1, 0, tag).unwrap(), payload);
        let (ms1, bs1, mr1, br1) = comm_snapshot();
        assert!(ms1 - ms0 >= 5, "sent msgs counted");
        assert!(bs1 - bs0 >= 96, "sent wire bytes include the frame");
        assert!(mr1 - mr0 >= 5, "recv msgs counted");
        assert!(br1 - br0 >= 96, "recv wire bytes include the frame");
    }

    #[test]
    fn malformed_chunk_count_is_loud() {
        let mut world = ChannelHub::world(2);
        let t1 = world.pop().unwrap();
        let t0 = world.pop().unwrap();
        let tag = ChunkTag::new(NS, 46);
        let mut w = WireWriter::new();
        w.put_u64(4);
        w.put_u64(0); // zero chunks: invalid
        t0.send(1, tag.at(0), &w.finish()).unwrap();
        assert!(matches!(
            ChunkStream::recv(&t1, 0, tag),
            Err(CommError::Malformed(_))
        ));
    }
}
