//! [`BufferPool`] — reusable wire buffers for the data-movement hot
//! path.
//!
//! Every remap/STREAM iteration used to allocate a fresh `WireWriter`
//! per message and drop it after the send; at one coalesced message
//! per peer per epoch that is still `peers × iterations` heap
//! round-trips of multi-megabyte buffers. The pool keeps returned
//! buffers (LIFO, so the warmest allocation is reused first) and hands
//! them back on the next checkout: steady-state send loops perform
//! **zero payload allocations** — asserted by tests via the
//! [`BufferPool::checkouts`] / [`BufferPool::hits`] instruments, not
//! assumed.
//!
//! Checkout returns a [`PooledBuf`] guard that gives the buffer back
//! on drop, so early returns (transport errors) cannot leak buffers
//! out of the pool.

use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// How many idle buffers a pool retains before excess ones are freed.
/// Remap needs two live buffers per in-flight send (header + payload);
/// 32 covers every realistic peer fan-out with room to spare.
const DEFAULT_RETAINED: usize = 32;

/// A pool of reusable `Vec<u8>` wire buffers.
pub struct BufferPool {
    free: Mutex<Vec<Vec<u8>>>,
    max_retained: usize,
    checkouts: AtomicU64,
    hits: AtomicU64,
}

impl Default for BufferPool {
    fn default() -> Self {
        BufferPool::new()
    }
}

impl BufferPool {
    pub fn new() -> BufferPool {
        BufferPool::with_retained(DEFAULT_RETAINED)
    }

    /// A pool retaining at most `max_retained` idle buffers.
    pub fn with_retained(max_retained: usize) -> BufferPool {
        BufferPool {
            free: Mutex::new(Vec::new()),
            max_retained,
            checkouts: AtomicU64::new(0),
            hits: AtomicU64::new(0),
        }
    }

    /// The process-wide pool used by the remap engine's send path.
    pub fn global() -> &'static BufferPool {
        static POOL: OnceLock<BufferPool> = OnceLock::new();
        POOL.get_or_init(BufferPool::new)
    }

    /// Check out a cleared buffer with at least `cap` bytes reserved,
    /// reusing a previously returned allocation when one is free.
    pub fn checkout(&self, cap: usize) -> PooledBuf<'_> {
        // Checkout latency (lock contention + miss allocation) feeds
        // the pool-wait histogram; free when recording is off.
        let wait = crate::obs::span_begin();
        self.checkouts.fetch_add(1, Ordering::Relaxed);
        let reused = self.free.lock().unwrap().pop();
        let mut buf = match reused {
            Some(b) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                b
            }
            None => {
                // A miss means a fresh heap allocation on the hot path;
                // steady-state loops should only see these during warm-up.
                crate::obs_event!(
                    crate::obs::EventKind::PoolMiss,
                    tag: 0,
                    peer: crate::obs::NO_PEER,
                    a: cap as u64,
                    b: 0
                );
                Vec::new()
            }
        };
        buf.clear();
        buf.reserve(cap);
        crate::obs::hist::record_since(crate::obs::hist::HistKind::PoolWait, wait);
        PooledBuf { pool: self, buf }
    }

    fn give_back(&self, mut buf: Vec<u8>) {
        if buf.capacity() == 0 {
            return;
        }
        buf.clear();
        let mut free = self.free.lock().unwrap();
        if free.len() < self.max_retained {
            free.push(buf);
        }
    }

    /// Total checkouts (the traffic instrument).
    pub fn checkouts(&self) -> u64 {
        self.checkouts.load(Ordering::Relaxed)
    }

    /// Checkouts served by a reused allocation — in steady state this
    /// tracks [`BufferPool::checkouts`] with a constant offset (the
    /// warm-up allocations), i.e. zero allocations per iteration.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Idle buffers currently retained.
    pub fn retained(&self) -> usize {
        self.free.lock().unwrap().len()
    }
}

/// A checked-out pool buffer; derefs to `Vec<u8>` and returns itself
/// to the pool on drop.
pub struct PooledBuf<'p> {
    pool: &'p BufferPool,
    buf: Vec<u8>,
}

impl PooledBuf<'_> {
    /// Move the backing vector out (e.g. into a `WireWriter`), leaving
    /// the guard empty; pair with [`PooledBuf::restore`] so the
    /// allocation still returns to the pool.
    pub fn take(&mut self) -> Vec<u8> {
        std::mem::take(&mut self.buf)
    }

    /// Put a vector (typically the one from [`PooledBuf::take`], after
    /// `WireWriter::finish`) back under this guard's management.
    pub fn restore(&mut self, buf: Vec<u8>) {
        self.buf = buf;
    }
}

impl Deref for PooledBuf<'_> {
    type Target = Vec<u8>;

    fn deref(&self) -> &Vec<u8> {
        &self.buf
    }
}

impl DerefMut for PooledBuf<'_> {
    fn deref_mut(&mut self) -> &mut Vec<u8> {
        &mut self.buf
    }
}

impl Drop for PooledBuf<'_> {
    fn drop(&mut self) {
        self.pool.give_back(std::mem::take(&mut self.buf));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn steady_state_reuses_one_allocation() {
        let pool = BufferPool::new();
        let first_ptr = {
            let mut b = pool.checkout(1024);
            b.extend_from_slice(&[1, 2, 3]);
            b.as_ptr() as usize
        };
        for _ in 0..100 {
            let b = pool.checkout(1024);
            assert!(b.is_empty(), "pooled buffers come back cleared");
            assert!(b.capacity() >= 1024);
            assert_eq!(b.as_ptr() as usize, first_ptr, "same allocation reused");
        }
        assert_eq!(pool.checkouts(), 101);
        assert_eq!(pool.hits(), 100, "every checkout after the first is allocation-free");
    }

    #[test]
    fn concurrent_checkouts_get_distinct_buffers() {
        let pool = BufferPool::new();
        let mut a = pool.checkout(16);
        let mut b = pool.checkout(16);
        a.push(1);
        b.push(2);
        assert_ne!(a.as_ptr(), b.as_ptr());
        drop(a);
        drop(b);
        assert_eq!(pool.retained(), 2);
    }

    #[test]
    fn retention_is_capped() {
        let pool = BufferPool::with_retained(2);
        let bufs: Vec<_> = (0..5).map(|_| pool.checkout(8)).collect();
        drop(bufs);
        assert_eq!(pool.retained(), 2);
    }

    #[test]
    fn take_restore_roundtrip_returns_to_pool() {
        let pool = BufferPool::new();
        {
            let mut guard = pool.checkout(64);
            let mut v = guard.take();
            v.extend_from_slice(b"framing");
            guard.restore(v);
            assert_eq!(&guard[..], b"framing");
        }
        assert_eq!(pool.retained(), 1);
        assert!(pool.checkout(8).capacity() >= 64);
    }

    #[test]
    fn zero_capacity_buffers_are_not_retained() {
        let pool = BufferPool::new();
        drop(pool.checkout(0));
        assert_eq!(pool.retained(), 0);
    }

    #[test]
    fn global_pool_is_shared() {
        let a = BufferPool::global() as *const BufferPool;
        let b = BufferPool::global() as *const BufferPool;
        assert_eq!(a, b);
    }
}
