//! Barrier synchronization over any [`Transport`].
//!
//! Client-server shape (the paper's §II simplest model): all workers
//! report to PID 0, PID 0 releases everyone. O(Np) messages, two
//! phases — fine at the scales the coordinator runs (the hot loop
//! never crosses a barrier; barriers bracket timed phases only).

use super::{tags, Result, Transport};
use std::time::Duration;

/// Enter a two-phase barrier identified by `epoch`.
///
/// All `np` endpoints must call this with the same `epoch`; the epoch
/// keeps back-to-back barriers from aliasing.
pub fn barrier(t: &dyn Transport, epoch: u64, timeout: Duration) -> Result<()> {
    let tag = tags::pack(tags::NS_BARRIER, epoch, 0);
    let np = t.np();
    if np == 1 {
        return Ok(());
    }
    if t.pid() == 0 {
        for from in 1..np {
            t.recv_timeout(from, tag, timeout)?;
        }
        for to in 1..np {
            t.send(to, tag, &[])?;
        }
    } else {
        t.send(0, tag, &[])?;
        t.recv_timeout(0, tag, timeout)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ChannelHub;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn barrier_separates_phases() {
        let np = 8;
        let world = ChannelHub::world(np);
        let phase1 = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for t in world {
            let phase1 = phase1.clone();
            handles.push(thread::spawn(move || {
                phase1.fetch_add(1, Ordering::SeqCst);
                barrier(&t, 0, Duration::from_secs(5)).unwrap();
                // After the barrier every participant must have bumped.
                assert_eq!(phase1.load(Ordering::SeqCst), 8);
                barrier(&t, 1, Duration::from_secs(5)).unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn single_pid_barrier_is_noop() {
        let mut world = ChannelHub::world(1);
        let t = world.pop().unwrap();
        barrier(&t, 0, Duration::from_millis(1)).unwrap();
        assert!(t.stats().is_silent());
    }
}
