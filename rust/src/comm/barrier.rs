//! Barrier synchronization over any [`Transport`].
//!
//! Routed through the [`crate::collective`] subsystem (`NS_BARRIER`
//! namespace). The process-default algorithm is the legacy
//! client-server star — all workers report to PID 0, PID 0 releases
//! everyone, O(Np) messages at one rank — and `--coll tree|ring|hier`
//! swap in the binomial up/down tree, the dissemination schedule, or
//! the two-level topology-aware composition ([`barrier_with`] for an
//! explicit context). Barriers bracket timed phases only (the hot
//! loop never crosses one), but at large Np the O(log Np) schedules
//! keep even that bracketing off the leader's critical path.

use super::{tags, Result, Transport};
use crate::collective::{Collective, TagSpace};
use std::time::Duration;

/// Enter a barrier identified by `epoch` under the process-default
/// collective algorithm.
///
/// All `np` endpoints must call this with the same `epoch`; the epoch
/// keeps back-to-back barriers from aliasing.
pub fn barrier(t: &dyn Transport, epoch: u64, timeout: Duration) -> Result<()> {
    barrier_with(&crate::collective::ambient(t.np()), t, epoch, timeout)
}

/// Enter a barrier under an explicit collective context.
pub fn barrier_with(
    coll: &Collective,
    t: &dyn Transport,
    epoch: u64,
    timeout: Duration,
) -> Result<()> {
    coll.barrier(t, TagSpace::packed(tags::NS_BARRIER, epoch), timeout)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::{CollKind, Topology};
    use crate::comm::ChannelHub;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn barrier_separates_phases() {
        let np = 8;
        let world = ChannelHub::world(np);
        let phase1 = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for t in world {
            let phase1 = phase1.clone();
            handles.push(thread::spawn(move || {
                phase1.fetch_add(1, Ordering::SeqCst);
                barrier(&t, 0, Duration::from_secs(5)).unwrap();
                // After the barrier every participant must have bumped.
                assert_eq!(phase1.load(Ordering::SeqCst), 8);
                barrier(&t, 1, Duration::from_secs(5)).unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn single_pid_barrier_is_noop() {
        let mut world = ChannelHub::world(1);
        let t = world.pop().unwrap();
        barrier(&t, 0, Duration::from_millis(1)).unwrap();
        assert!(t.stats().is_silent());
    }

    /// Every algorithm synchronizes: no thread observes a stale phase
    /// counter after release.
    #[test]
    fn barrier_with_every_algorithm() {
        for kind in [CollKind::Tree, CollKind::Ring, CollKind::Hier] {
            let np = 6;
            let world = ChannelHub::world(np);
            let phase = Arc::new(AtomicUsize::new(0));
            let mut handles = Vec::new();
            for t in world {
                let phase = phase.clone();
                handles.push(thread::spawn(move || {
                    let coll = Collective::new(kind, Topology::grouped(np, 2));
                    phase.fetch_add(1, Ordering::SeqCst);
                    barrier_with(&coll, &t, 5, Duration::from_secs(5)).unwrap();
                    assert_eq!(phase.load(Ordering::SeqCst), np, "kind {kind}");
                    barrier_with(&coll, &t, 6, Duration::from_secs(5)).unwrap();
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
        }
    }
}
