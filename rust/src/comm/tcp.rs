//! TCP transport: length-prefixed framed streams, one multiplexed
//! connection per directed peer pair.
//!
//! The cross-node path. Every endpoint binds a data listener; a
//! sender lazily dials one connection to each peer it talks to
//! (bounded connect retry with exponential backoff, Nagle off) and
//! multiplexes **all** tags over it. Each accepted connection gets a
//! reader thread that parses frames and dispatches payloads into
//! per-`(from, tag)` queues under one condvar — the receive side of
//! [`Transport`] never touches a socket.
//!
//! The wire frame is a 28-byte header followed by the payload:
//!
//! ```text
//! [magic: u32 = 0x44415252 "DARR"]
//! [len:   u64]  payload bytes
//! [tag:   u64]
//! [from:  u32]  sender PID
//! [crc:   u32]  CRC-32 of the 24 header bytes above
//! ```
//!
//! The CRC covers the header only (the kernel already checksums the
//! stream; the CRC catches desynchronization and truncation, not
//! payload corruption). A reader that hits a short header, a bad
//! magic/CRC, or EOF mid-payload **poisons** the attributable sender:
//! pending and future receives from that PID fail immediately with a
//! one-line [`CommError::Malformed`] instead of hanging out a
//! timeout. A clean close at a frame boundary is a normal shutdown.
//!
//! `send_parts` writes the header and every part with vectored I/O —
//! the scatter list goes straight from the caller's buffers to the
//! socket, so [`super::ChunkStream`]'s zero-copy contract holds.
//!
//! Rendezvous ([`TcpRendezvous`]) is leader-rooted: the leader binds
//! a boot listener before spawning workers and hands its address down
//! via `DISTARRAY_TCP_BOOT`; each worker binds its own data listener,
//! registers `(pid, addr)` over the boot connection, and receives the
//! full pid→address map in return. Addresses are loopback — the
//! launcher simulates nodes as processes on one machine; a real
//! multi-host deployment would advertise routable addresses through
//! the same map without touching the framing.

use super::{CommError, CommStats, Result, Tag, Transport, TransportKind};
use crate::dmap::Pid;
use std::collections::{HashMap, VecDeque};
use std::io::{self, IoSlice, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Frame header bytes (see the module docs for the layout).
pub const FRAME_HDR: usize = 28;
/// Frame magic: the bytes `"DARR"` on the wire.
pub const FRAME_MAGIC: u32 = u32::from_le_bytes(*b"DARR");
/// Sanity cap on one frame's payload.
const MAX_FRAME: u64 = 1 << 32;
/// Rendezvous handshake I/O timeout.
const BOOT_TIMEOUT: Duration = Duration::from_secs(30);

/// Bitwise (table-free) CRC-32 (IEEE polynomial).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Build the frame header for `len` payload bytes from `from`.
fn frame_header(from: Pid, tag: Tag, len: usize) -> [u8; FRAME_HDR] {
    let mut h = [0u8; FRAME_HDR];
    h[0..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
    h[4..12].copy_from_slice(&(len as u64).to_le_bytes());
    h[12..20].copy_from_slice(&tag.to_le_bytes());
    h[20..24].copy_from_slice(&(from as u32).to_le_bytes());
    let crc = crc32(&h[0..24]);
    h[24..28].copy_from_slice(&crc.to_le_bytes());
    h
}

/// Per-`(from, tag)` delivery queues plus the poisoned-peer table,
/// under one lock so a reader's verdict and its last deliveries are
/// observed atomically.
struct Inbox {
    queues: HashMap<(Pid, Tag), VecDeque<Vec<u8>>>,
    dead: HashMap<Pid, String>,
}

struct Shared {
    inbox: Mutex<Inbox>,
    cv: Condvar,
    shutdown: AtomicBool,
}

impl Shared {
    fn deliver(&self, from: Pid, tag: Tag, payload: Vec<u8>) {
        let mut inbox = self.inbox.lock().unwrap();
        inbox.queues.entry((from, tag)).or_default().push_back(payload);
        drop(inbox);
        self.cv.notify_all();
    }

    /// Mark `from` dead with a one-line reason; pending receives fail
    /// immediately. The first verdict wins (it names the root cause).
    fn poison(&self, from: Option<Pid>, reason: String) {
        let Some(from) = from else { return };
        let mut inbox = self.inbox.lock().unwrap();
        inbox.dead.entry(from).or_insert(reason);
        drop(inbox);
        self.cv.notify_all();
    }
}

/// TCP transport endpoint for one PID. See the module docs.
pub struct TcpTransport {
    pid: Pid,
    np: usize,
    /// `addrs[p]` — peer `p`'s data-listener address.
    addrs: Vec<String>,
    /// Lazily dialed outgoing connections, one per peer.
    conns: Vec<Mutex<Option<TcpStream>>>,
    shared: Arc<Shared>,
    accept_handle: Option<JoinHandle<()>>,
    connect_attempts: u32,
    connect_backoff: Duration,
    stats: CommStats,
}

impl TcpTransport {
    /// Endpoint over an already-bound data listener and the full
    /// pid→address map (what rendezvous produces).
    fn from_parts(
        pid: Pid,
        np: usize,
        listener: TcpListener,
        addrs: Vec<String>,
    ) -> io::Result<TcpTransport> {
        assert_eq!(addrs.len(), np, "address map must cover the world");
        let shared = Arc::new(Shared {
            inbox: Mutex::new(Inbox { queues: HashMap::new(), dead: HashMap::new() }),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let accept_shared = Arc::clone(&shared);
        let accept_handle = std::thread::Builder::new()
            .name(format!("tcp-accept-{pid}"))
            .spawn(move || accept_loop(listener, accept_shared))?;
        Ok(TcpTransport {
            pid,
            np,
            addrs,
            conns: (0..np).map(|_| Mutex::new(None)).collect(),
            shared,
            accept_handle: Some(accept_handle),
            connect_attempts: 40,
            connect_backoff: Duration::from_millis(25),
            stats: CommStats::new(),
        })
    }

    /// Override the bounded connect retry (attempts × exponential
    /// backoff from `backoff`, capped at 1 s per wait).
    pub fn with_connect_retry(mut self, attempts: u32, backoff: Duration) -> TcpTransport {
        self.connect_attempts = attempts.max(1);
        self.connect_backoff = backoff;
        self
    }

    /// This endpoint's data-listener address.
    pub fn addr(&self) -> &str {
        &self.addrs[self.pid]
    }

    fn dial(&self, to: Pid) -> Result<TcpStream> {
        let addr = &self.addrs[to];
        let mut delay = self.connect_backoff;
        let mut last: Option<io::Error> = None;
        for _ in 0..self.connect_attempts {
            match TcpStream::connect(addr) {
                Ok(s) => {
                    let _ = s.set_nodelay(true);
                    return Ok(s);
                }
                Err(e) => {
                    last = Some(e);
                    std::thread::sleep(delay);
                    delay = (delay * 2).min(Duration::from_secs(1));
                }
            }
        }
        let e = last.unwrap();
        Err(CommError::Io(io::Error::new(
            e.kind(),
            format!(
                "tcp connect to pid {to} at {addr} failed after {} attempts: {e}",
                self.connect_attempts
            ),
        )))
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_handle.take() {
            // Unblock the accept loop with a throwaway connection.
            let _ = TcpStream::connect(&self.addrs[self.pid]);
            let _ = h.join();
        }
    }
}

impl Transport for TcpTransport {
    fn pid(&self) -> Pid {
        self.pid
    }

    fn np(&self) -> usize {
        self.np
    }

    fn send(&self, to: Pid, tag: Tag, payload: &[u8]) -> Result<()> {
        self.send_parts(to, tag, &[payload])
    }

    fn send_parts(&self, to: Pid, tag: Tag, parts: &[&[u8]]) -> Result<()> {
        let Some(conn) = self.conns.get(to) else {
            return Err(CommError::Disconnected(to));
        };
        let total: usize = parts.iter().map(|p| p.len()).sum();
        let header = frame_header(self.pid, tag, total);
        let mut guard = conn.lock().unwrap();
        if guard.is_none() {
            *guard = Some(self.dial(to)?);
        }
        let stream = guard.as_mut().unwrap();
        let mut bufs: Vec<&[u8]> = Vec::with_capacity(parts.len() + 1);
        bufs.push(&header);
        bufs.extend_from_slice(parts);
        if let Err(e) = write_all_vectored(stream, &bufs) {
            // A broken connection is not resumable mid-frame; drop it
            // so a later send re-dials from a clean boundary.
            *guard = None;
            return Err(CommError::Io(io::Error::new(
                e.kind(),
                format!("tcp send of {total} bytes to pid {to} failed: {e}"),
            )));
        }
        self.stats.record_send(total);
        Ok(())
    }

    fn recv_timeout(&self, from: Pid, tag: Tag, timeout: Duration) -> Result<Vec<u8>> {
        if from >= self.np {
            return Err(CommError::Disconnected(from));
        }
        let deadline = Instant::now() + timeout;
        let mut inbox = self.shared.inbox.lock().unwrap();
        loop {
            if let Some(q) = inbox.queues.get_mut(&(from, tag)) {
                if let Some(msg) = q.pop_front() {
                    if q.is_empty() {
                        inbox.queues.remove(&(from, tag));
                    }
                    self.stats.record_recv(msg.len());
                    return Ok(msg);
                }
            }
            // Already-delivered frames above stay receivable; only a
            // queue miss consults the poison table.
            if let Some(reason) = inbox.dead.get(&from) {
                return Err(CommError::Malformed(reason.clone()));
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(CommError::timeout(from, tag));
            }
            let (g, _) = self.shared.cv.wait_timeout(inbox, deadline - now).unwrap();
            inbox = g;
        }
    }

    fn stats(&self) -> &CommStats {
        &self.stats
    }

    fn kind(&self) -> Option<TransportKind> {
        Some(TransportKind::Tcp)
    }
}

/// Accept connections until shutdown, one reader thread each.
fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    loop {
        let Ok((stream, _)) = listener.accept() else {
            return;
        };
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let _ = stream.set_nodelay(true);
        let reader_shared = Arc::clone(&shared);
        let _ = std::thread::Builder::new()
            .name("tcp-reader".into())
            .spawn(move || reader_loop(stream, reader_shared));
    }
}

enum HeaderRead {
    Full,
    /// Zero bytes at a frame boundary: clean shutdown.
    CleanEof,
}

fn read_header(stream: &mut TcpStream, buf: &mut [u8]) -> io::Result<HeaderRead> {
    let mut off = 0;
    while off < buf.len() {
        match stream.read(&mut buf[off..]) {
            Ok(0) if off == 0 => return Ok(HeaderRead::CleanEof),
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    format!("connection closed {off} bytes into a {} byte header", buf.len()),
                ))
            }
            Ok(n) => off += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(HeaderRead::Full)
}

/// Parse frames off one accepted connection, dispatching payloads
/// into the inbox. Any malformation or mid-frame EOF poisons the
/// attributable sender and ends the connection.
fn reader_loop(mut stream: TcpStream, shared: Arc<Shared>) {
    let mut last_from: Option<Pid> = None;
    loop {
        let mut hdr = [0u8; FRAME_HDR];
        match read_header(&mut stream, &mut hdr) {
            Ok(HeaderRead::CleanEof) => return,
            Ok(HeaderRead::Full) => {}
            Err(e) => {
                shared.poison(last_from, format!("tcp frame header truncated: {e}"));
                return;
            }
        }
        let magic = u32::from_le_bytes(hdr[0..4].try_into().unwrap());
        let crc = u32::from_le_bytes(hdr[24..28].try_into().unwrap());
        if magic != FRAME_MAGIC || crc != crc32(&hdr[0..24]) {
            // The `from` field is untrusted when the CRC fails; only a
            // previously attributed sender can be poisoned.
            shared.poison(
                last_from,
                format!("tcp frame desynchronized (magic {magic:#x}, bad header crc)"),
            );
            return;
        }
        let len = u64::from_le_bytes(hdr[4..12].try_into().unwrap());
        let tag = Tag::from_le_bytes(hdr[12..20].try_into().unwrap());
        let from = u32::from_le_bytes(hdr[20..24].try_into().unwrap()) as Pid;
        if len > MAX_FRAME {
            shared.poison(Some(from), format!("tcp frame from pid {from} claims {len} bytes"));
            return;
        }
        last_from = Some(from);
        let mut payload = vec![0u8; len as usize];
        if let Err(e) = stream.read_exact(&mut payload) {
            shared.poison(
                Some(from),
                format!("tcp frame from pid {from} truncated ({len} byte payload): {e}"),
            );
            return;
        }
        shared.deliver(from, tag, payload);
    }
}

/// Write every buffer in order with vectored I/O, resuming across
/// partial writes (`write_all_vectored` is not yet stable).
fn write_all_vectored(stream: &mut TcpStream, bufs: &[&[u8]]) -> io::Result<()> {
    let mut idx = 0;
    let mut off = 0;
    let mut slices: Vec<IoSlice<'_>> = Vec::with_capacity(bufs.len());
    while idx < bufs.len() {
        if bufs[idx].len() == off {
            idx += 1;
            off = 0;
            continue;
        }
        slices.clear();
        slices.push(IoSlice::new(&bufs[idx][off..]));
        for b in &bufs[idx + 1..] {
            if !b.is_empty() {
                slices.push(IoSlice::new(b));
            }
        }
        let mut n = match stream.write_vectored(&slices) {
            Ok(0) => return Err(io::ErrorKind::WriteZero.into()),
            Ok(n) => n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        };
        while idx < bufs.len() && n > 0 {
            let avail = bufs[idx].len() - off;
            if n < avail {
                off += n;
                break;
            }
            n -= avail;
            idx += 1;
            off = 0;
        }
    }
    Ok(())
}

fn put_u64(s: &mut TcpStream, v: u64) -> io::Result<()> {
    s.write_all(&v.to_le_bytes())
}

fn get_u64(s: &mut TcpStream) -> io::Result<u64> {
    let mut b = [0u8; 8];
    s.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn put_str(s: &mut TcpStream, v: &str) -> io::Result<()> {
    put_u64(s, v.len() as u64)?;
    s.write_all(v.as_bytes())
}

fn get_str(s: &mut TcpStream) -> io::Result<String> {
    let len = get_u64(s)?;
    if len > 4096 {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("rendezvous string of {len} bytes"),
        ));
    }
    let mut buf = vec![0u8; len as usize];
    s.read_exact(&mut buf)?;
    String::from_utf8(buf)
        .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "rendezvous string not utf-8"))
}

/// Leader-rooted address exchange for a TCP world (see module docs).
pub struct TcpRendezvous {
    np: usize,
    boot: TcpListener,
    data: TcpListener,
}

impl TcpRendezvous {
    /// Bind the leader's boot and data listeners — before spawning
    /// workers, so [`TcpRendezvous::boot_addr`] can ride their
    /// environment.
    pub fn leader(np: usize) -> io::Result<TcpRendezvous> {
        Ok(TcpRendezvous {
            np,
            boot: TcpListener::bind("127.0.0.1:0")?,
            data: TcpListener::bind("127.0.0.1:0")?,
        })
    }

    /// The boot address workers must register at
    /// (`DISTARRAY_TCP_BOOT`).
    pub fn boot_addr(&self) -> String {
        self.boot.local_addr().map(|a| a.to_string()).unwrap_or_default()
    }

    /// Accept every worker's `(pid, addr)` registration, reply with
    /// the complete map, and become the leader's endpoint.
    pub fn complete_leader(self) -> io::Result<TcpTransport> {
        let mut addrs = vec![String::new(); self.np];
        addrs[0] = self.data.local_addr()?.to_string();
        let mut pending = Vec::with_capacity(self.np.saturating_sub(1));
        for _ in 1..self.np {
            let (mut s, _) = self.boot.accept()?;
            s.set_read_timeout(Some(BOOT_TIMEOUT))?;
            let pid = get_u64(&mut s)? as usize;
            let addr = get_str(&mut s)?;
            if pid == 0 || pid >= self.np || !addrs[pid].is_empty() {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("rendezvous registration for invalid or duplicate pid {pid}"),
                ));
            }
            addrs[pid] = addr;
            pending.push(s);
        }
        for s in &mut pending {
            put_u64(s, self.np as u64)?;
            for a in &addrs {
                put_str(s, a)?;
            }
        }
        TcpTransport::from_parts(0, self.np, self.data, addrs)
    }

    /// Worker side: bind a data listener, register at `boot_addr`,
    /// receive the full map, and become this worker's endpoint.
    pub fn worker(pid: Pid, boot_addr: &str) -> io::Result<TcpTransport> {
        let data = TcpListener::bind("127.0.0.1:0")?;
        let mut boot = connect_with_retry(boot_addr, 100, Duration::from_millis(30))?;
        boot.set_read_timeout(Some(BOOT_TIMEOUT))?;
        put_u64(&mut boot, pid as u64)?;
        put_str(&mut boot, &data.local_addr()?.to_string())?;
        let np = get_u64(&mut boot)? as usize;
        if pid >= np {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("rendezvous map covers {np} pids, this worker is {pid}"),
            ));
        }
        let mut addrs = Vec::with_capacity(np);
        for _ in 0..np {
            addrs.push(get_str(&mut boot)?);
        }
        TcpTransport::from_parts(pid, np, data, addrs)
    }

    /// An in-process world over loopback — tests, conformance, and
    /// the transport microbench.
    pub fn loopback_world(np: usize) -> io::Result<Vec<TcpTransport>> {
        let listeners: Vec<TcpListener> =
            (0..np).map(|_| TcpListener::bind("127.0.0.1:0")).collect::<io::Result<_>>()?;
        let addrs: Vec<String> = listeners
            .iter()
            .map(|l| l.local_addr().map(|a| a.to_string()))
            .collect::<io::Result<_>>()?;
        listeners
            .into_iter()
            .enumerate()
            .map(|(pid, l)| TcpTransport::from_parts(pid, np, l, addrs.clone()))
            .collect()
    }
}

fn connect_with_retry(addr: &str, attempts: u32, backoff: Duration) -> io::Result<TcpStream> {
    let mut delay = backoff;
    let mut last: Option<io::Error> = None;
    for _ in 0..attempts.max(1) {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                last = Some(e);
                std::thread::sleep(delay);
                delay = (delay * 2).min(Duration::from_secs(1));
            }
        }
    }
    let e = last.unwrap();
    Err(io::Error::new(
        e.kind(),
        format!("connect to {addr} failed after {attempts} attempts: {e}"),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_known_vector() {
        // The classic IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn loopback_roundtrip_and_tag_order() {
        let world = TcpRendezvous::loopback_world(2).unwrap();
        let (t0, t1) = (&world[0], &world[1]);
        for i in 0..10u8 {
            t0.send(1, 7, &[i; 5]).unwrap();
            t0.send(1, 8, &[i + 50; 2]).unwrap();
        }
        for i in 0..10u8 {
            assert_eq!(t1.recv_timeout(0, 7, Duration::from_secs(5)).unwrap(), vec![i; 5]);
            assert_eq!(t1.recv_timeout(0, 8, Duration::from_secs(5)).unwrap(), vec![i + 50; 2]);
        }
        // Both directions work over the pair's two directed streams.
        t1.send(0, 9, b"pong").unwrap();
        assert_eq!(t0.recv_timeout(1, 9, Duration::from_secs(5)).unwrap(), b"pong");
    }

    #[test]
    fn send_parts_is_one_contiguous_payload() {
        let world = TcpRendezvous::loopback_world(2).unwrap();
        world[0].send_parts(1, 3, &[b"abc", b"", b"defg", b"h"]).unwrap();
        assert_eq!(world[1].recv_timeout(0, 3, Duration::from_secs(5)).unwrap(), b"abcdefgh");
    }

    #[test]
    fn timeout_names_the_silent_peer() {
        let world = TcpRendezvous::loopback_world(2).unwrap();
        let err = world[0].recv_timeout(1, 4, Duration::from_millis(30)).unwrap_err();
        match err {
            CommError::Timeout { from, tag, .. } => assert_eq!((from, tag), (1, 4)),
            other => panic!("expected Timeout, got {other:?}"),
        }
    }

    /// A frame whose header promises more payload than ever arrives
    /// poisons the sender: the pending receive fails with a one-line
    /// error well before the timeout would fire — never a hang.
    #[test]
    fn truncated_frame_fails_fast_instead_of_hanging() {
        let world = TcpRendezvous::loopback_world(2).unwrap();
        let t1 = &world[1];
        let mut raw = TcpStream::connect(t1.addr()).unwrap();
        let header = frame_header(0, 42, 1000);
        raw.write_all(&header).unwrap();
        raw.write_all(&[7u8; 10]).unwrap(); // 10 of 1000 payload bytes
        drop(raw);
        let t = Instant::now();
        let err = t1.recv_timeout(0, 42, Duration::from_secs(30)).unwrap_err();
        assert!(t.elapsed() < Duration::from_secs(5), "poisoning must not wait out the timeout");
        let msg = err.to_string();
        assert!(msg.contains("truncated") && msg.contains("pid 0"), "{msg}");
    }

    /// Garbage that fails the magic/CRC check cannot be attributed to
    /// any sender — the connection dies quietly and real traffic from
    /// properly framed connections keeps flowing.
    #[test]
    fn desynchronized_connection_does_not_poison_real_peers() {
        let world = TcpRendezvous::loopback_world(2).unwrap();
        let mut raw = TcpStream::connect(world[1].addr()).unwrap();
        raw.write_all(&[0xAAu8; 64]).unwrap();
        drop(raw);
        world[0].send(1, 5, b"still alive").unwrap();
        assert_eq!(
            world[1].recv_timeout(0, 5, Duration::from_secs(5)).unwrap(),
            b"still alive"
        );
    }

    /// Frames already delivered before the truncation stay
    /// receivable; only the queue miss reports the poisoning.
    #[test]
    fn poisoning_preserves_previously_landed_frames() {
        let world = TcpRendezvous::loopback_world(2).unwrap();
        let t1 = &world[1];
        let mut raw = TcpStream::connect(t1.addr()).unwrap();
        raw.write_all(&frame_header(0, 6, 4)).unwrap();
        raw.write_all(b"good").unwrap();
        raw.write_all(&frame_header(0, 6, 500)).unwrap();
        raw.write_all(&[1u8; 3]).unwrap();
        drop(raw);
        assert_eq!(t1.recv_timeout(0, 6, Duration::from_secs(5)).unwrap(), b"good");
        assert!(matches!(
            t1.recv_timeout(0, 6, Duration::from_secs(5)),
            Err(CommError::Malformed(_))
        ));
    }

    #[test]
    fn rendezvous_builds_a_working_world() {
        let np = 3;
        let rdv = TcpRendezvous::leader(np).unwrap();
        let boot = rdv.boot_addr();
        let workers: Vec<_> = (1..np)
            .map(|pid| {
                let boot = boot.clone();
                std::thread::spawn(move || TcpRendezvous::worker(pid, &boot).unwrap())
            })
            .collect();
        let leader = rdv.complete_leader().unwrap();
        let workers: Vec<TcpTransport> =
            workers.into_iter().map(|h| h.join().unwrap()).collect();
        for (i, w) in workers.iter().enumerate() {
            w.send(0, 1, &[w.pid() as u8; 4]).unwrap();
            assert_eq!(
                leader.recv_timeout(i + 1, 1, Duration::from_secs(5)).unwrap(),
                vec![(i + 1) as u8; 4]
            );
            leader.send(w.pid(), 2, b"ack").unwrap();
            assert_eq!(w.recv_timeout(0, 2, Duration::from_secs(5)).unwrap(), b"ack");
        }
    }
}
