//! Wire codec — hand-rolled little-endian encoding (no serde in the
//! offline environment; building the codec is part of the substrate).
//!
//! Framing: values are written in declaration order; variable-length
//! values carry a u64 length prefix. All multi-byte values are LE.
//!
//! Bulk array payloads are **typed**: [`WireWriter::put_slice`] /
//! [`WireReader::get_slice_into`] work for any [`Element`] and frame
//! the payload as `[count: u64][dtype code: u8][count × WIDTH bytes]`.
//! The dtype byte makes payloads self-describing, so a receiver
//! decoding at the wrong type gets a loud [`CommError::Malformed`]
//! instead of silently reinterpreted bits — the contract the generic
//! remap engine relies on. The legacy `put_f64_slice` family is a
//! thin wrapper over the typed calls.
//!
//! Payload bytes move through the [`Element`] **bulk codec**
//! (`copy_to_le` / `copy_from_le`): on little-endian targets a slice
//! encodes and decodes as one memcpy — no per-element loop anywhere on
//! the hot path. The gather/scatter variants
//! ([`WireWriter::put_slice_gather`] /
//! [`WireReader::get_slice_scatter`]) extend the same framing to
//! non-contiguous piece lists, which is how the remap engine packs one
//! coalesced message per peer without an intermediate staging copy.

use super::{CommError, Result};
use crate::element::{Dtype, Element};

/// Append-only wire writer.
#[derive(Default)]
pub struct WireWriter {
    buf: Vec<u8>,
}

impl WireWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(cap: usize) -> Self {
        WireWriter { buf: Vec::with_capacity(cap) }
    }

    /// Build a writer over an existing allocation (cleared first) —
    /// how pooled wire buffers ([`crate::comm::BufferPool`]) are
    /// reused without reallocating.
    pub fn from_vec(mut buf: Vec<u8>) -> Self {
        buf.clear();
        WireWriter { buf }
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    pub fn put_bool(&mut self, v: bool) {
        self.put_u8(u8::from(v));
    }

    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_u64(v.len() as u64);
        self.buf.extend_from_slice(v);
    }

    pub fn put_str(&mut self, v: &str) {
        self.put_bytes(v.as_bytes());
    }

    /// Bulk typed slice — the hot payload type (vector fragments).
    /// Framing: count, dtype code, then `count × T::WIDTH` LE bytes,
    /// encoded by the bulk codec (one memcpy on LE targets).
    pub fn put_slice<T: Element>(&mut self, v: &[T]) {
        self.put_u64(v.len() as u64);
        self.put_u8(T::DTYPE.code());
        self.buf.reserve(v.len() * T::WIDTH);
        T::copy_to_le(v, &mut self.buf);
    }

    /// Coalesced typed payload: frame `Σ len` elements as one slice,
    /// gathered from `segs = (offset, len)` pieces of `src` in order —
    /// the per-peer remap message body, packed without any
    /// intermediate staging buffer. (The iterator is walked twice —
    /// once for the count, once to gather — hence `Clone`.)
    pub fn put_slice_gather<T: Element>(
        &mut self,
        src: &[T],
        segs: impl Iterator<Item = (usize, usize)> + Clone,
    ) {
        let total: usize = segs.clone().map(|(_, len)| len).sum();
        self.put_u64(total as u64);
        self.put_u8(T::DTYPE.code());
        self.buf.reserve(total * T::WIDTH);
        for (off, len) in segs {
            T::copy_to_le(&src[off..off + len], &mut self.buf);
        }
    }

    /// Bulk f64 slice (compat wrapper over [`WireWriter::put_slice`]).
    pub fn put_f64_slice(&mut self, v: &[f64]) {
        self.put_slice::<f64>(v);
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

/// Cursor-based wire reader.
pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        WireReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(CommError::Malformed(format!(
                "need {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_usize(&mut self) -> Result<usize> {
        Ok(self.get_u64()? as usize)
    }

    pub fn get_bool(&mut self) -> Result<bool> {
        Ok(self.get_u8()? != 0)
    }

    pub fn get_bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.get_usize()?;
        self.take(n)
    }

    pub fn get_str(&mut self) -> Result<String> {
        let b = self.get_bytes()?;
        String::from_utf8(b.to_vec())
            .map_err(|e| CommError::Malformed(format!("bad utf8: {e}")))
    }

    /// Take exactly `n` raw bytes (no length prefix) — the payload
    /// region after a slice header, for callers that scatter it
    /// themselves (the chunked backend's parallel unpack).
    pub(crate) fn take_raw(&mut self, n: usize) -> Result<&'a [u8]> {
        self.take(n)
    }

    /// Read the `[count][dtype]` slice header, checking the dtype code
    /// against `T` (payload self-description).
    pub(crate) fn slice_header<T: Element>(&mut self) -> Result<usize> {
        let n = self.get_usize()?;
        let code = self.get_u8()?;
        match Dtype::from_code(code) {
            Some(d) if d == T::DTYPE => Ok(n),
            Some(d) => Err(CommError::Malformed(format!(
                "dtype mismatch: payload is {d}, reader expects {}",
                T::DTYPE
            ))),
            None => Err(CommError::Malformed(format!("unknown dtype code {code}"))),
        }
    }

    /// Decode a typed slice into a fresh vector.
    pub fn get_vec<T: Element>(&mut self) -> Result<Vec<T>> {
        let n = self.slice_header::<T>()?;
        let bytes = self.take(n * T::WIDTH)?;
        let mut out = vec![T::ZERO; n];
        T::copy_from_le(bytes, &mut out);
        Ok(out)
    }

    /// Decode a typed slice directly into `dst` (remap hot path — no
    /// intermediate allocation, bulk-decoded in one memcpy on LE
    /// targets).
    pub fn get_slice_into<T: Element>(&mut self, dst: &mut [T]) -> Result<()> {
        let n = self.slice_header::<T>()?;
        if n != dst.len() {
            return Err(CommError::Malformed(format!(
                "{} slice length {n} != destination {}",
                T::DTYPE,
                dst.len()
            )));
        }
        let bytes = self.take(n * T::WIDTH)?;
        T::copy_from_le(bytes, dst);
        Ok(())
    }

    /// Coalesced counterpart of [`WireReader::get_slice_into`]: decode
    /// one typed slice and scatter it into `dst` at `segs = (offset,
    /// len)` pieces in order. The framed element count must equal
    /// `Σ len` exactly.
    pub fn get_slice_scatter<T: Element>(
        &mut self,
        dst: &mut [T],
        segs: impl IntoIterator<Item = (usize, usize)>,
    ) -> Result<()> {
        let n = self.slice_header::<T>()?;
        let mut scattered = 0usize;
        for (off, len) in segs {
            let bytes = self.take(len * T::WIDTH)?;
            T::copy_from_le(bytes, &mut dst[off..off + len]);
            scattered += len;
        }
        if scattered != n {
            return Err(CommError::Malformed(format!(
                "{} scatter consumed {scattered} of {n} framed elements",
                T::DTYPE
            )));
        }
        Ok(())
    }

    /// Compat wrapper over [`WireReader::get_vec`].
    pub fn get_f64_vec(&mut self) -> Result<Vec<f64>> {
        self.get_vec::<f64>()
    }

    /// Compat wrapper over [`WireReader::get_slice_into`].
    pub fn get_f64_into(&mut self, dst: &mut [f64]) -> Result<()> {
        self.get_slice_into::<f64>(dst)
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// Types that can serialize themselves onto the wire.
pub trait Encode {
    fn encode(&self, w: &mut WireWriter);

    fn to_bytes(&self) -> Vec<u8> {
        let mut w = WireWriter::new();
        self.encode(&mut w);
        w.finish()
    }
}

/// Types that can deserialize themselves from the wire.
pub trait Decode: Sized {
    fn decode(r: &mut WireReader) -> Result<Self>;

    fn from_bytes(b: &[u8]) -> Result<Self> {
        Self::decode(&mut WireReader::new(b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut w = WireWriter::new();
        w.put_u8(7);
        w.put_u64(u64::MAX - 1);
        w.put_i64(-42);
        w.put_f64(std::f64::consts::PI);
        w.put_bool(true);
        w.put_str("stream");
        let buf = w.finish();
        let mut r = WireReader::new(&buf);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.get_i64().unwrap(), -42);
        assert_eq!(r.get_f64().unwrap(), std::f64::consts::PI);
        assert!(r.get_bool().unwrap());
        assert_eq!(r.get_str().unwrap(), "stream");
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn f64_slice_roundtrip() {
        let v: Vec<f64> = (0..1000).map(|i| i as f64 * 0.5).collect();
        let mut w = WireWriter::new();
        w.put_f64_slice(&v);
        let buf = w.finish();
        let mut r = WireReader::new(&buf);
        assert_eq!(r.get_f64_vec().unwrap(), v);
    }

    #[test]
    fn f64_into_checks_length() {
        let mut w = WireWriter::new();
        w.put_f64_slice(&[1.0, 2.0]);
        let buf = w.finish();
        let mut dst = [0.0; 3];
        assert!(WireReader::new(&buf).get_f64_into(&mut dst).is_err());
        let mut dst = [0.0; 2];
        WireReader::new(&buf).get_f64_into(&mut dst).unwrap();
        assert_eq!(dst, [1.0, 2.0]);
    }

    #[test]
    fn typed_slices_roundtrip_all_dtypes() {
        let mut w = WireWriter::new();
        w.put_slice::<f32>(&[1.5, -2.5, 0.0]);
        w.put_slice::<i64>(&[i64::MIN, -1, i64::MAX]);
        w.put_slice::<u64>(&[0, u64::MAX]);
        let buf = w.finish();
        let mut r = WireReader::new(&buf);
        assert_eq!(r.get_vec::<f32>().unwrap(), vec![1.5, -2.5, 0.0]);
        assert_eq!(r.get_vec::<i64>().unwrap(), vec![i64::MIN, -1, i64::MAX]);
        let mut dst = [0u64; 2];
        r.get_slice_into::<u64>(&mut dst).unwrap();
        assert_eq!(dst, [0, u64::MAX]);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn dtype_mismatch_is_loud_error() {
        // An f32 payload read as f64 must error, not reinterpret bits.
        let mut w = WireWriter::new();
        w.put_slice::<f32>(&[1.0, 2.0]);
        let buf = w.finish();
        let mut dst = [0.0f64; 2];
        let err = WireReader::new(&buf).get_slice_into::<f64>(&mut dst);
        assert!(matches!(err, Err(CommError::Malformed(_))), "{err:?}");
        assert!(WireReader::new(&buf).get_vec::<i64>().is_err());
    }

    /// Acceptance criterion: a 1M-element f64 payload goes through the
    /// codec's bulk path (one memcpy each way on LE targets — the
    /// `Element::copy_to_le`/`copy_from_le` hooks) and round-trips
    /// bit-exactly through `put_slice`/`get_slice_into`.
    #[test]
    fn one_million_f64_roundtrip_uses_bulk_path() {
        let n = 1 << 20;
        let v: Vec<f64> = (0..n).map(|i| i as f64 * 0.25 - 3.0).collect();
        let mut w = WireWriter::with_capacity(9 + 8 * n);
        w.put_slice::<f64>(&v);
        let buf = w.finish();
        assert_eq!(buf.len(), 9 + 8 * n);
        let mut dst = vec![0.0f64; n];
        WireReader::new(&buf).get_slice_into::<f64>(&mut dst).unwrap();
        assert_eq!(dst, v);
    }

    #[test]
    fn gather_scatter_roundtrip_is_bit_identical_to_contiguous() {
        // Gathering pieces must frame exactly like a contiguous slice
        // of the same elements.
        let src: Vec<f64> = (0..100).map(|i| i as f64 * 0.25).collect();
        let segs = [(10usize, 5usize), (0, 3), (90, 10)];
        let gathered: Vec<f64> = segs
            .iter()
            .flat_map(|&(off, len)| src[off..off + len].iter().copied())
            .collect();
        let mut wa = WireWriter::new();
        wa.put_slice_gather::<f64>(&src, segs.iter().copied());
        let mut wb = WireWriter::new();
        wb.put_slice::<f64>(&gathered);
        assert_eq!(wa.finish(), wb.finish());

        // Scatter back into a differently-laid-out destination.
        let mut w = WireWriter::new();
        w.put_slice_gather::<f64>(&src, segs.iter().copied());
        let buf = w.finish();
        let dsegs = [(2usize, 5usize), (20, 3), (40, 10)];
        let mut dst = vec![0.0f64; 64];
        WireReader::new(&buf)
            .get_slice_scatter::<f64>(&mut dst, dsegs.iter().copied())
            .unwrap();
        assert_eq!(&dst[2..7], &src[10..15]);
        assert_eq!(&dst[20..23], &src[0..3]);
        assert_eq!(&dst[40..50], &src[90..100]);
    }

    #[test]
    fn scatter_length_mismatch_is_error() {
        let mut w = WireWriter::new();
        w.put_slice::<i64>(&[1, 2, 3, 4]);
        let buf = w.finish();
        let mut dst = [0i64; 8];
        // Fewer scattered elements than framed → loud error.
        let err = WireReader::new(&buf).get_slice_scatter::<i64>(&mut dst, [(0usize, 3usize)]);
        assert!(matches!(err, Err(CommError::Malformed(_))), "{err:?}");
        // Too many → runs off the payload, also an error.
        let err = WireReader::new(&buf).get_slice_scatter::<i64>(&mut dst, [(0usize, 6usize)]);
        assert!(err.is_err());
    }

    #[test]
    fn from_vec_reuses_and_clears() {
        let mut w = WireWriter::new();
        w.put_u64(7);
        let buf = w.finish();
        let cap = buf.capacity();
        let mut w2 = WireWriter::from_vec(buf);
        assert!(w2.is_empty());
        w2.put_u64(9);
        let out = w2.finish();
        assert!(out.capacity() >= cap.min(8));
        assert_eq!(WireReader::new(&out).get_u64().unwrap(), 9);
    }

    #[test]
    fn truncated_buffer_is_error_not_panic() {
        let mut w = WireWriter::new();
        w.put_u64(5);
        let buf = w.finish();
        let mut r = WireReader::new(&buf[..4]);
        assert!(r.get_u64().is_err());
    }
}
