//! Asynchronous **file-based messaging** — the paper's aggregation
//! transport (§V; Byun et al., "Large scale parallelization using
//! file-based communications", HPEC 2019 [44]).
//!
//! Protocol (MatlabMPI-lineage):
//! * A message from `f` to `t` with tag `g` and sequence `s` is the
//!   file `spool/msg_f{f}_t{t}_g{g}_s{s}.bin`.
//! * The sender writes to a `.tmp` name and **atomically renames** —
//!   a reader never observes a partial message. Multi-part sends
//!   ([`crate::comm::Transport::send_parts`]) stream framing and
//!   payload into the spool file sequentially, so a coalesced remap
//!   message never exists as a concatenated copy in memory.
//! * The receiver polls for the next sequence number it expects for
//!   each (from, tag) pair and deletes the file after consuming it.
//!   Polling backs off exponentially from `poll` up to `poll_cap`, so
//!   a slow peer costs O(log wait) syscalls instead of a fixed-rate
//!   stat storm; [`FileTransport::with_poll`] pins both to one tight
//!   interval (the test hook).
//!
//! No daemon, no sockets: works across OS processes sharing a
//! filesystem, exactly like the paper's SuperCloud deployment (there,
//! a Lustre mount; here, a local spool directory).

use super::counter::CommStats;
use super::{CommError, Result, Tag, Transport, TransportKind};
use crate::dmap::Pid;
use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// File-based transport endpoint for one PID.
pub struct FileTransport {
    dir: PathBuf,
    pid: Pid,
    np: usize,
    stats: CommStats,
    /// Next sequence number per (to, tag) for sends.
    send_seq: Mutex<HashMap<(Pid, Tag), u64>>,
    /// Next expected sequence per (from, tag) for receives.
    recv_seq: Mutex<HashMap<(Pid, Tag), u64>>,
    /// Initial poll interval while waiting for a message file.
    poll: Duration,
    /// Upper bound of the exponential poll backoff.
    poll_cap: Duration,
    unique: AtomicU64,
}

impl FileTransport {
    /// Open (and create) a spool directory endpoint.
    pub fn new(dir: impl AsRef<Path>, pid: Pid, np: usize) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        fs::create_dir_all(&dir)?;
        Ok(FileTransport {
            dir,
            pid,
            np,
            stats: CommStats::new(),
            send_seq: Mutex::new(HashMap::new()),
            recv_seq: Mutex::new(HashMap::new()),
            poll: Duration::from_micros(200),
            poll_cap: Duration::from_millis(10),
            unique: AtomicU64::new(0),
        })
    }

    /// Pin the receive poll to one fixed interval — no backoff (tests
    /// use a tight poll to keep latencies deterministic).
    pub fn with_poll(mut self, poll: Duration) -> Self {
        self.poll = poll;
        self.poll_cap = poll;
        self
    }

    /// Explicit backoff window: polls start at `initial` and double up
    /// to `cap`.
    pub fn with_poll_backoff(mut self, initial: Duration, cap: Duration) -> Self {
        self.poll = initial;
        self.poll_cap = cap.max(initial);
        self
    }

    fn msg_path(&self, from: Pid, to: Pid, tag: Tag, seq: u64) -> PathBuf {
        self.dir.join(format!("msg_f{from}_t{to}_g{tag:x}_s{seq}.bin"))
    }

    /// Spool directory for inspection.
    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

impl Transport for FileTransport {
    fn pid(&self) -> Pid {
        self.pid
    }

    fn kind(&self) -> Option<TransportKind> {
        Some(TransportKind::File)
    }

    fn np(&self) -> usize {
        self.np
    }

    fn send(&self, to: Pid, tag: Tag, payload: &[u8]) -> Result<()> {
        self.send_parts(to, tag, &[payload])
    }

    /// Multi-part send: framing + payload parts are written to the
    /// spool file **sequentially** — the message is never materialized
    /// as one concatenated buffer in memory.
    fn send_parts(&self, to: Pid, tag: Tag, parts: &[&[u8]]) -> Result<()> {
        if to >= self.np {
            return Err(CommError::Disconnected(to));
        }
        let seq = {
            let mut m = self.send_seq.lock().unwrap();
            let e = m.entry((to, tag)).or_insert(0);
            let s = *e;
            *e += 1;
            s
        };
        let final_path = self.msg_path(self.pid, to, tag, seq);
        // Unique tmp name: two threads of one endpoint must not collide.
        let unique = self.unique.fetch_add(1, Ordering::Relaxed);
        let tmp = self
            .dir
            .join(format!(".tmp_f{}_u{}_{}", self.pid, unique, std::process::id()));
        let mut total = 0usize;
        {
            use std::io::Write;
            let mut f = fs::File::create(&tmp)?;
            for p in parts {
                f.write_all(p)?;
                total += p.len();
            }
        }
        fs::rename(&tmp, &final_path)?; // atomic publish
        self.stats.record_send(total);
        Ok(())
    }

    fn recv_timeout(&self, from: Pid, tag: Tag, timeout: Duration) -> Result<Vec<u8>> {
        let seq = {
            let mut m = self.recv_seq.lock().unwrap();
            let e = m.entry((from, tag)).or_insert(0);
            let s = *e;
            *e += 1;
            s
        };
        let path = self.msg_path(from, self.pid, tag, seq);
        let deadline = Instant::now() + timeout;
        let mut delay = self.poll;
        loop {
            match fs::read(&path) {
                Ok(payload) => {
                    let _ = fs::remove_file(&path);
                    self.stats.record_recv(payload.len());
                    return Ok(payload);
                }
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                    let now = Instant::now();
                    if now >= deadline {
                        // Roll back the sequence reservation so a retry
                        // looks for the same message again.
                        let mut m = self.recv_seq.lock().unwrap();
                        if let Some(e) = m.get_mut(&(from, tag)) {
                            *e = seq;
                        }
                        return Err(CommError::timeout(from, tag));
                    }
                    // Exponential backoff (capped, never past the
                    // deadline): slow peers cost O(log wait) stats
                    // instead of a fixed 200 µs poll storm.
                    std::thread::sleep(delay.min(deadline - now));
                    delay = (delay * 2).min(self.poll_cap);
                }
                Err(e) => return Err(CommError::Io(e)),
            }
        }
    }

    fn stats(&self) -> &CommStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("distarray_fmsg_{name}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn roundtrip_same_process() {
        let dir = tmpdir("rt");
        let a = FileTransport::new(&dir, 0, 2).unwrap();
        let b = FileTransport::new(&dir, 1, 2).unwrap();
        a.send(1, 3, b"payload").unwrap();
        assert_eq!(b.recv(0, 3).unwrap(), b"payload");
        // consumed: file removed
        assert_eq!(fs::read_dir(&dir).unwrap().count(), 0);
    }

    #[test]
    fn ordering_preserved() {
        let dir = tmpdir("ord");
        let a = FileTransport::new(&dir, 0, 2).unwrap();
        let b = FileTransport::new(&dir, 1, 2).unwrap();
        for i in 0u8..5 {
            a.send(1, 1, &[i]).unwrap();
        }
        for i in 0u8..5 {
            assert_eq!(b.recv(0, 1).unwrap(), vec![i]);
        }
    }

    #[test]
    fn timeout_then_retry_succeeds() {
        let dir = tmpdir("to");
        let a = FileTransport::new(&dir, 0, 2).unwrap();
        let b = FileTransport::new(&dir, 1, 2).unwrap().with_poll(Duration::from_micros(50));
        assert!(b
            .recv_timeout(0, 9, Duration::from_millis(10))
            .is_err());
        a.send(1, 9, b"late").unwrap();
        // After a timeout the same message must still be receivable.
        assert_eq!(b.recv(0, 9).unwrap(), b"late");
    }

    #[test]
    fn concurrent_reader_sees_complete_message() {
        let dir = tmpdir("conc");
        let a = FileTransport::new(&dir, 0, 2).unwrap();
        let big: Vec<u8> = (0..1_000_000u32).map(|i| (i % 251) as u8).collect();
        let big2 = big.clone();
        let dir2 = dir.clone();
        let reader = thread::spawn(move || {
            let b = FileTransport::new(&dir2, 1, 2)
                .unwrap()
                .with_poll(Duration::from_micros(10));
            b.recv(0, 2).unwrap()
        });
        thread::sleep(Duration::from_millis(5));
        a.send(1, 2, &big).unwrap();
        let got = reader.join().unwrap();
        assert_eq!(got, big2); // atomic rename ⇒ never a partial read
    }

    #[test]
    fn send_parts_arrives_as_one_contiguous_message() {
        let dir = tmpdir("parts");
        let a = FileTransport::new(&dir, 0, 2).unwrap();
        let b = FileTransport::new(&dir, 1, 2).unwrap();
        a.send_parts(1, 4, &[b"head", b"", b"payload"]).unwrap();
        assert_eq!(b.recv(0, 4).unwrap(), b"headpayload");
        // One message, stats count the total bytes once.
        assert_eq!(a.stats().msgs_sent(), 1);
        assert_eq!(a.stats().bytes_sent(), 11);
        // Ordered with plain sends on the same (to, tag) stream.
        a.send(1, 4, b"x").unwrap();
        a.send_parts(1, 4, &[b"y", b"z"]).unwrap();
        assert_eq!(b.recv(0, 4).unwrap(), b"x");
        assert_eq!(b.recv(0, 4).unwrap(), b"yz");
    }

    #[test]
    fn try_recv_is_nonblocking_and_preserves_order() {
        let dir = tmpdir("tryrecv");
        let a = FileTransport::new(&dir, 0, 2).unwrap();
        let b = FileTransport::new(&dir, 1, 2).unwrap();
        assert_eq!(b.try_recv(0, 7).unwrap(), None);
        a.send(1, 7, b"first").unwrap();
        a.send(1, 7, b"second").unwrap();
        // A miss must not consume the sequence slot.
        assert_eq!(b.try_recv(0, 7).unwrap().as_deref(), Some(&b"first"[..]));
        assert_eq!(b.recv(0, 7).unwrap(), b"second");
    }

    #[test]
    fn backoff_recv_still_sees_late_messages_and_times_out() {
        let dir = tmpdir("backoff");
        let b = FileTransport::new(&dir, 1, 2)
            .unwrap()
            .with_poll_backoff(Duration::from_micros(10), Duration::from_millis(2));
        let start = Instant::now();
        assert!(b.recv_timeout(0, 5, Duration::from_millis(20)).is_err());
        // The capped backoff must not overshoot the deadline wildly.
        assert!(start.elapsed() < Duration::from_millis(500));
        let dir2 = dir.clone();
        let sender = thread::spawn(move || {
            thread::sleep(Duration::from_millis(15));
            let a = FileTransport::new(&dir2, 0, 2).unwrap();
            a.send(1, 6, b"late").unwrap();
        });
        assert_eq!(b.recv(0, 6).unwrap(), b"late");
        sender.join().unwrap();
    }

    #[test]
    fn distinct_pairs_do_not_interfere() {
        let dir = tmpdir("pairs");
        let a = FileTransport::new(&dir, 0, 3).unwrap();
        let b = FileTransport::new(&dir, 1, 3).unwrap();
        let c = FileTransport::new(&dir, 2, 3).unwrap();
        a.send(2, 1, b"from0").unwrap();
        b.send(2, 1, b"from1").unwrap();
        assert_eq!(c.recv(1, 1).unwrap(), b"from1");
        assert_eq!(c.recv(0, 1).unwrap(), b"from0");
    }
}
