//! Communication statistics — the instrument behind the paper's
//! "Bounded communication" property (§IV): the same-map STREAM run
//! must show **zero** messages, and tests assert it.

use std::sync::atomic::{AtomicU64, Ordering};

/// Lock-free per-endpoint send/recv counters.
#[derive(Debug)]
pub struct CommStats {
    msgs_sent: AtomicU64,
    bytes_sent: AtomicU64,
    msgs_recv: AtomicU64,
    bytes_recv: AtomicU64,
}

impl Default for CommStats {
    fn default() -> Self {
        Self::new()
    }
}

impl CommStats {
    /// `const` so a counter can live in a `static` (the datapath's
    /// process-wide stream totals).
    pub const fn new() -> Self {
        CommStats {
            msgs_sent: AtomicU64::new(0),
            bytes_sent: AtomicU64::new(0),
            msgs_recv: AtomicU64::new(0),
            bytes_recv: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn record_send(&self, bytes: usize) {
        self.msgs_sent.fetch_add(1, Ordering::Relaxed);
        self.bytes_sent.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    #[inline]
    pub fn record_recv(&self, bytes: usize) {
        self.msgs_recv.fetch_add(1, Ordering::Relaxed);
        self.bytes_recv.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    pub fn msgs_sent(&self) -> u64 {
        self.msgs_sent.load(Ordering::Relaxed)
    }

    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent.load(Ordering::Relaxed)
    }

    pub fn msgs_recv(&self) -> u64 {
        self.msgs_recv.load(Ordering::Relaxed)
    }

    pub fn bytes_recv(&self) -> u64 {
        self.bytes_recv.load(Ordering::Relaxed)
    }

    /// True iff no traffic at all has passed this endpoint.
    pub fn is_silent(&self) -> bool {
        self.msgs_sent() == 0 && self.msgs_recv() == 0
    }

    /// Snapshot (sent msgs, sent bytes, recv msgs, recv bytes).
    pub fn snapshot(&self) -> (u64, u64, u64, u64) {
        (
            self.msgs_sent(),
            self.bytes_sent(),
            self.msgs_recv(),
            self.bytes_recv(),
        )
    }

    pub fn reset(&self) {
        self.msgs_sent.store(0, Ordering::Relaxed);
        self.bytes_sent.store(0, Ordering::Relaxed);
        self.msgs_recv.store(0, Ordering::Relaxed);
        self.bytes_recv.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_accumulate() {
        let s = CommStats::new();
        assert!(s.is_silent());
        s.record_send(100);
        s.record_send(50);
        s.record_recv(100);
        assert_eq!(s.msgs_sent(), 2);
        assert_eq!(s.bytes_sent(), 150);
        assert_eq!(s.msgs_recv(), 1);
        assert!(!s.is_silent());
        s.reset();
        assert!(s.is_silent());
    }
}
