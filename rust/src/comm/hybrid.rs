//! Hybrid transport: shared-memory rings inside a node, framed TCP
//! across nodes.
//!
//! [`HybridTransport`] owns one [`ShmemTransport`] and one
//! [`TcpTransport`] endpoint and routes every message by the
//! [`Topology`]'s node map — the transport-level mirror of the
//! two-level `hier` collective split (intra-node exchange over the
//! fast path, node leaders over the wire). Both inner endpoints keep
//! their own [`CommStats`]; the hybrid's own counter sees the union,
//! so per-route byte counts stay inspectable via
//! [`HybridTransport::shmem_stats`] / [`HybridTransport::tcp_stats`].
//!
//! TCP connections are dialed lazily, so ranks that never talk past
//! their node (everything but the node leaders under `hier`
//! collectives) never open a socket.

use super::{
    CommError, CommStats, Result, ShmemTransport, Tag, TcpTransport, Transport, TransportKind,
};
use crate::collective::Topology;
use crate::dmap::Pid;
use std::time::Duration;

/// Topology-routed composite of shmem and TCP endpoints for one PID.
pub struct HybridTransport {
    shmem: ShmemTransport,
    tcp: TcpTransport,
    topo: Topology,
    stats: CommStats,
}

impl HybridTransport {
    /// Compose two endpoints of the **same** pid/world with the node
    /// map that decides the route.
    pub fn new(shmem: ShmemTransport, tcp: TcpTransport, topo: Topology) -> HybridTransport {
        assert_eq!(shmem.pid(), tcp.pid(), "inner endpoints must agree on pid");
        assert_eq!(shmem.np(), tcp.np(), "inner endpoints must agree on np");
        assert_eq!(topo.np(), shmem.np(), "topology must cover the world");
        HybridTransport { shmem, tcp, topo, stats: CommStats::new() }
    }

    /// An in-process world: shmem rings under `dir`, TCP over
    /// loopback, nodes of `per_node` consecutive pids — tests and the
    /// transport microbench.
    pub fn world(
        dir: &std::path::Path,
        np: usize,
        per_node: usize,
    ) -> std::io::Result<Vec<HybridTransport>> {
        let shmems = ShmemTransport::world(dir, np)?;
        let tcps = super::TcpRendezvous::loopback_world(np)?;
        let topo = Topology::grouped(np, per_node);
        Ok(shmems
            .into_iter()
            .zip(tcps)
            .map(|(s, t)| HybridTransport::new(s, t, topo.clone()))
            .collect())
    }

    /// Is `peer` on this endpoint's node?
    fn same_node(&self, peer: Pid) -> bool {
        match (self.topo.node_of(self.shmem.pid()), self.topo.node_of(peer)) {
            (Some(a), Some(b)) => a == b,
            _ => false,
        }
    }

    /// The inner endpoint carrying traffic with `peer`.
    fn route(&self, peer: Pid) -> &dyn Transport {
        if self.same_node(peer) {
            &self.shmem
        } else {
            &self.tcp
        }
    }

    /// The intra-node route's counters.
    pub fn shmem_stats(&self) -> &CommStats {
        self.shmem.stats()
    }

    /// The cross-node route's counters.
    pub fn tcp_stats(&self) -> &CommStats {
        self.tcp.stats()
    }
}

impl Transport for HybridTransport {
    fn pid(&self) -> Pid {
        self.shmem.pid()
    }

    fn np(&self) -> usize {
        self.shmem.np()
    }

    fn send(&self, to: Pid, tag: Tag, payload: &[u8]) -> Result<()> {
        if to >= self.np() {
            return Err(CommError::Disconnected(to));
        }
        self.route(to).send(to, tag, payload)?;
        self.stats.record_send(payload.len());
        Ok(())
    }

    fn send_parts(&self, to: Pid, tag: Tag, parts: &[&[u8]]) -> Result<()> {
        if to >= self.np() {
            return Err(CommError::Disconnected(to));
        }
        self.route(to).send_parts(to, tag, parts)?;
        self.stats.record_send(parts.iter().map(|p| p.len()).sum());
        Ok(())
    }

    fn recv_timeout(&self, from: Pid, tag: Tag, timeout: Duration) -> Result<Vec<u8>> {
        if from >= self.np() {
            return Err(CommError::Disconnected(from));
        }
        let msg = self.route(from).recv_timeout(from, tag, timeout)?;
        self.stats.record_recv(msg.len());
        Ok(msg)
    }

    fn stats(&self) -> &CommStats {
        &self.stats
    }

    fn kind(&self) -> Option<TransportKind> {
        Some(TransportKind::Hybrid)
    }

    /// Per-peer attribution: the route actually taken, so trace
    /// events distinguish shmem hops from TCP hops inside one run.
    fn kind_to(&self, to: Pid) -> Option<TransportKind> {
        if to < self.np() && self.same_node(to) {
            Some(TransportKind::Shmem)
        } else if to < self.np() {
            Some(TransportKind::Tcp)
        } else {
            Some(TransportKind::Hybrid)
        }
    }
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn scratch(label: &str) -> PathBuf {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let d = std::env::temp_dir().join(format!(
            "distarray_hybrid_{label}_{}_{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    /// 2 nodes × 2 pids: 0↔1 and 2↔3 ride shmem, 0↔2 rides TCP, and
    /// the attribution reports the route taken.
    #[test]
    fn routes_by_node_and_attributes_the_route() {
        let dir = scratch("route");
        let world = HybridTransport::world(&dir, 4, 2).unwrap();
        assert_eq!(world[0].kind_to(1), Some(TransportKind::Shmem));
        assert_eq!(world[0].kind_to(2), Some(TransportKind::Tcp));
        assert_eq!(world[2].kind_to(3), Some(TransportKind::Shmem));
        assert_eq!(world[3].kind_to(0), Some(TransportKind::Tcp));

        world[0].send(1, 1, b"intra").unwrap();
        assert_eq!(world[1].recv(0, 1).unwrap(), b"intra");
        world[0].send(2, 1, b"inter").unwrap();
        assert_eq!(world[2].recv(0, 1).unwrap(), b"inter");

        // Per-route counters: pid 0 sent one message each way.
        assert_eq!(world[0].shmem_stats().msgs_sent(), 1);
        assert_eq!(world[0].tcp_stats().msgs_sent(), 1);
        assert_eq!(world[0].stats().msgs_sent(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn send_parts_routes_like_send() {
        let dir = scratch("parts");
        let world = HybridTransport::world(&dir, 4, 2).unwrap();
        world[1].send_parts(0, 2, &[b"a", b"bc"]).unwrap();
        world[1].send_parts(3, 2, &[b"x", b"yz"]).unwrap();
        assert_eq!(world[0].recv(1, 2).unwrap(), b"abc");
        assert_eq!(world[3].recv(1, 2).unwrap(), b"xyz");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn out_of_world_peers_are_disconnected() {
        let dir = scratch("oow");
        let world = HybridTransport::world(&dir, 2, 1).unwrap();
        assert!(matches!(world[0].send(9, 1, b"x"), Err(CommError::Disconnected(9))));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
