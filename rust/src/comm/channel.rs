//! In-process transport: one mailbox per PID, shared hub.
//!
//! Used by tests and by single-process multi-worker runs (each PID a
//! thread). Matching is by (from, tag) with per-pair FIFO ordering —
//! the same semantics the file transport provides across processes.

use super::counter::CommStats;
use super::{CommError, Result, Tag, Transport, TransportKind};
use crate::dmap::Pid;
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

type MailKey = (Pid, Tag); // (from, tag)

#[derive(Default)]
struct Mailbox {
    queues: HashMap<MailKey, VecDeque<Vec<u8>>>,
}

struct Slot {
    mbox: Mutex<Mailbox>,
    cv: Condvar,
}

/// Shared state connecting all endpoints of one world.
pub struct ChannelHub {
    slots: Vec<Arc<Slot>>,
}

impl ChannelHub {
    /// Create a world of `np` connected endpoints.
    pub fn world(np: usize) -> Vec<ChannelTransport> {
        assert!(np >= 1);
        let slots: Vec<Arc<Slot>> = (0..np)
            .map(|_| {
                Arc::new(Slot {
                    mbox: Mutex::new(Mailbox::default()),
                    cv: Condvar::new(),
                })
            })
            .collect();
        let hub = Arc::new(ChannelHub { slots });
        (0..np)
            .map(|pid| ChannelTransport {
                hub: hub.clone(),
                pid,
                np,
                stats: CommStats::new(),
            })
            .collect()
    }
}

/// One PID's endpoint of a [`ChannelHub`] world.
pub struct ChannelTransport {
    hub: Arc<ChannelHub>,
    pid: Pid,
    np: usize,
    stats: CommStats,
}

impl Transport for ChannelTransport {
    fn pid(&self) -> Pid {
        self.pid
    }

    fn np(&self) -> usize {
        self.np
    }

    fn kind(&self) -> Option<TransportKind> {
        Some(TransportKind::Channel)
    }

    fn send(&self, to: Pid, tag: Tag, payload: &[u8]) -> Result<()> {
        self.send_parts(to, tag, &[payload])
    }

    /// Multi-part send: the parts are gathered once, directly into the
    /// mailbox message (one copy total — the default trait impl would
    /// concatenate and then copy again through `send`).
    fn send_parts(&self, to: Pid, tag: Tag, parts: &[&[u8]]) -> Result<()> {
        if to >= self.np {
            return Err(CommError::Disconnected(to));
        }
        let total = parts.iter().map(|p| p.len()).sum();
        let mut buf = Vec::with_capacity(total);
        for p in parts {
            buf.extend_from_slice(p);
        }
        let slot = &self.hub.slots[to];
        {
            let mut mbox = slot.mbox.lock().unwrap();
            mbox.queues.entry((self.pid, tag)).or_default().push_back(buf);
        }
        slot.cv.notify_all();
        self.stats.record_send(total);
        Ok(())
    }

    fn recv_timeout(&self, from: Pid, tag: Tag, timeout: Duration) -> Result<Vec<u8>> {
        let slot = &self.hub.slots[self.pid];
        let deadline = Instant::now() + timeout;
        let mut mbox = slot.mbox.lock().unwrap();
        loop {
            if let Some(q) = mbox.queues.get_mut(&(from, tag)) {
                if let Some(payload) = q.pop_front() {
                    self.stats.record_recv(payload.len());
                    return Ok(payload);
                }
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(CommError::timeout(from, tag));
            }
            let (guard, _t) = slot.cv.wait_timeout(mbox, deadline - now).unwrap();
            mbox = guard;
        }
    }

    fn stats(&self) -> &CommStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn send_recv_roundtrip() {
        let mut world = ChannelHub::world(2);
        let t1 = world.pop().unwrap();
        let t0 = world.pop().unwrap();
        t0.send(1, 7, b"hello").unwrap();
        assert_eq!(t1.recv(0, 7).unwrap(), b"hello");
        assert_eq!(t0.stats().msgs_sent(), 1);
        assert_eq!(t1.stats().msgs_recv(), 1);
    }

    #[test]
    fn fifo_per_pair() {
        let mut world = ChannelHub::world(2);
        let t1 = world.pop().unwrap();
        let t0 = world.pop().unwrap();
        for i in 0u8..10 {
            t0.send(1, 1, &[i]).unwrap();
        }
        for i in 0u8..10 {
            assert_eq!(t1.recv(0, 1).unwrap(), vec![i]);
        }
    }

    #[test]
    fn tags_do_not_cross() {
        let mut world = ChannelHub::world(2);
        let t1 = world.pop().unwrap();
        let t0 = world.pop().unwrap();
        t0.send(1, 1, b"one").unwrap();
        t0.send(1, 2, b"two").unwrap();
        assert_eq!(t1.recv(0, 2).unwrap(), b"two");
        assert_eq!(t1.recv(0, 1).unwrap(), b"one");
    }

    #[test]
    fn send_parts_and_try_recv() {
        let mut world = ChannelHub::world(2);
        let t1 = world.pop().unwrap();
        let t0 = world.pop().unwrap();
        assert_eq!(t1.try_recv(0, 4).unwrap(), None);
        t0.send_parts(1, 4, &[b"ab", b"", b"cd"]).unwrap();
        assert_eq!(t1.try_recv(0, 4).unwrap().as_deref(), Some(&b"abcd"[..]));
        assert_eq!(t1.try_recv(0, 4).unwrap(), None);
        assert_eq!(t0.stats().msgs_sent(), 1);
        assert_eq!(t0.stats().bytes_sent(), 4);
    }

    #[test]
    fn recv_timeout_fires() {
        let mut world = ChannelHub::world(2);
        let t1 = world.pop().unwrap();
        let _t0 = world.pop().unwrap();
        let err = t1.recv_timeout(0, 9, Duration::from_millis(20));
        assert!(matches!(err, Err(CommError::Timeout { .. })));
    }

    #[test]
    fn cross_thread_delivery() {
        let world = ChannelHub::world(4);
        let mut handles = Vec::new();
        for t in world {
            handles.push(thread::spawn(move || {
                let me = t.pid();
                let np = t.np();
                // Ring exchange: send to (me+1) % np, recv from (me+np-1) % np.
                t.send((me + 1) % np, 5, &[me as u8]).unwrap();
                let got = t.recv((me + np - 1) % np, 5).unwrap();
                assert_eq!(got, vec![((me + np - 1) % np) as u8]);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn send_to_invalid_pid_errors() {
        let mut world = ChannelHub::world(1);
        let t0 = world.pop().unwrap();
        assert!(matches!(t0.send(3, 0, b"x"), Err(CommError::Disconnected(3))));
    }
}
