//! Shared-memory transport: per-peer-pair mmap'd SPSC rings.
//!
//! The fast intra-node path. For every **ordered** peer pair `(from,
//! to)` there is one file in the spool directory (`ring_f{from}_
//! t{to}.shm`) holding a single-producer single-consumer byte ring:
//!
//! ```text
//! offset 0    head  (u64, consumer cursor; low 32 bits = futex word)
//! offset 64   tail  (u64, producer cursor; low 32 bits = futex word)
//! offset 128  data  (power-of-two capacity)
//! ```
//!
//! Cursors are **monotone byte counts**; `cursor & (cap-1)` is the
//! ring position and `tail - head` the bytes in flight, so an
//! all-zero file is a valid empty ring and both sides can create and
//! size it idempotently — no initialization handshake. The head and
//! tail live a cache line apart so producer and consumer never false-
//! share.
//!
//! A record is a 16-byte header `[len: u32][kind: u32][tag: u64]`
//! followed by the payload padded to 8 bytes. Records never straddle
//! the ring end: a producer that would wrap emits a skip marker
//! (`len == u32::MAX`) and continues at position 0. Payloads above a
//! quarter of the ring capacity spill to a one-shot file next to the
//! ring, referenced by a 16-byte `[spill_seq][len]` descriptor
//! record; the consumer reads and deletes it.
//!
//! Publication order is the usual SPSC contract: the producer writes
//! the record bytes, then release-stores the advanced tail; the
//! consumer acquire-loads the tail before reading. Blocking on empty
//! (receiver) and full (sender) uses `futex` wait/wake on the low 32
//! bits of the tail/head word on Linux, degrading to a bounded sleep
//! elsewhere. Waits are sliced ([`WAIT_SLICE`]) so a message that a
//! *sibling thread* drained into the shared mailbox is picked up
//! promptly even though the ring itself stays quiet.

#[cfg(unix)]
pub use imp::ShmemTransport;

#[cfg(unix)]
mod imp {
    use super::sys;
    use crate::comm::{
        default_recv_timeout, CommError, CommStats, Result, Tag, Transport, TransportKind,
    };
    use crate::dmap::Pid;
    use std::collections::{HashMap, VecDeque};
    use std::fs::OpenOptions;
    use std::io;
    use std::path::{Path, PathBuf};
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::{Mutex, OnceLock};
    use std::time::{Duration, Instant};

    /// Ring header bytes: head at 0, tail one cache line later.
    const RING_HDR: usize = 128;
    const HEAD_OFF: usize = 0;
    const TAIL_OFF: usize = 64;
    /// Record header bytes: `[len: u32][kind: u32][tag: u64]`.
    const REC_HDR: usize = 16;
    /// `len` value of a skip-to-ring-start marker.
    const LEN_WRAP: u32 = u32::MAX;
    /// Record kinds.
    const K_INLINE: u32 = 0;
    const K_SPILL: u32 = 1;
    /// Default / minimum ring data capacity.
    const DEFAULT_RING_BYTES: usize = 1 << 20;
    const MIN_RING_BYTES: usize = 4096;
    /// Upper bound of one blocking slice: caps the latency of
    /// cross-thread mailbox handoffs and of the no-futex fallback.
    const WAIT_SLICE: Duration = Duration::from_millis(2);

    #[inline]
    fn pad8(n: usize) -> usize {
        (n + 7) & !7
    }

    /// `DISTARRAY_SHMEM_RING_BYTES` parsed once per process (rounded
    /// up to a power of two, floored at [`MIN_RING_BYTES`]).
    fn ambient_ring_bytes() -> usize {
        static ENV: OnceLock<usize> = OnceLock::new();
        *ENV.get_or_init(|| {
            std::env::var("DISTARRAY_SHMEM_RING_BYTES")
                .ok()
                .and_then(|v| v.parse::<usize>().ok())
                .filter(|&b| b > 0)
                .map(|b| b.next_power_of_two().max(MIN_RING_BYTES))
                .unwrap_or(DEFAULT_RING_BYTES)
        })
    }

    fn ring_path(dir: &Path, from: Pid, to: Pid) -> PathBuf {
        dir.join(format!("ring_f{from}_t{to}.shm"))
    }

    /// One mapped ring file.
    struct Ring {
        map: sys::Map,
        cap: usize,
    }

    impl Ring {
        /// Open (creating and sizing if new) and map the ring at
        /// `path`. An existing file must already have the expected
        /// size — a mismatch means the processes disagree on the ring
        /// capacity, which would corrupt both cursors.
        fn open(path: &Path, cap: usize) -> io::Result<Ring> {
            let total = RING_HDR + cap;
            let f = OpenOptions::new().read(true).write(true).create(true).open(path)?;
            let len = f.metadata()?.len();
            if len == 0 {
                f.set_len(total as u64)?;
            } else if len != total as u64 {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!(
                        "shmem ring {} is {len} bytes, expected {total}; \
                         DISTARRAY_SHMEM_RING_BYTES must agree across processes",
                        path.display()
                    ),
                ));
            }
            Ok(Ring { map: sys::Map::of_file(&f, total)?, cap })
        }

        fn head(&self) -> &AtomicU64 {
            unsafe { &*(self.map.ptr().add(HEAD_OFF) as *const AtomicU64) }
        }

        fn tail(&self) -> &AtomicU64 {
            unsafe { &*(self.map.ptr().add(TAIL_OFF) as *const AtomicU64) }
        }

        /// Futex word: the low half of the head cursor (the cursors
        /// are little-endian on every supported target; on a
        /// big-endian machine the word would track the high half and
        /// waits would still terminate via [`WAIT_SLICE`]).
        fn head_word(&self) -> *const u32 {
            unsafe { self.map.ptr().add(HEAD_OFF) as *const u32 }
        }

        fn tail_word(&self) -> *const u32 {
            unsafe { self.map.ptr().add(TAIL_OFF) as *const u32 }
        }

        fn data(&self) -> *mut u8 {
            unsafe { self.map.ptr().add(RING_HDR) }
        }
    }

    /// A ring plus the mutex serializing this process's side of it
    /// (threads of one endpoint; the other process never takes it).
    struct RingSlot {
        ring: Ring,
        lock: Mutex<()>,
    }

    type Mailbox = HashMap<(Pid, Tag), VecDeque<Vec<u8>>>;

    /// Shared-memory transport endpoint for one PID. See the module
    /// docs for the on-disk layout.
    pub struct ShmemTransport {
        pid: Pid,
        np: usize,
        dir: PathBuf,
        /// `out[to]` — ring this endpoint produces into (None at `pid`).
        out: Vec<Option<RingSlot>>,
        /// `inn[from]` — ring this endpoint consumes (None at `pid`).
        inn: Vec<Option<RingSlot>>,
        /// Records drained off the rings, keyed by `(from, tag)`.
        mbox: Mutex<Mailbox>,
        /// Inline records above this spill to a side file (cap / 4).
        spill_threshold: usize,
        spill_seq: AtomicU64,
        /// `None` = the process default ([`default_recv_timeout`]).
        send_patience: Option<Duration>,
        stats: CommStats,
    }

    impl ShmemTransport {
        /// Endpoint `pid` of an `np`-wide world rooted at `dir`, with
        /// the ambient ring capacity (`DISTARRAY_SHMEM_RING_BYTES` or
        /// 1 MiB). Maps all `2(np-1)` rings eagerly so the datapath
        /// never faults mid-stream.
        pub fn new(dir: &Path, pid: Pid, np: usize) -> io::Result<ShmemTransport> {
            Self::with_ring_bytes(dir, pid, np, ambient_ring_bytes())
        }

        /// [`ShmemTransport::new`] with an explicit per-ring data
        /// capacity (rounded up to a power of two; tests use small
        /// rings to exercise wrap and backpressure).
        pub fn with_ring_bytes(
            dir: &Path,
            pid: Pid,
            np: usize,
            ring_bytes: usize,
        ) -> io::Result<ShmemTransport> {
            assert!(pid < np, "pid {pid} outside world of {np}");
            let cap = ring_bytes.next_power_of_two().max(MIN_RING_BYTES);
            std::fs::create_dir_all(dir)?;
            let mut out = Vec::with_capacity(np);
            let mut inn = Vec::with_capacity(np);
            for peer in 0..np {
                if peer == pid {
                    out.push(None);
                    inn.push(None);
                    continue;
                }
                out.push(Some(RingSlot {
                    ring: Ring::open(&ring_path(dir, pid, peer), cap)?,
                    lock: Mutex::new(()),
                }));
                inn.push(Some(RingSlot {
                    ring: Ring::open(&ring_path(dir, peer, pid), cap)?,
                    lock: Mutex::new(()),
                }));
            }
            Ok(ShmemTransport {
                pid,
                np,
                dir: dir.to_path_buf(),
                out,
                inn,
                mbox: Mutex::new(HashMap::new()),
                spill_threshold: cap / 4,
                spill_seq: AtomicU64::new(0),
                send_patience: None,
                stats: CommStats::new(),
            })
        }

        /// All `np` endpoints over one directory — in-process worlds
        /// for tests and the transport microbench.
        pub fn world(dir: &Path, np: usize) -> io::Result<Vec<ShmemTransport>> {
            (0..np).map(|p| Self::new(dir, p, np)).collect()
        }

        /// Override how long a send waits on a full ring before
        /// failing (default: [`default_recv_timeout`]).
        pub fn with_send_patience(mut self, patience: Duration) -> ShmemTransport {
            self.send_patience = Some(patience);
            self
        }

        /// The spool directory holding this world's rings.
        pub fn dir(&self) -> &Path {
            &self.dir
        }

        fn send_patience(&self) -> Duration {
            self.send_patience.unwrap_or_else(default_recv_timeout)
        }

        /// Block until `ring` has `need` free bytes given our `tail`.
        fn wait_space(
            &self,
            ring: &Ring,
            to: Pid,
            tail: u64,
            need: usize,
            deadline: Instant,
        ) -> Result<()> {
            loop {
                let head = ring.head().load(Ordering::Acquire);
                let used = (tail - head) as usize;
                if ring.cap - used >= need {
                    return Ok(());
                }
                let now = Instant::now();
                if now >= deadline {
                    return Err(CommError::Io(io::Error::new(
                        io::ErrorKind::TimedOut,
                        format!(
                            "shmem ring to pid {to} full ({used} of {} bytes) past the \
                             {} ms send patience — receiver stalled?",
                            ring.cap,
                            self.send_patience().as_millis()
                        ),
                    )));
                }
                sys::futex_wait(ring.head_word(), head as u32, (deadline - now).min(WAIT_SLICE));
            }
        }

        /// Append one record (caller holds the slot lock, making this
        /// endpoint the ring's only producer).
        fn push(
            &self,
            ring: &Ring,
            to: Pid,
            tag: Tag,
            kind: u32,
            parts: &[&[u8]],
            deadline: Instant,
        ) -> Result<()> {
            let len: usize = parts.iter().map(|p| p.len()).sum();
            let need = REC_HDR + pad8(len);
            debug_assert!(need <= ring.cap / 2, "inline record exceeds half the ring");
            let mut tail = ring.tail().load(Ordering::Relaxed);
            loop {
                let pos = (tail as usize) & (ring.cap - 1);
                let rem = ring.cap - pos;
                if need > rem {
                    // Wrap: own the skipped slack plus the record so
                    // the consumer can never be lapped, mark the
                    // slack, and continue from position 0.
                    self.wait_space(ring, to, tail, rem + need, deadline)?;
                    if rem >= REC_HDR {
                        unsafe {
                            let base = ring.data().add(pos);
                            base.copy_from_nonoverlapping(LEN_WRAP.to_le_bytes().as_ptr(), 4);
                            std::ptr::write_bytes(base.add(4), 0, REC_HDR - 4);
                        }
                    }
                    tail += rem as u64;
                    ring.tail().store(tail, Ordering::Release);
                    sys::futex_wake(ring.tail_word());
                    continue;
                }
                self.wait_space(ring, to, tail, need, deadline)?;
                unsafe {
                    let base = ring.data().add(pos);
                    base.copy_from_nonoverlapping((len as u32).to_le_bytes().as_ptr(), 4);
                    base.add(4).copy_from_nonoverlapping(kind.to_le_bytes().as_ptr(), 4);
                    base.add(8).copy_from_nonoverlapping(tag.to_le_bytes().as_ptr(), 8);
                    let mut off = REC_HDR;
                    for p in parts {
                        base.add(off).copy_from_nonoverlapping(p.as_ptr(), p.len());
                        off += p.len();
                    }
                }
                tail += need as u64;
                ring.tail().store(tail, Ordering::Release);
                sys::futex_wake(ring.tail_word());
                return Ok(());
            }
        }

        /// Drain every complete record of `inn[from]` into the
        /// mailbox. Returns the drained count and the tail value the
        /// ring was observed empty at (the futex expectation for a
        /// subsequent wait).
        fn drain_ring(&self, from: Pid) -> Result<(usize, u64)> {
            let slot = self.inn[from].as_ref().expect("no ring to self");
            let _g = slot.lock.lock().unwrap();
            let ring = &slot.ring;
            let mut tail = ring.tail().load(Ordering::Acquire);
            let mut head = ring.head().load(Ordering::Relaxed);
            let start_head = head;
            let mut drained = 0usize;
            let mut landed: Vec<(Tag, Vec<u8>)> = Vec::new();
            loop {
                if head == tail {
                    // Pick up records that arrived while copying.
                    let t2 = ring.tail().load(Ordering::Acquire);
                    if t2 == tail {
                        break;
                    }
                    tail = t2;
                }
                let pos = (head as usize) & (ring.cap - 1);
                let rem = ring.cap - pos;
                if rem < REC_HDR {
                    head += rem as u64;
                    continue;
                }
                let mut hdr = [0u8; REC_HDR];
                unsafe { ring.data().add(pos).copy_to_nonoverlapping(hdr.as_mut_ptr(), REC_HDR) };
                let len = u32::from_le_bytes(hdr[0..4].try_into().unwrap());
                if len == LEN_WRAP {
                    head += rem as u64;
                    continue;
                }
                let kind = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
                let tag = Tag::from_le_bytes(hdr[8..16].try_into().unwrap());
                let len = len as usize;
                if REC_HDR + pad8(len) > rem {
                    return Err(CommError::Malformed(format!(
                        "shmem record from pid {from} ({len} bytes at {pos}) straddles the \
                         ring end"
                    )));
                }
                let mut payload = vec![0u8; len];
                unsafe {
                    ring.data().add(pos + REC_HDR).copy_to_nonoverlapping(payload.as_mut_ptr(), len)
                };
                head += (REC_HDR + pad8(len)) as u64;
                let msg = match kind {
                    K_INLINE => payload,
                    K_SPILL => self.read_spill(from, &payload)?,
                    other => {
                        return Err(CommError::Malformed(format!(
                            "shmem record from pid {from} has unknown kind {other}"
                        )))
                    }
                };
                landed.push((tag, msg));
                drained += 1;
            }
            if head != start_head {
                ring.head().store(head, Ordering::Release);
                sys::futex_wake(ring.head_word());
            }
            drop(_g);
            if !landed.is_empty() {
                let mut mb = self.mbox.lock().unwrap();
                for (tag, msg) in landed {
                    mb.entry((from, tag)).or_default().push_back(msg);
                }
            }
            Ok((drained, tail))
        }

        /// Resolve a spill descriptor: read and delete the side file.
        fn read_spill(&self, from: Pid, desc: &[u8]) -> Result<Vec<u8>> {
            if desc.len() != 16 {
                return Err(CommError::Malformed(format!(
                    "shmem spill descriptor from pid {from} is {} bytes, expected 16",
                    desc.len()
                )));
            }
            let seq = u64::from_le_bytes(desc[0..8].try_into().unwrap());
            let len = u64::from_le_bytes(desc[8..16].try_into().unwrap()) as usize;
            let path = self.dir.join(format!("spill_f{from}_t{}_{seq}.bin", self.pid));
            let bytes = std::fs::read(&path)?;
            if bytes.len() != len {
                return Err(CommError::Malformed(format!(
                    "shmem spill {} is {} bytes, descriptor said {len}",
                    path.display(),
                    bytes.len()
                )));
            }
            let _ = std::fs::remove_file(&path);
            Ok(bytes)
        }

        /// Write a large payload to a one-shot spill file (atomic via
        /// rename, like the file transport) and return its descriptor.
        fn write_spill(&self, to: Pid, parts: &[&[u8]]) -> Result<[u8; 16]> {
            use std::io::Write as _;
            let len: usize = parts.iter().map(|p| p.len()).sum();
            let seq = self.spill_seq.fetch_add(1, Ordering::Relaxed);
            let dst = self.dir.join(format!("spill_f{}_t{to}_{seq}.bin", self.pid));
            let tmp = self.dir.join(format!(".tmp_spill_f{}_t{to}_{seq}", self.pid));
            {
                let mut f = std::fs::File::create(&tmp)?;
                for p in parts {
                    f.write_all(p)?;
                }
            }
            std::fs::rename(&tmp, &dst)?;
            let mut desc = [0u8; 16];
            desc[0..8].copy_from_slice(&seq.to_le_bytes());
            desc[8..16].copy_from_slice(&(len as u64).to_le_bytes());
            Ok(desc)
        }

        fn pop_mbox(&self, from: Pid, tag: Tag) -> Option<Vec<u8>> {
            let mut mb = self.mbox.lock().unwrap();
            let q = mb.get_mut(&(from, tag))?;
            let msg = q.pop_front();
            if q.is_empty() {
                mb.remove(&(from, tag));
            }
            msg
        }
    }

    impl Transport for ShmemTransport {
        fn pid(&self) -> Pid {
            self.pid
        }

        fn np(&self) -> usize {
            self.np
        }

        fn send(&self, to: Pid, tag: Tag, payload: &[u8]) -> Result<()> {
            self.send_parts(to, tag, &[payload])
        }

        fn send_parts(&self, to: Pid, tag: Tag, parts: &[&[u8]]) -> Result<()> {
            let total: usize = parts.iter().map(|p| p.len()).sum();
            if to == self.pid {
                let mut buf = Vec::with_capacity(total);
                for p in parts {
                    buf.extend_from_slice(p);
                }
                self.mbox.lock().unwrap().entry((to, tag)).or_default().push_back(buf);
                self.stats.record_send(total);
                return Ok(());
            }
            let Some(slot) = self.out.get(to).and_then(|s| s.as_ref()) else {
                return Err(CommError::Disconnected(to));
            };
            let deadline = Instant::now() + self.send_patience();
            let _g = slot.lock.lock().unwrap();
            if total > self.spill_threshold {
                let desc = self.write_spill(to, parts)?;
                self.push(&slot.ring, to, tag, K_SPILL, &[&desc], deadline)?;
            } else {
                self.push(&slot.ring, to, tag, K_INLINE, parts, deadline)?;
            }
            self.stats.record_send(total);
            Ok(())
        }

        fn recv_timeout(&self, from: Pid, tag: Tag, timeout: Duration) -> Result<Vec<u8>> {
            if from != self.pid && self.inn.get(from).and_then(|s| s.as_ref()).is_none() {
                return Err(CommError::Disconnected(from));
            }
            let deadline = Instant::now() + timeout;
            loop {
                if let Some(msg) = self.pop_mbox(from, tag) {
                    self.stats.record_recv(msg.len());
                    return Ok(msg);
                }
                let (drained, empty_at) =
                    if from == self.pid { (0, 0) } else { self.drain_ring(from)? };
                if drained > 0 {
                    continue;
                }
                let now = Instant::now();
                if now >= deadline {
                    return Err(CommError::timeout(from, tag));
                }
                let slice = (deadline - now).min(WAIT_SLICE);
                if from == self.pid {
                    // Self-sends bypass the rings; poll the mailbox.
                    std::thread::sleep(slice.min(Duration::from_micros(100)));
                } else {
                    let ring = &self.inn[from].as_ref().unwrap().ring;
                    sys::futex_wait(ring.tail_word(), empty_at as u32, slice);
                }
            }
        }

        fn stats(&self) -> &CommStats {
            &self.stats
        }

        fn kind(&self) -> Option<TransportKind> {
            Some(TransportKind::Shmem)
        }
    }
}

/// Raw mmap + futex bindings (the crate is dependency-free, so these
/// are hand-rolled over glibc/the kernel like
/// [`crate::launcher::pinning`]).
#[cfg(unix)]
mod sys {
    use std::fs::File;
    use std::io;
    use std::os::unix::io::AsRawFd;

    /// An mmap'd shared region, unmapped on drop.
    pub struct Map {
        ptr: *mut u8,
        len: usize,
    }

    // The region is plain shared memory; all concurrent access goes
    // through atomics (the cursors) ordered by release/acquire.
    unsafe impl Send for Map {}
    unsafe impl Sync for Map {}

    extern "C" {
        fn mmap(addr: *mut u8, len: usize, prot: i32, flags: i32, fd: i32, off: i64) -> *mut u8;
        fn munmap(addr: *mut u8, len: usize) -> i32;
    }

    const PROT_READ: i32 = 1;
    const PROT_WRITE: i32 = 2;
    const MAP_SHARED: i32 = 1;

    impl Map {
        pub fn of_file(f: &File, len: usize) -> io::Result<Map> {
            let ptr = unsafe {
                mmap(
                    std::ptr::null_mut(),
                    len,
                    PROT_READ | PROT_WRITE,
                    MAP_SHARED,
                    f.as_raw_fd(),
                    0,
                )
            };
            if ptr.is_null() || ptr as isize == -1 {
                return Err(io::Error::last_os_error());
            }
            Ok(Map { ptr, len })
        }

        pub fn ptr(&self) -> *mut u8 {
            self.ptr
        }
    }

    impl Drop for Map {
        fn drop(&mut self) {
            unsafe { munmap(self.ptr, self.len) };
        }
    }

    #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
    mod futex {
        use std::time::Duration;

        #[cfg(target_arch = "x86_64")]
        const SYS_FUTEX: i64 = 202;
        #[cfg(target_arch = "aarch64")]
        const SYS_FUTEX: i64 = 98;
        // No FUTEX_PRIVATE_FLAG: the word is shared across processes.
        const FUTEX_WAIT: i32 = 0;
        const FUTEX_WAKE: i32 = 1;

        #[repr(C)]
        struct Timespec {
            tv_sec: i64,
            tv_nsec: i64,
        }

        extern "C" {
            fn syscall(num: i64, ...) -> i64;
        }

        /// Sleep until `*word != expected`, a wake, or `timeout` —
        /// returns immediately if the word already changed.
        pub fn wait(word: *const u32, expected: u32, timeout: Duration) {
            let ts = Timespec {
                tv_sec: timeout.as_secs() as i64,
                tv_nsec: timeout.subsec_nanos() as i64,
            };
            unsafe {
                syscall(SYS_FUTEX, word, FUTEX_WAIT, expected, &ts as *const Timespec, 0usize, 0u32)
            };
        }

        /// Wake every waiter on `word`.
        pub fn wake(word: *const u32) {
            unsafe {
                syscall(SYS_FUTEX, word, FUTEX_WAKE, i32::MAX, 0usize, 0usize, 0u32)
            };
        }
    }

    #[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
    mod futex {
        use std::time::Duration;

        /// Portable fallback: a bounded sleep (no kernel wait queue;
        /// the caller's slice loop re-checks the ring).
        pub fn wait(_word: *const u32, _expected: u32, timeout: Duration) {
            std::thread::sleep(timeout.min(Duration::from_micros(200)));
        }

        pub fn wake(_word: *const u32) {}
    }

    pub use futex::{wait as futex_wait, wake as futex_wake};
}

/// Non-unix stub: construction reports the platform gap up front.
#[cfg(not(unix))]
pub struct ShmemTransport {
    never: std::convert::Infallible,
    stats: crate::comm::CommStats,
}

#[cfg(not(unix))]
impl ShmemTransport {
    fn unsupported() -> std::io::Error {
        std::io::Error::new(
            std::io::ErrorKind::Unsupported,
            "the shmem transport requires a unix host (mmap)",
        )
    }

    pub fn new(
        _dir: &std::path::Path,
        _pid: crate::dmap::Pid,
        _np: usize,
    ) -> std::io::Result<ShmemTransport> {
        Err(Self::unsupported())
    }

    pub fn with_ring_bytes(
        _dir: &std::path::Path,
        _pid: crate::dmap::Pid,
        _np: usize,
        _ring_bytes: usize,
    ) -> std::io::Result<ShmemTransport> {
        Err(Self::unsupported())
    }

    pub fn world(_dir: &std::path::Path, _np: usize) -> std::io::Result<Vec<ShmemTransport>> {
        Err(Self::unsupported())
    }
}

#[cfg(not(unix))]
impl crate::comm::Transport for ShmemTransport {
    fn pid(&self) -> crate::dmap::Pid {
        match self.never {}
    }
    fn np(&self) -> usize {
        match self.never {}
    }
    fn send(
        &self,
        _to: crate::dmap::Pid,
        _tag: crate::comm::Tag,
        _payload: &[u8],
    ) -> crate::comm::Result<()> {
        match self.never {}
    }
    fn recv_timeout(
        &self,
        _from: crate::dmap::Pid,
        _tag: crate::comm::Tag,
        _timeout: std::time::Duration,
    ) -> crate::comm::Result<Vec<u8>> {
        match self.never {}
    }
    fn stats(&self) -> &crate::comm::CommStats {
        &self.stats
    }
    fn kind(&self) -> Option<crate::comm::TransportKind> {
        Some(crate::comm::TransportKind::Shmem)
    }
}

#[cfg(all(test, unix))]
mod tests {
    use super::ShmemTransport;
    use crate::comm::{CommError, Transport};
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::time::Duration;

    /// A fresh per-test spool directory (removed by the OS tempdir
    /// cleanup; unique across concurrent test processes and threads).
    fn scratch(label: &str) -> PathBuf {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let d = std::env::temp_dir().join(format!(
            "distarray_shmem_{label}_{}_{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip_and_per_tag_order() {
        let dir = scratch("rt");
        let world = ShmemTransport::world(&dir, 2).unwrap();
        let (t0, t1) = (&world[0], &world[1]);
        for i in 0..10u8 {
            t0.send(1, 7, &[i; 9]).unwrap();
            t0.send(1, 8, &[i + 100; 3]).unwrap();
        }
        for i in 0..10u8 {
            assert_eq!(t1.recv_timeout(0, 7, Duration::from_secs(5)).unwrap(), vec![i; 9]);
            assert_eq!(t1.recv_timeout(0, 8, Duration::from_secs(5)).unwrap(), vec![i + 100; 3]);
        }
        assert_eq!(t0.stats().msgs_sent(), 20);
        assert_eq!(t1.stats().msgs_recv(), 20);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A tiny ring forces wrap markers and full-ring backpressure;
    /// a concurrent consumer keeps the producer advancing.
    #[test]
    fn wrap_and_backpressure_with_a_tiny_ring() {
        let dir = scratch("wrap");
        let t0 = ShmemTransport::with_ring_bytes(&dir, 0, 2, 4096).unwrap();
        let t1 = ShmemTransport::with_ring_bytes(&dir, 1, 2, 4096).unwrap();
        let n = 200usize;
        let consumer = std::thread::spawn(move || {
            for i in 0..n {
                let msg = t1.recv_timeout(0, 3, Duration::from_secs(10)).unwrap();
                assert_eq!(msg, vec![(i % 251) as u8; 100 + (i % 57)], "message {i}");
            }
            t1
        });
        for i in 0..n {
            t0.send(1, 3, &vec![(i % 251) as u8; 100 + (i % 57)]).unwrap();
        }
        let t1 = consumer.join().unwrap();
        assert_eq!(t1.stats().msgs_recv() as usize, n);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Payloads above a quarter of the ring spill to a side file that
    /// the consumer deletes after reading.
    #[test]
    fn large_payloads_spill_and_clean_up() {
        let dir = scratch("spill");
        let t0 = ShmemTransport::with_ring_bytes(&dir, 0, 2, 4096).unwrap();
        let t1 = ShmemTransport::with_ring_bytes(&dir, 1, 2, 4096).unwrap();
        let big: Vec<u8> = (0..10_000u32).map(|i| (i % 253) as u8).collect();
        t0.send_parts(1, 9, &[&big[..4000], &big[4000..]]).unwrap();
        assert_eq!(t1.recv_timeout(0, 9, Duration::from_secs(5)).unwrap(), big);
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.contains("spill"))
            .collect();
        assert!(leftovers.is_empty(), "spill files left behind: {leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn timeout_names_the_silent_peer() {
        let dir = scratch("to");
        let world = ShmemTransport::world(&dir, 2).unwrap();
        let err = world[0].recv_timeout(1, 5, Duration::from_millis(30)).unwrap_err();
        match err {
            CommError::Timeout { from, tag, .. } => {
                assert_eq!((from, tag), (1, 5));
            }
            other => panic!("expected Timeout, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A full ring with no consumer fails the send with a one-line
    /// error instead of hanging forever.
    #[test]
    fn full_ring_send_fails_loudly() {
        let dir = scratch("full");
        let t0 = ShmemTransport::with_ring_bytes(&dir, 0, 2, 4096)
            .unwrap()
            .with_send_patience(Duration::from_millis(50));
        let mut err = None;
        for _ in 0..64 {
            // 1000-byte payloads stay inline (threshold 1024).
            if let Err(e) = t0.send(1, 2, &[7u8; 1000]) {
                err = Some(e);
                break;
            }
        }
        let msg = err.expect("ring never filled").to_string();
        assert!(msg.contains("full") && msg.contains("pid 1"), "{msg}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn self_send_delivers() {
        let dir = scratch("selfs");
        let world = ShmemTransport::world(&dir, 2).unwrap();
        world[0].send(0, 11, b"loop").unwrap();
        assert_eq!(world[0].recv_timeout(0, 11, Duration::from_secs(1)).unwrap(), b"loop");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn out_of_world_peers_are_disconnected() {
        let dir = scratch("oow");
        let world = ShmemTransport::world(&dir, 2).unwrap();
        assert!(matches!(world[0].send(5, 1, b"x"), Err(CommError::Disconnected(5))));
        assert!(matches!(
            world[0].recv_timeout(5, 1, Duration::ZERO),
            Err(CommError::Disconnected(5))
        ));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
