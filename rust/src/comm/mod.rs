//! Communication substrate.
//!
//! The paper's systems communicate two ways: distributed-array remaps
//! (PID↔PID messages, §II message-passing model) and leader/worker
//! result aggregation via **asynchronous file-based messaging** (§V,
//! reference [44] "Large scale parallelization using file-based
//! communications").  Both are expressed through the [`Transport`]
//! trait with two implementations:
//!
//! * [`ChannelTransport`] — in-process (one thread per PID); used by
//!   tests and single-process multi-worker runs.
//! * [`FileTransport`] — the paper's file-based messaging: messages
//!   are files in a spool directory, delivered by atomic rename; works
//!   across OS processes with no daemon.
//!
//! Every send/recv is counted by [`CommStats`] so the paper's central
//! claim — *same-map STREAM performs zero communication* (Figure 2) —
//! is asserted by tests rather than assumed.

pub mod barrier;
pub mod channel;
pub mod counter;
pub mod file_msg;
pub mod protocol;

pub use channel::{ChannelHub, ChannelTransport};
pub use counter::CommStats;
pub use file_msg::FileTransport;
pub use protocol::{Decode, Encode, WireReader, WireWriter};

use crate::dmap::Pid;
use std::sync::Arc;
use std::time::Duration;

/// Message tag (sender-chosen; disambiguates concurrent streams).
pub type Tag = u64;

/// Reserved tags used by the library itself.
pub mod tags {
    use super::Tag;
    /// Leader → worker run-configuration broadcast.
    pub const CONFIG: Tag = 0xC0FF;
    /// Worker → leader benchmark results.
    pub const RESULT: Tag = 0x0BE5;
    /// Barrier round-trips.
    pub const BARRIER: Tag = 0xBA77;
    /// Distributed-array remap payloads (base; +plan step).
    pub const REMAP: Tag = 0x0E0A_0000;
    /// Overlap/halo synchronization.
    pub const HALO: Tag = 0x4A10_0000;
    /// Aggregation (`agg()`) gathers.
    pub const AGG: Tag = 0xA660_0000;
}

/// Errors surfaced by transports.
#[derive(Debug, thiserror::Error)]
pub enum CommError {
    #[error("timeout waiting for message from {from} tag {tag:#x}")]
    Timeout { from: Pid, tag: Tag },
    #[error("peer {0} disconnected")]
    Disconnected(Pid),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("malformed message: {0}")]
    Malformed(String),
}

pub type Result<T> = std::result::Result<T, CommError>;

/// Point-to-point messaging endpoint for one PID.
///
/// Semantics (matching MPI two-sided + pMatlab MatlabMPI):
/// * `send` is asynchronous and ordered per (src, dst, tag);
/// * `recv` blocks until a matching message arrives or `timeout`.
pub trait Transport: Send + Sync {
    /// This endpoint's PID.
    fn pid(&self) -> Pid;
    /// World size.
    fn np(&self) -> usize;
    /// Send `payload` to `to` under `tag`.
    fn send(&self, to: Pid, tag: Tag, payload: &[u8]) -> Result<()>;
    /// Blocking receive of the next message from `from` with `tag`.
    fn recv_timeout(&self, from: Pid, tag: Tag, timeout: Duration) -> Result<Vec<u8>>;
    /// Communication statistics for this endpoint.
    fn stats(&self) -> &CommStats;

    /// Blocking receive with the default (generous) timeout.
    fn recv(&self, from: Pid, tag: Tag) -> Result<Vec<u8>> {
        self.recv_timeout(from, tag, Duration::from_secs(120))
    }
}

/// A `Transport` handle that can be shared across threads.
pub type SharedTransport = Arc<dyn Transport>;
