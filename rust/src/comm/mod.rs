//! Communication substrate.
//!
//! The paper's systems communicate two ways: distributed-array remaps
//! (PID↔PID messages, §II message-passing model) and leader/worker
//! result aggregation via **asynchronous file-based messaging** (§V,
//! reference [44] "Large scale parallelization using file-based
//! communications").  Both are expressed through the [`Transport`]
//! trait with several implementations:
//!
//! * [`ChannelTransport`] — in-process (one thread per PID); used by
//!   tests and single-process multi-worker runs.
//! * [`FileTransport`] — the paper's file-based messaging: messages
//!   are files in a spool directory, delivered by atomic rename; works
//!   across OS processes with no daemon.
//! * [`ShmemTransport`] — per-peer-pair mmap'd shared-memory SPSC
//!   rings with futex wait/wake; the fast intra-node path.
//! * [`TcpTransport`] — length-prefixed framed TCP, one multiplexed
//!   connection per peer pair; the cross-node path.
//! * [`HybridTransport`] — routes by [`crate::collective::Topology`]:
//!   shmem to same-node PIDs, TCP across nodes.
//!
//! See `docs/transport.md` for wire formats and the selection matrix.
//!
//! Every send/recv is counted by [`CommStats`] so the paper's central
//! claim — *same-map STREAM performs zero communication* (Figure 2) —
//! is asserted by tests rather than assumed.

pub mod barrier;
pub mod channel;
pub mod counter;
pub mod datapath;
pub mod file_msg;
pub mod hybrid;
pub mod pool;
pub mod protocol;
pub mod shmem;
pub mod tcp;

pub use channel::{ChannelHub, ChannelTransport};
pub use counter::CommStats;
pub use datapath::{ChunkStream, ChunkTag};
pub use file_msg::FileTransport;
pub use hybrid::HybridTransport;
pub use pool::{BufferPool, PooledBuf};
pub use protocol::{Decode, Encode, WireReader, WireWriter};
pub use shmem::ShmemTransport;
pub use tcp::{TcpRendezvous, TcpTransport};

use crate::dmap::Pid;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Duration;

/// Message tag (sender-chosen; disambiguates concurrent streams).
pub type Tag = u64;

/// Reserved tags used by the library itself.
///
/// Library-internal tags are **bit-field packed** so no two logical
/// message streams can ever alias:
///
/// ```text
/// bit 63........56 55........................24 23.............0
///     namespace    epoch (low 32 bits)          step / sequence
/// ```
///
/// The namespace occupies the top byte, so every packed tag is
/// ≥ 2^56; the legacy low-valued control tags ([`CONFIG`],
/// [`RESULT`]) and any user-chosen small tags live in namespace 0 and
/// are disjoint by construction. This replaces the old XOR mixing
/// (`REMAP ^ (epoch << 32) ^ step`), under which a (epoch, step) pair
/// from one subsystem could collide with another subsystem's base
/// constant.
pub mod tags {
    use super::Tag;
    /// Leader → worker run-configuration broadcast.
    pub const CONFIG: Tag = 0xC0FF;
    /// Worker → leader benchmark results.
    pub const RESULT: Tag = 0x0BE5;

    /// Barrier round-trips.
    pub const NS_BARRIER: u8 = 1;
    /// Distributed-array remap payloads — one coalesced chunk stream
    /// per communicating peer pair per epoch (the `(from, tag)` match
    /// disambiguates peers; the low 16 step bits carry the chunk
    /// index, 0 for sub-chunk-size messages).
    pub const NS_REMAP: u8 = 2;
    /// Overlap/halo synchronization.
    pub const NS_HALO: u8 = 3;
    /// Aggregation (`agg()`) gathers.
    pub const NS_AGG: u8 = 4;
    /// Scalar reductions (`allreduce`).
    pub const NS_REDUCE: u8 = 5;
    /// Global range gathers (`gather_range`).
    pub const NS_GATHER: u8 = 6;
    /// Pipeline stage transfers — one coalesced message per
    /// destination peer per epoch (like [`NS_REMAP`]).
    pub const NS_STAGE: u8 = 7;
    /// Collective subsystem operations (`crate::collective`): the
    /// coordinator's config/result control plane and any collective
    /// call that does not carry a legacy namespace. Steps are packed
    /// `level | phase | round` by
    /// [`TagSpace`](crate::collective::TagSpace).
    pub const NS_COLL: u8 = 8;
    /// Fault-tolerance control plane (`crate::fault`): heartbeat
    /// pings/pongs and survivor-reconfiguration messages. Rides its
    /// own namespace so detector traffic can never alias a data
    /// stream, and a redealt epoch's tags reject stale messages from
    /// a dead rank by construction.
    pub const NS_FAULT: u8 = 9;

    /// Pack `(namespace, epoch, step)` into disjoint bit fields.
    ///
    /// Epochs are truncated to 32 bits and steps to 24 bits (the plan
    /// sizes and epoch counts of any realistic run fit with room to
    /// spare; debug builds assert it). Two packed tags are equal iff
    /// all three fields are equal — no cross-namespace aliasing.
    #[inline]
    pub const fn pack(ns: u8, epoch: u64, step: u64) -> Tag {
        debug_assert!(epoch < 1 << 32, "epoch exceeds 32-bit tag field");
        debug_assert!(step < 1 << 24, "step exceeds 24-bit tag field");
        ((ns as Tag) << 56) | ((epoch & 0xFFFF_FFFF) << 24) | (step & 0x00FF_FFFF)
    }

    /// Split a packed tag back into `(namespace, epoch, step)`.
    ///
    /// Inverse of [`pack`] over its domain; legacy low-valued tags
    /// ([`CONFIG`], [`RESULT`]) come back as namespace 0 with the raw
    /// value in the step field, which is exactly how the trace plane
    /// wants them labelled.
    #[inline]
    pub const fn unpack(tag: Tag) -> (u8, u64, u64) {
        ((tag >> 56) as u8, (tag >> 24) & 0xFFFF_FFFF, tag & 0x00FF_FFFF)
    }
}

/// The transport families a run can ride — the `--transport` axis.
///
/// Wire codes are stable across versions (they are stamped into
/// `trace_event_v1` chunk events and into [`crate::coordinator::results::RunConfig`]'s
/// encoding); code 0 is reserved for "unknown / unstamped".
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum TransportKind {
    /// In-process mailboxes ([`ChannelTransport`]); one thread per PID.
    Channel,
    /// The paper's file-based spool ([`FileTransport`]).
    File,
    /// mmap'd shared-memory rings ([`ShmemTransport`]); same node only.
    Shmem,
    /// Length-prefixed framed TCP ([`TcpTransport`]).
    Tcp,
    /// [`HybridTransport`]: shmem same-node, TCP cross-node.
    Hybrid,
}

impl TransportKind {
    /// Every selectable kind, in CLI/doc order.
    pub const ALL: [TransportKind; 5] = [
        TransportKind::Channel,
        TransportKind::File,
        TransportKind::Shmem,
        TransportKind::Tcp,
        TransportKind::Hybrid,
    ];

    /// The axis-flag / config / trace label.
    pub const fn name(self) -> &'static str {
        match self {
            TransportKind::Channel => "channel",
            TransportKind::File => "file",
            TransportKind::Shmem => "shmem",
            TransportKind::Tcp => "tcp",
            TransportKind::Hybrid => "hybrid",
        }
    }

    /// Stable wire/trace code (0 is reserved for "unknown").
    pub const fn code(self) -> u8 {
        match self {
            TransportKind::Channel => 1,
            TransportKind::File => 2,
            TransportKind::Shmem => 3,
            TransportKind::Tcp => 4,
            TransportKind::Hybrid => 5,
        }
    }

    /// Inverse of [`TransportKind::code`].
    pub const fn from_code(code: u8) -> Option<TransportKind> {
        match code {
            1 => Some(TransportKind::Channel),
            2 => Some(TransportKind::File),
            3 => Some(TransportKind::Shmem),
            4 => Some(TransportKind::Tcp),
            5 => Some(TransportKind::Hybrid),
            _ => None,
        }
    }

    /// Parse an axis-flag value.
    pub fn parse(s: &str) -> Option<TransportKind> {
        TransportKind::ALL.iter().copied().find(|k| k.name() == s)
    }

    /// The `--transport` choices string for CLI errors and usage.
    pub const CHOICES: &'static str = "channel|file|shmem|tcp|hybrid";
}

impl std::fmt::Display for TransportKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The compiled-in fallback for [`default_recv_timeout`].
pub const DEFAULT_RECV_TIMEOUT: Duration = Duration::from_secs(120);

/// Process-wide override of the default receive timeout in
/// milliseconds (0 = unset). Installed by `--recv-timeout-ms` /
/// `RunConfig`; the environment (`DISTARRAY_RECV_TIMEOUT_MS`) seeds it
/// lazily so spawned workers inherit the leader's setting before
/// their config broadcast lands.
static RECV_TIMEOUT_OVERRIDE_MS: AtomicU64 = AtomicU64::new(0);

/// `DISTARRAY_RECV_TIMEOUT_MS` parsed once per process.
fn env_recv_timeout_ms() -> u64 {
    static ENV: OnceLock<u64> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("DISTARRAY_RECV_TIMEOUT_MS")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .unwrap_or(0)
    })
}

/// Install the process-default receive timeout (milliseconds; 0
/// restores the compiled-in [`DEFAULT_RECV_TIMEOUT`]).
pub fn set_default_recv_timeout_ms(ms: u64) {
    RECV_TIMEOUT_OVERRIDE_MS.store(ms, Ordering::Relaxed);
}

/// The default timeout used by [`Transport::recv`] and the datapath's
/// stall windows: the explicit process override if installed, else
/// `DISTARRAY_RECV_TIMEOUT_MS`, else [`DEFAULT_RECV_TIMEOUT`].
pub fn default_recv_timeout() -> Duration {
    let ms = match RECV_TIMEOUT_OVERRIDE_MS.load(Ordering::Relaxed) {
        0 => env_recv_timeout_ms(),
        ms => ms,
    };
    if ms == 0 {
        DEFAULT_RECV_TIMEOUT
    } else {
        Duration::from_millis(ms)
    }
}

/// Errors surfaced by transports.
#[derive(Debug)]
pub enum CommError {
    Timeout {
        from: Pid,
        tag: Tag,
        /// Every peer still owing data when a multi-peer drain timed
        /// out, with the chunk index it stalled on — empty for plain
        /// point-to-point timeouts. Makes multi-peer hangs diagnosable
        /// from the error alone instead of naming one arbitrary peer.
        stalled: Vec<(Pid, u64)>,
    },
    Disconnected(Pid),
    Io(std::io::Error),
    Malformed(String),
    /// A peer was declared dead by the failure detector
    /// ([`crate::fault::Detector`]) after missing `missed`
    /// consecutive heartbeats. Distinct from [`CommError::Timeout`]:
    /// this is a positive verdict, not a stall.
    RankDead { pid: Pid, missed: u32 },
}

impl CommError {
    /// A point-to-point timeout (no multi-peer stall detail).
    pub fn timeout(from: Pid, tag: Tag) -> CommError {
        CommError::Timeout { from, tag, stalled: Vec::new() }
    }
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::Timeout { from, tag, stalled } => {
                write!(f, "timeout waiting for message from {from} tag {tag:#x}")?;
                if !stalled.is_empty() {
                    write!(f, "; stalled peers:")?;
                    for (i, (peer, chunk)) in stalled.iter().enumerate() {
                        let sep = if i == 0 { ' ' } else { ',' };
                        write!(f, "{sep}pid {peer} (next chunk {chunk})")?;
                    }
                }
                Ok(())
            }
            CommError::Disconnected(p) => write!(f, "peer {p} disconnected"),
            CommError::Io(e) => write!(f, "io error: {e}"),
            CommError::Malformed(m) => write!(f, "malformed message: {m}"),
            CommError::RankDead { pid, missed } => {
                write!(f, "rank {pid} declared dead after {missed} missed heartbeats")
            }
        }
    }
}

impl std::error::Error for CommError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CommError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CommError {
    fn from(e: std::io::Error) -> Self {
        CommError::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, CommError>;

/// Point-to-point messaging endpoint for one PID.
///
/// Semantics (matching MPI two-sided + pMatlab MatlabMPI):
/// * `send` is asynchronous and ordered per (src, dst, tag);
/// * `recv` blocks until a matching message arrives or `timeout`.
pub trait Transport: Send + Sync {
    /// This endpoint's PID.
    fn pid(&self) -> Pid;
    /// World size.
    fn np(&self) -> usize;
    /// Send `payload` to `to` under `tag`.
    fn send(&self, to: Pid, tag: Tag, payload: &[u8]) -> Result<()>;
    /// Blocking receive of the next message from `from` with `tag`.
    fn recv_timeout(&self, from: Pid, tag: Tag, timeout: Duration) -> Result<Vec<u8>>;
    /// Communication statistics for this endpoint.
    fn stats(&self) -> &CommStats;

    /// The transport family of this endpoint (stamped into trace
    /// events so `repro analyze` can attribute wire time per
    /// transport). `None` means "unknown" — test doubles and wrappers
    /// that don't care inherit it.
    fn kind(&self) -> Option<TransportKind> {
        None
    }

    /// The transport family used for messages **to `to`** — equal to
    /// [`Transport::kind`] for every homogeneous transport; the hybrid
    /// transport overrides it to report shmem or TCP per peer.
    fn kind_to(&self, _to: Pid) -> Option<TransportKind> {
        self.kind()
    }

    /// Blocking receive with the default (generous) timeout —
    /// [`default_recv_timeout`], overridable per process via
    /// `--recv-timeout-ms` / `DISTARRAY_RECV_TIMEOUT_MS`.
    fn recv(&self, from: Pid, tag: Tag) -> Result<Vec<u8>> {
        self.recv_timeout(from, tag, default_recv_timeout())
    }

    /// Send a message whose payload is `parts` concatenated in order.
    ///
    /// The default materializes the concatenation and calls
    /// [`Transport::send`]; transports that can write incrementally
    /// (the file spool) override it so framing and payload go straight
    /// to the destination with no intermediate buffer. Receivers see a
    /// single contiguous payload either way.
    fn send_parts(&self, to: Pid, tag: Tag, parts: &[&[u8]]) -> Result<()> {
        let total = parts.iter().map(|p| p.len()).sum();
        let mut buf = Vec::with_capacity(total);
        for p in parts {
            buf.extend_from_slice(p);
        }
        self.send(to, tag, &buf)
    }

    /// Non-blocking receive: the next matching message if one has
    /// already arrived, `None` otherwise. Lets a receiver drain
    /// several peers in **arrival order** instead of blocking on one
    /// — the remap engine's per-peer completion loop.
    fn try_recv(&self, from: Pid, tag: Tag) -> Result<Option<Vec<u8>>> {
        match self.recv_timeout(from, tag, Duration::ZERO) {
            Ok(payload) => Ok(Some(payload)),
            Err(CommError::Timeout { .. }) => Ok(None),
            Err(e) => Err(e),
        }
    }
}

/// A `Transport` handle that can be shared across threads.
pub type SharedTransport = Arc<dyn Transport>;
