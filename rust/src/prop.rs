//! Tiny property-testing substrate (proptest is unavailable offline).
//!
//! A deterministic xorshift64* PRNG plus a `forall` driver that runs a
//! generator/checker pair for `iters` cases and reports the failing
//! seed — enough for the randomized invariant tests in
//! `rust/tests/prop_invariants.rs`.

/// xorshift64* PRNG — deterministic, seedable, no dependencies.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng { state: seed.max(1) }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, n)` (n ≥ 1).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n >= 1);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in `[lo, hi]`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

/// Run `check(rng)` for `iters` seeded cases; panic with the failing
/// seed on the first failure so the case is reproducible.
pub fn forall(iters: usize, base_seed: u64, check: impl Fn(&mut Rng)) {
    for i in 0..iters {
        let seed = base_seed.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15) | 1;
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| check(&mut rng)));
        if let Err(e) = result {
            crate::log!(Error, "property failed at iter {i} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn ranges_in_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.range(3, 17);
            assert!((3..=17).contains(&x));
            let f = r.f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn distribution_not_degenerate() {
        let mut r = Rng::new(1);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..100 {
            seen.insert(r.below(10));
        }
        assert!(seen.len() >= 9, "only {:?}", seen);
    }

    #[test]
    fn forall_runs_all_iters() {
        let count = std::cell::Cell::new(0);
        forall(25, 99, |_| count.set(count.get() + 1));
        assert_eq!(count.get(), 25);
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failure() {
        forall(10, 1, |rng| assert!(rng.below(10) < 5));
    }
}
