//! `repro` — the leader binary.
//!
//! Subcommands:
//! * `run`         — coordinated STREAM across worker processes (triples mode)
//! * `worker`      — internal: one spawned worker process
//! * `chaos`       — kill-one-worker fault drill: detect, re-deal, verify
//! * `bench-remap` — measure the coalesced remap hot path (bench_remap_v1)
//! * `bench-collective` — measure the collective algorithms (bench_collective_v1)
//! * `bench-overlap` — measure compute/communication overlap (bench_overlap_v1)
//! * `bench-transport` — ping-pong / streaming microbench across transports
//!   (bench_transport_v1)
//! * `sweep`       — regenerate a figure (fig3 | fig4 | petascale)
//! * `report`      — print a paper table (table1 | table2 | fig4)
//! * `trace-report` — merge per-rank NDJSON traces into a summary / Chrome export
//! * `analyze`     — causal attribution over traces: critical path, stragglers,
//!   latency histograms, achieved-vs-modeled bandwidth (analysis_v1)
//! * `bench-diff`  — compare two bench/analysis JSON documents, gate regressions
//! * `validate`    — run the PJRT artifacts and check numerics vs closed forms
//! * `info`        — platform / artifact summary

use distarray::backend::{BackendKind, BackendRegistry};
use distarray::cli::Args;
use distarray::collective::CollKind;
use distarray::comm::{
    FileTransport, HybridTransport, ShmemTransport, TcpRendezvous, Transport, TransportKind,
};
use distarray::coordinator::{run_leader, run_worker, EngineKind, MapKind, RunConfig};
use distarray::launcher::{spawn_workers, PinPlan, Triples, WorkerEnv};
use distarray::report::{bench_json, fig3, fig4, fmt_bw, petascale, table1, table2};
use distarray::stream::STREAM_Q;

fn main() {
    let args = Args::from_env();
    let code = match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("worker") => cmd_worker(),
        Some("chaos") => cmd_chaos(&args),
        Some("bench-remap") => cmd_bench_remap(&args),
        Some("bench-collective") => cmd_bench_collective(&args),
        Some("bench-overlap") => cmd_bench_overlap(&args),
        Some("bench-transport") => cmd_bench_transport(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("report") => cmd_report(&args),
        Some("trace-report") => cmd_trace_report(&args),
        Some("analyze") => cmd_analyze(&args),
        Some("bench-diff") => cmd_bench_diff(&args),
        Some("validate") => cmd_validate(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: repro <run|chaos|bench-remap|sweep|report|validate|info> [--flags]\n\
                 \n  run      [--config run.json] --triples 1x4x1 --n 1048576 --nt 10\n\
                 \n           --map block|cyclic|blockcyclic:K --engine native|pjrt|pjrt-fused\n\
                 \n           --dtype f32|f64|i64|u64 (native engine; default f64)\n\
                 \n           --backend host|threaded|pjrt (native engine; default host)\n\
                 \n           --coll star|tree|ring|hier|auto (collective algorithms; default star)\n\
                 \n           --chunk-bytes N (stream chunk of the shared datapath; default 65536)\n\
                 \n           --transport channel|file|shmem|tcp|hybrid (worker wire; default file;\n\
                 \n           channel runs the whole world in-process, hybrid routes shmem\n\
                 \n           intra-node and tcp across nodes per the triples Nppn axis)\n\
                 \n           --recv-timeout-ms N (receive patience everywhere; default 120000)\n\
                 \n           --bench-json out.json (machine-readable per-op bandwidths)\n\
                 \n           --trace out.ndjson|- (per-rank NDJSON span traces; workers\n\
                 \n           write out.ndjson.rank<pid>) --metrics-interval MS (counter samples)\n\
                 \n           --heartbeat (leader failure detector + worker responders)\n\
                 \n           --checkpoint DIR (ckpt_v1 shards, native engine) [--restore]\n\
                 \n  chaos    --np 4 --kill 2 [--n N] [--dtype f64] [--trace out.ndjson]\n\
                 \n           [--transport channel|file|shmem|tcp] (fault world's wire)\n\
                 \n           (kill one rank mid-job: detect, re-deal onto survivors,\n\
                 \n           verify bit-identity against a clean survivor run)\n\
                 \n  bench-remap --np 4 --n 1048576 --iters 10 --dtype f64\n\
                 \n           [--bench-json out.json] (bench_remap_v1: bytes, messages, GB/s)\n\
                 \n  bench-collective --np-list 2,4,8 --nppn 2 --bytes 65536 --iters 20\n\
                 \n           --coll star,tree,ring,hier,auto [--chunk-bytes N] [--bench-json out.json]\n\
                 \n           (bench_collective_v1: latency, bytes, messages, pool hits vs P)\n\
                 \n  bench-overlap --np 4 --bytes 67108864 --iters 3 [--chunk-bytes N]\n\
                 \n           [--bench-json out.json] (bench_overlap_v1: wire/compute/serial/total\n\
                 \n           seconds + overlap efficiency for remap and elimination allreduce)\n\
                 \n  bench-transport [--transport channel,file,shmem,tcp,hybrid] [--iters 200]\n\
                 \n           [--bytes 4194304] [--bench-json out.json] (bench_transport_v1:\n\
                 \n           small-message ping-pong RTT + chunked streaming GB/s per transport)\n\
                 \n  sweep    fig3|fig4|petascale [--measure] [--csv] [--backend host|threaded]\n\
                 \n  report   table1|table2|fig4\n\
                 \n  trace-report <trace.ndjson>... [--check] [--chrome out.json] [--analyze]\n\
                 \n           (merge per-rank traces: summary table, strict line validation,\n\
                 \n           chrome://tracing export; benches also accept --trace out.ndjson)\n\
                 \n  analyze  <trace.ndjson>... [--json out.json|-] [--era amd-e9]\n\
                 \n           [--nppn N] [--ntpn N] (causal attribution: matched message\n\
                 \n           edges, critical path, per-rank idle, straggler ranking,\n\
                 \n           achieved vs modeled bandwidth; --json emits analysis_v1)\n\
                 \n  bench-diff OLD.json NEW.json [--max-regress PCT] [--report-only]\n\
                 \n           (field-by-field regression gate over two same-schema\n\
                 \n           bench_*_v1 / analysis_v1 documents; exit 3 on regression)\n\
                 \n  validate --artifacts artifacts\n\
                 \n  info     --artifacts artifacts"
            );
            2
        }
    };
    std::process::exit(code);
}

/// Parse `--chunk-bytes` (absent → `default`, which may be 0 = the
/// built-in datapath default); invalid values die with one line and
/// exit code 2, like every other axis.
fn parse_chunk_bytes(args: &Args, default: usize) -> Result<usize, i32> {
    match args.flag("chunk-bytes") {
        None => Ok(default),
        Some(s) => match s.parse::<usize>() {
            Ok(b) if b >= 1 => Ok(b),
            _ => {
                distarray::log!(Error, "invalid --chunk-bytes '{s}' (expected a byte count >= 1)");
                Err(2)
            }
        },
    }
}

/// Parse `--metrics-interval` in milliseconds (absent → no sampler).
fn parse_metrics_interval(args: &Args) -> Result<Option<std::time::Duration>, i32> {
    match args.flag("metrics-interval") {
        None => Ok(None),
        Some(s) => match s.parse::<u64>() {
            Ok(ms) if ms >= 1 => Ok(Some(std::time::Duration::from_millis(ms))),
            _ => {
                distarray::log!(Error, "invalid --metrics-interval '{s}' (expected milliseconds >= 1)");
                Err(2)
            }
        },
    }
}

/// Enable tracing for an in-process bench when `--trace <path|->` is
/// given: this process is rank 0, the NDJSON sink opens immediately,
/// recording turns on, and `--metrics-interval` starts the counter
/// sampler. Returns whether a trace was set up (so the command can
/// close it on exit).
fn setup_local_trace(args: &Args) -> Result<bool, i32> {
    let Some(path) = args.flag("trace") else {
        return Ok(false);
    };
    let interval = parse_metrics_interval(args)?;
    distarray::obs::set_rank(0);
    if let Err(e) = distarray::obs::emit::install_sink(path) {
        distarray::log!(Error, "--trace {path}: {e}");
        return Err(1);
    }
    distarray::obs::set_enabled(true);
    if let Some(iv) = interval {
        distarray::obs::emit::start_metrics_sampler(iv);
    }
    Ok(true)
}

/// Flush and close the local trace (no-op when tracing is off).
fn finish_local_trace(traced: bool) {
    if traced {
        distarray::obs::emit::stop_metrics_sampler();
        distarray::obs::emit::close_sink();
    }
}

/// Parse one axis flag: absent → `default`, unknown value → a
/// one-line error naming the valid choices plus the exit code (every
/// axis shares this wording — never a silent fallback or an opaque
/// parse failure).
fn axis_flag<T>(
    args: &Args,
    name: &str,
    choices: &str,
    default: T,
    parse: impl Fn(&str) -> Option<T>,
) -> Result<T, i32> {
    match args.flag(name) {
        None => Ok(default),
        Some(s) => parse(s).ok_or_else(|| {
            distarray::log!(Error, "unknown {name} '{s}' (expected {choices})");
            2
        }),
    }
}

/// `repro run` — spawn triples-mode workers, coordinate one benchmark.
/// Flags override `--config <file.json>` values, which override defaults.
fn cmd_run(args: &Args) -> i32 {
    let base = match args.flag("config") {
        Some(path) => match distarray::config::LaunchConfig::load(path) {
            Ok(c) => c,
            Err(e) => {
                distarray::log!(Error, "config {path}: {e}");
                return 2;
            }
        },
        None => distarray::config::LaunchConfig::default_config(),
    };
    let triples = match axis_flag(
        args,
        "triples",
        "NnodesxNppnxNtpn, e.g. 1x4x1",
        base.triples,
        Triples::parse,
    ) {
        Ok(v) => v,
        Err(code) => return code,
    };
    let n = args.flag_usize("n", base.run.n_global);
    let nt = args.flag_usize("nt", base.run.nt);
    let map = match axis_flag(
        args,
        "map",
        "block|cyclic|blockcyclic:K",
        base.run.map,
        MapKind::parse,
    ) {
        Ok(v) => v,
        Err(code) => return code,
    };
    let engine = match axis_flag(
        args,
        "engine",
        "native|pjrt|pjrt-fused",
        base.run.engine,
        EngineKind::parse,
    ) {
        Ok(v) => v,
        Err(code) => return code,
    };
    let dtype = match axis_flag(
        args,
        "dtype",
        "f32|f64|i64|u64",
        base.run.dtype,
        distarray::element::Dtype::parse,
    ) {
        Ok(v) => v,
        Err(code) => return code,
    };
    let backend = match axis_flag(
        args,
        "backend",
        BackendKind::choices(),
        base.run.backend,
        BackendKind::parse,
    ) {
        Ok(v) => v,
        Err(code) => return code,
    };
    let coll = match axis_flag(args, "coll", CollKind::choices(), base.run.coll, CollKind::parse) {
        Ok(v) => v,
        Err(code) => return code,
    };
    let chunk_bytes = match parse_chunk_bytes(args, base.run.chunk_bytes) {
        Ok(v) => v,
        Err(code) => return code,
    };
    let transport = match axis_flag(
        args,
        "transport",
        TransportKind::CHOICES,
        base.run.transport,
        TransportKind::parse,
    ) {
        Ok(v) => v,
        Err(code) => return code,
    };
    let recv_timeout_ms = match args.flag("recv-timeout-ms") {
        None => base.run.recv_timeout_ms,
        Some(s) => match s.parse::<u64>() {
            Ok(ms) if ms >= 1 => ms,
            _ => {
                distarray::log!(
                    Error,
                    "invalid --recv-timeout-ms '{s}' (expected milliseconds >= 1)"
                );
                return 2;
            }
        },
    };
    // `--trace` names the leader's NDJSON file (`-` = stderr); a
    // config file can also set `"trace": true` and take the default
    // name. Workers write `<path>.rank<pid>` beside it.
    let trace_path: Option<String> = match args.flag("trace") {
        Some(p) => Some(p.to_string()),
        None if base.run.trace => Some("trace.ndjson".into()),
        None => None,
    };
    let metrics_interval = match parse_metrics_interval(args) {
        Ok(v) => v,
        Err(code) => return code,
    };
    let heartbeat = args.flag_bool("heartbeat");
    let checkpoint = args.flag("checkpoint").unwrap_or("").to_string();
    let restore = args.flag_bool("restore");
    if restore && checkpoint.is_empty() {
        distarray::log!(Error, "--restore needs --checkpoint <dir> (where are the shards?)");
        return 2;
    }
    if !checkpoint.is_empty() && engine != EngineKind::Native {
        distarray::log!(
            Error,
            "--checkpoint applies to the native engine; engine {} keeps state device-side",
            engine.name()
        );
        return 2;
    }
    if engine != EngineKind::Native && dtype != distarray::element::Dtype::F64 {
        distarray::log!(
            Error,
            "engine {} is f64-only; use --engine native for --dtype {dtype}",
            engine.name()
        );
        return 2;
    }
    if engine != EngineKind::Native && backend != BackendKind::Host {
        distarray::log!(
            Error,
            "--backend applies to the native engine; engine {} has its own execution path",
            engine.name()
        );
        return 2;
    }
    if !dtype.is_float() {
        distarray::log!(
            Warn,
            "dtype {dtype} runs with q = 0 (integer STREAM degenerates; \
             bandwidth numbers remain meaningful)"
        );
    }
    let artifacts = args.flag_str("artifacts", &base.run.artifacts).to_string();
    // Validate the backend before spawning anything: availability (the
    // pjrt backend exists in every build but executes only with the
    // feature + a vendored xla + generated artifacts) AND capability
    // for this run's dtype and PID-0 local length, so misconfigured
    // runs die with one line here instead of a worker panic.
    if engine == EngineKind::Native {
        let probe = BackendRegistry::with_defaults(triples.ntpn, &artifacts);
        let be = probe.get(backend).expect("default registry covers every kind");
        if !be.available() {
            distarray::log!(
                Error,
                "backend '{backend}' is unavailable in this build/environment \
                 (the pjrt backend needs `--features pjrt` and AOT artifacts)"
            );
            return 2;
        }
        let dmap = map.to_map(triples.np());
        for pid in 0..triples.np() {
            if let Err(e) = be.prepare_alloc(dtype, dmap.local_size(pid, &[n])) {
                distarray::log!(
                    Error,
                    "backend '{backend}' cannot run this configuration (pid {pid}): {e}"
                );
                return 2;
            }
        }
    }
    let spool = std::env::temp_dir().join(format!("distarray_run_{}", std::process::id()));

    let cfg = RunConfig {
        n_global: n,
        nt,
        q: base.run.q,
        map,
        engine,
        dtype,
        backend,
        threads: triples.ntpn,
        coll,
        nppn: triples.nppn,
        chunk_bytes,
        artifacts,
        trace: trace_path.is_some(),
        heartbeat,
        checkpoint,
        restore,
        transport,
        recv_timeout_ms,
    };
    // Any library collective in this process (darray reductions,
    // barriers) follows the configured algorithm too — and spawned
    // worker processes inherit it through the environment (read back
    // in `cmd_worker`), so an ambient-routed collective spanning the
    // whole world runs one algorithm everywhere. The datapath chunk
    // size travels the same way.
    distarray::collective::set_ambient(coll, triples.nppn);
    std::env::set_var("DISTARRAY_COLL", coll.name());
    std::env::set_var("DISTARRAY_NPPN", triples.nppn.to_string());
    if chunk_bytes > 0 {
        distarray::comm::datapath::set_ambient_chunk_bytes(chunk_bytes);
        std::env::set_var("DISTARRAY_CHUNK_BYTES", chunk_bytes.to_string());
    }
    // The receive patience travels both ways: set here for this
    // process (and workers, via the environment) so even the config
    // broadcast obeys it, and carried in the config wire so workers
    // re-apply it authoritatively after decode.
    if recv_timeout_ms > 0 {
        distarray::comm::set_default_recv_timeout_ms(recv_timeout_ms);
        std::env::set_var("DISTARRAY_RECV_TIMEOUT_MS", recv_timeout_ms.to_string());
    }
    if let Some(path) = &trace_path {
        // Workers learn the trace file and sampler interval from the
        // environment (like the collective/chunk axes above); the
        // config's `trace` bit keeps the wire exchange in lockstep.
        std::env::set_var("DISTARRAY_TRACE", path);
        if let Some(iv) = metrics_interval {
            std::env::set_var("DISTARRAY_METRICS_INTERVAL_MS", iv.as_millis().to_string());
        }
        distarray::obs::set_rank(0);
        if let Err(e) = distarray::obs::emit::install_sink(path) {
            distarray::log!(Error, "--trace {path}: {e}");
            return 1;
        }
        distarray::obs::set_enabled(true);
        if let Some(iv) = metrics_interval {
            distarray::obs::emit::start_metrics_sampler(iv);
        }
    }
    println!(
        "repro run: triples={triples} Np={} N={n} Nt={nt} engine={} dtype={} backend={} coll={} transport={}",
        triples.np(),
        cfg.engine.name(),
        cfg.dtype,
        cfg.backend,
        cfg.coll,
        cfg.transport
    );

    let plan = PinPlan::for_node(&triples);
    plan.apply(0);

    // Channel endpoints cannot cross a process boundary: the whole
    // world runs in this process, workers on threads — no spool, no
    // spawns, the fastest path for single-node smoke runs.
    if transport == TransportKind::Channel {
        let mut world = distarray::comm::ChannelHub::world(triples.np());
        let leader = world.remove(0);
        let handles: Vec<_> = world
            .into_iter()
            .map(|t| std::thread::spawn(move || run_worker(&t)))
            .collect();
        let out = run_leader(&leader, &cfg);
        let mut ok = true;
        for h in handles {
            ok &= matches!(h.join(), Ok(Ok(rep)) if rep.passed);
        }
        return match out {
            Ok((agg, results)) => {
                ok &= report_run(args, &cfg, &agg, &results);
                finish_local_trace(trace_path.is_some());
                if let Some(path) = trace_path.as_deref().filter(|p| *p != "-") {
                    println!("trace written to {path}");
                }
                i32::from(!ok)
            }
            Err(e) => {
                distarray::log!(Error, "leader failed: {e}");
                finish_local_trace(trace_path.is_some());
                1
            }
        };
    }

    // TCP-backed worlds rendezvous through the leader: bind the boot
    // and data listeners before spawning so the boot address rides the
    // workers' environment.
    let mut rendezvous = None;
    if matches!(transport, TransportKind::Tcp | TransportKind::Hybrid) {
        match TcpRendezvous::leader(triples.np()) {
            Ok(r) => {
                std::env::set_var("DISTARRAY_TCP_BOOT", r.boot_addr());
                rendezvous = Some(r);
            }
            Err(e) => {
                distarray::log!(Error, "tcp rendezvous: {e}");
                return 1;
            }
        }
    }
    std::env::set_var("DISTARRAY_TRANSPORT", transport.name());

    let workers = match spawn_workers(&triples, &spool, &[]) {
        Ok(w) => w,
        Err(e) => {
            distarray::log!(Error, "spawn failed: {e}");
            return 1;
        }
    };
    let np = triples.np();
    let built: Result<Box<dyn Transport>, distarray::comm::CommError> = match transport {
        TransportKind::File => {
            FileTransport::new(&spool, 0, np).map(|t| Box::new(t) as Box<dyn Transport>)
        }
        TransportKind::Shmem => ShmemTransport::new(&spool, 0, np)
            .map(|t| Box::new(t) as Box<dyn Transport>)
            .map_err(Into::into),
        TransportKind::Tcp => rendezvous
            .take()
            .expect("bound above")
            .complete_leader()
            .map(|t| Box::new(t) as Box<dyn Transport>)
            .map_err(Into::into),
        TransportKind::Hybrid => ShmemTransport::new(&spool, 0, np)
            .and_then(|sh| {
                let tcp = rendezvous.take().expect("bound above").complete_leader()?;
                let topo = distarray::collective::Topology::grouped(np, triples.nppn);
                Ok(Box::new(HybridTransport::new(sh, tcp, topo)) as Box<dyn Transport>)
            })
            .map_err(Into::into),
        TransportKind::Channel => unreachable!("channel worlds return above"),
    };
    let leader = match built {
        Ok(t) => t,
        Err(e) => {
            distarray::log!(Error, "transport: {e}");
            for w in workers {
                let pid = w.pid;
                if let Err(ke) = w.kill() {
                    distarray::log!(Warn, "reaping worker pid {pid}: {ke}");
                }
            }
            std::fs::remove_dir_all(&spool).ok();
            finish_local_trace(trace_path.is_some());
            return 1;
        }
    };
    match run_leader(&*leader, &cfg) {
        Ok((agg, results)) => {
            let mut ok = report_run(args, &cfg, &agg, &results);
            for w in workers {
                ok &= w.wait().unwrap_or(false);
            }
            finish_local_trace(trace_path.is_some());
            if let Some(path) = trace_path.as_deref().filter(|p| *p != "-") {
                println!("trace written to {path} (+ {path}.rank<pid> per worker)");
            }
            std::fs::remove_dir_all(&spool).ok();
            i32::from(!ok)
        }
        Err(e) => {
            distarray::log!(Error, "leader failed: {e}");
            // Reap every spawned worker (kill + wait — no zombies, no
            // orphans spinning on a dead spool) and remove the spool
            // so a failed run leaves no stale rendezvous files behind.
            for w in workers {
                let pid = w.pid;
                if let Err(ke) = w.kill() {
                    distarray::log!(Warn, "reaping worker pid {pid}: {ke}");
                }
            }
            std::fs::remove_dir_all(&spool).ok();
            finish_local_trace(trace_path.is_some());
            1
        }
    }
}

/// Print the per-rank and aggregate lines and write `--bench-json`;
/// true iff everything validated and any JSON wrote cleanly.
fn report_run(
    args: &Args,
    cfg: &RunConfig,
    agg: &distarray::stream::AggregateResult,
    results: &[distarray::stream::StreamResult],
) -> bool {
    for r in results {
        println!(
            "  pid n_local={:<10} triad={:<12} backend={:<9} ok={}",
            r.n_local,
            fmt_bw(r.triad_bw()),
            r.backend.name(),
            r.validation.passed
        );
    }
    println!(
        "AGGREGATE[{}]: copy={} scale={} add={} triad={} ({:.3e} elem/s @ {}B/elem) validated={}",
        agg.backend,
        fmt_bw(agg.bw[0]),
        fmt_bw(agg.bw[1]),
        fmt_bw(agg.bw[2]),
        fmt_bw(agg.bw[3]),
        agg.triad_elements_per_sec(),
        agg.width,
        agg.all_valid
    );
    let mut ok = agg.all_valid;
    if let Some(path) = args.flag("bench-json") {
        match bench_json::write_file(path, cfg, agg) {
            Ok(()) => println!("bench json written to {path}"),
            Err(e) => {
                distarray::log!(Error, "bench-json {path}: {e}");
                ok = false;
            }
        }
    }
    ok
}

/// `repro chaos` — the kill-one-worker fault drill: an in-process
/// `--np`-rank world runs a remap, `--kill` dies, the leader's
/// detector declares it dead, the survivors re-deal under a bumped
/// epoch, and every survivor shard is compared bit-for-bit against
/// the clean survivor reference. Exit 0 iff the drill recovered
/// bit-identically. `DISTARRAY_FAULT_HB_*` tune the detector;
/// `--trace` records the `fault_*` telemetry events.
fn cmd_chaos(args: &Args) -> i32 {
    use distarray::fault::DetectorConfig;
    let np = args.flag_usize("np", 4);
    let kill = args.flag_usize("kill", 2);
    let n = args.flag_usize("n", 1 << 20);
    let dtype = match axis_flag(
        args,
        "dtype",
        "f32|f64|i64|u64",
        distarray::element::Dtype::F64,
        distarray::element::Dtype::parse,
    ) {
        Ok(v) => v,
        Err(code) => return code,
    };
    let transport = match axis_flag(
        args,
        "transport",
        "channel|file|shmem|tcp",
        TransportKind::Channel,
        TransportKind::parse,
    ) {
        Ok(TransportKind::Hybrid) => {
            distarray::log!(Error, "chaos drills one transport at a time; pick channel|file|shmem|tcp");
            return 2;
        }
        Ok(v) => v,
        Err(code) => return code,
    };
    let traced = match setup_local_trace(args) {
        Ok(t) => t,
        Err(code) => return code,
    };
    let cfg = DetectorConfig::from_env();
    println!(
        "repro chaos: np={np} kill={kill} n={n} dtype={dtype} transport={transport} \
         hb_interval={:?} hb_misses={}",
        cfg.interval, cfg.miss_threshold
    );
    let scratch = std::env::temp_dir().join(format!("distarray_chaos_{}", std::process::id()));
    let report = match transport {
        TransportKind::Channel => {
            chaos_on_world(distarray::comm::ChannelHub::world(np), dtype, kill, n, cfg)
        }
        TransportKind::File => {
            let worlds: Result<Vec<_>, _> =
                (0..np).map(|p| FileTransport::new(&scratch, p, np)).collect();
            match worlds {
                Ok(w) => chaos_on_world(w, dtype, kill, n, cfg),
                Err(e) => Err(format!("transport: {e}")),
            }
        }
        TransportKind::Shmem => match ShmemTransport::world(&scratch, np) {
            Ok(w) => chaos_on_world(w, dtype, kill, n, cfg),
            Err(e) => Err(format!("transport: {e}")),
        },
        TransportKind::Tcp => match TcpRendezvous::loopback_world(np) {
            Ok(w) => chaos_on_world(w, dtype, kill, n, cfg),
            Err(e) => Err(format!("transport: {e}")),
        },
        TransportKind::Hybrid => unreachable!("rejected above"),
    };
    std::fs::remove_dir_all(&scratch).ok();
    let code = match report {
        Ok(r) => {
            println!(
                "CHAOS: killed={} survivors={:?} probe_rounds={} bit_identical={}",
                r.killed, r.survivors, r.probe_rounds, r.bit_identical
            );
            i32::from(!r.bit_identical)
        }
        Err(e) => {
            distarray::log!(Error, "chaos drill failed: {e}");
            1
        }
    };
    finish_local_trace(traced);
    code
}

/// Wrap an in-process world in the deterministic fault injector and
/// run the chaos drill for the requested dtype.
fn chaos_on_world<Tr: Transport>(
    world: Vec<Tr>,
    dtype: distarray::element::Dtype,
    kill: usize,
    n: usize,
    cfg: distarray::fault::DetectorConfig,
) -> Result<distarray::fault::ChaosReport, String> {
    use distarray::fault::{run_chaos_on, FaultPlan, FaultTransport};
    let endpoints: Vec<_> = world
        .into_iter()
        .map(|t| FaultTransport::new(t, FaultPlan::default()))
        .collect();
    match dtype {
        distarray::element::Dtype::F64 => run_chaos_on::<f64, _>(endpoints, kill, n, cfg),
        distarray::element::Dtype::F32 => run_chaos_on::<f32, _>(endpoints, kill, n, cfg),
        distarray::element::Dtype::I64 => run_chaos_on::<i64, _>(endpoints, kill, n, cfg),
        distarray::element::Dtype::U64 => run_chaos_on::<u64, _>(endpoints, kill, n, cfg),
    }
}

/// `repro bench-remap` — measure the coalesced remap hot path with
/// in-process SPMD PIDs and emit/print a `bench_remap_v1` document.
fn cmd_bench_remap(args: &Args) -> i32 {
    let np = args.flag_usize("np", 4);
    let n = args.flag_usize("n", 1 << 20);
    let iters = args.flag_usize("iters", 10);
    let dtype = match axis_flag(
        args,
        "dtype",
        "f32|f64|i64|u64",
        distarray::element::Dtype::F64,
        distarray::element::Dtype::parse,
    ) {
        Ok(v) => v,
        Err(code) => return code,
    };
    if np == 0 || n == 0 || iters == 0 {
        distarray::log!(Error, "bench-remap: --np, --n and --iters must all be >= 1");
        return 2;
    }
    let traced = match setup_local_trace(args) {
        Ok(t) => t,
        Err(code) => return code,
    };
    let b = bench_json::run_remap(np, n, iters, dtype);
    println!(
        "bench-remap: np={np} n={n} dtype={dtype} iters={iters} \
         msgs/remap={:.0} bytes={} payload={} {:.3} GB/s",
        b.messages as f64 / iters as f64,
        b.bytes_moved,
        b.payload_bytes,
        b.gb_per_sec()
    );
    let mut code = 0;
    if let Some(path) = args.flag("bench-json") {
        match bench_json::write_remap_file(path, &b) {
            Ok(()) => println!("bench json written to {path}"),
            Err(e) => {
                distarray::log!(Error, "bench-json {path}: {e}");
                code = 1;
            }
        }
    }
    finish_local_trace(traced);
    code
}

/// `repro bench-collective` — measure every collective algorithm ×
/// operation across a list of world sizes with in-process SPMD PIDs
/// and emit/print a `bench_collective_v1` document.
fn cmd_bench_collective(args: &Args) -> i32 {
    let np_list: Vec<usize> = args
        .flag_str("np-list", "2,4,8")
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.trim().parse::<usize>())
        .collect::<Result<_, _>>()
        .unwrap_or_default();
    if np_list.is_empty() || np_list.contains(&0) {
        distarray::log!(Error, "bench-collective: --np-list must be comma-separated positive integers");
        return 2;
    }
    let kinds: Vec<CollKind> = {
        let spec = args.flag_str("coll", "star,tree,ring,hier");
        let mut out = Vec::new();
        for s in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            match CollKind::parse(s) {
                Some(k) => out.push(k),
                None => {
                    distarray::log!(Error, "unknown coll '{s}' (expected {})", CollKind::choices());
                    return 2;
                }
            }
        }
        out
    };
    if kinds.is_empty() {
        distarray::log!(Error, "bench-collective: --coll selected no algorithms");
        return 2;
    }
    let nppn = args.flag_usize("nppn", 2);
    let bytes = args.flag_usize("bytes", 64 << 10);
    let iters = args.flag_usize("iters", 20);
    if bytes == 0 || iters == 0 {
        distarray::log!(Error, "bench-collective: --bytes and --iters must be >= 1");
        return 2;
    }
    match parse_chunk_bytes(args, 0) {
        Ok(0) => {}
        Ok(b) => distarray::comm::datapath::set_ambient_chunk_bytes(b),
        Err(code) => return code,
    }
    let traced = match setup_local_trace(args) {
        Ok(t) => t,
        Err(code) => return code,
    };
    let mut records = Vec::new();
    for &np in &np_list {
        records.extend(bench_json::run_collective(np, nppn, &kinds, bytes, iters));
    }
    println!(
        "bench-collective: np-list={np_list:?} nppn={nppn} bytes={bytes} iters={iters}"
    );
    println!(
        "{:<6} {:<10} {:>4} {:>6} {:>10} {:>12} {:>12}",
        "coll", "op", "np", "nodes", "msgs/op", "bytes/op", "avg µs"
    );
    for r in &records {
        println!(
            "{:<6} {:<10} {:>4} {:>6} {:>10.1} {:>12.0} {:>12.1}",
            r.coll.name(),
            r.op,
            r.np,
            r.nodes,
            r.msgs_per_op(),
            r.bytes_moved as f64 / r.iters as f64,
            r.avg_latency_us()
        );
    }
    let mut code = 0;
    if let Some(path) = args.flag("bench-json") {
        match bench_json::write_collective_file(path, &records) {
            Ok(()) => println!("bench json written to {path}"),
            Err(e) => {
                distarray::log!(Error, "bench-json {path}: {e}");
                code = 1;
            }
        }
    }
    finish_local_trace(traced);
    code
}

/// `repro bench-overlap` — measure how much of the wire time the
/// chunk-granular datapath hides behind compute: the remap and
/// elimination-allreduce phases each run wire-only, compute-only,
/// serial (overlap off), and overlapped, and emit/print a
/// `bench_overlap_v1` document.
fn cmd_bench_overlap(args: &Args) -> i32 {
    let np = args.flag_usize("np", 4);
    let bytes = args.flag_usize("bytes", 64 << 20);
    let iters = args.flag_usize("iters", 3);
    if np < 2 || bytes < 8 || iters == 0 {
        distarray::log!(Error, "bench-overlap: need --np >= 2, --bytes >= 8 and --iters >= 1");
        return 2;
    }
    let chunk = match parse_chunk_bytes(args, 0) {
        Ok(b) => b,
        Err(code) => return code,
    };
    if chunk > 0 {
        distarray::comm::datapath::set_ambient_chunk_bytes(chunk);
    }
    let traced = match setup_local_trace(args) {
        Ok(t) => t,
        Err(code) => return code,
    };
    let records = bench_json::run_overlap(np, bytes, iters, chunk);
    println!("bench-overlap: np={np} bytes-per-rank={bytes} iters={iters}");
    println!(
        "{:<10} {:>10} {:>10} {:>10} {:>10} {:>8} {:>8}",
        "phase", "wire s", "compute s", "serial s", "total s", "eff", "speedup"
    );
    for r in &records {
        println!(
            "{:<10} {:>10.4} {:>10.4} {:>10.4} {:>10.4} {:>8.3} {:>8.3}",
            r.phase,
            r.wire_seconds,
            r.compute_seconds,
            r.serial_seconds,
            r.total_seconds,
            r.efficiency(),
            r.speedup_vs_serial()
        );
    }
    let mut code = 0;
    if let Some(path) = args.flag("bench-json") {
        match bench_json::write_overlap_file(path, &records) {
            Ok(()) => println!("bench json written to {path}"),
            Err(e) => {
                distarray::log!(Error, "bench-json {path}: {e}");
                code = 1;
            }
        }
    }
    finish_local_trace(traced);
    code
}

/// `repro bench-transport` — measure each selected transport's
/// small-message round-trip time and `ChunkStream` goodput over an
/// in-process two-rank world of that transport, and emit/print a
/// `bench_transport_v1` document. The committed
/// `bench/BENCH_transport.json` baseline is produced by exactly this
/// command; CI diffs fresh numbers against it (report-only).
fn cmd_bench_transport(args: &Args) -> i32 {
    let iters = args.flag_usize("iters", 200);
    let bytes = args.flag_usize("bytes", 4 << 20);
    if iters == 0 || bytes < 8 {
        distarray::log!(Error, "bench-transport: need --iters >= 1 and --bytes >= 8");
        return 2;
    }
    let kinds: Vec<TransportKind> = {
        let spec = args.flag_str("transport", "channel,file,shmem,tcp,hybrid");
        let mut out = Vec::new();
        for s in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            match TransportKind::parse(s) {
                Some(k) => out.push(k),
                None => {
                    distarray::log!(
                        Error,
                        "unknown transport '{s}' (expected {})",
                        TransportKind::CHOICES
                    );
                    return 2;
                }
            }
        }
        out
    };
    if kinds.is_empty() {
        distarray::log!(Error, "bench-transport: --transport selected no transports");
        return 2;
    }
    match parse_chunk_bytes(args, 0) {
        Ok(0) => {}
        Ok(b) => distarray::comm::datapath::set_ambient_chunk_bytes(b),
        Err(code) => return code,
    }
    let traced = match setup_local_trace(args) {
        Ok(t) => t,
        Err(code) => return code,
    };
    let records = bench_json::run_transport(&kinds, iters, bytes);
    println!("bench-transport: iters={iters} bytes={bytes} np=2");
    println!(
        "{:<9} {:>8} {:>12} {:>12} {:>12}",
        "transport", "ping B", "rtt µs", "stream MB", "GB/s"
    );
    for b in &records {
        println!(
            "{:<9} {:>8} {:>12.2} {:>12.1} {:>12.3}",
            b.transport.name(),
            b.ping_bytes,
            b.rtt_us(),
            b.stream_bytes as f64 / 1e6,
            b.stream_gb_per_sec()
        );
    }
    // An empty table means every selected world failed to build —
    // that is a failure, not a trivially green bench.
    let mut code = i32::from(records.is_empty());
    if let Some(path) = args.flag("bench-json") {
        match bench_json::write_transport_file(path, &records) {
            Ok(()) => println!("bench json written to {path}"),
            Err(e) => {
                distarray::log!(Error, "bench-json {path}: {e}");
                code = 1;
            }
        }
    }
    finish_local_trace(traced);
    code
}

/// `repro worker` — internal entry for spawned workers.
fn cmd_worker() -> i32 {
    let Some(env) = WorkerEnv::from_env() else {
        distarray::log!(Error, "worker: missing DISTARRAY_* environment");
        return 1;
    };
    // Install the launch's collective algorithm as this process's
    // default (inherited from the leader's environment) so
    // ambient-routed collectives agree across the whole world. The
    // explicit coordinator paths carry the algorithm in the config;
    // this covers any library collective the run itself performs.
    if let Some(kind) = std::env::var("DISTARRAY_COLL").ok().as_deref().and_then(CollKind::parse) {
        let nppn = std::env::var("DISTARRAY_NPPN").ok().and_then(|s| s.parse().ok()).unwrap_or(0);
        distarray::collective::set_ambient(kind, nppn);
    }
    if let Some(b) = std::env::var("DISTARRAY_CHUNK_BYTES")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&b| b >= 1)
    {
        distarray::comm::datapath::set_ambient_chunk_bytes(b);
    }
    // The leader exports DISTARRAY_TRACE for traced runs: each worker
    // opens its own per-rank NDJSON file beside the leader's (`-`
    // traces to this process's stderr). Recording turns on before the
    // transport opens so even the config-broadcast arrivals are
    // captured — the causal matcher pairs them with the leader's
    // sends.
    if let Ok(path) = std::env::var("DISTARRAY_TRACE") {
        distarray::obs::set_rank(env.pid);
        distarray::obs::set_enabled(true);
        let mine =
            if path == "-" { path } else { format!("{path}.rank{}", env.pid) };
        if let Err(e) = distarray::obs::emit::install_sink(&mine) {
            distarray::log!(Error, "worker {} trace sink {mine}: {e}", env.pid);
            return 1;
        }
        if let Some(ms) = std::env::var("DISTARRAY_METRICS_INTERVAL_MS")
            .ok()
            .and_then(|s| s.parse::<u64>().ok())
            .filter(|&ms| ms >= 1)
        {
            distarray::obs::emit::start_metrics_sampler(std::time::Duration::from_millis(ms));
        }
    }
    // Pin to the adjacent-core plan slot.
    let triples = Triples::new(1, env.np, env.ntpn);
    PinPlan::for_node(&triples).apply(env.slot.min(env.np - 1));
    // The leader names the wire (`DISTARRAY_TRANSPORT`); absent means
    // a legacy launcher, which spoke the file spool.
    let kind = match std::env::var("DISTARRAY_TRANSPORT") {
        Err(_) => TransportKind::File,
        Ok(s) => match TransportKind::parse(&s) {
            Some(k) => k,
            None => {
                distarray::log!(
                    Error,
                    "worker {}: unknown DISTARRAY_TRANSPORT '{s}' (expected {})",
                    env.pid,
                    TransportKind::CHOICES
                );
                return 1;
            }
        },
    };
    let code = match kind {
        TransportKind::File => match FileTransport::new(&env.spool, env.pid, env.np) {
            Ok(t) => worker_body(t, env.pid),
            Err(e) => worker_transport_err(env.pid, &e),
        },
        TransportKind::Shmem => match ShmemTransport::new(&env.spool, env.pid, env.np) {
            Ok(t) => worker_body(t, env.pid),
            Err(e) => worker_transport_err(env.pid, &e),
        },
        TransportKind::Tcp => match worker_tcp(env.pid) {
            Ok(t) => worker_body(t, env.pid),
            Err(e) => worker_transport_err(env.pid, &e),
        },
        TransportKind::Hybrid => {
            let built = ShmemTransport::new(&env.spool, env.pid, env.np).and_then(|sh| {
                let tcp = worker_tcp(env.pid)?;
                let nppn = std::env::var("DISTARRAY_NPPN")
                    .ok()
                    .and_then(|s| s.parse().ok())
                    .unwrap_or(0);
                let topo = distarray::collective::Topology::grouped(env.np, nppn);
                Ok(HybridTransport::new(sh, tcp, topo))
            });
            match built {
                Ok(t) => worker_body(t, env.pid),
                Err(e) => worker_transport_err(env.pid, &e),
            }
        }
        TransportKind::Channel => {
            distarray::log!(
                Error,
                "worker {}: channel transports cannot cross processes",
                env.pid
            );
            1
        }
    };
    distarray::obs::emit::stop_metrics_sampler();
    distarray::obs::emit::close_sink();
    code
}

/// Dial this worker's TCP endpoint through the leader's boot address.
fn worker_tcp(pid: usize) -> std::io::Result<distarray::comm::TcpTransport> {
    let boot = std::env::var("DISTARRAY_TCP_BOOT").map_err(|_| {
        std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "DISTARRAY_TCP_BOOT missing (leader did not open a rendezvous)",
        )
    })?;
    TcpRendezvous::worker(pid, &boot)
}

fn worker_transport_err(pid: usize, e: &dyn std::fmt::Display) -> i32 {
    distarray::log!(Error, "worker {pid} transport: {e}");
    1
}

/// The worker lifecycle on a concrete endpoint, with the
/// `DISTARRAY_FAULT_*` deterministic fault injector wrapped around it
/// when the environment asks for chaos (any transport composes).
fn worker_body<T: Transport>(t: T, pid: usize) -> i32 {
    use distarray::fault::{FaultPlan, FaultTransport};
    let result = match FaultPlan::from_env(pid) {
        Some(plan) => {
            distarray::log!(Warn, "worker {pid}: fault injection active: {plan:?}");
            run_worker(&FaultTransport::new(t, plan))
        }
        None => run_worker(&t),
    };
    match result {
        Ok(rep) => i32::from(!rep.passed),
        Err(e) => {
            distarray::log!(Error, "worker {pid} failed: {e}");
            1
        }
    }
}

/// `repro sweep fig3|fig4|petascale`.
fn cmd_sweep(args: &Args) -> i32 {
    match args.positional.first().map(String::as_str) {
        Some("fig3") => {
            let mut series = fig3::simulate_all();
            if args.flag_bool("measure") {
                let max_np = args.flag_usize("max-np", 8);
                let n_per_p = args.flag_usize("n-per-p", 1 << 22);
                let nt = args.flag_usize("nt", 5);
                match args.flag("backend") {
                    None => series.push(fig3::measured_series(max_np, n_per_p, nt)),
                    Some(s) => {
                        let Some(kind) = BackendKind::parse(s) else {
                            distarray::log!(
                                Error,
                                "unknown backend '{s}' (expected {})",
                                BackendKind::choices()
                            );
                            return 2;
                        };
                        let reg = BackendRegistry::with_defaults(
                            args.flag_usize("threads", 0),
                            args.flag_str("artifacts", "artifacts"),
                        );
                        let be = reg.get(kind).expect("default registry covers every kind");
                        if !be.available() {
                            distarray::log!(Error, "backend '{kind}' is unavailable in this build");
                            return 2;
                        }
                        match fig3::measured_series_on(be, max_np, n_per_p, nt) {
                            Ok(s) => series.push(s),
                            Err(e) => {
                                distarray::log!(Error, "backend '{kind}' cannot run this sweep: {e}");
                                return 2;
                            }
                        }
                    }
                }
            }
            if args.flag_bool("csv") {
                print!("{}", fig3::to_csv(&series));
            } else {
                print!("{}", fig3::render(&series));
            }
            0
        }
        Some("fig4") => {
            print!("{}", fig4::render());
            0
        }
        Some("petascale") => {
            print!("{}", petascale::render(args.flag_usize("max-nodes", 1024)));
            0
        }
        other => {
            distarray::log!(Error, "unknown sweep {other:?}; expected fig3|fig4|petascale");
            2
        }
    }
}

/// `repro report table1|table2|fig4`.
fn cmd_report(args: &Args) -> i32 {
    match args.positional.first().map(String::as_str) {
        Some("table1") => {
            print!("{}", table1::render());
            0
        }
        Some("table2") => {
            print!("{}", table2::render());
            0
        }
        Some("fig4") => {
            print!("{}", fig4::render());
            0
        }
        other => {
            distarray::log!(Error, "unknown report {other:?}; expected table1|table2|fig4");
            2
        }
    }
}

/// `repro trace-report` — merge per-rank NDJSON trace files into one
/// fleet summary. `--check` validates every line strictly first;
/// `--chrome out.json` exports a chrome://tracing document. All passes
/// stream, so trace size is bounded only by disk.
fn cmd_trace_report(args: &Args) -> i32 {
    use distarray::obs::report;
    if args.positional.is_empty() {
        distarray::log!(Error, "trace-report: name at least one NDJSON trace file");
        return 2;
    }
    let files = args.positional.clone();
    if args.flag_bool("check") {
        match report::check_files(&files) {
            Ok(rep) => {
                for w in &rep.warnings {
                    distarray::log!(Warn, "trace-report check: {w}");
                }
                println!(
                    "check ok: {} line(s), {} event(s), {} hist(s), {} warning(s)",
                    rep.lines,
                    rep.events,
                    rep.hists,
                    rep.warnings.len()
                );
            }
            Err(e) => {
                distarray::log!(Error, "trace-report check: {e}");
                return 1;
            }
        }
    }
    let fold = match report::fold_files(&files) {
        Ok(f) => f,
        Err(e) => {
            distarray::log!(Error, "trace-report: {e}");
            return 1;
        }
    };
    print!("{}", report::render_summary(&fold));
    if let Some(out) = args.flag("chrome") {
        match report::write_chrome(&files, out) {
            Ok(()) => println!("chrome trace written to {out} (load in chrome://tracing)"),
            Err(e) => {
                distarray::log!(Error, "trace-report chrome: {e}");
                return 1;
            }
        }
    }
    if args.flag_bool("analyze") {
        println!();
        return cmd_analyze(args);
    }
    0
}

/// `repro analyze` — causal attribution over per-rank traces: match
/// message edges, compute the critical path, per-rank idle time and
/// the straggler ranking, and report achieved vs modeled bandwidth.
/// `--json <path|->` also emits the versioned `analysis_v1` document.
fn cmd_analyze(args: &Args) -> i32 {
    use distarray::obs::analyze::{analyze_files, AnalyzeOpts};
    if args.positional.is_empty() {
        distarray::log!(Error, "analyze: name at least one NDJSON trace file");
        return 2;
    }
    let era_label = args.flag_str("era", "amd-e9");
    let Some(era) = distarray::hardware::Era::by_label(era_label) else {
        distarray::log!(Error, "analyze: unknown era '{era_label}' (see `repro report table1`)");
        return 2;
    };
    let nppn = match args.flag("nppn") {
        None => None,
        Some(s) => match s.parse::<usize>() {
            Ok(v) if v >= 1 => Some(v),
            _ => {
                distarray::log!(Error, "invalid --nppn '{s}' (expected a count >= 1)");
                return 2;
            }
        },
    };
    let opts =
        AnalyzeOpts { era: era.label, nppn, ntpn: args.flag_usize("ntpn", 1).max(1) };
    let analysis = match analyze_files(&args.positional, &opts) {
        Ok(a) => a,
        Err(e) => {
            distarray::log!(Error, "analyze: {e}");
            return 1;
        }
    };
    print!("{}", analysis.render());
    if let Some(path) = args.flag("json") {
        let mut doc = analysis.to_json();
        doc.push('\n');
        if path == "-" {
            print!("{doc}");
        } else if let Err(e) = std::fs::write(path, doc) {
            distarray::log!(Error, "analyze: write {path}: {e}");
            return 1;
        } else {
            println!("analysis_v1 written to {path}");
        }
    }
    0
}

/// `repro bench-diff` — the perf regression gate: compare two
/// same-schema `bench_*_v1` / `analysis_v1` documents field by field.
/// Exit 3 when any metric regresses beyond `--max-regress` percent
/// (default 10); `--report-only` prints the table but always exits 0
/// (CI baselines come from different machines).
fn cmd_bench_diff(args: &Args) -> i32 {
    use distarray::report::bench_diff;
    if args.positional.len() != 2 {
        distarray::log!(Error, "bench-diff: expected exactly OLD.json NEW.json");
        return 2;
    }
    let max_regress = args.flag_f64("max-regress", 10.0);
    let diff = match bench_diff::diff_files(
        &args.positional[0],
        &args.positional[1],
        max_regress,
    ) {
        Ok(d) => d,
        Err(e) => {
            distarray::log!(Error, "bench-diff: {e}");
            return 1;
        }
    };
    print!("{}", diff.render());
    if diff.regressions() > 0 && !args.flag_bool("report-only") {
        distarray::log!(
            Error,
            "bench-diff: {} metric(s) regressed beyond {max_regress}%",
            diff.regressions()
        );
        return 3;
    }
    0
}

/// `repro validate` — prove the three layers compose: run the PJRT
/// artifacts (Pallas kernels lowered through JAX) and check against
/// the closed forms.
fn cmd_validate(args: &Args) -> i32 {
    use distarray::runtime::PjrtRuntime;
    let dir = args.flag_str("artifacts", "artifacts");
    let rt = match PjrtRuntime::load(dir) {
        Ok(rt) => rt,
        Err(e) => {
            distarray::log!(Error, "load artifacts: {e}");
            return 1;
        }
    };
    let n = rt.n();
    println!("platform={} n={} nt={}", rt.platform(), n, rt.nt());
    let a = vec![1.0f64; n];
    // Full run + validate, all inside the artifacts.
    let (a2, b2, c2) = match rt.run(&a, STREAM_Q) {
        Ok(x) => x,
        Err(e) => {
            distarray::log!(Error, "run artifact failed: {e}");
            return 1;
        }
    };
    let errs = rt.validate(&a2, &b2, &c2, STREAM_Q).expect("validate artifact");
    println!("pjrt errs: A={:.3e} B={:.3e} C={:.3e}", errs[0], errs[1], errs[2]);
    let tol = 1e-10 * rt.nt() as f64;
    // Cross-check against the native closed forms too.
    let rep = distarray::stream::validate(&a2, &b2, &c2, 1.0, STREAM_Q, rt.nt());
    println!("native cross-check: passed={} max_err={:.3e}", rep.passed, rep.max_err());
    if errs.iter().all(|e| *e < tol) && rep.passed {
        println!("VALIDATE OK — L1 Pallas → L2 JAX → HLO → L3 rust/PJRT agree");
        0
    } else {
        println!("VALIDATE FAILED");
        1
    }
}

/// `repro info` — environment summary.
fn cmd_info(args: &Args) -> i32 {
    println!(
        "distarray {} — Easy Acceleration with Distributed Arrays",
        env!("CARGO_PKG_VERSION")
    );
    println!("cores online: {}", distarray::launcher::pinning::online_cores());
    let dir = args.flag_str("artifacts", "artifacts");
    match distarray::runtime::Manifest::load(dir) {
        Ok(m) => {
            println!("artifacts: n={} nt={} ({} entries)", m.n, m.nt, m.artifacts.len());
            for name in m.artifacts.keys() {
                println!("  - {name}");
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    0
}
