//! Table II — the STREAM parameter schedule.
//!
//! The paper's rule (§V): start from a base per-process size
//! `N/Np = 2^30`; scale N with Np (constant local size) until the
//! node memory cap; past the cap hold N constant (shrinking local
//! size) and grow Nt to keep runtime a few hundred seconds. For
//! multi-node runs reuse the bolded single-node parameters and scale
//! N with the node count.

/// Parameters for one (hardware, Np) cell of Table II.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StreamParams {
    /// Trials.
    pub nt: usize,
    /// log2 of the per-process local vector length.
    pub log2_local: u32,
}

impl StreamParams {
    pub fn local_len(&self) -> usize {
        1usize << self.log2_local
    }

    /// Global N for `np` processes (constant local size).
    pub fn global_len(&self, np: usize) -> usize {
        self.local_len() * np
    }

    /// Memory footprint of the three vectors on one process, bytes,
    /// at the classic 8-byte (f64) width.
    pub fn local_bytes(&self) -> usize {
        self.local_bytes_for(8)
    }

    /// Memory footprint of the three vectors at an arbitrary element
    /// width (`width = Element::WIDTH`): an f32 schedule fits twice
    /// the elements in the same node memory.
    pub fn local_bytes_for(&self, width: usize) -> usize {
        3 * width * self.local_len()
    }
}

/// Derive the Table II schedule for a node: `base_log2` is the
/// starting per-process size (2^30 in the paper), `mem_bytes` the
/// node's memory, `base_nt` the starting trial count.
///
/// Returns `(np, params)` for np = 1,2,4,...  up to `max_np`.
pub fn schedule(
    base_log2: u32,
    base_nt: usize,
    mem_bytes: u64,
    max_np: usize,
) -> Vec<(usize, StreamParams)> {
    let mut out = Vec::new();
    let mut np = 1usize;
    // Usable fraction: the paper sizes to "a significant fraction" of
    // memory; we cap the three vectors at 80% of node RAM.
    let usable = (mem_bytes as f64 * 0.8) as u64;
    while np <= max_np {
        let mut p = StreamParams { nt: base_nt, log2_local: base_log2 };
        // Shrink local size (and grow Nt) until the node fits.
        while (p.local_bytes() as u64) * (np as u64) > usable {
            if p.log2_local == 0 {
                break;
            }
            p.log2_local -= 1;
            p.nt *= 2;
        }
        out.push((np, p));
        np *= 2;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const GIB: u64 = 1 << 30;

    #[test]
    fn paper_xeon_p8_schedule() {
        // xeon-p8: 192 GB, base 2^30, Nt=10 → Table II row:
        // Np=1..4: (10, 2^30); Np=8: (20, 2^29); 16: (40, 2^28); 32: (80, 2^27)
        let sched = schedule(30, 10, 192 * GIB, 32);
        let expect = [
            (1, 10, 30),
            (2, 10, 30),
            (4, 10, 30),
            (8, 20, 29),
            (16, 40, 28),
            (32, 80, 27),
        ];
        for ((np, p), (enp, ent, elog)) in sched.iter().zip(expect) {
            assert_eq!(*np, enp);
            assert_eq!(p.nt, ent, "np={np}");
            assert_eq!(p.log2_local, elog, "np={np}");
        }
    }

    #[test]
    fn paper_amd_e9_schedule() {
        // amd-e9: 750 GB → constant 2^30 through Np=16, shrink at 32.
        let sched = schedule(30, 20, 750 * GIB, 32);
        assert_eq!(sched[4], (16, StreamParams { nt: 20, log2_local: 30 }));
        assert_eq!(sched[5], (32, StreamParams { nt: 40, log2_local: 29 }));
    }

    #[test]
    fn bgp_tiny_memory() {
        // bg-p: 2 GB/node, base 2^25 → constant 2^25 for all Np (the
        // paper runs 2^25 across the board).
        let sched = schedule(25, 10, 2 * GIB, 2);
        assert_eq!(sched[0].1, StreamParams { nt: 10, log2_local: 25 });
    }

    #[test]
    fn memory_cap_respected() {
        for (np, p) in schedule(30, 10, 64 * GIB, 128) {
            assert!(
                (p.local_bytes() as u64) * (np as u64) <= (64 * GIB as u64 * 8 / 10) + 1,
                "np={np} {p:?}"
            );
        }
    }

    #[test]
    fn footprint_math() {
        let p = StreamParams { nt: 10, log2_local: 20 };
        assert_eq!(p.local_len(), 1 << 20);
        assert_eq!(p.local_bytes(), 24 << 20);
        assert_eq!(p.global_len(4), 4 << 20);
    }
}
