//! Algorithm 1 — serial STREAM over plain vectors.

use super::timing::{OpTimes, Timer};
use super::validate::{validate, STREAM_Q};
use super::{ops, StreamResult};

/// Initial values from the Code Listings: A0=1, B0=2, C0=0.
pub const A0: f64 = 1.0;
pub const B0: f64 = 2.0;
pub const C0: f64 = 0.0;

/// Run serial STREAM: `nt` iterations over `n`-element vectors.
///
/// Faithful to Algorithm 1: each op timed separately with tic/toc,
/// times accumulated across iterations. Note Add and Triad write into
/// an existing destination vector (in-place via a scratch swap keeps
/// the memory traffic identical to the C reference).
pub fn run_native_serial(n: usize, nt: usize, q: f64) -> StreamResult {
    assert!(n >= 1 && nt >= 1);
    let mut a = vec![A0; n];
    let mut b = vec![B0; n];
    let mut c = vec![C0; n];
    let mut times = OpTimes::zero();

    for _ in 0..nt {
        let t = Timer::tic();
        ops::copy(&mut c, &a); // Copy: C = A
        times.copy += t.toc();

        let t = Timer::tic();
        // Scale: B = q*C — write b from c.
        scale_into(&mut b, &c, q);
        times.scale += t.toc();

        let t = Timer::tic();
        // Add: C = A + B. C is also an input-free destination here
        // (A and B are the inputs), so in-place write is safe.
        add_into(&mut c, &a, &b);
        times.add += t.toc();

        let t = Timer::tic();
        // Triad: A = B + q*C — destination distinct from inputs.
        triad_into(&mut a, &b, &c, q);
        times.triad += t.toc();
    }

    let validation = validate(&a, &b, &c, A0, q, nt);
    StreamResult { n_global: n, n_local: n, nt, times, validation }
}

#[inline]
fn scale_into(dst: &mut [f64], src: &[f64], q: f64) {
    ops::scale(dst, src, q);
}

#[inline]
fn add_into(dst: &mut [f64], a: &[f64], b: &[f64]) {
    ops::add(dst, a, b);
}

#[inline]
fn triad_into(dst: &mut [f64], b: &[f64], c: &[f64], q: f64) {
    ops::triad(dst, b, c, q);
}

/// Convenience: run with the paper's defaults (q = √2−1).
pub fn run_default(n: usize, nt: usize) -> StreamResult {
    run_native_serial(n, nt, STREAM_Q)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_run_validates() {
        let r = run_default(10_000, 10);
        assert!(r.validation.passed, "{:?}", r.validation);
        assert_eq!(r.n_global, 10_000);
        assert_eq!(r.nt, 10);
    }

    #[test]
    fn bandwidths_positive_and_ordered_sanely() {
        let r = run_default(1 << 20, 5);
        let bw = r.bandwidths();
        for (i, b) in bw.iter().enumerate() {
            assert!(*b > 0.0, "op {i} bw {b}");
            // A laptop-class machine moves > 100 MB/s and < 10 TB/s.
            assert!(*b > 1e8 && *b < 1e13, "op {i} bw {b}");
        }
    }

    #[test]
    fn many_iterations_still_validate() {
        let r = run_default(1024, 200);
        assert!(r.validation.passed, "{:?}", r.validation);
    }

    #[test]
    fn n1_edge_case() {
        let r = run_default(1, 3);
        assert!(r.validation.passed);
    }
}
