//! Algorithm 1 — serial STREAM over plain vectors, generic over the
//! [`Element`] dtype (the classic run is [`run_native_serial`] = f64).

use super::timing::{OpTimes, Timer};
use super::validate::{validate_t, STREAM_Q};
use super::{ops, StreamResult};
use crate::element::Element;

/// Initial values from the Code Listings: A0=1, B0=2, C0=0.
pub const A0: f64 = 1.0;
pub const B0: f64 = 2.0;
pub const C0: f64 = 0.0;

/// Run serial STREAM at dtype `T`: `nt` iterations over `n`-element
/// vectors with scale factor `q`.
///
/// Faithful to Algorithm 1: each op timed separately with tic/toc,
/// times accumulated across iterations. Note Add and Triad write into
/// an existing destination vector (in-place via a scratch swap keeps
/// the memory traffic identical to the C reference).
pub fn run_serial_t<T: Element>(n: usize, nt: usize, q: T) -> StreamResult {
    assert!(n >= 1 && nt >= 1);
    let mut a = vec![T::from_f64(A0); n];
    let mut b = vec![T::from_f64(B0); n];
    let mut c = vec![T::from_f64(C0); n];
    let mut times = OpTimes::zero();

    for _ in 0..nt {
        let t = Timer::tic();
        ops::copy(&mut c, &a); // Copy: C = A
        times.copy += t.toc();

        let t = Timer::tic();
        // Scale: B = q*C — write b from c.
        ops::scale(&mut b, &c, q);
        times.scale += t.toc();

        let t = Timer::tic();
        // Add: C = A + B. C is also an input-free destination here
        // (A and B are the inputs), so in-place write is safe.
        ops::add(&mut c, &a, &b);
        times.add += t.toc();

        let t = Timer::tic();
        // Triad: A = B + q*C — destination distinct from inputs.
        ops::triad(&mut a, &b, &c, q);
        times.triad += t.toc();
    }

    let validation = validate_t(&a, &b, &c, A0, q, nt);
    StreamResult {
        n_global: n,
        n_local: n,
        nt,
        width: T::WIDTH,
        backend: crate::backend::BackendKind::Host,
        times,
        validation,
    }
}

/// The classic f64 serial run.
pub fn run_native_serial(n: usize, nt: usize, q: f64) -> StreamResult {
    run_serial_t::<f64>(n, nt, q)
}

/// Convenience: run with the paper's defaults (q = √2−1).
pub fn run_default(n: usize, nt: usize) -> StreamResult {
    run_native_serial(n, nt, STREAM_Q)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_run_validates() {
        let r = run_default(10_000, 10);
        assert!(r.validation.passed, "{:?}", r.validation);
        assert_eq!(r.n_global, 10_000);
        assert_eq!(r.nt, 10);
        assert_eq!(r.width, 8);
    }

    #[test]
    fn bandwidths_positive_and_ordered_sanely() {
        let r = run_default(1 << 20, 5);
        let bw = r.bandwidths();
        for (i, b) in bw.iter().enumerate() {
            assert!(*b > 0.0, "op {i} bw {b}");
            // A laptop-class machine moves > 100 MB/s and < 10 TB/s.
            assert!(*b > 1e8 && *b < 1e13, "op {i} bw {b}");
        }
    }

    #[test]
    fn many_iterations_still_validate() {
        let r = run_default(1024, 200);
        assert!(r.validation.passed, "{:?}", r.validation);
    }

    #[test]
    fn n1_edge_case() {
        let r = run_default(1, 3);
        assert!(r.validation.passed);
    }

    #[test]
    fn f32_serial_validates_and_halves_bytes() {
        let q32 = std::f32::consts::SQRT_2 - 1.0;
        let r32 = run_serial_t::<f32>(4096, 10, q32);
        assert!(r32.validation.passed, "{:?}", r32.validation);
        assert_eq!(r32.width, 4);
        let r64 = run_default(4096, 10);
        // §III with W = T::WIDTH: f32 triad bytes/iter are exactly half.
        assert_eq!(r32.bytes_per_iter()[3] * 2.0, r64.bytes_per_iter()[3]);
    }

    #[test]
    fn integer_serial_is_exact() {
        let r = run_serial_t::<i64>(512, 4, 0i64);
        assert!(r.validation.passed, "{:?}", r.validation);
        assert_eq!(r.validation.max_err(), 0.0);
    }
}
