//! `tic`/`toc` timing (Algorithm 1 lines 4–15) and per-op accumulators.

use std::time::Instant;

/// Matlab-style tic/toc.
#[derive(Debug, Clone, Copy)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// `TIC`.
    #[inline]
    pub fn tic() -> Self {
        Timer { start: Instant::now() }
    }

    /// `TOC` — seconds since the matching `tic`.
    #[inline]
    pub fn toc(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Accumulated seconds for the four STREAM ops.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OpTimes {
    pub copy: f64,
    pub scale: f64,
    pub add: f64,
    pub triad: f64,
}

impl OpTimes {
    pub fn zero() -> Self {
        Self::default()
    }

    pub fn as_array(&self) -> [f64; 4] {
        [self.copy, self.scale, self.add, self.triad]
    }

    pub fn total(&self) -> f64 {
        self.copy + self.scale + self.add + self.triad
    }

    /// Element-wise sum (combining trials).
    pub fn merged(&self, o: &OpTimes) -> OpTimes {
        OpTimes {
            copy: self.copy + o.copy,
            scale: self.scale + o.scale,
            add: self.add + o.add,
            triad: self.triad + o.triad,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tic_toc_measures_time() {
        let t = Timer::tic();
        std::thread::sleep(std::time::Duration::from_millis(5));
        let dt = t.toc();
        assert!(dt >= 0.004, "measured {dt}");
        assert!(dt < 1.0);
    }

    #[test]
    fn optimes_merge_and_total() {
        let a = OpTimes { copy: 1.0, scale: 2.0, add: 3.0, triad: 4.0 };
        let b = OpTimes { copy: 0.5, scale: 0.5, add: 0.5, triad: 0.5 };
        let m = a.merged(&b);
        assert_eq!(m.total(), 12.0);
        assert_eq!(m.as_array(), [1.5, 2.5, 3.5, 4.5]);
    }
}
