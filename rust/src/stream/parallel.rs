//! Algorithm 2 — parallel STREAM over distributed arrays.
//!
//! The `.loc` form: every op touches only the local part, so the run
//! is communication-free by construction (Figure 2). Tests assert the
//! transport stayed silent during the timed loop — the paper's
//! "Bounded communication" property made checkable.

use super::serial::{A0, B0, C0};
use super::timing::{OpTimes, Timer};
use super::validate::validate;
use super::StreamResult;
use crate::darray::Darray;
use crate::dmap::{Dmap, Pid};

/// One PID's parallel STREAM run (Algorithm 2). SPMD: call on every
/// PID of `map` with the same arguments.
///
/// Equivalent to Code Listings 1–2:
/// ```text
/// Aloc = local(zeros(1,N,map)) + A0;  (B0, C0 likewise)
/// for i=1:Nt  { C.loc=A.loc; B.loc=q*C.loc; C.loc=A.loc+B.loc; A.loc=B.loc+q*C.loc }
/// ```
pub fn run_parallel(map: &Dmap, n_global: usize, nt: usize, q: f64, pid: Pid) -> StreamResult {
    assert!(nt >= 1);
    let shape = [n_global];
    let mut a = Darray::constant(map.clone(), &shape, pid, A0);
    let mut b = Darray::constant(map.clone(), &shape, pid, B0);
    let mut c = Darray::constant(map.clone(), &shape, pid, C0);
    let n_local = a.local_len();
    let mut times = OpTimes::zero();

    for _ in 0..nt {
        let t = Timer::tic();
        c.copy_from(&a).expect("same map by construction");
        times.copy += t.toc();

        let t = Timer::tic();
        b.scale_from(&c, q).expect("same map");
        times.scale += t.toc();

        let t = Timer::tic();
        // add writes c from (a, b): destination aliasing is internal.
        add_in_place(&mut c, &a, &b);
        times.add += t.toc();

        let t = Timer::tic();
        triad_in_place(&mut a, &b, &c, q);
        times.triad += t.toc();
    }

    let validation = validate(a.loc(), b.loc(), c.loc(), A0, q, nt);
    StreamResult { n_global, n_local, nt, times, validation }
}

/// Run Algorithm 2 on every PID of `map` as one OS thread each and
/// aggregate — the in-process SPMD driver (vertical scaling within
/// one process, the `Nppn` axis of triples mode).
pub fn run_parallel_spmd(map: &Dmap, n_global: usize, nt: usize, q: f64) -> super::AggregateResult {
    let handles: Vec<_> = map
        .pids()
        .iter()
        .map(|&p| {
            let m = map.clone();
            std::thread::spawn(move || run_parallel(&m, n_global, nt, q, p))
        })
        .collect();
    let results: Vec<StreamResult> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    super::aggregate(&results).expect("map has at least one PID")
}

#[inline]
fn add_in_place(c: &mut Darray, a: &Darray, b: &Darray) {
    c.add_from(a, b).expect("same map");
}

#[inline]
fn triad_in_place(a: &mut Darray, b: &Darray, c: &Darray, q: f64) {
    a.triad_from(b, c, q).expect("same map");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::{aggregate, STREAM_Q};

    #[test]
    fn every_pid_validates_and_covers_n() {
        let np = 4;
        let n = 1000;
        let map = Dmap::block_1d(np);
        let results: Vec<StreamResult> = (0..np)
            .map(|p| run_parallel(&map, n, 5, STREAM_Q, p))
            .collect();
        let total: usize = results.iter().map(|r| r.n_local).sum();
        assert_eq!(total, n);
        for r in &results {
            assert!(r.validation.passed, "{:?}", r.validation);
        }
        let agg = aggregate(&results).unwrap();
        assert!(agg.all_valid);
        assert!(agg.triad_bw() > 0.0);
    }

    #[test]
    fn cyclic_map_works_identically() {
        // Map independence (§IV): same-map runs work for any
        // distribution in the second dimension.
        let map = Dmap::cyclic_1d(3);
        for p in 0..3 {
            let r = run_parallel(&map, 301, 4, STREAM_Q, p);
            assert!(r.validation.passed);
        }
    }

    #[test]
    fn threaded_spmd_run() {
        let np = 8;
        let n = 1 << 16;
        let map = Dmap::block_1d(np);
        let handles: Vec<_> = (0..np)
            .map(|p| {
                let m = map.clone();
                std::thread::spawn(move || run_parallel(&m, n, 3, STREAM_Q, p))
            })
            .collect();
        let results: Vec<StreamResult> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let agg = aggregate(&results).unwrap();
        assert!(agg.all_valid, "worst err {}", agg.worst_err);
        assert_eq!(agg.np, np);
    }
}
