//! Algorithm 2 — parallel STREAM over distributed arrays, generic
//! over the [`Element`] dtype.
//!
//! The `.loc` form: every op touches only the local part, so the run
//! is communication-free by construction (Figure 2). Tests assert the
//! transport stayed silent during the timed loop — the paper's
//! "Bounded communication" property made checkable. The dtype is the
//! bytes-per-element axis: an f32 run moves half the bytes of f64 at
//! the same N, so at equal bytes/sec it streams ~2× the elements/sec.

use super::serial::{A0, B0, C0};
use super::timing::{OpTimes, Timer};
use super::validate::validate_t;
use super::StreamResult;
use crate::darray::DarrayT;
use crate::dmap::{Dmap, Pid};
use crate::element::Element;

/// One PID's parallel STREAM run at dtype `T` (Algorithm 2). SPMD:
/// call on every PID of `map` with the same arguments.
///
/// Equivalent to Code Listings 1–2:
/// ```text
/// Aloc = local(zeros(1,N,map)) + A0;  (B0, C0 likewise)
/// for i=1:Nt  { C.loc=A.loc; B.loc=q*C.loc; C.loc=A.loc+B.loc; A.loc=B.loc+q*C.loc }
/// ```
pub fn run_parallel_t<T: Element>(
    map: &Dmap,
    n_global: usize,
    nt: usize,
    q: T,
    pid: Pid,
) -> StreamResult {
    assert!(nt >= 1);
    let shape = [n_global];
    let mut a = DarrayT::<T>::constant(map.clone(), &shape, pid, T::from_f64(A0));
    let mut b = DarrayT::<T>::constant(map.clone(), &shape, pid, T::from_f64(B0));
    let mut c = DarrayT::<T>::constant(map.clone(), &shape, pid, T::from_f64(C0));
    let n_local = a.local_len();
    let mut times = OpTimes::zero();

    for _ in 0..nt {
        let t = Timer::tic();
        c.copy_from(&a).expect("same map by construction");
        times.copy += t.toc();

        let t = Timer::tic();
        b.scale_from(&c, q).expect("same map");
        times.scale += t.toc();

        let t = Timer::tic();
        // add writes c from (a, b): destination aliasing is internal.
        c.add_from(&a, &b).expect("same map");
        times.add += t.toc();

        let t = Timer::tic();
        a.triad_from(&b, &c, q).expect("same map");
        times.triad += t.toc();
    }

    let validation = validate_t(a.loc(), b.loc(), c.loc(), A0, q, nt);
    StreamResult {
        n_global,
        n_local,
        nt,
        width: T::WIDTH,
        backend: crate::backend::BackendKind::Host,
        times,
        validation,
    }
}

/// The classic f64 run (Algorithm 2 as published).
pub fn run_parallel(map: &Dmap, n_global: usize, nt: usize, q: f64, pid: Pid) -> StreamResult {
    run_parallel_t::<f64>(map, n_global, nt, q, pid)
}

/// Run Algorithm 2 on every PID of `map` as one OS thread each and
/// aggregate — the in-process SPMD driver (vertical scaling within
/// one process, the `Nppn` axis of triples mode).
pub fn run_parallel_spmd_t<T: Element>(
    map: &Dmap,
    n_global: usize,
    nt: usize,
    q: T,
) -> super::AggregateResult {
    let handles: Vec<_> = map
        .pids()
        .iter()
        .map(|&p| {
            let m = map.clone();
            std::thread::spawn(move || run_parallel_t::<T>(&m, n_global, nt, q, p))
        })
        .collect();
    let results: Vec<StreamResult> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    super::aggregate(&results).expect("map has at least one PID")
}

/// The classic f64 SPMD driver.
pub fn run_parallel_spmd(map: &Dmap, n_global: usize, nt: usize, q: f64) -> super::AggregateResult {
    run_parallel_spmd_t::<f64>(map, n_global, nt, q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::{aggregate, STREAM_Q};

    #[test]
    fn every_pid_validates_and_covers_n() {
        let np = 4;
        let n = 1000;
        let map = Dmap::block_1d(np);
        let results: Vec<StreamResult> = (0..np)
            .map(|p| run_parallel(&map, n, 5, STREAM_Q, p))
            .collect();
        let total: usize = results.iter().map(|r| r.n_local).sum();
        assert_eq!(total, n);
        for r in &results {
            assert!(r.validation.passed, "{:?}", r.validation);
        }
        let agg = aggregate(&results).unwrap();
        assert!(agg.all_valid);
        assert!(agg.triad_bw() > 0.0);
    }

    #[test]
    fn cyclic_map_works_identically() {
        // Map independence (§IV): same-map runs work for any
        // distribution in the second dimension.
        let map = Dmap::cyclic_1d(3);
        for p in 0..3 {
            let r = run_parallel(&map, 301, 4, STREAM_Q, p);
            assert!(r.validation.passed);
        }
    }

    #[test]
    fn threaded_spmd_run() {
        let np = 8;
        let n = 1 << 16;
        let map = Dmap::block_1d(np);
        let handles: Vec<_> = (0..np)
            .map(|p| {
                let m = map.clone();
                std::thread::spawn(move || run_parallel(&m, n, 3, STREAM_Q, p))
            })
            .collect();
        let results: Vec<StreamResult> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let agg = aggregate(&results).unwrap();
        assert!(agg.all_valid, "worst err {}", agg.worst_err);
        assert_eq!(agg.np, np);
    }

    #[test]
    fn f32_parallel_validates_on_every_pid() {
        let q32 = std::f32::consts::SQRT_2 - 1.0;
        let map = Dmap::block_1d(4);
        for p in 0..4 {
            let r = run_parallel_t::<f32>(&map, 4 * 512, 8, q32, p);
            assert!(r.validation.passed, "pid {p}: {:?}", r.validation);
            assert_eq!(r.width, 4);
        }
    }

    #[test]
    fn f32_spmd_aggregate_doubles_elements_per_sec_at_equal_bw() {
        // Pure arithmetic check of the §III width formulas (timing-free):
        // equal bytes/sec ⇒ elements/sec scale as 8/W.
        let q32 = std::f32::consts::SQRT_2 - 1.0;
        let map = Dmap::block_1d(2);
        let agg32 = run_parallel_spmd_t::<f32>(&map, 2 * 4096, 3, q32);
        let agg64 = run_parallel_spmd(&map, 2 * 4096, 3, STREAM_Q);
        assert!(agg32.all_valid && agg64.all_valid);
        let e32 = agg32.triad_elements_per_sec() / agg32.triad_bw();
        let e64 = agg64.triad_elements_per_sec() / agg64.triad_bw();
        assert!((e32 / e64 - 2.0).abs() < 1e-12, "f32 must stream 2× elems per byte");
    }
}
