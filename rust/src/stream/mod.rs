//! The STREAM benchmark (§III) — the paper's workload.
//!
//! * [`ops`] — the four vector kernels as native Rust loops (the
//!   "regular numeric array" performance-guarantee path);
//! * [`serial`] — Algorithm 1 (single process);
//! * [`parallel`] — Algorithm 2 over [`crate::darray::Darray`] `.loc`
//!   parts (zero-communication by construction);
//! * [`params`] — the Table II parameter schedule (Nt, N/Np per era);
//! * [`validate`] — the §III closed-form checks with `q = √2 − 1`;
//! * [`timing`] — `tic`/`toc` equivalents and per-op accumulators.

pub mod ops;
pub mod params;
pub mod parallel;
pub mod serial;
pub mod threaded;
pub mod timing;
pub mod validate;

pub use params::StreamParams;
pub use parallel::{run_parallel, run_parallel_spmd, run_parallel_spmd_t, run_parallel_t};
pub use serial::{run_native_serial, run_serial_t};
pub use timing::{OpTimes, Timer};
pub use validate::{validate, validate_t, ValidationReport, STREAM_Q};

use crate::backend::BackendKind;

/// Result of one STREAM run (one process's view).
#[derive(Debug, Clone)]
pub struct StreamResult {
    /// Global vector length N.
    pub n_global: usize,
    /// This process's local length (== N when serial).
    pub n_local: usize,
    /// Iterations.
    pub nt: usize,
    /// Bytes per element of the streamed dtype
    /// ([`crate::element::Element::WIDTH`]; 8 for the classic f64 run).
    pub width: usize,
    /// Which execution backend produced this result (the `--backend`
    /// axis; the classic darray/serial engines are [`BackendKind::Host`]
    /// semantics, the `Ntpn` thread engine is
    /// [`BackendKind::Threaded`], the artifact engines
    /// [`BackendKind::Pjrt`]).
    pub backend: BackendKind,
    /// Accumulated per-op seconds over all iterations.
    pub times: OpTimes,
    /// Validation outcome.
    pub validation: ValidationReport,
}

impl StreamResult {
    /// Bytes moved per iteration for each op — the §III formulas with
    /// the dtype width `W` in place of the literal 8: Copy/Scale move
    /// `2·W·N` bytes, Add/Triad `3·W·N` — using the *local* length,
    /// which is what this process actually moved.
    pub fn bytes_per_iter(&self) -> [f64; 4] {
        let w = self.width as f64;
        let n = self.n_local as f64;
        [2.0 * w * n, 2.0 * w * n, 3.0 * w * n, 3.0 * w * n]
    }

    /// Per-op bandwidth in bytes/second: (bytes/iter × Nt) / t_op.
    pub fn bandwidths(&self) -> [f64; 4] {
        let b = self.bytes_per_iter();
        let t = self.times.as_array();
        let nt = self.nt as f64;
        [
            b[0] * nt / t[0],
            b[1] * nt / t[1],
            b[2] * nt / t[2],
            b[3] * nt / t[3],
        ]
    }

    /// Triad bandwidth (the figure the paper plots everywhere).
    pub fn triad_bw(&self) -> f64 {
        self.bandwidths()[3]
    }

    /// Per-op element throughput (elements/second): bandwidth divided
    /// by bytes-per-element-per-op. At equal bytes/sec, f32 streams
    /// ~2× the elements/sec of f64 — the mixed-precision lever.
    pub fn elements_per_sec(&self) -> [f64; 4] {
        let bw = self.bandwidths();
        let w = self.width as f64;
        [
            bw[0] / (2.0 * w),
            bw[1] / (2.0 * w),
            bw[2] / (3.0 * w),
            bw[3] / (3.0 * w),
        ]
    }
}

/// Sum the local results of all PIDs into the aggregate view the
/// paper reports ("the resulting times can be averaged to obtain
/// overall parallel bandwidths", Algorithm 2 caption).
///
/// Aggregate bandwidth = Σ_p (local bytes × Nt / t_p) — each process
/// streams its own memory concurrently.
pub fn aggregate(results: &[StreamResult]) -> Option<AggregateResult> {
    if results.is_empty() {
        return None;
    }
    let mut agg = AggregateResult {
        np: results.len(),
        n_global: results[0].n_global,
        nt: results[0].nt,
        width: results[0].width,
        backend: results[0].backend,
        bw: [0.0; 4],
        all_valid: true,
        worst_err: 0.0,
    };
    for r in results {
        let bws = r.bandwidths();
        for (a, b) in agg.bw.iter_mut().zip(bws) {
            *a += b;
        }
        agg.all_valid &= r.validation.passed;
        agg.worst_err = agg.worst_err.max(r.validation.max_err());
    }
    Some(agg)
}

/// Aggregated multi-process STREAM outcome.
#[derive(Debug, Clone)]
pub struct AggregateResult {
    pub np: usize,
    pub n_global: usize,
    pub nt: usize,
    /// Bytes per element of the streamed dtype.
    pub width: usize,
    /// Execution backend of the per-process results (first result's —
    /// one coordinated run never mixes backends).
    pub backend: BackendKind,
    /// [copy, scale, add, triad] aggregate bytes/sec.
    pub bw: [f64; 4],
    pub all_valid: bool,
    pub worst_err: f64,
}

impl AggregateResult {
    pub fn triad_bw(&self) -> f64 {
        self.bw[3]
    }

    /// Per-op aggregate element throughput (elements/second) — the
    /// §III vectors-per-op formula, mirroring
    /// [`StreamResult::elements_per_sec`] (the single home of the
    /// 2/2/3/3 constants for aggregates).
    pub fn elements_per_sec(&self) -> [f64; 4] {
        let w = self.width as f64;
        [
            self.bw[0] / (2.0 * w),
            self.bw[1] / (2.0 * w),
            self.bw[2] / (3.0 * w),
            self.bw[3] / (3.0 * w),
        ]
    }

    /// Aggregate triad element throughput (elements/second).
    pub fn triad_elements_per_sec(&self) -> f64 {
        self.elements_per_sec()[3]
    }
}
