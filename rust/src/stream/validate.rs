//! STREAM validation (§III): closed-form final values and the
//! `q = √2 − 1` trick that keeps magnitudes modest (`2q + q² = 1`).

/// The paper's scale factor: `q = √2 − 1` so `2q + q² = 1`.
pub const STREAM_Q: f64 = std::f64::consts::SQRT_2 - 1.0;

/// Closed-form expected values after `nt` iterations starting from
/// `A = a0` (B, C arbitrary — they are overwritten in iteration 1):
///
/// ```text
/// A_Nt(:) = (2q + q²)^Nt     · a0
/// B_Nt(:) = q                · A_{Nt-1}
/// C_Nt(:) = (1 + q)          · A_{Nt-1}
/// ```
pub fn expected(a0: f64, q: f64, nt: usize) -> (f64, f64, f64) {
    assert!(nt >= 1);
    let g = 2.0 * q + q * q;
    let a_prev = g.powi(nt as i32 - 1) * a0;
    (g.powi(nt as i32) * a0, q * a_prev, (1.0 + q) * a_prev)
}

/// Validation outcome for one run.
#[derive(Debug, Clone, Copy, Default)]
pub struct ValidationReport {
    pub passed: bool,
    /// Max |observed − expected| per vector.
    pub err_a: f64,
    pub err_b: f64,
    pub err_c: f64,
}

impl ValidationReport {
    pub fn max_err(&self) -> f64 {
        self.err_a.max(self.err_b).max(self.err_c)
    }
}

/// Tolerance: iteration count scales rounding accumulation.
pub fn tolerance(nt: usize) -> f64 {
    1e-13 * (nt as f64).max(1.0)
}

/// Validate final vectors against the closed forms.
pub fn validate(a: &[f64], b: &[f64], c: &[f64], a0: f64, q: f64, nt: usize) -> ValidationReport {
    let (ea, eb, ec) = expected(a0, q, nt);
    let dev = |xs: &[f64], e: f64| xs.iter().map(|&x| (x - e).abs()).fold(0.0, f64::max);
    let (err_a, err_b, err_c) = (dev(a, ea), dev(b, eb), dev(c, ec));
    ValidationReport {
        passed: err_a <= tolerance(nt) && err_b <= tolerance(nt) && err_c <= tolerance(nt),
        err_a,
        err_b,
        err_c,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::ops;

    #[test]
    fn q_satisfies_identity() {
        assert!((2.0 * STREAM_Q + STREAM_Q * STREAM_Q - 1.0).abs() < 1e-15);
    }

    #[test]
    fn expected_with_magic_q_is_stationary() {
        let (a, b, c) = expected(1.0, STREAM_Q, 1000);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - STREAM_Q).abs() < 1e-12);
        assert!((c - (1.0 + STREAM_Q)).abs() < 1e-12);
    }

    #[test]
    fn actual_run_validates() {
        let n = 257;
        let (mut a, mut b, mut c) = (vec![1.0; n], vec![2.0; n], vec![0.0; n]);
        let nt = 10;
        let mut tmp = vec![0.0; n];
        for _ in 0..nt {
            ops::copy(&mut c, &a);
            ops::scale(&mut b, &c, STREAM_Q);
            ops::add(&mut tmp, &a, &b);
            c.copy_from_slice(&tmp);
            ops::triad(&mut tmp, &b, &c, STREAM_Q);
            a.copy_from_slice(&tmp);
        }
        let rep = validate(&a, &b, &c, 1.0, STREAM_Q, nt);
        assert!(rep.passed, "{rep:?}");
    }

    #[test]
    fn corruption_detected() {
        let n = 64;
        let (ea, eb, ec) = expected(1.0, STREAM_Q, 5);
        let mut a = vec![ea; n];
        let b = vec![eb; n];
        let c = vec![ec; n];
        a[13] += 1e-6;
        let rep = validate(&a, &b, &c, 1.0, STREAM_Q, 5);
        assert!(!rep.passed);
        assert!(rep.err_a > 1e-7);
    }

    #[test]
    fn generic_q_closed_form_matches_iteration() {
        let q = 0.3;
        let mut a = 2.0f64;
        let nt = 7;
        let (mut bq, mut cq) = (0.0, 0.0);
        for _ in 0..nt {
            let c0 = a;
            let b0 = q * c0;
            let c1 = a + b0;
            bq = b0;
            cq = c1;
            a = b0 + q * c1;
        }
        let (ea, eb, ec) = expected(2.0, q, nt);
        assert!((a - ea).abs() < 1e-12 * ea.abs().max(1.0));
        assert!((bq - eb).abs() < 1e-12);
        assert!((cq - ec).abs() < 1e-12);
    }
}
