//! STREAM validation (§III): closed-form final values and the
//! `q = √2 − 1` trick that keeps magnitudes modest (`2q + q² = 1`).
//!
//! The closed forms are always evaluated in f64; a typed run is
//! checked by widening each element ([`validate_t`]) against a
//! tolerance scaled to the dtype's roundoff
//! ([`Element::TOL_BASE`] × Nt) — so an f32 run is held to f32
//! accuracy, an integer run to exactness.

use crate::element::Element;

/// The paper's scale factor: `q = √2 − 1` so `2q + q² = 1`.
pub const STREAM_Q: f64 = std::f64::consts::SQRT_2 - 1.0;

/// Closed-form expected values after `nt` iterations starting from
/// `A = a0` (B, C arbitrary — they are overwritten in iteration 1):
///
/// ```text
/// A_Nt(:) = (2q + q²)^Nt     · a0
/// B_Nt(:) = q                · A_{Nt-1}
/// C_Nt(:) = (1 + q)          · A_{Nt-1}
/// ```
pub fn expected(a0: f64, q: f64, nt: usize) -> (f64, f64, f64) {
    assert!(nt >= 1);
    let g = 2.0 * q + q * q;
    let a_prev = g.powi(nt as i32 - 1) * a0;
    (g.powi(nt as i32) * a0, q * a_prev, (1.0 + q) * a_prev)
}

/// Validation outcome for one run.
#[derive(Debug, Clone, Copy, Default)]
pub struct ValidationReport {
    pub passed: bool,
    /// Max |observed − expected| per vector.
    pub err_a: f64,
    pub err_b: f64,
    pub err_c: f64,
}

impl ValidationReport {
    pub fn max_err(&self) -> f64 {
        self.err_a.max(self.err_b).max(self.err_c)
    }
}

/// Tolerance: iteration count scales rounding accumulation (f64).
pub fn tolerance(nt: usize) -> f64 {
    tolerance_for(1e-13, nt)
}

/// Dtype-aware tolerance: `base` is the per-iteration roundoff budget
/// ([`Element::TOL_BASE`]).
pub fn tolerance_for(base: f64, nt: usize) -> f64 {
    base * (nt as f64).max(1.0)
}

/// Validate final vectors of any [`Element`] dtype against the f64
/// closed forms, at the dtype's own tolerance.
pub fn validate_t<T: Element>(a: &[T], b: &[T], c: &[T], a0: f64, q: T, nt: usize) -> ValidationReport {
    let (ea, eb, ec) = expected(a0, q.to_f64(), nt);
    let dev = |xs: &[T], e: f64| {
        xs.iter()
            .map(|&x| (x.to_f64() - e).abs())
            .fold(0.0, f64::max)
    };
    let (err_a, err_b, err_c) = (dev(a, ea), dev(b, eb), dev(c, ec));
    let tol = tolerance_for(T::TOL_BASE, nt);
    ValidationReport {
        passed: err_a <= tol && err_b <= tol && err_c <= tol,
        err_a,
        err_b,
        err_c,
    }
}

/// Validate final f64 vectors against the closed forms.
pub fn validate(a: &[f64], b: &[f64], c: &[f64], a0: f64, q: f64, nt: usize) -> ValidationReport {
    validate_t::<f64>(a, b, c, a0, q, nt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::ops;

    #[test]
    fn q_satisfies_identity() {
        assert!((2.0 * STREAM_Q + STREAM_Q * STREAM_Q - 1.0).abs() < 1e-15);
    }

    #[test]
    fn expected_with_magic_q_is_stationary() {
        let (a, b, c) = expected(1.0, STREAM_Q, 1000);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - STREAM_Q).abs() < 1e-12);
        assert!((c - (1.0 + STREAM_Q)).abs() < 1e-12);
    }

    #[test]
    fn actual_run_validates() {
        let n = 257;
        let (mut a, mut b, mut c) = (vec![1.0; n], vec![2.0; n], vec![0.0; n]);
        let nt = 10;
        let mut tmp = vec![0.0; n];
        for _ in 0..nt {
            ops::copy(&mut c, &a);
            ops::scale(&mut b, &c, STREAM_Q);
            ops::add(&mut tmp, &a, &b);
            c.copy_from_slice(&tmp);
            ops::triad(&mut tmp, &b, &c, STREAM_Q);
            a.copy_from_slice(&tmp);
        }
        let rep = validate(&a, &b, &c, 1.0, STREAM_Q, nt);
        assert!(rep.passed, "{rep:?}");
    }

    #[test]
    fn corruption_detected() {
        let n = 64;
        let (ea, eb, ec) = expected(1.0, STREAM_Q, 5);
        let mut a = vec![ea; n];
        let b = vec![eb; n];
        let c = vec![ec; n];
        a[13] += 1e-6;
        let rep = validate(&a, &b, &c, 1.0, STREAM_Q, 5);
        assert!(!rep.passed);
        assert!(rep.err_a > 1e-7);
    }

    #[test]
    fn f32_run_validates_at_f32_tolerance() {
        let n = 128;
        let q = std::f32::consts::SQRT_2 - 1.0;
        let (mut a, mut b, mut c) = (vec![1.0f32; n], vec![2.0f32; n], vec![0.0f32; n]);
        let nt = 20;
        let mut tmp = vec![0.0f32; n];
        for _ in 0..nt {
            ops::copy(&mut c, &a);
            ops::scale(&mut b, &c, q);
            ops::add(&mut tmp, &a, &b);
            c.copy_from_slice(&tmp);
            ops::triad(&mut tmp, &b, &c, q);
            a.copy_from_slice(&tmp);
        }
        let rep = validate_t::<f32>(&a, &b, &c, 1.0, q, nt);
        assert!(rep.passed, "{rep:?}");
        // ... but the same run is (correctly) outside f64 tolerance.
        assert!(rep.max_err() > tolerance(nt));
    }

    #[test]
    fn integer_run_is_exact() {
        // q = 0 for integers ⇒ A collapses to 0 after one iteration;
        // the closed form (g = 2q+q² = 0) predicts exactly that.
        let n = 16;
        let (mut a, mut b, mut c) = (vec![1i64; n], vec![2i64; n], vec![0i64; n]);
        let nt = 3;
        let mut tmp = vec![0i64; n];
        for _ in 0..nt {
            ops::copy(&mut c, &a);
            ops::scale(&mut b, &c, 0);
            ops::add(&mut tmp, &a, &b);
            c.copy_from_slice(&tmp);
            ops::triad(&mut tmp, &b, &c, 0);
            a.copy_from_slice(&tmp);
        }
        let rep = validate_t::<i64>(&a, &b, &c, 1.0, 0, nt);
        assert!(rep.passed, "{rep:?}");
        assert_eq!(rep.max_err(), 0.0);
    }

    #[test]
    fn generic_q_closed_form_matches_iteration() {
        let q = 0.3;
        let mut a = 2.0f64;
        let nt = 7;
        let (mut bq, mut cq) = (0.0, 0.0);
        for _ in 0..nt {
            let c0 = a;
            let b0 = q * c0;
            let c1 = a + b0;
            bq = b0;
            cq = c1;
            a = b0 + q * c1;
        }
        let (ea, eb, ec) = expected(2.0, q, nt);
        assert!((a - ea).abs() < 1e-12 * ea.abs().max(1.0));
        assert!((bq - eb).abs() < 1e-12);
        assert!((cq - ec).abs() < 1e-12);
    }
}
