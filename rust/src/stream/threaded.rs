//! The `Ntpn` axis of triples mode — §V: "each of the Nppn processes
//! and their corresponding Ntpn threads"; "Within each ... process,
//! the OpenMP parallelism is used as provided by their math
//! libraries."
//!
//! The native engine's analogue of that library-level threading: each
//! STREAM op splits the local vector into `ntpn` contiguous chunks
//! processed by a persistent thread pool. Chunks are contiguous (not
//! interleaved) to preserve streaming access per thread — the same
//! reason the paper pins threads to adjacent cores. Generic over the
//! [`Element`] dtype like the rest of the stream stack.

use super::serial::{A0, B0, C0};
use super::timing::{OpTimes, Timer};
use super::validate::validate_t;
use super::{ops, StreamResult};
use crate::darray::DarrayT;
use crate::dmap::{Dmap, Pid};
use crate::element::Element;
use std::sync::mpsc;
use std::sync::{Arc, Barrier};
use std::thread;

/// A persistent chunk-parallel worker pool for vector ops.
///
/// `run(f)` invokes `f(tid)` on every pool thread plus the caller
/// (tid 0), returning when all are done. The closure sees only its
/// thread id; slicing is the call-site's job.
pub struct OpPool {
    ntpn: usize,
    senders: Vec<mpsc::Sender<Job>>,
    done: Arc<Barrier>,
    /// Serializes concurrent `run` calls (the pool is one gang; two
    /// overlapping gangs would interleave jobs and barrier waits).
    gate: std::sync::Mutex<()>,
}

type Job = Arc<dyn Fn(usize) + Send + Sync>;

impl OpPool {
    pub fn new(ntpn: usize) -> OpPool {
        OpPool::build(ntpn, None)
    }

    /// A pool whose spawned threads pin themselves to the adjacent
    /// cores `base_core + tid` (§V), skipped gracefully when a core
    /// exceeds the machine. The caller thread (tid 0) keeps whatever
    /// affinity the process launcher applied.
    pub fn pinned(ntpn: usize, base_core: usize) -> OpPool {
        OpPool::build(ntpn, Some(base_core))
    }

    fn build(ntpn: usize, pin_base: Option<usize>) -> OpPool {
        assert!(ntpn >= 1);
        let done = Arc::new(Barrier::new(ntpn));
        let mut senders = Vec::new();
        for tid in 1..ntpn {
            let (tx, rx) = mpsc::channel::<Job>();
            let done = done.clone();
            thread::spawn(move || {
                if let Some(base) = pin_base {
                    crate::launcher::pinning::pin_to_core(base + tid);
                }
                while let Ok(job) = rx.recv() {
                    job(tid);
                    done.wait();
                }
            });
            senders.push(tx);
        }
        OpPool { ntpn, senders, done, gate: std::sync::Mutex::new(()) }
    }

    pub fn ntpn(&self) -> usize {
        self.ntpn
    }

    /// Run `f(tid)` for tid in 0..ntpn (0 on the caller's thread).
    pub fn run(&self, f: impl Fn(usize) + Send + Sync + 'static) {
        if self.ntpn == 1 {
            f(0);
            return;
        }
        let _gang = self.gate.lock().unwrap();
        let job: Job = Arc::new(f);
        for tx in &self.senders {
            tx.send(job.clone()).expect("pool thread alive");
        }
        job(0);
        self.done.wait();
    }

    /// Chunk bounds for thread `tid` over a length-`n` slice.
    pub fn chunk(&self, n: usize, tid: usize) -> (usize, usize) {
        chunk_bounds(self.ntpn, n, tid)
    }
}

/// Contiguous chunk bounds for worker `tid` of `ways` over a length-`n`
/// vector. The ranges of tids `0..ways` are disjoint and tile `[0, n)`
/// exactly — the invariant every raw-pointer gang kernel (the
/// `par_op!` ops here and the chunked backend's tiled kernels) relies
/// on for soundness, so there is exactly one definition.
pub fn chunk_bounds(ways: usize, n: usize, tid: usize) -> (usize, usize) {
    let b = n.div_ceil(ways).max(1);
    ((tid * b).min(n), ((tid + 1) * b).min(n))
}

macro_rules! par_op {
    ($pool:expr, $dst:expr, $n:expr, |$lo:ident, $hi:ident, $d:ident| $body:expr) => {{
        // Addresses cross the closure as usize (plain Send data); the
        // disjoint-chunk discipline makes the reconstruction sound.
        let dst_addr = $dst.as_mut_ptr() as usize;
        let pool = $pool;
        let n = $n;
        pool.run(move |tid| {
            let ($lo, $hi) = pool.chunk(n, tid);
            if $lo < $hi {
                // SAFETY: per-tid chunks are disjoint subranges of dst.
                let $d: &mut [T] = unsafe {
                    std::slice::from_raw_parts_mut((dst_addr as *mut T).add($lo), $hi - $lo)
                };
                $body
            }
        });
    }};
}

/// Parallel STREAM with `ntpn` threads over the local part —
/// Algorithm 2 with the §V thread axis, at dtype `T`. SPMD per PID
/// like [`super::parallel::run_parallel_t`].
pub fn run_parallel_threaded_t<T: Element>(
    map: &Dmap,
    n_global: usize,
    nt: usize,
    q: T,
    pid: Pid,
    pool: &'static OpPool,
) -> StreamResult {
    assert!(nt >= 1);
    let shape = [n_global];
    let mut a = DarrayT::<T>::constant(map.clone(), &shape, pid, T::from_f64(A0));
    let mut b = DarrayT::<T>::constant(map.clone(), &shape, pid, T::from_f64(B0));
    let mut c = DarrayT::<T>::constant(map.clone(), &shape, pid, T::from_f64(C0));
    let n_local = a.local_len();
    let mut times = OpTimes::zero();

    // Share the source slices with pool threads via raw parts; all
    // reads/writes are within disjoint chunks per op invocation.
    for _ in 0..nt {
        let (pa, pb, pc) = (
            a.loc_mut().as_mut_ptr() as usize,
            b.loc_mut().as_mut_ptr() as usize,
            c.loc_mut().as_mut_ptr() as usize,
        );

        let t = Timer::tic();
        par_op!(pool, c.loc_mut(), n_local, |lo, hi, d| {
            let src = unsafe { std::slice::from_raw_parts((pa as *const T).add(lo), hi - lo) };
            ops::copy(d, src)
        });
        times.copy += t.toc();

        let t = Timer::tic();
        par_op!(pool, b.loc_mut(), n_local, |lo, hi, d| {
            let src = unsafe { std::slice::from_raw_parts((pc as *const T).add(lo), hi - lo) };
            ops::scale(d, src, q)
        });
        times.scale += t.toc();

        let t = Timer::tic();
        par_op!(pool, c.loc_mut(), n_local, |lo, hi, d| {
            let sa = unsafe { std::slice::from_raw_parts((pa as *const T).add(lo), hi - lo) };
            let sb = unsafe { std::slice::from_raw_parts((pb as *const T).add(lo), hi - lo) };
            ops::add(d, sa, sb)
        });
        times.add += t.toc();

        let t = Timer::tic();
        par_op!(pool, a.loc_mut(), n_local, |lo, hi, d| {
            let sb = unsafe { std::slice::from_raw_parts((pb as *const T).add(lo), hi - lo) };
            let sc = unsafe { std::slice::from_raw_parts((pc as *const T).add(lo), hi - lo) };
            ops::triad(d, sb, sc, q)
        });
        times.triad += t.toc();
    }

    let validation = validate_t(a.loc(), b.loc(), c.loc(), A0, q, nt);
    StreamResult {
        n_global,
        n_local,
        nt,
        width: T::WIDTH,
        backend: crate::backend::BackendKind::Threaded,
        times,
        validation,
    }
}

/// The classic f64 threaded run.
pub fn run_parallel_threaded(
    map: &Dmap,
    n_global: usize,
    nt: usize,
    q: f64,
    pid: Pid,
    pool: &'static OpPool,
) -> StreamResult {
    run_parallel_threaded_t::<f64>(map, n_global, nt, q, pid, pool)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::STREAM_Q;
    use std::sync::OnceLock;

    fn pool(cell: &'static OnceLock<OpPool>, ntpn: usize) -> &'static OpPool {
        cell.get_or_init(|| OpPool::new(ntpn))
    }

    fn pool1() -> &'static OpPool {
        static P: OnceLock<OpPool> = OnceLock::new();
        pool(&P, 1)
    }

    fn pool2() -> &'static OpPool {
        static P: OnceLock<OpPool> = OnceLock::new();
        pool(&P, 2)
    }

    fn pool4() -> &'static OpPool {
        static P: OnceLock<OpPool> = OnceLock::new();
        pool(&P, 4)
    }

    #[test]
    fn threaded_run_validates() {
        for pool in [pool1(), pool2(), pool4()] {
            let r = run_parallel_threaded(&Dmap::block_1d(1), 100_000, 5, STREAM_Q, 0, pool);
            assert!(r.validation.passed, "ntpn={} {:?}", pool.ntpn(), r.validation);
        }
    }

    #[test]
    fn threaded_matches_single_thread_exactly() {
        // Element-wise determinism: threading must not change results.
        let r1 = run_parallel_threaded(&Dmap::block_1d(1), 4099, 7, STREAM_Q, 0, pool1());
        let r4 = run_parallel_threaded(&Dmap::block_1d(1), 4099, 7, STREAM_Q, 0, pool4());
        assert_eq!(r1.validation.max_err(), r4.validation.max_err());
        assert!(r4.validation.passed);
    }

    #[test]
    fn threaded_f32_validates() {
        let q32 = std::f32::consts::SQRT_2 - 1.0;
        let r = run_parallel_threaded_t::<f32>(&Dmap::block_1d(1), 10_000, 5, q32, 0, pool4());
        assert!(r.validation.passed, "{:?}", r.validation);
        assert_eq!(r.width, 4);
    }

    #[test]
    fn pool_chunks_tile_exactly() {
        for ntpn in [1usize, 2, 3, 4, 7] {
            let pool = OpPool::new(ntpn);
            for n in [0usize, 1, 5, 100, 4097] {
                let total: usize = (0..ntpn)
                    .map(|tid| {
                        let (lo, hi) = pool.chunk(n, tid);
                        hi - lo
                    })
                    .sum();
                assert_eq!(total, n, "ntpn={ntpn} n={n}");
            }
        }
    }

    #[test]
    fn pool_runs_all_tids() {
        use std::sync::atomic::{AtomicU64, Ordering};
        static HITS: AtomicU64 = AtomicU64::new(0);
        pool4().run(|tid| {
            HITS.fetch_add(1 << (tid * 8), Ordering::SeqCst);
        });
        assert_eq!(HITS.load(Ordering::SeqCst), 0x01010101);
    }

    #[test]
    fn multi_pid_threaded_spmd() {
        let map = Dmap::block_1d(2);
        let rs: Vec<_> = (0..2)
            .map(|pid| {
                let m = map.clone();
                std::thread::spawn(move || {
                    run_parallel_threaded(&m, 2 * 8192, 3, STREAM_Q, pid, pool2())
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect();
        let agg = crate::stream::aggregate(&rs).unwrap();
        assert!(agg.all_valid);
    }
}
