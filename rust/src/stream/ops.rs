//! Native STREAM vector kernels — the L3 hot path.
//!
//! Plain indexable loops over `&[T]`/`&mut [T]` for any sealed
//! [`Element`]: LLVM auto-vectorizes these to the machine's widest
//! loads/stores, which is the whole game for a bandwidth-bound kernel
//! (the `Element::add`/`mul` calls are `#[inline]` monomorphized
//! straight back to scalar `+`/`*`). The paper's "performance
//! guarantee" (§IV) — `.loc` parts are regular arrays with no hidden
//! cost — maps to exactly these functions, at every dtype: f32 STREAM
//! moves half the bytes per element of f64, so at equal bytes/second
//! it streams ~2× the elements/second.

use crate::element::Element;

/// Copy: `dst[i] = src[i]`.
#[inline]
pub fn copy<T: Element>(dst: &mut [T], src: &[T]) {
    dst.copy_from_slice(src);
}

/// Scale: `dst[i] = q * src[i]`.
#[inline]
pub fn scale<T: Element>(dst: &mut [T], src: &[T], q: T) {
    assert_eq!(dst.len(), src.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = T::mul(q, s);
    }
}

/// Add: `dst[i] = a[i] + b[i]`.
#[inline]
pub fn add<T: Element>(dst: &mut [T], a: &[T], b: &[T]) {
    assert_eq!(dst.len(), a.len());
    assert_eq!(dst.len(), b.len());
    for i in 0..dst.len() {
        dst[i] = T::add(a[i], b[i]);
    }
}

/// Triad: `dst[i] = b[i] + q * c[i]`.
#[inline]
pub fn triad<T: Element>(dst: &mut [T], b: &[T], c: &[T], q: T) {
    assert_eq!(dst.len(), b.len());
    assert_eq!(dst.len(), c.len());
    for i in 0..dst.len() {
        dst[i] = T::triad(b[i], q, c[i]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ops_match_definitions() {
        let a = [1.0, 2.0, 3.0];
        let b = [10.0, 20.0, 30.0];
        let mut d = [0.0; 3];
        copy(&mut d, &a);
        assert_eq!(d, a);
        scale(&mut d, &a, 2.0);
        assert_eq!(d, [2.0, 4.0, 6.0]);
        add(&mut d, &a, &b);
        assert_eq!(d, [11.0, 22.0, 33.0]);
        triad(&mut d, &b, &a, 0.5);
        assert_eq!(d, [10.5, 21.0, 31.5]);
    }

    #[test]
    fn ops_generic_over_dtypes() {
        let a = [1.0f32, 2.0, 3.0];
        let mut d = [0.0f32; 3];
        scale(&mut d, &a, 0.5f32);
        assert_eq!(d, [0.5, 1.0, 1.5]);

        let ia = [1i64, 2, 3];
        let ib = [10i64, 20, 30];
        let mut id = [0i64; 3];
        triad(&mut id, &ib, &ia, 2);
        assert_eq!(id, [12, 24, 36]);

        let ua = [u64::MAX, 1];
        let ub = [1u64, 1];
        let mut ud = [0u64; 2];
        add(&mut ud, &ua, &ub);
        assert_eq!(ud, [0, 2], "u64 add wraps instead of panicking");
    }

    #[test]
    #[should_panic]
    fn length_mismatch_panics() {
        let mut d = [0.0; 2];
        add(&mut d, &[1.0, 2.0], &[1.0]);
    }

    #[test]
    fn empty_slices_ok() {
        let mut d: [f64; 0] = [];
        copy(&mut d, &[]);
        scale(&mut d, &[], 2.0);
        add(&mut d, &[], &[]);
        triad(&mut d, &[], &[], 2.0);
    }
}
