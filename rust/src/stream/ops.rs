//! Native STREAM vector kernels — the L3 hot path.
//!
//! Plain indexable loops over `&[f64]`/`&mut [f64]`: LLVM
//! auto-vectorizes these to the machine's widest loads/stores, which
//! is the whole game for a bandwidth-bound kernel. The paper's
//! "performance guarantee" (§IV) — `.loc` parts are regular arrays
//! with no hidden cost — maps to exactly these functions.

/// Copy: `dst[i] = src[i]`.
#[inline]
pub fn copy(dst: &mut [f64], src: &[f64]) {
    dst.copy_from_slice(src);
}

/// Scale: `dst[i] = q * src[i]`.
#[inline]
pub fn scale(dst: &mut [f64], src: &[f64], q: f64) {
    assert_eq!(dst.len(), src.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = q * s;
    }
}

/// Add: `dst[i] = a[i] + b[i]`.
#[inline]
pub fn add(dst: &mut [f64], a: &[f64], b: &[f64]) {
    assert_eq!(dst.len(), a.len());
    assert_eq!(dst.len(), b.len());
    for i in 0..dst.len() {
        dst[i] = a[i] + b[i];
    }
}

/// Triad: `dst[i] = b[i] + q * c[i]`.
#[inline]
pub fn triad(dst: &mut [f64], b: &[f64], c: &[f64], q: f64) {
    assert_eq!(dst.len(), b.len());
    assert_eq!(dst.len(), c.len());
    for i in 0..dst.len() {
        dst[i] = b[i] + q * c[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ops_match_definitions() {
        let a = [1.0, 2.0, 3.0];
        let b = [10.0, 20.0, 30.0];
        let mut d = [0.0; 3];
        copy(&mut d, &a);
        assert_eq!(d, a);
        scale(&mut d, &a, 2.0);
        assert_eq!(d, [2.0, 4.0, 6.0]);
        add(&mut d, &a, &b);
        assert_eq!(d, [11.0, 22.0, 33.0]);
        triad(&mut d, &b, &a, 0.5);
        assert_eq!(d, [10.5, 21.0, 31.5]);
    }

    #[test]
    #[should_panic]
    fn length_mismatch_panics() {
        let mut d = [0.0; 2];
        add(&mut d, &[1.0, 2.0], &[1.0]);
    }

    #[test]
    fn empty_slices_ok() {
        let mut d: [f64; 0] = [];
        copy(&mut d, &[]);
        scale(&mut d, &[], 2.0);
        add(&mut d, &[], &[]);
        triad(&mut d, &[], &[], 2.0);
    }
}
