//! Run-configuration files — JSON configs for `repro run --config`.
//!
//! Example:
//! ```json
//! {
//!   "triples": "1x4x1",
//!   "n": 4194304,
//!   "nt": 10,
//!   "map": "block",
//!   "engine": "native",
//!   "dtype": "f64",
//!   "artifacts": "artifacts"
//! }
//! ```

use crate::backend::BackendKind;
use crate::collective::CollKind;
use crate::comm::TransportKind;
use crate::coordinator::{EngineKind, MapKind, RunConfig};
use crate::element::Dtype;
use crate::json::Json;
use crate::launcher::Triples;
use crate::stream::STREAM_Q;

/// A full benchmark launch description: coordination config + triples.
#[derive(Debug, Clone)]
pub struct LaunchConfig {
    pub triples: Triples,
    pub run: RunConfig,
}

/// Errors loading a config file.
#[derive(Debug)]
pub enum ConfigError {
    Io(std::io::Error),
    Json(crate::json::JsonError),
    Field(&'static str, String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Io(e) => write!(f, "io: {e}"),
            ConfigError::Json(e) => write!(f, "parse: {e}"),
            ConfigError::Field(name, msg) => write!(f, "bad field '{name}': {msg}"),
        }
    }
}

impl std::error::Error for ConfigError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ConfigError::Io(e) => Some(e),
            ConfigError::Json(e) => Some(e),
            ConfigError::Field(..) => None,
        }
    }
}

impl From<std::io::Error> for ConfigError {
    fn from(e: std::io::Error) -> Self {
        ConfigError::Io(e)
    }
}

impl From<crate::json::JsonError> for ConfigError {
    fn from(e: crate::json::JsonError) -> Self {
        ConfigError::Json(e)
    }
}

impl LaunchConfig {
    /// Built-in defaults (4 local processes, 2^22 elements, native).
    pub fn default_config() -> LaunchConfig {
        LaunchConfig {
            triples: Triples::new(1, 4, 1),
            run: RunConfig {
                n_global: 1 << 22,
                nt: 10,
                q: STREAM_Q,
                map: MapKind::Block,
                engine: EngineKind::Native,
                dtype: Dtype::F64,
                backend: BackendKind::Host,
                threads: 1,
                coll: CollKind::Star,
                nppn: 4,
                chunk_bytes: 0,
                artifacts: "artifacts".into(),
                trace: false,
                heartbeat: false,
                checkpoint: String::new(),
                restore: false,
                transport: TransportKind::File,
                recv_timeout_ms: 0,
            },
        }
    }

    /// Parse from JSON text; absent fields keep defaults.
    pub fn from_json(text: &str) -> Result<LaunchConfig, ConfigError> {
        let j = Json::parse(text)?;
        let mut cfg = LaunchConfig::default_config();
        if let Some(t) = j.get("triples") {
            let s = t
                .as_str()
                .ok_or_else(|| ConfigError::Field("triples", "must be a string".into()))?;
            cfg.triples = Triples::parse(s)
                .ok_or_else(|| ConfigError::Field("triples", format!("bad spec '{s}'")))?;
        }
        if let Some(v) = j.get("n") {
            cfg.run.n_global = v
                .as_usize()
                .ok_or_else(|| ConfigError::Field("n", "must be a number".into()))?;
        }
        if let Some(v) = j.get("nt") {
            cfg.run.nt =
                v.as_usize().ok_or_else(|| ConfigError::Field("nt", "must be a number".into()))?;
        }
        if let Some(v) = j.get("q") {
            cfg.run.q =
                v.as_f64().ok_or_else(|| ConfigError::Field("q", "must be a number".into()))?;
        }
        if let Some(v) = j.get("map") {
            let s = v
                .as_str()
                .ok_or_else(|| ConfigError::Field("map", "must be a string".into()))?;
            cfg.run.map = MapKind::parse(s)
                .ok_or_else(|| ConfigError::Field("map", format!("unknown map '{s}'")))?;
        }
        if let Some(v) = j.get("engine") {
            let s = v
                .as_str()
                .ok_or_else(|| ConfigError::Field("engine", "must be a string".into()))?;
            cfg.run.engine = EngineKind::parse(s)
                .ok_or_else(|| ConfigError::Field("engine", format!("unknown engine '{s}'")))?;
        }
        if let Some(v) = j.get("dtype") {
            let s = v
                .as_str()
                .ok_or_else(|| ConfigError::Field("dtype", "must be a string".into()))?;
            cfg.run.dtype = Dtype::parse(s)
                .ok_or_else(|| ConfigError::Field("dtype", format!("unknown dtype '{s}'")))?;
        }
        if let Some(v) = j.get("backend") {
            let s = v
                .as_str()
                .ok_or_else(|| ConfigError::Field("backend", "must be a string".into()))?;
            cfg.run.backend = BackendKind::parse(s).ok_or_else(|| {
                ConfigError::Field(
                    "backend",
                    format!("unknown backend '{s}' (expected {})", BackendKind::choices()),
                )
            })?;
        }
        if let Some(v) = j.get("coll") {
            let s = v
                .as_str()
                .ok_or_else(|| ConfigError::Field("coll", "must be a string".into()))?;
            cfg.run.coll = CollKind::parse(s).ok_or_else(|| {
                ConfigError::Field(
                    "coll",
                    format!("unknown collective '{s}' (expected {})", CollKind::choices()),
                )
            })?;
        }
        if let Some(v) = j.get("chunk_bytes") {
            let b = v
                .as_usize()
                .ok_or_else(|| ConfigError::Field("chunk_bytes", "must be a number".into()))?;
            if b == 0 {
                return Err(ConfigError::Field(
                    "chunk_bytes",
                    "must be a byte count >= 1".into(),
                ));
            }
            cfg.run.chunk_bytes = b;
        }
        if let Some(v) = j.get("artifacts") {
            cfg.run.artifacts = v
                .as_str()
                .ok_or_else(|| ConfigError::Field("artifacts", "must be a string".into()))?
                .to_string();
        }
        if let Some(v) = j.get("trace") {
            cfg.run.trace = v
                .as_bool()
                .ok_or_else(|| ConfigError::Field("trace", "must be a boolean".into()))?;
        }
        if let Some(v) = j.get("transport") {
            let s = v
                .as_str()
                .ok_or_else(|| ConfigError::Field("transport", "must be a string".into()))?;
            cfg.run.transport = TransportKind::parse(s).ok_or_else(|| {
                ConfigError::Field(
                    "transport",
                    format!("unknown transport '{s}' (expected {})", TransportKind::CHOICES),
                )
            })?;
        }
        if let Some(v) = j.get("recv_timeout_ms") {
            cfg.run.recv_timeout_ms = v
                .as_usize()
                .ok_or_else(|| ConfigError::Field("recv_timeout_ms", "must be a number".into()))?
                as u64;
        }
        // The threaded backend's pool width is the Ntpn axis; the
        // collective topology's node width is the Nppn axis.
        cfg.run.threads = cfg.triples.ntpn;
        cfg.run.nppn = cfg.triples.nppn;
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn load(path: &str) -> Result<LaunchConfig, ConfigError> {
        Self::from_json(&std::fs::read_to_string(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_config_parses() {
        let cfg = LaunchConfig::from_json(
            r#"{"triples": "2x4x2", "n": 1024, "nt": 3, "q": 0.5,
                "map": "blockcyclic:16", "engine": "pjrt-fused",
                "dtype": "f32", "backend": "threaded", "coll": "hier",
                "chunk_bytes": 4096, "artifacts": "art",
                "transport": "shmem", "recv_timeout_ms": 45000}"#,
        )
        .unwrap();
        assert_eq!(cfg.triples, Triples::new(2, 4, 2));
        assert_eq!(cfg.run.n_global, 1024);
        assert_eq!(cfg.run.nt, 3);
        assert_eq!(cfg.run.q, 0.5);
        assert_eq!(cfg.run.map, MapKind::BlockCyclic { block_size: 16 });
        assert_eq!(cfg.run.engine, EngineKind::PjrtFused);
        assert_eq!(cfg.run.dtype, Dtype::F32);
        assert_eq!(cfg.run.backend, BackendKind::Threaded);
        assert_eq!(cfg.run.threads, 2, "pool width follows the Ntpn axis");
        assert_eq!(cfg.run.coll, CollKind::Hier);
        assert_eq!(cfg.run.nppn, 4, "collective topology follows the Nppn axis");
        assert_eq!(cfg.run.chunk_bytes, 4096);
        assert_eq!(cfg.run.artifacts, "art");
        assert_eq!(cfg.run.transport, TransportKind::Shmem);
        assert_eq!(cfg.run.recv_timeout_ms, 45_000);
    }

    #[test]
    fn partial_config_keeps_defaults() {
        let cfg = LaunchConfig::from_json(r#"{"n": 99}"#).unwrap();
        assert_eq!(cfg.run.n_global, 99);
        assert_eq!(cfg.run.nt, 10);
        assert_eq!(cfg.run.map, MapKind::Block);
        assert_eq!(cfg.run.dtype, Dtype::F64);
        assert_eq!(cfg.run.chunk_bytes, 0, "0 = datapath default");
        assert_eq!(cfg.run.transport, TransportKind::File);
        assert_eq!(cfg.run.recv_timeout_ms, 0, "0 = built-in 120 s default");
    }

    #[test]
    fn bad_fields_are_specific_errors() {
        assert!(matches!(
            LaunchConfig::from_json(r#"{"triples": "nope"}"#),
            Err(ConfigError::Field("triples", _))
        ));
        assert!(matches!(
            LaunchConfig::from_json(r#"{"engine": "cuda"}"#),
            Err(ConfigError::Field("engine", _))
        ));
        assert!(matches!(
            LaunchConfig::from_json(r#"{"dtype": "f16"}"#),
            Err(ConfigError::Field("dtype", _))
        ));
        assert!(matches!(
            LaunchConfig::from_json(r#"{"backend": "cuda"}"#),
            Err(ConfigError::Field("backend", _))
        ));
        assert!(matches!(
            LaunchConfig::from_json(r#"{"coll": "mesh"}"#),
            Err(ConfigError::Field("coll", _))
        ));
        assert!(matches!(
            LaunchConfig::from_json(r#"{"chunk_bytes": 0}"#),
            Err(ConfigError::Field("chunk_bytes", _))
        ));
        assert!(matches!(
            LaunchConfig::from_json(r#"{"transport": "carrier-pigeon"}"#),
            Err(ConfigError::Field("transport", _))
        ));
        assert!(matches!(
            LaunchConfig::from_json("{"),
            Err(ConfigError::Json(_))
        ));
    }
}
