//! Per-dimension distribution algebra: block, cyclic, block-cyclic.
//!
//! All functions are pure index arithmetic over one dimension of
//! global extent `n` split across `g` grid coordinates.  Invariants
//! (checked by unit + property tests):
//!
//! * ownership partitions `[0, n)` — every global index has exactly
//!   one `(coord, local)` pair;
//! * `local_to_global(owner(i), global_to_local(i)) == i`;
//! * `Σ_c local_len(c) == n`.

/// How one array dimension is distributed over one grid dimension.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dist {
    /// Each coordinate holds one contiguous slab (pMatlab default).
    Block,
    /// Element `i` lives on coordinate `i % g` (maximal interleave).
    Cyclic,
    /// Blocks of `block_size` dealt round-robin across coordinates.
    BlockCyclic { block_size: usize },
}

impl Default for Dist {
    fn default() -> Self {
        Dist::Block
    }
}

impl Dist {
    /// Block size used by `Block` for extent `n` over `g` coords.
    #[inline]
    pub fn block_quantum(n: usize, g: usize) -> usize {
        n.div_ceil(g).max(1)
    }

    /// Grid coordinate that owns global index `i` (`i < n`).
    #[inline]
    pub fn owner(&self, i: usize, n: usize, g: usize) -> usize {
        debug_assert!(i < n, "global index {i} out of range {n}");
        match *self {
            Dist::Block => (i / Self::block_quantum(n, g)).min(g - 1),
            Dist::Cyclic => i % g,
            Dist::BlockCyclic { block_size } => {
                let bs = block_size.max(1);
                (i / bs) % g
            }
        }
    }

    /// Number of elements coordinate `c` owns.
    pub fn local_len(&self, c: usize, n: usize, g: usize) -> usize {
        debug_assert!(c < g);
        match *self {
            Dist::Block => {
                let b = Self::block_quantum(n, g);
                let lo = c * b;
                if lo >= n {
                    0
                } else {
                    (n - lo).min(b)
                }
            }
            // #{ i < n : i ≡ c (mod g) } = ceil((n - c) / g), clamped at 0.
            Dist::Cyclic => (n + g - 1).saturating_sub(c) / g,
            Dist::BlockCyclic { block_size } => {
                let bs = block_size.max(1);
                let nb = n.div_ceil(bs); // total blocks (last may be partial)
                if nb == 0 {
                    return 0;
                }
                // #{ k < nb : k ≡ c (mod g) }
                let owned_blocks = (nb + g - 1).saturating_sub(c) / g;
                if owned_blocks == 0 {
                    return 0;
                }
                let last_block = nb - 1;
                let last_size = n - last_block * bs;
                if last_block % g == c {
                    (owned_blocks - 1) * bs + last_size
                } else {
                    owned_blocks * bs
                }
            }
        }
    }

    /// Local index of global `i` on its owning coordinate.
    #[inline]
    pub fn global_to_local(&self, i: usize, n: usize, g: usize) -> usize {
        debug_assert!(i < n);
        match *self {
            Dist::Block => {
                let b = Self::block_quantum(n, g);
                let c = (i / b).min(g - 1);
                i - c * b
            }
            Dist::Cyclic => i / g,
            Dist::BlockCyclic { block_size } => {
                let bs = block_size.max(1);
                let k = i / bs; // global block index
                (k / g) * bs + i % bs
            }
        }
    }

    /// Global index of local `l` on coordinate `c`.
    #[inline]
    pub fn local_to_global(&self, c: usize, l: usize, n: usize, g: usize) -> usize {
        match *self {
            Dist::Block => c * Self::block_quantum(n, g) + l,
            Dist::Cyclic => l * g + c,
            Dist::BlockCyclic { block_size } => {
                let bs = block_size.max(1);
                let kb = l / bs; // local block index
                (kb * g + c) * bs + l % bs
            }
        }
    }

    /// Is the ownership of coordinate `c` one contiguous global range?
    pub fn is_contiguous(&self, n: usize, g: usize) -> bool {
        match *self {
            Dist::Block => true,
            Dist::Cyclic => g == 1 || n <= 1,
            Dist::BlockCyclic { block_size } => {
                let bs = block_size.max(1);
                g == 1 || n <= bs
            }
        }
    }

    /// Contiguous global ranges owned by coordinate `c`, in order.
    pub fn owned_ranges(&self, c: usize, n: usize, g: usize) -> Vec<(usize, usize)> {
        match *self {
            Dist::Block => {
                let b = Self::block_quantum(n, g);
                let lo = (c * b).min(n);
                let hi = ((c + 1) * b).min(n);
                if lo >= hi {
                    vec![]
                } else {
                    vec![(lo, hi)]
                }
            }
            Dist::Cyclic => {
                let mut out = Vec::new();
                let mut i = c;
                while i < n {
                    out.push((i, i + 1));
                    i += g;
                }
                out
            }
            Dist::BlockCyclic { block_size } => {
                let bs = block_size.max(1);
                let mut out = Vec::new();
                let mut k = c;
                let nb = n.div_ceil(bs);
                while k < nb {
                    let lo = k * bs;
                    let hi = ((k + 1) * bs).min(n);
                    out.push((lo, hi));
                    k += g;
                }
                out
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_dists() -> Vec<Dist> {
        vec![
            Dist::Block,
            Dist::Cyclic,
            Dist::BlockCyclic { block_size: 1 },
            Dist::BlockCyclic { block_size: 3 },
            Dist::BlockCyclic { block_size: 8 },
        ]
    }

    #[test]
    fn ownership_partitions_range() {
        for d in all_dists() {
            for &(n, g) in &[(1usize, 1usize), (7, 3), (16, 4), (100, 7), (5, 8), (64, 64)] {
                let mut counts = vec![0usize; g];
                for i in 0..n {
                    counts[d.owner(i, n, g)] += 1;
                }
                for c in 0..g {
                    assert_eq!(
                        counts[c],
                        d.local_len(c, n, g),
                        "{d:?} n={n} g={g} c={c}"
                    );
                }
                assert_eq!(counts.iter().sum::<usize>(), n);
            }
        }
    }

    #[test]
    fn g2l_l2g_roundtrip() {
        for d in all_dists() {
            for &(n, g) in &[(1usize, 1usize), (7, 3), (16, 4), (100, 7), (5, 8)] {
                for i in 0..n {
                    let c = d.owner(i, n, g);
                    let l = d.global_to_local(i, n, g);
                    assert!(l < d.local_len(c, n, g), "{d:?} n={n} g={g} i={i}");
                    assert_eq!(d.local_to_global(c, l, n, g), i, "{d:?} n={n} g={g} i={i}");
                }
            }
        }
    }

    #[test]
    fn owned_ranges_cover_exactly() {
        for d in all_dists() {
            for &(n, g) in &[(16usize, 4usize), (100, 7), (5, 8), (33, 2)] {
                for c in 0..g {
                    let ranges = d.owned_ranges(c, n, g);
                    let total: usize = ranges.iter().map(|(lo, hi)| hi - lo).sum();
                    assert_eq!(total, d.local_len(c, n, g), "{d:?} n={n} g={g} c={c}");
                    for (lo, hi) in ranges {
                        for i in lo..hi {
                            assert_eq!(d.owner(i, n, g), c);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn block_is_contiguous_cyclic_is_not() {
        assert!(Dist::Block.is_contiguous(100, 4));
        assert!(!Dist::Cyclic.is_contiguous(100, 4));
        assert!(Dist::Cyclic.is_contiguous(100, 1));
        assert!(!Dist::BlockCyclic { block_size: 4 }.is_contiguous(100, 4));
    }

    #[test]
    fn block_quantum_never_zero() {
        assert_eq!(Dist::block_quantum(0, 4), 1);
        assert_eq!(Dist::block_quantum(7, 3), 3);
        assert_eq!(Dist::block_quantum(8, 4), 2);
    }
}
