//! Boundary **overlap** (halo) descriptors — Figure 1's rightmost panel.
//!
//! Overlap lets the boundary of a block live on two neighbouring PIDs
//! so stencil-style computations read neighbours without explicit
//! messages; a `sync` operation refreshes the halo from the owner.
//! For the block distribution, coordinate `c`'s *stored* range extends
//! `amount` elements past its owned range into coordinate `c+1`'s
//! territory (pMatlab overlap semantics).

use super::dist::Dist;

/// Per-dimension halo width (elements shared with the next neighbour).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct Overlap {
    pub amount: usize,
}

impl Overlap {
    pub fn none() -> Self {
        Overlap { amount: 0 }
    }

    pub fn new(amount: usize) -> Self {
        Overlap { amount }
    }

    pub fn is_none(&self) -> bool {
        self.amount == 0
    }

    /// Stored (owned + halo) length for coordinate `c`.
    ///
    /// Only meaningful for `Dist::Block` (pMatlab restricts overlap to
    /// block maps); the last coordinate has no right neighbour.
    pub fn stored_len(&self, dist: &Dist, c: usize, n: usize, g: usize) -> usize {
        let own = dist.local_len(c, n, g);
        if own == 0 || self.amount == 0 {
            return own;
        }
        match dist {
            Dist::Block => {
                let b = Dist::block_quantum(n, g);
                let hi = ((c + 1) * b).min(n);
                own + self.amount.min(n - hi)
            }
            _ => own, // overlap unsupported on non-block dists
        }
    }

    /// Global range of the halo coordinate `c` must *receive* from its
    /// right neighbour after that neighbour writes: `[hi, hi+amount)`
    /// clamped to `n`. Empty when there is no halo.
    pub fn halo_range(&self, dist: &Dist, c: usize, n: usize, g: usize) -> Option<(usize, usize)> {
        if self.amount == 0 {
            return None;
        }
        match dist {
            Dist::Block => {
                let b = Dist::block_quantum(n, g);
                let hi = ((c + 1) * b).min(n);
                let end = (hi + self.amount).min(n);
                if hi < end && dist.local_len(c, n, g) > 0 {
                    Some((hi, end))
                } else {
                    None
                }
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_overlap_is_owned_len() {
        let d = Dist::Block;
        let o = Overlap::none();
        assert_eq!(o.stored_len(&d, 0, 10, 2), 5);
        assert_eq!(o.stored_len(&d, 1, 10, 2), 5);
    }

    #[test]
    fn overlap_extends_into_neighbour() {
        let d = Dist::Block;
        let o = Overlap::new(2);
        // n=10, g=2 → c0 owns [0,5), stores [0,7); c1 owns [5,10), stores [5,10)
        assert_eq!(o.stored_len(&d, 0, 10, 2), 7);
        assert_eq!(o.stored_len(&d, 1, 10, 2), 5);
        assert_eq!(o.halo_range(&d, 0, 10, 2), Some((5, 7)));
        assert_eq!(o.halo_range(&d, 1, 10, 2), None);
    }

    #[test]
    fn halo_clamped_at_array_end() {
        let d = Dist::Block;
        let o = Overlap::new(100);
        assert_eq!(o.stored_len(&d, 0, 10, 2), 10);
        assert_eq!(o.halo_range(&d, 0, 10, 2), Some((5, 10)));
    }

    #[test]
    fn overlap_ignored_on_cyclic() {
        let o = Overlap::new(2);
        assert_eq!(o.stored_len(&Dist::Cyclic, 0, 10, 2), 5);
        assert_eq!(o.halo_range(&Dist::Cyclic, 0, 10, 2), None);
    }
}
