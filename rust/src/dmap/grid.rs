//! Processor grids: the `[1 Np]` part of `map([1 Np], {}, 0:Np-1)`.
//!
//! A grid arranges the participating PIDs into an N-dimensional
//! lattice; each array dimension is distributed over the matching grid
//! dimension.  Linearization is row-major (last dimension fastest),
//! matching pMatlab.

/// An N-dimensional processor grid.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Grid {
    dims: Vec<usize>,
}

impl Grid {
    /// Build a grid from its dimensions. Every dim must be ≥ 1.
    pub fn new(dims: &[usize]) -> Self {
        assert!(!dims.is_empty(), "grid must have at least one dimension");
        assert!(dims.iter().all(|&d| d >= 1), "grid dims must be >= 1");
        Grid { dims: dims.to_vec() }
    }

    /// 1-D grid over `np` slots (the common row-vector map `[1, np]`
    /// collapses to this after squeezing the unit dimension).
    pub fn line(np: usize) -> Self {
        Grid::new(&[np])
    }

    /// Number of grid dimensions.
    pub fn ndim(&self) -> usize {
        self.dims.len()
    }

    /// Extent of grid dimension `d`.
    pub fn dim(&self, d: usize) -> usize {
        self.dims[d]
    }

    /// All dimensions.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Total number of grid slots (`Np` when fully populated).
    pub fn size(&self) -> usize {
        self.dims.iter().product()
    }

    /// Row-major linear slot of coordinate `coord`.
    pub fn linear(&self, coord: &[usize]) -> usize {
        assert_eq!(coord.len(), self.dims.len());
        let mut idx = 0usize;
        for (d, (&c, &ext)) in coord.iter().zip(&self.dims).enumerate() {
            assert!(c < ext, "grid coord {c} out of range {ext} in dim {d}");
            idx = idx * ext + c;
        }
        idx
    }

    /// Inverse of [`Grid::linear`].
    pub fn coord(&self, mut linear: usize) -> Vec<usize> {
        assert!(linear < self.size(), "linear slot out of range");
        let mut coord = vec![0usize; self.dims.len()];
        for d in (0..self.dims.len()).rev() {
            coord[d] = linear % self.dims[d];
            linear /= self.dims[d];
        }
        coord
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_coord_roundtrip() {
        let g = Grid::new(&[3, 4, 2]);
        assert_eq!(g.size(), 24);
        for s in 0..g.size() {
            assert_eq!(g.linear(&g.coord(s)), s);
        }
    }

    #[test]
    fn row_major_order() {
        let g = Grid::new(&[2, 3]);
        assert_eq!(g.linear(&[0, 0]), 0);
        assert_eq!(g.linear(&[0, 2]), 2);
        assert_eq!(g.linear(&[1, 0]), 3);
        assert_eq!(g.coord(5), vec![1, 2]);
    }

    #[test]
    fn line_grid() {
        let g = Grid::line(8);
        assert_eq!(g.ndim(), 1);
        assert_eq!(g.size(), 8);
        assert_eq!(g.coord(5), vec![5]);
    }

    #[test]
    #[should_panic]
    fn coord_out_of_range_panics() {
        Grid::new(&[2, 2]).linear(&[2, 0]);
    }
}
