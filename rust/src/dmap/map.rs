//! [`Dmap`] — the full map object: grid × distributions × overlap × PID
//! list.  Equivalent to pMatlab's `map(grid, dist, pids, overlap)`.

use super::dist::Dist;
use super::grid::Grid;
use super::overlap::Overlap;
use super::Pid;
use std::hash::{Hash, Hasher};
use std::sync::Arc;

/// FNV-1a 64 — a tiny deterministic hasher for the map fingerprint
/// (no dependencies; the fingerprint never crosses the wire, so only
/// within-process determinism matters).
struct Fnv64(u64);

impl Fnv64 {
    fn new() -> Fnv64 {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }
}

impl Hasher for Fnv64 {
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

/// The immutable body of a [`Dmap`]; shared via `Arc` so map clones
/// (plan-cache keys, darray handles) are pointer copies.
#[derive(Debug)]
struct DmapInner {
    grid: Grid,
    dists: Vec<Dist>,
    overlaps: Vec<Overlap>,
    /// Linear grid slot → PID. `pids.len() == grid.size()`.
    pids: Vec<Pid>,
    /// Precomputed content fingerprint — `Hash` writes this single
    /// u64, so hashing a map (e.g. a remap plan-cache lookup) costs
    /// O(1) instead of a deep structural walk.
    fingerprint: u64,
}

/// A distributed-array map over an N-dimensional global shape.
///
/// The map is *shape-agnostic*: it is combined with a concrete global
/// shape at use time (matching pMatlab, where the same map object can
/// describe arrays of different sizes).
///
/// Maps are immutable and cheaply clonable (`Arc`-backed), with a
/// precomputed [`Dmap::fingerprint`]: equality checks pointer identity
/// first, then the fingerprint, and walks the structure only for
/// distinct equal-fingerprint allocations — so hot caches keyed by
/// maps (the remap engine) pay a hash lookup, not a deep clone +
/// compare, per hit.
#[derive(Clone)]
pub struct Dmap {
    inner: Arc<DmapInner>,
}

impl std::fmt::Debug for Dmap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Dmap")
            .field("grid", &self.inner.grid)
            .field("dists", &self.inner.dists)
            .field("overlaps", &self.inner.overlaps)
            .field("pids", &self.inner.pids)
            .finish()
    }
}

impl PartialEq for Dmap {
    fn eq(&self, other: &Dmap) -> bool {
        if Arc::ptr_eq(&self.inner, &other.inner) {
            return true;
        }
        // Fingerprint mismatch decides instantly; a match still deep-
        // compares so a (vanishingly rare) collision cannot alias two
        // different maps.
        self.inner.fingerprint == other.inner.fingerprint
            && self.inner.grid == other.inner.grid
            && self.inner.dists == other.inner.dists
            && self.inner.overlaps == other.inner.overlaps
            && self.inner.pids == other.inner.pids
    }
}

impl Eq for Dmap {}

impl Hash for Dmap {
    fn hash<H: Hasher>(&self, state: &mut H) {
        // Consistent with Eq: the fingerprint is a pure function of
        // the structural content.
        state.write_u64(self.inner.fingerprint);
    }
}

impl Dmap {
    /// General constructor.
    pub fn new(grid: Grid, dists: Vec<Dist>, overlaps: Vec<Overlap>, pids: Vec<Pid>) -> Self {
        assert_eq!(grid.ndim(), dists.len(), "one dist per grid dim");
        assert_eq!(grid.ndim(), overlaps.len(), "one overlap per grid dim");
        assert_eq!(grid.size(), pids.len(), "one PID per grid slot");
        let mut seen = std::collections::HashSet::new();
        assert!(pids.iter().all(|p| seen.insert(*p)), "duplicate PID in map");
        let mut h = Fnv64::new();
        grid.hash(&mut h);
        dists.hash(&mut h);
        overlaps.hash(&mut h);
        pids.hash(&mut h);
        let fingerprint = h.finish();
        Dmap {
            inner: Arc::new(DmapInner { grid, dists, overlaps, pids, fingerprint }),
        }
    }

    /// The precomputed content fingerprint (what [`Hash`] emits).
    pub fn fingerprint(&self) -> u64 {
        self.inner.fingerprint
    }

    /// The paper's Code Listing map: `map([1 Np], {}, 0:Np-1)` — a row
    /// vector block-distributed by columns over all `np` PIDs.
    pub fn row_vector(np: usize) -> Self {
        Dmap::new(
            Grid::new(&[1, np]),
            vec![Dist::Block, Dist::Block],
            vec![Overlap::none(), Overlap::none()],
            (0..np).collect(),
        )
    }

    /// 1-D block map over `np` PIDs (squeezed row vector).
    pub fn block_1d(np: usize) -> Self {
        Dmap::new(
            Grid::line(np),
            vec![Dist::Block],
            vec![Overlap::none()],
            (0..np).collect(),
        )
    }

    /// 1-D cyclic map over `np` PIDs.
    pub fn cyclic_1d(np: usize) -> Self {
        Dmap::new(
            Grid::line(np),
            vec![Dist::Cyclic],
            vec![Overlap::none()],
            (0..np).collect(),
        )
    }

    /// 1-D block-cyclic map over `np` PIDs.
    pub fn block_cyclic_1d(np: usize, block_size: usize) -> Self {
        Dmap::new(
            Grid::line(np),
            vec![Dist::BlockCyclic { block_size }],
            vec![Overlap::none()],
            (0..np).collect(),
        )
    }

    /// 2-D block map (Figure 1 "rows and columns").
    pub fn block_2d(prows: usize, pcols: usize) -> Self {
        Dmap::new(
            Grid::new(&[prows, pcols]),
            vec![Dist::Block, Dist::Block],
            vec![Overlap::none(), Overlap::none()],
            (0..prows * pcols).collect(),
        )
    }

    /// 1-D block map with overlap (Figure 1 rightmost panel).
    pub fn block_1d_overlap(np: usize, overlap: usize) -> Self {
        Dmap::new(
            Grid::line(np),
            vec![Dist::Block],
            vec![Overlap::new(overlap)],
            (0..np).collect(),
        )
    }

    /// The elastic re-deal of this map onto a new owner list: the
    /// same 1-D distribution and overlap dealt over `new_pids` (the
    /// survivor group after a failure, or a grown group on
    /// scale-up). `None` for multi-dimensional grids — a survivor
    /// set has no canonical factorization into a higher-rank grid —
    /// and for an empty `new_pids`.
    pub fn redeal_1d(&self, new_pids: &[Pid]) -> Option<Dmap> {
        if self.ndim() != 1 || new_pids.is_empty() {
            return None;
        }
        Some(Dmap::new(
            Grid::line(new_pids.len()),
            self.inner.dists.clone(),
            self.inner.overlaps.clone(),
            new_pids.to_vec(),
        ))
    }

    pub fn grid(&self) -> &Grid {
        &self.inner.grid
    }

    pub fn dists(&self) -> &[Dist] {
        &self.inner.dists
    }

    pub fn overlaps(&self) -> &[Overlap] {
        &self.inner.overlaps
    }

    pub fn pids(&self) -> &[Pid] {
        &self.inner.pids
    }

    /// Number of participating processes.
    pub fn np(&self) -> usize {
        self.inner.pids.len()
    }

    pub fn ndim(&self) -> usize {
        self.inner.grid.ndim()
    }

    /// Does `pid` participate in this map?
    pub fn contains(&self, pid: Pid) -> bool {
        self.inner.pids.contains(&pid)
    }

    /// Grid coordinate of `pid` (panics if absent).
    pub fn coord_of(&self, pid: Pid) -> Vec<usize> {
        let slot = self
            .inner
            .pids
            .iter()
            .position(|&p| p == pid)
            .unwrap_or_else(|| panic!("PID {pid} not in map"));
        self.inner.grid.coord(slot)
    }

    /// PID owning grid coordinate `coord`.
    pub fn pid_at(&self, coord: &[usize]) -> Pid {
        self.inner.pids[self.inner.grid.linear(coord)]
    }

    /// PID owning global index `gidx` of an array with `shape`.
    pub fn owner(&self, gidx: &[usize], shape: &[usize]) -> Pid {
        assert_eq!(gidx.len(), self.ndim());
        assert_eq!(shape.len(), self.ndim());
        let coord: Vec<usize> = (0..self.ndim())
            .map(|d| self.inner.dists[d].owner(gidx[d], shape[d], self.inner.grid.dim(d)))
            .collect();
        self.pid_at(&coord)
    }

    /// Owned (excluding halo) local shape for `pid` under `shape`.
    pub fn local_shape(&self, pid: Pid, shape: &[usize]) -> Vec<usize> {
        let coord = self.coord_of(pid);
        (0..self.ndim())
            .map(|d| self.inner.dists[d].local_len(coord[d], shape[d], self.inner.grid.dim(d)))
            .collect()
    }

    /// Stored (owned + halo) local shape for `pid` under `shape`.
    pub fn stored_shape(&self, pid: Pid, shape: &[usize]) -> Vec<usize> {
        let coord = self.coord_of(pid);
        (0..self.ndim())
            .map(|d| {
                self.inner.overlaps[d].stored_len(
                    &self.inner.dists[d],
                    coord[d],
                    shape[d],
                    self.inner.grid.dim(d),
                )
            })
            .collect()
    }

    /// Global index of a local (owned-region) index on `pid`.
    pub fn local_to_global(&self, pid: Pid, lidx: &[usize], shape: &[usize]) -> Vec<usize> {
        let coord = self.coord_of(pid);
        (0..self.ndim())
            .map(|d| {
                self.inner.dists[d].local_to_global(
                    coord[d],
                    lidx[d],
                    shape[d],
                    self.inner.grid.dim(d),
                )
            })
            .collect()
    }

    /// Local index of a global index on its owner (owner, local).
    pub fn global_to_local(&self, gidx: &[usize], shape: &[usize]) -> (Pid, Vec<usize>) {
        let pid = self.owner(gidx, shape);
        let l = (0..self.ndim())
            .map(|d| self.inner.dists[d].global_to_local(gidx[d], shape[d], self.inner.grid.dim(d)))
            .collect();
        (pid, l)
    }

    /// Total number of elements `pid` owns under `shape`.
    pub fn local_size(&self, pid: Pid, shape: &[usize]) -> usize {
        self.local_shape(pid, shape).iter().product()
    }

    /// Two maps are *aligned* for a shape when every PID owns exactly
    /// the same global indices under both — the paper's "same map"
    /// condition that guarantees zero communication (Figure 2).
    pub fn aligned_with(&self, other: &Dmap, shape: &[usize]) -> bool {
        if self == other {
            return true;
        }
        if self.np() != other.np() || self.ndim() != other.ndim() {
            return false;
        }
        // Structural fast path failed — compare materialized ownership.
        let a = super::partition::Partition::of(self, shape);
        let b = super::partition::Partition::of(other, shape);
        a.same_ownership(&b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_vector_matches_paper_listing() {
        // map([1 4], {}, 0:3) over a 1 × 16 row vector.
        let m = Dmap::row_vector(4);
        let shape = [1usize, 16];
        assert_eq!(m.np(), 4);
        for pid in 0..4 {
            assert_eq!(m.local_shape(pid, &shape), vec![1, 4]);
        }
        assert_eq!(m.owner(&[0, 0], &shape), 0);
        assert_eq!(m.owner(&[0, 5], &shape), 1);
        assert_eq!(m.owner(&[0, 15], &shape), 3);
    }

    #[test]
    fn global_local_roundtrip_2d() {
        let m = Dmap::block_2d(2, 3);
        let shape = [8usize, 9];
        for i in 0..shape[0] {
            for j in 0..shape[1] {
                let (pid, l) = m.global_to_local(&[i, j], &shape);
                assert_eq!(m.local_to_global(pid, &l, &shape), vec![i, j]);
            }
        }
    }

    #[test]
    fn local_sizes_sum_to_global() {
        for m in [
            Dmap::block_1d(5),
            Dmap::cyclic_1d(5),
            Dmap::block_cyclic_1d(5, 3),
        ] {
            let shape = [101usize];
            let total: usize = (0..5).map(|p| m.local_size(p, &shape)).sum();
            assert_eq!(total, 101, "{m:?}");
        }
    }

    #[test]
    fn fingerprint_tracks_structural_equality() {
        // Separately constructed equal maps: equal, same fingerprint,
        // same hash — a plan cache keyed by maps hits across
        // constructions, not just across clones.
        let a = Dmap::block_cyclic_1d(4, 3);
        let b = Dmap::block_cyclic_1d(4, 3);
        assert_eq!(a, b);
        assert_eq!(a.fingerprint(), b.fingerprint());
        // Different structure → different map (and, for these cases,
        // different fingerprints).
        for other in [
            Dmap::block_1d(4),
            Dmap::cyclic_1d(4),
            Dmap::block_cyclic_1d(4, 2),
            Dmap::block_cyclic_1d(5, 3),
        ] {
            assert_ne!(a, other);
            assert_ne!(a.fingerprint(), other.fingerprint(), "{other:?}");
        }
        // Clones share the allocation (pointer-equality fast path).
        let c = a.clone();
        assert_eq!(a, c);
        assert_eq!(a.fingerprint(), c.fingerprint());
    }

    #[test]
    fn aligned_same_map() {
        let a = Dmap::block_1d(4);
        let b = Dmap::block_1d(4);
        assert!(a.aligned_with(&b, &[64]));
    }

    #[test]
    fn not_aligned_different_dist() {
        let a = Dmap::block_1d(4);
        let b = Dmap::cyclic_1d(4);
        assert!(!a.aligned_with(&b, &[64]));
        // ... but over a shape where block == cyclic (n == np) they align.
        assert!(a.aligned_with(&b, &[4]));
    }

    #[test]
    #[should_panic]
    fn duplicate_pid_rejected() {
        Dmap::new(
            Grid::line(2),
            vec![Dist::Block],
            vec![Overlap::none()],
            vec![0, 0],
        );
    }

    #[test]
    fn stored_shape_includes_halo() {
        let m = Dmap::block_1d_overlap(2, 3);
        let shape = [10usize];
        assert_eq!(m.local_shape(0, &shape), vec![5]);
        assert_eq!(m.stored_shape(0, &shape), vec![8]);
        assert_eq!(m.stored_shape(1, &shape), vec![5]);
    }
}
