//! [`Dmap`] — the full map object: grid × distributions × overlap × PID
//! list.  Equivalent to pMatlab's `map(grid, dist, pids, overlap)`.

use super::dist::Dist;
use super::grid::Grid;
use super::overlap::Overlap;
use super::Pid;

/// A distributed-array map over an N-dimensional global shape.
///
/// The map is *shape-agnostic*: it is combined with a concrete global
/// shape at use time (matching pMatlab, where the same map object can
/// describe arrays of different sizes).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Dmap {
    grid: Grid,
    dists: Vec<Dist>,
    overlaps: Vec<Overlap>,
    /// Linear grid slot → PID. `pids.len() == grid.size()`.
    pids: Vec<Pid>,
}

impl Dmap {
    /// General constructor.
    pub fn new(grid: Grid, dists: Vec<Dist>, overlaps: Vec<Overlap>, pids: Vec<Pid>) -> Self {
        assert_eq!(grid.ndim(), dists.len(), "one dist per grid dim");
        assert_eq!(grid.ndim(), overlaps.len(), "one overlap per grid dim");
        assert_eq!(grid.size(), pids.len(), "one PID per grid slot");
        let mut seen = std::collections::HashSet::new();
        assert!(pids.iter().all(|p| seen.insert(*p)), "duplicate PID in map");
        Dmap { grid, dists, overlaps, pids }
    }

    /// The paper's Code Listing map: `map([1 Np], {}, 0:Np-1)` — a row
    /// vector block-distributed by columns over all `np` PIDs.
    pub fn row_vector(np: usize) -> Self {
        Dmap::new(
            Grid::new(&[1, np]),
            vec![Dist::Block, Dist::Block],
            vec![Overlap::none(), Overlap::none()],
            (0..np).collect(),
        )
    }

    /// 1-D block map over `np` PIDs (squeezed row vector).
    pub fn block_1d(np: usize) -> Self {
        Dmap::new(
            Grid::line(np),
            vec![Dist::Block],
            vec![Overlap::none()],
            (0..np).collect(),
        )
    }

    /// 1-D cyclic map over `np` PIDs.
    pub fn cyclic_1d(np: usize) -> Self {
        Dmap::new(
            Grid::line(np),
            vec![Dist::Cyclic],
            vec![Overlap::none()],
            (0..np).collect(),
        )
    }

    /// 1-D block-cyclic map over `np` PIDs.
    pub fn block_cyclic_1d(np: usize, block_size: usize) -> Self {
        Dmap::new(
            Grid::line(np),
            vec![Dist::BlockCyclic { block_size }],
            vec![Overlap::none()],
            (0..np).collect(),
        )
    }

    /// 2-D block map (Figure 1 "rows and columns").
    pub fn block_2d(prows: usize, pcols: usize) -> Self {
        Dmap::new(
            Grid::new(&[prows, pcols]),
            vec![Dist::Block, Dist::Block],
            vec![Overlap::none(), Overlap::none()],
            (0..prows * pcols).collect(),
        )
    }

    /// 1-D block map with overlap (Figure 1 rightmost panel).
    pub fn block_1d_overlap(np: usize, overlap: usize) -> Self {
        Dmap::new(
            Grid::line(np),
            vec![Dist::Block],
            vec![Overlap::new(overlap)],
            (0..np).collect(),
        )
    }

    pub fn grid(&self) -> &Grid {
        &self.grid
    }

    pub fn dists(&self) -> &[Dist] {
        &self.dists
    }

    pub fn overlaps(&self) -> &[Overlap] {
        &self.overlaps
    }

    pub fn pids(&self) -> &[Pid] {
        &self.pids
    }

    /// Number of participating processes.
    pub fn np(&self) -> usize {
        self.pids.len()
    }

    pub fn ndim(&self) -> usize {
        self.grid.ndim()
    }

    /// Does `pid` participate in this map?
    pub fn contains(&self, pid: Pid) -> bool {
        self.pids.contains(&pid)
    }

    /// Grid coordinate of `pid` (panics if absent).
    pub fn coord_of(&self, pid: Pid) -> Vec<usize> {
        let slot = self
            .pids
            .iter()
            .position(|&p| p == pid)
            .unwrap_or_else(|| panic!("PID {pid} not in map"));
        self.grid.coord(slot)
    }

    /// PID owning grid coordinate `coord`.
    pub fn pid_at(&self, coord: &[usize]) -> Pid {
        self.pids[self.grid.linear(coord)]
    }

    /// PID owning global index `gidx` of an array with `shape`.
    pub fn owner(&self, gidx: &[usize], shape: &[usize]) -> Pid {
        assert_eq!(gidx.len(), self.ndim());
        assert_eq!(shape.len(), self.ndim());
        let coord: Vec<usize> = (0..self.ndim())
            .map(|d| self.dists[d].owner(gidx[d], shape[d], self.grid.dim(d)))
            .collect();
        self.pid_at(&coord)
    }

    /// Owned (excluding halo) local shape for `pid` under `shape`.
    pub fn local_shape(&self, pid: Pid, shape: &[usize]) -> Vec<usize> {
        let coord = self.coord_of(pid);
        (0..self.ndim())
            .map(|d| self.dists[d].local_len(coord[d], shape[d], self.grid.dim(d)))
            .collect()
    }

    /// Stored (owned + halo) local shape for `pid` under `shape`.
    pub fn stored_shape(&self, pid: Pid, shape: &[usize]) -> Vec<usize> {
        let coord = self.coord_of(pid);
        (0..self.ndim())
            .map(|d| {
                self.overlaps[d].stored_len(&self.dists[d], coord[d], shape[d], self.grid.dim(d))
            })
            .collect()
    }

    /// Global index of a local (owned-region) index on `pid`.
    pub fn local_to_global(&self, pid: Pid, lidx: &[usize], shape: &[usize]) -> Vec<usize> {
        let coord = self.coord_of(pid);
        (0..self.ndim())
            .map(|d| self.dists[d].local_to_global(coord[d], lidx[d], shape[d], self.grid.dim(d)))
            .collect()
    }

    /// Local index of a global index on its owner (owner, local).
    pub fn global_to_local(&self, gidx: &[usize], shape: &[usize]) -> (Pid, Vec<usize>) {
        let pid = self.owner(gidx, shape);
        let l = (0..self.ndim())
            .map(|d| self.dists[d].global_to_local(gidx[d], shape[d], self.grid.dim(d)))
            .collect();
        (pid, l)
    }

    /// Total number of elements `pid` owns under `shape`.
    pub fn local_size(&self, pid: Pid, shape: &[usize]) -> usize {
        self.local_shape(pid, shape).iter().product()
    }

    /// Two maps are *aligned* for a shape when every PID owns exactly
    /// the same global indices under both — the paper's "same map"
    /// condition that guarantees zero communication (Figure 2).
    pub fn aligned_with(&self, other: &Dmap, shape: &[usize]) -> bool {
        if self == other {
            return true;
        }
        if self.np() != other.np() || self.ndim() != other.ndim() {
            return false;
        }
        // Structural fast path failed — compare materialized ownership.
        let a = super::partition::Partition::of(self, shape);
        let b = super::partition::Partition::of(other, shape);
        a.same_ownership(&b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_vector_matches_paper_listing() {
        // map([1 4], {}, 0:3) over a 1 × 16 row vector.
        let m = Dmap::row_vector(4);
        let shape = [1usize, 16];
        assert_eq!(m.np(), 4);
        for pid in 0..4 {
            assert_eq!(m.local_shape(pid, &shape), vec![1, 4]);
        }
        assert_eq!(m.owner(&[0, 0], &shape), 0);
        assert_eq!(m.owner(&[0, 5], &shape), 1);
        assert_eq!(m.owner(&[0, 15], &shape), 3);
    }

    #[test]
    fn global_local_roundtrip_2d() {
        let m = Dmap::block_2d(2, 3);
        let shape = [8usize, 9];
        for i in 0..shape[0] {
            for j in 0..shape[1] {
                let (pid, l) = m.global_to_local(&[i, j], &shape);
                assert_eq!(m.local_to_global(pid, &l, &shape), vec![i, j]);
            }
        }
    }

    #[test]
    fn local_sizes_sum_to_global() {
        for m in [
            Dmap::block_1d(5),
            Dmap::cyclic_1d(5),
            Dmap::block_cyclic_1d(5, 3),
        ] {
            let shape = [101usize];
            let total: usize = (0..5).map(|p| m.local_size(p, &shape)).sum();
            assert_eq!(total, 101, "{m:?}");
        }
    }

    #[test]
    fn aligned_same_map() {
        let a = Dmap::block_1d(4);
        let b = Dmap::block_1d(4);
        assert!(a.aligned_with(&b, &[64]));
    }

    #[test]
    fn not_aligned_different_dist() {
        let a = Dmap::block_1d(4);
        let b = Dmap::cyclic_1d(4);
        assert!(!a.aligned_with(&b, &[64]));
        // ... but over a shape where block == cyclic (n == np) they align.
        assert!(a.aligned_with(&b, &[4]));
    }

    #[test]
    #[should_panic]
    fn duplicate_pid_rejected() {
        Dmap::new(
            Grid::line(2),
            vec![Dist::Block],
            vec![Overlap::none()],
            vec![0, 0],
        );
    }

    #[test]
    fn stored_shape_includes_halo() {
        let m = Dmap::block_1d_overlap(2, 3);
        let shape = [10usize];
        assert_eq!(m.local_shape(0, &shape), vec![5]);
        assert_eq!(m.stored_shape(0, &shape), vec![8]);
        assert_eq!(m.stored_shape(1, &shape), vec![5]);
    }
}
