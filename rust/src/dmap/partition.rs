//! Ownership partitions and map intersection — the planning substrate
//! for remap communication (`darray::remap`).
//!
//! A [`Partition`] materializes, for a concrete global shape, the set
//! of contiguous global ranges each PID owns (flattened row-major).
//! Remap plans are computed by intersecting the source and destination
//! partitions: each non-empty intersection becomes one message.

use super::map::Dmap;
use super::Pid;

/// A contiguous range `[lo, hi)` of flattened global indices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GlobalRange {
    pub lo: usize,
    pub hi: usize,
}

impl GlobalRange {
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    pub fn is_empty(&self) -> bool {
        self.hi <= self.lo
    }

    /// Intersection of two ranges (possibly empty).
    pub fn intersect(&self, other: &GlobalRange) -> GlobalRange {
        GlobalRange {
            lo: self.lo.max(other.lo),
            hi: self.hi.min(other.hi).max(self.lo.max(other.lo)),
        }
    }
}

/// Per-PID owned ranges over the row-major flattening of `shape`.
#[derive(Debug, Clone)]
pub struct Partition {
    /// `ranges[k]` = (pid, range); sorted by `range.lo`.
    ranges: Vec<(Pid, GlobalRange)>,
    np: usize,
    total: usize,
}

impl Partition {
    /// Materialize the partition of `map` over `shape`.
    ///
    /// For 1-D maps this is exact per the distribution. For N-D maps
    /// the flattened ownership of a PID is the cross product of the
    /// per-dim ranges; we emit one `GlobalRange` per contiguous run.
    pub fn of(map: &Dmap, shape: &[usize]) -> Self {
        assert_eq!(shape.len(), map.ndim());
        let total: usize = shape.iter().product();
        let mut ranges: Vec<(Pid, GlobalRange)> = Vec::new();
        for &pid in map.pids() {
            for r in Self::pid_ranges(map, pid, shape) {
                if !r.is_empty() {
                    ranges.push((pid, r));
                }
            }
        }
        ranges.sort_by_key(|(_, r)| r.lo);
        Partition { ranges, np: map.np(), total }
    }

    /// Contiguous flattened ranges owned by one PID.
    fn pid_ranges(map: &Dmap, pid: Pid, shape: &[usize]) -> Vec<GlobalRange> {
        let coord = map.coord_of(pid);
        let nd = map.ndim();
        // Per-dimension owned ranges.
        let per_dim: Vec<Vec<(usize, usize)>> = (0..nd)
            .map(|d| map.dists()[d].owned_ranges(coord[d], shape[d], map.grid().dim(d)))
            .collect();
        if per_dim.iter().any(|v| v.is_empty()) {
            return vec![];
        }
        // Row-major strides.
        let mut stride = vec![1usize; nd];
        for d in (0..nd.saturating_sub(1)).rev() {
            stride[d] = stride[d + 1] * shape[d + 1];
        }
        // The last dimension's ranges are contiguous in the flattening;
        // all outer dimensions contribute per-index offsets.
        let mut out = Vec::new();
        let mut outer_offsets = vec![0usize];
        for d in 0..nd.saturating_sub(1) {
            let mut next = Vec::new();
            for &base in &outer_offsets {
                for &(lo, hi) in &per_dim[d] {
                    for i in lo..hi {
                        next.push(base + i * stride[d]);
                    }
                }
            }
            outer_offsets = next;
        }
        let last = &per_dim[nd - 1];
        for &base in &outer_offsets {
            for &(lo, hi) in last {
                out.push(GlobalRange { lo: base + lo, hi: base + hi });
            }
        }
        // Merge adjacent ranges (e.g. a full row span).
        out.sort_by_key(|r| r.lo);
        let mut merged: Vec<GlobalRange> = Vec::with_capacity(out.len());
        for r in out {
            if let Some(last) = merged.last_mut() {
                if last.hi == r.lo {
                    last.hi = r.hi;
                    continue;
                }
            }
            merged.push(r);
        }
        merged
    }

    pub fn np(&self) -> usize {
        self.np
    }

    pub fn total(&self) -> usize {
        self.total
    }

    /// All (pid, range) pairs sorted by range start.
    pub fn ranges(&self) -> &[(Pid, GlobalRange)] {
        &self.ranges
    }

    /// Ranges owned by a single PID.
    pub fn ranges_of(&self, pid: Pid) -> Vec<GlobalRange> {
        self.ranges
            .iter()
            .filter(|(p, _)| *p == pid)
            .map(|(_, r)| *r)
            .collect()
    }

    /// Owner of flattened global index `i` (binary search).
    pub fn owner_of(&self, i: usize) -> Option<Pid> {
        let idx = self.ranges.partition_point(|(_, r)| r.hi <= i);
        match self.ranges.get(idx) {
            Some((p, r)) if r.lo <= i && i < r.hi => Some(*p),
            _ => None,
        }
    }

    /// Do two partitions assign identical ownership?
    pub fn same_ownership(&self, other: &Partition) -> bool {
        self.total == other.total && self.ranges == other.ranges
    }

    /// Communication plan from `self` (source layout) to `dst`:
    /// list of (src_pid, dst_pid, range) transfers. Transfers where
    /// `src_pid == dst_pid` are local copies (no message).
    pub fn transfers_to(&self, dst: &Partition) -> Vec<(Pid, Pid, GlobalRange)> {
        assert_eq!(self.total, dst.total, "shape mismatch in remap plan");
        let mut plan = Vec::new();
        // Both range lists are sorted and non-overlapping: for each src
        // range binary-search the first overlapping dst range, then walk.
        for &(sp, sr) in &self.ranges {
            let mut j = dst.ranges.partition_point(|(_, r)| r.hi <= sr.lo);
            while j < dst.ranges.len() {
                let (dp, dr) = dst.ranges[j];
                if dr.lo >= sr.hi {
                    break;
                }
                let x = sr.intersect(&dr);
                if !x.is_empty() {
                    plan.push((sp, dp, x));
                }
                j += 1;
            }
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dmap::Dmap;

    #[test]
    fn block_partition_1d() {
        let p = Partition::of(&Dmap::block_1d(4), &[100]);
        assert_eq!(p.ranges().len(), 4);
        assert_eq!(p.ranges_of(0), vec![GlobalRange { lo: 0, hi: 25 }]);
        assert_eq!(p.owner_of(99), Some(3));
        assert_eq!(p.owner_of(100), None);
    }

    #[test]
    fn cyclic_partition_has_n_ranges() {
        let p = Partition::of(&Dmap::cyclic_1d(4), &[16]);
        assert_eq!(p.ranges().len(), 16);
        assert_eq!(p.owner_of(5), Some(1));
    }

    #[test]
    fn partition_covers_all_indices() {
        for map in [
            Dmap::block_1d(3),
            Dmap::cyclic_1d(3),
            Dmap::block_cyclic_1d(3, 4),
            Dmap::block_2d(2, 2),
        ] {
            let shape: Vec<usize> = if map.ndim() == 1 { vec![37] } else { vec![6, 7] };
            let p = Partition::of(&map, &shape);
            let total: usize = shape.iter().product();
            for i in 0..total {
                let owner = p.owner_of(i).unwrap_or_else(|| panic!("uncovered idx {i} {map:?}"));
                assert!(owner < 4);
            }
            let sum: usize = p.ranges().iter().map(|(_, r)| r.len()).sum();
            assert_eq!(sum, total);
        }
    }

    #[test]
    fn row_map_2d_matches_rows() {
        // 2-D map [2,1]: block by rows (Figure 1 leftmost).
        let m = Dmap::block_2d(2, 1);
        let p = Partition::of(&m, &[4, 6]);
        // PID 0 owns rows 0-1 → flattened [0, 12); PID 1 rows 2-3 → [12, 24).
        assert_eq!(p.ranges_of(0), vec![GlobalRange { lo: 0, hi: 12 }]);
        assert_eq!(p.ranges_of(1), vec![GlobalRange { lo: 12, hi: 24 }]);
    }

    #[test]
    fn same_map_transfer_plan_is_all_local() {
        let p = Partition::of(&Dmap::block_1d(4), &[64]);
        let q = Partition::of(&Dmap::block_1d(4), &[64]);
        let plan = p.transfers_to(&q);
        assert!(plan.iter().all(|(s, d, _)| s == d));
        let bytes: usize = plan.iter().map(|(_, _, r)| r.len()).sum();
        assert_eq!(bytes, 64);
    }

    #[test]
    fn block_to_cyclic_plan_covers_everything() {
        let src = Partition::of(&Dmap::block_1d(4), &[64]);
        let dst = Partition::of(&Dmap::cyclic_1d(4), &[64]);
        let plan = src.transfers_to(&dst);
        let total: usize = plan.iter().map(|(_, _, r)| r.len()).sum();
        assert_eq!(total, 64);
        // Most transfers cross PIDs.
        assert!(plan.iter().any(|(s, d, _)| s != d));
        // Every transferred element's src/dst owners agree with the partitions.
        for (s, d, r) in plan {
            for i in r.lo..r.hi {
                assert_eq!(src.owner_of(i), Some(s));
                assert_eq!(dst.owner_of(i), Some(d));
            }
        }
    }
}
