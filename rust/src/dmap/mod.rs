//! Distributed-array **maps** — the core abstraction of the paper (§II).
//!
//! A [`Dmap`] describes how a global N-dimensional array is broken up
//! among `Np` processes: a processor [`Grid`], a per-dimension
//! [`Dist`]ribution (block / cyclic / block-cyclic — Figure 1), an
//! optional per-dimension [`Overlap`], and the list of participating
//! PIDs.  This mirrors pMatlab's `map([1 Np], {}, 0:Np-1)` and
//! pPython's `Dmap([1,Np], {}, range(Np))`.
//!
//! Every PID can compute, from the map alone, which global indices any
//! other PID owns — the property that makes owner-computes and remap
//! planning possible without central coordination.

pub mod dist;
pub mod grid;
pub mod map;
pub mod overlap;
pub mod partition;

pub use dist::Dist;
pub use grid::Grid;
pub use map::Dmap;
pub use overlap::Overlap;
pub use partition::{GlobalRange, Partition};

/// Process identifier (the paper's `P_ID`; MPI "rank").
pub type Pid = usize;
