//! In-house micro-benchmark harness (criterion is unavailable
//! offline). Used by every `rust/benches/*.rs` target
//! (`harness = false`).
//!
//! Methodology: warmup runs, then `samples` timed runs; report
//! min/median/mean. Black-box the results to keep LLVM honest.

use std::hint::black_box;
use std::time::Instant;

/// One measured statistic set (seconds).
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub min: f64,
    pub median: f64,
    pub mean: f64,
    pub samples: usize,
}

impl Stats {
    fn from_samples(mut xs: Vec<f64>) -> Stats {
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        Stats {
            min: xs[0],
            median: xs[n / 2],
            mean: xs.iter().sum::<f64>() / n as f64,
            samples: n,
        }
    }
}

/// Benchmark `f`, returning timing stats.
pub fn bench<T>(warmup: usize, samples: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        black_box(f());
        times.push(t.elapsed().as_secs_f64());
    }
    Stats::from_samples(times)
}

/// Print one result row: name, median time, and an optional derived
/// throughput (`bytes` moved per run → bandwidth).
pub fn report(name: &str, stats: &Stats, bytes: Option<f64>) {
    match bytes {
        Some(b) => println!(
            "{name:<44} median {:>10.3} ms   {:>12}",
            stats.median * 1e3,
            crate::report::fmt_bw(b / stats.median)
        ),
        None => println!("{name:<44} median {:>10.3} ms", stats.median * 1e3),
    }
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = Stats::from_samples(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.mean, 2.0);
    }

    #[test]
    fn bench_runs_function() {
        let mut count = 0;
        let s = bench(2, 5, || {
            count += 1;
            count
        });
        assert_eq!(count, 7);
        assert_eq!(s.samples, 5);
        assert!(s.min >= 0.0);
    }
}
