//! Distributed arrays (§II "distributed array model").
//!
//! A [`DarrayT`] is the SPMD view one PID holds of a global array: the
//! shared [`Dmap`](crate::dmap::Dmap), the global shape, and **only
//! the local part** — exactly like the paper's Code Listings, where
//! `Aloc`, `Bloc`, `Cloc` are the only allocations ("the distributed
//! arrays A, B, C are never actually allocated"). [`Darray`] is the
//! `f64` instantiation; the container is generic over the sealed
//! [`Element`](crate::element::Element) dtypes (`f64`, `f32`, `i64`,
//! `u64`).
//!
//! * `loc()` / `loc_mut()` — the paper's `.loc` construct: guaranteed
//!   zero-communication access to the owned region.
//! * Owner-computes element-wise ops (`copy_from`, `scale_from`,
//!   `add_from`, `triad_from`, `zip2`, …) require aligned maps and are
//!   pure local loops — the "performance guarantee" property (§IV).
//! * Global assignment [`DarrayT::assign_from`] is map-independent: if
//!   the maps align it degenerates to a local copy; otherwise it runs
//!   the remap communication plan (§IV map-independence discussion).
//!   Iterated remaps should go through a [`RemapEngine`], which caches
//!   the `(plan, src_offsets, dst_offsets)` triple per
//!   `(src_map, dst_map, shape)` so replanning never repeats.

pub mod agg;
pub mod dense;
pub mod elastic;
pub mod engine;
pub mod halo;
pub mod ops;
pub mod pipeline;
pub mod reduce;
pub mod remap;
pub mod subsref;

pub use dense::{Darray, DarrayT};
pub use engine::{RemapEngine, RemapPlan};
pub use pipeline::{stage_map, StageArray, StageArrayT};
pub use reduce::{allreduce, allreduce_t, allreduce_with, ReduceOp};

/// Errors from distributed-array operations.
#[derive(Debug)]
pub enum DarrayError {
    NotAligned { shape: Vec<usize> },
    ShapeMismatch { a: Vec<usize>, b: Vec<usize> },
    PidMismatch { a: usize, b: usize },
    Comm(crate::comm::CommError),
    Unsupported(String),
}

impl std::fmt::Display for DarrayError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DarrayError::NotAligned { shape } => write!(
                f,
                "maps are not aligned for shape {shape:?}; use assign_from (remap) instead"
            ),
            DarrayError::ShapeMismatch { a, b } => write!(f, "shape mismatch: {a:?} vs {b:?}"),
            DarrayError::PidMismatch { a, b } => write!(f, "pid mismatch: {a} vs {b}"),
            DarrayError::Comm(e) => write!(f, "communication failed: {e}"),
            DarrayError::Unsupported(m) => f.write_str(m),
        }
    }
}

impl std::error::Error for DarrayError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            DarrayError::Comm(e) => Some(e),
            _ => None,
        }
    }
}

impl From<crate::comm::CommError> for DarrayError {
    fn from(e: crate::comm::CommError) -> Self {
        DarrayError::Comm(e)
    }
}

pub type Result<T> = std::result::Result<T, DarrayError>;
