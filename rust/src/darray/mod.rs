//! Distributed arrays (§II "distributed array model").
//!
//! A [`Darray`] is the SPMD view one PID holds of a global array: the
//! shared [`Dmap`], the global shape, and **only the local part** —
//! exactly like the paper's Code Listings, where `Aloc`, `Bloc`,
//! `Cloc` are the only allocations ("the distributed arrays A, B, C
//! are never actually allocated").
//!
//! * `loc()` / `loc_mut()` — the paper's `.loc` construct: guaranteed
//!   zero-communication access to the owned region.
//! * Owner-computes element-wise ops (`copy_from`, `scale_from`,
//!   `add_from`, `triad_from`, `zip2`, …) require aligned maps and are
//!   pure local loops — the "performance guarantee" property (§IV).
//! * Global assignment [`Darray::assign_from`] is map-independent: if
//!   the maps align it degenerates to a local copy; otherwise it runs
//!   the remap communication plan (§IV map-independence discussion).

pub mod agg;
pub mod dense;
pub mod halo;
pub mod ops;
pub mod pipeline;
pub mod reduce;
pub mod remap;
pub mod subsref;

pub use dense::Darray;
pub use pipeline::{stage_map, StageArray};
pub use reduce::{allreduce, ReduceOp};

use thiserror::Error;

/// Errors from distributed-array operations.
#[derive(Debug, Error)]
pub enum DarrayError {
    #[error("maps are not aligned for shape {shape:?}; use assign_from (remap) instead")]
    NotAligned { shape: Vec<usize> },
    #[error("shape mismatch: {a:?} vs {b:?}")]
    ShapeMismatch { a: Vec<usize>, b: Vec<usize> },
    #[error("pid mismatch: {a} vs {b}")]
    PidMismatch { a: usize, b: usize },
    #[error("communication failed: {0}")]
    Comm(#[from] crate::comm::CommError),
    #[error("{0}")]
    Unsupported(String),
}

pub type Result<T> = std::result::Result<T, DarrayError>;
