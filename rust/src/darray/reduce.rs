//! Distributed reductions — the collective building blocks a pMatlab
//! user gets from `sum(A)`, `min(A)`, `norm(A)`, `dot(A,B)`.
//!
//! All reductions route through the [`crate::collective`] subsystem
//! (`NS_REDUCE` tag namespace): the algorithm — star, binomial tree,
//! ring, hierarchical — is the process-default (`--coll` axis) for
//! the plain entry points, or explicit via [`allreduce_with`].
//! Contributions fold in PID order regardless of algorithm, so every
//! algorithm returns bit-identical results (the star default is
//! bit-for-bit the legacy wire exchange).
//!
//! [`ReduceOp`] is dtype-generic over the sealed
//! [`Element`](crate::element::Element) set: `DarrayT<i64>` sums wrap
//! exactly and `DarrayT<f32>` reduces in f32 via the `*_t` entry
//! points — no round-trip through f64. The historical f64-widening
//! API (`global_sum`, …) is unchanged.

use super::dense::DarrayT;
use super::Result;
use crate::collective::{Collective, TagSpace};
use crate::comm::{tags, Transport};
use crate::element::Element;

pub use crate::collective::ReduceOp;

/// Collective scalar reduction over all PIDs (f64 — the historical
/// entry point). SPMD.
pub fn allreduce(t: &dyn Transport, local: f64, op: ReduceOp, epoch: u64) -> Result<f64> {
    allreduce_t(t, local, op, epoch)
}

/// Dtype-generic collective scalar reduction under the
/// process-default algorithm. SPMD.
pub fn allreduce_t<T: Element>(t: &dyn Transport, local: T, op: ReduceOp, epoch: u64) -> Result<T> {
    allreduce_with(&crate::collective::ambient(t.np()), t, local, op, epoch)
}

/// Dtype-generic collective scalar reduction under an explicit
/// algorithm context. SPMD.
pub fn allreduce_with<T: Element>(
    coll: &Collective,
    t: &dyn Transport,
    local: T,
    op: ReduceOp,
    epoch: u64,
) -> Result<T> {
    let space = TagSpace::packed(tags::NS_REDUCE, epoch);
    Ok(coll.allreduce_scalar(t, space, local, op)?)
}

impl<T: Element> DarrayT<T> {
    /// Global sum: `sum(A(:))`, widened to f64. Collective.
    pub fn global_sum(&self, t: &dyn Transport, epoch: u64) -> Result<f64> {
        allreduce(t, self.local_sum(), ReduceOp::Sum, epoch)
    }

    /// Global sum in `T` itself (wrapping for integer dtypes, f32
    /// accumulation for f32). Collective.
    pub fn global_sum_t(&self, t: &dyn Transport, epoch: u64) -> Result<T> {
        let local = self.loc().iter().fold(T::ZERO, |a, &b| T::add(a, b));
        allreduce_t(t, local, ReduceOp::Sum, epoch)
    }

    /// Global minimum (f64). Collective.
    pub fn global_min(&self, t: &dyn Transport, epoch: u64) -> Result<f64> {
        let local = self
            .loc()
            .iter()
            .map(|x| x.to_f64())
            .fold(f64::INFINITY, f64::min);
        allreduce(t, local, ReduceOp::Min, epoch)
    }

    /// Global minimum in `T` itself. Collective.
    pub fn global_min_t(&self, t: &dyn Transport, epoch: u64) -> Result<T> {
        let local = self.loc().iter().fold(T::MAX_BOUND, |a, &b| T::elem_min(a, b));
        allreduce_t(t, local, ReduceOp::Min, epoch)
    }

    /// Global maximum (f64). Collective.
    pub fn global_max(&self, t: &dyn Transport, epoch: u64) -> Result<f64> {
        let local = self
            .loc()
            .iter()
            .map(|x| x.to_f64())
            .fold(f64::NEG_INFINITY, f64::max);
        allreduce(t, local, ReduceOp::Max, epoch)
    }

    /// Global maximum in `T` itself. Collective.
    pub fn global_max_t(&self, t: &dyn Transport, epoch: u64) -> Result<T> {
        let local = self.loc().iter().fold(T::MIN_BOUND, |a, &b| T::elem_max(a, b));
        allreduce_t(t, local, ReduceOp::Max, epoch)
    }

    /// Global dot product `A(:)' * B(:)` in f64 (maps must align).
    /// Collective.
    pub fn global_dot(&self, other: &DarrayT<T>, t: &dyn Transport, epoch: u64) -> Result<f64> {
        self.check_aligned(other)?;
        let local: f64 = self
            .loc()
            .iter()
            .zip(other.loc())
            .map(|(a, b)| a.to_f64() * b.to_f64())
            .sum();
        allreduce(t, local, ReduceOp::Sum, epoch)
    }

    /// Global 2-norm `‖A(:)‖₂` in f64. Collective.
    pub fn global_norm2(&self, t: &dyn Transport, epoch: u64) -> Result<f64> {
        let local: f64 = self.loc().iter().map(|x| x.to_f64() * x.to_f64()).sum();
        Ok(allreduce(t, local, ReduceOp::Sum, epoch)?.sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::{CollKind, Topology};
    use crate::comm::ChannelHub;
    use crate::darray::dense::Darray;
    use crate::dmap::Dmap;
    use std::thread;

    fn spmd<R: Send + 'static>(
        np: usize,
        f: impl Fn(usize, &dyn Transport) -> R + Send + Sync + 'static,
    ) -> Vec<R> {
        let world = ChannelHub::world(np);
        let f = std::sync::Arc::new(f);
        world
            .into_iter()
            .map(|t| {
                let f = f.clone();
                thread::spawn(move || f(t.pid(), &t))
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    }

    #[test]
    fn sum_over_any_map_is_global_sum() {
        let n = 101;
        for mk in [Dmap::block_1d as fn(usize) -> Dmap, Dmap::cyclic_1d] {
            let sums = spmd(4, move |pid, t| {
                let a = Darray::from_global_fn(mk(4), &[n], pid, |g| g as f64);
                a.global_sum(t, 0).unwrap()
            });
            let want = (n * (n - 1) / 2) as f64;
            for s in sums {
                assert_eq!(s, want);
            }
        }
    }

    #[test]
    fn min_max_agree_on_every_pid() {
        let out = spmd(3, |pid, t| {
            let a = Darray::from_global_fn(Dmap::cyclic_1d(3), &[50], pid, |g| {
                (g as f64 - 20.0) * (g as f64 - 20.0)
            });
            (a.global_min(t, 1).unwrap(), a.global_max(t, 2).unwrap())
        });
        for (mn, mx) in out {
            assert_eq!(mn, 0.0); // at g = 20
            assert_eq!(mx, 29.0 * 29.0); // at g = 49
        }
    }

    #[test]
    fn dot_and_norm() {
        let out = spmd(4, |pid, t| {
            let m = Dmap::block_1d(4);
            let a = Darray::constant(m.clone(), &[64], pid, 2.0);
            let b = Darray::constant(m, &[64], pid, 3.0);
            (
                a.global_dot(&b, t, 3).unwrap(),
                a.global_norm2(t, 4).unwrap(),
            )
        });
        for (dot, norm) in out {
            assert_eq!(dot, 64.0 * 6.0);
            assert!((norm - (64.0f64 * 4.0).sqrt()).abs() < 1e-12);
        }
    }

    #[test]
    fn dot_requires_aligned_maps() {
        spmd(2, |pid, t| {
            let a = Darray::constant(Dmap::block_1d(2), &[10], pid, 1.0);
            let b = Darray::constant(Dmap::cyclic_1d(2), &[10], pid, 1.0);
            assert!(a.global_dot(&b, t, 5).is_err());
        });
    }

    #[test]
    fn single_pid_reduction_is_local() {
        spmd(1, |pid, t| {
            let a = Darray::from_global_fn(Dmap::block_1d(1), &[7], pid, |g| g as f64);
            assert_eq!(a.global_sum(t, 0).unwrap(), 21.0);
            assert!(t.stats().is_silent());
        });
    }

    #[test]
    fn typed_reductions_widen_to_f64() {
        let sums = spmd(3, |pid, t| {
            let a = DarrayT::<i64>::from_global_fn(Dmap::cyclic_1d(3), &[100], pid, |g| g as i64);
            let f = DarrayT::<f32>::from_global_fn(Dmap::block_1d(3), &[100], pid, |_| 0.5f32);
            (a.global_sum(t, 6).unwrap(), f.global_sum(t, 7).unwrap())
        });
        for (i_sum, f_sum) in sums {
            assert_eq!(i_sum, 4950.0);
            assert_eq!(f_sum, 50.0);
        }
    }

    /// The `*_t` entry points reduce in the array's own dtype: i64
    /// sums stay exact integers, u64 maxima never touch a float.
    #[test]
    fn native_dtype_reductions_skip_f64() {
        let out = spmd(4, |pid, t| {
            let a = DarrayT::<i64>::from_global_fn(Dmap::block_1d(4), &[64], pid, |g| {
                1 + (1i64 << 60) * (g == 0) as i64
            });
            let u = DarrayT::<u64>::from_global_fn(Dmap::cyclic_1d(4), &[64], pid, |g| g as u64);
            (
                a.global_sum_t(t, 8).unwrap(),
                u.global_max_t(t, 9).unwrap(),
                u.global_min_t(t, 10).unwrap(),
            )
        });
        for (s, mx, mn) in out {
            // 64 ones plus one 2^60 spike — exact in i64, lossy in f64.
            assert_eq!(s, 64 + (1i64 << 60));
            assert_eq!(mx, 63);
            assert_eq!(mn, 0);
        }
    }

    /// Every algorithm produces the bit-identical scalar (rank-order
    /// folding), via the explicit-context entry point.
    #[test]
    fn allreduce_with_matches_across_algorithms() {
        for kind in [CollKind::Star, CollKind::Tree, CollKind::Ring, CollKind::Hier] {
            let out = spmd(5, move |pid, t| {
                let coll = Collective::new(kind, Topology::grouped(5, 2));
                allreduce_with(&coll, t, 0.1f64 + pid as f64 * 1e-3, ReduceOp::Sum, 11).unwrap()
            });
            let want = (0..5).fold(0.0f64, |a, p| a + (0.1 + p as f64 * 1e-3));
            for got in out {
                assert_eq!(got.to_bits(), want.to_bits(), "kind {kind}");
            }
        }
    }
}
