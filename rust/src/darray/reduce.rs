//! Distributed reductions — the collective building blocks a pMatlab
//! user gets from `sum(A)`, `min(A)`, `norm(A)`, `dot(A,B)`.
//!
//! Client-server shape (§II): every PID reduces its local part, sends
//! one scalar to the leader, the leader combines and **broadcasts the
//! result back** so the call is collective and every PID returns the
//! same value (matching pMatlab semantics).

use super::dense::DarrayT;
use super::Result;
use crate::comm::{tags, Transport, WireReader, WireWriter};
use crate::element::Element;

/// A binary reduction operator over f64.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    Sum,
    Min,
    Max,
}

impl ReduceOp {
    #[inline]
    fn identity(&self) -> f64 {
        match self {
            ReduceOp::Sum => 0.0,
            ReduceOp::Min => f64::INFINITY,
            ReduceOp::Max => f64::NEG_INFINITY,
        }
    }

    #[inline]
    fn combine(&self, a: f64, b: f64) -> f64 {
        match self {
            ReduceOp::Sum => a + b,
            ReduceOp::Min => a.min(b),
            ReduceOp::Max => a.max(b),
        }
    }
}

/// Collective scalar reduction over all PIDs of a map. SPMD.
pub fn allreduce(t: &dyn Transport, local: f64, op: ReduceOp, epoch: u64) -> Result<f64> {
    let tag = tags::pack(tags::NS_REDUCE, epoch, 0);
    let np = t.np();
    if np == 1 {
        return Ok(local);
    }
    if t.pid() == 0 {
        let mut acc = local;
        for from in 1..np {
            let payload = t.recv(from, tag)?;
            let v = WireReader::new(&payload).get_f64()?;
            acc = op.combine(acc, v);
        }
        let mut w = WireWriter::new();
        w.put_f64(acc);
        let bytes = w.finish();
        for to in 1..np {
            t.send(to, tag, &bytes)?;
        }
        Ok(acc)
    } else {
        let mut w = WireWriter::new();
        w.put_f64(local);
        t.send(0, tag, &w.finish())?;
        let payload = t.recv(0, tag)?;
        Ok(WireReader::new(&payload).get_f64()?)
    }
}

impl<T: Element> DarrayT<T> {
    /// Global sum: `sum(A(:))`, widened to f64. Collective.
    pub fn global_sum(&self, t: &dyn Transport, epoch: u64) -> Result<f64> {
        allreduce(t, self.local_sum(), ReduceOp::Sum, epoch)
    }

    /// Global minimum (f64). Collective.
    pub fn global_min(&self, t: &dyn Transport, epoch: u64) -> Result<f64> {
        let local = self
            .loc()
            .iter()
            .map(|x| x.to_f64())
            .fold(f64::INFINITY, f64::min);
        allreduce(t, local, ReduceOp::Min, epoch)
    }

    /// Global maximum (f64). Collective.
    pub fn global_max(&self, t: &dyn Transport, epoch: u64) -> Result<f64> {
        let local = self
            .loc()
            .iter()
            .map(|x| x.to_f64())
            .fold(f64::NEG_INFINITY, f64::max);
        allreduce(t, local, ReduceOp::Max, epoch)
    }

    /// Global dot product `A(:)' * B(:)` in f64 (maps must align).
    /// Collective.
    pub fn global_dot(&self, other: &DarrayT<T>, t: &dyn Transport, epoch: u64) -> Result<f64> {
        self.check_aligned(other)?;
        let local: f64 = self
            .loc()
            .iter()
            .zip(other.loc())
            .map(|(a, b)| a.to_f64() * b.to_f64())
            .sum();
        allreduce(t, local, ReduceOp::Sum, epoch)
    }

    /// Global 2-norm `‖A(:)‖₂` in f64. Collective.
    pub fn global_norm2(&self, t: &dyn Transport, epoch: u64) -> Result<f64> {
        let local: f64 = self.loc().iter().map(|x| x.to_f64() * x.to_f64()).sum();
        Ok(allreduce(t, local, ReduceOp::Sum, epoch)?.sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ChannelHub;
    use crate::darray::dense::Darray;
    use crate::dmap::Dmap;
    use std::thread;

    fn spmd<R: Send + 'static>(
        np: usize,
        f: impl Fn(usize, &dyn Transport) -> R + Send + Sync + 'static,
    ) -> Vec<R> {
        let world = ChannelHub::world(np);
        let f = std::sync::Arc::new(f);
        world
            .into_iter()
            .map(|t| {
                let f = f.clone();
                thread::spawn(move || f(t.pid(), &t))
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    }

    #[test]
    fn sum_over_any_map_is_global_sum() {
        let n = 101;
        for mk in [Dmap::block_1d as fn(usize) -> Dmap, Dmap::cyclic_1d] {
            let sums = spmd(4, move |pid, t| {
                let a = Darray::from_global_fn(mk(4), &[n], pid, |g| g as f64);
                a.global_sum(t, 0).unwrap()
            });
            let want = (n * (n - 1) / 2) as f64;
            for s in sums {
                assert_eq!(s, want);
            }
        }
    }

    #[test]
    fn min_max_agree_on_every_pid() {
        let out = spmd(3, |pid, t| {
            let a = Darray::from_global_fn(Dmap::cyclic_1d(3), &[50], pid, |g| {
                (g as f64 - 20.0) * (g as f64 - 20.0)
            });
            (a.global_min(t, 1).unwrap(), a.global_max(t, 2).unwrap())
        });
        for (mn, mx) in out {
            assert_eq!(mn, 0.0); // at g = 20
            assert_eq!(mx, 29.0 * 29.0); // at g = 49
        }
    }

    #[test]
    fn dot_and_norm() {
        let out = spmd(4, |pid, t| {
            let m = Dmap::block_1d(4);
            let a = Darray::constant(m.clone(), &[64], pid, 2.0);
            let b = Darray::constant(m, &[64], pid, 3.0);
            (
                a.global_dot(&b, t, 3).unwrap(),
                a.global_norm2(t, 4).unwrap(),
            )
        });
        for (dot, norm) in out {
            assert_eq!(dot, 64.0 * 6.0);
            assert!((norm - (64.0f64 * 4.0).sqrt()).abs() < 1e-12);
        }
    }

    #[test]
    fn dot_requires_aligned_maps() {
        spmd(2, |pid, t| {
            let a = Darray::constant(Dmap::block_1d(2), &[10], pid, 1.0);
            let b = Darray::constant(Dmap::cyclic_1d(2), &[10], pid, 1.0);
            assert!(a.global_dot(&b, t, 5).is_err());
        });
    }

    #[test]
    fn single_pid_reduction_is_local() {
        spmd(1, |pid, t| {
            let a = Darray::from_global_fn(Dmap::block_1d(1), &[7], pid, |g| g as f64);
            assert_eq!(a.global_sum(t, 0).unwrap(), 21.0);
            assert!(t.stats().is_silent());
        });
    }

    #[test]
    fn typed_reductions_widen_to_f64() {
        let sums = spmd(3, |pid, t| {
            let a = DarrayT::<i64>::from_global_fn(Dmap::cyclic_1d(3), &[100], pid, |g| g as i64);
            let f = DarrayT::<f32>::from_global_fn(Dmap::block_1d(3), &[100], pid, |_| 0.5f32);
            (a.global_sum(t, 6).unwrap(), f.global_sum(t, 7).unwrap())
        });
        for (i_sum, f_sum) in sums {
            assert_eq!(i_sum, 4950.0);
            assert_eq!(f_sum, 50.0);
        }
    }
}
