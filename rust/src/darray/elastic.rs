//! Elastic re-deal — remapping a darray from P to P−k (or P+k)
//! owners after the failure detector shrinks the world.
//!
//! Shrink/grow is literally a remap: the destination map is the same
//! distribution dealt over the survivor list
//! ([`Dmap::redeal_1d`](crate::dmap::Dmap::redeal_1d)), the transfer
//! plan comes from the ordinary [`RemapEngine`], and the data moves
//! over the same coalesced per-peer streams as any `assign_from`.
//! Two failure-specific twists:
//!
//! * **Epoch bump** — the caller runs the redeal under a *new* epoch,
//!   and epochs are baked into the message tag
//!   ([`tags::pack`](crate::comm::tags::pack)), so anything a dead
//!   rank sent under the old epoch can never match a redeal receive.
//!   Stale messages are rejected by tag, not by luck.
//! * **Lost shards** — data owned solely by a dead rank is gone; no
//!   protocol can fetch it. Incoming groups whose source is not in
//!   the survivor list are *refilled* locally from a caller-supplied
//!   `refill(global_index)` (deterministic re-initialization, or
//!   values restored from a [`ckpt_v1`](crate::fault::ckpt) shard).
//!   [`DarrayT::redeal`] zero-fills; when every source PID survives
//!   (pure elastic shrink/grow of a live world) nothing is refilled
//!   and the result is exactly the remap.

use super::dense::DarrayT;
use super::engine::{remap_tag, send_group_typed, GroupScatter, RemapEngine};
use super::{DarrayError, Result};
use crate::comm::{ChunkStream, Transport};
use crate::dmap::Pid;
use crate::element::Element;
use crate::obs::EventKind;
use crate::obs_span;

impl<T: Element> DarrayT<T> {
    /// Re-deal this array onto `survivors`, zero-filling any region
    /// whose only copy lived on a dead rank. See
    /// [`redeal_with`](DarrayT::redeal_with) for the general form.
    /// SPMD: every survivor calls this with the same `survivors` and
    /// `epoch`.
    pub fn redeal(
        &self,
        survivors: &[Pid],
        t: &dyn Transport,
        epoch: u64,
        engine: &RemapEngine,
    ) -> Result<DarrayT<T>> {
        self.redeal_with(survivors, t, epoch, engine, |_| T::ZERO)
    }

    /// Re-deal this array onto `survivors`, rebuilding dead ranks'
    /// regions from `refill(global_flat_index)`.
    ///
    /// The destination map is this map's distribution over
    /// `survivors`; the plan comes from `engine` (cached per map
    /// pair). `epoch` must be **fresh** — strictly newer than any
    /// epoch the failed configuration used — so in-flight messages
    /// from the dead rank can never alias the redeal's tag stream.
    /// Sends target only survivors by construction (the destination
    /// map contains no dead PID); receives from dead sources are
    /// replaced by local refills.
    pub fn redeal_with(
        &self,
        survivors: &[Pid],
        t: &dyn Transport,
        epoch: u64,
        engine: &RemapEngine,
        refill: impl Fn(usize) -> T,
    ) -> Result<DarrayT<T>> {
        let dst_map = self.map().redeal_1d(survivors).ok_or_else(|| {
            DarrayError::Unsupported(format!(
                "redeal needs a 1-D map and a non-empty survivor list \
                 (ndim={}, survivors={})",
                self.map().ndim(),
                survivors.len()
            ))
        })?;
        if !dst_map.contains(self.pid()) {
            return Err(DarrayError::Unsupported(format!(
                "pid {} is not a survivor; dead ranks do not participate in a redeal",
                self.pid()
            )));
        }
        let t0 = crate::obs::span_begin();
        let pid = self.pid();
        let shape = self.shape().to_vec();
        let mut dst = DarrayT::<T>::zeros(dst_map.clone(), &shape, pid);
        let plan = engine.plan(self.map(), &dst_map, &shape);
        let tag = remap_tag(epoch);
        if plan.is_aligned() {
            dst.loc_mut().copy_from_slice(self.loc());
            return Ok(dst);
        }
        for &(s_off, d_off, len) in plan.local_copies(pid) {
            dst.loc_mut()[d_off..d_off + len].copy_from_slice(&self.loc()[s_off..s_off + len]);
        }
        // Outgoing groups all target survivors — the destination map
        // contains nothing else.
        for g in plan.peer_sends(pid) {
            send_group_typed::<T>(g, self.loc(), t, tag)?;
        }
        // Incoming groups split by source liveness: survivors are
        // drained as coalesced streams, dead sources are refilled.
        let alive = |p: Pid| survivors.contains(&p);
        let groups = plan.peer_recvs(pid);
        let dst_loc = dst.loc_mut();
        for g in groups.iter().filter(|g| !alive(g.peer)) {
            for (r, &off) in g.ranges.iter().zip(&g.local_offsets) {
                for (k, slot) in dst_loc[off..off + r.len()].iter_mut().enumerate() {
                    *slot = refill(r.lo + k);
                }
            }
        }
        let live: Vec<_> = groups.iter().filter(|g| alive(g.peer)).collect();
        let peers: Vec<Pid> = live.iter().map(|g| g.peer).collect();
        let mut scatters: Vec<GroupScatter<'_, T>> =
            live.iter().map(|g| GroupScatter::new(g)).collect();
        ChunkStream::drain_chunks(t, &peers, tag, |c| {
            scatters[c.peer_idx].feed(c.payload(), dst_loc)
        })?;
        for s in &scatters {
            s.finish()?;
        }
        obs_span!(
            EventKind::Redeal,
            t0,
            tag: tag.at(0),
            peer: crate::obs::NO_PEER,
            a: dst.global_len() as u64,
            b: survivors.len() as u64
        );
        Ok(dst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ChannelHub;
    use crate::darray::Darray;
    use crate::dmap::Dmap;
    use std::sync::Arc;
    use std::thread;

    /// SPMD over an explicit participant list (survivors may be a
    /// strict subset of the world).
    fn spmd_on(
        np: usize,
        participants: &[Pid],
        f: impl Fn(usize, &dyn Transport) + Send + Sync + 'static,
    ) {
        let world = ChannelHub::world(np);
        let f = Arc::new(f);
        let mut hs = Vec::new();
        for t in world {
            if !participants.contains(&t.pid()) {
                continue;
            }
            let f = f.clone();
            hs.push(thread::spawn(move || f(t.pid(), &t)));
        }
        for h in hs {
            h.join().unwrap();
        }
    }

    #[test]
    fn shrink_with_all_sources_alive_preserves_every_element() {
        // 4 → 3 owners, nobody dead: a pure elastic shrink. Every
        // global element must survive the move.
        spmd_on(4, &[0, 1, 2, 3], |pid, t| {
            let src = Darray::from_global_fn(Dmap::block_1d(4), &[97], pid, |g| g as f64 + 0.25);
            let survivors = [0, 1, 2];
            if !survivors.contains(&pid) {
                // Rank 3 still participates as a *source*: it owns a
                // block that must flow to the survivors.
                let engine = RemapEngine::new();
                let dst_map = src.map().redeal_1d(&survivors).unwrap();
                let plan = engine.plan(src.map(), &dst_map, &[97]);
                for g in plan.peer_sends(pid) {
                    send_group_typed::<f64>(g, src.loc(), t, remap_tag(1)).unwrap();
                }
                return;
            }
            let engine = RemapEngine::new();
            let dst = src.redeal(&survivors, t, 1, &engine).unwrap();
            assert_eq!(dst.map().np(), 3);
            for g in 0..97 {
                if let Some(v) = dst.global_get(g) {
                    assert_eq!(v, g as f64 + 0.25, "pid={pid} g={g}");
                }
            }
        });
    }

    #[test]
    fn dead_source_regions_are_refilled_not_hung() {
        // Rank 1 of 3 is dead and never sends. Its block is refilled
        // from the closure; everything else moves normally.
        let n = 60usize;
        spmd_on(3, &[0, 2], move |pid, t| {
            let src = Darray::from_global_fn(Dmap::block_1d(3), &[n], pid, |g| g as f64);
            let engine = RemapEngine::new();
            let survivors = [0, 2];
            let dst = src.redeal_with(&survivors, t, 1, &engine, |g| -(g as f64)).unwrap();
            for g in 0..n {
                if let Some(v) = dst.global_get(g) {
                    let dead_owned = src.map().owner(&[g], &[n]) == 1;
                    let want = if dead_owned { -(g as f64) } else { g as f64 };
                    assert_eq!(v, want, "pid={pid} g={g}");
                }
            }
        });
    }

    #[test]
    fn stale_old_epoch_messages_are_ignored_by_tag() {
        // A message the "dead" rank sent under the old epoch sits in
        // a survivor's mailbox; the redeal runs under a bumped epoch
        // and must never consume it. Survivors [1, 0] flip block
        // ownership, so the redeal genuinely communicates past the
        // poisoned mailbox entry.
        let n = 40usize;
        spmd_on(2, &[0, 1], move |pid, t| {
            if pid == 1 {
                // Poison: bytes under the OLD epoch's remap tag.
                t.send(0, remap_tag(0).at(0), b"stale garbage from a dying rank").unwrap();
            }
            let src = Darray::from_global_fn(Dmap::block_1d(2), &[n], pid, |g| g as f64);
            let engine = RemapEngine::new();
            let dst = src.redeal(&[1, 0], t, 1, &engine).unwrap();
            assert!(!t.stats().is_silent(), "reordered survivors must communicate");
            for g in 0..n {
                if let Some(v) = dst.global_get(g) {
                    assert_eq!(v, g as f64);
                }
            }
        });
    }

    #[test]
    fn non_survivor_caller_is_an_error() {
        spmd_on(2, &[0], |pid, t| {
            let src = Darray::from_global_fn(Dmap::block_1d(2), &[8], pid, |g| g as f64);
            let engine = RemapEngine::new();
            let err = src.redeal(&[1], t, 1, &engine).unwrap_err();
            assert!(err.to_string().contains("not a survivor"), "{err}");
        });
    }

    #[test]
    fn redeal_of_2d_map_is_unsupported() {
        spmd_on(1, &[0], |pid, t| {
            let src = Darray::zeros(Dmap::block_2d(1, 1), &[4, 4], pid);
            let engine = RemapEngine::new();
            let err = src.redeal(&[0], t, 1, &engine).unwrap_err();
            assert!(err.to_string().contains("1-D"), "{err}");
        });
    }
}
