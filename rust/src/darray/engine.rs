//! [`RemapEngine`] — reusable remap planning for the whole
//! darray/comm/stream stack.
//!
//! A remap (`A(:) = B` across different maps, a pipeline stage hand-
//! off, a halo-style redistribution) is two separable concerns:
//!
//! 1. **Planning** — intersect the source and destination
//!    [`Partition`]s into a transfer list, group it **per peer**, and
//!    precompute every local/payload offset the data movement will
//!    need. Pure index arithmetic, identical on every PID, O(ranges)
//!    work.
//! 2. **Execution** — move bytes per the plan over a
//!    [`Transport`](crate::comm::Transport). O(data) work.
//!
//! Execution is the bandwidth hot path, and it is built to be
//! bandwidth-bound rather than allocation/syscall-bound:
//!
//! * **One coalesced stream per destination peer** per epoch
//!   ([`PeerGroup`]): all ranges flowing between a PID pair travel as
//!   `[n_ranges][(dst_lo, len)…][count][dtype][packed payload]`,
//!   so a block→cyclic remap costs `np − 1` streams per PID instead
//!   of one per plan step (which for strided maps means one per
//!   element run).
//! * **The shared datapath** ([`crate::comm::datapath`]): headers and
//!   payloads live in pooled wire buffers (checked out per send,
//!   returned on completion — steady-state remap loops allocate
//!   nothing on the send path) and travel as a
//!   [`ChunkStream`](crate::comm::ChunkStream), which also pipelines
//!   multi-MB payloads in chunks without staging copies.
//! * **Bulk byte-cast packing**: payloads are gathered and scattered
//!   with the [`Element`] bulk codec (one memcpy per contiguous range
//!   on little-endian targets, never a per-element loop).
//! * **Arrival-order receives**: incoming peers are drained with
//!   non-blocking sweeps ([`ChunkStream::drain`](crate::comm::ChunkStream::drain)),
//!   so a slow peer does not serialize the unpacking of the fast
//!   ones.
//!
//! [`RemapPlan`] materializes concern 1 as a value; [`RemapEngine`]
//! caches plans keyed by `(src_map, dst_map, shape)` so a repeated
//! remap plans **exactly once** (observable via
//! [`RemapEngine::plans_built`] — the tests assert it rather than
//! assume it). Plans are returned as `Arc`s: SPMD threads of one
//! process can share one engine. Since [`Dmap`] is `Arc`-backed with
//! a precomputed fingerprint, a cache hit costs a mutex plus an O(1)
//! hash lookup — no deep map clone or structural compare. The cache
//! lock is never held during data movement; it IS held across the
//! build of a missing plan, which keeps the build counter exact under
//! thread races at the cost of serializing first-touch planning.

use crate::comm::datapath::{self, ChunkStream, ChunkTag};
use crate::comm::{tags, CommError, Transport, WireReader, WireWriter};
use crate::dmap::{Dmap, GlobalRange, Partition, Pid};
use crate::element::Element;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Per-PID offset table: `(global_lo, len, local_offset)` per owned
/// contiguous range, in ascending global order.
pub type OffsetTable = Vec<(usize, usize, usize)>;

/// The remap stream tag for `epoch`: one coalesced chunk stream per
/// peer pair per epoch, so the `(from, tag)` match fully identifies
/// it (sub-chunk-size payloads keep the historical single message
/// with step 0).
#[inline]
pub(crate) fn remap_tag(epoch: u64) -> ChunkTag {
    ChunkTag::new(tags::NS_REMAP, epoch)
}

/// One peer's coalesced transfer group under a plan: every range that
/// flows between this PID and `peer`, in deterministic plan order,
/// with local and payload offsets precomputed at plan time so
/// execution is pure memcpy plus exactly one message.
#[derive(Debug)]
pub struct PeerGroup {
    /// The other endpoint (the sender's destination / the receiver's
    /// source).
    pub peer: Pid,
    /// Global ranges carried by this group's single message.
    pub ranges: Vec<GlobalRange>,
    /// Local offset of each range in the owning side's layout (the
    /// sender's source layout / the receiver's destination layout).
    pub local_offsets: Vec<usize>,
    /// Exclusive prefix sums of range lengths: range `i`'s elements
    /// occupy `[payload_offsets[i], payload_offsets[i] + len_i)` of
    /// the packed payload.
    pub payload_offsets: Vec<usize>,
    /// Total elements in the packed payload.
    pub total: usize,
    /// One past the highest local element this group touches
    /// (`max(local_offsets[i] + len_i)`) — the bounds witness the
    /// raw-pointer pack/unpack kernels check against the slice length
    /// before running.
    pub local_extent: usize,
}

impl PeerGroup {
    fn build(peer: Pid, ranges: Vec<GlobalRange>, table: &OffsetTable) -> PeerGroup {
        let local_offsets: Vec<usize> = ranges.iter().map(|r| lookup(table, r.lo)).collect();
        let mut payload_offsets = Vec::with_capacity(ranges.len());
        let mut total = 0usize;
        let mut local_extent = 0usize;
        for (r, &off) in ranges.iter().zip(&local_offsets) {
            payload_offsets.push(total);
            total += r.len();
            local_extent = local_extent.max(off + r.len());
        }
        PeerGroup { peer, ranges, local_offsets, payload_offsets, total, local_extent }
    }

    /// `(local_offset, len)` pieces in payload order — the gather /
    /// scatter list the codec calls consume.
    pub fn segs(&self) -> impl Iterator<Item = (usize, usize)> + Clone + '_ {
        self.ranges.iter().zip(&self.local_offsets).map(|(r, &off)| (off, r.len()))
    }

    /// Wire size of this group's message header (the range table; the
    /// typed-slice prefix lives at the head of the payload part).
    pub(crate) fn header_bytes(&self) -> usize {
        8 + 16 * self.ranges.len()
    }
}

/// A fully precomputed remap: the transfer list, both sides' offset
/// tables, and the per-peer coalesced groups. Everything
/// `assign_from` needs except the data.
#[derive(Debug)]
pub struct RemapPlan {
    /// Source and destination assign identical ownership — execution
    /// degenerates to a local copy with zero messages.
    aligned: bool,
    /// `(src_pid, dst_pid, global_range)` transfers, in deterministic
    /// plan order (empty when `aligned`). Entries with
    /// `src_pid == dst_pid` are local copies, not messages.
    transfers: Vec<(Pid, Pid, GlobalRange)>,
    src_offsets: HashMap<Pid, OffsetTable>,
    dst_offsets: HashMap<Pid, OffsetTable>,
    /// Per sender: coalesced outgoing groups, ascending peer order.
    peer_sends: HashMap<Pid, Vec<PeerGroup>>,
    /// Per receiver: coalesced incoming groups, ascending peer order.
    peer_recvs: HashMap<Pid, Vec<PeerGroup>>,
    /// Per PID: `(src_offset, dst_offset, len)` purely local copies.
    locals: HashMap<Pid, Vec<(usize, usize, usize)>>,
}

impl RemapPlan {
    /// Plan the remap of an array of `shape` from `src` to `dst`.
    pub fn build(src: &Dmap, dst: &Dmap, shape: &[usize]) -> RemapPlan {
        let src_part = Partition::of(src, shape);
        let dst_part = Partition::of(dst, shape);
        if src_part.same_ownership(&dst_part) {
            return RemapPlan {
                aligned: true,
                transfers: Vec::new(),
                src_offsets: HashMap::new(),
                dst_offsets: HashMap::new(),
                peer_sends: HashMap::new(),
                peer_recvs: HashMap::new(),
                locals: HashMap::new(),
            };
        }
        let transfers = src_part.transfers_to(&dst_part);
        let src_offsets = offset_tables(&src_part, src);
        let dst_offsets = offset_tables(&dst_part, dst);

        // Group the transfer list per communicating pair (BTreeMap ⇒
        // deterministic ascending peer order on every PID).
        type ByPeer = BTreeMap<Pid, Vec<GlobalRange>>;
        let mut sends: HashMap<Pid, ByPeer> = HashMap::new();
        let mut recvs: HashMap<Pid, ByPeer> = HashMap::new();
        let mut locals: HashMap<Pid, Vec<(usize, usize, usize)>> = HashMap::new();
        for &(sp, dp, r) in &transfers {
            if sp == dp {
                locals.entry(sp).or_default().push((
                    lookup(&src_offsets[&sp], r.lo),
                    lookup(&dst_offsets[&dp], r.lo),
                    r.len(),
                ));
            } else {
                sends.entry(sp).or_default().entry(dp).or_default().push(r);
                recvs.entry(dp).or_default().entry(sp).or_default().push(r);
            }
        }
        let peer_sends = sends
            .into_iter()
            .map(|(pid, by_peer)| {
                let table = &src_offsets[&pid];
                let groups = by_peer
                    .into_iter()
                    .map(|(peer, ranges)| PeerGroup::build(peer, ranges, table))
                    .collect();
                (pid, groups)
            })
            .collect();
        let peer_recvs = recvs
            .into_iter()
            .map(|(pid, by_peer)| {
                let table = &dst_offsets[&pid];
                let groups = by_peer
                    .into_iter()
                    .map(|(peer, ranges)| PeerGroup::build(peer, ranges, table))
                    .collect();
                (pid, groups)
            })
            .collect();
        RemapPlan {
            aligned: false,
            transfers,
            src_offsets,
            dst_offsets,
            peer_sends,
            peer_recvs,
            locals,
        }
    }

    /// Source and destination own identical index sets?
    pub fn is_aligned(&self) -> bool {
        self.aligned
    }

    /// The transfer list (empty for aligned plans).
    pub fn transfers(&self) -> &[(Pid, Pid, GlobalRange)] {
        &self.transfers
    }

    /// Coalesced outgoing groups for `pid` — one message each.
    pub fn peer_sends(&self, pid: Pid) -> &[PeerGroup] {
        self.peer_sends.get(&pid).map_or(&[], Vec::as_slice)
    }

    /// Coalesced incoming groups for `pid` — one message each.
    pub fn peer_recvs(&self, pid: Pid) -> &[PeerGroup] {
        self.peer_recvs.get(&pid).map_or(&[], Vec::as_slice)
    }

    /// Purely local `(src_offset, dst_offset, len)` copies for `pid`.
    pub fn local_copies(&self, pid: Pid) -> &[(usize, usize, usize)] {
        self.locals.get(&pid).map_or(&[], Vec::as_slice)
    }

    /// Messages `pid` will actually send/receive under this plan —
    /// with per-peer coalescing, one per distinct communicating peer
    /// per direction (**not** one per plan step), and still zero for
    /// aligned plans. The "bounded communication" number.
    pub fn message_count(&self, pid: Pid) -> usize {
        self.peer_sends(pid).len() + self.peer_recvs(pid).len()
    }

    /// Local offset of global index `g` in `pid`'s **source** layout.
    pub fn src_offset(&self, pid: Pid, g: usize) -> usize {
        lookup(&self.src_offsets[&pid], g)
    }

    /// Local offset of global index `g` in `pid`'s **destination**
    /// layout.
    pub fn dst_offset(&self, pid: Pid, g: usize) -> usize {
        lookup(&self.dst_offsets[&pid], g)
    }

    /// Execute this plan's transfer list on an execution backend: the
    /// typed local parts are erased into the backend currency and the
    /// data movement is delegated to
    /// [`Backend::execute_plan`](crate::backend::Backend::execute_plan).
    /// The plan MUST have been built for `(src map, dst map, shape)`
    /// of the arrays these slices belong to.
    pub fn execute_on<T: Element>(
        &self,
        backend: &dyn crate::backend::Backend,
        src: &[T],
        dst: &mut [T],
        pid: Pid,
        t: &dyn Transport,
        epoch: u64,
    ) -> crate::backend::Result<()> {
        backend.execute_plan(self, T::erase(src), T::erase_mut(dst), pid, t, epoch)
    }
}

/// Execute a prebuilt remap plan for one PID's typed local parts:
/// aligned plans degenerate to a memcpy; otherwise local pieces copy
/// and remote pieces travel as **one coalesced message per peer**,
/// packed from pooled wire buffers by the bulk codec and received in
/// arrival order.
///
/// This is the single data-movement routine behind both
/// `DarrayT::assign_from*` and every host-class
/// [`Backend::execute_plan`](crate::backend::Backend::execute_plan)
/// implementation — one definition, bit-identical outcomes.
pub fn execute_plan_typed<T: Element>(
    plan: &RemapPlan,
    src: &[T],
    dst: &mut [T],
    pid: Pid,
    t: &dyn Transport,
    epoch: u64,
) -> crate::comm::Result<()> {
    // Fast path: aligned maps → pure local copy, zero messages.
    if plan.is_aligned() {
        dst.copy_from_slice(src);
        return Ok(());
    }
    let t0 = crate::obs::span_begin();
    let tag = remap_tag(epoch);
    for &(s_off, d_off, len) in plan.local_copies(pid) {
        dst[d_off..d_off + len].copy_from_slice(&src[s_off..s_off + len]);
    }
    for g in plan.peer_sends(pid) {
        send_group_typed::<T>(g, src, t, tag)?;
    }
    recv_groups_into::<T>(plan, pid, t, tag, dst)?;
    let sent_bytes: usize = plan.peer_sends(pid).iter().map(|g| g.total * T::WIDTH).sum();
    let peers = plan.message_count(pid);
    crate::obs_span!(
        crate::obs::EventKind::RemapExec,
        t0,
        tag: tag.at(0),
        peer: crate::obs::NO_PEER,
        a: sent_bytes as u64,
        b: peers as u64
    );
    Ok(())
}

/// Pack and send one peer's coalesced message:
/// `[n_ranges][(dst_lo, len)…][count][dtype][payload]`, streamed as a
/// [`ChunkStream`] over the shared datapath. Header and payload live
/// in pooled wire buffers (zero steady-state allocations); the
/// payload is gathered straight from `src` by the bulk codec; the
/// stream layer windows both parts straight into
/// [`Transport::send_parts`] without concatenating them. The caller
/// supplies the `tag` (remap epochs, pipeline stage epochs, …) — one
/// coalesced stream per peer per tag.
pub(crate) fn send_group_typed<T: Element>(
    g: &PeerGroup,
    src: &[T],
    t: &dyn Transport,
    tag: ChunkTag,
) -> crate::comm::Result<()> {
    let mut header = datapath::checkout(g.header_bytes());
    let mut w = WireWriter::from_vec(header.take());
    write_group_header(&mut w, g);
    header.restore(w.finish());

    let mut payload = datapath::checkout(9 + g.total * T::WIDTH);
    let mut pw = WireWriter::from_vec(payload.take());
    pw.put_slice_gather::<T>(src, g.segs());
    payload.restore(pw.finish());
    ChunkStream::send(
        t,
        g.peer,
        tag,
        datapath::ambient_chunk_bytes(),
        &[header.as_slice(), payload.as_slice()],
    )?;
    Ok(())
}

/// The coalesced message header: the range table. The typed-slice
/// framing (`[count][dtype]`) opens the payload part, written by
/// `put_slice_gather` (or its parallel equivalent).
pub(crate) fn write_group_header(w: &mut WireWriter, g: &PeerGroup) {
    w.put_u64(g.ranges.len() as u64);
    for r in &g.ranges {
        w.put_u64(r.lo as u64);
        w.put_u64(r.len() as u64);
    }
}

/// Validate one received message's range table against the plan's
/// expectation for this group.
fn check_group_header(g: &PeerGroup, rd: &mut WireReader) -> crate::comm::Result<()> {
    let n = rd.get_usize()?;
    if n != g.ranges.len() {
        return Err(CommError::Malformed(format!(
            "coalesced remap: message carries {n} ranges, plan expects {}",
            g.ranges.len()
        )));
    }
    for want in &g.ranges {
        let lo = rd.get_usize()?;
        let len = rd.get_usize()?;
        if lo != want.lo || len != want.len() {
            return Err(CommError::Malformed(format!(
                "coalesced remap: range ({lo}, {len}) does not match plan ({}, {})",
                want.lo,
                want.len()
            )));
        }
    }
    Ok(())
}

/// Scatter one coalesced message into `dst` per the group's
/// precomputed offsets (serial; the chunked backend has a
/// pool-parallel counterpart over [`check_group_payload`]).
pub(crate) fn unpack_group_typed<T: Element>(
    g: &PeerGroup,
    payload: &[u8],
    dst: &mut [T],
) -> crate::comm::Result<()> {
    let mut rd = WireReader::new(payload);
    check_group_header(g, &mut rd)?;
    rd.get_slice_scatter::<T>(dst, g.segs())
}

/// Validate a coalesced message fully and return its raw packed
/// payload bytes (for callers that scatter in parallel).
pub(crate) fn check_group_payload<'a, T: Element>(
    g: &PeerGroup,
    payload: &'a [u8],
) -> crate::comm::Result<&'a [u8]> {
    let mut rd = WireReader::new(payload);
    check_group_header(g, &mut rd)?;
    let n = rd.slice_header::<T>()?;
    if n != g.total {
        return Err(CommError::Malformed(format!(
            "coalesced remap: payload frames {n} elements, plan expects {}",
            g.total
        )));
    }
    let bytes = rd.take_raw(n * T::WIDTH)?;
    if rd.remaining() != 0 {
        return Err(CommError::Malformed(format!(
            "coalesced remap: {} trailing bytes after payload",
            rd.remaining()
        )));
    }
    Ok(bytes)
}

/// Scatter one byte window of a group's **packed payload space** into
/// `dst` at the group's precomputed offsets. `byte_off` is the
/// window's offset within the packed payload (element
/// `payload_offsets[i]` starts at byte `payload_offsets[i] × WIDTH`);
/// windows may start or end mid-element — a split element completes
/// across consecutive windows through the destination's byte view.
///
/// Little-endian targets only (raw element bytes ARE the wire
/// encoding); callers gate on endianness. The caller must have
/// checked `local_extent ≤ dst.len()` and
/// `byte_off + bytes.len() ≤ total × WIDTH`.
pub(crate) fn scatter_payload_bytes<T: Element>(
    g: &PeerGroup,
    byte_off: usize,
    bytes: &[u8],
    dst: &mut [T],
) {
    let width = T::WIDTH;
    debug_assert!(byte_off + bytes.len() <= g.total * width);
    // SAFETY: `Element` impls are plain-old-data; the byte view lets a
    // window boundary split an element and still land every byte.
    let dst_bytes = unsafe {
        std::slice::from_raw_parts_mut(dst.as_mut_ptr() as *mut u8, dst.len() * width)
    };
    let mut k = g.payload_offsets.partition_point(|&p| p * width <= byte_off) - 1;
    let mut pos = byte_off;
    let mut src = bytes;
    while !src.is_empty() {
        let seg_lo = g.payload_offsets[k] * width;
        let seg_hi = seg_lo + g.ranges[k].len() * width;
        if pos == seg_hi {
            k += 1;
            continue;
        }
        let n = (seg_hi - pos).min(src.len());
        let local = g.local_offsets[k] * width + (pos - seg_lo);
        dst_bytes[local..local + n].copy_from_slice(&src[..n]);
        pos += n;
        src = &src[n..];
    }
}

/// Incremental consumer of one peer's coalesced message under a
/// chunk-granular drain ([`ChunkStream::drain_chunks`]): accumulates
/// and validates the prefix (range table + typed-slice header) once,
/// then scatters every later byte window straight into the
/// destination — the compute-on-arrival replacement for reassembling
/// a `Vec<u8>` per peer and unpacking it after the fact.
///
/// Chunk boundaries are arbitrary: a window may split the prefix, or
/// a single element, and the byte cursor carries across. Little-
/// endian targets only; callers gate on endianness.
pub(crate) struct GroupScatter<'a, T: Element> {
    g: &'a PeerGroup,
    /// Accumulated message head until `header_bytes() + 9` bytes land.
    prefix: Vec<u8>,
    /// Packed payload bytes consumed so far.
    scattered: usize,
    _t: std::marker::PhantomData<T>,
}

impl<'a, T: Element> GroupScatter<'a, T> {
    pub(crate) fn new(g: &'a PeerGroup) -> GroupScatter<'a, T> {
        let prefix_len = g.header_bytes() + 9;
        GroupScatter {
            g,
            prefix: Vec::with_capacity(prefix_len),
            scattered: 0,
            _t: std::marker::PhantomData,
        }
    }

    /// Consume one landed chunk's bytes. Returns the chunk's validated
    /// payload window and its byte offset in the packed payload space
    /// — `None` while the window is still all prefix. The prefix is
    /// validated against the plan the moment it completes.
    pub(crate) fn feed_raw<'b>(
        &mut self,
        mut bytes: &'b [u8],
    ) -> crate::comm::Result<Option<(usize, &'b [u8])>> {
        let prefix_len = self.g.header_bytes() + 9;
        if self.prefix.len() < prefix_len {
            let take = (prefix_len - self.prefix.len()).min(bytes.len());
            self.prefix.extend_from_slice(&bytes[..take]);
            bytes = &bytes[take..];
            if self.prefix.len() == prefix_len {
                let mut rd = WireReader::new(&self.prefix);
                check_group_header(self.g, &mut rd)?;
                let n = rd.slice_header::<T>()?;
                if n != self.g.total {
                    return Err(CommError::Malformed(format!(
                        "coalesced remap: payload frames {n} elements, plan expects {}",
                        self.g.total
                    )));
                }
            }
            if bytes.is_empty() {
                return Ok(None);
            }
        }
        let off = self.scattered;
        if off + bytes.len() > self.g.total * T::WIDTH {
            return Err(CommError::Malformed(format!(
                "coalesced remap: {} trailing bytes after payload",
                off + bytes.len() - self.g.total * T::WIDTH
            )));
        }
        self.scattered = off + bytes.len();
        Ok(Some((off, bytes)))
    }

    /// Consume one landed chunk and scatter its payload window into
    /// `dst` immediately (the serial compute-on-arrival kernel).
    pub(crate) fn feed(&mut self, bytes: &[u8], dst: &mut [T]) -> crate::comm::Result<()> {
        if let Some((off, win)) = self.feed_raw(bytes)? {
            scatter_payload_bytes::<T>(self.g, off, win, dst);
        }
        Ok(())
    }

    /// Assert the whole message landed (prefix complete, every payload
    /// byte consumed) — call once its stream reports `is_last`.
    pub(crate) fn finish(&self) -> crate::comm::Result<()> {
        let prefix_len = self.g.header_bytes() + 9;
        if self.prefix.len() != prefix_len || self.scattered != self.g.total * T::WIDTH {
            return Err(CommError::Malformed(format!(
                "coalesced remap: incomplete stream from pid {} ({} of {} payload bytes)",
                self.g.peer,
                self.scattered,
                self.g.total * T::WIDTH
            )));
        }
        Ok(())
    }
}

/// Compute-on-arrival receive: every landed chunk of every incoming
/// coalesced stream is scattered **straight into `dst`** by a
/// [`GroupScatter`] — zero reassembly copies on the remap hot path.
/// Streams from different peers interleave in arrival order exactly
/// as under [`recv_groups`]; the wire bytes are identical. Big-endian
/// targets fall back to the reassembling [`recv_groups`] + serial
/// unpack (the wire stays LE either way).
pub(crate) fn recv_groups_into<T: Element>(
    plan: &RemapPlan,
    pid: Pid,
    t: &dyn Transport,
    tag: ChunkTag,
    dst: &mut [T],
) -> crate::comm::Result<()> {
    if !cfg!(target_endian = "little") {
        return recv_groups(plan, pid, t, tag, |g, payload| {
            unpack_group_typed::<T>(g, &payload, dst)
        });
    }
    let groups = plan.peer_recvs(pid);
    for g in groups {
        assert!(
            g.local_extent <= dst.len(),
            "remap plan/slice mismatch: group writes {} destination elements, slice has {}",
            g.local_extent,
            dst.len()
        );
    }
    let peers: Vec<Pid> = groups.iter().map(|g| g.peer).collect();
    let mut scatters: Vec<GroupScatter<'_, T>> = groups.iter().map(GroupScatter::new).collect();
    ChunkStream::drain_chunks(t, &peers, tag, |c| scatters[c.peer_idx].feed(c.payload(), dst))?;
    for s in &scatters {
        s.finish()?;
    }
    Ok(())
}

/// Receive one coalesced stream from every incoming peer of `pid`,
/// completing them in **arrival order** via the shared datapath's
/// multi-peer drain ([`ChunkStream::drain`] — non-blocking sweeps
/// with spin-then-backoff). `unpack(group, payload)` scatters one
/// reassembled message. Kept for consumers that need the contiguous
/// payload (the pipeline's stage hand-off, the bench wire-only
/// passes); the remap hot path takes [`recv_groups_into`].
pub(crate) fn recv_groups(
    plan: &RemapPlan,
    pid: Pid,
    t: &dyn Transport,
    tag: ChunkTag,
    mut unpack: impl FnMut(&PeerGroup, Vec<u8>) -> crate::comm::Result<()>,
) -> crate::comm::Result<()> {
    let groups = plan.peer_recvs(pid);
    let peers: Vec<Pid> = groups.iter().map(|g| g.peer).collect();
    ChunkStream::drain(t, &peers, tag, |i, payload| unpack(&groups[i], payload))
}

/// Offset tables for every PID participating in `map`.
fn offset_tables(p: &Partition, map: &Dmap) -> HashMap<Pid, OffsetTable> {
    map.pids()
        .iter()
        .map(|&pid| {
            let mut table = Vec::new();
            let mut off = 0usize;
            for r in p.ranges_of(pid) {
                table.push((r.lo, r.len(), off));
                off += r.len();
            }
            (pid, table)
        })
        .collect()
}

/// Local offset of flattened global index `g` given an offset table.
fn lookup(table: &OffsetTable, g: usize) -> usize {
    // Tables are sorted by global_lo; binary search the covering range.
    let idx = table.partition_point(|&(lo, len, _)| lo + len <= g);
    match table.get(idx) {
        Some(&(lo, len, off)) if g >= lo && g < lo + len => off + (g - lo),
        _ => panic!("global index {g} not owned (plan/offset table mismatch)"),
    }
}

/// Cache key: the remap is fully determined by the map pair + shape.
/// Maps are `Arc`-backed with precomputed fingerprints, so cloning
/// and hashing the key are O(1) in the map structure.
#[derive(PartialEq, Eq, Hash, Clone)]
struct PlanKey {
    src: Dmap,
    dst: Dmap,
    shape: Vec<usize>,
}

/// A plan cache shared by every remap-shaped operation.
///
/// ```no_run
/// use distarray::darray::{Darray, RemapEngine};
/// use distarray::dmap::Dmap;
/// # let transport: &dyn distarray::comm::Transport = unimplemented!();
/// let engine = RemapEngine::new();
/// let src = Darray::zeros(Dmap::block_1d(4), &[1 << 20], 0);
/// let mut dst = Darray::zeros(Dmap::cyclic_1d(4), &[1 << 20], 0);
/// for epoch in 0..100 {
///     // plans once, moves data 100 times
///     dst.assign_from_engine(&src, transport, epoch, &engine).unwrap();
/// }
/// assert_eq!(engine.plans_built(), 1);
/// ```
#[derive(Default)]
pub struct RemapEngine {
    cache: Mutex<HashMap<PlanKey, Arc<RemapPlan>>>,
    builds: AtomicU64,
}

impl RemapEngine {
    pub fn new() -> RemapEngine {
        RemapEngine::default()
    }

    /// The cached plan for `(src, dst, shape)`, building it on first
    /// use. A hit is a mutex plus a fingerprint-keyed hash lookup
    /// (maps clone as `Arc`s — no deep copy). Holding the cache lock
    /// across the build keeps the build counter exact even under SPMD
    /// thread races.
    pub fn plan(&self, src: &Dmap, dst: &Dmap, shape: &[usize]) -> Arc<RemapPlan> {
        let key = PlanKey { src: src.clone(), dst: dst.clone(), shape: shape.to_vec() };
        let mut cache = self.cache.lock().unwrap();
        if let Some(p) = cache.get(&key) {
            return p.clone();
        }
        self.builds.fetch_add(1, Ordering::Relaxed);
        let t0 = crate::obs::span_begin();
        let plan = Arc::new(RemapPlan::build(src, dst, shape));
        let groups: usize = plan.peer_sends.values().map(Vec::len).sum();
        crate::obs_span!(
            crate::obs::EventKind::RemapPlan,
            t0,
            tag: 0,
            peer: crate::obs::NO_PEER,
            a: shape.iter().product::<usize>() as u64,
            b: groups as u64
        );
        cache.insert(key, plan.clone());
        plan
    }

    /// How many plans have been *built* (cache misses) — the
    /// replanning-amortization instrument.
    pub fn plans_built(&self) -> u64 {
        self.builds.load(Ordering::Relaxed)
    }

    /// How many distinct plans the cache currently holds.
    pub fn cached_plans(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Drop every cached plan (the build counter is preserved).
    pub fn clear(&self) {
        self.cache.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dmap::Dmap;

    #[test]
    fn aligned_plan_is_empty() {
        let p = RemapPlan::build(&Dmap::block_1d(4), &Dmap::block_1d(4), &[64]);
        assert!(p.is_aligned());
        assert!(p.transfers().is_empty());
        assert_eq!(p.message_count(0), 0);
        assert!(p.peer_sends(0).is_empty() && p.peer_recvs(0).is_empty());
    }

    #[test]
    fn block_to_cyclic_plan_covers_and_offsets_agree() {
        let src = Dmap::block_1d(4);
        let dst = Dmap::cyclic_1d(4);
        let p = RemapPlan::build(&src, &dst, &[64]);
        assert!(!p.is_aligned());
        let total: usize = p.transfers().iter().map(|(_, _, r)| r.len()).sum();
        assert_eq!(total, 64);
        // Offsets must match the partitions' own arithmetic.
        let sp = Partition::of(&src, &[64]);
        let dp = Partition::of(&dst, &[64]);
        for &(s, d, r) in p.transfers() {
            for g in r.lo..r.hi {
                assert_eq!(sp.owner_of(g), Some(s));
                assert_eq!(dp.owner_of(g), Some(d));
                // Source is block: offset = g - 16*s. Dest is cyclic:
                // offset = g / 4.
                assert_eq!(p.src_offset(s, g), g - 16 * s);
                assert_eq!(p.dst_offset(d, g), g / 4);
            }
        }
    }

    /// The acceptance-criterion shape: block→cyclic on np=4 — every
    /// PID talks to every other PID, exactly one message per peer.
    #[test]
    fn block_to_cyclic_np4_coalesces_to_one_message_per_peer() {
        let p = RemapPlan::build(&Dmap::block_1d(4), &Dmap::cyclic_1d(4), &[64]);
        for pid in 0..4 {
            let sends = p.peer_sends(pid);
            let recvs = p.peer_recvs(pid);
            assert_eq!(sends.len(), 3, "pid {pid} sends one message per peer");
            assert_eq!(recvs.len(), 3, "pid {pid} receives one message per peer");
            assert_eq!(p.message_count(pid), 6);
            // Ascending deterministic peer order, self excluded.
            let speers: Vec<Pid> = sends.iter().map(|g| g.peer).collect();
            let expect: Vec<Pid> = (0..4).filter(|&q| q != pid).collect();
            assert_eq!(speers, expect);
            // The per-plan-step count this replaces is strictly larger.
            let steps = p
                .transfers()
                .iter()
                .filter(|(s, d, _)| s != d && *s == pid)
                .count();
            assert!(steps > sends.len(), "coalescing must merge steps ({steps} > 3)");
        }
    }

    #[test]
    fn peer_group_offsets_are_consistent() {
        let p = RemapPlan::build(&Dmap::block_1d(3), &Dmap::block_cyclic_1d(3, 4), &[60]);
        for pid in 0..3 {
            for g in p.peer_sends(pid) {
                assert_eq!(g.ranges.len(), g.local_offsets.len());
                assert_eq!(g.ranges.len(), g.payload_offsets.len());
                let mut total = 0usize;
                let mut extent = 0usize;
                for (i, r) in g.ranges.iter().enumerate() {
                    assert_eq!(g.payload_offsets[i], total, "prefix sums");
                    assert_eq!(g.local_offsets[i], p.src_offset(pid, r.lo));
                    total += r.len();
                    extent = extent.max(g.local_offsets[i] + r.len());
                }
                assert_eq!(g.total, total);
                assert_eq!(g.local_extent, extent, "bounds witness");
                // The seg iterator mirrors (local_offset, len).
                let segs: Vec<(usize, usize)> = g.segs().collect();
                assert_eq!(segs.len(), g.ranges.len());
                assert_eq!(segs[0], (g.local_offsets[0], g.ranges[0].len()));
            }
            for g in p.peer_recvs(pid) {
                for (i, r) in g.ranges.iter().enumerate() {
                    assert_eq!(g.local_offsets[i], p.dst_offset(pid, r.lo));
                }
            }
        }
    }

    #[test]
    fn message_count_counts_peers_not_steps() {
        let p = RemapPlan::build(&Dmap::block_1d(2), &Dmap::cyclic_1d(2), &[8]);
        let msgs: usize = (0..2).map(|pid| p.message_count(pid)).sum();
        // Distinct crossing (src, dst) pairs, counted at both ends.
        let pairs: std::collections::HashSet<(Pid, Pid)> = p
            .transfers()
            .iter()
            .filter(|(s, d, _)| s != d)
            .map(|&(s, d, _)| (s, d))
            .collect();
        assert_eq!(msgs, 2 * pairs.len());
        assert!(!pairs.is_empty());
    }

    #[test]
    fn engine_builds_each_key_once() {
        let eng = RemapEngine::new();
        let a = Dmap::block_1d(4);
        let b = Dmap::cyclic_1d(4);
        let p1 = eng.plan(&a, &b, &[100]);
        let p2 = eng.plan(&a, &b, &[100]);
        assert!(Arc::ptr_eq(&p1, &p2), "second lookup must hit the cache");
        assert_eq!(eng.plans_built(), 1);
        // Any component changing is a new key.
        eng.plan(&b, &a, &[100]);
        eng.plan(&a, &b, &[101]);
        assert_eq!(eng.plans_built(), 3);
        assert_eq!(eng.cached_plans(), 3);
        eng.clear();
        assert_eq!(eng.cached_plans(), 0);
        assert_eq!(eng.plans_built(), 3, "clear keeps the instrument");
    }

    /// Cache hits must work across separately *constructed* (not just
    /// cloned) maps — the fingerprint keys structural equality.
    #[test]
    fn engine_hits_across_reconstructed_maps() {
        let eng = RemapEngine::new();
        eng.plan(&Dmap::block_1d(4), &Dmap::cyclic_1d(4), &[64]);
        let p = eng.plan(&Dmap::block_1d(4), &Dmap::cyclic_1d(4), &[64]);
        assert_eq!(eng.plans_built(), 1, "reconstructed equal maps must hit");
        assert!(!p.is_aligned());
    }

    /// Feeding a coalesced message to a [`GroupScatter`] in arbitrary
    /// byte windows — including ones that split the prefix and split
    /// single elements — must land bit-identically to the serial
    /// reassemble-then-unpack path.
    #[test]
    #[cfg(target_endian = "little")]
    fn group_scatter_matches_serial_unpack_at_any_window_size() {
        let p = RemapPlan::build(&Dmap::block_1d(3), &Dmap::cyclic_1d(3), &[60]);
        let g = &p.peer_recvs(0)[0];
        // Synthesize the wire message: range table + typed payload in
        // plan order (what the sender's gather would produce).
        let gathered: Vec<f64> = g
            .ranges
            .iter()
            .flat_map(|r| (r.lo..r.hi).map(|i| i as f64 * 0.5 - 7.0))
            .collect();
        assert_eq!(gathered.len(), g.total);
        let mut w = WireWriter::new();
        write_group_header(&mut w, g);
        w.put_slice::<f64>(&gathered);
        let msg = w.finish();

        let mut expect = vec![0.0f64; 60];
        unpack_group_typed::<f64>(g, &msg, &mut expect).unwrap();

        for window in [1usize, 13, 64, msg.len()] {
            let mut got = vec![0.0f64; 60];
            let mut s = GroupScatter::<f64>::new(g);
            for win in msg.chunks(window) {
                s.feed(win, &mut got).unwrap();
            }
            s.finish().unwrap();
            assert_eq!(got, expect, "window {window}");
        }

        // Trailing bytes past the framed payload are a loud error.
        let mut s = GroupScatter::<f64>::new(g);
        s.feed(&msg, &mut vec![0.0f64; 60]).unwrap();
        assert!(matches!(s.feed(&[0u8], &mut vec![0.0f64; 60]), Err(CommError::Malformed(_))));

        // A short stream is caught by `finish`, not silently accepted.
        let mut s = GroupScatter::<f64>::new(g);
        s.feed(&msg[..msg.len() - 3], &mut vec![0.0f64; 60]).unwrap();
        assert!(matches!(s.finish(), Err(CommError::Malformed(_))));
    }

    #[test]
    fn shared_engine_under_threads_builds_once() {
        let eng = Arc::new(RemapEngine::new());
        let hs: Vec<_> = (0..8)
            .map(|_| {
                let eng = eng.clone();
                std::thread::spawn(move || {
                    eng.plan(&Dmap::block_1d(3), &Dmap::cyclic_1d(3), &[999]);
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(eng.plans_built(), 1, "racing threads must not double-build");
    }
}
