//! [`RemapEngine`] — reusable remap planning for the whole
//! darray/comm/stream stack.
//!
//! A remap (`A(:) = B` across different maps, a pipeline stage hand-
//! off, a halo-style redistribution) is two separable concerns:
//!
//! 1. **Planning** — intersect the source and destination
//!    [`Partition`]s into a transfer list and precompute, per PID, the
//!    global-range → local-offset tables for both layouts. Pure index
//!    arithmetic, identical on every PID, O(ranges) work.
//! 2. **Execution** — move bytes per the plan over a
//!    [`Transport`](crate::comm::Transport). O(data) work.
//!
//! The seed implementation fused the two inside `assign_from`, so an
//! iterated pipeline re-planned on every iteration. [`RemapPlan`]
//! materializes concern 1 as a value; [`RemapEngine`] caches plans
//! keyed by `(src_map, dst_map, shape)` so a repeated remap plans
//! **exactly once** (observable via [`RemapEngine::plans_built`] — the
//! tests assert it rather than assume it). Plans are returned as
//! `Arc`s: SPMD threads of one process can share one engine. The
//! cache lock is never held during data movement; it IS held across
//! the build of a missing plan, which keeps the build counter exact
//! under thread races at the cost of serializing first-touch
//! planning. A cache hit still pays the mutex plus a key clone —
//! loops that care should hoist the `Arc` once
//! ([`RemapEngine::plan`]) and execute through
//! `DarrayT::assign_from_plan`.

use crate::comm::{tags, Transport, WireReader, WireWriter};
use crate::dmap::{Dmap, GlobalRange, Partition, Pid};
use crate::element::Element;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Per-PID offset table: `(global_lo, len, local_offset)` per owned
/// contiguous range, in ascending global order.
pub type OffsetTable = Vec<(usize, usize, usize)>;

/// A fully precomputed remap: the transfer list plus both sides'
/// offset tables. Everything `assign_from` needs except the data.
#[derive(Debug)]
pub struct RemapPlan {
    /// Source and destination assign identical ownership — execution
    /// degenerates to a local copy with zero messages.
    aligned: bool,
    /// `(src_pid, dst_pid, global_range)` transfers, in deterministic
    /// plan order (empty when `aligned`). Entries with
    /// `src_pid == dst_pid` are local copies, not messages.
    transfers: Vec<(Pid, Pid, GlobalRange)>,
    src_offsets: HashMap<Pid, OffsetTable>,
    dst_offsets: HashMap<Pid, OffsetTable>,
}

impl RemapPlan {
    /// Plan the remap of an array of `shape` from `src` to `dst`.
    pub fn build(src: &Dmap, dst: &Dmap, shape: &[usize]) -> RemapPlan {
        let src_part = Partition::of(src, shape);
        let dst_part = Partition::of(dst, shape);
        if src_part.same_ownership(&dst_part) {
            return RemapPlan {
                aligned: true,
                transfers: Vec::new(),
                src_offsets: HashMap::new(),
                dst_offsets: HashMap::new(),
            };
        }
        let transfers = src_part.transfers_to(&dst_part);
        RemapPlan {
            aligned: false,
            transfers,
            src_offsets: offset_tables(&src_part, src),
            dst_offsets: offset_tables(&dst_part, dst),
        }
    }

    /// Source and destination own identical index sets?
    pub fn is_aligned(&self) -> bool {
        self.aligned
    }

    /// The transfer list (empty for aligned plans).
    pub fn transfers(&self) -> &[(Pid, Pid, GlobalRange)] {
        &self.transfers
    }

    /// Messages `pid` will actually send/receive under this plan
    /// (excludes local copies) — the "bounded communication" number.
    pub fn message_count(&self, pid: Pid) -> usize {
        self.transfers
            .iter()
            .filter(|(s, d, _)| s != d && (*s == pid || *d == pid))
            .count()
    }

    /// Local offset of global index `g` in `pid`'s **source** layout.
    pub fn src_offset(&self, pid: Pid, g: usize) -> usize {
        lookup(&self.src_offsets[&pid], g)
    }

    /// Local offset of global index `g` in `pid`'s **destination**
    /// layout.
    pub fn dst_offset(&self, pid: Pid, g: usize) -> usize {
        lookup(&self.dst_offsets[&pid], g)
    }

    /// Execute this plan's transfer list on an execution backend: the
    /// typed local parts are erased into the backend currency and the
    /// data movement is delegated to
    /// [`Backend::execute_plan`](crate::backend::Backend::execute_plan).
    /// The plan MUST have been built for `(src map, dst map, shape)`
    /// of the arrays these slices belong to.
    pub fn execute_on<T: Element>(
        &self,
        backend: &dyn crate::backend::Backend,
        src: &[T],
        dst: &mut [T],
        pid: Pid,
        t: &dyn Transport,
        epoch: u64,
    ) -> crate::backend::Result<()> {
        backend.execute_plan(self, T::erase(src), T::erase_mut(dst), pid, t, epoch)
    }
}

/// Execute a prebuilt remap plan for one PID's typed local parts:
/// aligned plans degenerate to a memcpy; otherwise local pieces copy
/// and remote pieces travel as one typed message per plan step, tagged
/// by step index so ordering is deterministic on both sides.
///
/// This is the single data-movement routine behind both
/// `DarrayT::assign_from*` and every host-class
/// [`Backend::execute_plan`](crate::backend::Backend::execute_plan)
/// implementation — one definition, bit-identical outcomes.
pub fn execute_plan_typed<T: Element>(
    plan: &RemapPlan,
    src: &[T],
    dst: &mut [T],
    pid: Pid,
    t: &dyn Transport,
    epoch: u64,
) -> crate::comm::Result<()> {
    // Fast path: aligned maps → pure local copy, zero messages.
    if plan.is_aligned() {
        dst.copy_from_slice(src);
        return Ok(());
    }

    // Phase 1: satisfy local pieces + send outgoing pieces.
    for (step, &(sp, dp, r)) in plan.transfers().iter().enumerate() {
        if sp != pid {
            continue;
        }
        let s_off = plan.src_offset(pid, r.lo);
        let src_slice = &src[s_off..s_off + r.len()];
        if dp == pid {
            let d_off = plan.dst_offset(pid, r.lo);
            dst[d_off..d_off + r.len()].copy_from_slice(src_slice);
        } else {
            let mut w = WireWriter::with_capacity(24 + T::WIDTH * r.len());
            w.put_u64(step as u64);
            w.put_slice::<T>(src_slice);
            t.send(dp, tags::pack(tags::NS_REMAP, epoch, step as u64), &w.finish())?;
        }
    }
    // Phase 2: receive incoming pieces.
    for (step, &(sp, dp, r)) in plan.transfers().iter().enumerate() {
        if dp != pid || sp == pid {
            continue;
        }
        let payload = t.recv(sp, tags::pack(tags::NS_REMAP, epoch, step as u64))?;
        let mut rd = WireReader::new(&payload);
        let got_step = rd.get_u64()?;
        debug_assert_eq!(got_step as usize, step);
        let d_off = plan.dst_offset(pid, r.lo);
        let dst_slice = &mut dst[d_off..d_off + r.len()];
        rd.get_slice_into::<T>(dst_slice)?;
    }
    Ok(())
}

/// Offset tables for every PID participating in `map`.
fn offset_tables(p: &Partition, map: &Dmap) -> HashMap<Pid, OffsetTable> {
    map.pids()
        .iter()
        .map(|&pid| {
            let mut table = Vec::new();
            let mut off = 0usize;
            for r in p.ranges_of(pid) {
                table.push((r.lo, r.len(), off));
                off += r.len();
            }
            (pid, table)
        })
        .collect()
}

/// Local offset of flattened global index `g` given an offset table.
fn lookup(table: &OffsetTable, g: usize) -> usize {
    // Tables are sorted by global_lo; binary search the covering range.
    let idx = table.partition_point(|&(lo, len, _)| lo + len <= g);
    match table.get(idx) {
        Some(&(lo, len, off)) if g >= lo && g < lo + len => off + (g - lo),
        _ => panic!("global index {g} not owned (plan/offset table mismatch)"),
    }
}

/// Cache key: the remap is fully determined by the map pair + shape.
#[derive(PartialEq, Eq, Hash, Clone)]
struct PlanKey {
    src: Dmap,
    dst: Dmap,
    shape: Vec<usize>,
}

/// A plan cache shared by every remap-shaped operation.
///
/// ```no_run
/// use distarray::darray::{Darray, RemapEngine};
/// use distarray::dmap::Dmap;
/// # let transport: &dyn distarray::comm::Transport = unimplemented!();
/// let engine = RemapEngine::new();
/// let src = Darray::zeros(Dmap::block_1d(4), &[1 << 20], 0);
/// let mut dst = Darray::zeros(Dmap::cyclic_1d(4), &[1 << 20], 0);
/// for epoch in 0..100 {
///     // plans once, moves data 100 times
///     dst.assign_from_engine(&src, transport, epoch, &engine).unwrap();
/// }
/// assert_eq!(engine.plans_built(), 1);
/// ```
#[derive(Default)]
pub struct RemapEngine {
    cache: Mutex<HashMap<PlanKey, Arc<RemapPlan>>>,
    builds: AtomicU64,
}

impl RemapEngine {
    pub fn new() -> RemapEngine {
        RemapEngine::default()
    }

    /// The cached plan for `(src, dst, shape)`, building it on first
    /// use. Holding the cache lock across the build keeps the build
    /// counter exact even under SPMD thread races.
    pub fn plan(&self, src: &Dmap, dst: &Dmap, shape: &[usize]) -> Arc<RemapPlan> {
        let key = PlanKey { src: src.clone(), dst: dst.clone(), shape: shape.to_vec() };
        let mut cache = self.cache.lock().unwrap();
        if let Some(p) = cache.get(&key) {
            return p.clone();
        }
        self.builds.fetch_add(1, Ordering::Relaxed);
        let plan = Arc::new(RemapPlan::build(src, dst, shape));
        cache.insert(key, plan.clone());
        plan
    }

    /// How many plans have been *built* (cache misses) — the
    /// replanning-amortization instrument.
    pub fn plans_built(&self) -> u64 {
        self.builds.load(Ordering::Relaxed)
    }

    /// How many distinct plans the cache currently holds.
    pub fn cached_plans(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Drop every cached plan (the build counter is preserved).
    pub fn clear(&self) {
        self.cache.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dmap::Dmap;

    #[test]
    fn aligned_plan_is_empty() {
        let p = RemapPlan::build(&Dmap::block_1d(4), &Dmap::block_1d(4), &[64]);
        assert!(p.is_aligned());
        assert!(p.transfers().is_empty());
        assert_eq!(p.message_count(0), 0);
    }

    #[test]
    fn block_to_cyclic_plan_covers_and_offsets_agree() {
        let src = Dmap::block_1d(4);
        let dst = Dmap::cyclic_1d(4);
        let p = RemapPlan::build(&src, &dst, &[64]);
        assert!(!p.is_aligned());
        let total: usize = p.transfers().iter().map(|(_, _, r)| r.len()).sum();
        assert_eq!(total, 64);
        // Offsets must match the partitions' own arithmetic.
        let sp = Partition::of(&src, &[64]);
        let dp = Partition::of(&dst, &[64]);
        for &(s, d, r) in p.transfers() {
            for g in r.lo..r.hi {
                assert_eq!(sp.owner_of(g), Some(s));
                assert_eq!(dp.owner_of(g), Some(d));
                // Source is block: offset = g - 16*s. Dest is cyclic:
                // offset = g / 4.
                assert_eq!(p.src_offset(s, g), g - 16 * s);
                assert_eq!(p.dst_offset(d, g), g / 4);
            }
        }
    }

    #[test]
    fn message_count_excludes_local_copies() {
        let p = RemapPlan::build(&Dmap::block_1d(2), &Dmap::cyclic_1d(2), &[8]);
        let msgs: usize = (0..2).map(|pid| p.message_count(pid)).sum();
        let crossings = p.transfers().iter().filter(|(s, d, _)| s != d).count();
        // Each crossing counts once at the sender and once at the receiver.
        assert_eq!(msgs, 2 * crossings);
        assert!(crossings > 0);
    }

    #[test]
    fn engine_builds_each_key_once() {
        let eng = RemapEngine::new();
        let a = Dmap::block_1d(4);
        let b = Dmap::cyclic_1d(4);
        let p1 = eng.plan(&a, &b, &[100]);
        let p2 = eng.plan(&a, &b, &[100]);
        assert!(Arc::ptr_eq(&p1, &p2), "second lookup must hit the cache");
        assert_eq!(eng.plans_built(), 1);
        // Any component changing is a new key.
        eng.plan(&b, &a, &[100]);
        eng.plan(&a, &b, &[101]);
        assert_eq!(eng.plans_built(), 3);
        assert_eq!(eng.cached_plans(), 3);
        eng.clear();
        assert_eq!(eng.cached_plans(), 0);
        assert_eq!(eng.plans_built(), 3, "clear keeps the instrument");
    }

    #[test]
    fn shared_engine_under_threads_builds_once() {
        let eng = Arc::new(RemapEngine::new());
        let hs: Vec<_> = (0..8)
            .map(|_| {
                let eng = eng.clone();
                std::thread::spawn(move || {
                    eng.plan(&Dmap::block_1d(3), &Dmap::cyclic_1d(3), &[999]);
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(eng.plans_built(), 1, "racing threads must not double-build");
    }
}
