//! The [`DarrayT`] container: map + global shape + local storage.

use super::{DarrayError, Result};
use crate::dmap::{Dmap, Pid};
use crate::element::{Dtype, Element};

/// One PID's view of a distributed dense array of `T`.
///
/// The map algebra is dtype-independent (the paper's model never
/// inspects values); `T` controls only bytes-per-element, arithmetic,
/// and the wire encoding. [`Darray`] aliases the classic `f64`
/// instantiation so existing call sites read unchanged.
///
/// Storage covers the *stored* region (owned + halo); for 1-D block
/// maps the halo is a suffix, so `loc()` is always a prefix slice.
#[derive(Debug, Clone)]
pub struct DarrayT<T: Element> {
    map: Dmap,
    shape: Vec<usize>,
    pid: Pid,
    /// Row-major over `map.stored_shape(pid, shape)`.
    data: Vec<T>,
    /// Cached: number of *owned* elements (prefix of `data` for 1-D).
    owned: usize,
}

/// The classic f64 distributed array (the paper's STREAM dtype).
pub type Darray = DarrayT<f64>;

impl<T: Element> DarrayT<T> {
    /// Allocate the local part of a zero-filled distributed array.
    pub fn zeros(map: Dmap, shape: &[usize], pid: Pid) -> Self {
        assert_eq!(map.ndim(), shape.len(), "map/shape rank mismatch");
        assert!(map.contains(pid), "PID {pid} not in map");
        let stored: usize = map.stored_shape(pid, shape).iter().product();
        let owned: usize = map.local_shape(pid, shape).iter().product();
        DarrayT {
            map,
            shape: shape.to_vec(),
            pid,
            data: vec![T::ZERO; stored],
            owned,
        }
    }

    /// Allocate with every owned element set to `v` (the Code Listing
    /// idiom `local(zeros(1,N,map)) + A0`).
    pub fn constant(map: Dmap, shape: &[usize], pid: Pid, v: T) -> Self {
        let mut a = Self::zeros(map, shape, pid);
        a.fill(v);
        a
    }

    /// Initialize each owned element from its **global** flat index —
    /// deterministic across any map (test workhorse).
    pub fn from_global_fn(map: Dmap, shape: &[usize], pid: Pid, f: impl Fn(usize) -> T) -> Self {
        let mut a = Self::zeros(map, shape, pid);
        let part = crate::dmap::Partition::of(&a.map, &a.shape);
        let mut off = 0usize;
        for r in part.ranges_of(pid) {
            for g in r.lo..r.hi {
                a.data[off] = f(g);
                off += 1;
            }
        }
        debug_assert_eq!(off, a.owned);
        a
    }

    pub fn map(&self) -> &Dmap {
        &self.map
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn pid(&self) -> Pid {
        self.pid
    }

    /// Runtime dtype of the stored elements.
    pub fn dtype(&self) -> Dtype {
        T::DTYPE
    }

    /// Global element count.
    pub fn global_len(&self) -> usize {
        self.shape.iter().product()
    }

    /// Owned element count on this PID.
    pub fn local_len(&self) -> usize {
        self.owned
    }

    /// Owned bytes on this PID (the quantity bandwidth formulas use).
    pub fn local_bytes(&self) -> usize {
        self.owned * T::WIDTH
    }

    /// The paper's `.loc`: immutable view of the owned region.
    #[inline]
    pub fn loc(&self) -> &[T] {
        &self.data[..self.owned]
    }

    /// The paper's `.loc` (mutable).
    #[inline]
    pub fn loc_mut(&mut self) -> &mut [T] {
        &mut self.data[..self.owned]
    }

    /// Stored region (owned + halo).
    pub fn stored(&self) -> &[T] {
        &self.data
    }

    pub fn stored_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Set every owned element.
    pub fn fill(&mut self, v: T) {
        for x in self.loc_mut() {
            *x = v;
        }
    }

    /// Are `self` and `other` compatible for owner-computes ops?
    pub fn check_aligned(&self, other: &DarrayT<T>) -> Result<()> {
        if self.shape != other.shape {
            return Err(DarrayError::ShapeMismatch {
                a: self.shape.clone(),
                b: other.shape.clone(),
            });
        }
        if self.pid != other.pid {
            return Err(DarrayError::PidMismatch { a: self.pid, b: other.pid });
        }
        if !self.map.aligned_with(&other.map, &self.shape) {
            return Err(DarrayError::NotAligned { shape: self.shape.clone() });
        }
        Ok(())
    }

    /// Read the value at a global flat index **if** this PID owns it.
    pub fn global_get(&self, gflat: usize) -> Option<T> {
        let part = crate::dmap::Partition::of(&self.map, &self.shape);
        if part.owner_of(gflat)? != self.pid {
            return None;
        }
        let mut off = 0usize;
        for r in part.ranges_of(self.pid) {
            if gflat >= r.lo && gflat < r.hi {
                return Some(self.data[off + (gflat - r.lo)]);
            }
            off += r.len();
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dmap::Dmap;

    #[test]
    fn zeros_allocates_local_only() {
        let a = Darray::zeros(Dmap::block_1d(4), &[100], 1);
        assert_eq!(a.local_len(), 25);
        assert_eq!(a.global_len(), 100);
        assert!(a.loc().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn uneven_block_sizes() {
        // 10 over 4 → block quantum 3: 3,3,3,1.
        let sizes: Vec<usize> = (0..4)
            .map(|p| Darray::zeros(Dmap::block_1d(4), &[10], p).local_len())
            .collect();
        assert_eq!(sizes, vec![3, 3, 3, 1]);
    }

    #[test]
    fn constant_fills_owned() {
        let a = Darray::constant(Dmap::block_1d(2), &[8], 0, 2.5);
        assert!(a.loc().iter().all(|&x| x == 2.5));
    }

    #[test]
    fn from_global_fn_block_and_cyclic_agree_globally() {
        for map in [Dmap::block_1d(3), Dmap::cyclic_1d(3)] {
            for pid in 0..3 {
                let a = Darray::from_global_fn(map.clone(), &[11], pid, |g| g as f64);
                for g in 0..11 {
                    if let Some(v) = a.global_get(g) {
                        assert_eq!(v, g as f64, "{map:?} pid={pid} g={g}");
                    }
                }
            }
        }
    }

    #[test]
    fn global_get_respects_ownership() {
        let a = Darray::from_global_fn(Dmap::block_1d(4), &[16], 2, |g| g as f64);
        assert_eq!(a.global_get(8), Some(8.0)); // pid 2 owns [8,12)
        assert_eq!(a.global_get(0), None);
        assert_eq!(a.global_get(100), None);
    }

    #[test]
    fn halo_storage_is_suffix() {
        let a = Darray::zeros(Dmap::block_1d_overlap(2, 2), &[10], 0);
        assert_eq!(a.local_len(), 5);
        assert_eq!(a.stored().len(), 7);
    }

    #[test]
    fn check_aligned_catches_mismatch() {
        let a = Darray::zeros(Dmap::block_1d(4), &[64], 0);
        let b = Darray::zeros(Dmap::cyclic_1d(4), &[64], 0);
        assert!(matches!(
            a.check_aligned(&b),
            Err(DarrayError::NotAligned { .. })
        ));
        let c = Darray::zeros(Dmap::block_1d(4), &[64], 0);
        assert!(a.check_aligned(&c).is_ok());
    }

    #[test]
    fn typed_instantiations_share_the_map_algebra() {
        let f = DarrayT::<f32>::from_global_fn(Dmap::cyclic_1d(3), &[10], 1, |g| g as f32);
        let i = DarrayT::<i64>::from_global_fn(Dmap::cyclic_1d(3), &[10], 1, |g| g as i64);
        let u = DarrayT::<u64>::from_global_fn(Dmap::cyclic_1d(3), &[10], 1, |g| g as u64);
        assert_eq!(f.local_len(), i.local_len());
        assert_eq!(f.local_bytes(), 3 * 4);
        assert_eq!(i.local_bytes(), 3 * 8);
        assert_eq!(u.global_get(4), Some(4u64));
        assert_eq!(f.dtype(), crate::element::Dtype::F32);
    }
}
