//! Global indexing — pMatlab's `subsref`/`subsasgn`: read or write an
//! arbitrary global range of a distributed array from any PID,
//! regardless of which PIDs own the elements.
//!
//! These are the *convenience* global operations the paper's §IV
//! contrasts with `.loc`: correct for any map, but every call may
//! communicate — the cost the `.loc` discipline avoids on the hot
//! path.

use super::dense::DarrayT;
use super::Result;
use crate::comm::{tags, Transport, WireReader, WireWriter};
use crate::dmap::Partition;
use crate::element::Element;

impl<T: Element> DarrayT<T> {
    /// Collective read of the global range `[lo, hi)` (flattened
    /// row-major): every PID returns the same dense vector.
    ///
    /// Protocol: each owner sends its overlap with the range to PID 0;
    /// PID 0 assembles and broadcasts. SPMD — all PIDs must call.
    pub fn gather_range(
        &self,
        lo: usize,
        hi: usize,
        t: &dyn Transport,
        epoch: u64,
    ) -> Result<Vec<T>> {
        assert!(lo <= hi && hi <= self.global_len(), "range out of bounds");
        let tag = tags::pack(tags::NS_GATHER, epoch, 0);
        let me = self.pid();
        let part = Partition::of(self.map(), &self.shape().to_vec());

        // Every PID extracts its overlap with [lo, hi).
        let mut mine: Vec<(usize, Vec<T>)> = Vec::new();
        let mut off = 0usize;
        for r in part.ranges_of(me) {
            let s = r.lo.max(lo);
            let e = r.hi.min(hi);
            if s < e {
                let local_s = off + (s - r.lo);
                mine.push((s, self.loc()[local_s..local_s + (e - s)].to_vec()));
            }
            off += r.len();
        }

        if me == 0 {
            let mut out = vec![T::ZERO; hi - lo];
            for (s, chunk) in &mine {
                out[s - lo..s - lo + chunk.len()].copy_from_slice(chunk);
            }
            for &pid in self.map().pids() {
                if pid == 0 {
                    continue;
                }
                let payload = t.recv(pid, tag)?;
                let mut rd = WireReader::new(&payload);
                let npieces = rd.get_usize()?;
                for _ in 0..npieces {
                    let s = rd.get_usize()?;
                    let chunk = rd.get_vec::<T>()?;
                    out[s - lo..s - lo + chunk.len()].copy_from_slice(&chunk);
                }
            }
            // Broadcast the assembled range.
            let mut w = WireWriter::with_capacity(24 + T::WIDTH * out.len());
            w.put_slice::<T>(&out);
            let bytes = w.finish();
            for &pid in self.map().pids() {
                if pid != 0 {
                    t.send(pid, tag, &bytes)?;
                }
            }
            Ok(out)
        } else {
            let mut w = WireWriter::new();
            w.put_usize(mine.len());
            for (s, chunk) in &mine {
                w.put_usize(*s);
                w.put_slice::<T>(chunk);
            }
            t.send(0, tag, &w.finish())?;
            let payload = t.recv(0, tag)?;
            Ok(WireReader::new(&payload).get_vec::<T>()?)
        }
    }

    /// Local write of a global range: each PID stores the pieces of
    /// `values` (covering `[lo, hi)`) that it owns. No communication —
    /// every PID is handed the full value vector (pMatlab's
    /// `subsasgn` with a replicated right-hand side).
    pub fn scatter_range(&mut self, lo: usize, values: &[T]) -> Result<()> {
        let hi = lo + values.len();
        assert!(hi <= self.global_len(), "range out of bounds");
        let me = self.pid();
        let part = Partition::of(self.map(), &self.shape().to_vec());
        let mut off = 0usize;
        for r in part.ranges_of(me) {
            let s = r.lo.max(lo);
            let e = r.hi.min(hi);
            if s < e {
                let local_s = off + (s - r.lo);
                self.loc_mut()[local_s..local_s + (e - s)]
                    .copy_from_slice(&values[s - lo..e - lo]);
            }
            off += r.len();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ChannelHub;
    use crate::darray::dense::Darray;
    use crate::dmap::Dmap;
    use std::thread;

    fn spmd<R: Send + 'static>(
        np: usize,
        f: impl Fn(usize, &dyn Transport) -> R + Send + Sync + 'static,
    ) -> Vec<R> {
        let world = ChannelHub::world(np);
        let f = std::sync::Arc::new(f);
        world
            .into_iter()
            .map(|t| {
                let f = f.clone();
                thread::spawn(move || f(t.pid(), &t))
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    }

    #[test]
    fn gather_range_spans_owners() {
        for mk in [Dmap::block_1d as fn(usize) -> Dmap, Dmap::cyclic_1d] {
            let out = spmd(4, move |pid, t| {
                let a = Darray::from_global_fn(mk(4), &[100], pid, |g| g as f64);
                a.gather_range(20, 70, t, 0).unwrap()
            });
            for v in out {
                assert_eq!(v.len(), 50);
                for (i, x) in v.iter().enumerate() {
                    assert_eq!(*x, (20 + i) as f64);
                }
            }
        }
    }

    #[test]
    fn gather_empty_and_full_ranges() {
        let out = spmd(3, |pid, t| {
            let a = Darray::from_global_fn(Dmap::block_1d(3), &[30], pid, |g| g as f64);
            let empty = a.gather_range(5, 5, t, 1).unwrap();
            let full = a.gather_range(0, 30, t, 2).unwrap();
            (empty.len(), full)
        });
        for (e, f) in out {
            assert_eq!(e, 0);
            assert_eq!(f, (0..30).map(|g| g as f64).collect::<Vec<_>>());
        }
    }

    #[test]
    fn scatter_then_gather_roundtrip() {
        let out = spmd(4, |pid, t| {
            let mut a = Darray::zeros(Dmap::block_cyclic_1d(4, 3), &[64], pid);
            let vals: Vec<f64> = (0..40).map(|i| (i * i) as f64).collect();
            a.scatter_range(10, &vals).unwrap();
            a.gather_range(10, 50, t, 3).unwrap()
        });
        for v in out {
            for (i, x) in v.iter().enumerate() {
                assert_eq!(*x, (i * i) as f64);
            }
        }
    }

    #[test]
    fn typed_gather_range_i64() {
        let out = spmd(3, |pid, t| {
            let a = DarrayT::<i64>::from_global_fn(Dmap::cyclic_1d(3), &[30], pid, |g| g as i64);
            a.gather_range(7, 23, t, 4).unwrap()
        });
        for v in out {
            assert_eq!(v, (7i64..23).collect::<Vec<_>>());
        }
    }
}
