//! Pipeline maps — §II: "Another example are pipelines which can be
//! implemented by mapping different arrays to different sets of PIDs."
//!
//! A stage map assigns an array to a *subset* of the world's PIDs;
//! PIDs outside the stage hold an empty local part. Moving data
//! between stages is exactly a remap between the two subsets'
//! partitions, so [`StageArrayT::send_to`] executes a shared
//! [`RemapPlan`] — and the iterated form
//! [`StageArrayT::send_to_engine`] reuses a [`RemapEngine`]'s cache so
//! a steady-state pipeline replans nothing.

use super::dense::DarrayT;
use super::engine::{RemapEngine, RemapPlan};
use super::Result;
use crate::comm::{tags, Transport, WireReader, WireWriter};
use crate::dmap::{Dist, Dmap, Grid, Overlap, Pid};
use crate::element::Element;

/// Build a 1-D block map over an explicit PID subset (a pipeline
/// stage). The world may contain many more PIDs.
pub fn stage_map(pids: &[Pid]) -> Dmap {
    assert!(!pids.is_empty());
    Dmap::new(
        Grid::line(pids.len()),
        vec![Dist::Block],
        vec![Overlap::none()],
        pids.to_vec(),
    )
}

/// One PID's view of a pipeline stage's array: participants hold
/// their local block, non-participants hold nothing.
pub struct StageArrayT<T: Element> {
    /// `Some` iff this PID participates in the stage.
    pub local: Option<DarrayT<T>>,
    map: Dmap,
    shape: Vec<usize>,
    me: Pid,
}

/// The classic f64 stage array.
pub type StageArray = StageArrayT<f64>;

impl<T: Element> StageArrayT<T> {
    /// Allocate the stage array on this PID (empty if not a member).
    pub fn zeros(map: Dmap, shape: &[usize], me: Pid) -> StageArrayT<T> {
        let local = map
            .contains(me)
            .then(|| DarrayT::<T>::zeros(map.clone(), shape, me));
        StageArrayT { local, map, shape: shape.to_vec(), me }
    }

    pub fn map(&self) -> &Dmap {
        &self.map
    }

    pub fn participates(&self) -> bool {
        self.local.is_some()
    }

    /// Transfer this stage's content into `dst` (the next stage),
    /// across possibly disjoint PID subsets, planning from scratch.
    /// SPMD over the **union** of both stages' PIDs (plus any others —
    /// non-members no-op).
    pub fn send_to(&self, dst: &mut StageArrayT<T>, t: &dyn Transport, epoch: u64) -> Result<()> {
        assert_eq!(self.shape, dst.shape, "stage shapes must match");
        let plan = RemapPlan::build(&self.map, &dst.map, &self.shape);
        self.execute_stage_plan(&plan, dst, t, epoch)
    }

    /// [`StageArrayT::send_to`] through a plan cache — the steady-state
    /// pipeline path (plans once per `(src_map, dst_map, shape)`).
    pub fn send_to_engine(
        &self,
        dst: &mut StageArrayT<T>,
        t: &dyn Transport,
        epoch: u64,
        engine: &RemapEngine,
    ) -> Result<()> {
        assert_eq!(self.shape, dst.shape, "stage shapes must match");
        let plan = engine.plan(&self.map, &dst.map, &self.shape);
        self.execute_stage_plan(&plan, dst, t, epoch)
    }

    fn execute_stage_plan(
        &self,
        plan: &RemapPlan,
        dst: &mut StageArrayT<T>,
        t: &dyn Transport,
        epoch: u64,
    ) -> Result<()> {
        // Identical PID subsets and distributions: pure local copy.
        if plan.is_aligned() {
            if let (Some(src), Some(d)) = (&self.local, &mut dst.local) {
                d.loc_mut().copy_from_slice(src.loc());
            }
            return Ok(());
        }
        // Phase 1: source members push their pieces.
        if let Some(src) = &self.local {
            for (step, &(sp, dp, r)) in plan.transfers().iter().enumerate() {
                if sp != self.me {
                    continue;
                }
                let s_off = plan.src_offset(self.me, r.lo);
                let slice = &src.loc()[s_off..s_off + r.len()];
                if dp == self.me {
                    if let Some(d) = &mut dst.local {
                        let d_off = plan.dst_offset(self.me, r.lo);
                        d.loc_mut()[d_off..d_off + r.len()].copy_from_slice(slice);
                    }
                } else {
                    let mut w = WireWriter::with_capacity(24 + T::WIDTH * r.len());
                    w.put_u64(step as u64);
                    w.put_slice::<T>(slice);
                    t.send(dp, tags::pack(tags::NS_STAGE, epoch, step as u64), &w.finish())?;
                }
            }
        }
        // Phase 2: destination members pull their pieces.
        if let Some(d) = &mut dst.local {
            for (step, &(sp, dp, r)) in plan.transfers().iter().enumerate() {
                if dp != self.me || sp == self.me {
                    continue;
                }
                let payload = t.recv(sp, tags::pack(tags::NS_STAGE, epoch, step as u64))?;
                let mut rd = WireReader::new(&payload);
                let _step = rd.get_u64()?;
                let d_off = plan.dst_offset(self.me, r.lo);
                rd.get_slice_into::<T>(&mut d.loc_mut()[d_off..d_off + r.len()])?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ChannelHub;
    use std::sync::Arc;
    use std::thread;

    /// Two-stage pipeline over a 4-PID world: stage A on {0,1},
    /// stage B on {2,3}. Stage A produces, transfers, stage B consumes.
    #[test]
    fn two_stage_pipeline_transfers_across_subsets() {
        let np = 4;
        let n = 1000;
        let world = ChannelHub::world(np);
        let hs: Vec<_> = world
            .into_iter()
            .map(|t| {
                thread::spawn(move || {
                    let me = t.pid();
                    let m_a = stage_map(&[0, 1]);
                    let m_b = stage_map(&[2, 3]);
                    let mut a = StageArray::zeros(m_a, &[n], me);
                    let mut b = StageArray::zeros(m_b, &[n], me);
                    // Stage A computes (owner-computes on its subset).
                    if let Some(arr) = &mut a.local {
                        let base = crate::dmap::Partition::of(arr.map(), &[n]);
                        let mut off = 0;
                        let ranges = base.ranges_of(me);
                        for r in ranges {
                            for g in r.lo..r.hi {
                                arr.loc_mut()[off] = (g * 2) as f64;
                                off += 1;
                            }
                        }
                    }
                    // Transfer A → B.
                    a.send_to(&mut b, &t, 0).unwrap();
                    // Stage B verifies.
                    if let Some(arr) = &b.local {
                        for g in 0..n {
                            if let Some(v) = arr.global_get(g) {
                                assert_eq!(v, (g * 2) as f64, "pid {me} g={g}");
                            }
                        }
                        true
                    } else {
                        assert!(me < 2);
                        false
                    }
                })
            })
            .collect();
        let consumed: Vec<bool> = hs.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(consumed.iter().filter(|&&c| c).count(), 2);
    }

    /// Overlapping stages (a PID in both) still transfer correctly.
    #[test]
    fn overlapping_stage_membership() {
        let np = 3;
        let n = 90;
        let world = ChannelHub::world(np);
        let hs: Vec<_> = world
            .into_iter()
            .map(|t| {
                thread::spawn(move || {
                    let me = t.pid();
                    let m_a = stage_map(&[0, 1]);
                    let m_b = stage_map(&[1, 2]);
                    let mut a = StageArray::zeros(m_a, &[n], me);
                    if let Some(arr) = &mut a.local {
                        let part = crate::dmap::Partition::of(arr.map(), &[n]);
                        let mut off = 0;
                        for r in part.ranges_of(me) {
                            for g in r.lo..r.hi {
                                arr.loc_mut()[off] = g as f64 + 0.5;
                                off += 1;
                            }
                        }
                    }
                    let mut b = StageArray::zeros(m_b, &[n], me);
                    a.send_to(&mut b, &t, 1).unwrap();
                    if let Some(arr) = &b.local {
                        for g in 0..n {
                            if let Some(v) = arr.global_get(g) {
                                assert_eq!(v, g as f64 + 0.5);
                            }
                        }
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
    }

    #[test]
    fn stage_map_requires_pids() {
        let m = stage_map(&[5, 9]);
        assert!(m.contains(5) && m.contains(9) && !m.contains(0));
        assert_eq!(m.np(), 2);
    }

    /// An iterated f32 pipeline through a shared engine plans once per
    /// hop direction and keeps the data exact.
    #[test]
    fn iterated_pipeline_plans_once_per_hop() {
        let np = 4;
        let n = 640;
        let iters = 5u64;
        let engine = Arc::new(RemapEngine::new());
        let world = ChannelHub::world(np);
        let hs: Vec<_> = world
            .into_iter()
            .map(|t| {
                let engine = engine.clone();
                thread::spawn(move || {
                    let me = t.pid();
                    let m_a = stage_map(&[0, 1]);
                    let m_b = stage_map(&[2, 3]);
                    for it in 0..iters {
                        let mut a = StageArrayT::<f32>::zeros(m_a.clone(), &[n], me);
                        let mut b = StageArrayT::<f32>::zeros(m_b.clone(), &[n], me);
                        if let Some(arr) = &mut a.local {
                            let part = crate::dmap::Partition::of(arr.map(), &[n]);
                            let mut off = 0;
                            for r in part.ranges_of(me) {
                                for g in r.lo..r.hi {
                                    arr.loc_mut()[off] = (g + it as usize) as f32;
                                    off += 1;
                                }
                            }
                        }
                        a.send_to_engine(&mut b, &t, it, &engine).unwrap();
                        if let Some(arr) = &b.local {
                            for g in (0..n).step_by(13) {
                                if let Some(v) = arr.global_get(g) {
                                    assert_eq!(v, (g + it as usize) as f32);
                                }
                            }
                        }
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(engine.plans_built(), 1, "one hop key, one plan");
    }
}
