//! Pipeline maps — §II: "Another example are pipelines which can be
//! implemented by mapping different arrays to different sets of PIDs."
//!
//! A [`StageMap`] assigns an array to a *subset* of the world's PIDs;
//! PIDs outside the stage hold an empty local part. Moving data
//! between stages is a [`Darray::assign_from`]-style transfer between
//! the two subsets' partitions.

use super::dense::Darray;
use super::Result;
use crate::comm::{tags, Transport, WireReader, WireWriter};
use crate::dmap::{Dist, Dmap, Grid, Overlap, Partition, Pid};

const TAG_STAGE: u64 = tags::REMAP ^ 0x5700_0000;

/// Build a 1-D block map over an explicit PID subset (a pipeline
/// stage). The world may contain many more PIDs.
pub fn stage_map(pids: &[Pid]) -> Dmap {
    assert!(!pids.is_empty());
    Dmap::new(
        Grid::line(pids.len()),
        vec![Dist::Block],
        vec![Overlap::none()],
        pids.to_vec(),
    )
}

/// One PID's view of a pipeline stage's array: participants hold
/// their local block, non-participants hold nothing.
pub struct StageArray {
    /// `Some` iff this PID participates in the stage.
    pub local: Option<Darray>,
    map: Dmap,
    shape: Vec<usize>,
    me: Pid,
}

impl StageArray {
    /// Allocate the stage array on this PID (empty if not a member).
    pub fn zeros(map: Dmap, shape: &[usize], me: Pid) -> StageArray {
        let local = map.contains(me).then(|| Darray::zeros(map.clone(), shape, me));
        StageArray { local, map, shape: shape.to_vec(), me }
    }

    pub fn map(&self) -> &Dmap {
        &self.map
    }

    pub fn participates(&self) -> bool {
        self.local.is_some()
    }

    /// Transfer this stage's content into `dst` (the next stage),
    /// across possibly disjoint PID subsets. SPMD over the **union**
    /// of both stages' PIDs (plus any others — non-members no-op).
    pub fn send_to(&self, dst: &mut StageArray, t: &dyn Transport, epoch: u64) -> Result<()> {
        assert_eq!(self.shape, dst.shape, "stage shapes must match");
        let tag = TAG_STAGE ^ (epoch << 8);
        let src_part = Partition::of(&self.map, &self.shape);
        let dst_part = Partition::of(&dst.map, &self.shape);
        let plan = src_part.transfers_to(&dst_part);

        // Phase 1: source members push their pieces.
        if let Some(src) = &self.local {
            let offsets = offsets_of(&src_part, self.me);
            for (step, &(sp, dp, r)) in plan.iter().enumerate() {
                if sp != self.me {
                    continue;
                }
                let s_off = lookup(&offsets, r.lo);
                let slice = &src.loc()[s_off..s_off + r.len()];
                if dp == self.me {
                    if let Some(d) = &mut dst.local {
                        let d_off = lookup(&offsets_of(&dst_part, self.me), r.lo);
                        d.loc_mut()[d_off..d_off + r.len()].copy_from_slice(slice);
                    }
                } else {
                    let mut w = WireWriter::with_capacity(16 + 8 * r.len());
                    w.put_u64(step as u64);
                    w.put_f64_slice(slice);
                    t.send(dp, tag ^ step as u64, &w.finish())?;
                }
            }
        }
        // Phase 2: destination members pull their pieces.
        if let Some(d) = &mut dst.local {
            let offsets = offsets_of(&dst_part, self.me);
            for (step, &(sp, dp, r)) in plan.iter().enumerate() {
                if dp != self.me || sp == self.me {
                    continue;
                }
                let payload = t.recv(sp, tag ^ step as u64)?;
                let mut rd = WireReader::new(&payload);
                let _step = rd.get_u64()?;
                let d_off = lookup(&offsets, r.lo);
                rd.get_f64_into(&mut d.loc_mut()[d_off..d_off + r.len()])?;
            }
        }
        Ok(())
    }
}

fn offsets_of(p: &Partition, pid: Pid) -> Vec<(usize, usize, usize)> {
    let mut out = Vec::new();
    let mut off = 0usize;
    for r in p.ranges_of(pid) {
        out.push((r.lo, r.len(), off));
        off += r.len();
    }
    out
}

fn lookup(table: &[(usize, usize, usize)], g: usize) -> usize {
    for &(lo, len, off) in table {
        if g >= lo && g < lo + len {
            return off + (g - lo);
        }
    }
    panic!("global index {g} not owned");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ChannelHub;
    use std::thread;

    /// Two-stage pipeline over a 4-PID world: stage A on {0,1},
    /// stage B on {2,3}. Stage A produces, transfers, stage B consumes.
    #[test]
    fn two_stage_pipeline_transfers_across_subsets() {
        let np = 4;
        let n = 1000;
        let world = ChannelHub::world(np);
        let hs: Vec<_> = world
            .into_iter()
            .map(|t| {
                thread::spawn(move || {
                    let me = t.pid();
                    let m_a = stage_map(&[0, 1]);
                    let m_b = stage_map(&[2, 3]);
                    let mut a = StageArray::zeros(m_a, &[n], me);
                    let mut b = StageArray::zeros(m_b, &[n], me);
                    // Stage A computes (owner-computes on its subset).
                    if let Some(arr) = &mut a.local {
                        let base = crate::dmap::Partition::of(arr.map(), &[n]);
                        let mut off = 0;
                        let ranges = base.ranges_of(me);
                        for r in ranges {
                            for g in r.lo..r.hi {
                                arr.loc_mut()[off] = (g * 2) as f64;
                                off += 1;
                            }
                        }
                    }
                    // Transfer A → B.
                    a.send_to(&mut b, &t, 0).unwrap();
                    // Stage B verifies.
                    if let Some(arr) = &b.local {
                        for g in 0..n {
                            if let Some(v) = arr.global_get(g) {
                                assert_eq!(v, (g * 2) as f64, "pid {me} g={g}");
                            }
                        }
                        true
                    } else {
                        assert!(me < 2);
                        false
                    }
                })
            })
            .collect();
        let consumed: Vec<bool> = hs.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(consumed.iter().filter(|&&c| c).count(), 2);
    }

    /// Overlapping stages (a PID in both) still transfer correctly.
    #[test]
    fn overlapping_stage_membership() {
        let np = 3;
        let n = 90;
        let world = ChannelHub::world(np);
        let hs: Vec<_> = world
            .into_iter()
            .map(|t| {
                thread::spawn(move || {
                    let me = t.pid();
                    let m_a = stage_map(&[0, 1]);
                    let m_b = stage_map(&[1, 2]);
                    let mut a = StageArray::zeros(m_a, &[n], me);
                    if let Some(arr) = &mut a.local {
                        let part = crate::dmap::Partition::of(arr.map(), &[n]);
                        let mut off = 0;
                        for r in part.ranges_of(me) {
                            for g in r.lo..r.hi {
                                arr.loc_mut()[off] = g as f64 + 0.5;
                                off += 1;
                            }
                        }
                    }
                    let mut b = StageArray::zeros(m_b, &[n], me);
                    a.send_to(&mut b, &t, 1).unwrap();
                    if let Some(arr) = &b.local {
                        for g in 0..n {
                            if let Some(v) = arr.global_get(g) {
                                assert_eq!(v, g as f64 + 0.5);
                            }
                        }
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
    }

    #[test]
    fn stage_map_requires_pids() {
        let m = stage_map(&[5, 9]);
        assert!(m.contains(5) && m.contains(9) && !m.contains(0));
        assert_eq!(m.np(), 2);
    }
}
