//! Pipeline maps — §II: "Another example are pipelines which can be
//! implemented by mapping different arrays to different sets of PIDs."
//!
//! A stage map assigns an array to a *subset* of the world's PIDs;
//! PIDs outside the stage hold an empty local part. Moving data
//! between stages is exactly a remap between the two subsets'
//! partitions, so [`StageArrayT::send_to`] executes a shared
//! [`RemapPlan`] — and the iterated form
//! [`StageArrayT::send_to_engine`] reuses a [`RemapEngine`]'s cache so
//! a steady-state pipeline replans nothing.

use super::dense::DarrayT;
use super::engine::{recv_groups, send_group_typed, unpack_group_typed, RemapEngine, RemapPlan};
use super::Result;
use crate::comm::{tags, ChunkTag, Transport};
use crate::dmap::{Dist, Dmap, Grid, Overlap, Pid};
use crate::element::Element;

/// Build a 1-D block map over an explicit PID subset (a pipeline
/// stage). The world may contain many more PIDs.
pub fn stage_map(pids: &[Pid]) -> Dmap {
    assert!(!pids.is_empty());
    Dmap::new(
        Grid::line(pids.len()),
        vec![Dist::Block],
        vec![Overlap::none()],
        pids.to_vec(),
    )
}

/// One PID's view of a pipeline stage's array: participants hold
/// their local block, non-participants hold nothing.
pub struct StageArrayT<T: Element> {
    /// `Some` iff this PID participates in the stage.
    pub local: Option<DarrayT<T>>,
    map: Dmap,
    shape: Vec<usize>,
    me: Pid,
}

/// The classic f64 stage array.
pub type StageArray = StageArrayT<f64>;

impl<T: Element> StageArrayT<T> {
    /// Allocate the stage array on this PID (empty if not a member).
    pub fn zeros(map: Dmap, shape: &[usize], me: Pid) -> StageArrayT<T> {
        let local = map
            .contains(me)
            .then(|| DarrayT::<T>::zeros(map.clone(), shape, me));
        StageArrayT { local, map, shape: shape.to_vec(), me }
    }

    pub fn map(&self) -> &Dmap {
        &self.map
    }

    pub fn participates(&self) -> bool {
        self.local.is_some()
    }

    /// Transfer this stage's content into `dst` (the next stage),
    /// across possibly disjoint PID subsets, planning from scratch.
    /// SPMD over the **union** of both stages' PIDs (plus any others —
    /// non-members no-op).
    pub fn send_to(&self, dst: &mut StageArrayT<T>, t: &dyn Transport, epoch: u64) -> Result<()> {
        assert_eq!(self.shape, dst.shape, "stage shapes must match");
        let plan = RemapPlan::build(&self.map, &dst.map, &self.shape);
        self.execute_stage_plan(&plan, dst, t, epoch)
    }

    /// [`StageArrayT::send_to`] through a plan cache — the steady-state
    /// pipeline path (plans once per `(src_map, dst_map, shape)`).
    pub fn send_to_engine(
        &self,
        dst: &mut StageArrayT<T>,
        t: &dyn Transport,
        epoch: u64,
        engine: &RemapEngine,
    ) -> Result<()> {
        assert_eq!(self.shape, dst.shape, "stage shapes must match");
        let plan = engine.plan(&self.map, &dst.map, &self.shape);
        self.execute_stage_plan(&plan, dst, t, epoch)
    }

    /// Stage transfers ride the remap engine's per-peer coalescing
    /// over the shared datapath: every range flowing between a PID
    /// pair travels as **one** chunked stream
    /// (`[n_ranges][(dst_lo, len)…][payload]`, pooled wire buffers,
    /// bulk codec), tagged per stage epoch in `NS_STAGE` — not one
    /// `NS_STAGE` message per plan step as before. Incoming peers
    /// complete in arrival order.
    fn execute_stage_plan(
        &self,
        plan: &RemapPlan,
        dst: &mut StageArrayT<T>,
        t: &dyn Transport,
        epoch: u64,
    ) -> Result<()> {
        // Identical PID subsets and distributions: pure local copy.
        if plan.is_aligned() {
            if let (Some(src), Some(d)) = (&self.local, &mut dst.local) {
                d.loc_mut().copy_from_slice(src.loc());
            }
            return Ok(());
        }
        let tag = ChunkTag::new(tags::NS_STAGE, epoch);
        // Overlapping membership: ranges this PID owns in both stages
        // never touch the wire.
        let src_loc: &[T] = self.local.as_ref().map_or(&[], |a| a.loc());
        for &(s_off, d_off, len) in plan.local_copies(self.me) {
            let d = dst.local.as_mut().expect("a local copy implies dst membership");
            d.loc_mut()[d_off..d_off + len].copy_from_slice(&src_loc[s_off..s_off + len]);
        }
        // Source members push one coalesced message per destination
        // peer (non-members have no send groups).
        for g in plan.peer_sends(self.me) {
            send_group_typed::<T>(g, src_loc, t, tag)?;
        }
        // Destination members drain their incoming peers in arrival
        // order (non-members have no recv groups).
        if let Some(d) = &mut dst.local {
            let dst_loc = d.loc_mut();
            recv_groups(plan, self.me, t, tag, |g, payload| {
                unpack_group_typed::<T>(g, &payload, dst_loc)
            })?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ChannelHub;
    use std::sync::Arc;
    use std::thread;

    /// Two-stage pipeline over a 4-PID world: stage A on {0,1},
    /// stage B on {2,3}. Stage A produces, transfers, stage B consumes.
    #[test]
    fn two_stage_pipeline_transfers_across_subsets() {
        let np = 4;
        let n = 1000;
        let world = ChannelHub::world(np);
        let hs: Vec<_> = world
            .into_iter()
            .map(|t| {
                thread::spawn(move || {
                    let me = t.pid();
                    let m_a = stage_map(&[0, 1]);
                    let m_b = stage_map(&[2, 3]);
                    let mut a = StageArray::zeros(m_a, &[n], me);
                    let mut b = StageArray::zeros(m_b, &[n], me);
                    // Stage A computes (owner-computes on its subset).
                    if let Some(arr) = &mut a.local {
                        let base = crate::dmap::Partition::of(arr.map(), &[n]);
                        let mut off = 0;
                        let ranges = base.ranges_of(me);
                        for r in ranges {
                            for g in r.lo..r.hi {
                                arr.loc_mut()[off] = (g * 2) as f64;
                                off += 1;
                            }
                        }
                    }
                    // Transfer A → B.
                    a.send_to(&mut b, &t, 0).unwrap();
                    // Stage B verifies.
                    if let Some(arr) = &b.local {
                        for g in 0..n {
                            if let Some(v) = arr.global_get(g) {
                                assert_eq!(v, (g * 2) as f64, "pid {me} g={g}");
                            }
                        }
                        true
                    } else {
                        assert!(me < 2);
                        false
                    }
                })
            })
            .collect();
        let consumed: Vec<bool> = hs.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(consumed.iter().filter(|&&c| c).count(), 2);
    }

    /// Overlapping stages (a PID in both) still transfer correctly.
    #[test]
    fn overlapping_stage_membership() {
        let np = 3;
        let n = 90;
        let world = ChannelHub::world(np);
        let hs: Vec<_> = world
            .into_iter()
            .map(|t| {
                thread::spawn(move || {
                    let me = t.pid();
                    let m_a = stage_map(&[0, 1]);
                    let m_b = stage_map(&[1, 2]);
                    let mut a = StageArray::zeros(m_a, &[n], me);
                    if let Some(arr) = &mut a.local {
                        let part = crate::dmap::Partition::of(arr.map(), &[n]);
                        let mut off = 0;
                        for r in part.ranges_of(me) {
                            for g in r.lo..r.hi {
                                arr.loc_mut()[off] = g as f64 + 0.5;
                                off += 1;
                            }
                        }
                    }
                    let mut b = StageArray::zeros(m_b, &[n], me);
                    a.send_to(&mut b, &t, 1).unwrap();
                    if let Some(arr) = &b.local {
                        for g in 0..n {
                            if let Some(v) = arr.global_get(g) {
                                assert_eq!(v, g as f64 + 0.5);
                            }
                        }
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
    }

    #[test]
    fn stage_map_requires_pids() {
        let m = stage_map(&[5, 9]);
        assert!(m.contains(5) && m.contains(9) && !m.contains(0));
        assert_eq!(m.np(), 2);
    }

    /// Stage transfers are coalesced: a strided (cyclic → block) hop
    /// between disjoint subsets sends exactly one `NS_STAGE` message
    /// per communicating peer pair — strictly fewer than the plan's
    /// step count — and the data still lands exactly.
    #[test]
    fn stage_transfer_sends_one_message_per_peer() {
        let np = 4;
        let n = 96;
        let m_a = Dmap::new(
            Grid::line(2),
            vec![Dist::Cyclic],
            vec![Overlap::none()],
            vec![0, 1],
        );
        let m_b = stage_map(&[2, 3]);
        let plan = RemapPlan::build(&m_a, &m_b, &[n]);
        // The shape this satellite exists for: many plan steps, few
        // peers.
        let sends_planned: usize = (0..np).map(|p| plan.peer_sends(p).len()).sum();
        let steps_crossing = plan.transfers().iter().filter(|(s, d, _)| s != d).count();
        assert_eq!(sends_planned, 4, "2 sources × 2 destinations");
        assert!(steps_crossing > sends_planned, "coalescing must merge steps");
        let world = ChannelHub::world(np);
        let hs: Vec<_> = world
            .into_iter()
            .map(|t| {
                let m_a = m_a.clone();
                let m_b = m_b.clone();
                thread::spawn(move || {
                    let me = t.pid();
                    let mut a = StageArray::zeros(m_a, &[n], me);
                    if let Some(arr) = &mut a.local {
                        let part = crate::dmap::Partition::of(arr.map(), &[n]);
                        let mut off = 0;
                        for r in part.ranges_of(me) {
                            for g in r.lo..r.hi {
                                arr.loc_mut()[off] = g as f64 * 3.0;
                                off += 1;
                            }
                        }
                    }
                    let mut b = StageArray::zeros(m_b, &[n], me);
                    a.send_to(&mut b, &t, 7).unwrap();
                    if let Some(arr) = &b.local {
                        for g in 0..n {
                            if let Some(v) = arr.global_get(g) {
                                assert_eq!(v, g as f64 * 3.0);
                            }
                        }
                    }
                    t.stats().msgs_sent()
                })
            })
            .collect();
        let total_msgs: u64 = hs.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total_msgs as usize, sends_planned, "one message per peer pair");
    }

    /// An iterated f32 pipeline through a shared engine plans once per
    /// hop direction and keeps the data exact.
    #[test]
    fn iterated_pipeline_plans_once_per_hop() {
        let np = 4;
        let n = 640;
        let iters = 5u64;
        let engine = Arc::new(RemapEngine::new());
        let world = ChannelHub::world(np);
        let hs: Vec<_> = world
            .into_iter()
            .map(|t| {
                let engine = engine.clone();
                thread::spawn(move || {
                    let me = t.pid();
                    let m_a = stage_map(&[0, 1]);
                    let m_b = stage_map(&[2, 3]);
                    for it in 0..iters {
                        let mut a = StageArrayT::<f32>::zeros(m_a.clone(), &[n], me);
                        let mut b = StageArrayT::<f32>::zeros(m_b.clone(), &[n], me);
                        if let Some(arr) = &mut a.local {
                            let part = crate::dmap::Partition::of(arr.map(), &[n]);
                            let mut off = 0;
                            for r in part.ranges_of(me) {
                                for g in r.lo..r.hi {
                                    arr.loc_mut()[off] = (g + it as usize) as f32;
                                    off += 1;
                                }
                            }
                        }
                        a.send_to_engine(&mut b, &t, it, &engine).unwrap();
                        if let Some(arr) = &b.local {
                            for g in (0..n).step_by(13) {
                                if let Some(v) = arr.global_get(g) {
                                    assert_eq!(v, (g + it as usize) as f32);
                                }
                            }
                        }
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(engine.plans_built(), 1, "one hop key, one plan");
    }
}
