//! `agg()` — gather a distributed array to the leader (pMatlab's
//! aggregation; used at the end of a run "the results were aggregated
//! using asynchronous file-based messaging" §V).
//!
//! Routed through the [`crate::collective`] gather (`NS_AGG`
//! namespace): under the default star algorithm the wire exchange is
//! bit-for-bit the legacy one (each PID's typed local part straight
//! to PID 0, received in map-PID order); `--coll tree|ring|hier`
//! swap in logarithmic or topology-aware gathers without touching
//! this call site again.

use super::dense::DarrayT;
use super::Result;
use crate::collective::{Collective, TagSpace};
use crate::comm::{tags, Transport, WireReader, WireWriter};
use crate::dmap::Partition;
use crate::element::Element;

impl<T: Element> DarrayT<T> {
    /// Gather the full global array onto the map's first PID — PID 0
    /// for every world-spanning map.
    ///
    /// Returns `Some(global)` on that leader, `None` elsewhere. SPMD:
    /// every PID in the map must call with the same `epoch`.
    pub fn agg(&self, t: &dyn Transport, epoch: u64) -> Result<Option<Vec<T>>> {
        self.agg_with(&crate::collective::ambient(t.np()), t, epoch)
    }

    /// [`DarrayT::agg`] under an explicit collective context.
    pub fn agg_with(
        &self,
        coll: &Collective,
        t: &dyn Transport,
        epoch: u64,
    ) -> Result<Option<Vec<T>>> {
        let space = TagSpace::packed(tags::NS_AGG, epoch);
        // The assembly root is the map's first PID — PID 0 for every
        // world-spanning map (the legacy contract). A non-member PID
        // cannot reach this method (DarrayT construction asserts map
        // membership), so for subset maps the global lands at the
        // subset's own leader; the legacy code instead sent those
        // contributions to a PID that could hold no array and lost
        // them.
        let group = self.map().pids().to_vec();
        let mut w = WireWriter::with_capacity(24 + T::WIDTH * self.local_len());
        w.put_slice::<T>(self.loc());
        let Some(parts) = coll.gather_group(t, space, &group, w.finish())? else {
            return Ok(None);
        };
        // Root: scatter every PID's typed part into the global layout.
        let part = Partition::of(self.map(), &self.shape().to_vec());
        let mut global = vec![T::ZERO; self.global_len()];
        for (&pid, payload) in group.iter().zip(&parts) {
            let mut rd = WireReader::new(payload);
            let data = rd.get_vec::<T>().map_err(crate::darray::DarrayError::from)?;
            let mut off = 0usize;
            for r in part.ranges_of(pid) {
                global[r.lo..r.hi].copy_from_slice(&data[off..off + r.len()]);
                off += r.len();
            }
        }
        Ok(Some(global))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::{CollKind, Topology};
    use crate::comm::ChannelHub;
    use crate::darray::dense::Darray;
    use crate::dmap::Dmap;
    use std::thread;

    fn run_agg(map_for: impl Fn(usize) -> Dmap + Send + Sync + 'static, n: usize, np: usize) {
        let world = ChannelHub::world(np);
        let f = std::sync::Arc::new(map_for);
        let mut hs = Vec::new();
        for t in world {
            let f = f.clone();
            hs.push(thread::spawn(move || {
                let pid = t.pid();
                let a = Darray::from_global_fn(f(np), &[n], pid, |g| g as f64 + 0.25);
                let got = a.agg(&t, 0).unwrap();
                if pid == 0 {
                    let g = got.expect("leader gets the global array");
                    assert_eq!(g.len(), n);
                    for (i, v) in g.iter().enumerate() {
                        assert_eq!(*v, i as f64 + 0.25);
                    }
                } else {
                    assert!(got.is_none());
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
    }

    #[test]
    fn agg_block() {
        run_agg(Dmap::block_1d, 103, 4);
    }

    #[test]
    fn agg_cyclic() {
        run_agg(Dmap::cyclic_1d, 64, 5);
    }

    #[test]
    fn agg_block_cyclic() {
        run_agg(|np| Dmap::block_cyclic_1d(np, 3), 50, 3);
    }

    #[test]
    fn agg_single_pid() {
        run_agg(Dmap::block_1d, 17, 1);
    }

    #[test]
    fn agg_typed_u64() {
        let np = 3;
        let world = ChannelHub::world(np);
        let mut hs = Vec::new();
        for t in world {
            hs.push(thread::spawn(move || {
                let pid = t.pid();
                let a =
                    DarrayT::<u64>::from_global_fn(Dmap::cyclic_1d(np), &[29], pid, |g| g as u64);
                let got = a.agg(&t, 1).unwrap();
                if pid == 0 {
                    let g = got.unwrap();
                    assert_eq!(g, (0..29u64).collect::<Vec<_>>());
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
    }

    /// A map over a PID subset aggregates onto the subset's first PID
    /// (non-members hold no array and do not participate; the legacy
    /// code sent their contributions to PID 0, which could hold no
    /// array for this map, and lost them).
    #[test]
    fn agg_subset_map_roots_at_first_map_pid() {
        let np = 3;
        let n = 40;
        let world = ChannelHub::world(np);
        let mut hs = Vec::new();
        for t in world {
            hs.push(thread::spawn(move || {
                let pid = t.pid();
                if pid == 0 {
                    return None; // not a map member: no array, no call
                }
                let map = crate::darray::pipeline::stage_map(&[1, 2]);
                let a = Darray::from_global_fn(map, &[n], pid, |g| g as f64 + 0.5);
                a.agg(&t, 3).unwrap()
            }));
        }
        let outs: Vec<_> = hs.into_iter().map(|h| h.join().unwrap()).collect();
        let g = outs[1].as_ref().expect("the subset leader assembles");
        assert_eq!(g.len(), n);
        for (i, v) in g.iter().enumerate() {
            assert_eq!(*v, i as f64 + 0.5);
        }
        assert!(outs[0].is_none() && outs[2].is_none());
    }

    /// Explicit non-star contexts aggregate the identical global
    /// array (the equivalence the property suite checks exhaustively).
    #[test]
    fn agg_with_every_algorithm_matches() {
        for kind in [CollKind::Tree, CollKind::Ring, CollKind::Hier] {
            let np = 5;
            let world = ChannelHub::world(np);
            let mut hs = Vec::new();
            for t in world {
                hs.push(thread::spawn(move || {
                    let pid = t.pid();
                    let coll = Collective::new(kind, Topology::grouped(np, 2));
                    let a = Darray::from_global_fn(Dmap::cyclic_1d(np), &[77], pid, |g| {
                        g as f64 * 0.5
                    });
                    a.agg_with(&coll, &t, 2).unwrap()
                }));
            }
            let outs: Vec<_> = hs.into_iter().map(|h| h.join().unwrap()).collect();
            let g = outs[0].as_ref().expect("root output");
            for (i, v) in g.iter().enumerate() {
                assert_eq!(*v, i as f64 * 0.5, "kind {kind}");
            }
            assert!(outs[1..].iter().all(Option::is_none));
        }
    }
}
