//! `agg()` — gather a distributed array to the leader (pMatlab's
//! aggregation; used at the end of a run "the results were aggregated
//! using asynchronous file-based messaging" §V).

use super::dense::DarrayT;
use super::Result;
use crate::comm::{tags, Transport, WireReader, WireWriter};
use crate::dmap::Partition;
use crate::element::Element;

impl<T: Element> DarrayT<T> {
    /// Gather the full global array onto PID 0.
    ///
    /// Returns `Some(global)` on the leader, `None` elsewhere. SPMD:
    /// every PID in the map must call with the same `epoch`.
    pub fn agg(&self, t: &dyn Transport, epoch: u64) -> Result<Option<Vec<T>>> {
        let tag = tags::pack(tags::NS_AGG, epoch, 0);
        let part = Partition::of(self.map(), &self.shape().to_vec());
        if self.pid() == 0 {
            let mut global = vec![T::ZERO; self.global_len()];
            // Own pieces first.
            let mut off = 0usize;
            for r in part.ranges_of(0) {
                global[r.lo..r.hi].copy_from_slice(&self.loc()[off..off + r.len()]);
                off += r.len();
            }
            // Then one message per other PID.
            for &pid in self.map().pids() {
                if pid == 0 {
                    continue;
                }
                let payload = t.recv(pid, tag)?;
                let mut rd = WireReader::new(&payload);
                let data = rd.get_vec::<T>()?;
                let mut off = 0usize;
                for r in part.ranges_of(pid) {
                    global[r.lo..r.hi].copy_from_slice(&data[off..off + r.len()]);
                    off += r.len();
                }
            }
            Ok(Some(global))
        } else {
            let mut w = WireWriter::with_capacity(24 + T::WIDTH * self.local_len());
            w.put_slice::<T>(self.loc());
            t.send(0, tag, &w.finish())?;
            Ok(None)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ChannelHub;
    use crate::darray::dense::Darray;
    use crate::dmap::Dmap;
    use std::thread;

    fn run_agg(map_for: impl Fn(usize) -> Dmap + Send + Sync + 'static, n: usize, np: usize) {
        let world = ChannelHub::world(np);
        let f = std::sync::Arc::new(map_for);
        let mut hs = Vec::new();
        for t in world {
            let f = f.clone();
            hs.push(thread::spawn(move || {
                let pid = t.pid();
                let a = Darray::from_global_fn(f(np), &[n], pid, |g| g as f64 + 0.25);
                let got = a.agg(&t, 0).unwrap();
                if pid == 0 {
                    let g = got.expect("leader gets the global array");
                    assert_eq!(g.len(), n);
                    for (i, v) in g.iter().enumerate() {
                        assert_eq!(*v, i as f64 + 0.25);
                    }
                } else {
                    assert!(got.is_none());
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
    }

    #[test]
    fn agg_block() {
        run_agg(Dmap::block_1d, 103, 4);
    }

    #[test]
    fn agg_cyclic() {
        run_agg(Dmap::cyclic_1d, 64, 5);
    }

    #[test]
    fn agg_block_cyclic() {
        run_agg(|np| Dmap::block_cyclic_1d(np, 3), 50, 3);
    }

    #[test]
    fn agg_single_pid() {
        run_agg(Dmap::block_1d, 17, 1);
    }

    #[test]
    fn agg_typed_u64() {
        let np = 3;
        let world = ChannelHub::world(np);
        let mut hs = Vec::new();
        for t in world {
            hs.push(thread::spawn(move || {
                let pid = t.pid();
                let a =
                    DarrayT::<u64>::from_global_fn(Dmap::cyclic_1d(np), &[29], pid, |g| g as u64);
                let got = a.agg(&t, 1).unwrap();
                if pid == 0 {
                    let g = got.unwrap();
                    assert_eq!(g, (0..29u64).collect::<Vec<_>>());
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
    }
}
