//! Overlap (halo) synchronization — Figure 1's "overlap" mapping:
//! "Overlap allows the boundaries of an array to be stored on two
//! neighboring PIDs" and is "implicitly communicated to complete the
//! computation".
//!
//! [`Darray::sync_halo`] refreshes each PID's halo suffix from the
//! owner (its right neighbour). Supported for 1-D block maps, the
//! form pMatlab supports.

use super::dense::DarrayT;
use super::{DarrayError, Result};
use crate::comm::{tags, Transport, WireReader, WireWriter};
use crate::dmap::{Dist, Overlap};
use crate::element::Element;

impl<T: Element> DarrayT<T> {
    /// Refresh this PID's halo from its right neighbour. SPMD.
    ///
    /// Equivalent to [`DarrayT::sync_halo_send`] immediately followed
    /// by [`DarrayT::sync_halo_recv`]; callers that have local work to
    /// do can issue the halves separately and compute between them
    /// while the boundary is in flight (see
    /// `examples/jacobi_stencil.rs`).
    pub fn sync_halo(&mut self, t: &dyn Transport, epoch: u64) -> Result<()> {
        self.sync_halo_send(t, epoch)?;
        self.sync_halo_recv(t, epoch)
    }

    /// The send half of [`DarrayT::sync_halo`]: push my leading
    /// elements to my LEFT neighbour (they store my boundary as their
    /// halo) and return without waiting for my own halo to land.
    pub fn sync_halo_send(&self, t: &dyn Transport, epoch: u64) -> Result<()> {
        let (ov, dist, n, g, coord) = match self.halo_ctx()? {
            Some(c) => c,
            None => return Ok(()),
        };
        let tag = tags::pack(tags::NS_HALO, epoch, 0);
        if coord > 0 {
            let left = self.map().pid_at(&[coord - 1]);
            if let Some((lo, hi)) = ov.halo_range(&dist, coord - 1, n, g) {
                // Their halo range [lo,hi) is global; it lives at the
                // head of MY owned region.
                let my_lo = dist.local_to_global(coord, 0, n, g);
                let s = lo - my_lo;
                let e = hi - my_lo;
                let mut w = WireWriter::with_capacity(24 + T::WIDTH * (e - s));
                w.put_slice::<T>(&self.loc()[s..e]);
                t.send(left, tag, &w.finish())?;
            }
        }
        Ok(())
    }

    /// The receive half of [`DarrayT::sync_halo`]: land my halo suffix
    /// from my RIGHT neighbour (blocks until it arrives).
    pub fn sync_halo_recv(&mut self, t: &dyn Transport, epoch: u64) -> Result<()> {
        let (ov, dist, n, g, coord) = match self.halo_ctx()? {
            Some(c) => c,
            None => return Ok(()),
        };
        let tag = tags::pack(tags::NS_HALO, epoch, 0);
        if let Some((lo, hi)) = ov.halo_range(&dist, coord, n, g) {
            let right = self.map().pid_at(&[coord + 1]);
            let payload = t.recv(right, tag)?;
            let mut rd = WireReader::new(&payload);
            let owned = self.local_len();
            let halo_len = hi - lo;
            let stored = self.stored_mut();
            rd.get_slice_into::<T>(&mut stored[owned..owned + halo_len])?;
        }
        Ok(())
    }

    /// Shared validation of both halves: `None` means "no overlap,
    /// nothing to sync" (a silent no-op), `Err` an unsupported map.
    #[allow(clippy::type_complexity)]
    fn halo_ctx(&self) -> Result<Option<(Overlap, Dist, usize, usize, usize)>> {
        if self.map().ndim() != 1 {
            return Err(DarrayError::Unsupported(
                "halo sync supported for 1-D block maps only".into(),
            ));
        }
        let ov = self.map().overlaps()[0];
        if ov.is_none() {
            return Ok(None);
        }
        let dist = self.map().dists()[0];
        if !matches!(dist, Dist::Block) {
            return Err(DarrayError::Unsupported(
                "overlap requires a block distribution".into(),
            ));
        }
        let n = self.shape()[0];
        let g = self.map().grid().dim(0);
        let coord = self.map().coord_of(self.pid())[0];
        Ok(Some((ov, dist, n, g, coord)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ChannelHub;
    use crate::darray::dense::Darray;
    use crate::dmap::Dmap;
    use std::thread;

    #[test]
    fn halo_reflects_neighbour_values() {
        let np = 4;
        let n = 20;
        let world = ChannelHub::world(np);
        let mut hs = Vec::new();
        for t in world {
            hs.push(thread::spawn(move || {
                let pid = t.pid();
                let mut a =
                    Darray::from_global_fn(Dmap::block_1d_overlap(np, 2), &[n], pid, |g| g as f64);
                a.sync_halo(&t, 0).unwrap();
                // Each of pids 0..2 owns 5 elems and stores 2 halo elems
                // equal to the next two global values.
                let owned = a.local_len();
                let stored = a.stored();
                if pid < np - 1 {
                    let my_hi = (pid + 1) * 5;
                    assert_eq!(stored[owned], my_hi as f64);
                    assert_eq!(stored[owned + 1], (my_hi + 1) as f64);
                } else {
                    assert_eq!(stored.len(), owned);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
    }

    #[test]
    fn no_overlap_sync_is_silent_noop() {
        let mut world = ChannelHub::world(1);
        let t = world.pop().unwrap();
        let mut a = Darray::zeros(Dmap::block_1d(1), &[8], 0);
        a.sync_halo(&t, 0).unwrap();
        assert!(t.stats().is_silent());
    }

    #[test]
    fn halo_sync_f32() {
        let np = 2;
        let world = ChannelHub::world(np);
        let mut hs = Vec::new();
        for t in world {
            hs.push(thread::spawn(move || {
                let pid = t.pid();
                let mut a = DarrayT::<f32>::from_global_fn(
                    Dmap::block_1d_overlap(np, 1),
                    &[8],
                    pid,
                    |g| g as f32,
                );
                a.sync_halo(&t, 0).unwrap();
                if pid == 0 {
                    assert_eq!(a.stored()[a.local_len()], 4.0f32);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
    }

    #[test]
    fn split_halo_halves_match_combined() {
        let np = 3;
        let n = 12;
        let world = ChannelHub::world(np);
        let mut hs = Vec::new();
        for t in world {
            hs.push(thread::spawn(move || {
                let pid = t.pid();
                let f = |g: usize| g as f64 * 3.0;
                let map = Dmap::block_1d_overlap(np, 1);
                let mut a = Darray::from_global_fn(map.clone(), &[n], pid, f);
                a.sync_halo_send(&t, 7).unwrap();
                a.sync_halo_recv(&t, 7).unwrap();
                let mut b = Darray::from_global_fn(map, &[n], pid, f);
                b.sync_halo(&t, 8).unwrap();
                assert_eq!(a.stored(), b.stored());
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
    }

    #[test]
    fn halo_on_cyclic_is_error() {
        let mut world = ChannelHub::world(1);
        let t = world.pop().unwrap();
        // construct a cyclic map with overlap manually
        use crate::dmap::{Dist, Grid, Overlap};
        let m = crate::dmap::Dmap::new(
            Grid::line(1),
            vec![Dist::Cyclic],
            vec![Overlap::new(1)],
            vec![0],
        );
        let mut a = Darray::zeros(m, &[8], 0);
        assert!(a.sync_halo(&t, 0).is_err());
    }
}
