//! Map-independent global assignment — the paper's §IV discussion:
//! "if copy was implemented using `C(:,:) = A`, then it would run
//! correctly regardless of the map. However, if A and C had different
//! maps, then significant communication would be required."
//!
//! [`DarrayT::assign_from`] implements exactly that: aligned maps
//! degenerate to a local memcpy (zero messages — asserted by tests);
//! mismatched maps execute a [`RemapPlan`] over the transport. SPMD:
//! every participating PID calls this with its own endpoint; the plan
//! is deterministic so no coordination is needed beyond the data
//! messages themselves.
//!
//! Planning is delegated to [`crate::darray::engine`]: `assign_from`
//! builds a one-shot plan, [`DarrayT::assign_from_engine`] reuses a
//! cached one — iterated remaps (pipelines, alternating layouts) plan
//! exactly once per `(src_map, dst_map, shape)`.

use super::dense::DarrayT;
use super::engine::{execute_plan_typed, RemapEngine, RemapPlan};
use super::{DarrayError, Result};
use crate::backend::{Backend, BackendError};
use crate::comm::Transport;
use crate::element::Element;

impl<T: Element> DarrayT<T> {
    /// Global assignment `self(:) = src(:)` for any pair of maps,
    /// planning from scratch.
    ///
    /// `epoch` disambiguates concurrent remaps (like a barrier epoch).
    pub fn assign_from(&mut self, src: &DarrayT<T>, t: &dyn Transport, epoch: u64) -> Result<()> {
        self.check_assign_shapes(src)?;
        let plan = RemapPlan::build(src.map(), self.map(), self.shape());
        self.execute_remap(&plan, src, t, epoch)
    }

    /// Global assignment through a plan cache: the first call for a
    /// given `(src_map, dst_map, shape)` plans, every later call moves
    /// data only. Each call pays one cache lookup (a mutex + a
    /// fingerprint-keyed hash — maps clone as `Arc`s, no deep copy);
    /// the tightest loops can still hoist the `Arc<RemapPlan>` once
    /// and use [`DarrayT::assign_from_plan`] instead.
    pub fn assign_from_engine(
        &mut self,
        src: &DarrayT<T>,
        t: &dyn Transport,
        epoch: u64,
        engine: &RemapEngine,
    ) -> Result<()> {
        self.check_assign_shapes(src)?;
        let plan = engine.plan(src.map(), self.map(), self.shape());
        self.execute_remap(&plan, src, t, epoch)
    }

    /// Global assignment executing a prebuilt plan — the zero-lookup
    /// hot path for iterated remaps (`engine.plan(..)` once, then this
    /// per iteration). The plan MUST have been built for
    /// `(src.map(), self.map(), shape)`; offset-table mismatches panic
    /// rather than corrupt.
    pub fn assign_from_plan(
        &mut self,
        src: &DarrayT<T>,
        t: &dyn Transport,
        epoch: u64,
        plan: &RemapPlan,
    ) -> Result<()> {
        self.check_assign_shapes(src)?;
        self.execute_remap(plan, src, t, epoch)
    }

    fn check_assign_shapes(&self, src: &DarrayT<T>) -> Result<()> {
        if self.shape() != src.shape() {
            return Err(super::DarrayError::ShapeMismatch {
                a: self.shape().to_vec(),
                b: src.shape().to_vec(),
            });
        }
        Ok(())
    }

    /// Global assignment whose data movement runs on an execution
    /// backend: planning goes through `engine` (exactly once per
    /// `(src_map, dst_map, shape)` key), execution through
    /// [`Backend::execute_plan`] — the cached plan is a
    /// backend-agnostic index set, so the same plan drives host
    /// memcpys, pooled copies, or staged device transfers.
    pub fn assign_from_engine_on(
        &mut self,
        src: &DarrayT<T>,
        t: &dyn Transport,
        epoch: u64,
        engine: &RemapEngine,
        backend: &dyn Backend,
    ) -> Result<()> {
        self.check_assign_shapes(src)?;
        let plan = engine.plan(src.map(), self.map(), self.shape());
        let pid = self.pid();
        plan.execute_on::<T>(backend, src.loc(), self.loc_mut(), pid, t, epoch)
            .map_err(|e| match e {
                BackendError::Comm(c) => DarrayError::Comm(c),
                other => DarrayError::Unsupported(format!(
                    "backend '{}' remap failed: {other}",
                    backend.kind().name()
                )),
            })
    }

    /// Execute a prebuilt remap plan: local pieces copy, remote pieces
    /// travel as one coalesced typed message per destination peer (the
    /// shared [`execute_plan_typed`] routine backends reuse).
    fn execute_remap(
        &mut self,
        plan: &RemapPlan,
        src: &DarrayT<T>,
        t: &dyn Transport,
        epoch: u64,
    ) -> Result<()> {
        let pid = self.pid();
        execute_plan_typed::<T>(plan, src.loc(), self.loc_mut(), pid, t, epoch)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ChannelHub;
    use crate::darray::dense::Darray;
    use crate::dmap::Dmap;
    use std::sync::Arc;
    use std::thread;

    /// SPMD helper: run `f(pid, transport)` on np threads.
    fn spmd(np: usize, f: impl Fn(usize, &dyn Transport) + Send + Sync + 'static) {
        let world = ChannelHub::world(np);
        let f = Arc::new(f);
        let mut hs = Vec::new();
        for t in world {
            let f = f.clone();
            hs.push(thread::spawn(move || f(t.pid(), &t)));
        }
        for h in hs {
            h.join().unwrap();
        }
    }

    #[test]
    fn aligned_assign_is_local_and_silent() {
        spmd(4, |pid, t| {
            let src = Darray::from_global_fn(Dmap::block_1d(4), &[64], pid, |g| g as f64);
            let mut dst = Darray::zeros(Dmap::block_1d(4), &[64], pid);
            dst.assign_from(&src, t, 0).unwrap();
            assert_eq!(dst.loc(), src.loc());
            assert!(t.stats().is_silent(), "aligned assign must not message");
        });
    }

    #[test]
    fn block_to_cyclic_remap_correct() {
        spmd(4, |pid, t| {
            let src = Darray::from_global_fn(Dmap::block_1d(4), &[64], pid, |g| g as f64);
            let mut dst = Darray::zeros(Dmap::cyclic_1d(4), &[64], pid);
            dst.assign_from(&src, t, 1).unwrap();
            for g in 0..64 {
                if let Some(v) = dst.global_get(g) {
                    assert_eq!(v, g as f64, "pid={pid} g={g}");
                }
            }
            assert!(!t.stats().is_silent(), "remap must communicate");
        });
    }

    #[test]
    fn cyclic_to_block_cyclic_remap_correct() {
        spmd(3, |pid, t| {
            let src = Darray::from_global_fn(Dmap::cyclic_1d(3), &[50], pid, |g| (g * g) as f64);
            let mut dst = Darray::zeros(Dmap::block_cyclic_1d(3, 4), &[50], pid);
            dst.assign_from(&src, t, 2).unwrap();
            for g in 0..50 {
                if let Some(v) = dst.global_get(g) {
                    assert_eq!(v, (g * g) as f64);
                }
            }
        });
    }

    #[test]
    fn np1_remap_never_messages() {
        spmd(1, |pid, t| {
            let src = Darray::from_global_fn(Dmap::block_1d(1), &[32], pid, |g| g as f64);
            let mut dst = Darray::zeros(Dmap::cyclic_1d(1), &[32], pid);
            dst.assign_from(&src, t, 3).unwrap();
            assert!(t.stats().is_silent());
            for g in 0..32 {
                assert_eq!(dst.global_get(g), Some(g as f64));
            }
        });
    }

    #[test]
    fn typed_remaps_roundtrip_f32_and_i64() {
        spmd(3, |pid, t| {
            let src =
                DarrayT::<f32>::from_global_fn(Dmap::block_1d(3), &[40], pid, |g| g as f32 * 0.5);
            let mut dst = DarrayT::<f32>::zeros(Dmap::cyclic_1d(3), &[40], pid);
            dst.assign_from(&src, t, 4).unwrap();
            for g in 0..40 {
                if let Some(v) = dst.global_get(g) {
                    assert_eq!(v, g as f32 * 0.5);
                }
            }
            let src =
                DarrayT::<i64>::from_global_fn(Dmap::cyclic_1d(3), &[33], pid, |g| -(g as i64));
            let mut dst = DarrayT::<i64>::zeros(Dmap::block_1d(3), &[33], pid);
            dst.assign_from(&src, t, 5).unwrap();
            for g in 0..33 {
                if let Some(v) = dst.global_get(g) {
                    assert_eq!(v, -(g as i64));
                }
            }
        });
    }

    /// The hoisted hot path: fetch the Arc once, execute many times
    /// with zero cache lookups.
    #[test]
    fn hoisted_plan_execution_matches_engine_path() {
        let np = 3;
        let n = 90;
        let engine = Arc::new(RemapEngine::new());
        let world = ChannelHub::world(np);
        let mut hs = Vec::new();
        for t in world {
            let engine = engine.clone();
            hs.push(thread::spawn(move || {
                let pid = t.pid();
                let src = Darray::from_global_fn(Dmap::cyclic_1d(np), &[n], pid, |g| g as f64);
                let mut dst = Darray::zeros(Dmap::block_1d(np), &[n], pid);
                let plan = engine.plan(src.map(), dst.map(), &[n]);
                for epoch in 0..4 {
                    dst.fill(-1.0);
                    dst.assign_from_plan(&src, &t, epoch, &plan).unwrap();
                }
                for g in 0..n {
                    if let Some(v) = dst.global_get(g) {
                        assert_eq!(v, g as f64);
                    }
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(engine.plans_built(), 1);
    }

    /// Backend-driven assignment is bit-identical to the direct path
    /// and still plans exactly once.
    #[test]
    fn backend_assign_matches_direct_assign() {
        spmd(3, |pid, t| {
            let src = Darray::from_global_fn(Dmap::block_1d(3), &[48], pid, |g| g as f64);
            let mut direct = Darray::zeros(Dmap::cyclic_1d(3), &[48], pid);
            direct.assign_from(&src, t, 10).unwrap();
            let engine = RemapEngine::new();
            let backend = crate::backend::HostBackend::new();
            let mut via = Darray::zeros(Dmap::cyclic_1d(3), &[48], pid);
            via.assign_from_engine_on(&src, t, 11, &engine, &backend).unwrap();
            assert_eq!(via.loc(), direct.loc(), "pid {pid}");
            assert_eq!(engine.plans_built(), 1);
        });
    }

    /// The acceptance-criterion property: iterated remaps through a
    /// shared engine plan exactly once per direction.
    #[test]
    fn engine_plans_once_across_iterated_assigns() {
        let np = 4;
        let n = 256;
        let iters = 6u64;
        let engine = Arc::new(RemapEngine::new());
        let world = ChannelHub::world(np);
        let mut hs = Vec::new();
        for t in world {
            let engine = engine.clone();
            hs.push(thread::spawn(move || {
                let pid = t.pid();
                let src = Darray::from_global_fn(Dmap::block_1d(np), &[n], pid, |g| g as f64);
                let mut dst = Darray::zeros(Dmap::cyclic_1d(np), &[n], pid);
                for epoch in 0..iters {
                    dst.fill(0.0);
                    dst.assign_from_engine(&src, &t, epoch, &engine).unwrap();
                }
                for g in 0..n {
                    if let Some(v) = dst.global_get(g) {
                        assert_eq!(v, g as f64);
                    }
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(
            engine.plans_built(),
            1,
            "one (src,dst,shape) key must plan exactly once across {iters} iterations × {np} PIDs"
        );
    }

    use crate::comm::Transport;
}
