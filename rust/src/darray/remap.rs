//! Map-independent global assignment — the paper's §IV discussion:
//! "if copy was implemented using `C(:,:) = A`, then it would run
//! correctly regardless of the map. However, if A and C had different
//! maps, then significant communication would be required."
//!
//! [`Darray::assign_from`] implements exactly that: aligned maps
//! degenerate to a local memcpy (zero messages — asserted by tests);
//! mismatched maps execute the [`Partition::transfers_to`] plan over
//! the transport. SPMD: every participating PID calls this with its
//! own endpoint; the plan is deterministic so no coordination is
//! needed beyond the data messages themselves.

use super::dense::Darray;
use super::Result;
use crate::comm::{tags, Transport, WireReader, WireWriter};
use crate::dmap::{Partition, Pid};

impl Darray {
    /// Global assignment `self(:) = src(:)` for any pair of maps.
    ///
    /// `epoch` disambiguates concurrent remaps (like a barrier epoch).
    pub fn assign_from(&mut self, src: &Darray, t: &dyn Transport, epoch: u64) -> Result<()> {
        if self.shape() != src.shape() {
            return Err(super::DarrayError::ShapeMismatch {
                a: self.shape().to_vec(),
                b: src.shape().to_vec(),
            });
        }
        // Fast path: aligned maps → pure local copy, zero messages.
        if self.map().aligned_with(src.map(), &self.shape().to_vec()) {
            self.loc_mut().copy_from_slice(src.loc());
            return Ok(());
        }
        let me: Pid = self.pid();
        let shape = self.shape().to_vec();
        let src_part = Partition::of(src.map(), &shape);
        let dst_part = Partition::of(self.map(), &shape);
        let plan = src_part.transfers_to(&dst_part);
        let tag_base = tags::REMAP ^ (epoch << 32);

        // Local offsets: flattened-global-range → local offset tables.
        let src_offsets = local_offsets(&src_part, me);
        let dst_offsets = local_offsets(&dst_part, me);

        // Phase 1: satisfy local pieces + send outgoing pieces.
        // One message per (src=me, dst≠me) plan step, tagged by step
        // index so ordering is deterministic on both sides.
        for (step, &(sp, dp, r)) in plan.iter().enumerate() {
            if sp != me {
                continue;
            }
            let s_off = offset_in(&src_offsets, r.lo);
            let src_slice = &src.loc()[s_off..s_off + r.len()];
            if dp == me {
                let d_off = offset_in(&dst_offsets, r.lo);
                self.loc_mut()[d_off..d_off + r.len()].copy_from_slice(src_slice);
            } else {
                let mut w = WireWriter::with_capacity(16 + 8 * r.len());
                w.put_u64(step as u64);
                w.put_f64_slice(src_slice);
                t.send(dp, tag_base ^ (step as u64), &w.finish())?;
            }
        }
        // Phase 2: receive incoming pieces.
        for (step, &(sp, dp, r)) in plan.iter().enumerate() {
            if dp != me || sp == me {
                continue;
            }
            let payload = t.recv(sp, tag_base ^ (step as u64))?;
            let mut rd = WireReader::new(&payload);
            let got_step = rd.get_u64()?;
            debug_assert_eq!(got_step as usize, step);
            let d_off = offset_in(&dst_offsets, r.lo);
            let dst = &mut self.loc_mut()[d_off..d_off + r.len()];
            rd.get_f64_into(dst)?;
        }
        Ok(())
    }
}

/// (range_start, range_len, local_offset) table for one PID.
fn local_offsets(p: &Partition, pid: Pid) -> Vec<(usize, usize, usize)> {
    let mut out = Vec::new();
    let mut off = 0usize;
    for r in p.ranges_of(pid) {
        out.push((r.lo, r.len(), off));
        off += r.len();
    }
    out
}

/// Local offset of flattened global index `g` given the offset table.
fn offset_in(table: &[(usize, usize, usize)], g: usize) -> usize {
    for &(lo, len, off) in table {
        if g >= lo && g < lo + len {
            return off + (g - lo);
        }
    }
    panic!("global index {g} not owned (plan/offset table mismatch)");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ChannelHub;
    use crate::dmap::Dmap;
    use std::thread;

    /// SPMD helper: run `f(pid, transport)` on np threads.
    fn spmd(np: usize, f: impl Fn(usize, &dyn Transport) + Send + Sync + 'static) {
        let world = ChannelHub::world(np);
        let f = std::sync::Arc::new(f);
        let mut hs = Vec::new();
        for t in world {
            let f = f.clone();
            hs.push(thread::spawn(move || f(t.pid(), &t)));
        }
        for h in hs {
            h.join().unwrap();
        }
    }

    #[test]
    fn aligned_assign_is_local_and_silent() {
        spmd(4, |pid, t| {
            let src = Darray::from_global_fn(Dmap::block_1d(4), &[64], pid, |g| g as f64);
            let mut dst = Darray::zeros(Dmap::block_1d(4), &[64], pid);
            dst.assign_from(&src, t, 0).unwrap();
            assert_eq!(dst.loc(), src.loc());
            assert!(t.stats().is_silent(), "aligned assign must not message");
        });
    }

    #[test]
    fn block_to_cyclic_remap_correct() {
        spmd(4, |pid, t| {
            let src = Darray::from_global_fn(Dmap::block_1d(4), &[64], pid, |g| g as f64);
            let mut dst = Darray::zeros(Dmap::cyclic_1d(4), &[64], pid);
            dst.assign_from(&src, t, 1).unwrap();
            for g in 0..64 {
                if let Some(v) = dst.global_get(g) {
                    assert_eq!(v, g as f64, "pid={pid} g={g}");
                }
            }
            assert!(!t.stats().is_silent(), "remap must communicate");
        });
    }

    #[test]
    fn cyclic_to_block_cyclic_remap_correct() {
        spmd(3, |pid, t| {
            let src = Darray::from_global_fn(Dmap::cyclic_1d(3), &[50], pid, |g| (g * g) as f64);
            let mut dst = Darray::zeros(Dmap::block_cyclic_1d(3, 4), &[50], pid);
            dst.assign_from(&src, t, 2).unwrap();
            for g in 0..50 {
                if let Some(v) = dst.global_get(g) {
                    assert_eq!(v, (g * g) as f64);
                }
            }
        });
    }

    #[test]
    fn np1_remap_never_messages() {
        spmd(1, |pid, t| {
            let src = Darray::from_global_fn(Dmap::block_1d(1), &[32], pid, |g| g as f64);
            let mut dst = Darray::zeros(Dmap::cyclic_1d(1), &[32], pid);
            dst.assign_from(&src, t, 3).unwrap();
            assert!(t.stats().is_silent());
            for g in 0..32 {
                assert_eq!(dst.global_get(g), Some(g as f64));
            }
        });
    }

    use crate::comm::Transport;
}
