//! Owner-computes element-wise operations (§II "owner computes" rule).
//!
//! All ops here require aligned maps and touch only `.loc` — they are
//! the paper's "performance guarantee" path: plain loops over local
//! memory with no hidden communication. The four STREAM ops are
//! first-class; `zip1`/`zip2` generalize. Everything is generic over
//! the sealed [`Element`] dtypes; the scalar kernels live in
//! [`crate::stream::ops`] so darray and raw-vector STREAM engines run
//! the same loops.

use super::dense::DarrayT;
use super::Result;
use crate::element::Element;

impl<T: Element> DarrayT<T> {
    /// STREAM Copy: `self.loc = a.loc`.
    pub fn copy_from(&mut self, a: &DarrayT<T>) -> Result<()> {
        self.check_aligned(a)?;
        self.loc_mut().copy_from_slice(a.loc());
        Ok(())
    }

    /// STREAM Scale: `self.loc = q * c.loc`.
    pub fn scale_from(&mut self, c: &DarrayT<T>, q: T) -> Result<()> {
        self.check_aligned(c)?;
        let dst = self.loc_mut();
        let src = c.loc();
        crate::stream::ops::scale(dst, src, q);
        Ok(())
    }

    /// STREAM Add: `self.loc = a.loc + b.loc`.
    pub fn add_from(&mut self, a: &DarrayT<T>, b: &DarrayT<T>) -> Result<()> {
        self.check_aligned(a)?;
        self.check_aligned(b)?;
        crate::stream::ops::add(self.loc_mut(), a.loc(), b.loc());
        Ok(())
    }

    /// STREAM Triad: `self.loc = b.loc + q * c.loc`.
    pub fn triad_from(&mut self, b: &DarrayT<T>, c: &DarrayT<T>, q: T) -> Result<()> {
        self.check_aligned(b)?;
        self.check_aligned(c)?;
        crate::stream::ops::triad(self.loc_mut(), b.loc(), c.loc(), q);
        Ok(())
    }

    /// General unary owner-computes: `self.loc[i] = f(a.loc[i])`.
    pub fn zip1(&mut self, a: &DarrayT<T>, f: impl Fn(T) -> T) -> Result<()> {
        self.check_aligned(a)?;
        for (d, &s) in self.loc_mut().iter_mut().zip(a.loc()) {
            *d = f(s);
        }
        Ok(())
    }

    /// General binary owner-computes: `self.loc[i] = f(a.loc[i], b.loc[i])`.
    pub fn zip2(&mut self, a: &DarrayT<T>, b: &DarrayT<T>, f: impl Fn(T, T) -> T) -> Result<()> {
        self.check_aligned(a)?;
        self.check_aligned(b)?;
        let dst = self.loc_mut();
        for (i, d) in dst.iter_mut().enumerate() {
            *d = f(a.loc()[i], b.loc()[i]);
        }
        Ok(())
    }

    /// Local sum, widened to f64 (building block for distributed
    /// reductions).
    pub fn local_sum(&self) -> f64 {
        self.loc().iter().map(|x| x.to_f64()).sum()
    }

    /// Local max-abs-deviation from a constant — the validation
    /// primitive (§III): `max_i |loc[i] - v|`, computed in f64.
    pub fn local_max_abs_dev(&self, v: f64) -> f64 {
        self.loc()
            .iter()
            .map(|&x| (x.to_f64() - v).abs())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::darray::dense::Darray;
    use crate::dmap::Dmap;

    fn abc(np: usize, pid: usize, n: usize) -> (Darray, Darray, Darray) {
        let m = Dmap::block_1d(np);
        (
            Darray::constant(m.clone(), &[n], pid, 1.0),
            Darray::constant(m.clone(), &[n], pid, 2.0),
            Darray::constant(m, &[n], pid, 0.0),
        )
    }

    #[test]
    fn stream_ops_one_iteration_closed_form() {
        let q = std::f64::consts::SQRT_2 - 1.0;
        for pid in 0..4 {
            let (mut a, mut b, mut c) = abc(4, pid, 64);
            c.copy_from(&a).unwrap();
            b.scale_from(&c, q).unwrap();
            c.add_from(&a, &b).unwrap();
            a.triad_from(&b, &c, q).unwrap();
            // 2q + q² = 1 → A stays 1.0
            assert!(a.local_max_abs_dev(1.0) < 1e-15);
            assert!(b.local_max_abs_dev(q) < 1e-15);
            assert!(c.local_max_abs_dev(1.0 + q) < 1e-15);
        }
    }

    #[test]
    fn mismatched_maps_rejected_not_silently_wrong() {
        let a = Darray::constant(Dmap::block_1d(4), &[64], 0, 1.0);
        let mut c = Darray::zeros(Dmap::cyclic_1d(4), &[64], 0);
        assert!(c.copy_from(&a).is_err());
    }

    #[test]
    fn zip2_general_op() {
        let m = Dmap::cyclic_1d(2);
        let a = Darray::from_global_fn(m.clone(), &[9], 1, |g| g as f64);
        let b = Darray::constant(m.clone(), &[9], 1, 10.0);
        let mut c = Darray::zeros(m, &[9], 1);
        c.zip2(&a, &b, |x, y| x * y).unwrap();
        // pid 1 owns odd indices 1,3,5,7
        assert_eq!(c.loc(), &[10.0, 30.0, 50.0, 70.0]);
    }

    #[test]
    fn local_sum_over_all_pids_is_global_sum() {
        let n = 101;
        let total: f64 = (0..3)
            .map(|p| {
                Darray::from_global_fn(Dmap::block_cyclic_1d(3, 7), &[n], p, |g| g as f64)
                    .local_sum()
            })
            .sum();
        assert_eq!(total, (n * (n - 1) / 2) as f64);
    }

    #[test]
    fn f32_stream_step_stays_near_stationary() {
        let q = std::f32::consts::SQRT_2 - 1.0;
        let m = Dmap::block_1d(2);
        let mut a = DarrayT::<f32>::constant(m.clone(), &[32], 0, 1.0);
        let mut b = DarrayT::<f32>::constant(m.clone(), &[32], 0, 2.0);
        let mut c = DarrayT::<f32>::constant(m, &[32], 0, 0.0);
        c.copy_from(&a).unwrap();
        b.scale_from(&c, q).unwrap();
        c.add_from(&a, &b).unwrap();
        a.triad_from(&b, &c, q).unwrap();
        assert!(a.local_max_abs_dev(1.0) < 1e-6);
    }

    #[test]
    fn integer_ops_wrap_not_panic() {
        let m = Dmap::block_1d(1);
        let a = DarrayT::<i64>::constant(m.clone(), &[4], 0, i64::MAX);
        let b = DarrayT::<i64>::constant(m.clone(), &[4], 0, 1);
        let mut c = DarrayT::<i64>::zeros(m, &[4], 0);
        c.add_from(&a, &b).unwrap();
        assert!(c.loc().iter().all(|&x| x == i64::MIN));
    }
}
