//! Worker process spawning — the "simulated node" substrate.
//!
//! The leader re-executes its own binary with `worker` arguments and
//! `DISTARRAY_*` environment; workers rendezvous with the leader over
//! a [`crate::comm::FileTransport`] spool directory, exactly like the
//! paper's SuperCloud launch where workers rendezvous on a shared
//! filesystem.

use crate::launcher::triples::Triples;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};

/// Environment a worker reads at startup.
#[derive(Debug, Clone)]
pub struct WorkerEnv {
    pub pid: usize,
    pub np: usize,
    pub node: usize,
    pub slot: usize,
    pub ntpn: usize,
    pub spool: PathBuf,
}

impl WorkerEnv {
    /// Read the environment of the current (worker) process.
    pub fn from_env() -> Option<WorkerEnv> {
        let get = |k: &str| std::env::var(k).ok();
        Some(WorkerEnv {
            pid: get("DISTARRAY_PID")?.parse().ok()?,
            np: get("DISTARRAY_NP")?.parse().ok()?,
            node: get("DISTARRAY_NODE")?.parse().ok()?,
            slot: get("DISTARRAY_SLOT")?.parse().ok()?,
            ntpn: get("DISTARRAY_NTPN")?.parse().ok()?,
            spool: PathBuf::from(get("DISTARRAY_SPOOL")?),
        })
    }
}

/// A spawned worker process.
pub struct WorkerHandle {
    pub pid: usize,
    pub child: Child,
}

impl WorkerHandle {
    /// Wait for exit; true iff success.
    pub fn wait(mut self) -> std::io::Result<bool> {
        Ok(self.child.wait()?.success())
    }

    /// Kill the worker and reap it (kill + wait — never leaves a
    /// zombie). Killing an already-exited worker is not an error.
    pub fn kill(mut self) -> std::io::Result<()> {
        // `Child::kill` on an exited-but-unreaped child is Ok; the
        // wait below then reaps it either way.
        self.child.kill()?;
        self.child.wait()?;
        Ok(())
    }
}

/// Spawn the worker processes of a triples launch (all but PID 0,
/// which is the calling leader). `extra_args` are forwarded verbatim
/// after `worker`.
pub fn spawn_workers(
    t: &Triples,
    spool: &Path,
    extra_args: &[String],
) -> std::io::Result<Vec<WorkerHandle>> {
    let exe = std::env::current_exe()?;
    std::fs::create_dir_all(spool)?;
    let mut handles = Vec::new();
    for pid in 1..t.np() {
        let child = Command::new(&exe)
            .arg("worker")
            .args(extra_args)
            .env("DISTARRAY_PID", pid.to_string())
            .env("DISTARRAY_NP", t.np().to_string())
            .env("DISTARRAY_NODE", t.node_of(pid).to_string())
            .env("DISTARRAY_SLOT", t.slot_of(pid).to_string())
            .env("DISTARRAY_NTPN", t.ntpn.to_string())
            .env("DISTARRAY_SPOOL", spool)
            .stdout(Stdio::inherit())
            .stderr(Stdio::inherit())
            .spawn()?;
        handles.push(WorkerHandle { pid, child });
    }
    Ok(handles)
}

/// The leader's own WorkerEnv (PID 0).
pub fn leader_env(t: &Triples, spool: &Path) -> WorkerEnv {
    WorkerEnv {
        pid: 0,
        np: t.np(),
        node: 0,
        slot: 0,
        ntpn: t.ntpn,
        spool: spool.to_path_buf(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn leader_env_is_pid0() {
        let t = Triples::new(2, 3, 1);
        let e = leader_env(&t, Path::new("/tmp/spool"));
        assert_eq!(e.pid, 0);
        assert_eq!(e.np, 6);
        assert_eq!(e.node, 0);
    }

    #[test]
    fn from_env_roundtrip() {
        // Set env vars, read them back. (Serialized by test name — no
        // other test touches DISTARRAY_*.)
        std::env::set_var("DISTARRAY_PID", "3");
        std::env::set_var("DISTARRAY_NP", "8");
        std::env::set_var("DISTARRAY_NODE", "1");
        std::env::set_var("DISTARRAY_SLOT", "0");
        std::env::set_var("DISTARRAY_NTPN", "2");
        std::env::set_var("DISTARRAY_SPOOL", "/tmp/x");
        let e = WorkerEnv::from_env().unwrap();
        assert_eq!(e.pid, 3);
        assert_eq!(e.np, 8);
        assert_eq!(e.ntpn, 2);
        for k in [
            "DISTARRAY_PID",
            "DISTARRAY_NP",
            "DISTARRAY_NODE",
            "DISTARRAY_SLOT",
            "DISTARRAY_NTPN",
            "DISTARRAY_SPOOL",
        ] {
            std::env::remove_var(k);
        }
        assert!(WorkerEnv::from_env().is_none());
    }
}
