//! Triples-mode hierarchical launcher (§V):
//! `[Nnode Nppn Ntpn]` — `Nnode` nodes, `Nppn` processes per node,
//! `Ntpn` threads per process, with processes "pinned to adjacent
//! cores to minimize interprocess contention" [43].
//!
//! The SuperCloud substitution (DESIGN.md §3): "nodes" are simulated
//! by groups of real OS processes on this machine, launched by
//! [`spawn`] with `DISTARRAY_PID`/`DISTARRAY_NP` environment and a
//! shared file-messaging spool; [`pinning`] computes (and on Linux
//! applies) the adjacent-core affinity plan.

pub mod pinning;
pub mod spawn;
pub mod triples;

pub use pinning::PinPlan;
pub use spawn::{spawn_workers, WorkerEnv, WorkerHandle};
pub use triples::Triples;
