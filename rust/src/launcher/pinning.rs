//! Core pinning — §V: "each of the Nppn processes and their
//! corresponding Ntpn threads were pinned to adjacent cores to
//! minimize interprocess contention and maximize cache locality".
//!
//! [`PinPlan`] computes the adjacent-core assignment; `apply` sets the
//! affinity of the calling process on Linux via `sched_setaffinity`
//! (a no-op elsewhere, and gracefully skipped when the plan exceeds
//! the machine).

use super::triples::Triples;

/// Adjacent-core assignment for one node's processes.
#[derive(Debug, Clone)]
pub struct PinPlan {
    /// `cores[slot]` = core ids for process slot `slot` on the node.
    cores: Vec<Vec<usize>>,
}

impl PinPlan {
    /// Build the plan for one node of a triples launch: process slot
    /// `s` gets cores `[s·ntpn, (s+1)·ntpn)` — adjacent, non-overlapping.
    pub fn for_node(t: &Triples) -> PinPlan {
        let cores = (0..t.nppn)
            .map(|slot| (slot * t.ntpn..(slot + 1) * t.ntpn).collect())
            .collect();
        PinPlan { cores }
    }

    /// Core ids for process slot `slot`.
    pub fn cores_of(&self, slot: usize) -> &[usize] {
        &self.cores[slot]
    }

    /// Number of process slots in the plan.
    pub fn slots(&self) -> usize {
        self.cores.len()
    }

    /// Highest core id used (for fit checks).
    pub fn max_core(&self) -> usize {
        self.cores.iter().flatten().copied().max().unwrap_or(0)
    }

    /// Does the plan fit on a machine with `ncores` cores?
    pub fn fits(&self, ncores: usize) -> bool {
        self.max_core() < ncores
    }

    /// Apply the affinity for `slot` to the calling thread/process.
    ///
    /// Returns `true` if affinity was set. Never fails the run: if the
    /// plan exceeds the machine (simulated-node oversubscription) the
    /// pin is skipped — matching how the paper's launcher degrades on
    /// shared nodes.
    pub fn apply(&self, slot: usize) -> bool {
        let cores = self.cores_of(slot);
        apply_affinity(cores)
    }
}

/// Number of online cores on this machine.
pub fn online_cores() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Pin the calling thread to one core. Same degrade-gracefully
/// contract as [`PinPlan::apply`]: returns `false` (and pins nothing)
/// when the core exceeds the machine or the platform can't pin.
pub fn pin_to_core(core: usize) -> bool {
    apply_affinity(&[core])
}

#[cfg(target_os = "linux")]
fn apply_affinity(cores: &[usize]) -> bool {
    // Hand-rolled `cpu_set_t` (the crate is dependency-free, so no
    // libc binding): glibc's set is 1024 bits; the kernel accepts any
    // size as long as the set bits fit.
    const SET_BITS: usize = 1024;
    let ncores = online_cores();
    if cores.iter().any(|&c| c >= ncores || c >= SET_BITS) {
        return false; // oversubscribed simulated node: skip
    }
    let mut mask = [0u64; SET_BITS / 64];
    for &c in cores {
        mask[c / 64] |= 1u64 << (c % 64);
    }
    extern "C" {
        // glibc wrapper over the sched_setaffinity(2) syscall; pid 0
        // targets the calling thread.
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) == 0 }
}

#[cfg(not(target_os = "linux"))]
fn apply_affinity(_cores: &[usize]) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adjacent_non_overlapping() {
        let plan = PinPlan::for_node(&Triples::new(1, 4, 2));
        assert_eq!(plan.slots(), 4);
        assert_eq!(plan.cores_of(0), &[0, 1]);
        assert_eq!(plan.cores_of(1), &[2, 3]);
        assert_eq!(plan.cores_of(3), &[6, 7]);
        assert_eq!(plan.max_core(), 7);
        // All cores distinct.
        let mut all: Vec<usize> = (0..4).flat_map(|s| plan.cores_of(s).to_vec()).collect();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), 8);
    }

    #[test]
    fn fits_check() {
        let plan = PinPlan::for_node(&Triples::new(1, 2, 2));
        assert!(plan.fits(4));
        assert!(!plan.fits(3));
    }

    #[test]
    fn apply_within_machine_or_skip() {
        // Whatever the machine, apply must not panic and must return
        // false when the plan exceeds it.
        let big = PinPlan::for_node(&Triples::new(1, 1, 100_000));
        assert!(!big.apply(0));
        let small = PinPlan::for_node(&Triples::new(1, 1, 1));
        let _ = small.apply(0); // may be true or false by platform
    }
}
