//! The `[Nnode Nppn Ntpn]` triple and its derived quantities.

/// A triples-mode launch specification.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Triples {
    /// Nodes.
    pub nnode: usize,
    /// Processes per node.
    pub nppn: usize,
    /// Threads per process.
    pub ntpn: usize,
}

impl Triples {
    pub fn new(nnode: usize, nppn: usize, ntpn: usize) -> Self {
        assert!(nnode >= 1 && nppn >= 1 && ntpn >= 1);
        Triples { nnode, nppn, ntpn }
    }

    /// Total process count `Np = Nnode × Nppn` (§V).
    pub fn np(&self) -> usize {
        self.nnode * self.nppn
    }

    /// Total hardware threads claimed.
    pub fn total_threads(&self) -> usize {
        self.np() * self.ntpn
    }

    /// Node index hosting `pid` (processes are dealt node-major:
    /// node 0 gets pids 0..nppn, node 1 the next nppn, ...).
    pub fn node_of(&self, pid: usize) -> usize {
        assert!(pid < self.np());
        pid / self.nppn
    }

    /// Process slot of `pid` within its node.
    pub fn slot_of(&self, pid: usize) -> usize {
        assert!(pid < self.np());
        pid % self.nppn
    }

    /// Parse `"NxMxK"` or `"[N M K]"` forms.
    pub fn parse(s: &str) -> Option<Triples> {
        let cleaned = s.trim().trim_start_matches('[').trim_end_matches(']');
        let parts: Vec<&str> = cleaned
            .split(|c: char| c == 'x' || c == ',' || c.is_whitespace())
            .filter(|p| !p.is_empty())
            .collect();
        if parts.len() != 3 {
            return None;
        }
        let v: Option<Vec<usize>> = parts.iter().map(|p| p.parse().ok()).collect();
        let v = v?;
        if v.iter().any(|&x| x == 0) {
            return None;
        }
        Some(Triples::new(v[0], v[1], v[2]))
    }
}

impl std::fmt::Display for Triples {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{} {} {}]", self.nnode, self.nppn, self.ntpn)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn np_is_product_of_first_two() {
        let t = Triples::new(4, 8, 2);
        assert_eq!(t.np(), 32);
        assert_eq!(t.total_threads(), 64);
    }

    #[test]
    fn node_and_slot_assignment() {
        let t = Triples::new(2, 4, 1);
        assert_eq!(t.node_of(0), 0);
        assert_eq!(t.node_of(3), 0);
        assert_eq!(t.node_of(4), 1);
        assert_eq!(t.slot_of(5), 1);
    }

    #[test]
    fn parse_forms() {
        assert_eq!(Triples::parse("2x4x1"), Some(Triples::new(2, 4, 1)));
        assert_eq!(Triples::parse("[2 4 1]"), Some(Triples::new(2, 4, 1)));
        assert_eq!(Triples::parse("2,4,1"), Some(Triples::new(2, 4, 1)));
        assert_eq!(Triples::parse("2x4"), None);
        assert_eq!(Triples::parse("0x4x1"), None);
        assert_eq!(Triples::parse("junk"), None);
    }

    #[test]
    fn display_matches_paper_notation() {
        assert_eq!(Triples::new(1, 32, 1).to_string(), "[1 32 1]");
    }
}
