//! Figure 3 — STREAM triad bandwidth vs process count, per hardware
//! configuration × language: vertical scaling within the node and
//! horizontal scaling across nodes.
//!
//! The simulated engine generates every era's series; the native
//! engine additionally produces a **measured** series on this
//! machine (label "native-local") so the real measurement path is
//! exercised end-to-end.

use crate::hardware::{simulate_node, Era, Lang, NodeModel, ERAS};
use crate::stream::params::schedule;
use crate::stream::{aggregate, run_parallel_spmd, STREAM_Q};

/// One point of a Figure 3 series.
#[derive(Debug, Clone)]
pub struct Point {
    pub np: usize,
    /// Triad bandwidth, bytes/s.
    pub triad_bw: f64,
}

/// One panel series (an era × language curve).
#[derive(Debug, Clone)]
pub struct Series {
    pub era: String,
    pub lang: &'static str,
    /// Which execution backend produced the numbers: a real
    /// [`crate::backend::BackendKind`] name for measured series,
    /// `"model"` for the era simulations.
    pub backend: &'static str,
    pub points: Vec<Point>,
}

/// Simulate the vertical-scaling series for one era and language.
/// Uses the Table II cells (including the published bg-p override) so
/// Figure 3 and Table II stay consistent.
pub fn simulate_series(era: &'static Era, lang: Lang) -> Series {
    let cells = super::table2::rows()
        .into_iter()
        .find(|r| r.era.label == era.label)
        .map(|r| r.cells)
        .unwrap_or_else(|| schedule(era.base_log2, era.base_nt, era.mem_bytes(), era.max_np));
    let points = cells
        .iter()
        .map(|(np, params)| {
            let node = NodeModel::new(era, *np, 1);
            let agg = aggregate(&simulate_node(&node, params, lang)).unwrap();
            Point { np: *np, triad_bw: agg.triad_bw() }
        })
        .collect();
    Series { era: era.label.to_string(), lang: lang.name(), backend: "model", points }
}

/// All simulated panels of Figure 3.
pub fn simulate_all() -> Vec<Series> {
    let mut out = Vec::new();
    for era in ERAS {
        for lang in Lang::ALL {
            out.push(simulate_series(era, lang));
        }
    }
    out
}

/// Measured series on *this* machine via the native engine — real
/// data through the identical reporting path. `n_per_p` elements per
/// process, doubling process counts up to `max_np`.
pub fn measured_series(max_np: usize, n_per_p: usize, nt: usize) -> Series {
    let mut points = Vec::new();
    let mut np = 1usize;
    while np <= max_np {
        let map = crate::dmap::Dmap::block_1d(np);
        let agg = run_parallel_spmd(&map, n_per_p * np, nt, STREAM_Q);
        assert!(agg.all_valid, "measured run failed validation");
        points.push(Point { np, triad_bw: agg.triad_bw() });
        np *= 2;
    }
    Series { era: "native-local".into(), lang: "rust", backend: "host", points }
}

/// Measured series driven through an execution backend: the same
/// doubling sweep as [`measured_series`], but every process's share
/// runs on `backend` via the plan-driven scheduler — so `repro sweep
/// fig3 --measure --backend threaded` compares backends through the
/// identical reporting path.
///
/// Caveat for the threaded backend: concurrent PIDs share one gang
/// pool whose gate serializes kernel launches, so per-op times at
/// `np > 1` include gate waits and the curve flattens. Its vertical
/// scaling is the *pool width* axis — measure with `np = 1` and a
/// wider pool (`--threads`), or compare per-np numbers on the host
/// backend where PIDs are fully independent.
pub fn measured_series_on(
    backend: &std::sync::Arc<dyn crate::backend::Backend>,
    max_np: usize,
    n_per_p: usize,
    nt: usize,
) -> Result<Series, crate::backend::BackendError> {
    let mut points = Vec::new();
    let mut np = 1usize;
    while np <= max_np {
        let map = crate::dmap::Dmap::block_1d(np);
        let agg =
            crate::backend::run_stream_spmd_t::<f64>(backend, &map, n_per_p * np, nt, STREAM_Q)?;
        assert!(agg.all_valid, "measured run failed validation");
        points.push(Point { np, triad_bw: agg.triad_bw() });
        np *= 2;
    }
    Ok(Series {
        era: "native-local".into(),
        lang: "rust",
        backend: backend.kind().name(),
        points,
    })
}

/// Render a set of series as the panel grid (text form).
pub fn render(series: &[Series]) -> String {
    let mut s = String::new();
    s.push_str("FIGURE 3 — STREAM TRIAD BANDWIDTH (vertical scaling)\n");
    for sr in series {
        s.push_str(&format!("-- {} [{}] backend={} --\n", sr.era, sr.lang, sr.backend));
        for p in &sr.points {
            s.push_str(&format!(
                "  Np={:<4} triad={}\n",
                p.np,
                super::fmt_bw(p.triad_bw)
            ));
        }
    }
    s
}

/// CSV emitter (era,lang,backend,np,triad_bytes_per_s).
pub fn to_csv(series: &[Series]) -> String {
    let mut s = String::from("era,lang,backend,np,triad_bytes_per_s\n");
    for sr in series {
        for p in &sr.points {
            s.push_str(&format!(
                "{},{},{},{},{}\n",
                sr.era, sr.lang, sr.backend, p.np, p.triad_bw
            ));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_panels_generated() {
        let all = simulate_all();
        // 8 eras × 3 languages.
        assert_eq!(all.len(), 24);
        for s in &all {
            assert!(!s.points.is_empty(), "{} {}", s.era, s.lang);
        }
    }

    #[test]
    fn vertical_scaling_shape_monotone_then_flat() {
        let era = Era::by_label("xeon-p8").unwrap();
        let s = simulate_series(era, Lang::Matlab);
        // Monotone non-decreasing until saturation; final/first ratio
        // large (the paper's "excellent vertical scaling").
        let first = s.points.first().unwrap().triad_bw;
        let last = s.points.last().unwrap().triad_bw;
        assert!(last / first > 5.0, "ratio {}", last / first);
        for w in s.points.windows(2) {
            assert!(w[1].triad_bw >= w[0].triad_bw * 0.98);
        }
    }

    #[test]
    fn octave_sits_30pct_below_matlab() {
        let era = Era::by_label("xeon-g6").unwrap();
        let m = simulate_series(era, Lang::Matlab);
        let o = simulate_series(era, Lang::Octave);
        for (pm, po) in m.points.iter().zip(&o.points) {
            let ratio = po.triad_bw / pm.triad_bw;
            assert!((ratio - 0.7).abs() < 0.02, "np={} ratio={ratio}", pm.np);
        }
    }

    #[test]
    fn measured_series_runs_on_this_machine() {
        let s = measured_series(2, 1 << 16, 3);
        assert_eq!(s.points.len(), 2);
        assert_eq!(s.backend, "host");
        for p in &s.points {
            assert!(p.triad_bw > 1e8, "np={} bw={}", p.np, p.triad_bw);
        }
    }

    #[test]
    fn measured_series_on_threaded_backend() {
        let reg = crate::backend::BackendRegistry::with_defaults(2, "artifacts");
        let be = reg.get(crate::backend::BackendKind::Threaded).unwrap();
        let s = measured_series_on(be, 2, 1 << 14, 2).unwrap();
        assert_eq!(s.backend, "threaded");
        assert_eq!(s.points.len(), 2);
        for p in &s.points {
            assert!(p.triad_bw > 1e7, "np={} bw={}", p.np, p.triad_bw);
        }
    }

    #[test]
    fn csv_well_formed() {
        let s = simulate_series(Era::by_label("xeon-e5").unwrap(), Lang::Python);
        let csv = to_csv(&[s]);
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert!(lines.len() > 2);
        assert_eq!(lines[0], "era,lang,backend,np,triad_bytes_per_s");
        assert!(lines[1].starts_with("xeon-e5,python,model,1,"));
    }
}
