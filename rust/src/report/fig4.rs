//! Figure 4 — temporal scaling: best single-core, single-node, and
//! GPU-node triad bandwidth per hardware era, plus the headline
//! ratios (10× core / 100× node over 20 years, 5× GPU over ~5 years).

use crate::hardware::{simulate_stream, Era, Lang, NodeModel, ERAS};
use crate::stream::params::schedule;
use crate::stream::StreamParams;

/// One Figure 4 point.
#[derive(Debug, Clone)]
pub struct TemporalPoint {
    pub era: &'static Era,
    /// Best single-core single-thread bandwidth (bottom black line).
    pub single_core: Option<f64>,
    /// Best whole-node multi-process bandwidth (middle blue line).
    pub single_node: Option<f64>,
    /// GPU-node bandwidth (top green line).
    pub gpu_node: Option<f64>,
}

fn best_node_bw(era: &'static Era) -> f64 {
    let best = schedule(era.base_log2, era.base_nt, era.mem_bytes(), era.max_np)
        .iter()
        .map(|(np, p)| {
            let node = NodeModel::new(era, *np, 1);
            crate::stream::aggregate(&crate::hardware::simulate_node(&node, p, Lang::Matlab))
                .unwrap()
                .triad_bw()
        })
        .fold(0.0, f64::max);
    // Figure 4 plots per-*node* bandwidth; the bg-p Table I entry is a
    // 32-node partition, so normalize it back to one Blue Gene/P node.
    best / era.nodes_in_entry as f64
}

fn single_core_bw(era: &'static Era) -> f64 {
    let p = StreamParams { nt: era.base_nt, log2_local: era.base_log2.min(24) };
    simulate_stream(&NodeModel::new(era, 1, 1), &p, Lang::Matlab).triad_bw()
}

/// Compute the Figure 4 points for every era.
pub fn points() -> Vec<TemporalPoint> {
    ERAS.iter()
        .map(|era| {
            if era.is_gpu() {
                TemporalPoint {
                    era,
                    single_core: None,
                    single_node: None,
                    gpu_node: Some(best_node_bw(era)),
                }
            } else {
                TemporalPoint {
                    era,
                    single_core: Some(single_core_bw(era)),
                    single_node: Some(best_node_bw(era)),
                    gpu_node: None,
                }
            }
        })
        .collect()
}

/// The paper's three headline ratios (core20y, node20y, gpu5y).
pub fn headline_ratios() -> (f64, f64, f64) {
    let pts = points();
    let by = |label: &str| pts.iter().find(|p| p.era.label == label).unwrap().clone();
    let p4 = by("xeon-p4");
    let e9 = by("amd-e9");
    let v100 = by("v100");
    let h100 = by("h100nvl");
    (
        e9.single_core.unwrap() / p4.single_core.unwrap(),
        e9.single_node.unwrap() / p4.single_node.unwrap(),
        h100.gpu_node.unwrap() / v100.gpu_node.unwrap(),
    )
}

/// Render Figure 4 as a table + ratio summary.
pub fn render() -> String {
    let mut s = String::new();
    s.push_str("FIGURE 4 — TEMPORAL SCALING (triad bandwidth by era)\n");
    s.push_str("| Era | Node | single-core | single-node | GPU node |\n");
    s.push_str("|---|---|---|---|---|\n");
    let mut pts = points();
    pts.sort_by_key(|p| p.era.year);
    for p in &pts {
        let f = |o: &Option<f64>| o.map(super::fmt_bw).unwrap_or_else(|| "-".into());
        s.push_str(&format!(
            "| {} | {} | {} | {} | {} |\n",
            p.era.year,
            p.era.label,
            f(&p.single_core),
            f(&p.single_node),
            f(&p.gpu_node)
        ));
    }
    let (core, node, gpu) = headline_ratios();
    s.push_str(&format!(
        "\nratios: single-core 20y = {core:.1}x (paper: ~10x), \
         single-node 20y = {node:.1}x (paper: ~100x), \
         GPU node ~5y = {gpu:.1}x (paper: ~5x)\n"
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_rows_have_core_and_node_gpu_rows_have_gpu() {
        for p in points() {
            if p.era.is_gpu() {
                assert!(p.gpu_node.is_some() && p.single_core.is_none());
            } else {
                assert!(p.single_core.is_some() && p.single_node.is_some());
            }
        }
    }

    #[test]
    fn headline_ratios_match_paper_bands() {
        let (core, node, gpu) = headline_ratios();
        assert!((5.0..20.0).contains(&core), "core {core}");
        assert!((50.0..200.0).contains(&node), "node {node}");
        assert!((3.0..8.0).contains(&gpu), "gpu {gpu}");
    }

    #[test]
    fn node_bw_grows_monotonically_with_era_for_cpus() {
        let mut cpu: Vec<_> = points().into_iter().filter(|p| !p.era.is_gpu()).collect();
        cpu.sort_by_key(|p| p.era.year);
        for w in cpu.windows(2) {
            assert!(
                w[1].single_node.unwrap() >= w[0].single_node.unwrap(),
                "{} -> {}",
                w[0].era.label,
                w[1].era.label
            );
        }
    }

    #[test]
    fn render_has_ratio_line() {
        assert!(render().contains("ratios:"));
    }
}
