//! Report generators — one per paper artifact (DESIGN.md §5).
//!
//! Every generator returns both structured rows (for tests/benches)
//! and a rendered table so `repro report <id>` prints the same
//! rows/series the paper shows.

pub mod bench_diff;
pub mod bench_json;
pub mod fig3;
pub mod fig4;
pub mod petascale;
pub mod table1;
pub mod table2;

/// Human-readable bytes/s with the paper's units.
pub fn fmt_bw(bytes_per_s: f64) -> String {
    const UNITS: [(&str, f64); 5] = [
        ("PB/s", 1e15),
        ("TB/s", 1e12),
        ("GB/s", 1e9),
        ("MB/s", 1e6),
        ("kB/s", 1e3),
    ];
    for (u, f) in UNITS {
        if bytes_per_s >= f {
            return format!("{:.2} {u}", bytes_per_s / f);
        }
    }
    format!("{bytes_per_s:.0} B/s")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn units() {
        assert_eq!(fmt_bw(2.5e9), "2.50 GB/s");
        assert_eq!(fmt_bw(1.2e15), "1.20 PB/s");
        assert_eq!(fmt_bw(10.0), "10 B/s");
    }
}
