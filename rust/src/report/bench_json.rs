//! `BENCH_stream.json` / `BENCH_remap.json` / `BENCH_collective.json`
//! — the machine-readable perf trajectory.
//!
//! `repro run --bench-json <path>` emits one `bench_stream_v1`
//! document per run with per-op bandwidths (bytes/s and GB/s),
//! element throughput, and the full axis coordinates (dtype, backend,
//! engine, Nt, Np); `repro bench-remap --bench-json <path>` emits a
//! `bench_remap_v1` document (bytes moved, message counts, GB/s per
//! remap) for the coalesced data-movement hot path; `repro
//! bench-collective --bench-json <path>` emits a
//! `bench_collective_v1` document (per-algorithm × per-operation
//! latency, bytes, and message counts vs P) so the scaling behavior
//! of the collective subsystem is measured, not asserted — successive
//! PRs can diff the numbers mechanically instead of scraping stdout.

use crate::collective::{AllreduceOrder, CollKind, Collective, ReduceOp, TagSpace, Topology};
use crate::comm::datapath;
use crate::comm::{tags, ChannelHub, Transport};
use crate::coordinator::RunConfig;
use crate::darray::{DarrayT, RemapEngine};
use crate::dmap::Dmap;
use crate::element::{Dtype, Element};
use crate::json::Json;
use crate::stream::AggregateResult;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Schema tag, bumped on any field change.
pub const SCHEMA: &str = "bench_stream_v1";

/// Schema tag of the remap benchmark document.
pub const REMAP_SCHEMA: &str = "bench_remap_v1";

/// Schema tag of the collective benchmark document.
pub const COLL_SCHEMA: &str = "bench_collective_v1";

/// The four op names, in the order of [`AggregateResult::bw`].
pub const OP_NAMES: [&str; 4] = ["copy", "scale", "add", "triad"];

/// Build the benchmark document from a run's config + aggregate.
pub fn to_json(cfg: &RunConfig, agg: &AggregateResult) -> Json {
    let eps = agg.elements_per_sec();
    let mut ops = BTreeMap::new();
    for (i, name) in OP_NAMES.iter().enumerate() {
        let bw = agg.bw[i];
        let mut m = BTreeMap::new();
        m.insert("bytes_per_sec".to_string(), Json::Num(bw));
        m.insert("gb_per_sec".to_string(), Json::Num(bw / 1e9));
        m.insert("elements_per_sec".to_string(), Json::Num(eps[i]));
        ops.insert((*name).to_string(), Json::Obj(m));
    }
    let mut top = BTreeMap::new();
    top.insert("schema".to_string(), Json::Str(SCHEMA.to_string()));
    top.insert("engine".to_string(), Json::Str(cfg.engine.name().to_string()));
    top.insert("backend".to_string(), Json::Str(agg.backend.name().to_string()));
    top.insert("dtype".to_string(), Json::Str(cfg.dtype.name().to_string()));
    top.insert("width".to_string(), Json::Num(agg.width as f64));
    top.insert("n".to_string(), Json::Num(agg.n_global as f64));
    top.insert("nt".to_string(), Json::Num(agg.nt as f64));
    top.insert("np".to_string(), Json::Num(agg.np as f64));
    top.insert("threads".to_string(), Json::Num(cfg.threads as f64));
    top.insert("validated".to_string(), Json::Bool(agg.all_valid));
    top.insert("worst_err".to_string(), Json::Num(agg.worst_err));
    top.insert("ops".to_string(), Json::Obj(ops));
    Json::Obj(top)
}

/// Emit the document to `path` (newline-terminated).
pub fn write_file(path: &str, cfg: &RunConfig, agg: &AggregateResult) -> std::io::Result<()> {
    std::fs::write(path, format!("{}\n", to_json(cfg, agg)))
}

/// One measured remap benchmark: iterated block→cyclic global
/// assignment through a cached plan over the in-process transport —
/// the worst-case (fully strided) data-movement pattern the per-peer
/// coalescing exists for.
#[derive(Debug, Clone)]
pub struct RemapBench {
    pub np: usize,
    pub n_global: usize,
    pub dtype: Dtype,
    pub iters: usize,
    /// Total messages sent (all PIDs, all timed iterations). With
    /// coalescing this is `iters × Σ_pid distinct peers`, independent
    /// of plan-step count.
    pub messages: u64,
    /// Total wire bytes sent (framing + payload).
    pub bytes_moved: u64,
    /// Element payload bytes only (crossing elements × width × iters).
    pub payload_bytes: u64,
    /// Wall time of the timed iterations (max across PIDs).
    pub seconds: f64,
    /// Global [`BufferPool`](crate::comm::BufferPool) checkouts
    /// during the timed iterations (warm-up excluded).
    pub pool_checkouts: u64,
    /// Checkouts served by a reused allocation. Equal to
    /// [`RemapBench::pool_checkouts`] in steady state — the
    /// zero-allocation proof.
    pub pool_hits: u64,
}

impl RemapBench {
    pub fn gb_per_sec(&self) -> f64 {
        if self.seconds > 0.0 {
            self.bytes_moved as f64 / self.seconds / 1e9
        } else {
            0.0
        }
    }
}

/// Build the `bench_remap_v1` document.
pub fn remap_to_json(b: &RemapBench) -> Json {
    let mut top = BTreeMap::new();
    top.insert("schema".to_string(), Json::Str(REMAP_SCHEMA.to_string()));
    top.insert("np".to_string(), Json::Num(b.np as f64));
    top.insert("n".to_string(), Json::Num(b.n_global as f64));
    top.insert("dtype".to_string(), Json::Str(b.dtype.name().to_string()));
    top.insert("iters".to_string(), Json::Num(b.iters as f64));
    top.insert("messages".to_string(), Json::Num(b.messages as f64));
    top.insert(
        "messages_per_remap".to_string(),
        Json::Num(if b.iters > 0 { b.messages as f64 / b.iters as f64 } else { 0.0 }),
    );
    top.insert("bytes_moved".to_string(), Json::Num(b.bytes_moved as f64));
    top.insert("payload_bytes".to_string(), Json::Num(b.payload_bytes as f64));
    top.insert("seconds".to_string(), Json::Num(b.seconds));
    top.insert("gb_per_sec".to_string(), Json::Num(b.gb_per_sec()));
    top.insert("pool_checkouts".to_string(), Json::Num(b.pool_checkouts as f64));
    top.insert("pool_hits".to_string(), Json::Num(b.pool_hits as f64));
    Json::Obj(top)
}

/// Emit the remap document to `path` (newline-terminated).
pub fn write_remap_file(path: &str, b: &RemapBench) -> std::io::Result<()> {
    std::fs::write(path, format!("{}\n", remap_to_json(b)))
}

/// Run the remap benchmark: `np` in-process SPMD PIDs, `iters` timed
/// block→cyclic remaps of an `n_global`-element array at `dtype`
/// (plus one untimed warm-up that builds the plan and the pooled wire
/// buffers).
pub fn run_remap(np: usize, n_global: usize, iters: usize, dtype: Dtype) -> RemapBench {
    match dtype {
        Dtype::F32 => run_remap_t::<f32>(np, n_global, iters),
        Dtype::F64 => run_remap_t::<f64>(np, n_global, iters),
        Dtype::I64 => run_remap_t::<i64>(np, n_global, iters),
        Dtype::U64 => run_remap_t::<u64>(np, n_global, iters),
    }
}

fn run_remap_t<T: Element>(np: usize, n_global: usize, iters: usize) -> RemapBench {
    assert!(np >= 1 && n_global >= 1);
    let engine = Arc::new(RemapEngine::new());
    let world = ChannelHub::world(np);
    // Two rendezvous with the measuring parent: after warm-up (so the
    // pool counter baseline excludes the populating allocations) and
    // before the timed loop.
    let gate = Arc::new(std::sync::Barrier::new(np + 1));
    let mut hs = Vec::new();
    for t in world {
        let engine = engine.clone();
        let gate = gate.clone();
        hs.push(std::thread::spawn(move || {
            let pid = t.pid();
            let src = DarrayT::<T>::from_global_fn(Dmap::block_1d(np), &[n_global], pid, |g| {
                T::from_f64((g % 1024) as f64)
            });
            let mut dst = DarrayT::<T>::zeros(Dmap::cyclic_1d(np), &[n_global], pid);
            // Warm-up: plans once, populates the buffer pool.
            dst.assign_from_engine(&src, &t, 0, &engine).unwrap();
            t.stats().reset();
            gate.wait();
            gate.wait();
            let start = Instant::now();
            for epoch in 1..=iters as u64 {
                dst.assign_from_engine(&src, &t, epoch, &engine).unwrap();
            }
            let secs = start.elapsed().as_secs_f64();
            let (msgs, bytes, _, _) = t.stats().snapshot();
            (secs, msgs, bytes)
        }));
    }
    gate.wait();
    let (c0, h0) = datapath::pool_counters();
    gate.wait();
    let mut seconds = 0f64;
    let mut messages = 0u64;
    let mut bytes_moved = 0u64;
    for h in hs {
        let (s, m, b) = h.join().unwrap();
        seconds = seconds.max(s);
        messages += m;
        bytes_moved += b;
    }
    let (c1, h1) = datapath::pool_counters();
    let plan = engine.plan(&Dmap::block_1d(np), &Dmap::cyclic_1d(np), &[n_global]);
    let crossing: usize = plan
        .transfers()
        .iter()
        .filter(|(s, d, _)| s != d)
        .map(|(_, _, r)| r.len())
        .sum();
    RemapBench {
        np,
        n_global,
        dtype: T::DTYPE,
        iters,
        messages,
        bytes_moved,
        payload_bytes: (crossing * T::WIDTH * iters) as u64,
        seconds,
        pool_checkouts: c1 - c0,
        pool_hits: h1 - h0,
    }
}

/// The measured collective operations, in run order.
/// `allreduce_vec` is the long-vector shape: a whole `payload_bytes`
/// f64 vector reduced under [`AllreduceOrder::Fast`], so an `auto`
/// context above the elimination threshold exercises the
/// reduce-scatter + allgather schedule.
pub const COLL_OPS: [&str; 6] =
    ["bcast", "allreduce", "allreduce_vec", "gather", "allgather", "barrier"];

/// One measured collective data point: `(algorithm, operation, P)` →
/// latency, messages, wire bytes.
#[derive(Debug, Clone)]
pub struct CollBench {
    pub coll: CollKind,
    pub op: &'static str,
    pub np: usize,
    /// Node-group count of the topology the run used.
    pub nodes: usize,
    /// Broadcast payload size; gathers contribute `payload/np` per PID.
    pub payload_bytes: usize,
    pub iters: usize,
    /// Total messages sent (all PIDs, timed iterations only).
    pub messages: u64,
    /// Total wire bytes sent (framing + payload).
    pub bytes_moved: u64,
    /// Wall time of the timed iterations (max across PIDs).
    pub seconds: f64,
}

impl CollBench {
    /// Mean wall time of one collective call, in microseconds.
    pub fn avg_latency_us(&self) -> f64 {
        if self.iters > 0 {
            self.seconds / self.iters as f64 * 1e6
        } else {
            0.0
        }
    }

    /// Mean messages per collective call.
    pub fn msgs_per_op(&self) -> f64 {
        if self.iters > 0 {
            self.messages as f64 / self.iters as f64
        } else {
            0.0
        }
    }
}

/// Run one collective call so benchmarks and smoke tests share the
/// exact call shapes.
fn coll_once(
    coll: &Collective,
    t: &dyn Transport,
    op: &str,
    epoch: u64,
    payload_bytes: usize,
    timeout: Duration,
) {
    let space = TagSpace::packed(tags::NS_COLL, epoch);
    let part_len = (payload_bytes / t.np()).max(1);
    match op {
        "bcast" => {
            let payload = if t.pid() == 0 { vec![7u8; payload_bytes] } else { Vec::new() };
            coll.bcast(t, space, payload).unwrap();
        }
        "allreduce" => {
            coll.allreduce_scalar(t, space, t.pid() as f64 + 0.5, ReduceOp::Sum).unwrap();
        }
        "allreduce_vec" => {
            let n = (payload_bytes / 8).max(1);
            let local: Vec<f64> = vec![t.pid() as f64 * 0.5 + 1.0; n];
            coll.allreduce_ordered(t, space, &local, ReduceOp::Sum, AllreduceOrder::Fast)
                .unwrap();
        }
        "gather" => {
            coll.gather(t, space, vec![t.pid() as u8; part_len]).unwrap();
        }
        "allgather" => {
            coll.allgather(t, space, vec![t.pid() as u8; part_len]).unwrap();
        }
        "barrier" => coll.barrier(t, space, timeout).unwrap(),
        other => unreachable!("unknown collective op {other}"),
    }
}

/// Measure every op of every requested algorithm at world size `np`
/// over the in-process transport (one warm-up + `iters` timed calls
/// per op; messages and bytes from [`crate::comm::CommStats`]
/// deltas).
pub fn run_collective(
    np: usize,
    nppn: usize,
    kinds: &[CollKind],
    payload_bytes: usize,
    iters: usize,
) -> Vec<CollBench> {
    assert!(np >= 1 && iters >= 1);
    let mut out = Vec::new();
    for &kind in kinds {
        let coll = Arc::new(Collective::new(kind, Topology::grouped(np, nppn)));
        let world = ChannelHub::world(np);
        let mut hs = Vec::new();
        for t in world {
            let coll = coll.clone();
            hs.push(std::thread::spawn(move || {
                let timeout = Duration::from_secs(60);
                let mut epoch = 0u64;
                let mut per_op = Vec::with_capacity(COLL_OPS.len());
                for op in COLL_OPS {
                    coll_once(&coll, &t, op, epoch, payload_bytes, timeout);
                    epoch += 1;
                    let (m0, b0, _, _) = t.stats().snapshot();
                    let start = Instant::now();
                    for _ in 0..iters {
                        coll_once(&coll, &t, op, epoch, payload_bytes, timeout);
                        epoch += 1;
                    }
                    let secs = start.elapsed().as_secs_f64();
                    let (m1, b1, _, _) = t.stats().snapshot();
                    per_op.push((secs, m1 - m0, b1 - b0));
                }
                per_op
            }));
        }
        let mut totals = vec![(0.0f64, 0u64, 0u64); COLL_OPS.len()];
        for h in hs {
            for (i, (s, m, b)) in h.join().unwrap().into_iter().enumerate() {
                totals[i].0 = totals[i].0.max(s);
                totals[i].1 += m;
                totals[i].2 += b;
            }
        }
        for (i, op) in COLL_OPS.into_iter().enumerate() {
            out.push(CollBench {
                coll: coll.kind(),
                op,
                np,
                nodes: coll.topology().node_count(),
                payload_bytes,
                iters,
                messages: totals[i].1,
                bytes_moved: totals[i].2,
                seconds: totals[i].0,
            });
        }
    }
    out
}

/// Build the `bench_collective_v1` document from a set of runs
/// (typically one [`run_collective`] call per P).
pub fn collective_to_json(records: &[CollBench]) -> Json {
    let runs = records
        .iter()
        .map(|b| {
            let mut m = BTreeMap::new();
            m.insert("coll".to_string(), Json::Str(b.coll.name().to_string()));
            m.insert("op".to_string(), Json::Str(b.op.to_string()));
            m.insert("np".to_string(), Json::Num(b.np as f64));
            m.insert("nodes".to_string(), Json::Num(b.nodes as f64));
            m.insert("payload_bytes".to_string(), Json::Num(b.payload_bytes as f64));
            m.insert("iters".to_string(), Json::Num(b.iters as f64));
            m.insert("messages".to_string(), Json::Num(b.messages as f64));
            m.insert("msgs_per_op".to_string(), Json::Num(b.msgs_per_op()));
            m.insert("bytes_moved".to_string(), Json::Num(b.bytes_moved as f64));
            m.insert("seconds".to_string(), Json::Num(b.seconds));
            m.insert("avg_latency_us".to_string(), Json::Num(b.avg_latency_us()));
            Json::Obj(m)
        })
        .collect();
    let mut top = BTreeMap::new();
    top.insert("schema".to_string(), Json::Str(COLL_SCHEMA.to_string()));
    // Process-cumulative datapath pool counters at document build —
    // for a dedicated `repro bench-collective` process this is the
    // bench's own pool traffic (hits ≈ checkouts ⇒ steady-state
    // sends allocated nothing).
    let (pc, ph) = datapath::pool_counters();
    top.insert("pool_checkouts".to_string(), Json::Num(pc as f64));
    top.insert("pool_hits".to_string(), Json::Num(ph as f64));
    top.insert("runs".to_string(), Json::Arr(runs));
    Json::Obj(top)
}

/// Emit the collective document to `path` (newline-terminated).
pub fn write_collective_file(path: &str, records: &[CollBench]) -> std::io::Result<()> {
    std::fs::write(path, format!("{}\n", collective_to_json(records)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::BackendKind;
    use crate::coordinator::{EngineKind, MapKind};
    use crate::element::Dtype;

    fn sample() -> (RunConfig, AggregateResult) {
        let cfg = RunConfig {
            n_global: 1 << 16,
            nt: 5,
            q: crate::stream::STREAM_Q,
            map: MapKind::Block,
            engine: EngineKind::Native,
            dtype: Dtype::F32,
            backend: BackendKind::Threaded,
            threads: 4,
            coll: crate::collective::CollKind::Star,
            nppn: 0,
            chunk_bytes: 0,
            artifacts: "artifacts".into(),
        };
        let agg = AggregateResult {
            np: 2,
            n_global: 1 << 16,
            nt: 5,
            width: 4,
            backend: BackendKind::Threaded,
            bw: [4e9, 4e9, 6e9, 6e9],
            all_valid: true,
            worst_err: 1e-7,
        };
        (cfg, agg)
    }

    #[test]
    fn document_roundtrips_and_carries_every_axis() {
        let (cfg, agg) = sample();
        let doc = to_json(&cfg, &agg);
        let parsed = Json::parse(&doc.to_string()).expect("emitted json parses");
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some(SCHEMA));
        assert_eq!(parsed.get("backend").unwrap().as_str(), Some("threaded"));
        assert_eq!(parsed.get("dtype").unwrap().as_str(), Some("f32"));
        assert_eq!(parsed.get("nt").unwrap().as_usize(), Some(5));
        assert_eq!(parsed.get("np").unwrap().as_usize(), Some(2));
        assert_eq!(parsed.get("validated").unwrap().as_bool(), Some(true));
        for op in OP_NAMES {
            let o = parsed.get("ops").unwrap().get(op).unwrap();
            assert!(o.get("bytes_per_sec").unwrap().as_f64().unwrap() > 0.0);
            assert!(o.get("gb_per_sec").unwrap().as_f64().unwrap() > 0.0);
            assert!(o.get("elements_per_sec").unwrap().as_f64().unwrap() > 0.0);
        }
    }

    #[test]
    fn elements_per_sec_follows_the_width_formulas() {
        let (cfg, agg) = sample();
        let doc = to_json(&cfg, &agg);
        // Triad at 6e9 B/s, 3 vectors × 4 B/elem → 5e8 elem/s.
        let triad = doc.get("ops").unwrap().get("triad").unwrap();
        let eps = triad.get("elements_per_sec").unwrap().as_f64().unwrap();
        assert!((eps - 5e8).abs() < 1e-3);
        // Copy at 4e9 B/s, 2 vectors × 4 B/elem → 5e8 elem/s too.
        let copy = doc.get("ops").unwrap().get("copy").unwrap();
        let eps = copy.get("elements_per_sec").unwrap().as_f64().unwrap();
        assert!((eps - 5e8).abs() < 1e-3);
    }

    #[test]
    fn remap_bench_runs_and_documents() {
        // Small but strided: block→cyclic on np=3 — every PID talks to
        // both peers, so sends per timed remap = 3 × 2 = 6.
        let b = run_remap(3, 96, 2, Dtype::F32);
        assert_eq!(b.messages, 2 * 6, "one send per peer per remap");
        // 2/3 of elements cross PIDs, 4 bytes each, 2 iterations.
        assert_eq!(b.payload_bytes, 64 * 4 * 2);
        assert!(b.bytes_moved >= b.payload_bytes, "wire bytes include framing");
        assert!(b.seconds >= 0.0 && b.gb_per_sec() >= 0.0);
        let doc = remap_to_json(&b);
        let parsed = Json::parse(&doc.to_string()).expect("emitted json parses");
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some(REMAP_SCHEMA));
        assert_eq!(parsed.get("dtype").unwrap().as_str(), Some("f32"));
        assert_eq!(parsed.get("messages_per_remap").unwrap().as_usize(), Some(6));
        assert!(parsed.get("gb_per_sec").unwrap().as_f64().is_some());
        // The pool instruments ride along (the strict 100%-hit-rate
        // assertion lives in rust/tests/datapath_stream.rs, where the
        // process's pool traffic is controlled).
        let pc = parsed.get("pool_checkouts").unwrap().as_usize();
        assert_eq!(pc, Some(b.pool_checkouts as usize));
        assert_eq!(parsed.get("pool_hits").unwrap().as_usize(), Some(b.pool_hits as usize));
        assert!(b.pool_hits <= b.pool_checkouts);
        assert!(b.pool_checkouts > 0, "timed sends check buffers out of the pool");
    }

    #[test]
    fn collective_bench_runs_and_documents() {
        let recs = run_collective(3, 2, &[CollKind::Star, CollKind::Tree], 256, 2);
        assert_eq!(recs.len(), 2 * COLL_OPS.len());
        // Message models at P=3: star bcast sends P−1 per call; the
        // binomial tree also sends P−1 (fewer serial hops, not fewer
        // messages); a star allreduce is a gather + a bcast.
        let find = |k: CollKind, op: &str| {
            recs.iter().find(|r| r.coll == k && r.op == op).expect("record present")
        };
        assert_eq!(find(CollKind::Star, "bcast").msgs_per_op(), 2.0);
        assert_eq!(find(CollKind::Tree, "bcast").msgs_per_op(), 2.0);
        assert_eq!(find(CollKind::Star, "allreduce").msgs_per_op(), 4.0);
        for r in &recs {
            assert!(r.seconds >= 0.0 && r.messages > 0, "{}/{}", r.coll, r.op);
            assert_eq!(r.np, 3);
            assert_eq!(r.nodes, 2);
        }
        let doc = collective_to_json(&recs);
        let parsed = Json::parse(&doc.to_string()).expect("emitted json parses");
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some(COLL_SCHEMA));
        let runs = parsed.get("runs").unwrap().items().expect("runs is an array");
        assert_eq!(runs.len(), recs.len());
        assert_eq!(runs[0].get("coll").unwrap().as_str(), Some("star"));
        assert_eq!(runs[0].get("op").unwrap().as_str(), Some("bcast"));
        assert!(runs[0].get("avg_latency_us").unwrap().as_f64().is_some());
        assert!(parsed.get("pool_checkouts").unwrap().as_usize().is_some());
        assert!(parsed.get("pool_hits").unwrap().as_usize().is_some());
    }

    #[test]
    fn write_collective_file_emits_parseable_json() {
        let recs = run_collective(2, 0, &[CollKind::Hier], 64, 1);
        let path = std::env::temp_dir()
            .join(format!("bench_collective_test_{}.json", std::process::id()));
        let path_s = path.to_str().unwrap();
        write_collective_file(path_s, &recs).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.ends_with('\n'));
        assert!(Json::parse(text.trim()).is_ok());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn write_remap_file_emits_parseable_json() {
        let b = run_remap(2, 32, 1, Dtype::F64);
        let path =
            std::env::temp_dir().join(format!("bench_remap_test_{}.json", std::process::id()));
        let path_s = path.to_str().unwrap();
        write_remap_file(path_s, &b).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.ends_with('\n'));
        assert!(Json::parse(text.trim()).is_ok());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn write_file_emits_parseable_json() {
        let (cfg, agg) = sample();
        let path = std::env::temp_dir().join(format!("bench_stream_test_{}.json", std::process::id()));
        let path_s = path.to_str().unwrap();
        write_file(path_s, &cfg, &agg).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.ends_with('\n'));
        assert!(Json::parse(text.trim()).is_ok());
        std::fs::remove_file(&path).ok();
    }
}
