//! `BENCH_stream.json` — the machine-readable perf trajectory.
//!
//! `repro run --bench-json <path>` emits one JSON document per run
//! with per-op bandwidths (bytes/s and GB/s), element throughput,
//! and the full axis coordinates (dtype, backend, engine, Nt, Np) —
//! so successive PRs can diff bandwidth numbers mechanically instead
//! of scraping stdout.

use crate::coordinator::RunConfig;
use crate::json::Json;
use crate::stream::AggregateResult;
use std::collections::BTreeMap;

/// Schema tag, bumped on any field change.
pub const SCHEMA: &str = "bench_stream_v1";

/// The four op names, in the order of [`AggregateResult::bw`].
pub const OP_NAMES: [&str; 4] = ["copy", "scale", "add", "triad"];

/// Build the benchmark document from a run's config + aggregate.
pub fn to_json(cfg: &RunConfig, agg: &AggregateResult) -> Json {
    let eps = agg.elements_per_sec();
    let mut ops = BTreeMap::new();
    for (i, name) in OP_NAMES.iter().enumerate() {
        let bw = agg.bw[i];
        let mut m = BTreeMap::new();
        m.insert("bytes_per_sec".to_string(), Json::Num(bw));
        m.insert("gb_per_sec".to_string(), Json::Num(bw / 1e9));
        m.insert("elements_per_sec".to_string(), Json::Num(eps[i]));
        ops.insert((*name).to_string(), Json::Obj(m));
    }
    let mut top = BTreeMap::new();
    top.insert("schema".to_string(), Json::Str(SCHEMA.to_string()));
    top.insert("engine".to_string(), Json::Str(cfg.engine.name().to_string()));
    top.insert("backend".to_string(), Json::Str(agg.backend.name().to_string()));
    top.insert("dtype".to_string(), Json::Str(cfg.dtype.name().to_string()));
    top.insert("width".to_string(), Json::Num(agg.width as f64));
    top.insert("n".to_string(), Json::Num(agg.n_global as f64));
    top.insert("nt".to_string(), Json::Num(agg.nt as f64));
    top.insert("np".to_string(), Json::Num(agg.np as f64));
    top.insert("threads".to_string(), Json::Num(cfg.threads as f64));
    top.insert("validated".to_string(), Json::Bool(agg.all_valid));
    top.insert("worst_err".to_string(), Json::Num(agg.worst_err));
    top.insert("ops".to_string(), Json::Obj(ops));
    Json::Obj(top)
}

/// Emit the document to `path` (newline-terminated).
pub fn write_file(path: &str, cfg: &RunConfig, agg: &AggregateResult) -> std::io::Result<()> {
    std::fs::write(path, format!("{}\n", to_json(cfg, agg)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::BackendKind;
    use crate::coordinator::{EngineKind, MapKind};
    use crate::element::Dtype;

    fn sample() -> (RunConfig, AggregateResult) {
        let cfg = RunConfig {
            n_global: 1 << 16,
            nt: 5,
            q: crate::stream::STREAM_Q,
            map: MapKind::Block,
            engine: EngineKind::Native,
            dtype: Dtype::F32,
            backend: BackendKind::Threaded,
            threads: 4,
            artifacts: "artifacts".into(),
        };
        let agg = AggregateResult {
            np: 2,
            n_global: 1 << 16,
            nt: 5,
            width: 4,
            backend: BackendKind::Threaded,
            bw: [4e9, 4e9, 6e9, 6e9],
            all_valid: true,
            worst_err: 1e-7,
        };
        (cfg, agg)
    }

    #[test]
    fn document_roundtrips_and_carries_every_axis() {
        let (cfg, agg) = sample();
        let doc = to_json(&cfg, &agg);
        let parsed = Json::parse(&doc.to_string()).expect("emitted json parses");
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some(SCHEMA));
        assert_eq!(parsed.get("backend").unwrap().as_str(), Some("threaded"));
        assert_eq!(parsed.get("dtype").unwrap().as_str(), Some("f32"));
        assert_eq!(parsed.get("nt").unwrap().as_usize(), Some(5));
        assert_eq!(parsed.get("np").unwrap().as_usize(), Some(2));
        assert_eq!(parsed.get("validated").unwrap().as_bool(), Some(true));
        for op in OP_NAMES {
            let o = parsed.get("ops").unwrap().get(op).unwrap();
            assert!(o.get("bytes_per_sec").unwrap().as_f64().unwrap() > 0.0);
            assert!(o.get("gb_per_sec").unwrap().as_f64().unwrap() > 0.0);
            assert!(o.get("elements_per_sec").unwrap().as_f64().unwrap() > 0.0);
        }
    }

    #[test]
    fn elements_per_sec_follows_the_width_formulas() {
        let (cfg, agg) = sample();
        let doc = to_json(&cfg, &agg);
        // Triad at 6e9 B/s, 3 vectors × 4 B/elem → 5e8 elem/s.
        let triad = doc.get("ops").unwrap().get("triad").unwrap();
        let eps = triad.get("elements_per_sec").unwrap().as_f64().unwrap();
        assert!((eps - 5e8).abs() < 1e-3);
        // Copy at 4e9 B/s, 2 vectors × 4 B/elem → 5e8 elem/s too.
        let copy = doc.get("ops").unwrap().get("copy").unwrap();
        let eps = copy.get("elements_per_sec").unwrap().as_f64().unwrap();
        assert!((eps - 5e8).abs() < 1e-3);
    }

    #[test]
    fn write_file_emits_parseable_json() {
        let (cfg, agg) = sample();
        let path = std::env::temp_dir().join(format!("bench_stream_test_{}.json", std::process::id()));
        let path_s = path.to_str().unwrap();
        write_file(path_s, &cfg, &agg).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.ends_with('\n'));
        assert!(Json::parse(text.trim()).is_ok());
        std::fs::remove_file(&path).ok();
    }
}
