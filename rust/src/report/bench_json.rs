//! `BENCH_stream.json` / `BENCH_remap.json` — the machine-readable
//! perf trajectory.
//!
//! `repro run --bench-json <path>` emits one `bench_stream_v1`
//! document per run with per-op bandwidths (bytes/s and GB/s),
//! element throughput, and the full axis coordinates (dtype, backend,
//! engine, Nt, Np); `repro bench-remap --bench-json <path>` emits a
//! `bench_remap_v1` document (bytes moved, message counts, GB/s per
//! remap) for the coalesced data-movement hot path — so successive
//! PRs can diff bandwidth numbers mechanically instead of scraping
//! stdout.

use crate::comm::{ChannelHub, Transport};
use crate::coordinator::RunConfig;
use crate::darray::{DarrayT, RemapEngine};
use crate::dmap::Dmap;
use crate::element::{Dtype, Element};
use crate::json::Json;
use crate::stream::AggregateResult;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

/// Schema tag, bumped on any field change.
pub const SCHEMA: &str = "bench_stream_v1";

/// Schema tag of the remap benchmark document.
pub const REMAP_SCHEMA: &str = "bench_remap_v1";

/// The four op names, in the order of [`AggregateResult::bw`].
pub const OP_NAMES: [&str; 4] = ["copy", "scale", "add", "triad"];

/// Build the benchmark document from a run's config + aggregate.
pub fn to_json(cfg: &RunConfig, agg: &AggregateResult) -> Json {
    let eps = agg.elements_per_sec();
    let mut ops = BTreeMap::new();
    for (i, name) in OP_NAMES.iter().enumerate() {
        let bw = agg.bw[i];
        let mut m = BTreeMap::new();
        m.insert("bytes_per_sec".to_string(), Json::Num(bw));
        m.insert("gb_per_sec".to_string(), Json::Num(bw / 1e9));
        m.insert("elements_per_sec".to_string(), Json::Num(eps[i]));
        ops.insert((*name).to_string(), Json::Obj(m));
    }
    let mut top = BTreeMap::new();
    top.insert("schema".to_string(), Json::Str(SCHEMA.to_string()));
    top.insert("engine".to_string(), Json::Str(cfg.engine.name().to_string()));
    top.insert("backend".to_string(), Json::Str(agg.backend.name().to_string()));
    top.insert("dtype".to_string(), Json::Str(cfg.dtype.name().to_string()));
    top.insert("width".to_string(), Json::Num(agg.width as f64));
    top.insert("n".to_string(), Json::Num(agg.n_global as f64));
    top.insert("nt".to_string(), Json::Num(agg.nt as f64));
    top.insert("np".to_string(), Json::Num(agg.np as f64));
    top.insert("threads".to_string(), Json::Num(cfg.threads as f64));
    top.insert("validated".to_string(), Json::Bool(agg.all_valid));
    top.insert("worst_err".to_string(), Json::Num(agg.worst_err));
    top.insert("ops".to_string(), Json::Obj(ops));
    Json::Obj(top)
}

/// Emit the document to `path` (newline-terminated).
pub fn write_file(path: &str, cfg: &RunConfig, agg: &AggregateResult) -> std::io::Result<()> {
    std::fs::write(path, format!("{}\n", to_json(cfg, agg)))
}

/// One measured remap benchmark: iterated block→cyclic global
/// assignment through a cached plan over the in-process transport —
/// the worst-case (fully strided) data-movement pattern the per-peer
/// coalescing exists for.
#[derive(Debug, Clone)]
pub struct RemapBench {
    pub np: usize,
    pub n_global: usize,
    pub dtype: Dtype,
    pub iters: usize,
    /// Total messages sent (all PIDs, all timed iterations). With
    /// coalescing this is `iters × Σ_pid distinct peers`, independent
    /// of plan-step count.
    pub messages: u64,
    /// Total wire bytes sent (framing + payload).
    pub bytes_moved: u64,
    /// Element payload bytes only (crossing elements × width × iters).
    pub payload_bytes: u64,
    /// Wall time of the timed iterations (max across PIDs).
    pub seconds: f64,
}

impl RemapBench {
    pub fn gb_per_sec(&self) -> f64 {
        if self.seconds > 0.0 {
            self.bytes_moved as f64 / self.seconds / 1e9
        } else {
            0.0
        }
    }
}

/// Build the `bench_remap_v1` document.
pub fn remap_to_json(b: &RemapBench) -> Json {
    let mut top = BTreeMap::new();
    top.insert("schema".to_string(), Json::Str(REMAP_SCHEMA.to_string()));
    top.insert("np".to_string(), Json::Num(b.np as f64));
    top.insert("n".to_string(), Json::Num(b.n_global as f64));
    top.insert("dtype".to_string(), Json::Str(b.dtype.name().to_string()));
    top.insert("iters".to_string(), Json::Num(b.iters as f64));
    top.insert("messages".to_string(), Json::Num(b.messages as f64));
    top.insert(
        "messages_per_remap".to_string(),
        Json::Num(if b.iters > 0 { b.messages as f64 / b.iters as f64 } else { 0.0 }),
    );
    top.insert("bytes_moved".to_string(), Json::Num(b.bytes_moved as f64));
    top.insert("payload_bytes".to_string(), Json::Num(b.payload_bytes as f64));
    top.insert("seconds".to_string(), Json::Num(b.seconds));
    top.insert("gb_per_sec".to_string(), Json::Num(b.gb_per_sec()));
    Json::Obj(top)
}

/// Emit the remap document to `path` (newline-terminated).
pub fn write_remap_file(path: &str, b: &RemapBench) -> std::io::Result<()> {
    std::fs::write(path, format!("{}\n", remap_to_json(b)))
}

/// Run the remap benchmark: `np` in-process SPMD PIDs, `iters` timed
/// block→cyclic remaps of an `n_global`-element array at `dtype`
/// (plus one untimed warm-up that builds the plan and the pooled wire
/// buffers).
pub fn run_remap(np: usize, n_global: usize, iters: usize, dtype: Dtype) -> RemapBench {
    match dtype {
        Dtype::F32 => run_remap_t::<f32>(np, n_global, iters),
        Dtype::F64 => run_remap_t::<f64>(np, n_global, iters),
        Dtype::I64 => run_remap_t::<i64>(np, n_global, iters),
        Dtype::U64 => run_remap_t::<u64>(np, n_global, iters),
    }
}

fn run_remap_t<T: Element>(np: usize, n_global: usize, iters: usize) -> RemapBench {
    assert!(np >= 1 && n_global >= 1);
    let engine = Arc::new(RemapEngine::new());
    let world = ChannelHub::world(np);
    let mut hs = Vec::new();
    for t in world {
        let engine = engine.clone();
        hs.push(std::thread::spawn(move || {
            let pid = t.pid();
            let src = DarrayT::<T>::from_global_fn(Dmap::block_1d(np), &[n_global], pid, |g| {
                T::from_f64((g % 1024) as f64)
            });
            let mut dst = DarrayT::<T>::zeros(Dmap::cyclic_1d(np), &[n_global], pid);
            // Warm-up: plans once, populates the buffer pool.
            dst.assign_from_engine(&src, &t, 0, &engine).unwrap();
            t.stats().reset();
            let start = Instant::now();
            for epoch in 1..=iters as u64 {
                dst.assign_from_engine(&src, &t, epoch, &engine).unwrap();
            }
            let secs = start.elapsed().as_secs_f64();
            let (msgs, bytes, _, _) = t.stats().snapshot();
            (secs, msgs, bytes)
        }));
    }
    let mut seconds = 0f64;
    let mut messages = 0u64;
    let mut bytes_moved = 0u64;
    for h in hs {
        let (s, m, b) = h.join().unwrap();
        seconds = seconds.max(s);
        messages += m;
        bytes_moved += b;
    }
    let plan = engine.plan(&Dmap::block_1d(np), &Dmap::cyclic_1d(np), &[n_global]);
    let crossing: usize = plan
        .transfers()
        .iter()
        .filter(|(s, d, _)| s != d)
        .map(|(_, _, r)| r.len())
        .sum();
    RemapBench {
        np,
        n_global,
        dtype: T::DTYPE,
        iters,
        messages,
        bytes_moved,
        payload_bytes: (crossing * T::WIDTH * iters) as u64,
        seconds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::BackendKind;
    use crate::coordinator::{EngineKind, MapKind};
    use crate::element::Dtype;

    fn sample() -> (RunConfig, AggregateResult) {
        let cfg = RunConfig {
            n_global: 1 << 16,
            nt: 5,
            q: crate::stream::STREAM_Q,
            map: MapKind::Block,
            engine: EngineKind::Native,
            dtype: Dtype::F32,
            backend: BackendKind::Threaded,
            threads: 4,
            artifacts: "artifacts".into(),
        };
        let agg = AggregateResult {
            np: 2,
            n_global: 1 << 16,
            nt: 5,
            width: 4,
            backend: BackendKind::Threaded,
            bw: [4e9, 4e9, 6e9, 6e9],
            all_valid: true,
            worst_err: 1e-7,
        };
        (cfg, agg)
    }

    #[test]
    fn document_roundtrips_and_carries_every_axis() {
        let (cfg, agg) = sample();
        let doc = to_json(&cfg, &agg);
        let parsed = Json::parse(&doc.to_string()).expect("emitted json parses");
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some(SCHEMA));
        assert_eq!(parsed.get("backend").unwrap().as_str(), Some("threaded"));
        assert_eq!(parsed.get("dtype").unwrap().as_str(), Some("f32"));
        assert_eq!(parsed.get("nt").unwrap().as_usize(), Some(5));
        assert_eq!(parsed.get("np").unwrap().as_usize(), Some(2));
        assert_eq!(parsed.get("validated").unwrap().as_bool(), Some(true));
        for op in OP_NAMES {
            let o = parsed.get("ops").unwrap().get(op).unwrap();
            assert!(o.get("bytes_per_sec").unwrap().as_f64().unwrap() > 0.0);
            assert!(o.get("gb_per_sec").unwrap().as_f64().unwrap() > 0.0);
            assert!(o.get("elements_per_sec").unwrap().as_f64().unwrap() > 0.0);
        }
    }

    #[test]
    fn elements_per_sec_follows_the_width_formulas() {
        let (cfg, agg) = sample();
        let doc = to_json(&cfg, &agg);
        // Triad at 6e9 B/s, 3 vectors × 4 B/elem → 5e8 elem/s.
        let triad = doc.get("ops").unwrap().get("triad").unwrap();
        let eps = triad.get("elements_per_sec").unwrap().as_f64().unwrap();
        assert!((eps - 5e8).abs() < 1e-3);
        // Copy at 4e9 B/s, 2 vectors × 4 B/elem → 5e8 elem/s too.
        let copy = doc.get("ops").unwrap().get("copy").unwrap();
        let eps = copy.get("elements_per_sec").unwrap().as_f64().unwrap();
        assert!((eps - 5e8).abs() < 1e-3);
    }

    #[test]
    fn remap_bench_runs_and_documents() {
        // Small but strided: block→cyclic on np=3 — every PID talks to
        // both peers, so sends per timed remap = 3 × 2 = 6.
        let b = run_remap(3, 96, 2, Dtype::F32);
        assert_eq!(b.messages, 2 * 6, "one send per peer per remap");
        // 2/3 of elements cross PIDs, 4 bytes each, 2 iterations.
        assert_eq!(b.payload_bytes, 64 * 4 * 2);
        assert!(b.bytes_moved >= b.payload_bytes, "wire bytes include framing");
        assert!(b.seconds >= 0.0 && b.gb_per_sec() >= 0.0);
        let doc = remap_to_json(&b);
        let parsed = Json::parse(&doc.to_string()).expect("emitted json parses");
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some(REMAP_SCHEMA));
        assert_eq!(parsed.get("dtype").unwrap().as_str(), Some("f32"));
        assert_eq!(parsed.get("messages_per_remap").unwrap().as_usize(), Some(6));
        assert!(parsed.get("gb_per_sec").unwrap().as_f64().is_some());
    }

    #[test]
    fn write_remap_file_emits_parseable_json() {
        let b = run_remap(2, 32, 1, Dtype::F64);
        let path =
            std::env::temp_dir().join(format!("bench_remap_test_{}.json", std::process::id()));
        let path_s = path.to_str().unwrap();
        write_remap_file(path_s, &b).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.ends_with('\n'));
        assert!(Json::parse(text.trim()).is_ok());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn write_file_emits_parseable_json() {
        let (cfg, agg) = sample();
        let path = std::env::temp_dir().join(format!("bench_stream_test_{}.json", std::process::id()));
        let path_s = path.to_str().unwrap();
        write_file(path_s, &cfg, &agg).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.ends_with('\n'));
        assert!(Json::parse(text.trim()).is_ok());
        std::fs::remove_file(&path).ok();
    }
}
