//! `BENCH_stream.json` / `BENCH_remap.json` / `BENCH_collective.json`
//! — the machine-readable perf trajectory.
//!
//! `repro run --bench-json <path>` emits one `bench_stream_v1`
//! document per run with per-op bandwidths (bytes/s and GB/s),
//! element throughput, and the full axis coordinates (dtype, backend,
//! engine, Nt, Np); `repro bench-remap --bench-json <path>` emits a
//! `bench_remap_v1` document (bytes moved, message counts, GB/s per
//! remap) for the coalesced data-movement hot path; `repro
//! bench-collective --bench-json <path>` emits a
//! `bench_collective_v1` document (per-algorithm × per-operation
//! latency, bytes, and message counts vs P) so the scaling behavior
//! of the collective subsystem is measured, not asserted — successive
//! PRs can diff the numbers mechanically instead of scraping stdout.

use crate::backend::ChunkedThreadedBackend;
use crate::collective::{
    AllreduceOrder, CollKind, Collective, ReduceOp, TagSpace, Topology, PH_AG, PH_RS,
};
use crate::comm::datapath::{self, ChunkStream, ChunkTag};
use crate::comm::{
    tags, ChannelHub, FileTransport, HybridTransport, ShmemTransport, Tag, TcpRendezvous,
    Transport, TransportKind, WireWriter,
};
use crate::coordinator::RunConfig;
use crate::darray::engine::{remap_tag, send_group_typed, unpack_group_typed, write_group_header};
use crate::darray::{DarrayT, RemapEngine};
use crate::dmap::Dmap;
use crate::element::{Dtype, Element};
use crate::json::Json;
use crate::stream::AggregateResult;
use std::collections::BTreeMap;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

/// Schema tag, bumped on any field change.
pub const SCHEMA: &str = "bench_stream_v1";

/// Schema tag of the remap benchmark document.
pub const REMAP_SCHEMA: &str = "bench_remap_v1";

/// Schema tag of the collective benchmark document.
pub const COLL_SCHEMA: &str = "bench_collective_v1";

/// Schema tag of the compute/communication-overlap benchmark document.
pub const OVERLAP_SCHEMA: &str = "bench_overlap_v1";

/// Schema tag of the transport microbenchmark document.
pub const TRANSPORT_SCHEMA: &str = "bench_transport_v1";

/// The four op names, in the order of [`AggregateResult::bw`].
pub const OP_NAMES: [&str; 4] = ["copy", "scale", "add", "triad"];

/// Build the benchmark document from a run's config + aggregate.
pub fn to_json(cfg: &RunConfig, agg: &AggregateResult) -> Json {
    let eps = agg.elements_per_sec();
    let mut ops = BTreeMap::new();
    for (i, name) in OP_NAMES.iter().enumerate() {
        let bw = agg.bw[i];
        let mut m = BTreeMap::new();
        m.insert("bytes_per_sec".to_string(), Json::Num(bw));
        m.insert("gb_per_sec".to_string(), Json::Num(bw / 1e9));
        m.insert("elements_per_sec".to_string(), Json::Num(eps[i]));
        ops.insert((*name).to_string(), Json::Obj(m));
    }
    let mut top = BTreeMap::new();
    top.insert("schema".to_string(), Json::Str(SCHEMA.to_string()));
    top.insert("engine".to_string(), Json::Str(cfg.engine.name().to_string()));
    top.insert("backend".to_string(), Json::Str(agg.backend.name().to_string()));
    top.insert("dtype".to_string(), Json::Str(cfg.dtype.name().to_string()));
    top.insert("width".to_string(), Json::Num(agg.width as f64));
    top.insert("n".to_string(), Json::Num(agg.n_global as f64));
    top.insert("nt".to_string(), Json::Num(agg.nt as f64));
    top.insert("np".to_string(), Json::Num(agg.np as f64));
    top.insert("threads".to_string(), Json::Num(cfg.threads as f64));
    top.insert("validated".to_string(), Json::Bool(agg.all_valid));
    top.insert("worst_err".to_string(), Json::Num(agg.worst_err));
    top.insert("ops".to_string(), Json::Obj(ops));
    Json::Obj(top)
}

/// Emit the document to `path` (newline-terminated).
pub fn write_file(path: &str, cfg: &RunConfig, agg: &AggregateResult) -> std::io::Result<()> {
    std::fs::write(path, format!("{}\n", to_json(cfg, agg)))
}

/// One measured remap benchmark: iterated block→cyclic global
/// assignment through a cached plan over the in-process transport —
/// the worst-case (fully strided) data-movement pattern the per-peer
/// coalescing exists for.
#[derive(Debug, Clone)]
pub struct RemapBench {
    pub np: usize,
    pub n_global: usize,
    pub dtype: Dtype,
    pub iters: usize,
    /// Total messages sent (all PIDs, all timed iterations). With
    /// coalescing this is `iters × Σ_pid distinct peers`, independent
    /// of plan-step count.
    pub messages: u64,
    /// Total wire bytes sent (framing + payload).
    pub bytes_moved: u64,
    /// Element payload bytes only (crossing elements × width × iters).
    pub payload_bytes: u64,
    /// Wall time of the timed iterations (max across PIDs).
    pub seconds: f64,
    /// Global [`BufferPool`](crate::comm::BufferPool) checkouts
    /// during the timed iterations (warm-up excluded).
    pub pool_checkouts: u64,
    /// Checkouts served by a reused allocation. Equal to
    /// [`RemapBench::pool_checkouts`] in steady state — the
    /// zero-allocation proof.
    pub pool_hits: u64,
    /// Datapath [`CommStats`](crate::comm::CommStats) deltas over the
    /// timed iterations: [`ChunkStream`] messages and wire bytes sent
    /// and received (framing included) — the proof the remap hot path
    /// routed through the shared streaming layer.
    pub dp_msgs_sent: u64,
    pub dp_bytes_sent: u64,
    pub dp_msgs_recv: u64,
    pub dp_bytes_recv: u64,
}

impl RemapBench {
    pub fn gb_per_sec(&self) -> f64 {
        if self.seconds > 0.0 {
            self.bytes_moved as f64 / self.seconds / 1e9
        } else {
            0.0
        }
    }
}

/// Build the `bench_remap_v1` document.
pub fn remap_to_json(b: &RemapBench) -> Json {
    let mut top = BTreeMap::new();
    top.insert("schema".to_string(), Json::Str(REMAP_SCHEMA.to_string()));
    top.insert("np".to_string(), Json::Num(b.np as f64));
    top.insert("n".to_string(), Json::Num(b.n_global as f64));
    top.insert("dtype".to_string(), Json::Str(b.dtype.name().to_string()));
    top.insert("iters".to_string(), Json::Num(b.iters as f64));
    top.insert("messages".to_string(), Json::Num(b.messages as f64));
    top.insert(
        "messages_per_remap".to_string(),
        Json::Num(if b.iters > 0 { b.messages as f64 / b.iters as f64 } else { 0.0 }),
    );
    top.insert("bytes_moved".to_string(), Json::Num(b.bytes_moved as f64));
    top.insert("payload_bytes".to_string(), Json::Num(b.payload_bytes as f64));
    top.insert("seconds".to_string(), Json::Num(b.seconds));
    top.insert("gb_per_sec".to_string(), Json::Num(b.gb_per_sec()));
    top.insert("pool_checkouts".to_string(), Json::Num(b.pool_checkouts as f64));
    top.insert("pool_hits".to_string(), Json::Num(b.pool_hits as f64));
    top.insert("datapath_msgs_sent".to_string(), Json::Num(b.dp_msgs_sent as f64));
    top.insert("datapath_bytes_sent".to_string(), Json::Num(b.dp_bytes_sent as f64));
    top.insert("datapath_msgs_recv".to_string(), Json::Num(b.dp_msgs_recv as f64));
    top.insert("datapath_bytes_recv".to_string(), Json::Num(b.dp_bytes_recv as f64));
    Json::Obj(top)
}

/// Emit the remap document to `path` (newline-terminated).
pub fn write_remap_file(path: &str, b: &RemapBench) -> std::io::Result<()> {
    std::fs::write(path, format!("{}\n", remap_to_json(b)))
}

/// Run the remap benchmark: `np` in-process SPMD PIDs, `iters` timed
/// block→cyclic remaps of an `n_global`-element array at `dtype`
/// (plus one untimed warm-up that builds the plan and the pooled wire
/// buffers).
pub fn run_remap(np: usize, n_global: usize, iters: usize, dtype: Dtype) -> RemapBench {
    match dtype {
        Dtype::F32 => run_remap_t::<f32>(np, n_global, iters),
        Dtype::F64 => run_remap_t::<f64>(np, n_global, iters),
        Dtype::I64 => run_remap_t::<i64>(np, n_global, iters),
        Dtype::U64 => run_remap_t::<u64>(np, n_global, iters),
    }
}

fn run_remap_t<T: Element>(np: usize, n_global: usize, iters: usize) -> RemapBench {
    assert!(np >= 1 && n_global >= 1);
    let engine = Arc::new(RemapEngine::new());
    let world = ChannelHub::world(np);
    // Two rendezvous with the measuring parent: after warm-up (so the
    // pool counter baseline excludes the populating allocations) and
    // before the timed loop.
    let gate = Arc::new(std::sync::Barrier::new(np + 1));
    let mut hs = Vec::new();
    for t in world {
        let engine = engine.clone();
        let gate = gate.clone();
        hs.push(std::thread::spawn(move || {
            let pid = t.pid();
            let src = DarrayT::<T>::from_global_fn(Dmap::block_1d(np), &[n_global], pid, |g| {
                T::from_f64((g % 1024) as f64)
            });
            let mut dst = DarrayT::<T>::zeros(Dmap::cyclic_1d(np), &[n_global], pid);
            // Warm-up: plans once, populates the buffer pool.
            dst.assign_from_engine(&src, &t, 0, &engine).unwrap();
            t.stats().reset();
            gate.wait();
            gate.wait();
            let start = Instant::now();
            for epoch in 1..=iters as u64 {
                dst.assign_from_engine(&src, &t, epoch, &engine).unwrap();
            }
            let secs = start.elapsed().as_secs_f64();
            let (msgs, bytes, _, _) = t.stats().snapshot();
            (secs, msgs, bytes)
        }));
    }
    gate.wait();
    let (c0, h0) = datapath::pool_counters();
    let (ms0, bs0, mr0, br0) = datapath::comm_snapshot();
    gate.wait();
    let mut seconds = 0f64;
    let mut messages = 0u64;
    let mut bytes_moved = 0u64;
    for h in hs {
        let (s, m, b) = h.join().unwrap();
        seconds = seconds.max(s);
        messages += m;
        bytes_moved += b;
    }
    let (c1, h1) = datapath::pool_counters();
    let (ms1, bs1, mr1, br1) = datapath::comm_snapshot();
    let plan = engine.plan(&Dmap::block_1d(np), &Dmap::cyclic_1d(np), &[n_global]);
    let crossing: usize = plan
        .transfers()
        .iter()
        .filter(|(s, d, _)| s != d)
        .map(|(_, _, r)| r.len())
        .sum();
    RemapBench {
        np,
        n_global,
        dtype: T::DTYPE,
        iters,
        messages,
        bytes_moved,
        payload_bytes: (crossing * T::WIDTH * iters) as u64,
        seconds,
        pool_checkouts: c1 - c0,
        pool_hits: h1 - h0,
        dp_msgs_sent: ms1 - ms0,
        dp_bytes_sent: bs1 - bs0,
        dp_msgs_recv: mr1 - mr0,
        dp_bytes_recv: br1 - br0,
    }
}

/// The measured collective operations, in run order.
/// `allreduce_vec` is the long-vector shape: a whole `payload_bytes`
/// f64 vector reduced under [`AllreduceOrder::Fast`], so an `auto`
/// context above the elimination threshold exercises the
/// reduce-scatter + allgather schedule.
pub const COLL_OPS: [&str; 6] =
    ["bcast", "allreduce", "allreduce_vec", "gather", "allgather", "barrier"];

/// One measured collective data point: `(algorithm, operation, P)` →
/// latency, messages, wire bytes.
#[derive(Debug, Clone)]
pub struct CollBench {
    pub coll: CollKind,
    pub op: &'static str,
    pub np: usize,
    /// Node-group count of the topology the run used.
    pub nodes: usize,
    /// Broadcast payload size; gathers contribute `payload/np` per PID.
    pub payload_bytes: usize,
    pub iters: usize,
    /// Total messages sent (all PIDs, timed iterations only).
    pub messages: u64,
    /// Total wire bytes sent (framing + payload).
    pub bytes_moved: u64,
    /// Wall time of the timed iterations (max across PIDs).
    pub seconds: f64,
}

impl CollBench {
    /// Mean wall time of one collective call, in microseconds.
    pub fn avg_latency_us(&self) -> f64 {
        if self.iters > 0 {
            self.seconds / self.iters as f64 * 1e6
        } else {
            0.0
        }
    }

    /// Mean messages per collective call.
    pub fn msgs_per_op(&self) -> f64 {
        if self.iters > 0 {
            self.messages as f64 / self.iters as f64
        } else {
            0.0
        }
    }
}

/// Run one collective call so benchmarks and smoke tests share the
/// exact call shapes.
fn coll_once(
    coll: &Collective,
    t: &dyn Transport,
    op: &str,
    epoch: u64,
    payload_bytes: usize,
    timeout: Duration,
) {
    let space = TagSpace::packed(tags::NS_COLL, epoch);
    let part_len = (payload_bytes / t.np()).max(1);
    match op {
        "bcast" => {
            let payload = if t.pid() == 0 { vec![7u8; payload_bytes] } else { Vec::new() };
            coll.bcast(t, space, payload).unwrap();
        }
        "allreduce" => {
            coll.allreduce_scalar(t, space, t.pid() as f64 + 0.5, ReduceOp::Sum).unwrap();
        }
        "allreduce_vec" => {
            let n = (payload_bytes / 8).max(1);
            let local: Vec<f64> = vec![t.pid() as f64 * 0.5 + 1.0; n];
            coll.allreduce_ordered(t, space, &local, ReduceOp::Sum, AllreduceOrder::Fast)
                .unwrap();
        }
        "gather" => {
            coll.gather(t, space, vec![t.pid() as u8; part_len]).unwrap();
        }
        "allgather" => {
            coll.allgather(t, space, vec![t.pid() as u8; part_len]).unwrap();
        }
        "barrier" => coll.barrier(t, space, timeout).unwrap(),
        other => unreachable!("unknown collective op {other}"),
    }
}

/// Measure every op of every requested algorithm at world size `np`
/// over the in-process transport (one warm-up + `iters` timed calls
/// per op; messages and bytes from [`crate::comm::CommStats`]
/// deltas).
pub fn run_collective(
    np: usize,
    nppn: usize,
    kinds: &[CollKind],
    payload_bytes: usize,
    iters: usize,
) -> Vec<CollBench> {
    assert!(np >= 1 && iters >= 1);
    let mut out = Vec::new();
    for &kind in kinds {
        let coll = Arc::new(Collective::new(kind, Topology::grouped(np, nppn)));
        let world = ChannelHub::world(np);
        let mut hs = Vec::new();
        for t in world {
            let coll = coll.clone();
            hs.push(std::thread::spawn(move || {
                let timeout = Duration::from_secs(60);
                let mut epoch = 0u64;
                let mut per_op = Vec::with_capacity(COLL_OPS.len());
                for op in COLL_OPS {
                    coll_once(&coll, &t, op, epoch, payload_bytes, timeout);
                    epoch += 1;
                    let (m0, b0, _, _) = t.stats().snapshot();
                    let start = Instant::now();
                    for _ in 0..iters {
                        coll_once(&coll, &t, op, epoch, payload_bytes, timeout);
                        epoch += 1;
                    }
                    let secs = start.elapsed().as_secs_f64();
                    let (m1, b1, _, _) = t.stats().snapshot();
                    per_op.push((secs, m1 - m0, b1 - b0));
                }
                per_op
            }));
        }
        let mut totals = vec![(0.0f64, 0u64, 0u64); COLL_OPS.len()];
        for h in hs {
            for (i, (s, m, b)) in h.join().unwrap().into_iter().enumerate() {
                totals[i].0 = totals[i].0.max(s);
                totals[i].1 += m;
                totals[i].2 += b;
            }
        }
        for (i, op) in COLL_OPS.into_iter().enumerate() {
            out.push(CollBench {
                coll: coll.kind(),
                op,
                np,
                nodes: coll.topology().node_count(),
                payload_bytes,
                iters,
                messages: totals[i].1,
                bytes_moved: totals[i].2,
                seconds: totals[i].0,
            });
        }
    }
    out
}

/// Build the `bench_collective_v1` document from a set of runs
/// (typically one [`run_collective`] call per P).
pub fn collective_to_json(records: &[CollBench]) -> Json {
    let runs = records
        .iter()
        .map(|b| {
            let mut m = BTreeMap::new();
            m.insert("coll".to_string(), Json::Str(b.coll.name().to_string()));
            m.insert("op".to_string(), Json::Str(b.op.to_string()));
            m.insert("np".to_string(), Json::Num(b.np as f64));
            m.insert("nodes".to_string(), Json::Num(b.nodes as f64));
            m.insert("payload_bytes".to_string(), Json::Num(b.payload_bytes as f64));
            m.insert("iters".to_string(), Json::Num(b.iters as f64));
            m.insert("messages".to_string(), Json::Num(b.messages as f64));
            m.insert("msgs_per_op".to_string(), Json::Num(b.msgs_per_op()));
            m.insert("bytes_moved".to_string(), Json::Num(b.bytes_moved as f64));
            m.insert("seconds".to_string(), Json::Num(b.seconds));
            m.insert("avg_latency_us".to_string(), Json::Num(b.avg_latency_us()));
            Json::Obj(m)
        })
        .collect();
    let mut top = BTreeMap::new();
    top.insert("schema".to_string(), Json::Str(COLL_SCHEMA.to_string()));
    // Process-cumulative datapath pool counters at document build —
    // for a dedicated `repro bench-collective` process this is the
    // bench's own pool traffic (hits ≈ checkouts ⇒ steady-state
    // sends allocated nothing).
    let (pc, ph) = datapath::pool_counters();
    top.insert("pool_checkouts".to_string(), Json::Num(pc as f64));
    top.insert("pool_hits".to_string(), Json::Num(ph as f64));
    // Process-cumulative datapath stream counters (CommStats wired
    // into ChunkStream send/recv) — same caveat as the pool counters.
    let (ms, bs, mr, br) = datapath::comm_snapshot();
    top.insert("datapath_msgs_sent".to_string(), Json::Num(ms as f64));
    top.insert("datapath_bytes_sent".to_string(), Json::Num(bs as f64));
    top.insert("datapath_msgs_recv".to_string(), Json::Num(mr as f64));
    top.insert("datapath_bytes_recv".to_string(), Json::Num(br as f64));
    top.insert("runs".to_string(), Json::Arr(runs));
    Json::Obj(top)
}

/// Emit the collective document to `path` (newline-terminated).
pub fn write_collective_file(path: &str, records: &[CollBench]) -> std::io::Result<()> {
    std::fs::write(path, format!("{}\n", collective_to_json(records)))
}

/// One phase of the compute-on-arrival benchmark: the same work
/// measured four ways — pure wire (same bytes, no compute), pure
/// compute (same unpack/fold, no wire), the serial datapath
/// (whole-message reassembly, overlap off), and the overlapped
/// datapath (chunk-granular, overlap on).
#[derive(Debug, Clone)]
pub struct OverlapBench {
    /// `"remap"` (chunked-backend block→cyclic) or `"allreduce"`
    /// (elimination reduce-scatter + allgather).
    pub phase: &'static str,
    pub np: usize,
    /// Payload bytes owned per rank (remap: owned slice; allreduce:
    /// the reduced vector).
    pub bytes_per_rank: usize,
    pub iters: usize,
    /// Stream chunk size the phase ran at.
    pub chunk_bytes: usize,
    /// Wall time of `iters` wire-only exchanges (max across ranks).
    pub wire_seconds: f64,
    /// Wall time of `iters` compute-only passes (max across ranks).
    pub compute_seconds: f64,
    /// Wall time of `iters` full operations with overlap off.
    pub serial_seconds: f64,
    /// Wall time of `iters` full operations with overlap on.
    pub total_seconds: f64,
}

impl OverlapBench {
    /// `1 − total/(wire + compute)`: 0 when the phases run strictly
    /// back to back, approaching `1 − max/(wire+compute)` when one
    /// fully hides behind the other.
    pub fn efficiency(&self) -> f64 {
        let denom = self.wire_seconds + self.compute_seconds;
        if denom > 0.0 {
            1.0 - self.total_seconds / denom
        } else {
            0.0
        }
    }

    /// Serial (overlap off) time over overlapped time.
    pub fn speedup_vs_serial(&self) -> f64 {
        if self.total_seconds > 0.0 {
            self.serial_seconds / self.total_seconds
        } else {
            0.0
        }
    }
}

/// Run both overlap phases at f64. `chunk_bytes == 0` means the
/// ambient process default; the remap phase always streams at the
/// ambient size (its datapath reads the process default internally),
/// so callers who want a specific size should set the ambient chunk
/// size *and* pass it here, as the CLI does.
pub fn run_overlap(
    np: usize,
    bytes_per_rank: usize,
    iters: usize,
    chunk_bytes: usize,
) -> Vec<OverlapBench> {
    assert!(np >= 2 && iters >= 1 && bytes_per_rank >= 8);
    let effective = if chunk_bytes > 0 { chunk_bytes } else { datapath::ambient_chunk_bytes() };
    vec![
        overlap_remap_phase(np, bytes_per_rank, iters, effective),
        overlap_allreduce_phase(np, bytes_per_rank, iters, effective),
    ]
}

/// The remap phase: block→cyclic through [`ChunkedThreadedBackend`],
/// overlap on vs off, against a wire-only pass (the real coalesced
/// group messages, drained without unpacking) and a compute-only pass
/// (the same group messages unpacked from local memory).
fn overlap_remap_phase(
    np: usize,
    bytes_per_rank: usize,
    iters: usize,
    chunk_bytes: usize,
) -> OverlapBench {
    let n_global = (np * bytes_per_rank / 8).max(np);
    let engine = Arc::new(RemapEngine::new());
    let gate = Arc::new(Barrier::new(np));
    let world = ChannelHub::world(np);
    let mut hs = Vec::new();
    for t in world {
        let engine = engine.clone();
        let gate = gate.clone();
        hs.push(std::thread::spawn(move || {
            let pid = t.pid();
            let src = DarrayT::<f64>::from_global_fn(Dmap::block_1d(np), &[n_global], pid, |g| {
                (g % 8191) as f64 * 0.5
            });
            let mut dst = DarrayT::<f64>::zeros(Dmap::cyclic_1d(np), &[n_global], pid);
            let plan = engine.plan(&Dmap::block_1d(np), &Dmap::cyclic_1d(np), &[n_global]);
            let peers: Vec<_> = plan.peer_recvs(pid).iter().map(|g| g.peer).collect();
            let b_ov = ChunkedThreadedBackend::new(2);
            let b_ser = ChunkedThreadedBackend::new(2).with_overlap(false);
            let mut epoch = 0u64;

            // Wire-only: the real coalesced sends, received by a
            // no-op chunk drain (not one payload byte is unpacked).
            epoch += 1;
            wire_remap_iter(&*plan, pid, &t, &src, &peers, epoch);
            gate.wait();
            let start = Instant::now();
            for _ in 0..iters {
                epoch += 1;
                wire_remap_iter(&*plan, pid, &t, &src, &peers, epoch);
            }
            let wire = start.elapsed().as_secs_f64();
            gate.wait();

            // Compute-only: the same group messages synthesized in
            // local memory, unpacked into the destination each iter.
            let msgs: Vec<Vec<u8>> = plan
                .peer_recvs(pid)
                .iter()
                .map(|g| {
                    let mut w = WireWriter::with_capacity(g.header_bytes() + 9 + g.total * 8);
                    write_group_header(&mut w, g);
                    let vals = vec![1.0f64; g.total];
                    w.put_slice::<f64>(&vals);
                    w.finish()
                })
                .collect();
            for (g, m) in plan.peer_recvs(pid).iter().zip(&msgs) {
                unpack_group_typed::<f64>(g, m, dst.loc_mut()).unwrap();
            }
            gate.wait();
            let start = Instant::now();
            for _ in 0..iters {
                for (g, m) in plan.peer_recvs(pid).iter().zip(&msgs) {
                    unpack_group_typed::<f64>(g, m, dst.loc_mut()).unwrap();
                }
            }
            let compute = start.elapsed().as_secs_f64();
            gate.wait();

            // Serial reference: whole-message reassembly, overlap off.
            epoch += 1;
            dst.assign_from_engine_on(&src, &t, epoch, &engine, &b_ser).unwrap();
            let serial_result = dst.loc().to_vec();
            gate.wait();
            let start = Instant::now();
            for _ in 0..iters {
                epoch += 1;
                dst.assign_from_engine_on(&src, &t, epoch, &engine, &b_ser).unwrap();
            }
            let serial = start.elapsed().as_secs_f64();
            gate.wait();

            // Overlapped: chunk-granular double-buffered receive.
            epoch += 1;
            dst.assign_from_engine_on(&src, &t, epoch, &engine, &b_ov).unwrap();
            assert_eq!(
                serial_result,
                dst.loc(),
                "overlapped remap diverged from the serial datapath"
            );
            gate.wait();
            let start = Instant::now();
            for _ in 0..iters {
                epoch += 1;
                dst.assign_from_engine_on(&src, &t, epoch, &engine, &b_ov).unwrap();
            }
            let total = start.elapsed().as_secs_f64();
            (wire, compute, serial, total)
        }));
    }
    let mut agg = (0f64, 0f64, 0f64, 0f64);
    for h in hs {
        let (w, c, s, tt) = h.join().unwrap();
        agg = (agg.0.max(w), agg.1.max(c), agg.2.max(s), agg.3.max(tt));
    }
    OverlapBench {
        phase: "remap",
        np,
        bytes_per_rank,
        iters,
        chunk_bytes,
        wire_seconds: agg.0,
        compute_seconds: agg.1,
        serial_seconds: agg.2,
        total_seconds: agg.3,
    }
}

/// One wire-only remap iteration: real sends, chunk-drained receives,
/// zero unpack work.
fn wire_remap_iter(
    plan: &crate::darray::RemapPlan,
    pid: crate::dmap::Pid,
    t: &dyn Transport,
    src: &DarrayT<f64>,
    peers: &[crate::dmap::Pid],
    epoch: u64,
) {
    let tag = remap_tag(epoch);
    for g in plan.peer_sends(pid) {
        send_group_typed::<f64>(g, src.loc(), t, tag).unwrap();
    }
    ChunkStream::drain_chunks(t, peers, tag, |_| Ok(())).unwrap();
}

/// The allreduce phase: the Fast elimination schedule with overlap on
/// vs off, against a wire-only ring pass (same segment streams,
/// drained without folding) and a compute-only pass (the same folds
/// and decodes over local memory).
fn overlap_allreduce_phase(
    np: usize,
    bytes_per_rank: usize,
    iters: usize,
    chunk_bytes: usize,
) -> OverlapBench {
    let n = (bytes_per_rank / 8).max(np);
    let gate = Arc::new(Barrier::new(np));
    let world = ChannelHub::world(np);
    let mut hs = Vec::new();
    for t in world {
        let gate = gate.clone();
        hs.push(std::thread::spawn(move || {
            let pid = t.pid();
            let coll_ov = Collective::new(CollKind::Auto, Topology::flat(np))
                .with_elim_threshold(1)
                .with_chunk_bytes(chunk_bytes)
                .with_overlap(true);
            let coll_ser = coll_ov.clone().with_overlap(false);
            let local: Vec<f64> =
                (0..n).map(|i| (pid + 1) as f64 * 0.25 + i as f64 * 1e-6).collect();
            let seg = |k: usize| (k * n / np, (k + 1) * n / np);
            let me = pid;
            let next = (me + 1) % np;
            let prev = (me + np - 1) % np;
            let mut epoch = 0u64;

            // Wire-only: the exact ring schedule's segment streams,
            // received by a no-op drain.
            let max_seg_bytes = (0..np).map(|k| (seg(k).1 - seg(k).0) * 8).max().unwrap();
            let zeros = vec![0u8; max_seg_bytes];
            let wire_iter = |epoch: u64| {
                let space = TagSpace::packed(tags::NS_COLL, epoch);
                for (phase, shift) in [(PH_RS, 0), (PH_AG, 1)] {
                    let tag = space.chunk_tag(0, phase);
                    for s in 0..np - 1 {
                        let (lo, hi) = seg((me + shift + np - s) % np);
                        ChunkStream::send(&t, next, tag, chunk_bytes, &[&zeros[..(hi - lo) * 8]])
                            .unwrap();
                        ChunkStream::drain_chunks(&t, &[prev], tag, |_| Ok(())).unwrap();
                    }
                }
            };
            epoch += 1;
            wire_iter(epoch);
            gate.wait();
            let start = Instant::now();
            for _ in 0..iters {
                epoch += 1;
                wire_iter(epoch);
            }
            let wire = start.elapsed().as_secs_f64();
            gate.wait();

            // Compute-only: the same folds (reduce-scatter) and LE
            // decodes (allgather) over local buffers.
            let mut acc = local.clone();
            let mut scratch = vec![0.0f64; max_seg_bytes / 8];
            let compute_iter = |acc: &mut [f64], scratch: &mut [f64]| {
                for s in 0..np - 1 {
                    let (lo, hi) = seg((me + np - s - 1) % np);
                    for (a, b) in acc[lo..hi].iter_mut().zip(&scratch[..hi - lo]) {
                        *a = ReduceOp::Sum.combine(*b, *a);
                    }
                }
                for s in 0..np - 1 {
                    let (lo, hi) = seg((me + np - s) % np);
                    f64::copy_from_le(&zeros[..(hi - lo) * 8], &mut acc[lo..hi]);
                }
            };
            compute_iter(&mut acc, &mut scratch);
            gate.wait();
            let start = Instant::now();
            for _ in 0..iters {
                compute_iter(&mut acc, &mut scratch);
            }
            let compute = start.elapsed().as_secs_f64();
            gate.wait();

            // Serial reference: whole-segment receives, overlap off.
            let mut space = || {
                epoch += 1;
                TagSpace::packed(tags::NS_COLL, epoch)
            };
            let serial_result = coll_ser
                .allreduce_ordered(&t, space(), &local, ReduceOp::Sum, AllreduceOrder::Fast)
                .unwrap();
            gate.wait();
            let start = Instant::now();
            for _ in 0..iters {
                coll_ser
                    .allreduce_ordered(&t, space(), &local, ReduceOp::Sum, AllreduceOrder::Fast)
                    .unwrap();
            }
            let serial = start.elapsed().as_secs_f64();
            gate.wait();

            // Overlapped: fold each segment chunk as it arrives.
            let overlapped_result = coll_ov
                .allreduce_ordered(&t, space(), &local, ReduceOp::Sum, AllreduceOrder::Fast)
                .unwrap();
            assert_eq!(
                serial_result,
                overlapped_result,
                "overlapped allreduce diverged from the serial schedule"
            );
            gate.wait();
            let start = Instant::now();
            for _ in 0..iters {
                coll_ov
                    .allreduce_ordered(&t, space(), &local, ReduceOp::Sum, AllreduceOrder::Fast)
                    .unwrap();
            }
            let total = start.elapsed().as_secs_f64();
            (wire, compute, serial, total)
        }));
    }
    let mut agg = (0f64, 0f64, 0f64, 0f64);
    for h in hs {
        let (w, c, s, tt) = h.join().unwrap();
        agg = (agg.0.max(w), agg.1.max(c), agg.2.max(s), agg.3.max(tt));
    }
    OverlapBench {
        phase: "allreduce",
        np,
        bytes_per_rank,
        iters,
        chunk_bytes,
        wire_seconds: agg.0,
        compute_seconds: agg.1,
        serial_seconds: agg.2,
        total_seconds: agg.3,
    }
}

/// Build the `bench_overlap_v1` document.
pub fn overlap_to_json(records: &[OverlapBench]) -> Json {
    let runs = records
        .iter()
        .map(|b| {
            let mut m = BTreeMap::new();
            m.insert("phase".to_string(), Json::Str(b.phase.to_string()));
            m.insert("np".to_string(), Json::Num(b.np as f64));
            m.insert("bytes_per_rank".to_string(), Json::Num(b.bytes_per_rank as f64));
            m.insert("iters".to_string(), Json::Num(b.iters as f64));
            m.insert("chunk_bytes".to_string(), Json::Num(b.chunk_bytes as f64));
            m.insert("wire_seconds".to_string(), Json::Num(b.wire_seconds));
            m.insert("compute_seconds".to_string(), Json::Num(b.compute_seconds));
            m.insert("serial_seconds".to_string(), Json::Num(b.serial_seconds));
            m.insert("total_seconds".to_string(), Json::Num(b.total_seconds));
            m.insert("overlap_efficiency".to_string(), Json::Num(b.efficiency()));
            m.insert("speedup_vs_serial".to_string(), Json::Num(b.speedup_vs_serial()));
            Json::Obj(m)
        })
        .collect();
    let mut top = BTreeMap::new();
    top.insert("schema".to_string(), Json::Str(OVERLAP_SCHEMA.to_string()));
    // Process-cumulative datapath stream counters — the overlap bench
    // is pure ChunkStream traffic, so these are its wire totals.
    let (ms, bs, mr, br) = datapath::comm_snapshot();
    top.insert("datapath_msgs_sent".to_string(), Json::Num(ms as f64));
    top.insert("datapath_bytes_sent".to_string(), Json::Num(bs as f64));
    top.insert("datapath_msgs_recv".to_string(), Json::Num(mr as f64));
    top.insert("datapath_bytes_recv".to_string(), Json::Num(br as f64));
    top.insert("runs".to_string(), Json::Arr(runs));
    Json::Obj(top)
}

/// Emit the overlap document to `path` (newline-terminated).
pub fn write_overlap_file(path: &str, records: &[OverlapBench]) -> std::io::Result<()> {
    std::fs::write(path, format!("{}\n", overlap_to_json(records)))
}

/// Ping payload of the transport microbench — small enough that the
/// round trip measures per-message overhead, not bandwidth.
pub const TRANSPORT_PING_BYTES: usize = 64;

/// Timed full-stream repetitions per transport (one warm-up stream on
/// top dials connections, pages rings in, and fills the buffer pool).
const TRANSPORT_STREAM_ITERS: usize = 4;

/// Epoch base reserved for bench traffic: far above any epoch a real
/// run reaches, so the tags cannot alias application streams.
const TRANSPORT_BENCH_EPOCH: u64 = 0xBE6C;

fn transport_ping_tag() -> Tag {
    tags::pack(tags::NS_COLL, TRANSPORT_BENCH_EPOCH, 1)
}

fn transport_pong_tag() -> Tag {
    tags::pack(tags::NS_COLL, TRANSPORT_BENCH_EPOCH, 2)
}

fn transport_ack_tag() -> Tag {
    tags::pack(tags::NS_COLL, TRANSPORT_BENCH_EPOCH, 3)
}

/// One stream tag per repetition — distinct epochs keep the streams
/// unambiguous even on transports that buffer ahead.
fn transport_stream_tag(i: u64) -> ChunkTag {
    ChunkTag::new(tags::NS_COLL, TRANSPORT_BENCH_EPOCH + 1 + i)
}

/// One transport's measured point: small-message round trips plus
/// [`ChunkStream`] streaming, both over an in-process two-rank world
/// of that transport. The same harness runs every
/// [`TransportKind`], so the numbers are directly comparable — the
/// shmem-vs-file RTT ratio in `bench/BENCH_transport.json` is the
/// committed acceptance evidence for the shared-memory datapath.
#[derive(Debug, Clone)]
pub struct TransportBench {
    pub transport: TransportKind,
    /// Timed round trips (one warm-up round excluded).
    pub ping_iters: usize,
    /// Ping payload bytes ([`TRANSPORT_PING_BYTES`]).
    pub ping_bytes: usize,
    /// Wall time of all timed round trips.
    pub ping_seconds: f64,
    /// Timed full streams (one warm-up stream excluded).
    pub stream_iters: usize,
    /// Payload bytes per stream.
    pub stream_bytes: usize,
    /// Chunk size the streams were cut into (the ambient datapath
    /// setting at bench time).
    pub chunk_bytes: usize,
    /// Wall time of all timed streams, completion acks included.
    pub stream_seconds: f64,
}

impl TransportBench {
    /// Mean small-message round-trip time in microseconds.
    pub fn rtt_us(&self) -> f64 {
        if self.ping_iters > 0 {
            self.ping_seconds / self.ping_iters as f64 * 1e6
        } else {
            0.0
        }
    }

    /// Streaming goodput in GB/s — payload bytes over acked wall
    /// time, so a transport cannot win by buffering without draining.
    pub fn stream_gb_per_sec(&self) -> f64 {
        if self.stream_seconds > 0.0 {
            (self.stream_iters as f64 * self.stream_bytes as f64) / self.stream_seconds / 1e9
        } else {
            0.0
        }
    }
}

/// Drive the two-phase microbench over a two-endpoint world: rank 1
/// echoes pings and acks drained streams on its own thread, rank 0
/// times the round trips and the acked streams.
fn bench_transport_world<Tr: Transport + 'static>(
    kind: TransportKind,
    mut world: Vec<Tr>,
    ping_iters: usize,
    stream_bytes: usize,
) -> TransportBench {
    assert_eq!(world.len(), 2, "transport bench runs a 2-rank ping/stream pair");
    let t1 = world.pop().expect("peer endpoint");
    let t0 = world.pop().expect("driver endpoint");
    let chunk_bytes = datapath::ambient_chunk_bytes();
    let echo = std::thread::spawn(move || -> crate::comm::Result<()> {
        // Phase 1 echo: warm-up round plus the timed rounds.
        for _ in 0..=ping_iters {
            let m = t1.recv(0, transport_ping_tag())?;
            t1.send(0, transport_pong_tag(), &m)?;
        }
        // Phase 2 sink: drain each stream fully, then ack with the
        // byte count — the ack puts stream *completion* (not merely
        // the sender's last write) inside the timed window.
        for i in 0..=TRANSPORT_STREAM_ITERS as u64 {
            let mut got = 0u64;
            ChunkStream::drain_chunks(&t1, &[0], transport_stream_tag(i), |c| {
                got += c.payload().len() as u64;
                Ok(())
            })?;
            t1.send(0, transport_ack_tag(), &got.to_le_bytes())?;
        }
        Ok(())
    });
    let ping = vec![0xA5u8; TRANSPORT_PING_BYTES];
    // Warm-up round trip: dials TCP connections, pages rings in,
    // fills the pool — none of that belongs in the RTT.
    t0.send(1, transport_ping_tag(), &ping).expect("bench warm-up ping");
    t0.recv(1, transport_pong_tag()).expect("bench warm-up pong");
    let start = Instant::now();
    for _ in 0..ping_iters {
        t0.send(1, transport_ping_tag(), &ping).expect("bench ping");
        t0.recv(1, transport_pong_tag()).expect("bench pong");
    }
    let ping_seconds = start.elapsed().as_secs_f64();

    let payload = vec![0x5Au8; stream_bytes];
    ChunkStream::send(&t0, 1, transport_stream_tag(0), chunk_bytes, &[&payload])
        .expect("bench warm-up stream");
    t0.recv(1, transport_ack_tag()).expect("bench warm-up ack");
    let start = Instant::now();
    for i in 1..=TRANSPORT_STREAM_ITERS as u64 {
        ChunkStream::send(&t0, 1, transport_stream_tag(i), chunk_bytes, &[&payload])
            .expect("bench stream");
        t0.recv(1, transport_ack_tag()).expect("bench ack");
    }
    let stream_seconds = start.elapsed().as_secs_f64();
    echo.join().expect("echo thread").expect("echo peer");
    TransportBench {
        transport: kind,
        ping_iters,
        ping_bytes: TRANSPORT_PING_BYTES,
        ping_seconds,
        stream_iters: TRANSPORT_STREAM_ITERS,
        stream_bytes,
        chunk_bytes,
        stream_seconds,
    }
}

/// Run the transport microbench for each requested kind. A kind whose
/// world cannot be built on this host (shmem on non-unix, say) is
/// skipped with a warning rather than failing the whole bench — the
/// emitted document simply lacks that run.
pub fn run_transport(
    kinds: &[TransportKind],
    ping_iters: usize,
    stream_bytes: usize,
) -> Vec<TransportBench> {
    let mut out = Vec::new();
    for &kind in kinds {
        let scratch = std::env::temp_dir().join(format!(
            "distarray_bench_{}_{}",
            kind.name(),
            std::process::id()
        ));
        let built: Result<TransportBench, String> = match kind {
            TransportKind::Channel => {
                Ok(bench_transport_world(kind, ChannelHub::world(2), ping_iters, stream_bytes))
            }
            TransportKind::File => (0..2)
                .map(|p| FileTransport::new(&scratch, p, 2))
                .collect::<crate::comm::Result<Vec<_>>>()
                .map_err(|e| e.to_string())
                .map(|w| bench_transport_world(kind, w, ping_iters, stream_bytes)),
            TransportKind::Shmem => ShmemTransport::world(&scratch, 2)
                .map_err(|e| e.to_string())
                .map(|w| bench_transport_world(kind, w, ping_iters, stream_bytes)),
            TransportKind::Tcp => TcpRendezvous::loopback_world(2)
                .map_err(|e| e.to_string())
                .map(|w| bench_transport_world(kind, w, ping_iters, stream_bytes)),
            // Two one-pid "nodes", so the route under test is the
            // cross-node TCP leg behind the hybrid dispatch — the
            // interesting overhead; the same-node leg is just shmem.
            TransportKind::Hybrid => HybridTransport::world(&scratch, 2, 1)
                .map_err(|e| e.to_string())
                .map(|w| bench_transport_world(kind, w, ping_iters, stream_bytes)),
        };
        std::fs::remove_dir_all(&scratch).ok();
        match built {
            Ok(b) => out.push(b),
            Err(e) => crate::log!(Warn, "bench-transport: {} skipped: {e}", kind.name()),
        }
    }
    out
}

/// Build the `bench_transport_v1` document.
pub fn transport_to_json(records: &[TransportBench]) -> Json {
    let runs: Vec<Json> = records
        .iter()
        .map(|b| {
            let mut m = BTreeMap::new();
            m.insert("transport".to_string(), Json::Str(b.transport.name().to_string()));
            m.insert("ping_iters".to_string(), Json::Num(b.ping_iters as f64));
            m.insert("ping_bytes".to_string(), Json::Num(b.ping_bytes as f64));
            m.insert("ping_seconds".to_string(), Json::Num(b.ping_seconds));
            m.insert("rtt_us".to_string(), Json::Num(b.rtt_us()));
            m.insert("stream_iters".to_string(), Json::Num(b.stream_iters as f64));
            m.insert("stream_bytes".to_string(), Json::Num(b.stream_bytes as f64));
            m.insert("chunk_bytes".to_string(), Json::Num(b.chunk_bytes as f64));
            m.insert("stream_seconds".to_string(), Json::Num(b.stream_seconds));
            m.insert("stream_gb_per_sec".to_string(), Json::Num(b.stream_gb_per_sec()));
            Json::Obj(m)
        })
        .collect();
    let mut top = BTreeMap::new();
    top.insert("schema".to_string(), Json::Str(TRANSPORT_SCHEMA.to_string()));
    top.insert("np".to_string(), Json::Num(2.0));
    top.insert("runs".to_string(), Json::Arr(runs));
    Json::Obj(top)
}

/// Emit the transport document to `path` (newline-terminated).
pub fn write_transport_file(path: &str, records: &[TransportBench]) -> std::io::Result<()> {
    std::fs::write(path, format!("{}\n", transport_to_json(records)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::BackendKind;
    use crate::coordinator::{EngineKind, MapKind};
    use crate::element::Dtype;

    fn sample() -> (RunConfig, AggregateResult) {
        let cfg = RunConfig {
            n_global: 1 << 16,
            nt: 5,
            q: crate::stream::STREAM_Q,
            map: MapKind::Block,
            engine: EngineKind::Native,
            dtype: Dtype::F32,
            backend: BackendKind::Threaded,
            threads: 4,
            coll: crate::collective::CollKind::Star,
            nppn: 0,
            chunk_bytes: 0,
            artifacts: "artifacts".into(),
            trace: false,
            heartbeat: false,
            checkpoint: String::new(),
            restore: false,
            transport: TransportKind::Channel,
            recv_timeout_ms: 0,
        };
        let agg = AggregateResult {
            np: 2,
            n_global: 1 << 16,
            nt: 5,
            width: 4,
            backend: BackendKind::Threaded,
            bw: [4e9, 4e9, 6e9, 6e9],
            all_valid: true,
            worst_err: 1e-7,
        };
        (cfg, agg)
    }

    #[test]
    fn document_roundtrips_and_carries_every_axis() {
        let (cfg, agg) = sample();
        let doc = to_json(&cfg, &agg);
        let parsed = Json::parse(&doc.to_string()).expect("emitted json parses");
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some(SCHEMA));
        assert_eq!(parsed.get("backend").unwrap().as_str(), Some("threaded"));
        assert_eq!(parsed.get("dtype").unwrap().as_str(), Some("f32"));
        assert_eq!(parsed.get("nt").unwrap().as_usize(), Some(5));
        assert_eq!(parsed.get("np").unwrap().as_usize(), Some(2));
        assert_eq!(parsed.get("validated").unwrap().as_bool(), Some(true));
        for op in OP_NAMES {
            let o = parsed.get("ops").unwrap().get(op).unwrap();
            assert!(o.get("bytes_per_sec").unwrap().as_f64().unwrap() > 0.0);
            assert!(o.get("gb_per_sec").unwrap().as_f64().unwrap() > 0.0);
            assert!(o.get("elements_per_sec").unwrap().as_f64().unwrap() > 0.0);
        }
    }

    #[test]
    fn elements_per_sec_follows_the_width_formulas() {
        let (cfg, agg) = sample();
        let doc = to_json(&cfg, &agg);
        // Triad at 6e9 B/s, 3 vectors × 4 B/elem → 5e8 elem/s.
        let triad = doc.get("ops").unwrap().get("triad").unwrap();
        let eps = triad.get("elements_per_sec").unwrap().as_f64().unwrap();
        assert!((eps - 5e8).abs() < 1e-3);
        // Copy at 4e9 B/s, 2 vectors × 4 B/elem → 5e8 elem/s too.
        let copy = doc.get("ops").unwrap().get("copy").unwrap();
        let eps = copy.get("elements_per_sec").unwrap().as_f64().unwrap();
        assert!((eps - 5e8).abs() < 1e-3);
    }

    #[test]
    fn remap_bench_runs_and_documents() {
        // Small but strided: block→cyclic on np=3 — every PID talks to
        // both peers, so sends per timed remap = 3 × 2 = 6.
        let b = run_remap(3, 96, 2, Dtype::F32);
        assert_eq!(b.messages, 2 * 6, "one send per peer per remap");
        // 2/3 of elements cross PIDs, 4 bytes each, 2 iterations.
        assert_eq!(b.payload_bytes, 64 * 4 * 2);
        assert!(b.bytes_moved >= b.payload_bytes, "wire bytes include framing");
        assert!(b.seconds >= 0.0 && b.gb_per_sec() >= 0.0);
        let doc = remap_to_json(&b);
        let parsed = Json::parse(&doc.to_string()).expect("emitted json parses");
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some(REMAP_SCHEMA));
        assert_eq!(parsed.get("dtype").unwrap().as_str(), Some("f32"));
        assert_eq!(parsed.get("messages_per_remap").unwrap().as_usize(), Some(6));
        assert!(parsed.get("gb_per_sec").unwrap().as_f64().is_some());
        // The pool instruments ride along (the strict 100%-hit-rate
        // assertion lives in rust/tests/datapath_stream.rs, where the
        // process's pool traffic is controlled).
        let pc = parsed.get("pool_checkouts").unwrap().as_usize();
        assert_eq!(pc, Some(b.pool_checkouts as usize));
        assert_eq!(parsed.get("pool_hits").unwrap().as_usize(), Some(b.pool_hits as usize));
        assert!(b.pool_hits <= b.pool_checkouts);
        assert!(b.pool_checkouts > 0, "timed sends check buffers out of the pool");
        // The datapath stream counters ride along too. The counters
        // are process-global, so parallel tests may add traffic —
        // assert at-least, not equality.
        assert!(b.dp_msgs_sent > 0, "remap traffic must route through the datapath");
        assert!(b.dp_bytes_sent >= b.payload_bytes, "wire bytes cover the payload");
        for f in [
            "datapath_msgs_sent",
            "datapath_bytes_sent",
            "datapath_msgs_recv",
            "datapath_bytes_recv",
        ] {
            assert!(parsed.get(f).unwrap().as_f64().is_some(), "{f} missing");
        }
    }

    #[test]
    fn collective_bench_runs_and_documents() {
        let recs = run_collective(3, 2, &[CollKind::Star, CollKind::Tree], 256, 2);
        assert_eq!(recs.len(), 2 * COLL_OPS.len());
        // Message models at P=3: star bcast sends P−1 per call; the
        // binomial tree also sends P−1 (fewer serial hops, not fewer
        // messages); a star allreduce is a gather + a bcast.
        let find = |k: CollKind, op: &str| {
            recs.iter().find(|r| r.coll == k && r.op == op).expect("record present")
        };
        assert_eq!(find(CollKind::Star, "bcast").msgs_per_op(), 2.0);
        assert_eq!(find(CollKind::Tree, "bcast").msgs_per_op(), 2.0);
        assert_eq!(find(CollKind::Star, "allreduce").msgs_per_op(), 4.0);
        for r in &recs {
            assert!(r.seconds >= 0.0 && r.messages > 0, "{}/{}", r.coll, r.op);
            assert_eq!(r.np, 3);
            assert_eq!(r.nodes, 2);
        }
        let doc = collective_to_json(&recs);
        let parsed = Json::parse(&doc.to_string()).expect("emitted json parses");
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some(COLL_SCHEMA));
        let runs = parsed.get("runs").unwrap().items().expect("runs is an array");
        assert_eq!(runs.len(), recs.len());
        assert_eq!(runs[0].get("coll").unwrap().as_str(), Some("star"));
        assert_eq!(runs[0].get("op").unwrap().as_str(), Some("bcast"));
        assert!(runs[0].get("avg_latency_us").unwrap().as_f64().is_some());
        assert!(parsed.get("pool_checkouts").unwrap().as_usize().is_some());
        assert!(parsed.get("pool_hits").unwrap().as_usize().is_some());
        assert!(parsed.get("datapath_msgs_sent").unwrap().as_f64().is_some());
        assert!(parsed.get("datapath_bytes_recv").unwrap().as_f64().is_some());
    }

    #[test]
    fn overlap_bench_runs_documents_and_stays_bit_identical() {
        // Tiny payloads: the four passes still run (the in-phase
        // asserts check overlap-on == overlap-off bit-for-bit), the
        // document carries every field. Efficiency itself is only
        // meaningful at bench scale.
        let recs = run_overlap(2, 4096, 1, 0);
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].phase, "remap");
        assert_eq!(recs[1].phase, "allreduce");
        for r in &recs {
            assert!(r.wire_seconds >= 0.0 && r.compute_seconds >= 0.0);
            assert!(r.serial_seconds > 0.0 && r.total_seconds > 0.0);
            assert!(r.efficiency() < 1.0);
            assert_eq!(r.np, 2);
            assert_eq!(r.bytes_per_rank, 4096);
            assert!(r.chunk_bytes > 0);
        }
        let doc = overlap_to_json(&recs);
        let parsed = Json::parse(&doc.to_string()).expect("emitted json parses");
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some(OVERLAP_SCHEMA));
        let runs = parsed.get("runs").unwrap().items().expect("runs is an array");
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].get("phase").unwrap().as_str(), Some("remap"));
        assert_eq!(runs[1].get("phase").unwrap().as_str(), Some("allreduce"));
        assert!(runs[0].get("overlap_efficiency").unwrap().as_f64().is_some());
        assert!(runs[1].get("speedup_vs_serial").unwrap().as_f64().is_some());
        assert!(parsed.get("datapath_msgs_sent").unwrap().as_f64().is_some());
        assert!(parsed.get("datapath_bytes_sent").unwrap().as_f64().is_some());
    }

    #[test]
    fn transport_bench_measures_and_documents_channel() {
        let recs = run_transport(&[TransportKind::Channel], 8, 1 << 16);
        assert_eq!(recs.len(), 1);
        let b = &recs[0];
        assert_eq!(b.transport, TransportKind::Channel);
        assert_eq!(b.ping_iters, 8);
        assert_eq!(b.ping_bytes, TRANSPORT_PING_BYTES);
        assert!(b.ping_seconds > 0.0 && b.rtt_us() > 0.0);
        assert!(b.stream_seconds > 0.0 && b.stream_gb_per_sec() > 0.0);
        let doc = transport_to_json(&recs);
        let parsed = Json::parse(&doc.to_string()).expect("emitted json parses");
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some(TRANSPORT_SCHEMA));
        let runs = parsed.get("runs").unwrap().items().expect("runs is an array");
        assert_eq!(runs.len(), 1);
        assert_eq!(runs[0].get("transport").unwrap().as_str(), Some("channel"));
        assert!(runs[0].get("rtt_us").unwrap().as_f64().unwrap() > 0.0);
        assert!(runs[0].get("stream_gb_per_sec").unwrap().as_f64().unwrap() > 0.0);
    }

    /// The same harness runs the OS-backed worlds — the committed
    /// baseline's shmem/tcp rows come from exactly this path.
    #[cfg(unix)]
    #[test]
    fn transport_bench_covers_shmem_and_tcp_worlds() {
        let recs = run_transport(&[TransportKind::Shmem, TransportKind::Tcp], 4, 1 << 15);
        assert_eq!(recs.len(), 2, "unix hosts build both worlds");
        assert_eq!(recs[0].transport, TransportKind::Shmem);
        assert_eq!(recs[1].transport, TransportKind::Tcp);
        for b in &recs {
            assert!(b.rtt_us() > 0.0, "{}", b.transport.name());
            assert!(b.stream_gb_per_sec() > 0.0, "{}", b.transport.name());
        }
    }

    #[test]
    fn write_collective_file_emits_parseable_json() {
        let recs = run_collective(2, 0, &[CollKind::Hier], 64, 1);
        let path = std::env::temp_dir()
            .join(format!("bench_collective_test_{}.json", std::process::id()));
        let path_s = path.to_str().unwrap();
        write_collective_file(path_s, &recs).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.ends_with('\n'));
        assert!(Json::parse(text.trim()).is_ok());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn write_remap_file_emits_parseable_json() {
        let b = run_remap(2, 32, 1, Dtype::F64);
        let path =
            std::env::temp_dir().join(format!("bench_remap_test_{}.json", std::process::id()));
        let path_s = path.to_str().unwrap();
        write_remap_file(path_s, &b).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.ends_with('\n'));
        assert!(Json::parse(text.trim()).is_ok());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn write_file_emits_parseable_json() {
        let (cfg, agg) = sample();
        let path = std::env::temp_dir().join(format!("bench_stream_test_{}.json", std::process::id()));
        let path_s = path.to_str().unwrap();
        write_file(path_s, &cfg, &agg).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.ends_with('\n'));
        assert!(Json::parse(text.trim()).is_ok());
        std::fs::remove_file(&path).ok();
    }
}
