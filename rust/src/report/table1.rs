//! Table I — computer hardware specifications.

use crate::hardware::{Era, ERAS};

/// Render Table I as markdown (same columns as the paper).
pub fn render() -> String {
    let mut s = String::new();
    s.push_str("TABLE I — COMPUTER HARDWARE SPECIFICATIONS\n");
    s.push_str("| Node Label | Era | Processor Part | Clock | Cores | Mem Part | Mem Size |\n");
    s.push_str("|---|---|---|---|---|---|---|\n");
    for e in ERAS {
        s.push_str(&format!(
            "| {} | {} | {} | {:.1} GHz | {} | {:?} | {} GB |\n",
            e.label,
            e.year,
            e.part,
            e.clock_ghz,
            if e.cores == 0 { "-".to_string() } else { e.cores.to_string() },
            e.mem,
            e.mem_gb
        ));
    }
    s
}

/// The rows, for programmatic checks.
pub fn rows() -> &'static [Era] {
    ERAS
}

#[cfg(test)]
mod tests {
    #[test]
    fn render_contains_all_labels() {
        let s = super::render();
        for e in super::rows() {
            assert!(s.contains(e.label), "{}", e.label);
        }
        assert!(s.contains("2005") && s.contains("2024"));
    }
}
