//! The >1 PB/s headline — "Running on hundreds of MIT SuperCloud
//! nodes simultaneously achieved a sustained bandwidth >1 PB/s."
//!
//! Horizontal scaling is communication-free, so aggregate bandwidth is
//! linear in node count; this report sweeps node counts over a
//! SuperCloud-like mix of modern CPU and GPU nodes and reports where
//! the PB/s line is crossed.

use crate::hardware::{horizontal_triad_bw, Era, Lang, NodeModel};
use crate::stream::params::schedule;

/// One sweep point.
#[derive(Debug, Clone)]
pub struct ScalePoint {
    pub nnode_cpu: usize,
    pub nnode_gpu: usize,
    /// Aggregate triad bandwidth (bytes/s).
    pub bw: f64,
}

/// Best per-node params for an era (max vertical scaling).
fn best_params(era: &'static Era) -> (usize, crate::stream::StreamParams) {
    let cells = schedule(era.base_log2, era.base_nt, era.mem_bytes(), era.max_np);
    *cells.last().expect("non-empty schedule")
}

/// Sweep a SuperCloud-like mix: `r` CPU nodes per GPU node, doubling
/// total node count until `max_nodes`.
pub fn sweep(max_nodes: usize) -> Vec<ScalePoint> {
    let cpu = Era::by_label("amd-e9").unwrap();
    let gpu = Era::by_label("h100nvl").unwrap();
    let (cpu_np, cpu_p) = best_params(cpu);
    let (gpu_np, gpu_p) = best_params(gpu);
    let cpu_node = NodeModel::new(cpu, cpu_np, 1);
    let gpu_node = NodeModel::new(gpu, gpu_np, 1);
    let mut out = Vec::new();
    // Start at 4 nodes so the 3:1 CPU:GPU mix (SuperCloud's
    // TX-GAIA-like ratio) stays proportional as the count doubles.
    let mut n = 4usize;
    while n <= max_nodes {
        let ngpu = n / 4;
        let ncpu = n - ngpu;
        let bw = horizontal_triad_bw(&cpu_node, &cpu_p, Lang::Matlab, ncpu)
            + horizontal_triad_bw(&gpu_node, &gpu_p, Lang::Python, ngpu);
        out.push(ScalePoint { nnode_cpu: ncpu, nnode_gpu: ngpu, bw });
        n *= 2;
    }
    out
}

/// First total node count whose aggregate crosses `target` bytes/s.
pub fn nodes_to_reach(target: f64, max_nodes: usize) -> Option<usize> {
    sweep(max_nodes)
        .into_iter()
        .find(|p| p.bw >= target)
        .map(|p| p.nnode_cpu + p.nnode_gpu)
}

/// Render the sweep.
pub fn render(max_nodes: usize) -> String {
    let mut s = String::new();
    s.push_str("HEADLINE — HORIZONTAL SCALING TO >1 PB/s\n");
    s.push_str("| nodes (cpu+gpu) | aggregate triad |\n|---|---|\n");
    for p in sweep(max_nodes) {
        s.push_str(&format!(
            "| {} ({}+{}) | {} |\n",
            p.nnode_cpu + p.nnode_gpu,
            p.nnode_cpu,
            p.nnode_gpu,
            super::fmt_bw(p.bw)
        ));
    }
    match nodes_to_reach(1e15, max_nodes) {
        Some(n) => s.push_str(&format!("\n>1 PB/s reached at {n} nodes (paper: \"hundreds\")\n")),
        None => s.push_str("\n>1 PB/s not reached in this sweep\n"),
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_in_node_count() {
        let pts = sweep(64);
        // Doubling nodes ≈ doubles bandwidth (mix rounding aside).
        for w in pts.windows(2) {
            let ratio = w[1].bw / w[0].bw;
            assert!((1.7..2.3).contains(&ratio), "ratio {ratio}");
        }
    }

    #[test]
    fn pb_per_s_reached_at_hundreds_of_nodes() {
        let n = nodes_to_reach(1e15, 1024).expect("PB/s reachable");
        assert!((64..=1024).contains(&n), "nodes {n}");
    }

    #[test]
    fn render_mentions_pb() {
        assert!(render(1024).contains("PB/s reached"));
    }
}
