//! Table II — single-node STREAM parameters (Nt, N/Np per Np).

use crate::hardware::{Era, ERAS};
use crate::stream::params::{schedule, StreamParams};

/// One era's parameter row.
#[derive(Debug, Clone)]
pub struct Row {
    pub era: &'static Era,
    /// (np, params) pairs, np doubling.
    pub cells: Vec<(usize, StreamParams)>,
}

/// Derive every era's Table II row from the §V sizing rule.
///
/// One published override: the paper's bg-p row (from the earlier
/// mega-scale pMatlab study [46]) holds 2^25 per process through
/// Np = 128, which overcommits the §V 80%-of-memory rule on 2 GB
/// nodes — we reproduce the published cells verbatim for that row.
pub fn rows() -> Vec<Row> {
    ERAS.iter()
        .map(|era| Row {
            era,
            cells: if era.label == "bg-p" {
                (0..8).map(|i| (1usize << i, StreamParams { nt: 10, log2_local: 25 })).collect()
            } else {
                schedule(era.base_log2, era.base_nt, era.mem_bytes(), era.max_np)
            },
        })
        .collect()
}

/// Render Table II as markdown.
pub fn render() -> String {
    let mut s = String::new();
    s.push_str("TABLE II — SINGLE NODE STREAM PARAMETERS (Nt, N/Np)\n");
    s.push_str("| Node Label | Np=1 | 2 | 4 | 8 | 16 | 32 | 64 | 128 |\n");
    s.push_str("|---|---|---|---|---|---|---|---|---|\n");
    for row in rows() {
        s.push_str(&format!("| {} |", row.era.label));
        let mut np = 1usize;
        for _ in 0..8 {
            if let Some((_, p)) = row.cells.iter().find(|(c, _)| *c == np) {
                s.push_str(&format!(" {}, 2^{} |", p.nt, p.log2_local));
            } else {
                s.push_str("  |");
            }
            np *= 2;
        }
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_era_has_a_row() {
        assert_eq!(rows().len(), ERAS.len());
    }

    #[test]
    fn xeon_p8_row_matches_paper() {
        let rows = rows();
        let r = rows.iter().find(|r| r.era.label == "xeon-p8").unwrap();
        // Paper: 10,2^30 | 10,2^30 | 10,2^30 | 20,2^29 | 40,2^28 | 80,2^27
        let want = [(1, 10, 30u32), (2, 10, 30), (4, 10, 30), (8, 20, 29), (16, 40, 28), (32, 80, 27)];
        for (np, nt, log2) in want {
            let (_, p) = r.cells.iter().find(|(c, _)| *c == np).unwrap();
            assert_eq!((p.nt, p.log2_local), (nt, log2), "np={np}");
        }
    }

    #[test]
    fn bgp_row_is_constant_2_25() {
        let rows = rows();
        let r = rows.iter().find(|r| r.era.label == "bg-p").unwrap();
        for (np, p) in &r.cells {
            assert_eq!(p.log2_local, 25, "np={np}");
        }
        // bg-p runs out to Np=128 in the paper.
        assert!(r.cells.iter().any(|(np, _)| *np == 128));
    }

    #[test]
    fn render_mentions_all_eras() {
        let s = render();
        for e in ERAS {
            assert!(s.contains(e.label));
        }
    }
}
