//! `repro bench-diff` — the perf regression gate.
//!
//! Compares two same-schema JSON documents (`bench_remap_v1`,
//! `bench_collective_v1`, `bench_overlap_v1`, `analysis_v1`, ...)
//! field by field. Documents are flattened to `path → number` maps:
//! objects join with `.`, arrays of objects key by their identifying
//! field (`coll`, `op`, `phase`, `np`, ...) so rows still line up
//! when order changes, and everything else keys by index. Whether a
//! change is a *regression* follows from the field's name — bandwidth
//! and hit rates should not fall, latencies and message counts should
//! not rise — and unclassifiable fields are reported but never gated.

use crate::json::Json;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// How a metric is judged.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    HigherBetter,
    LowerBetter,
    /// Informational only — never a regression.
    Neutral,
}

/// Classify a flattened path by its final segment. The convention is
/// already enforced by the emitters: rates end in `*_per_sec` /
/// `*efficiency*` / `*hit*`, costs end in `*_ns` / `*_us` /
/// `*seconds` / `*messages*` / `*miss*` / `*dropped*`.
pub fn direction_of(path: &str) -> Direction {
    let seg = path.rsplit('.').next().unwrap_or(path);
    let higher = ["per_sec", "hit", "efficiency", "speedup", "bandwidth"];
    if higher.iter().any(|h| seg.contains(h)) {
        return Direction::HigherBetter;
    }
    let lower_suffix = ["_ns", "_us", "_ms", "seconds"];
    let lower_any = ["latency", "messages", "msgs", "miss", "dropped", "skew", "unmatched"];
    if lower_suffix.iter().any(|s| seg.ends_with(s))
        || lower_any.iter().any(|s| seg.contains(s))
    {
        return Direction::LowerBetter;
    }
    Direction::Neutral
}

/// Keys that identify a row of an array-of-objects (first match
/// wins): flattening by them keeps rows aligned across reorderings.
const ROW_KEYS: [&str; 9] =
    ["coll", "op", "phase", "hist", "label", "kind", "transport", "np", "rank"];

fn row_key(item: &Json) -> Option<String> {
    let m = item.obj()?;
    for k in ROW_KEYS {
        if let Some(v) = m.get(k) {
            if let Some(s) = v.as_str() {
                return Some(format!("{k}={s}"));
            }
            if let Some(n) = v.as_f64() {
                return Some(format!("{k}={n}"));
            }
        }
    }
    None
}

/// Flatten every numeric leaf of `doc` into `out` under `prefix`.
fn flatten(doc: &Json, prefix: &str, out: &mut BTreeMap<String, f64>) {
    if let Some(v) = doc.as_f64() {
        out.insert(prefix.to_string(), v);
        return;
    }
    if let Some(m) = doc.obj() {
        for (k, v) in m {
            let p = if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
            flatten(v, &p, out);
        }
        return;
    }
    if let Some(items) = doc.items() {
        for (i, item) in items.iter().enumerate() {
            let key = row_key(item).unwrap_or_else(|| i.to_string());
            let p = if prefix.is_empty() { key.clone() } else { format!("{prefix}.{key}") };
            flatten(item, &p, out);
        }
    }
    // Strings / bools / nulls carry no comparable value.
}

/// One compared metric.
#[derive(Debug, Clone)]
pub struct Row {
    pub path: String,
    pub old: f64,
    pub new: f64,
    /// Signed relative change in percent (positive = value went up);
    /// `None` when the baseline is 0.
    pub delta_pct: Option<f64>,
    pub direction: Direction,
    /// Regressed beyond the threshold.
    pub regressed: bool,
}

/// The full field-by-field comparison.
#[derive(Debug)]
pub struct Diff {
    pub schema: String,
    pub rows: Vec<Row>,
    /// Paths present in only one document.
    pub only_old: Vec<String>,
    pub only_new: Vec<String>,
    pub max_regress_pct: f64,
}

impl Diff {
    pub fn regressions(&self) -> usize {
        self.rows.iter().filter(|r| r.regressed).count()
    }

    /// The comparison table: regressions first, then the largest
    /// moves; unchanged fields are summarized, not listed.
    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "bench-diff: schema {}  {} field(s)  {} regression(s) (threshold {}%)",
            self.schema,
            self.rows.len(),
            self.regressions(),
            self.max_regress_pct
        );
        let mut shown: Vec<&Row> = self
            .rows
            .iter()
            .filter(|r| r.delta_pct.map(|d| d.abs() > 1e-9).unwrap_or(false))
            .collect();
        shown.sort_by(|a, b| {
            b.regressed.cmp(&a.regressed).then(
                b.delta_pct
                    .unwrap_or(0.0)
                    .abs()
                    .partial_cmp(&a.delta_pct.unwrap_or(0.0).abs())
                    .unwrap_or(std::cmp::Ordering::Equal),
            )
        });
        if shown.is_empty() {
            let _ = writeln!(s, "no changed metrics");
        } else {
            let _ = writeln!(
                s,
                "{:<52} {:>14} {:>14} {:>9}  {}",
                "metric", "old", "new", "delta", "verdict"
            );
            for r in shown {
                let verdict = if r.regressed {
                    "REGRESSED"
                } else {
                    match r.direction {
                        Direction::Neutral => "-",
                        _ => "ok",
                    }
                };
                let _ = writeln!(
                    s,
                    "{:<52} {:>14.4} {:>14.4} {:>8.1}%  {}",
                    r.path,
                    r.old,
                    r.new,
                    r.delta_pct.unwrap_or(0.0),
                    verdict
                );
            }
        }
        for p in &self.only_old {
            let _ = writeln!(s, "only in OLD: {p}");
        }
        for p in &self.only_new {
            let _ = writeln!(s, "only in NEW: {p}");
        }
        s
    }
}

/// Compare two parsed documents. Errors when the schemas differ —
/// cross-schema diffs line up nothing and would silently pass.
pub fn diff_docs(old: &Json, new: &Json, max_regress_pct: f64) -> Result<Diff, String> {
    let schema_of = |d: &Json| {
        d.get("schema").and_then(|s| s.as_str()).map(str::to_string).unwrap_or_default()
    };
    let (so, sn) = (schema_of(old), schema_of(new));
    if so != sn {
        return Err(format!("schema mismatch: OLD is '{so}', NEW is '{sn}'"));
    }
    let mut fo = BTreeMap::new();
    let mut fn_ = BTreeMap::new();
    flatten(old, "", &mut fo);
    flatten(new, "", &mut fn_);
    let mut rows = Vec::new();
    let mut only_old = Vec::new();
    for (path, &ov) in &fo {
        let Some(&nv) = fn_.get(path) else {
            only_old.push(path.clone());
            continue;
        };
        let direction = direction_of(path);
        let delta_pct = if ov != 0.0 { Some(100.0 * (nv - ov) / ov.abs()) } else { None };
        let regressed = match (direction, delta_pct) {
            (Direction::HigherBetter, Some(d)) => d < -max_regress_pct,
            (Direction::LowerBetter, Some(d)) => d > max_regress_pct,
            _ => false,
        };
        rows.push(Row { path: path.clone(), old: ov, new: nv, delta_pct, direction, regressed });
    }
    let only_new: Vec<String> =
        fn_.keys().filter(|k| !fo.contains_key(*k)).cloned().collect();
    Ok(Diff { schema: so, rows, only_old, only_new, max_regress_pct })
}

/// Load, parse, and compare two JSON files.
pub fn diff_files(old_path: &str, new_path: &str, max_regress_pct: f64) -> Result<Diff, String> {
    let load = |p: &str| -> Result<Json, String> {
        let text = std::fs::read_to_string(p).map_err(|e| format!("{p}: {e}"))?;
        Json::parse(text.trim()).map_err(|e| format!("{p}: {e}"))
    };
    diff_docs(&load(old_path)?, &load(new_path)?, max_regress_pct)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn direction_classification_follows_field_names() {
        assert_eq!(direction_of("ops.remap.gb_per_sec"), Direction::HigherBetter);
        assert_eq!(direction_of("pool.hit_rate"), Direction::HigherBetter);
        assert_eq!(direction_of("overlap_efficiency"), Direction::HigherBetter);
        assert_eq!(direction_of("total_seconds"), Direction::LowerBetter);
        assert_eq!(direction_of("latency_us"), Direction::LowerBetter);
        assert_eq!(direction_of("wire.messages"), Direction::LowerBetter);
        assert_eq!(direction_of("dropped"), Direction::LowerBetter);
        // "ranks" must NOT be misread as a *_ns cost.
        assert_eq!(direction_of("ranks"), Direction::Neutral);
        assert_eq!(direction_of("np"), Direction::Neutral);
    }

    #[test]
    fn bandwidth_drop_beyond_threshold_regresses() {
        let old = Json::parse(
            "{\"schema\":\"bench_overlap_v1\",\"remap\":{\"gb_per_sec\":10.0,\
             \"total_seconds\":1.0}}",
        )
        .unwrap();
        let new = Json::parse(
            "{\"schema\":\"bench_overlap_v1\",\"remap\":{\"gb_per_sec\":8.0,\
             \"total_seconds\":1.01}}",
        )
        .unwrap();
        let d = diff_docs(&old, &new, 10.0).unwrap();
        // -20% bandwidth regresses; +1% seconds is within threshold.
        assert_eq!(d.regressions(), 1);
        let r = d.rows.iter().find(|r| r.path.contains("gb_per_sec")).unwrap();
        assert!(r.regressed);
        assert!(d.render().contains("REGRESSED"));
    }

    #[test]
    fn improvement_and_neutral_fields_never_regress() {
        let old = Json::parse(
            "{\"schema\":\"bench_remap_v1\",\"gb_per_sec\":5.0,\"messages\":100,\"np\":4}",
        )
        .unwrap();
        let new = Json::parse(
            "{\"schema\":\"bench_remap_v1\",\"gb_per_sec\":9.0,\"messages\":50,\"np\":8}",
        )
        .unwrap();
        let d = diff_docs(&old, &new, 10.0).unwrap();
        assert_eq!(d.regressions(), 0, "{:?}", d.rows);
    }

    #[test]
    fn arrays_of_objects_align_by_row_key_not_order() {
        let old = Json::parse(
            "{\"schema\":\"bench_collective_v1\",\"results\":[\
             {\"coll\":\"star\",\"latency_us\":10.0},\
             {\"coll\":\"ring\",\"latency_us\":20.0}]}",
        )
        .unwrap();
        // Same rows, reversed order, ring got 3x slower.
        let new = Json::parse(
            "{\"schema\":\"bench_collective_v1\",\"results\":[\
             {\"coll\":\"ring\",\"latency_us\":60.0},\
             {\"coll\":\"star\",\"latency_us\":10.0}]}",
        )
        .unwrap();
        let d = diff_docs(&old, &new, 10.0).unwrap();
        assert_eq!(d.regressions(), 1);
        let r = d.rows.iter().find(|r| r.regressed).unwrap();
        assert!(r.path.contains("coll=ring"), "{}", r.path);
    }

    #[test]
    fn schema_mismatch_is_an_error() {
        let a = Json::parse("{\"schema\":\"bench_remap_v1\"}").unwrap();
        let b = Json::parse("{\"schema\":\"analysis_v1\"}").unwrap();
        assert!(diff_docs(&a, &b, 10.0).unwrap_err().contains("schema mismatch"));
    }

    #[test]
    fn zero_baseline_is_reported_not_gated() {
        let old = Json::parse("{\"schema\":\"x\",\"dropped\":0}").unwrap();
        let new = Json::parse("{\"schema\":\"x\",\"dropped\":7}").unwrap();
        let d = diff_docs(&old, &new, 10.0).unwrap();
        assert_eq!(d.regressions(), 0);
        assert!(d.rows[0].delta_pct.is_none());
    }
}
