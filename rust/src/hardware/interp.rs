//! Interpreter model — the language-level effects the paper reports.
//!
//! Figure 3 plots Matlab, Octave, and Python separately; §VI explains
//! the one systematic difference: "The Octave interpreter defers the
//! first copy in the Stream benchmark and folds it into triad, which
//! is why the Octave results are generally ~30% lower."

/// High-level language running the benchmark.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Lang {
    Matlab,
    Octave,
    Python,
}

impl Lang {
    pub const ALL: [Lang; 3] = [Lang::Matlab, Lang::Octave, Lang::Python];

    pub fn name(&self) -> &'static str {
        match self {
            Lang::Matlab => "matlab",
            Lang::Octave => "octave",
            Lang::Python => "python",
        }
    }

    /// Per-op wall-time multiplier applied by the interpreter, indexed
    /// [copy, scale, add, triad].
    ///
    /// * Matlab — baseline (vectorized ops hit the math library).
    /// * Python — numpy path, essentially baseline too (the paper's
    ///   Matlab and Python curves track closely).
    /// * Octave — defers Copy (lazy copy-on-write: the timed `C=A` is
    ///   ~free) and pays it inside Triad, whose measured time grows so
    ///   triad bandwidth drops ~30% (1/0.7 ≈ 1.43× time).
    pub fn op_time_factor(&self) -> [f64; 4] {
        match self {
            Lang::Matlab => [1.0, 1.0, 1.0, 1.0],
            Lang::Python => [1.02, 1.02, 1.02, 1.02],
            Lang::Octave => [0.05, 1.0, 1.0, 1.0 / 0.7],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn octave_triad_penalty_is_30_percent() {
        let f = Lang::Octave.op_time_factor();
        // time × 1/0.7 ⇒ bandwidth × 0.7.
        assert!((1.0 / f[3] - 0.7).abs() < 1e-12);
        // ... and the copy is deferred (near-free).
        assert!(f[0] < 0.1);
    }

    #[test]
    fn matlab_is_baseline() {
        assert_eq!(Lang::Matlab.op_time_factor(), [1.0; 4]);
    }

    #[test]
    fn names_unique() {
        let mut names: Vec<_> = Lang::ALL.iter().map(|l| l.name()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 3);
    }
}
