//! Analytic memory-bandwidth model — the simulated engine.
//!
//! Model per node: `Np` processes × `Ntpn` threads stream concurrently.
//! Effective node bandwidth for `k` active cores is a saturating
//! roofline:
//!
//! ```text
//! bw(k) = min(k · core_bw, node_bw) · contention(k)
//! ```
//!
//! with a mild contention term past saturation (shared memory
//! controllers lose a few percent under full load — visible in the
//! paper's Figure 3 as the flat-with-slight-droop region). Horizontal
//! scaling multiplies by the node count: the same-map STREAM design
//! communicates nothing, so aggregate bandwidth is exactly linear in
//! nodes (the paper's "linear horizontal scaling" observation).

use super::era::Era;
use super::interp::Lang;
use crate::stream::timing::OpTimes;
use crate::stream::validate::{ValidationReport, STREAM_Q};
use crate::stream::{StreamParams, StreamResult};

/// Resolved per-run view of one node's memory system.
#[derive(Debug, Clone, Copy)]
pub struct NodeModel {
    pub era: &'static Era,
    /// Processes per node.
    pub nppn: usize,
    /// Threads per process.
    pub ntpn: usize,
}

impl NodeModel {
    pub fn new(era: &'static Era, nppn: usize, ntpn: usize) -> Self {
        assert!(nppn >= 1 && ntpn >= 1);
        NodeModel { era, nppn, ntpn }
    }

    /// Active streaming cores (GPU rows: one "core" = one GPU).
    pub fn active_cores(&self) -> usize {
        let k = self.nppn * self.ntpn;
        if self.era.cores == 0 {
            k // GPU: nppn counts GPUs
        } else {
            k.min(self.era.cores)
        }
    }

    /// Effective aggregate node bandwidth (bytes/s) for this run shape.
    ///
    /// Smooth saturating roofline: a p-norm soft-min of the linear
    /// (cores × per-core) ramp and the node ceiling,
    /// `(linear^-p + node^-p)^(-1/p)` with p = 4 — monotone
    /// non-decreasing in core count, asymptoting at `node_bw`, with a
    /// soft knee like the measured curves in Figure 3.
    pub fn node_bandwidth(&self) -> f64 {
        let k = self.active_cores();
        softmin4(k as f64 * self.era.core_bw, self.era.node_bw)
    }

    /// Per-process share of the node bandwidth.
    pub fn per_process_bandwidth(&self) -> f64 {
        self.node_bandwidth() / self.nppn as f64
    }
}

/// p-norm soft minimum (p = 16): smooth, monotone in both arguments,
/// ≤ min(a, b), within ~4% of min at the knee (a = b) and converging
/// to min rapidly away from it. Computed in ratio form for stability.
#[inline]
fn softmin4(a: f64, b: f64) -> f64 {
    let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
    lo * (1.0 + (lo / hi).powi(16)).powf(-1.0 / 16.0)
}

/// Simulate one process's STREAM run on `node` in `lang`.
///
/// Produces the same [`StreamResult`] shape the native engine emits —
/// the reporting stack cannot tell the difference (by design).
pub fn simulate_stream(node: &NodeModel, params: &StreamParams, lang: Lang) -> StreamResult {
    let n_local = params.local_len();
    let nt = params.nt;
    let share = node.per_process_bandwidth();
    let factors = lang.op_time_factor();
    // §III byte counts per iteration.
    let bytes = [
        16.0 * n_local as f64,
        16.0 * n_local as f64,
        24.0 * n_local as f64,
        24.0 * n_local as f64,
    ];
    let t = |op: usize| bytes[op] * nt as f64 / share * factors[op];
    let times = OpTimes { copy: t(0), scale: t(1), add: t(2), triad: t(3) };
    StreamResult {
        n_global: n_local * node.nppn,
        n_local,
        nt,
        width: 8,
        // Era models emulate the host execution path.
        backend: crate::backend::BackendKind::Host,
        times,
        // The simulated engine runs no arithmetic; validation is
        // vacuously exact (the real engines actually check).
        validation: ValidationReport { passed: true, err_a: 0.0, err_b: 0.0, err_c: 0.0 },
    }
}

/// Simulate a whole node: `nppn` identical process results.
pub fn simulate_node(node: &NodeModel, params: &StreamParams, lang: Lang) -> Vec<StreamResult> {
    (0..node.nppn).map(|_| simulate_stream(node, params, lang)).collect()
}

/// Aggregate triad bandwidth of `nnode` identical nodes (bytes/s).
/// Linear by construction (no inter-node communication).
pub fn horizontal_triad_bw(node: &NodeModel, params: &StreamParams, lang: Lang, nnode: usize) -> f64 {
    let per_node = crate::stream::aggregate(&simulate_node(node, params, lang))
        .expect("nppn >= 1")
        .triad_bw();
    per_node * nnode as f64
}

/// Convenience: q is irrelevant to the simulated timing but part of
/// the workload definition; expose it for symmetry with real engines.
pub fn sim_q() -> f64 {
    STREAM_Q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::era::Era;

    fn p(log2: u32, nt: usize) -> StreamParams {
        StreamParams { nt, log2_local: log2 }
    }

    #[test]
    fn single_core_bw_close_to_calibration() {
        let era = Era::by_label("xeon-p8").unwrap();
        let node = NodeModel::new(era, 1, 1);
        let r = simulate_stream(&node, &p(20, 10), Lang::Matlab);
        let bw = r.triad_bw();
        assert!((bw - era.core_bw).abs() / era.core_bw < 0.1, "bw {bw}");
    }

    #[test]
    fn node_saturates_at_node_bw() {
        let era = Era::by_label("xeon-p8").unwrap();
        let node = NodeModel::new(era, 48, 1);
        let agg = crate::stream::aggregate(&simulate_node(&node, &p(20, 10), Lang::Matlab)).unwrap();
        let bw = agg.triad_bw();
        assert!(bw <= era.node_bw * 1.001, "bw {bw}");
        assert!(bw >= era.node_bw * 0.85, "bw {bw}");
    }

    #[test]
    fn vertical_scaling_monotone_until_knee() {
        let era = Era::by_label("amd-e9").unwrap();
        let mut last = 0.0;
        for np in [1usize, 2, 4, 8, 16, 32] {
            let node = NodeModel::new(era, np, 1);
            let bw = crate::stream::aggregate(&simulate_node(&node, &p(20, 10), Lang::Matlab))
                .unwrap()
                .triad_bw();
            assert!(bw >= last * 0.999, "np={np} bw {bw} < last {last}");
            last = bw;
        }
    }

    #[test]
    fn octave_triad_is_30pct_lower() {
        let era = Era::by_label("xeon-g6").unwrap();
        let node = NodeModel::new(era, 1, 1);
        let m = simulate_stream(&node, &p(20, 10), Lang::Matlab).triad_bw();
        let o = simulate_stream(&node, &p(20, 10), Lang::Octave).triad_bw();
        assert!((o / m - 0.7).abs() < 0.01, "ratio {}", o / m);
    }

    #[test]
    fn horizontal_scaling_is_linear() {
        let era = Era::by_label("xeon-p8").unwrap();
        let node = NodeModel::new(era, 32, 1);
        let one = horizontal_triad_bw(&node, &p(27, 80), Lang::Matlab, 1);
        let hundred = horizontal_triad_bw(&node, &p(27, 80), Lang::Matlab, 100);
        assert!((hundred / one - 100.0).abs() < 1e-9);
    }

    #[test]
    fn petabyte_headline_reachable() {
        // Paper: hundreds of SuperCloud nodes sustain > 1 PB/s. A
        // SuperCloud-scale mix needs a few hundred modern nodes:
        // 256 amd-e9 (0.36 TB/s each) + 64 dual-H100 NVL (7.2 TB/s).
        let cpu = NodeModel::new(Era::by_label("amd-e9").unwrap(), 48, 1);
        let gpu = NodeModel::new(Era::by_label("h100nvl").unwrap(), 2, 1);
        let total = horizontal_triad_bw(&cpu, &p(29, 40), Lang::Matlab, 256)
            + horizontal_triad_bw(&gpu, &p(30, 1000), Lang::Python, 64);
        assert!(total > 0.5e15, "total {total}"); // approaching PB/s
    }

    #[test]
    fn gpu_node_uses_gpu_count_as_cores() {
        let era = Era::by_label("h100nvl").unwrap();
        let one = NodeModel::new(era, 1, 1);
        let two = NodeModel::new(era, 2, 1);
        let b1 = simulate_stream(&one, &p(30, 10), Lang::Python).triad_bw();
        let agg2 = crate::stream::aggregate(&simulate_node(&two, &p(30, 10), Lang::Python))
            .unwrap()
            .triad_bw();
        assert!(agg2 > b1 * 1.8, "2 GPUs ≈ 2x: {b1} -> {agg2}");
    }
}
