//! Hardware substrate — Table I and the analytic memory model.
//!
//! The paper's testbed is MIT SuperCloud hardware spanning two decades
//! (plus Argonne's Blue Gene/P). That hardware is not available here;
//! per the substitution rule (DESIGN.md §3), [`era`] encodes Table I
//! verbatim and [`model`] provides a STREAM-calibrated analytic
//! bandwidth model that drives the *simulated* engine for the temporal
//! and many-node experiments. The measurement machinery above it
//! (params schedule, validation, aggregation, reporting) is identical
//! to the real-measurement path, so a future run on real hardware
//! swaps engines without touching anything else.

pub mod era;
pub mod interp;
pub mod model;

pub use era::{Era, EraKind, MemKind, ERAS};
pub use interp::Lang;
pub use model::{horizontal_triad_bw, simulate_node, simulate_stream, NodeModel};
