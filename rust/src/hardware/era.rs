//! Table I — computer hardware specifications, encoded verbatim, plus
//! the STREAM-calibrated bandwidth envelopes the analytic model uses.
//!
//! Bandwidth calibration sources: the paper's own Figure 3/4 readings
//! (10× core / 100× node over 20 years, 5× GPU node over ~5 years,
//! PB/s on hundreds of nodes) and published STREAM numbers for each
//! part. Absolute values are envelopes, not measurements — DESIGN.md
//! records the substitution.

/// Memory technology (Table I "Memory Part").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemKind {
    Ddr2,
    Ddr4,
    Ddr5,
    Hbm2,
    Hbm3,
}

/// Node class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EraKind {
    Cpu,
    Gpu,
}

/// One row of Table I, extended with calibrated bandwidth envelopes.
#[derive(Debug, Clone, Copy)]
pub struct Era {
    /// Node label ("amd-e9", "xeon-p8", ...).
    pub label: &'static str,
    /// Hardware era (year).
    pub year: u32,
    /// Processor part description.
    pub part: &'static str,
    /// Clock in GHz.
    pub clock_ghz: f64,
    /// Core count (0 for GPU rows — the paper leaves them blank).
    pub cores: usize,
    pub mem: MemKind,
    /// Memory size in GB.
    pub mem_gb: u64,
    pub kind: EraKind,
    /// Sustained single-core STREAM triad bandwidth (bytes/s).
    pub core_bw: f64,
    /// Sustained whole-node STREAM triad bandwidth (bytes/s).
    pub node_bw: f64,
    /// Table II base: log2 of per-process base vector length.
    pub base_log2: u32,
    /// Table II base trial count.
    pub base_nt: usize,
    /// Max process count benchmarked within the node (Table II row width).
    pub max_np: usize,
    /// Physical nodes this Table I entry spans (1 for all rows except
    /// bg-p, which is a 32-node Blue Gene/P partition; its `node_bw`
    /// and `cores` cover the whole partition).
    pub nodes_in_entry: usize,
}

impl Era {
    pub fn mem_bytes(&self) -> u64 {
        self.mem_gb * (1 << 30)
    }

    pub fn is_gpu(&self) -> bool {
        self.kind == EraKind::Gpu
    }

    /// Look up an era by label.
    pub fn by_label(label: &str) -> Option<&'static Era> {
        ERAS.iter().find(|e| e.label == label)
    }
}

/// Table I, top-to-bottom. GPU rows sit below their host systems.
pub static ERAS: &[Era] = &[
    Era {
        label: "amd-e9",
        year: 2024,
        part: "Dual AMD EPYC 9254",
        clock_ghz: 2.9,
        cores: 48,
        mem: MemKind::Ddr5,
        mem_gb: 750,
        kind: EraKind::Cpu,
        core_bw: 22.0e9,
        node_bw: 360.0e9,
        base_log2: 30,
        base_nt: 20,
        max_np: 32,
        nodes_in_entry: 1,
    },
    Era {
        label: "h100nvl",
        year: 2024,
        part: "Dual Nvidia H100 NVL",
        clock_ghz: 1.7,
        cores: 0,
        mem: MemKind::Hbm3,
        mem_gb: 188,
        kind: EraKind::Gpu,
        core_bw: 3.6e12, // one GPU ≈ one "core" slot
        node_bw: 7.2e12,
        base_log2: 30,
        base_nt: 1000,
        max_np: 2,
        nodes_in_entry: 1,
    },
    Era {
        label: "xeon-p8",
        year: 2020,
        part: "Dual Xeon Platinum 8260",
        clock_ghz: 2.4,
        cores: 48,
        mem: MemKind::Ddr4,
        mem_gb: 192,
        kind: EraKind::Cpu,
        core_bw: 13.0e9,
        node_bw: 220.0e9,
        base_log2: 30,
        base_nt: 10,
        max_np: 32,
        nodes_in_entry: 1,
    },
    Era {
        label: "xeon-g6",
        year: 2018,
        part: "Dual Xeon Gold 6248",
        clock_ghz: 2.5,
        cores: 40,
        mem: MemKind::Ddr4,
        mem_gb: 384,
        kind: EraKind::Cpu,
        core_bw: 12.5e9,
        node_bw: 180.0e9,
        base_log2: 30,
        base_nt: 10,
        max_np: 32,
        nodes_in_entry: 1,
    },
    Era {
        label: "v100",
        year: 2018,
        part: "Dual Nvidia V100",
        clock_ghz: 1.2,
        cores: 0,
        mem: MemKind::Hbm2,
        mem_gb: 64,
        kind: EraKind::Gpu,
        core_bw: 0.72e12,
        node_bw: 1.44e12,
        base_log2: 29,
        base_nt: 1000,
        max_np: 2,
        nodes_in_entry: 1,
    },
    Era {
        label: "xeon-e5",
        year: 2014,
        part: "Dual Xeon E5-2683 v3",
        clock_ghz: 2.0,
        cores: 28,
        mem: MemKind::Ddr4,
        mem_gb: 256,
        kind: EraKind::Cpu,
        core_bw: 10.0e9,
        node_bw: 95.0e9,
        base_log2: 30,
        base_nt: 10,
        max_np: 32,
        nodes_in_entry: 1,
    },
    Era {
        label: "bg-p",
        year: 2009,
        part: "32 x PowerPC 450",
        clock_ghz: 0.85,
        cores: 128,
        mem: MemKind::Ddr2,
        mem_gb: 2,
        kind: EraKind::Cpu,
        core_bw: 2.0e9,
        node_bw: 8.5e9 * 32.0, // 32-node partition, 13.6 GB/s peak each
        base_log2: 25,
        base_nt: 10,
        max_np: 128,
        nodes_in_entry: 32,
    },
    Era {
        label: "xeon-p4",
        year: 2005,
        part: "Dual Xeon P4",
        clock_ghz: 2.8,
        cores: 2,
        mem: MemKind::Ddr2,
        mem_gb: 4,
        kind: EraKind::Cpu,
        core_bw: 2.2e9,
        node_bw: 3.6e9,
        base_log2: 25,
        base_nt: 10,
        max_np: 2,
        nodes_in_entry: 1,
    },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_eight_rows() {
        assert_eq!(ERAS.len(), 8);
    }

    #[test]
    fn lookup_by_label() {
        assert_eq!(Era::by_label("xeon-p8").unwrap().year, 2020);
        assert!(Era::by_label("nope").is_none());
    }

    #[test]
    fn paper_temporal_ratios_hold() {
        // 10x CPU-core bandwidth over 20 years (§VI / Fig. 4).
        let p4 = Era::by_label("xeon-p4").unwrap();
        let e9 = Era::by_label("amd-e9").unwrap();
        let core_ratio = e9.core_bw / p4.core_bw;
        assert!((5.0..20.0).contains(&core_ratio), "core ratio {core_ratio}");
        // 100x CPU-node bandwidth over 20 years.
        let node_ratio = e9.node_bw / p4.node_bw;
        assert!((50.0..200.0).contains(&node_ratio), "node ratio {node_ratio}");
        // 5x GPU-node bandwidth over ~5 years.
        let v = Era::by_label("v100").unwrap();
        let h = Era::by_label("h100nvl").unwrap();
        let gpu_ratio = h.node_bw / v.node_bw;
        assert!((3.0..8.0).contains(&gpu_ratio), "gpu ratio {gpu_ratio}");
    }

    #[test]
    fn gpu_rows_marked() {
        assert!(Era::by_label("v100").unwrap().is_gpu());
        assert!(Era::by_label("h100nvl").unwrap().is_gpu());
        assert!(!Era::by_label("bg-p").unwrap().is_gpu());
    }

    #[test]
    fn node_bw_at_least_core_bw() {
        for e in ERAS {
            assert!(e.node_bw >= e.core_bw, "{}", e.label);
        }
    }
}
