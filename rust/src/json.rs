//! Minimal JSON parser/emitter (serde is unavailable offline; the
//! codec is part of the substrate inventory). Supports the full JSON
//! grammar minus exotic number forms; good for `manifest.json`, run
//! configs, and report emission.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn items(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { at: self.i, msg: msg.into() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // UTF-8 passthrough: copy the full codepoint.
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|_| {
                        JsonError { at: start, msg: "invalid utf8".into() }
                    })?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { at: start, msg: format!("bad number '{s}'") })
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write!(f, "{}", escape(s)),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", escape(k))?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_shape() {
        let j = Json::parse(
            r#"{"n": 65536, "nt": 10, "dtype": "f64",
                "artifacts": {"copy": {"file": "copy.hlo.txt", "outputs": 1}}}"#,
        )
        .unwrap();
        assert_eq!(j.get("n").unwrap().as_usize(), Some(65536));
        assert_eq!(
            j.get("artifacts")
                .unwrap()
                .get("copy")
                .unwrap()
                .get("file")
                .unwrap()
                .as_str(),
            Some("copy.hlo.txt")
        );
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"a":[1,2.5,-3],"b":"x\"y","c":true,"d":null,"e":{"f":[]}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse(r#"{"a": }"#).is_err());
        assert!(Json::parse("[1] trailing").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(j.as_str(), Some("café ☕"));
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("0").unwrap().as_usize(), Some(0));
    }
}
