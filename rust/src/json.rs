//! Minimal JSON parser/emitter (serde is unavailable offline; the
//! codec is part of the substrate inventory). Supports the full JSON
//! grammar minus exotic number forms; good for `manifest.json`, run
//! configs, and report emission.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn items(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { at: self.i, msg: msg.into() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // UTF-8 passthrough: copy the full codepoint.
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|_| {
                        JsonError { at: start, msg: "invalid utf8".into() }
                    })?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { at: start, msg: format!("bad number '{s}'") })
    }
}

// ---------------------------------------------------------------------------
// Incremental (push) parsing
// ---------------------------------------------------------------------------

/// One parse event from [`PushParser`]. String payloads borrow the
/// parser's token buffer and are valid only inside the callback.
#[derive(Debug, PartialEq)]
pub enum JsonEvent<'a> {
    ObjBegin,
    ObjEnd,
    ArrBegin,
    ArrEnd,
    /// An object key (always followed by its value's events).
    Key(&'a str),
    Str(&'a str),
    Num(f64),
    Bool(bool),
    Null,
}

/// Maximum container nesting the push parser accepts. Deeper input is
/// an error, never a crash.
pub const MAX_DEPTH: usize = 512;

/// Maximum bytes buffered for a single token (string or number).
/// Bounds memory on adversarial input: the parser's resident state is
/// one token plus the container stack, never the document.
pub const MAX_TOKEN_BYTES: usize = 1 << 26;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Frame {
    Obj,
    Arr,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Mode {
    /// Expecting a value (top level, after `[`, `,` in an array, or
    /// `:` in an object).
    Value,
    /// Right after `[`: a value or an immediate `]`.
    ArrValueOrEnd,
    /// Right after `{`: a key or an immediate `}`.
    ObjKeyOrEnd,
    /// After `,` in an object: a key is required.
    ObjKey,
    /// After a key: `:` is required.
    ObjColon,
    ObjCommaOrEnd,
    ArrCommaOrEnd,
    /// Inside a string token.
    Str { is_key: bool },
    /// After a backslash inside a string.
    StrEscape { is_key: bool },
    /// Inside a `\u` escape, accumulating hex digits.
    StrHex { is_key: bool, n: u8, code: u32 },
    /// Inside a number token.
    Num,
    /// Inside `true` / `false` / `null`.
    Lit { lit: &'static str, pos: usize },
}

/// Event-driven incremental JSON parser over byte slices.
///
/// Feed arbitrary chunks — a network drain, a 7-byte-at-a-time test —
/// and receive [`JsonEvent`]s as tokens complete; the parse result is
/// identical no matter where the input is split. Resident state is
/// bounded by the current token plus the container stack (never the
/// document), capped by [`MAX_TOKEN_BYTES`] / [`MAX_DEPTH`] so
/// malformed or adversarial input errors instead of exhausting
/// memory. After the final chunk call [`PushParser::finish`], which
/// completes a trailing number and rejects truncated input.
///
/// Grammar and semantics match [`Json::parse`] (loose number runs,
/// `\u` escapes with U+FFFD fallback, UTF-8 validation); the
/// whole-document API stays for small configs, this one is for
/// streams. Multiple whitespace-separated top-level values are
/// accepted — that is exactly NDJSON; [`StreamDocs`] builds on it.
pub struct PushParser {
    stack: Vec<Frame>,
    mode: Mode,
    tok: Vec<u8>,
    /// Absolute byte offset across feeds (error positions).
    pos: usize,
    failed: bool,
}

impl Default for PushParser {
    fn default() -> Self {
        Self::new()
    }
}

impl PushParser {
    pub fn new() -> PushParser {
        PushParser { stack: Vec::new(), mode: Mode::Value, tok: Vec::new(), pos: 0, failed: false }
    }

    /// Bytes currently buffered for an in-progress token.
    pub fn buffered_bytes(&self) -> usize {
        self.tok.len()
    }

    fn fail(&mut self, msg: &str) -> JsonError {
        self.failed = true;
        JsonError { at: self.pos, msg: msg.into() }
    }

    fn after_value(&mut self) {
        self.mode = match self.stack.last() {
            Some(Frame::Obj) => Mode::ObjCommaOrEnd,
            Some(Frame::Arr) => Mode::ArrCommaOrEnd,
            None => Mode::Value,
        };
    }

    fn push_frame(&mut self, f: Frame) -> Result<(), JsonError> {
        if self.stack.len() >= MAX_DEPTH {
            return Err(self.fail("nesting too deep"));
        }
        self.stack.push(f);
        Ok(())
    }

    fn finish_number(&mut self, f: &mut impl FnMut(JsonEvent<'_>)) -> Result<(), JsonError> {
        // The token is a run of [0-9.eE+-] — always ASCII.
        let s = std::str::from_utf8(&self.tok).expect("number token is ascii");
        match s.parse::<f64>() {
            Ok(n) => {
                f(JsonEvent::Num(n));
                self.tok.clear();
                self.after_value();
                Ok(())
            }
            Err(_) => {
                let msg = format!("bad number '{s}'");
                Err(self.fail(&msg))
            }
        }
    }

    fn grow_tok(&mut self, extra: usize) -> Result<(), JsonError> {
        if self.tok.len() + extra > MAX_TOKEN_BYTES {
            return Err(self.fail("token too large"));
        }
        Ok(())
    }

    /// Parse the next chunk, invoking `f` for each completed event.
    /// An error poisons the parser; later feeds keep failing.
    pub fn feed(
        &mut self,
        bytes: &[u8],
        mut f: impl FnMut(JsonEvent<'_>),
    ) -> Result<(), JsonError> {
        self.feed_mut(bytes, &mut f)
    }

    fn feed_mut(
        &mut self,
        bytes: &[u8],
        f: &mut impl FnMut(JsonEvent<'_>),
    ) -> Result<(), JsonError> {
        if self.failed {
            return Err(JsonError { at: self.pos, msg: "parser already failed".into() });
        }
        let mut i = 0;
        while i < bytes.len() {
            let c = bytes[i];
            match self.mode {
                Mode::Value
                | Mode::ArrValueOrEnd
                | Mode::ObjKeyOrEnd
                | Mode::ObjKey
                | Mode::ObjColon
                | Mode::ObjCommaOrEnd
                | Mode::ArrCommaOrEnd
                    if matches!(c, b' ' | b'\t' | b'\n' | b'\r') =>
                {
                    i += 1;
                    self.pos += 1;
                }
                Mode::Value | Mode::ArrValueOrEnd => {
                    if self.mode == Mode::ArrValueOrEnd {
                        if c == b']' {
                            self.stack.pop();
                            f(JsonEvent::ArrEnd);
                            self.after_value();
                            i += 1;
                            self.pos += 1;
                            continue;
                        }
                        self.mode = Mode::Value;
                        continue; // reprocess as a value start
                    }
                    match c {
                        b'{' => {
                            self.push_frame(Frame::Obj)?;
                            f(JsonEvent::ObjBegin);
                            self.mode = Mode::ObjKeyOrEnd;
                        }
                        b'[' => {
                            self.push_frame(Frame::Arr)?;
                            f(JsonEvent::ArrBegin);
                            self.mode = Mode::ArrValueOrEnd;
                        }
                        b'"' => {
                            self.tok.clear();
                            self.mode = Mode::Str { is_key: false };
                        }
                        b't' => self.mode = Mode::Lit { lit: "true", pos: 1 },
                        b'f' => self.mode = Mode::Lit { lit: "false", pos: 1 },
                        b'n' => self.mode = Mode::Lit { lit: "null", pos: 1 },
                        b'-' | b'0'..=b'9' => {
                            self.tok.clear();
                            self.tok.push(c);
                            self.mode = Mode::Num;
                        }
                        _ => return Err(self.fail("unexpected character")),
                    }
                    i += 1;
                    self.pos += 1;
                }
                Mode::ObjKeyOrEnd | Mode::ObjKey => {
                    match c {
                        b'}' if self.mode == Mode::ObjKeyOrEnd => {
                            self.stack.pop();
                            f(JsonEvent::ObjEnd);
                            self.after_value();
                        }
                        b'"' => {
                            self.tok.clear();
                            self.mode = Mode::Str { is_key: true };
                        }
                        _ => return Err(self.fail("expected '\"'")),
                    }
                    i += 1;
                    self.pos += 1;
                }
                Mode::ObjColon => {
                    if c != b':' {
                        return Err(self.fail("expected ':'"));
                    }
                    self.mode = Mode::Value;
                    i += 1;
                    self.pos += 1;
                }
                Mode::ObjCommaOrEnd => {
                    match c {
                        b',' => self.mode = Mode::ObjKey,
                        b'}' => {
                            self.stack.pop();
                            f(JsonEvent::ObjEnd);
                            self.after_value();
                        }
                        _ => return Err(self.fail("expected ',' or '}'")),
                    }
                    i += 1;
                    self.pos += 1;
                }
                Mode::ArrCommaOrEnd => {
                    match c {
                        b',' => self.mode = Mode::Value,
                        b']' => {
                            self.stack.pop();
                            f(JsonEvent::ArrEnd);
                            self.after_value();
                        }
                        _ => return Err(self.fail("expected ',' or ']'")),
                    }
                    i += 1;
                    self.pos += 1;
                }
                Mode::Str { is_key } => {
                    match c {
                        b'"' => {
                            match std::str::from_utf8(&self.tok) {
                                Ok(s) => {
                                    if is_key {
                                        f(JsonEvent::Key(s));
                                    } else {
                                        f(JsonEvent::Str(s));
                                    }
                                }
                                Err(_) => return Err(self.fail("invalid utf8")),
                            }
                            self.tok.clear();
                            if is_key {
                                self.mode = Mode::ObjColon;
                            } else {
                                self.after_value();
                            }
                        }
                        b'\\' => self.mode = Mode::StrEscape { is_key },
                        _ => {
                            self.grow_tok(1)?;
                            self.tok.push(c);
                        }
                    }
                    i += 1;
                    self.pos += 1;
                }
                Mode::StrEscape { is_key } => {
                    let decoded: &[u8] = match c {
                        b'"' => b"\"",
                        b'\\' => b"\\",
                        b'/' => b"/",
                        b'n' => b"\n",
                        b't' => b"\t",
                        b'r' => b"\r",
                        b'b' => &[0x08],
                        b'f' => &[0x0C],
                        b'u' => {
                            self.mode = Mode::StrHex { is_key, n: 0, code: 0 };
                            i += 1;
                            self.pos += 1;
                            continue;
                        }
                        _ => return Err(self.fail("unknown escape")),
                    };
                    self.grow_tok(decoded.len())?;
                    self.tok.extend_from_slice(decoded);
                    self.mode = Mode::Str { is_key };
                    i += 1;
                    self.pos += 1;
                }
                Mode::StrHex { is_key, n, code } => {
                    let d = match c {
                        b'0'..=b'9' => (c - b'0') as u32,
                        b'a'..=b'f' => (c - b'a' + 10) as u32,
                        b'A'..=b'F' => (c - b'A' + 10) as u32,
                        _ => return Err(self.fail("bad \\u escape")),
                    };
                    let code = code << 4 | d;
                    if n == 3 {
                        // Lone surrogates and out-of-range codes fall
                        // back to U+FFFD, matching `Json::parse`.
                        let ch = char::from_u32(code).unwrap_or('\u{fffd}');
                        let mut buf = [0u8; 4];
                        let enc = ch.encode_utf8(&mut buf);
                        self.grow_tok(enc.len())?;
                        self.tok.extend_from_slice(enc.as_bytes());
                        self.mode = Mode::Str { is_key };
                    } else {
                        self.mode = Mode::StrHex { is_key, n: n + 1, code };
                    }
                    i += 1;
                    self.pos += 1;
                }
                Mode::Num => {
                    if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                        self.grow_tok(1)?;
                        self.tok.push(c);
                        i += 1;
                        self.pos += 1;
                    } else {
                        self.finish_number(f)?;
                        // Reprocess `c` under the post-value mode.
                    }
                }
                Mode::Lit { lit, pos } => {
                    if lit.as_bytes().get(pos) == Some(&c) {
                        if pos + 1 == lit.len() {
                            f(match lit {
                                "true" => JsonEvent::Bool(true),
                                "false" => JsonEvent::Bool(false),
                                _ => JsonEvent::Null,
                            });
                            self.after_value();
                        } else {
                            self.mode = Mode::Lit { lit, pos: pos + 1 };
                        }
                        i += 1;
                        self.pos += 1;
                    } else {
                        let msg = format!("expected '{lit}'");
                        return Err(self.fail(&msg));
                    }
                }
            }
        }
        Ok(())
    }

    /// Signal end of input: completes a trailing number token and
    /// rejects truncated strings, literals, or unclosed containers.
    pub fn finish(&mut self, mut f: impl FnMut(JsonEvent<'_>)) -> Result<(), JsonError> {
        if self.failed {
            return Err(JsonError { at: self.pos, msg: "parser already failed".into() });
        }
        if self.mode == Mode::Num {
            self.finish_number(&mut f)?;
        }
        if self.mode == Mode::Value && self.stack.is_empty() {
            Ok(())
        } else {
            Err(self.fail("unexpected end of input"))
        }
    }
}

/// Streaming NDJSON document builder over [`PushParser`]: feed bytes
/// in any chunking, get one [`Json`] per completed top-level value
/// (whitespace/newline separated). Resident memory is the document
/// under construction plus the current token — for line-oriented
/// telemetry that means *the largest line*, not the stream; the
/// observed high-water mark is available as
/// [`StreamDocs::peak_resident_bytes`] so tests can assert the bound.
pub struct StreamDocs {
    p: PushParser,
    build: Vec<(Json, Option<String>)>,
    resident: usize,
    peak: usize,
    docs: usize,
}

impl Default for StreamDocs {
    fn default() -> Self {
        Self::new()
    }
}

fn stream_event(
    build: &mut Vec<(Json, Option<String>)>,
    resident: &mut usize,
    peak: &mut usize,
    docs: &mut usize,
    on_doc: &mut impl FnMut(Json),
    ev: JsonEvent<'_>,
) {
    // Coarse per-node size estimate for the bounded-memory claim.
    fn attach(
        build: &mut Vec<(Json, Option<String>)>,
        resident: &mut usize,
        docs: &mut usize,
        on_doc: &mut impl FnMut(Json),
        v: Json,
    ) {
        match build.last_mut() {
            Some((Json::Obj(m), key)) => {
                let k = key.take().expect("parser emits Key before every member value");
                m.insert(k, v);
            }
            Some((Json::Arr(a), _)) => a.push(v),
            _ => {
                *resident = 0;
                *docs += 1;
                on_doc(v);
            }
        }
    }
    match ev {
        JsonEvent::ObjBegin => {
            *resident += 48;
            build.push((Json::Obj(BTreeMap::new()), None));
        }
        JsonEvent::ArrBegin => {
            *resident += 48;
            build.push((Json::Arr(Vec::new()), None));
        }
        JsonEvent::Key(s) => {
            *resident += s.len() + 32;
            if let Some((_, key)) = build.last_mut() {
                *key = Some(s.to_string());
            }
        }
        JsonEvent::Str(s) => {
            *resident += s.len() + 32;
            attach(build, resident, docs, on_doc, Json::Str(s.to_string()));
        }
        JsonEvent::Num(n) => {
            *resident += 16;
            attach(build, resident, docs, on_doc, Json::Num(n));
        }
        JsonEvent::Bool(b) => {
            *resident += 16;
            attach(build, resident, docs, on_doc, Json::Bool(b));
        }
        JsonEvent::Null => {
            *resident += 16;
            attach(build, resident, docs, on_doc, Json::Null);
        }
        JsonEvent::ObjEnd | JsonEvent::ArrEnd => {
            let (v, _) = build.pop().expect("parser balances container events");
            attach(build, resident, docs, on_doc, v);
        }
    }
    *peak = (*peak).max(*resident);
}

impl StreamDocs {
    pub fn new() -> StreamDocs {
        StreamDocs { p: PushParser::new(), build: Vec::new(), resident: 0, peak: 0, docs: 0 }
    }

    /// Parse the next chunk; `on_doc` fires once per completed
    /// top-level value.
    pub fn feed(&mut self, bytes: &[u8], mut on_doc: impl FnMut(Json)) -> Result<(), JsonError> {
        let build = &mut self.build;
        let resident = &mut self.resident;
        let peak = &mut self.peak;
        let docs = &mut self.docs;
        self.p
            .feed(bytes, |ev| stream_event(build, resident, peak, docs, &mut on_doc, ev))?;
        self.peak = self.peak.max(self.resident + self.p.buffered_bytes());
        Ok(())
    }

    /// Signal end of input: flushes a trailing bare number document
    /// and rejects truncated input.
    pub fn finish(&mut self, mut on_doc: impl FnMut(Json)) -> Result<(), JsonError> {
        let build = &mut self.build;
        let resident = &mut self.resident;
        let peak = &mut self.peak;
        let docs = &mut self.docs;
        self.p
            .finish(|ev| stream_event(build, resident, peak, docs, &mut on_doc, ev))
    }

    /// Completed documents delivered so far.
    pub fn docs(&self) -> usize {
        self.docs
    }

    /// High-water estimate of resident parse state in bytes (the
    /// largest in-flight document + token, not the stream).
    pub fn peak_resident_bytes(&self) -> usize {
        self.peak.max(self.resident + self.p.buffered_bytes())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write!(f, "{}", escape(s)),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", escape(k))?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_shape() {
        let j = Json::parse(
            r#"{"n": 65536, "nt": 10, "dtype": "f64",
                "artifacts": {"copy": {"file": "copy.hlo.txt", "outputs": 1}}}"#,
        )
        .unwrap();
        assert_eq!(j.get("n").unwrap().as_usize(), Some(65536));
        assert_eq!(
            j.get("artifacts")
                .unwrap()
                .get("copy")
                .unwrap()
                .get("file")
                .unwrap()
                .as_str(),
            Some("copy.hlo.txt")
        );
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"a":[1,2.5,-3],"b":"x\"y","c":true,"d":null,"e":{"f":[]}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse(r#"{"a": }"#).is_err());
        assert!(Json::parse("[1] trailing").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(j.as_str(), Some("café ☕"));
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("0").unwrap().as_usize(), Some(0));
    }
}
