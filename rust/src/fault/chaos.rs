//! The end-to-end chaos scenario: kill one worker mid-job, detect,
//! re-deal onto the survivors, verify bit-identity.
//!
//! [`run_chaos`] choreographs an in-process `np`-rank world over
//! [`FaultTransport`]-wrapped channel endpoints:
//!
//! 1. every rank deals a block array and remaps it to a cyclic layout
//!    (epoch 0 — the "job" is mid-flight, data has already moved);
//! 2. the victim's endpoint is killed; its heartbeat responder goes
//!    silent;
//! 3. the leader's [`Detector`] declares it dead within the miss
//!    threshold and broadcasts a survivor list + bumped epoch on the
//!    `NS_FAULT` control step;
//! 4. survivors [`redeal_with`](crate::darray::DarrayT::redeal_with)
//!    onto the shrunk world (epoch 1), refilling the victim's lost
//!    shard from the deterministic generator;
//! 5. every survivor compares its shard against a freshly generated
//!    reference on the survivor map — exactly what a clean run on the
//!    surviving ranks would hold. Bit-identical or the run fails.
//!
//! The same scenario backs the `repro chaos` CLI subcommand, the CI
//! chaos smoke, and the `fault_recovery` integration test — one
//! choreography, three harnesses.

use super::detect::{respond_loop, Detector, DetectorConfig};
use super::inject::{FaultPlan, FaultTransport};
use crate::comm::{tags, ChannelHub, Tag, Transport, WireReader, WireWriter};
use crate::darray::{DarrayT, RemapEngine};
use crate::dmap::{Dmap, Pid};
use crate::element::Element;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Tag carrying the leader's post-detection reconfiguration order
/// (survivor list + new epoch).
pub fn ctrl_tag() -> Tag {
    tags::pack(tags::NS_FAULT, 0, 2)
}

/// What the chaos run observed — enough for a harness (CLI, CI, test)
/// to assert on and report.
#[derive(Debug, Clone)]
pub struct ChaosReport {
    /// The rank that was killed.
    pub killed: Pid,
    /// The ranks that completed the redeal.
    pub survivors: Vec<Pid>,
    /// Probe rounds the leader ran before the verdict.
    pub probe_rounds: u64,
    /// Did every survivor's shard match the clean-survivor reference
    /// bit for bit?
    pub bit_identical: bool,
    /// Global element count of the chaos array.
    pub n_global: usize,
}

/// The deterministic generator every rank (and the refill) draws from.
fn gen_at<T: Element>(g: usize) -> T {
    T::from_f64((g % 97) as f64)
}

fn encode_ctrl(epoch: u64, survivors: &[Pid]) -> Vec<u8> {
    let mut w = WireWriter::new();
    w.put_u64(epoch);
    let pids: Vec<u64> = survivors.iter().map(|&p| p as u64).collect();
    w.put_slice::<u64>(&pids);
    w.finish()
}

fn decode_ctrl(bytes: &[u8]) -> crate::comm::Result<(u64, Vec<Pid>)> {
    let mut r = WireReader::new(bytes);
    let epoch = r.get_u64()?;
    let pids = r.get_vec::<u64>()?;
    Ok((epoch, pids.into_iter().map(|p| p as Pid).collect()))
}

/// Run the kill-one-worker chaos scenario for element type `T`.
///
/// `np` ranks, `victim` (must be a nonzero rank — rank 0 is the
/// leader/detector) killed after the epoch-0 remap, `n` global
/// elements. Returns the report, or a one-line description of the
/// first rank failure. Deterministic: same arguments, same data, same
/// verdict.
pub fn run_chaos<T: Element>(
    np: usize,
    victim: Pid,
    n: usize,
    cfg: DetectorConfig,
) -> Result<ChaosReport, String> {
    let endpoints: Vec<FaultTransport<_>> = ChannelHub::world(np)
        .into_iter()
        .map(|t| FaultTransport::new(t, FaultPlan::default()))
        .collect();
    run_chaos_on::<T, _>(endpoints, victim, n, cfg)
}

/// [`run_chaos`] over caller-built endpoints — the same choreography
/// on any [`Transport`] (the CLI drills shmem and TCP worlds through
/// this). Endpoints must be the full `0..np` world, each already
/// wrapped in a [`FaultTransport`] (the kill switch is the drill's
/// fault).
pub fn run_chaos_on<T: Element, Tr: Transport>(
    endpoints: Vec<FaultTransport<Tr>>,
    victim: Pid,
    n: usize,
    cfg: DetectorConfig,
) -> Result<ChaosReport, String> {
    let np = endpoints.len();
    if np < 2 || victim == 0 || victim >= np {
        return Err(format!(
            "chaos needs np >= 2 and a worker victim in 1..np (np={np}, victim={victim})"
        ));
    }
    let survivors: Vec<Pid> = (0..np).filter(|&p| p != victim).collect();
    let identical = Mutex::new(true);
    let rounds = Mutex::new(0u64);
    let first_err: Mutex<Option<String>> = Mutex::new(None);
    let fail = |pid: Pid, msg: String| {
        let mut slot = first_err.lock().unwrap();
        if slot.is_none() {
            *slot = Some(format!("rank {pid}: {msg}"));
        }
    };

    std::thread::scope(|s| {
        for t in &endpoints {
            let survivors = &survivors;
            let identical = &identical;
            let rounds = &rounds;
            let fail = &fail;
            s.spawn(move || {
                let pid = t.pid();
                crate::obs::set_thread_rank(pid);
                let engine = RemapEngine::new();
                // Phase 1: the job — deal a block array, remap it to a
                // cyclic layout. All ranks alive; must complete clean.
                let src =
                    DarrayT::<T>::from_global_fn(Dmap::block_1d(np), &[n], pid, gen_at::<T>);
                let mut mid = DarrayT::<T>::zeros(Dmap::cyclic_1d(np), &[n], pid);
                if let Err(e) = mid.assign_from_engine(&src, t, 0, &engine) {
                    fail(pid, format!("epoch-0 remap failed: {e}"));
                    return;
                }
                // Phase 2: the fault. The victim's endpoint dies; its
                // responder falls silent and its thread "crashes" out.
                if pid == victim {
                    t.kill_now();
                    return;
                }
                if pid == 0 {
                    // Leader: probe until the victim is declared dead,
                    // then order the survivors into the new epoch.
                    let mut det = Detector::new(0, np, cfg.clone());
                    let cap = cfg.miss_threshold as u64 + 8;
                    while det.rounds() < cap && !det.is_dead(victim) {
                        if let Err(e) = det.probe(t) {
                            fail(pid, format!("probe failed: {e}"));
                            return;
                        }
                    }
                    *rounds.lock().unwrap() = det.rounds();
                    if !det.is_dead(victim) {
                        fail(pid, format!("victim {victim} not declared dead in {cap} rounds"));
                        return;
                    }
                    let order = encode_ctrl(1, survivors);
                    for &p in survivors.iter().filter(|&&p| p != 0) {
                        if let Err(e) = t.send(p, ctrl_tag(), &order) {
                            fail(pid, format!("ctrl send to {p} failed: {e}"));
                            return;
                        }
                    }
                    run_survivor(t, &mid, survivors, 1, &engine, identical, fail);
                    return;
                }
                // Surviving worker: heartbeat responder on a sidecar,
                // main thread waits for the reconfiguration order.
                let stop = AtomicBool::new(false);
                std::thread::scope(|inner| {
                    inner.spawn(|| respond_loop(t, 0, &stop));
                    let order = match t.recv_timeout(0, ctrl_tag(), Duration::from_secs(60)) {
                        Ok(b) => b,
                        Err(e) => {
                            fail(pid, format!("no reconfiguration order: {e}"));
                            stop.store(true, Ordering::Relaxed);
                            return;
                        }
                    };
                    match decode_ctrl(&order) {
                        Ok((epoch, listed)) if listed == *survivors => {
                            run_survivor(t, &mid, survivors, epoch, &engine, identical, fail)
                        }
                        Ok((_, listed)) => {
                            fail(pid, format!("survivor list mismatch: {listed:?}"))
                        }
                        Err(e) => fail(pid, format!("bad reconfiguration order: {e}")),
                    }
                    stop.store(true, Ordering::Relaxed);
                });
            });
        }
    });

    if let Some(e) = first_err.into_inner().unwrap() {
        return Err(e);
    }
    Ok(ChaosReport {
        killed: victim,
        survivors,
        probe_rounds: rounds.into_inner().unwrap(),
        bit_identical: identical.into_inner().unwrap(),
        n_global: n,
    })
}

/// One survivor's share of phase 3: redeal onto the shrunk world and
/// compare against the clean-survivor reference.
fn run_survivor<T: Element>(
    t: &dyn Transport,
    mid: &DarrayT<T>,
    survivors: &[Pid],
    epoch: u64,
    engine: &RemapEngine,
    identical: &Mutex<bool>,
    fail: &dyn Fn(Pid, String),
) {
    let pid = t.pid();
    let redealt = match mid.redeal_with(survivors, t, epoch, engine, gen_at::<T>) {
        Ok(d) => d,
        Err(e) => {
            fail(pid, format!("redeal failed: {e}"));
            return;
        }
    };
    // The reference is what a clean run on exactly the surviving ranks
    // would hold: the same generator dealt over the survivor map.
    let reference = DarrayT::<T>::from_global_fn(
        redealt.map().clone(),
        redealt.shape(),
        pid,
        gen_at::<T>,
    );
    if redealt.loc() != reference.loc() {
        let mut id = identical.lock().unwrap();
        *id = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast() -> DetectorConfig {
        DetectorConfig { interval: Duration::from_millis(10), miss_threshold: 3 }
    }

    #[test]
    fn kill_one_of_four_recovers_bit_identically() {
        let r = run_chaos::<f64>(4, 2, 4096, fast()).unwrap();
        assert_eq!(r.killed, 2);
        assert_eq!(r.survivors, vec![0, 1, 3]);
        assert!(r.bit_identical, "survivor shards must match the clean reference");
        assert!(r.probe_rounds <= fast().miss_threshold as u64 + 8);
    }

    #[test]
    fn victim_choice_is_validated() {
        assert!(run_chaos::<f64>(4, 0, 64, fast()).is_err(), "leader is not killable");
        assert!(run_chaos::<f64>(4, 7, 64, fast()).is_err(), "victim must exist");
        assert!(run_chaos::<f64>(1, 1, 64, fast()).is_err(), "need a worker");
    }

    /// The drill is transport-generic: the same choreography over
    /// shared-memory endpoints recovers bit-identically.
    #[cfg(unix)]
    #[test]
    fn chaos_composes_over_shmem_endpoints() {
        use crate::comm::ShmemTransport;
        let dir = std::env::temp_dir()
            .join(format!("distarray_chaos_shmem_{}", std::process::id()));
        let endpoints: Vec<_> = ShmemTransport::world(&dir, 3)
            .unwrap()
            .into_iter()
            .map(|t| FaultTransport::new(t, FaultPlan::default()))
            .collect();
        let r = run_chaos_on::<f64, _>(endpoints, 1, 2048, fast()).unwrap();
        assert_eq!(r.survivors, vec![0, 2]);
        assert!(r.bit_identical);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn ctrl_order_roundtrips() {
        let b = encode_ctrl(3, &[0, 1, 5]);
        assert_eq!(decode_ctrl(&b).unwrap(), (3, vec![0, 1, 5]));
        assert!(decode_ctrl(&b[..4]).is_err(), "torn order is a clean error");
    }
}
