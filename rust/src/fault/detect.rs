//! Leader-driven heartbeat failure detector.
//!
//! The leader pings every worker each round on the
//! [`NS_FAULT`](crate::comm::tags::NS_FAULT) namespace and tallies
//! consecutive silent rounds per peer; a worker crossing the miss
//! threshold is *declared dead* — a positive verdict the coordinator
//! can act on (reap, redeal, resume) instead of spinning in
//! [`CommError::Timeout`](crate::comm::CommError::Timeout). Workers
//! run [`respond_loop`] on a sidecar thread: echo every ping back as
//! a pong, nothing else — a wedged or killed worker stops echoing and
//! that is the whole detection signal.
//!
//! Pings and pongs are separate steps of the same namespace (epoch 0),
//! so detector traffic can never alias a data stream; the round
//! sequence rides in the payload, and *any* pong arrival counts for
//! its sender — a late pong proves liveness just as well as a prompt
//! one.

use crate::comm::{tags, Result, Tag, Transport};
use crate::dmap::Pid;
use crate::obs::EventKind;
use crate::obs_event;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

/// Tag carrying leader → worker pings.
pub fn ping_tag() -> Tag {
    tags::pack(tags::NS_FAULT, 0, 0)
}

/// Tag carrying worker → leader pongs.
pub fn pong_tag() -> Tag {
    tags::pack(tags::NS_FAULT, 0, 1)
}

/// Detector tuning. `Default` is one round per 100 ms and a verdict
/// after 3 silent rounds — a dead worker is declared within ~300 ms
/// while a worker merely busy for a round survives.
#[derive(Debug, Clone)]
pub struct DetectorConfig {
    /// Length of one probe round (ping, then collect pongs).
    pub interval: Duration,
    /// Consecutive silent rounds before a peer is declared dead.
    pub miss_threshold: u32,
}

impl Default for DetectorConfig {
    fn default() -> DetectorConfig {
        DetectorConfig { interval: Duration::from_millis(100), miss_threshold: 3 }
    }
}

impl DetectorConfig {
    /// Read `DISTARRAY_FAULT_HB_INTERVAL_MS` /
    /// `DISTARRAY_FAULT_HB_MISSES`, defaulting per [`Default`].
    pub fn from_env() -> DetectorConfig {
        let mut cfg = DetectorConfig::default();
        if let Some(ms) = std::env::var("DISTARRAY_FAULT_HB_INTERVAL_MS")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
        {
            cfg.interval = Duration::from_millis(ms.max(1));
        }
        if let Some(n) = std::env::var("DISTARRAY_FAULT_HB_MISSES")
            .ok()
            .and_then(|v| v.parse::<u32>().ok())
        {
            cfg.miss_threshold = n.max(1);
        }
        cfg
    }
}

/// Leader-side detector state: per-peer consecutive-miss counters and
/// the accumulated dead set. Probing is pull-based — the caller runs
/// [`Detector::probe`] once per round from wherever its event loop
/// lives (the coordinator uses a monitor thread).
pub struct Detector {
    cfg: DetectorConfig,
    me: Pid,
    misses: Vec<u32>,
    dead: Vec<bool>,
    round: u64,
}

impl Detector {
    /// A detector at endpoint `t_pid` watching all other PIDs of an
    /// `np`-wide world.
    pub fn new(me: Pid, np: usize, cfg: DetectorConfig) -> Detector {
        Detector { cfg, me, misses: vec![0; np], dead: vec![false; np], round: 0 }
    }

    /// Has `pid` been declared dead?
    pub fn is_dead(&self, pid: Pid) -> bool {
        self.dead[pid]
    }

    /// Every declared-dead PID, ascending.
    pub fn dead(&self) -> Vec<Pid> {
        (0..self.dead.len()).filter(|&p| self.dead[p]).collect()
    }

    /// Every PID not declared dead (self included), ascending — the
    /// survivor group a redeal targets.
    pub fn survivors(&self) -> Vec<Pid> {
        (0..self.dead.len()).filter(|&p| !self.dead[p]).collect()
    }

    /// Completed probe rounds.
    pub fn rounds(&self) -> u64 {
        self.round
    }

    /// Run one probe round: ping every live peer, collect pongs for
    /// one interval, tally misses, and return any *newly* dead PIDs.
    /// A send failure counts as a miss for that peer (a torn-down
    /// endpoint is indistinguishable from silence). Emits
    /// `fault_hb_miss` / `fault_rank_dead` trace events.
    pub fn probe(&mut self, t: &dyn Transport) -> Result<Vec<Pid>> {
        self.round += 1;
        let peers: Vec<Pid> =
            (0..t.np()).filter(|&p| p != self.me && !self.dead[p]).collect();
        if peers.is_empty() {
            return Ok(Vec::new());
        }
        let mut reachable = vec![true; peers.len()];
        for (i, &p) in peers.iter().enumerate() {
            if t.send(p, ping_tag(), &self.round.to_le_bytes()).is_err() {
                reachable[i] = false;
            }
        }
        // Collect pongs until the round interval elapses. Any pong —
        // including one from an earlier round — proves liveness.
        let mut ponged = vec![false; peers.len()];
        let deadline = Instant::now() + self.cfg.interval;
        loop {
            let mut progressed = false;
            for (i, &p) in peers.iter().enumerate() {
                while t.try_recv(p, pong_tag())?.is_some() {
                    ponged[i] = true;
                    progressed = true;
                }
            }
            if ponged.iter().all(|&x| x) || Instant::now() >= deadline {
                break;
            }
            if !progressed {
                std::thread::sleep(Duration::from_millis(1).min(self.cfg.interval / 4));
            }
        }
        let mut newly_dead = Vec::new();
        for (i, &p) in peers.iter().enumerate() {
            if ponged[i] && reachable[i] {
                self.misses[p] = 0;
                continue;
            }
            self.misses[p] += 1;
            obs_event!(
                EventKind::HeartbeatMiss,
                tag: ping_tag(),
                peer: p as u32,
                a: self.misses[p] as u64,
                b: 0
            );
            if self.misses[p] >= self.cfg.miss_threshold {
                self.dead[p] = true;
                newly_dead.push(p);
                obs_event!(
                    EventKind::RankDead,
                    tag: ping_tag(),
                    peer: p as u32,
                    a: self.misses[p] as u64,
                    b: 0
                );
                crate::log!(
                    Warn,
                    "rank {p} declared dead after {} missed heartbeats",
                    self.misses[p]
                );
            }
        }
        Ok(newly_dead)
    }
}

/// Worker-side heartbeat responder: echo every leader ping back as a
/// pong until `stop` is raised or the transport fails (a killed
/// [`FaultTransport`](super::FaultTransport) endpoint exits here,
/// which is exactly how its silence begins). Run on a sidecar thread
/// (`std::thread::scope` — `&dyn Transport` is `Sync`).
pub fn respond_loop(t: &dyn Transport, leader: Pid, stop: &AtomicBool) {
    let poll = Duration::from_millis(25);
    while !stop.load(Ordering::Relaxed) {
        match t.recv_timeout(leader, ping_tag(), poll) {
            Ok(seq) => {
                if t.send(leader, pong_tag(), &seq).is_err() {
                    return;
                }
            }
            Err(crate::comm::CommError::Timeout { .. }) => continue,
            Err(_) => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ChannelHub;
    use crate::fault::{FaultPlan, FaultTransport};

    fn fast() -> DetectorConfig {
        DetectorConfig { interval: Duration::from_millis(5), miss_threshold: 3 }
    }

    #[test]
    fn live_responders_are_never_declared_dead() {
        let mut world = ChannelHub::world(3);
        let t2 = world.pop().unwrap();
        let t1 = world.pop().unwrap();
        let t0 = world.pop().unwrap();
        let stop = AtomicBool::new(false);
        std::thread::scope(|s| {
            s.spawn(|| respond_loop(&t1, 0, &stop));
            s.spawn(|| respond_loop(&t2, 0, &stop));
            let mut det = Detector::new(0, 3, fast());
            for _ in 0..5 {
                assert_eq!(det.probe(&t0).unwrap(), Vec::<Pid>::new());
            }
            assert_eq!(det.survivors(), vec![0, 1, 2]);
            stop.store(true, Ordering::Relaxed);
        });
    }

    #[test]
    fn silent_worker_is_declared_dead_within_threshold() {
        let mut world = ChannelHub::world(3);
        let t2 = world.pop().unwrap();
        let t1 = world.pop().unwrap();
        let t0 = world.pop().unwrap();
        // Rank 2 responds; rank 1 is killed before it ever pongs.
        let t1 = FaultTransport::new(t1, FaultPlan::default());
        t1.kill_now();
        let stop = AtomicBool::new(false);
        std::thread::scope(|s| {
            s.spawn(|| respond_loop(&t1, 0, &stop));
            s.spawn(|| respond_loop(&t2, 0, &stop));
            let cfg = fast();
            let mut det = Detector::new(0, 3, cfg.clone());
            let mut dead = Vec::new();
            for _ in 0..cfg.miss_threshold + 2 {
                dead.extend(det.probe(&t0).unwrap());
                if !dead.is_empty() {
                    break;
                }
            }
            assert_eq!(dead, vec![1]);
            assert!(det.rounds() <= cfg.miss_threshold as u64, "verdict within threshold");
            assert!(det.is_dead(1) && !det.is_dead(2));
            assert_eq!(det.survivors(), vec![0, 2]);
            stop.store(true, Ordering::Relaxed);
        });
    }

    #[test]
    fn one_missed_round_recovers() {
        let mut world = ChannelHub::world(2);
        let t1 = world.pop().unwrap();
        let t0 = world.pop().unwrap();
        let mut det = Detector::new(0, 2, fast());
        // Round 1: nobody answers → one miss, no verdict.
        assert!(det.probe(&t0).unwrap().is_empty());
        assert!(!det.is_dead(1));
        // The worker comes back: drain pings, answer, miss count resets.
        let stop = AtomicBool::new(false);
        std::thread::scope(|s| {
            s.spawn(|| respond_loop(&t1, 0, &stop));
            for _ in 0..5 {
                assert!(det.probe(&t0).unwrap().is_empty());
            }
            stop.store(true, Ordering::Relaxed);
        });
        assert_eq!(det.survivors(), vec![0, 1]);
    }
}
