//! Deterministic fault injection at the transport seam.
//!
//! [`FaultTransport`] wraps any [`Transport`] and perturbs it from a
//! seeded [`prop::Rng`](crate::prop::Rng): probabilistic silent
//! drops, fixed delivery delay, probabilistic truncation, and a hard
//! kill after N operations (or on demand via
//! [`FaultTransport::kill_now`]). The same seed replays the same
//! fault schedule, so every failure path found by a chaos run is a
//! deterministic regression test. Composes over both the channel and
//! file transports — the wrapper only sees the trait.

use crate::comm::{CommError, CommStats, Result, Tag, Transport, TransportKind};
use crate::dmap::Pid;
use crate::prop::Rng;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// The fault schedule: what to inject and when. `Default` injects
/// nothing — a `FaultTransport` over the default plan is a transparent
/// pass-through.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    /// Seed for the per-endpoint fault PRNG (mixed with the PID so
    /// ranks draw independent streams).
    pub seed: u64,
    /// Probability a `send` is silently dropped (receiver never sees
    /// it; sender sees `Ok`).
    pub drop_prob: f64,
    /// Fixed delay applied to every `send` before delivery.
    pub delay: Duration,
    /// Probability a `send` delivers only the first half of its
    /// payload (framing survives, content is torn — exercises the
    /// `Malformed` paths).
    pub truncate_prob: f64,
    /// Kill this endpoint after it completes N send/recv operations;
    /// every operation after that fails `Disconnected(self)`.
    pub kill_after: Option<u64>,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan {
            seed: 1,
            drop_prob: 0.0,
            delay: Duration::ZERO,
            truncate_prob: 0.0,
            kill_after: None,
        }
    }
}

impl FaultPlan {
    /// Build a plan from the `DISTARRAY_FAULT_*` environment knobs,
    /// or `None` when no fault knob is set (the common case — spawned
    /// workers check once at startup):
    ///
    /// * `DISTARRAY_FAULT_SEED` — PRNG seed (default 1)
    /// * `DISTARRAY_FAULT_DROP` — send drop probability
    /// * `DISTARRAY_FAULT_DELAY_MS` — per-send delay
    /// * `DISTARRAY_FAULT_TRUNCATE` — send truncation probability
    /// * `DISTARRAY_FAULT_KILL_AFTER` — kill after N operations
    /// * `DISTARRAY_FAULT_KILL_PID` — restrict the kill to one PID
    ///   (unset: the kill applies to every wrapped endpoint)
    pub fn from_env(pid: Pid) -> Option<FaultPlan> {
        fn f64_var(name: &str) -> Option<f64> {
            std::env::var(name).ok()?.parse().ok()
        }
        fn u64_var(name: &str) -> Option<u64> {
            std::env::var(name).ok()?.parse().ok()
        }
        let drop_prob = f64_var("DISTARRAY_FAULT_DROP");
        let delay_ms = u64_var("DISTARRAY_FAULT_DELAY_MS");
        let truncate_prob = f64_var("DISTARRAY_FAULT_TRUNCATE");
        let mut kill_after = u64_var("DISTARRAY_FAULT_KILL_AFTER");
        if let Some(kp) = u64_var("DISTARRAY_FAULT_KILL_PID") {
            if kp as usize != pid {
                kill_after = None;
            }
        }
        if drop_prob.is_none()
            && delay_ms.is_none()
            && truncate_prob.is_none()
            && kill_after.is_none()
        {
            return None;
        }
        Some(FaultPlan {
            seed: u64_var("DISTARRAY_FAULT_SEED").unwrap_or(1),
            drop_prob: drop_prob.unwrap_or(0.0),
            delay: Duration::from_millis(delay_ms.unwrap_or(0)),
            truncate_prob: truncate_prob.unwrap_or(0.0),
            kill_after,
        })
    }
}

/// A [`Transport`] decorator that injects the faults of a
/// [`FaultPlan`]. Deterministic given (seed, pid, operation order);
/// transparent under the default plan.
pub struct FaultTransport<T: Transport> {
    inner: T,
    plan: FaultPlan,
    rng: Mutex<Rng>,
    ops: AtomicU64,
    dead: AtomicBool,
}

impl<T: Transport> FaultTransport<T> {
    pub fn new(inner: T, plan: FaultPlan) -> FaultTransport<T> {
        // Golden-ratio mix so per-rank streams are independent even
        // for adjacent seeds/pids.
        let seed = plan
            .seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(inner.pid() as u64 + 1);
        FaultTransport {
            inner,
            plan,
            rng: Mutex::new(Rng::new(seed)),
            ops: AtomicU64::new(0),
            dead: AtomicBool::new(false),
        }
    }

    /// The wrapped transport.
    pub fn inner(&self) -> &T {
        &self.inner
    }

    /// Kill this endpoint immediately: every subsequent operation
    /// fails `Disconnected(self)`. Used by tests and the chaos
    /// scenario to fail a rank at a chosen point.
    pub fn kill_now(&self) {
        self.dead.store(true, Ordering::SeqCst);
    }

    /// Has this endpoint been killed (on demand or by `kill_after`)?
    pub fn is_killed(&self) -> bool {
        self.dead.load(Ordering::SeqCst)
    }

    /// Count one operation; fail if the endpoint is (or just became)
    /// dead.
    fn step(&self) -> Result<()> {
        if self.is_killed() {
            return Err(CommError::Disconnected(self.inner.pid()));
        }
        let n = self.ops.fetch_add(1, Ordering::SeqCst) + 1;
        if let Some(k) = self.plan.kill_after {
            if n > k {
                self.kill_now();
                return Err(CommError::Disconnected(self.inner.pid()));
            }
        }
        Ok(())
    }
}

impl<T: Transport> Transport for FaultTransport<T> {
    fn pid(&self) -> Pid {
        self.inner.pid()
    }

    fn np(&self) -> usize {
        self.inner.np()
    }

    fn kind(&self) -> Option<TransportKind> {
        self.inner.kind()
    }

    fn kind_to(&self, to: Pid) -> Option<TransportKind> {
        self.inner.kind_to(to)
    }

    fn send(&self, to: Pid, tag: Tag, payload: &[u8]) -> Result<()> {
        self.step()?;
        if !self.plan.delay.is_zero() {
            std::thread::sleep(self.plan.delay);
        }
        let (drop, truncate) = {
            let mut rng = self.rng.lock().unwrap();
            (
                self.plan.drop_prob > 0.0 && rng.f64() < self.plan.drop_prob,
                self.plan.truncate_prob > 0.0 && rng.f64() < self.plan.truncate_prob,
            )
        };
        if drop {
            return Ok(()); // swallowed — the receiver waits in vain
        }
        if truncate {
            return self.inner.send(to, tag, &payload[..payload.len() / 2]);
        }
        self.inner.send(to, tag, payload)
    }

    fn recv_timeout(&self, from: Pid, tag: Tag, timeout: Duration) -> Result<Vec<u8>> {
        self.step()?;
        self.inner.recv_timeout(from, tag, timeout)
    }

    fn stats(&self) -> &CommStats {
        self.inner.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ChannelHub;

    #[test]
    fn default_plan_is_transparent() {
        let mut world = ChannelHub::world(2);
        let t1 = world.pop().unwrap();
        let t0 = FaultTransport::new(world.pop().unwrap(), FaultPlan::default());
        t0.send(1, 7, b"hello").unwrap();
        assert_eq!(t1.recv(0, 7).unwrap(), b"hello");
        assert!(!t0.is_killed());
    }

    #[test]
    fn kill_after_n_operations_then_disconnected() {
        let mut world = ChannelHub::world(2);
        let _t1 = world.pop().unwrap();
        let plan = FaultPlan { kill_after: Some(3), ..FaultPlan::default() };
        let t0 = FaultTransport::new(world.pop().unwrap(), plan);
        for _ in 0..3 {
            t0.send(1, 1, b"x").unwrap();
        }
        let err = t0.send(1, 1, b"x").unwrap_err();
        assert!(matches!(err, CommError::Disconnected(0)), "{err}");
        assert!(t0.is_killed());
        // Dead is sticky across operation kinds.
        assert!(t0.try_recv(1, 1).is_err());
    }

    #[test]
    fn kill_now_is_immediate() {
        let mut world = ChannelHub::world(2);
        let _t1 = world.pop().unwrap();
        let t0 = FaultTransport::new(world.pop().unwrap(), FaultPlan::default());
        t0.send(1, 1, b"ok").unwrap();
        t0.kill_now();
        assert!(matches!(t0.send(1, 1, b"x"), Err(CommError::Disconnected(0))));
    }

    #[test]
    fn drops_are_deterministic_under_a_seed() {
        let run = |seed| {
            let mut world = ChannelHub::world(2);
            let t1 = world.pop().unwrap();
            let plan = FaultPlan { seed, drop_prob: 0.5, ..FaultPlan::default() };
            let t0 = FaultTransport::new(world.pop().unwrap(), plan);
            for i in 0..64u64 {
                t0.send(1, i, &i.to_le_bytes()).unwrap();
            }
            (0..64u64).map(|i| t1.try_recv(0, i).unwrap().is_some()).collect::<Vec<_>>()
        };
        let a = run(42);
        assert_eq!(a, run(42), "same seed, same drop schedule");
        assert_ne!(a, run(43), "different seed, different schedule");
        assert!(a.iter().any(|&d| d) && a.iter().any(|&d| !d), "p=0.5 drops some, not all");
    }

    #[test]
    fn truncation_tears_payloads_in_half() {
        let mut world = ChannelHub::world(2);
        let t1 = world.pop().unwrap();
        let plan = FaultPlan { truncate_prob: 1.0, ..FaultPlan::default() };
        let t0 = FaultTransport::new(world.pop().unwrap(), plan);
        t0.send(1, 9, &[1, 2, 3, 4, 5, 6]).unwrap();
        assert_eq!(t1.recv(0, 9).unwrap(), &[1, 2, 3]);
    }

    #[test]
    fn from_env_is_none_without_knobs() {
        // Env-var tests stay read-only (other tests run in parallel);
        // the unset case is the ambient state of the test process.
        assert!(FaultPlan::from_env(0).is_none());
    }
}
