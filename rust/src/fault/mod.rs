//! Fault tolerance and elasticity.
//!
//! The paper's headline operating point — hundreds of nodes sustaining
//! petabyte-per-second aggregate bandwidth — is a regime where worker
//! failure is routine. Without this module a dead PID hangs the whole
//! run at a `drain_chunks` timeout and loses all completed work. The
//! subsystem has four pieces, each usable on its own:
//!
//! * [`Detector`](detect::Detector) — leader-driven heartbeats on the
//!   dedicated [`NS_FAULT`](crate::comm::tags::NS_FAULT) tag
//!   namespace. A worker that misses a configurable number of rounds
//!   is *declared dead*
//!   ([`RankDead`](crate::comm::CommError::RankDead)), a positive
//!   verdict instead of an indefinite stall.
//! * [`FaultTransport`](inject::FaultTransport) — a deterministic,
//!   seeded fault-injection wrapper over any
//!   [`Transport`](crate::comm::Transport) (drop / delay / truncate /
//!   kill-after-N), so every failure path is testable in-process.
//! * **Elastic re-deal**
//!   ([`redeal`](crate::darray::DarrayT::redeal)) — shrinking or
//!   growing a darray's owner set is literally a remap through the
//!   existing [`RemapEngine`](crate::darray::RemapEngine), executed
//!   under a bumped epoch so stale messages from a dead rank are
//!   rejected by tag, not by luck.
//! * [`ckpt`] — the versioned `ckpt_v1` per-rank shard format
//!   (self-describing dtype header, CRC-32 trailer) behind
//!   `repro run --checkpoint <dir> [--restore]`.
//!
//! [`chaos`] packages the canonical kill-one-worker scenario (detect →
//! redeal → bit-identical survivors) for both the integration tests
//! and the `repro chaos` CLI smoke. `docs/fault_model.md` documents
//! the full model and the `DISTARRAY_FAULT_*` knobs.

pub mod chaos;
pub mod ckpt;
pub mod detect;
pub mod inject;

pub use chaos::{run_chaos, run_chaos_on, ChaosReport};
pub use ckpt::{read_shard, shard_path, write_shard, CkptError, Shard};
pub use detect::{respond_loop, Detector, DetectorConfig};
pub use inject::{FaultPlan, FaultTransport};
