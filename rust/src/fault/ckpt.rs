//! `ckpt_v1` — spool-backed checkpoint shards with restore.
//!
//! One file per rank (`ckpt_v1.rank<pid>` in the checkpoint
//! directory), written atomically (tmp + rename) so a crash mid-write
//! can never leave a half-shard under the final name:
//!
//! ```text
//! magic "DACKPT1\0"                     8 bytes
//! version                               u8  (= 1)
//! dtype code                            u8  (Dtype::code)
//! pid, np, epoch, n_global, n_sections  u64 × 5, LE
//! sections                              n_sections × put_slice::<T>
//! CRC-32 (IEEE) over all of the above   u32, LE
//! ```
//!
//! The header is self-describing (a shard read at the wrong dtype is
//! rejected by name, not misinterpreted) and the CRC trailer turns
//! truncation and bit rot into one clean [`CkptError::Corrupt`] line
//! — never a panic, never silent corruption. Reading validates in
//! order: length → CRC → magic → version → dtype → geometry, so the
//! most common damage (a torn tail) is caught before any field is
//! trusted.
//!
//! [`run_stream_ckpt_t`] is the checkpoint-aware STREAM driver behind
//! `repro run --checkpoint <dir> [--restore]`: same kernel sequence
//! and validation as [`run_stream_t`](crate::backend::run_stream_t),
//! with the three vectors downloaded and shard-written every
//! `DISTARRAY_FAULT_CKPT_EVERY` iterations and a `--restore` resuming
//! bit-identically from the last completed epoch.

use crate::backend::{Backend, BackendError, DeviceBuffer};
use crate::comm::{WireReader, WireWriter};
use crate::dmap::{Dmap, Pid};
use crate::element::{Dtype, Element};
use crate::obs::EventKind;
use crate::obs_span;
use std::path::{Path, PathBuf};

/// File magic of every `ckpt_v1` shard.
pub const MAGIC: [u8; 8] = *b"DACKPT1\0";
const VERSION: u8 = 1;
/// Geometry sanity bound — a CRC-valid header still shouldn't drive
/// an absurd allocation.
const MAX_SECTIONS: u64 = 4096;

// ---------------------------------------------------------------------------
// CRC-32 (IEEE 802.3), hand-rolled — the crate is dependency-free.
// ---------------------------------------------------------------------------

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// CRC-32 (IEEE) of `bytes` — the shard trailer checksum.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

// ---------------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------------

/// Checkpoint I/O and validation failures. `Corrupt` messages are one
/// line and name the shard — the operator-facing contract.
#[derive(Debug)]
pub enum CkptError {
    Io(std::io::Error),
    Corrupt(String),
}

impl std::fmt::Display for CkptError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CkptError::Io(e) => write!(f, "checkpoint io error: {e}"),
            CkptError::Corrupt(m) => write!(f, "checkpoint rejected: {m}"),
        }
    }
}

impl std::error::Error for CkptError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CkptError::Io(e) => Some(e),
            CkptError::Corrupt(_) => None,
        }
    }
}

impl From<std::io::Error> for CkptError {
    fn from(e: std::io::Error) -> Self {
        CkptError::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, CkptError>;

// ---------------------------------------------------------------------------
// Codec
// ---------------------------------------------------------------------------

/// One decoded checkpoint shard.
#[derive(Debug, Clone, PartialEq)]
pub struct Shard<T: Element> {
    pub pid: Pid,
    pub np: usize,
    /// Completed epochs (iterations) at the time of the checkpoint.
    pub epoch: u64,
    pub n_global: usize,
    /// Typed payload sections (e.g. the three STREAM vectors, or one
    /// darray local part).
    pub sections: Vec<Vec<T>>,
}

/// Path of rank `pid`'s shard inside checkpoint directory `dir`.
pub fn shard_path(dir: &Path, pid: Pid) -> PathBuf {
    dir.join(format!("ckpt_v1.rank{pid}"))
}

/// Encode one shard to bytes (header, sections, CRC trailer).
pub fn encode_shard<T: Element>(
    pid: Pid,
    np: usize,
    epoch: u64,
    n_global: usize,
    sections: &[&[T]],
) -> Vec<u8> {
    let payload: usize = sections.iter().map(|s| 9 + s.len() * T::WIDTH).sum();
    let mut buf = Vec::with_capacity(8 + 2 + 40 + payload + 4);
    buf.extend_from_slice(&MAGIC);
    let mut w = WireWriter::from_vec(Vec::with_capacity(2 + 40 + payload));
    w.put_u8(VERSION);
    w.put_u8(T::DTYPE.code());
    w.put_u64(pid as u64);
    w.put_u64(np as u64);
    w.put_u64(epoch);
    w.put_u64(n_global as u64);
    w.put_u64(sections.len() as u64);
    for s in sections {
        w.put_slice::<T>(s);
    }
    buf.extend_from_slice(&w.finish());
    let crc = crc32(&buf);
    buf.extend_from_slice(&crc.to_le_bytes());
    buf
}

/// Decode and fully validate one shard from bytes. `what` names the
/// source (a path) in error messages.
pub fn decode_shard<T: Element>(bytes: &[u8], what: &str) -> Result<Shard<T>> {
    let corrupt = |m: String| CkptError::Corrupt(format!("{what}: {m}"));
    if bytes.len() < MAGIC.len() + 4 {
        return Err(corrupt(format!("too short ({} bytes)", bytes.len())));
    }
    let (body, tail) = bytes.split_at(bytes.len() - 4);
    let stored = u32::from_le_bytes(tail.try_into().unwrap());
    if crc32(body) != stored {
        return Err(corrupt("CRC mismatch (truncated or corrupt)".into()));
    }
    if body[..MAGIC.len()] != MAGIC {
        return Err(corrupt("bad magic (not a ckpt_v1 shard)".into()));
    }
    let mut rd = WireReader::new(&body[MAGIC.len()..]);
    let field = |r: crate::comm::Result<u64>| r.map_err(|e| corrupt(e.to_string()));
    let version = field(rd.get_u8().map(u64::from))? as u8;
    if version != VERSION {
        return Err(corrupt(format!("unsupported version {version} (want {VERSION})")));
    }
    let code = field(rd.get_u8().map(u64::from))? as u8;
    let dtype = Dtype::from_code(code)
        .ok_or_else(|| corrupt(format!("unknown dtype code {code}")))?;
    if dtype != T::DTYPE {
        return Err(corrupt(format!("dtype mismatch: shard holds {dtype}, expected {}", T::DTYPE)));
    }
    let pid = field(rd.get_u64())? as usize;
    let np = field(rd.get_u64())? as usize;
    let epoch = field(rd.get_u64())?;
    let n_global = field(rd.get_u64())? as usize;
    let n_sections = field(rd.get_u64())?;
    if n_sections > MAX_SECTIONS {
        return Err(corrupt(format!("implausible section count {n_sections}")));
    }
    let mut sections = Vec::with_capacity(n_sections as usize);
    for _ in 0..n_sections {
        sections.push(rd.get_vec::<T>().map_err(|e| corrupt(e.to_string()))?);
    }
    if rd.remaining() != 0 {
        return Err(corrupt(format!("{} trailing bytes after sections", rd.remaining())));
    }
    Ok(Shard { pid, np, epoch, n_global, sections })
}

/// Write rank `pid`'s shard into `dir` atomically (tmp + rename).
/// Returns the shard size in bytes and emits a `fault_ckpt` span.
pub fn write_shard<T: Element>(
    dir: &Path,
    pid: Pid,
    np: usize,
    epoch: u64,
    n_global: usize,
    sections: &[&[T]],
) -> Result<usize> {
    let t0 = crate::obs::span_begin();
    std::fs::create_dir_all(dir)?;
    let bytes = encode_shard::<T>(pid, np, epoch, n_global, sections);
    let path = shard_path(dir, pid);
    let tmp = dir.join(format!("ckpt_v1.rank{pid}.tmp"));
    std::fs::write(&tmp, &bytes)?;
    std::fs::rename(&tmp, &path)?;
    obs_span!(
        EventKind::Checkpoint,
        t0,
        tag: 0,
        peer: crate::obs::NO_PEER,
        a: bytes.len() as u64,
        b: epoch
    );
    Ok(bytes.len())
}

/// Read and validate rank `pid`'s shard from `dir`. Emits a
/// `fault_restore` span on success.
pub fn read_shard<T: Element>(dir: &Path, pid: Pid) -> Result<Shard<T>> {
    let t0 = crate::obs::span_begin();
    let path = shard_path(dir, pid);
    let bytes = std::fs::read(&path)?;
    let shard = decode_shard::<T>(&bytes, &path.display().to_string())?;
    obs_span!(
        EventKind::Restore,
        t0,
        tag: 0,
        peer: crate::obs::NO_PEER,
        a: bytes.len() as u64,
        b: shard.epoch
    );
    Ok(shard)
}

// ---------------------------------------------------------------------------
// Checkpoint-aware STREAM driver
// ---------------------------------------------------------------------------

/// Checkpoint cadence from `DISTARRAY_FAULT_CKPT_EVERY` (default:
/// every iteration).
pub fn ckpt_every_from_env() -> usize {
    std::env::var("DISTARRAY_FAULT_CKPT_EVERY")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .map_or(1, |n| n.max(1))
}

/// [`run_stream_t`](crate::backend::run_stream_t) with per-epoch
/// shard checkpoints: the three vectors are downloaded and written as
/// one shard every `every` completed iterations, and `restore`
/// resumes from the last shard instead of the §III initial state —
/// bit-identically, because the shard holds the exact vectors. Shard
/// geometry (pid/np/n_global/local length/dtype) is validated on
/// restore; a mismatched or damaged shard is a one-line error, not a
/// wrong answer.
#[allow(clippy::too_many_arguments)]
pub fn run_stream_ckpt_t<T: Element>(
    backend: &dyn Backend,
    map: &Dmap,
    n_global: usize,
    nt: usize,
    q: T,
    pid: Pid,
    dir: &Path,
    restore: bool,
    every: usize,
) -> crate::backend::Result<crate::stream::StreamResult> {
    use crate::stream::serial::{A0, B0, C0};
    use crate::stream::timing::{OpTimes, Timer};
    use crate::stream::validate::{expected, tolerance_for, ValidationReport};

    assert!(nt >= 1 && every >= 1);
    if !backend.available() {
        return Err(BackendError::Unavailable(backend.kind()));
    }
    let ckpt_err = |e: CkptError| BackendError::Runtime(e.to_string());
    let shape = [n_global];
    let n_local = map.local_size(pid, &shape);

    let mut da = DeviceBuffer::<T>::alloc(backend, n_local)?;
    let mut db = DeviceBuffer::<T>::alloc(backend, n_local)?;
    let mut dc = DeviceBuffer::<T>::alloc(backend, n_local)?;
    let mut stage = vec![T::ZERO; n_local];

    let start_epoch = if restore {
        let shard = read_shard::<T>(dir, pid).map_err(ckpt_err)?;
        let geometry_ok = shard.np == map.np()
            && shard.n_global == n_global
            && shard.sections.len() == 3
            && shard.sections.iter().all(|s| s.len() == n_local);
        if !geometry_ok {
            return Err(ckpt_err(CkptError::Corrupt(format!(
                "{}: geometry mismatch (shard np={} n={} sections={:?}, run np={} n={} local={})",
                shard_path(dir, pid).display(),
                shard.np,
                shard.n_global,
                shard.sections.iter().map(Vec::len).collect::<Vec<_>>(),
                map.np(),
                n_global,
                n_local
            ))));
        }
        da.upload_from(backend, &shard.sections[0])?;
        db.upload_from(backend, &shard.sections[1])?;
        dc.upload_from(backend, &shard.sections[2])?;
        crate::log!(Info, "restored rank {pid} from epoch {} of {}", shard.epoch, dir.display());
        shard.epoch as usize
    } else {
        stage.fill(T::from_f64(A0));
        da.upload_from(backend, &stage)?;
        stage.fill(T::from_f64(B0));
        db.upload_from(backend, &stage)?;
        stage.fill(T::from_f64(C0));
        dc.upload_from(backend, &stage)?;
        0
    };

    let qf = q.to_f64();
    let mut times = OpTimes::zero();
    let mut b_stage = Vec::new();
    let mut c_stage = Vec::new();
    for it in start_epoch..nt {
        let t = Timer::tic();
        backend.copy(da.view(), dc.view_mut())?; // C = A
        times.copy += t.toc();

        let t = Timer::tic();
        backend.scale(dc.view(), db.view_mut(), qf)?; // B = q·C
        times.scale += t.toc();

        let t = Timer::tic();
        backend.add(da.view(), db.view(), dc.view_mut())?; // C = A + B
        times.add += t.toc();

        let t = Timer::tic();
        backend.triad(db.view(), dc.view(), da.view_mut(), qf)?; // A = B + q·C
        times.triad += t.toc();

        let epoch = it + 1;
        if epoch % every == 0 || epoch == nt {
            b_stage.resize(n_local, T::ZERO);
            c_stage.resize(n_local, T::ZERO);
            da.download_into(backend, &mut stage)?;
            db.download_into(backend, &mut b_stage)?;
            dc.download_into(backend, &mut c_stage)?;
            write_shard::<T>(
                dir,
                pid,
                map.np(),
                epoch as u64,
                n_global,
                &[&stage, &b_stage, &c_stage],
            )
            .map_err(ckpt_err)?;
        }
    }

    let (ea, eb, ec) = expected(A0, qf, nt);
    da.download_into(backend, &mut stage)?;
    let err_a = max_dev(&stage, ea);
    db.download_into(backend, &mut stage)?;
    let err_b = max_dev(&stage, eb);
    dc.download_into(backend, &mut stage)?;
    let err_c = max_dev(&stage, ec);
    let tol = tolerance_for(T::TOL_BASE, nt);
    let validation = ValidationReport {
        passed: err_a <= tol && err_b <= tol && err_c <= tol,
        err_a,
        err_b,
        err_c,
    };
    Ok(crate::stream::StreamResult {
        n_global,
        n_local,
        nt,
        width: T::WIDTH,
        backend: backend.kind(),
        times,
        validation,
    })
}

fn max_dev<T: Element>(xs: &[T], e: f64) -> f64 {
    xs.iter().map(|&x| (x.to_f64() - e).abs()).fold(0.0, f64::max)
}

/// Dtype dispatch for [`run_stream_ckpt_t`], mirroring
/// [`run_stream_dtype`](crate::backend::run_stream_dtype).
#[allow(clippy::too_many_arguments)]
pub fn run_stream_ckpt_dtype(
    backend: &dyn Backend,
    map: &Dmap,
    n_global: usize,
    nt: usize,
    q: f64,
    dtype: Dtype,
    pid: Pid,
    dir: &Path,
    restore: bool,
) -> crate::backend::Result<crate::stream::StreamResult> {
    let every = ckpt_every_from_env();
    match dtype {
        Dtype::F64 => {
            run_stream_ckpt_t::<f64>(backend, map, n_global, nt, q, pid, dir, restore, every)
        }
        Dtype::F32 => run_stream_ckpt_t::<f32>(
            backend, map, n_global, nt, q as f32, pid, dir, restore, every,
        ),
        Dtype::I64 => run_stream_ckpt_t::<i64>(
            backend, map, n_global, nt, q as i64, pid, dir, restore, every,
        ),
        Dtype::U64 => run_stream_ckpt_t::<u64>(
            backend, map, n_global, nt, q as u64, pid, dir, restore, every,
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{BackendKind, BackendRegistry};
    use crate::stream::STREAM_Q;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("distarray_{name}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn shard_roundtrip_preserves_everything() {
        let d = tmpdir("ckpt_rt");
        let a: Vec<f64> = (0..100).map(|i| i as f64 * 0.5).collect();
        let b: Vec<f64> = (0..100).map(|i| -(i as f64)).collect();
        write_shard::<f64>(&d, 2, 4, 7, 400, &[&a, &b]).unwrap();
        let s = read_shard::<f64>(&d, 2).unwrap();
        assert_eq!((s.pid, s.np, s.epoch, s.n_global), (2, 4, 7, 400));
        assert_eq!(s.sections, vec![a, b]);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn dtype_confused_read_is_a_clean_error() {
        let d = tmpdir("ckpt_dtype");
        let a: Vec<f32> = vec![1.0, 2.0, 3.0];
        write_shard::<f32>(&d, 0, 1, 1, 3, &[&a]).unwrap();
        let err = read_shard::<f64>(&d, 0).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("dtype mismatch"), "{msg}");
        assert!(msg.contains("f32") && msg.contains("f64"), "{msg}");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn truncation_and_bitflips_are_clean_errors() {
        let d = tmpdir("ckpt_damage");
        let a: Vec<i64> = (0..64).collect();
        write_shard::<i64>(&d, 1, 2, 3, 128, &[&a]).unwrap();
        let path = shard_path(&d, 1);
        let good = std::fs::read(&path).unwrap();
        // Truncate at every prefix length: always an error, never a panic.
        for cut in 0..good.len() {
            let err = decode_shard::<i64>(&good[..cut], "trunc").unwrap_err();
            assert!(matches!(err, CkptError::Corrupt(_)), "cut={cut}: {err}");
        }
        // Single bit flips anywhere: caught by the CRC.
        crate::prop::forall(64, 0xC0FFEE, |rng| {
            let mut bad = good.clone();
            let bit = rng.below(bad.len() * 8);
            bad[bit / 8] ^= 1 << (bit % 8);
            let err = decode_shard::<i64>(&bad, "flip").unwrap_err();
            assert!(matches!(err, CkptError::Corrupt(_)), "bit={bit}: {err}");
        });
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn missing_shard_is_io_not_corrupt() {
        let d = tmpdir("ckpt_missing");
        let err = read_shard::<f64>(&d, 9).unwrap_err();
        assert!(matches!(err, CkptError::Io(_)), "{err}");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn interrupted_run_resumes_bit_identically() {
        let reg = BackendRegistry::with_defaults(1, "artifacts");
        let be = reg.get(BackendKind::Host).unwrap();
        let map = Dmap::block_1d(1);
        let (n, nt) = (4096, 6);
        // Reference: one uninterrupted checkpointed run.
        let d_ref = tmpdir("ckpt_ref");
        let r_ref =
            run_stream_ckpt_t::<f64>(be.as_ref(), &map, n, nt, STREAM_Q, 0, &d_ref, false, 1)
                .unwrap();
        assert!(r_ref.validation.passed);
        let want = std::fs::read(shard_path(&d_ref, 0)).unwrap();
        // Interrupted: run to epoch 3, then restore and finish.
        let d = tmpdir("ckpt_resume");
        run_stream_ckpt_t::<f64>(be.as_ref(), &map, n, 3, STREAM_Q, 0, &d, false, 1).unwrap();
        let r = run_stream_ckpt_t::<f64>(be.as_ref(), &map, n, nt, STREAM_Q, 0, &d, true, 1)
            .unwrap();
        assert!(r.validation.passed);
        let got = std::fs::read(shard_path(&d, 0)).unwrap();
        assert_eq!(got, want, "resumed final shard must be bit-identical");
        std::fs::remove_dir_all(&d_ref).ok();
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn restore_rejects_geometry_mismatch() {
        let reg = BackendRegistry::with_defaults(1, "artifacts");
        let be = reg.get(BackendKind::Host).unwrap();
        let d = tmpdir("ckpt_geom");
        let map = Dmap::block_1d(1);
        run_stream_ckpt_t::<f64>(be.as_ref(), &map, 1024, 2, STREAM_Q, 0, &d, false, 1).unwrap();
        // Same dir, different n_global: rejected with one line.
        let err = run_stream_ckpt_t::<f64>(be.as_ref(), &map, 2048, 4, STREAM_Q, 0, &d, true, 1)
            .unwrap_err();
        assert!(err.to_string().contains("geometry mismatch"), "{err}");
        std::fs::remove_dir_all(&d).ok();
    }
}
