//! Baseline parallel programming models (§II) — the comparators the
//! paper frames distributed arrays against.
//!
//! * [`msgpass`] — the message-passing model: explicit send/recv of
//!   every vector fragment; "the programmer must manage every
//!   individual message" (§II). Correct, but pays explicit
//!   distribution traffic and far more code.
//! * [`mapreduce`] — the client-server / map-reduce model: workers
//!   receive independent tasks from the leader and never talk to each
//!   other (§II).
//!
//! The ablation bench `ablation_models` compares all three on the
//! same workload: the distributed-array model matches map-reduce
//! bandwidth with map-independence, while message-passing pays the
//! scatter/gather traffic the paper's `.loc` design avoids.

pub mod mapreduce;
pub mod msgpass;

pub use mapreduce::run_mapreduce_stream;
pub use msgpass::run_msgpass_stream;
