//! STREAM under the client-server / map-reduce model (§II).
//!
//! The leader (server) splits the global vector into independent
//! tasks; workers (clients) request nothing from each other, process
//! their assigned chunk, and send a reduced summary (times + local
//! validation error) back. "Each worker communicates only with the
//! leader and requires no knowledge about what the other workers are
//! doing."

use crate::comm::{Decode, Encode, Result, Transport, WireReader, WireWriter};
use crate::stream::serial::{A0, B0, C0};
use crate::stream::timing::{OpTimes, Timer};
use crate::stream::validate::validate;
use crate::stream::{ops, StreamResult};

const TAG_TASK: u64 = 0x7A5C_0000;
const TAG_DONE: u64 = 0x00DE_0000;

/// A map task: process [lo, hi) of the global vector for nt trials.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Task {
    pub lo: usize,
    pub hi: usize,
    pub nt: usize,
    pub q: f64,
}

impl Encode for Task {
    fn encode(&self, w: &mut WireWriter) {
        w.put_usize(self.lo);
        w.put_usize(self.hi);
        w.put_usize(self.nt);
        w.put_f64(self.q);
    }
}

impl Decode for Task {
    fn decode(r: &mut WireReader) -> crate::comm::Result<Self> {
        Ok(Task {
            lo: r.get_usize()?,
            hi: r.get_usize()?,
            nt: r.get_usize()?,
            q: r.get_f64()?,
        })
    }
}

/// Reduced per-task summary (the "reduce" payload).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TaskDone {
    pub n_local: usize,
    pub times: [f64; 4],
    pub passed: bool,
    pub max_err: f64,
}

impl Encode for TaskDone {
    fn encode(&self, w: &mut WireWriter) {
        w.put_usize(self.n_local);
        for t in self.times {
            w.put_f64(t);
        }
        w.put_bool(self.passed);
        w.put_f64(self.max_err);
    }
}

impl Decode for TaskDone {
    fn decode(r: &mut WireReader) -> crate::comm::Result<Self> {
        let n_local = r.get_usize()?;
        let mut times = [0.0; 4];
        for t in &mut times {
            *t = r.get_f64()?;
        }
        Ok(TaskDone { n_local, times, passed: r.get_bool()?, max_err: r.get_f64()? })
    }
}

/// Process one task locally (the "map" function).
pub fn execute_task(task: &Task) -> TaskDone {
    let n = task.hi - task.lo;
    let mut a = vec![A0; n];
    let mut b = vec![B0; n];
    let mut c = vec![C0; n];
    let mut times = OpTimes::zero();
    for _ in 0..task.nt {
        let t = Timer::tic();
        ops::copy(&mut c, &a);
        times.copy += t.toc();
        let t = Timer::tic();
        ops::scale(&mut b, &c, task.q);
        times.scale += t.toc();
        let t = Timer::tic();
        for i in 0..n {
            c[i] = a[i] + b[i];
        }
        times.add += t.toc();
        let t = Timer::tic();
        for i in 0..n {
            a[i] = b[i] + task.q * c[i];
        }
        times.triad += t.toc();
    }
    let v = validate(&a, &b, &c, A0, task.q, task.nt);
    TaskDone {
        n_local: n,
        times: times.as_array(),
        passed: v.passed,
        max_err: v.max_err(),
    }
}

/// SPMD entry: run map-reduce STREAM on this endpoint. Returns each
/// endpoint's own StreamResult (the leader's includes its own chunk).
pub fn run_mapreduce_stream(t: &dyn Transport, n: usize, nt: usize, q: f64) -> Result<StreamResult> {
    let (me, np) = (t.pid(), t.np());
    let b = n.div_ceil(np).max(1);
    let result;
    if me == 0 {
        // Server: hand out tasks 1..np, do task 0 itself, reduce.
        for p in 1..np {
            let task = Task { lo: (p * b).min(n), hi: ((p + 1) * b).min(n), nt, q };
            t.send(p, TAG_TASK, &task.to_bytes())?;
        }
        let my = execute_task(&Task { lo: 0, hi: b.min(n), nt, q });
        let mut done = vec![my];
        for p in 1..np {
            done.push(TaskDone::from_bytes(&t.recv(p, TAG_DONE)?)?);
        }
        // Reduce: the leader's own StreamResult carries its chunk; the
        // aggregate check folds everyone's validity.
        let all_pass = done.iter().all(|d| d.passed);
        result = to_result(n, nt, &my, all_pass);
    } else {
        let task = Task::from_bytes(&t.recv(0, TAG_TASK)?)?;
        let done = execute_task(&task);
        t.send(0, TAG_DONE, &done.to_bytes())?;
        result = to_result(n, nt, &done, done.passed);
    }
    Ok(result)
}

fn to_result(n: usize, nt: usize, d: &TaskDone, passed: bool) -> StreamResult {
    StreamResult {
        n_global: n,
        n_local: d.n_local,
        nt,
        width: 8,
        backend: crate::backend::BackendKind::Host,
        times: OpTimes {
            copy: d.times[0],
            scale: d.times[1],
            add: d.times[2],
            triad: d.times[3],
        },
        validation: crate::stream::validate::ValidationReport {
            passed,
            err_a: d.max_err,
            err_b: d.max_err,
            err_c: d.max_err,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ChannelHub;
    use crate::stream::{aggregate, STREAM_Q};
    use std::thread;

    #[test]
    fn mapreduce_stream_validates() {
        let np = 4;
        let world = ChannelHub::world(np);
        let handles: Vec<_> = world
            .into_iter()
            .map(|t| thread::spawn(move || run_mapreduce_stream(&t, 8000, 3, STREAM_Q).unwrap()))
            .collect();
        let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let agg = aggregate(&results).unwrap();
        assert!(agg.all_valid);
        let covered: usize = results.iter().map(|r| r.n_local).sum();
        assert_eq!(covered, 8000);
    }

    #[test]
    fn task_roundtrip() {
        let t = Task { lo: 5, hi: 10, nt: 3, q: 0.25 };
        assert_eq!(Task::from_bytes(&t.to_bytes()).unwrap(), t);
    }

    #[test]
    fn execute_task_correctness() {
        let d = execute_task(&Task { lo: 100, hi: 612, nt: 7, q: STREAM_Q });
        assert!(d.passed, "err {}", d.max_err);
        assert_eq!(d.n_local, 512);
    }

    #[test]
    fn control_traffic_is_tiny_relative_to_data() {
        // Map-reduce only ships task descriptors + summaries: bytes on
        // the wire must be O(np), not O(n) like msgpass scatter.
        let np = 4;
        let n = 100_000;
        let world = ChannelHub::world(np);
        let handles: Vec<_> = world
            .into_iter()
            .map(|t| {
                thread::spawn(move || {
                    run_mapreduce_stream(&t, n, 2, STREAM_Q).unwrap();
                    t.stats().bytes_sent()
                })
            })
            .collect();
        let total_bytes: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(total_bytes < 10_000, "control traffic {total_bytes}B");
    }
}
