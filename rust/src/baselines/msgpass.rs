//! STREAM under the message-passing model (§II).
//!
//! Faithful to the model's costs: the leader owns the logical global
//! vectors, **explicitly scatters** each worker's fragment, workers
//! iterate locally (as any sane MPI STREAM would), and the leader
//! **explicitly gathers** the final fragments for validation. The
//! timed loop is identical to the distributed-array run; the model's
//! overhead shows up as scatter/gather messages and code volume —
//! exactly the paper's point.

use crate::comm::{Result, Transport, WireReader, WireWriter};
use crate::stream::serial::{A0, B0, C0};
use crate::stream::timing::{OpTimes, Timer};
use crate::stream::validate::validate;
use crate::stream::{ops, StreamResult};

const TAG_SCATTER: u64 = 0x5CA7_0000;
const TAG_GATHER: u64 = 0x6A78_0000;

/// Block extent of `pid` for n over np (leader-computed, like an MPI
/// program would hand-roll).
fn extent(n: usize, np: usize, pid: usize) -> (usize, usize) {
    let b = n.div_ceil(np).max(1);
    let lo = (pid * b).min(n);
    let hi = ((pid + 1) * b).min(n);
    (lo, hi)
}

/// SPMD entry: run message-passing STREAM on this endpoint.
pub fn run_msgpass_stream(t: &dyn Transport, n: usize, nt: usize, q: f64) -> Result<StreamResult> {
    let (me, np) = (t.pid(), t.np());
    let (lo, hi) = extent(n, np, me);
    let n_local = hi - lo;

    // --- explicit scatter (rank 0 sends every fragment) ---
    let (mut a, mut b, mut c);
    if me == 0 {
        let ga = vec![A0; n];
        let gb = vec![B0; n];
        let gc = vec![C0; n];
        for p in 1..np {
            let (plo, phi) = extent(n, np, p);
            let mut w = WireWriter::with_capacity(24 + 24 * (phi - plo));
            w.put_f64_slice(&ga[plo..phi]);
            w.put_f64_slice(&gb[plo..phi]);
            w.put_f64_slice(&gc[plo..phi]);
            t.send(p, TAG_SCATTER, &w.finish())?;
        }
        a = ga[lo..hi].to_vec();
        b = gb[lo..hi].to_vec();
        c = gc[lo..hi].to_vec();
    } else {
        let payload = t.recv(0, TAG_SCATTER)?;
        let mut r = WireReader::new(&payload);
        a = r.get_f64_vec()?;
        b = r.get_f64_vec()?;
        c = r.get_f64_vec()?;
    }

    // --- timed loop (identical kernel work) ---
    let mut times = OpTimes::zero();
    for _ in 0..nt {
        let tm = Timer::tic();
        ops::copy(&mut c, &a);
        times.copy += tm.toc();
        let tm = Timer::tic();
        ops::scale(&mut b, &c, q);
        times.scale += tm.toc();
        let tm = Timer::tic();
        let (aa, bb) = (&a, &b);
        // Add writes c from a, b.
        for i in 0..c.len() {
            c[i] = aa[i] + bb[i];
        }
        times.add += tm.toc();
        let tm = Timer::tic();
        for i in 0..a.len() {
            a[i] = b[i] + q * c[i];
        }
        times.triad += tm.toc();
    }

    // --- explicit gather for validation at rank 0 ---
    let validation;
    if me == 0 {
        let mut ga = vec![0.0; n];
        let mut gb = vec![0.0; n];
        let mut gc = vec![0.0; n];
        ga[lo..hi].copy_from_slice(&a);
        gb[lo..hi].copy_from_slice(&b);
        gc[lo..hi].copy_from_slice(&c);
        for p in 1..np {
            let (plo, phi) = extent(n, np, p);
            let payload = t.recv(p, TAG_GATHER)?;
            let mut r = WireReader::new(&payload);
            r.get_f64_into(&mut ga[plo..phi])?;
            r.get_f64_into(&mut gb[plo..phi])?;
            r.get_f64_into(&mut gc[plo..phi])?;
        }
        validation = validate(&ga, &gb, &gc, A0, q, nt);
    } else {
        let mut w = WireWriter::with_capacity(24 + 24 * n_local);
        w.put_f64_slice(&a);
        w.put_f64_slice(&b);
        w.put_f64_slice(&c);
        t.send(0, TAG_GATHER, &w.finish())?;
        validation = validate(&a, &b, &c, A0, q, nt);
    }

    Ok(StreamResult {
        n_global: n,
        n_local,
        nt,
        width: 8,
        backend: crate::backend::BackendKind::Host,
        times,
        validation,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ChannelHub;
    use crate::stream::{aggregate, STREAM_Q};
    use std::thread;

    #[test]
    fn msgpass_stream_validates_and_pays_traffic() {
        let np = 4;
        let n = 4096;
        let world = ChannelHub::world(np);
        let handles: Vec<_> = world
            .into_iter()
            .map(|t| {
                thread::spawn(move || {
                    let r = run_msgpass_stream(&t, n, 3, STREAM_Q).unwrap();
                    let silent = t.stats().is_silent();
                    (r, silent)
                })
            })
            .collect();
        let outcomes: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let results: Vec<_> = outcomes.iter().map(|(r, _)| r.clone()).collect();
        let agg = aggregate(&results).unwrap();
        assert!(agg.all_valid, "worst {}", agg.worst_err);
        // The defining contrast with the distributed-array run: every
        // endpoint moved data.
        for (_, silent) in outcomes {
            assert!(!silent, "message-passing model must communicate");
        }
    }

    #[test]
    fn extents_cover_exactly() {
        for (n, np) in [(100usize, 7usize), (16, 4), (5, 8)] {
            let total: usize = (0..np).map(|p| { let (lo, hi) = extent(n, np, p); hi - lo }).sum();
            assert_eq!(total, n);
        }
    }

    #[test]
    fn single_rank_runs_without_peers() {
        let mut world = ChannelHub::world(1);
        let t = world.pop().unwrap();
        let r = run_msgpass_stream(&t, 512, 2, STREAM_Q).unwrap();
        assert!(r.validation.passed);
    }
}
