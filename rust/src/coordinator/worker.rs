//! Worker side of the protocol: receive config → run → report.

use super::leader::{config_space, result_space, trace_tag};
use super::results::{EngineKind, RunConfig, WorkerReport};
use crate::backend::{run_stream_dtype, BackendRegistry};
use crate::collective::{Collective, Topology};
use crate::comm::datapath::{self, ChunkStream};
use crate::comm::{Decode, Encode, Result, Transport};
use crate::stream::timing::{OpTimes, Timer};
use crate::stream::validate::validate;
use crate::stream::StreamResult;

/// Execute one configured STREAM run on this PID.
///
/// The native engine routes through the execution-backend subsystem:
/// each process constructs its own [`BackendRegistry`] (backends hold
/// process-local pools/artifacts) and the scheduler dispatches on the
/// config's dtype (the `--dtype` axis) and backend (the `--backend`
/// axis). The PJRT *engines* execute f64 artifacts regardless of
/// dtype — the CLI rejects bad combinations before a run starts; the
/// panics here are the backstop for hand-built configs.
pub fn run_configured_stream(cfg: &RunConfig, pid: usize, np: usize) -> StreamResult {
    let map = cfg.map.to_map(np);
    match cfg.engine {
        EngineKind::Native => {
            let registry = BackendRegistry::with_defaults(cfg.threads, &cfg.artifacts);
            let backend = registry
                .get(cfg.backend)
                .expect("default registry covers every BackendKind");
            // `--checkpoint` routes the native engine through the
            // shard-writing driver (the CLI rejects the flag for the
            // PJRT engines, whose state lives device-side).
            if !cfg.checkpoint.is_empty() {
                return crate::fault::ckpt::run_stream_ckpt_dtype(
                    backend.as_ref(),
                    &map,
                    cfg.n_global,
                    cfg.nt,
                    cfg.q,
                    cfg.dtype,
                    pid,
                    std::path::Path::new(&cfg.checkpoint),
                    cfg.restore,
                )
                .unwrap_or_else(|e| panic!("backend '{}': {e}", cfg.backend));
            }
            run_stream_dtype(
                backend.as_ref(),
                &map,
                cfg.n_global,
                cfg.nt,
                cfg.q,
                cfg.dtype,
                pid,
            )
            .unwrap_or_else(|e| panic!("backend '{}': {e}", cfg.backend))
        }
        EngineKind::Pjrt => run_pjrt_stream(cfg, pid, np),
        EngineKind::PjrtFused => run_pjrt_fused_stream(cfg, pid, np),
    }
}

/// Fused PJRT engine: one `step_fused` artifact call per iteration
/// instead of four per-op calls — the L1 fusion optimization carried
/// to L3 (8 → 2 HBM round-trips per element, 4× fewer PJRT
/// invocations). Per-op timings collapse into triad; copy/scale/add
/// times are attributed proportionally for reporting symmetry.
fn run_pjrt_fused_stream(cfg: &RunConfig, pid: usize, np: usize) -> StreamResult {
    use crate::stream::serial::A0;
    let rt = crate::runtime::PjrtRuntime::load_subset(&cfg.artifacts, &["step_fused"])
        .expect("artifacts load (run `make artifacts`)");
    let map = cfg.map.to_map(np);
    let shape = [cfg.n_global];
    let n_local = map.local_size(pid, &shape);
    let chunk = rt.n();
    let chunks = (n_local / chunk).max(1);
    let eff_local = chunks * chunk;
    let mut a = vec![A0; eff_local];
    let mut b = vec![0.0; eff_local];
    let mut c = vec![0.0; eff_local];
    let mut times = OpTimes::zero();
    for it in 0..cfg.nt {
        // B and C are recomputed from A every iteration; only the
        // final iteration's values are observable (validation), so
        // skip their copy-back on all earlier iterations (§Perf L3).
        let last = it + 1 == cfg.nt;
        let t = Timer::tic();
        for k in 0..chunks {
            let s = k * chunk;
            let (ao, bo, co) = rt.step_fused(&a[s..s + chunk], cfg.q).expect("pjrt fused step");
            a[s..s + chunk].copy_from_slice(&ao);
            if last {
                b[s..s + chunk].copy_from_slice(&bo);
                c[s..s + chunk].copy_from_slice(&co);
            }
        }
        let dt = t.toc();
        // One fused call covers all four ops; split by byte weight
        // (16:16:24:24) so bandwidth formulas stay meaningful.
        times.copy += dt * 0.2;
        times.scale += dt * 0.2;
        times.add += dt * 0.3;
        times.triad += dt * 0.3;
    }
    let validation = validate(&a, &b, &c, A0, cfg.q, cfg.nt);
    StreamResult {
        n_global: cfg.n_global,
        n_local: eff_local,
        nt: cfg.nt,
        width: 8,
        backend: crate::backend::BackendKind::Pjrt,
        times,
        validation,
    }
}

/// PJRT engine: the local part is processed by the AOT artifacts
/// (L1 Pallas kernels lowered through L2 JAX). The artifact was
/// lowered for a fixed local length `rt.n()`; the local part is
/// processed in chunks of that length (same-map ⇒ local-only, so
/// chunking is sound).
fn run_pjrt_stream(cfg: &RunConfig, pid: usize, np: usize) -> StreamResult {
    use crate::stream::serial::{A0, B0, C0};
    let rt = crate::runtime::PjrtRuntime::load_subset(
        &cfg.artifacts,
        &["copy", "scale", "add", "triad"],
    )
    .expect("artifacts load (run `make artifacts`)");
    let map = cfg.map.to_map(np);
    let shape = [cfg.n_global];
    let n_local = map.local_size(pid, &shape);
    let chunk = rt.n();
    // Round the local length down to whole chunks (≥1 chunk).
    let chunks = (n_local / chunk).max(1);
    let eff_local = chunks * chunk;
    let mut a = vec![A0; eff_local];
    let mut b = vec![B0; eff_local];
    let mut c = vec![C0; eff_local];
    let mut times = OpTimes::zero();
    for _ in 0..cfg.nt {
        let t = Timer::tic();
        for k in 0..chunks {
            let s = k * chunk;
            let out = rt.copy(&a[s..s + chunk]).expect("pjrt copy");
            c[s..s + chunk].copy_from_slice(&out);
        }
        times.copy += t.toc();
        let t = Timer::tic();
        for k in 0..chunks {
            let s = k * chunk;
            let out = rt.scale(&c[s..s + chunk], cfg.q).expect("pjrt scale");
            b[s..s + chunk].copy_from_slice(&out);
        }
        times.scale += t.toc();
        let t = Timer::tic();
        for k in 0..chunks {
            let s = k * chunk;
            let out = rt.add(&a[s..s + chunk], &b[s..s + chunk]).expect("pjrt add");
            c[s..s + chunk].copy_from_slice(&out);
        }
        times.add += t.toc();
        let t = Timer::tic();
        for k in 0..chunks {
            let s = k * chunk;
            let out = rt
                .triad(&b[s..s + chunk], &c[s..s + chunk], cfg.q)
                .expect("pjrt triad");
            a[s..s + chunk].copy_from_slice(&out);
        }
        times.triad += t.toc();
    }
    let validation = validate(&a, &b, &c, A0, cfg.q, cfg.nt);
    StreamResult {
        n_global: cfg.n_global,
        n_local: eff_local,
        nt: cfg.nt,
        width: 8,
        backend: crate::backend::BackendKind::Pjrt,
        times,
        validation,
    }
}

/// Full worker lifecycle over a transport: receive the broadcast
/// config (star bootstrap — see the leader module docs), run, then
/// join the result aggregation under the configured `--coll`
/// algorithm. Under `--heartbeat` a sidecar thread echoes the
/// leader's failure-detector pings for the whole lifecycle (compute
/// through report), so only a genuinely dead worker goes silent.
pub fn run_worker(t: &dyn Transport) -> Result<WorkerReport> {
    let np = t.np();
    let payload = Collective::star(np).bcast(t, config_space(), Vec::new())?;
    let cfg = RunConfig::from_bytes(&payload)?;
    // The broadcast config is authoritative for the datapath chunk
    // size (the env inherit in `cmd_worker` covers ambient users that
    // run before the config lands).
    if cfg.chunk_bytes > 0 {
        crate::comm::datapath::set_ambient_chunk_bytes(cfg.chunk_bytes);
    }
    // Same authority for the receive patience: the broadcast value
    // wins over the env inherit (0 keeps the 120 s default).
    if cfg.recv_timeout_ms > 0 {
        crate::comm::set_default_recv_timeout_ms(cfg.recv_timeout_ms);
    }
    if cfg.trace {
        crate::obs::set_thread_rank(t.pid());
        crate::obs::set_enabled(true);
    }
    if cfg.heartbeat {
        let stop = std::sync::atomic::AtomicBool::new(false);
        return std::thread::scope(|s| {
            s.spawn(|| crate::fault::respond_loop(t, 0, &stop));
            let r = finish_worker(t, &cfg, np);
            stop.store(true, std::sync::atomic::Ordering::Relaxed);
            r
        });
    }
    finish_worker(t, &cfg, np)
}

/// Compute + report + telemetry — the post-config part of the worker
/// lifecycle, factored out so `run_worker` can run it under the
/// heartbeat responder scope.
fn finish_worker(t: &dyn Transport, cfg: &RunConfig, np: usize) -> Result<WorkerReport> {
    let result = run_configured_stream(cfg, t.pid(), np);
    let report = WorkerReport::from_result(t.pid(), &result);
    let coll = Collective::new(cfg.coll, Topology::grouped(np, cfg.nppn));
    coll.gather(t, result_space(), report.to_bytes())?;
    if cfg.trace {
        // Stream this rank's NDJSON telemetry to the leader. This is
        // keyed off the *config*, not the local recording gate, so the
        // exchange stays in protocol lockstep even under an `obs-off`
        // build (the blob then carries only the meta lines).
        let blob = crate::obs::emit::render_pending();
        ChunkStream::send(
            t,
            0,
            trace_tag(),
            datapath::ambient_chunk_bytes(),
            &[blob.as_bytes()],
        )?;
        crate::obs::clear_thread_rank();
    }
    Ok(report)
}
