//! Wire types for the leader/worker protocol.

use crate::backend::BackendKind;
use crate::collective::CollKind;
use crate::comm::{CommError, Decode, Encode, TransportKind, WireReader, WireWriter};
use crate::dmap::Dmap;
use crate::element::Dtype;
use crate::stream::timing::OpTimes;
use crate::stream::validate::ValidationReport;
use crate::stream::StreamResult;

/// Which distribution the benchmark vectors use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MapKind {
    Block,
    Cyclic,
    BlockCyclic { block_size: usize },
}

impl MapKind {
    pub fn to_map(&self, np: usize) -> Dmap {
        match *self {
            MapKind::Block => Dmap::block_1d(np),
            MapKind::Cyclic => Dmap::cyclic_1d(np),
            MapKind::BlockCyclic { block_size } => Dmap::block_cyclic_1d(np, block_size),
        }
    }

    pub fn parse(s: &str) -> Option<MapKind> {
        match s {
            "block" => Some(MapKind::Block),
            "cyclic" => Some(MapKind::Cyclic),
            _ => s
                .strip_prefix("blockcyclic:")
                .and_then(|bs| bs.parse().ok())
                .map(|block_size| MapKind::BlockCyclic { block_size }),
        }
    }

    fn code(&self) -> (u8, u64) {
        match *self {
            MapKind::Block => (0, 0),
            MapKind::Cyclic => (1, 0),
            MapKind::BlockCyclic { block_size } => (2, block_size as u64),
        }
    }
}

/// Which engine executes the STREAM ops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Native Rust loops (primary measurement engine).
    Native,
    /// PJRT-executed AOT artifacts, one call per op (faithful to
    /// Algorithm 1's four separately-timed operations).
    Pjrt,
    /// PJRT fused-step artifact, one call per iteration (the L1
    /// fusion optimization surfaced at L3 — see EXPERIMENTS.md §Perf).
    PjrtFused,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<EngineKind> {
        match s {
            "native" => Some(EngineKind::Native),
            "pjrt" => Some(EngineKind::Pjrt),
            "pjrt-fused" => Some(EngineKind::PjrtFused),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Native => "native",
            EngineKind::Pjrt => "pjrt",
            EngineKind::PjrtFused => "pjrt-fused",
        }
    }
}

/// The leader's run configuration, broadcast to every worker.
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    /// Global vector length N.
    pub n_global: usize,
    /// Trials.
    pub nt: usize,
    /// Scale factor (√2−1 by default).
    pub q: f64,
    pub map: MapKind,
    pub engine: EngineKind,
    /// Element dtype of the benchmark vectors (`--dtype` axis; the
    /// native engine supports every float dtype, PJRT is f64-only).
    pub dtype: Dtype,
    /// Execution backend for the native engine (`--backend` axis).
    pub backend: BackendKind,
    /// Worker pool width for the threaded backend — the `Ntpn` axis of
    /// the triples spec (0 = one thread per online core).
    pub threads: usize,
    /// Collective algorithm for the coordinator's result aggregation
    /// (`--coll` axis; the config broadcast itself bootstraps over
    /// star since it is what tells workers which algorithm to use).
    pub coll: CollKind,
    /// PIDs per node — the `Nppn` axis of the triples spec, the
    /// hierarchical collectives' topology (0 = flat/unknown).
    pub nppn: usize,
    /// Stream chunk size of the shared bulk-transfer datapath
    /// (`--chunk-bytes`; 0 = the built-in default). Workers inherit
    /// it through the environment like `--coll`.
    pub chunk_bytes: usize,
    /// Artifacts directory for the PJRT engine.
    pub artifacts: String,
    /// Telemetry recording is on (`--trace`): every worker records
    /// spans and, after its report, streams its NDJSON trace to the
    /// leader for the bounded-memory fold. Part of the config wire so
    /// the telemetry exchange stays in protocol lockstep even when a
    /// worker's own sink install fails.
    pub trace: bool,
    /// Run the leader-side heartbeat failure detector and worker-side
    /// responders (`--heartbeat`; see [`crate::fault::detect`]).
    pub heartbeat: bool,
    /// Checkpoint directory for `ckpt_v1` shards (`--checkpoint`;
    /// empty = checkpointing off).
    pub checkpoint: String,
    /// Resume from the shards in `checkpoint` instead of the §III
    /// initial state (`--restore`).
    pub restore: bool,
    /// Wire transport carrying the worker world (`--transport` axis).
    /// Workers inherit the concrete endpoint through the environment;
    /// the config copy keeps the choice in provenance records and on
    /// the protocol wire.
    pub transport: TransportKind,
    /// Receive-timeout override in milliseconds (`--recv-timeout-ms`;
    /// 0 = the built-in 120 s default). Applied by every worker via
    /// [`crate::comm::set_default_recv_timeout_ms`].
    pub recv_timeout_ms: u64,
}

impl Encode for RunConfig {
    fn encode(&self, w: &mut WireWriter) {
        w.put_usize(self.n_global);
        w.put_usize(self.nt);
        w.put_f64(self.q);
        let (mc, mb) = self.map.code();
        w.put_u8(mc);
        w.put_u64(mb);
        w.put_u8(match self.engine {
            EngineKind::Native => 0,
            EngineKind::Pjrt => 1,
            EngineKind::PjrtFused => 2,
        });
        w.put_u8(self.dtype.code());
        w.put_u8(self.backend.code());
        w.put_usize(self.threads);
        w.put_u8(self.coll.code());
        w.put_usize(self.nppn);
        w.put_usize(self.chunk_bytes);
        w.put_str(&self.artifacts);
        w.put_bool(self.trace);
        w.put_bool(self.heartbeat);
        w.put_str(&self.checkpoint);
        w.put_bool(self.restore);
        w.put_u8(self.transport.code());
        w.put_u64(self.recv_timeout_ms);
    }
}

impl Decode for RunConfig {
    fn decode(r: &mut WireReader) -> crate::comm::Result<Self> {
        let n_global = r.get_usize()?;
        let nt = r.get_usize()?;
        let q = r.get_f64()?;
        let mc = r.get_u8()?;
        let mb = r.get_u64()?;
        let map = match mc {
            0 => MapKind::Block,
            1 => MapKind::Cyclic,
            2 => MapKind::BlockCyclic { block_size: mb as usize },
            x => return Err(CommError::Malformed(format!("bad map code {x}"))),
        };
        let engine = match r.get_u8()? {
            0 => EngineKind::Native,
            1 => EngineKind::Pjrt,
            2 => EngineKind::PjrtFused,
            x => return Err(CommError::Malformed(format!("bad engine code {x}"))),
        };
        let dcode = r.get_u8()?;
        let dtype = Dtype::from_code(dcode)
            .ok_or_else(|| CommError::Malformed(format!("bad dtype code {dcode}")))?;
        let bcode = r.get_u8()?;
        let backend = BackendKind::from_code(bcode)
            .ok_or_else(|| CommError::Malformed(format!("bad backend code {bcode}")))?;
        let threads = r.get_usize()?;
        let ccode = r.get_u8()?;
        let coll = CollKind::from_code(ccode)
            .ok_or_else(|| CommError::Malformed(format!("bad coll code {ccode}")))?;
        let nppn = r.get_usize()?;
        let chunk_bytes = r.get_usize()?;
        let artifacts = r.get_str()?;
        let trace = r.get_bool()?;
        let heartbeat = r.get_bool()?;
        let checkpoint = r.get_str()?;
        let restore = r.get_bool()?;
        let tcode = r.get_u8()?;
        let transport = TransportKind::from_code(tcode)
            .ok_or_else(|| CommError::Malformed(format!("bad transport code {tcode}")))?;
        let recv_timeout_ms = r.get_u64()?;
        Ok(RunConfig {
            n_global,
            nt,
            q,
            map,
            engine,
            dtype,
            backend,
            threads,
            coll,
            nppn,
            chunk_bytes,
            artifacts,
            trace,
            heartbeat,
            checkpoint,
            restore,
            transport,
            recv_timeout_ms,
        })
    }
}

/// One process's benchmark report.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerReport {
    pub pid: usize,
    pub n_global: usize,
    pub n_local: usize,
    pub nt: usize,
    /// Bytes per element of the streamed dtype.
    pub width: usize,
    /// Execution backend that produced the result.
    pub backend: BackendKind,
    pub times: [f64; 4],
    pub passed: bool,
    pub errs: [f64; 3],
}

impl WorkerReport {
    pub fn from_result(pid: usize, r: &StreamResult) -> Self {
        WorkerReport {
            pid,
            n_global: r.n_global,
            n_local: r.n_local,
            nt: r.nt,
            width: r.width,
            backend: r.backend,
            times: r.times.as_array(),
            passed: r.validation.passed,
            errs: [r.validation.err_a, r.validation.err_b, r.validation.err_c],
        }
    }

    pub fn to_result(&self) -> StreamResult {
        StreamResult {
            n_global: self.n_global,
            n_local: self.n_local,
            nt: self.nt,
            width: self.width,
            backend: self.backend,
            times: OpTimes {
                copy: self.times[0],
                scale: self.times[1],
                add: self.times[2],
                triad: self.times[3],
            },
            validation: ValidationReport {
                passed: self.passed,
                err_a: self.errs[0],
                err_b: self.errs[1],
                err_c: self.errs[2],
            },
        }
    }
}

impl Encode for WorkerReport {
    fn encode(&self, w: &mut WireWriter) {
        w.put_usize(self.pid);
        w.put_usize(self.n_global);
        w.put_usize(self.n_local);
        w.put_usize(self.nt);
        w.put_usize(self.width);
        w.put_u8(self.backend.code());
        for t in self.times {
            w.put_f64(t);
        }
        w.put_bool(self.passed);
        for e in self.errs {
            w.put_f64(e);
        }
    }
}

impl Decode for WorkerReport {
    fn decode(r: &mut WireReader) -> crate::comm::Result<Self> {
        let pid = r.get_usize()?;
        let n_global = r.get_usize()?;
        let n_local = r.get_usize()?;
        let nt = r.get_usize()?;
        let width = r.get_usize()?;
        let bcode = r.get_u8()?;
        let backend = BackendKind::from_code(bcode)
            .ok_or_else(|| CommError::Malformed(format!("bad backend code {bcode}")))?;
        let mut times = [0.0; 4];
        for t in &mut times {
            *t = r.get_f64()?;
        }
        let passed = r.get_bool()?;
        let mut errs = [0.0; 3];
        for e in &mut errs {
            *e = r.get_f64()?;
        }
        Ok(WorkerReport { pid, n_global, n_local, nt, width, backend, times, passed, errs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runconfig_roundtrip() {
        let c = RunConfig {
            n_global: 1 << 20,
            nt: 10,
            q: crate::stream::STREAM_Q,
            map: MapKind::BlockCyclic { block_size: 64 },
            engine: EngineKind::Pjrt,
            dtype: Dtype::F32,
            backend: BackendKind::Threaded,
            threads: 4,
            coll: CollKind::Hier,
            nppn: 4,
            chunk_bytes: 1 << 20,
            artifacts: "artifacts".into(),
            trace: true,
            heartbeat: true,
            checkpoint: "ckpt/run1".into(),
            restore: true,
            transport: TransportKind::Shmem,
            recv_timeout_ms: 45_000,
        };
        let got = RunConfig::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(got, c);
    }

    #[test]
    fn report_roundtrip() {
        let rep = WorkerReport {
            pid: 3,
            n_global: 100,
            n_local: 25,
            nt: 10,
            width: 4,
            backend: BackendKind::Threaded,
            times: [0.1, 0.2, 0.3, 0.4],
            passed: true,
            errs: [0.0, 1e-16, 0.0],
        };
        let got = WorkerReport::from_bytes(&rep.to_bytes()).unwrap();
        assert_eq!(got, rep);
        let r = got.to_result();
        assert_eq!(r.times.triad, 0.4);
        assert_eq!(r.width, 4);
        assert_eq!(r.backend, BackendKind::Threaded);
        assert!(r.validation.passed);
    }

    #[test]
    fn mapkind_parse() {
        assert_eq!(MapKind::parse("block"), Some(MapKind::Block));
        assert_eq!(MapKind::parse("cyclic"), Some(MapKind::Cyclic));
        assert_eq!(
            MapKind::parse("blockcyclic:16"),
            Some(MapKind::BlockCyclic { block_size: 16 })
        );
        assert_eq!(MapKind::parse("huh"), None);
    }

    #[test]
    fn truncated_config_is_error() {
        let c = RunConfig {
            n_global: 8,
            nt: 1,
            q: 0.5,
            map: MapKind::Block,
            engine: EngineKind::Native,
            dtype: Dtype::F64,
            backend: BackendKind::Host,
            threads: 1,
            coll: CollKind::Star,
            nppn: 0,
            chunk_bytes: 0,
            artifacts: String::new(),
            trace: false,
            heartbeat: false,
            checkpoint: String::new(),
            restore: false,
            transport: TransportKind::File,
            recv_timeout_ms: 0,
        };
        let bytes = c.to_bytes();
        assert!(RunConfig::from_bytes(&bytes[..bytes.len() - 3]).is_err());
    }
}
