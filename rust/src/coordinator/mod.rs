//! Leader/worker coordination — the client-server model of §II
//! applied as the paper applies it: workers run the benchmark
//! independently and "communicate only with the leader"; results are
//! aggregated at the end over the messaging transport (§V).
//!
//! Protocol (both exchanges route through [`crate::collective`]):
//! 1. leader broadcasts [`RunConfig`] (star bootstrap, legacy CONFIG
//!    tag under `--coll star`);
//! 2. everyone (leader included) runs the configured STREAM;
//! 3. reports are gathered under the configured `--coll` algorithm
//!    (legacy RESULT tag under star); the leader folds them into an
//!    [`crate::stream::AggregateResult`].

pub mod leader;
pub mod results;
pub mod worker;

pub use leader::run_leader;
pub use results::{EngineKind, MapKind, RunConfig, WorkerReport};
pub use worker::{run_configured_stream, run_worker};
