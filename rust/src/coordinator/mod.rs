//! Leader/worker coordination — the client-server model of §II
//! applied as the paper applies it: workers run the benchmark
//! independently and "communicate only with the leader"; results are
//! aggregated at the end over the messaging transport (§V).
//!
//! Protocol (tags in [`crate::comm::tags`]):
//! 1. leader broadcasts [`RunConfig`] (CONFIG) to every worker;
//! 2. everyone (leader included) runs the configured STREAM;
//! 3. workers send a [`WorkerReport`] (RESULT); the leader folds them
//!    into an [`crate::stream::AggregateResult`].

pub mod leader;
pub mod results;
pub mod worker;

pub use leader::run_leader;
pub use results::{EngineKind, MapKind, RunConfig, WorkerReport};
pub use worker::{run_configured_stream, run_worker};
